//! Extension ablation: CPL median gain vs configuration cost.
//!
//! The paper reports a 1.40x median utilization gain from configuration
//! pre-loading; our executed RV32I config stream is cheaper than their
//! Snitch runtime's, so our median gain is smaller (EXPERIMENTS.md E1
//! deviations). This bench sweeps the CSRManager handshake latency —
//! the knob standing in for "how expensive is one configuration" — and
//! shows the CPL gain growing toward and past the paper's 1.40x,
//! bracketing their implied operating point.
//!
//! Run with:  cargo bench --bench ablation_cpl_sensitivity

use std::time::Instant;

use opengemm::config::{Mechanisms, PlatformConfig};
use opengemm::coordinator::{Coordinator, JobRequest};
use opengemm::util::stats::BoxStats;
use opengemm::util::table::Table;
use opengemm::workloads::random_suite;

fn median_utilization(cfg: &PlatformConfig, latency: u64, mech: Mechanisms) -> f64 {
    let coord = Coordinator::new(cfg.clone()).with_csr_latency(latency);
    let shapes = random_suite(2024, 150);
    let reqs: Vec<JobRequest> = shapes
        .iter()
        .map(|&s| JobRequest::timing(s, mech, 10))
        .collect();
    let samples: Vec<f64> = coord
        .run_batch(reqs)
        .into_iter()
        .map(|r| r.expect("job").report.overall)
        .collect();
    BoxStats::compute(&samples).expect("nonempty sample set").median
}

fn main() {
    let cfg = PlatformConfig::case_study();
    let mut table = Table::new(&[
        "csr handshake (cycles)", "median OU no-CPL", "median OU CPL", "CPL gain",
    ]);
    let t0 = Instant::now();
    let mut crossed_paper = None;
    for latency in [2u64, 8, 24, 48, 80, 128, 192] {
        let base = median_utilization(&cfg, latency, Mechanisms::BASELINE);
        let cpl = median_utilization(&cfg, latency, Mechanisms::CPL);
        let gain = cpl / base;
        if crossed_paper.is_none() && gain >= 1.40 {
            crossed_paper = Some(latency);
        }
        table.row(vec![
            latency.to_string(),
            format!("{base:.4}"),
            format!("{cpl:.4}"),
            format!("{gain:.2}x"),
        ]);
    }
    println!("## CPL gain vs configuration cost (150 workloads x 10 repeats)\n");
    println!("{}", table.markdown());
    match crossed_paper {
        Some(l) => println!(
            "\npaper's 1.40x median CPL gain is reached at ~{l} cycles/CSR access —\n\
             the implied cost of the paper's Snitch configuration path."
        ),
        None => println!("\npaper's 1.40x not reached in the swept range."),
    }
    println!("bench ablation_cpl_sensitivity: {:.1}s wall", t0.elapsed().as_secs_f64());
}
