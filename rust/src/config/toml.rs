//! Minimal TOML-subset parser for platform config files.
//!
//! Supports: `[section]` headers, `key = value` with integer, float,
//! boolean and basic-string values, `#` comments and blank lines. This is
//! all the surface the config files use; nested tables, arrays and dates
//! are rejected loudly rather than mis-parsed.

use std::collections::BTreeMap;

#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Int(i64),
    Float(f64),
    Bool(bool),
    Str(String),
}

impl TomlValue {
    pub fn as_int(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }
}

pub type TomlDoc = BTreeMap<String, BTreeMap<String, TomlValue>>;

/// Parse a TOML-subset document into `section -> key -> value`.
/// Top-level keys (before any section header) land in section `""`.
pub fn parse_toml(text: &str) -> Result<TomlDoc, String> {
    let mut doc: TomlDoc = BTreeMap::new();
    let mut section = String::new();
    doc.entry(section.clone()).or_default();

    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| format!("line {}: unterminated section header", lineno + 1))?
                .trim();
            if name.is_empty() || name.contains('[') || name.contains('.') {
                return Err(format!("line {}: unsupported section {name:?}", lineno + 1));
            }
            section = name.to_string();
            doc.entry(section.clone()).or_default();
            continue;
        }
        let (key, value) = line
            .split_once('=')
            .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
        let key = key.trim();
        if key.is_empty() {
            return Err(format!("line {}: empty key", lineno + 1));
        }
        let value = parse_value(value.trim())
            .map_err(|e| format!("line {}: {e}", lineno + 1))?;
        doc.get_mut(&section).unwrap().insert(key.to_string(), value);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // '#' inside a basic string does not start a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(body) = s.strip_prefix('"') {
        let body = body
            .strip_suffix('"')
            .ok_or_else(|| format!("unterminated string: {s:?}"))?;
        if body.contains('\\') {
            return Err("string escapes not supported".into());
        }
        return Ok(TomlValue::Str(body.to_string()));
    }
    if s.starts_with('[') || s.starts_with('{') {
        return Err(format!("arrays/inline tables not supported: {s:?}"));
    }
    let cleaned = s.replace('_', "");
    if let Ok(i) = cleaned.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = cleaned.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("cannot parse value {s:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = parse_toml(
            r#"
# platform file
top = 1

[core]
mu = 8          # rows
scale = 1.5
name = "gemm"
fast = true
big = 1_000_000
"#,
        )
        .unwrap();
        assert_eq!(doc[""]["top"], TomlValue::Int(1));
        assert_eq!(doc["core"]["mu"].as_int(), Some(8));
        assert_eq!(doc["core"]["scale"].as_f64(), Some(1.5));
        assert_eq!(doc["core"]["name"].as_str(), Some("gemm"));
        assert_eq!(doc["core"]["fast"].as_bool(), Some(true));
        assert_eq!(doc["core"]["big"].as_int(), Some(1_000_000));
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let doc = parse_toml(r##"k = "a#b""##).unwrap();
        assert_eq!(doc[""]["k"].as_str(), Some("a#b"));
    }

    #[test]
    fn rejects_unsupported_syntax() {
        assert!(parse_toml("[a.b]\n").is_err());
        assert!(parse_toml("k = [1, 2]\n").is_err());
        assert!(parse_toml("k =\n").is_err());
        assert!(parse_toml("just a line\n").is_err());
        assert!(parse_toml("[unterminated\n").is_err());
    }

    #[test]
    fn later_keys_override() {
        let doc = parse_toml("[s]\nk = 1\nk = 2\n").unwrap();
        assert_eq!(doc["s"]["k"].as_int(), Some(2));
    }
}
