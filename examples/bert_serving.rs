//! Serving-style driver, now a thin front-end over the sustained-
//! traffic harness (`opengemm::serve`): a seeded arrival process
//! (open-loop Poisson by default) pushes BERT encoder-layer requests
//! at mixed sequence lengths through the virtual-time queueing model,
//! and the report carries p50/p90/p95/p99/max per-request latency —
//! the platform acting as an edge inference service.
//!
//! The old one-shot loop in this example clamped the per-head repeat
//! count to 12 (silently mismeasuring any model with more heads);
//! the harness's service model honors true repeat counts — try
//! `--workload bert-large` (16 heads) to exercise exactly that case.
//!
//! Run with:
//!   cargo run --release --example bert_serving -- [--requests N]
//!     [--workload bert|bert-large|resnet18|mixed] [--rate RPS]
//!     [--arrival poisson|closed --clients N] [--seed S]
//!     [--devices N --placement round-robin|least-work|affinity]

use std::time::Instant;

use opengemm::config::PlatformConfig;
use opengemm::serve::{
    ms_to_cycles, run_serve, ArrivalSpec, BatchPolicy, PlacementPolicy, ServeOptions, WorkloadSpec,
};
use opengemm::util::cli::Args;
use opengemm::{anyhow, bail};

fn main() -> opengemm::util::error::Result<()> {
    let args = Args::from_env()?;
    let cfg = PlatformConfig::case_study();
    let workload_name = args.get_or("workload", "bert");
    let workload =
        WorkloadSpec::from_name(workload_name, &WorkloadSpec::DEFAULT_SEQS).ok_or_else(|| {
            anyhow!("--workload must be bert|bert-large|resnet18|mixed, got {workload_name:?}")
        })?;
    let arrival = match args.get_or("arrival", "poisson") {
        "poisson" => ArrivalSpec::OpenPoisson { rate_rps: args.f64_or("rate", 200.0)? },
        "closed" => ArrivalSpec::ClosedLoop {
            clients: args.usize_or("clients", 4)?,
            think_cycles: ms_to_cycles(args.f64_or("think-ms", 0.0)?, cfg.freq_mhz),
        },
        other => bail!("--arrival must be poisson|closed, got {other:?}"),
    };
    let placement_name = args.get_or("placement", "round-robin");
    let placement = PlacementPolicy::from_name(placement_name).ok_or_else(|| {
        anyhow!("--placement must be {}, got {placement_name:?}", PlacementPolicy::VALID_NAMES)
    })?;
    let opts = ServeOptions {
        workload,
        arrival,
        batching: BatchPolicy::Immediate,
        requests: args.usize_or("requests", 32)?,
        seed: args.u64_or("seed", 1)?,
        fast_forward: args.enabled_unless_no("fast-forward"),
        devices: args.usize_or("devices", 1)?,
        placement,
        ..Default::default()
    };

    println!(
        "serving {} {} requests ({} arrivals, seed {}) ...\n",
        opts.requests,
        workload_name,
        opts.arrival.label(),
        opts.seed
    );
    let t0 = Instant::now();
    let report = run_serve(&cfg, &opts).map_err(|e| anyhow!(e))?;
    let wall = t0.elapsed().as_secs_f64();

    println!("{}", report.render());
    println!(
        "\nsimulation wall-clock: {wall:.2}s ({:.1} M simulated cycles/s)",
        report.measurement.simulated_cycles as f64 / wall.max(1e-9) / 1e6
    );
    Ok(())
}
