//! The software stack: tiling, data-layout planning, convolution
//! lowering, and RV32I configuration-code generation.
//!
//! `compile_gemm` is the top-level entry: it splits a GeMM over the SPM
//! capacity, plans per-call placements under the chosen layout, and
//! generates the host program that configures and launches every call
//! (with or without configuration pre-loading).

pub mod codegen;
pub mod im2col;
pub mod layout;
pub mod tiling;

pub use codegen::{config_instruction_estimate, gen_config_program, CsrImage};
pub use im2col::{im2col as im2col_transform, weights_to_b, ConvShape};
pub use layout::{pack_a, pack_b, plan, unpack_c, Layout, Placement};
pub use tiling::{call_footprint, split_for_capacity, GemmBlock, GemmShape, SplitError};

use std::sync::Arc;

use crate::config::PlatformConfig;

/// One compiled accelerator call.
#[derive(Debug, Clone)]
pub struct CompiledCall {
    pub block: GemmBlock,
    pub placement: Placement,
}

/// A fully compiled GeMM job: calls + host configuration program.
#[derive(Debug, Clone)]
pub struct CompiledJob {
    pub shape: GemmShape,
    pub layout: Layout,
    pub repeats: u32,
    pub cpl: bool,
    /// Shared so the simulator can reference the call list per run
    /// without deep-copying every placement (`Arc` clone instead).
    pub calls: Arc<[CompiledCall]>,
    /// RV32I machine code for the host.
    pub program: Vec<u32>,
}

impl CompiledJob {
    /// Total ideal compute cycles per repeat (sum over calls).
    pub fn ideal_cycles(&self, cfg: &PlatformConfig) -> u64 {
        self.calls
            .iter()
            .map(|c| c.block.shape.ideal_cycles(&cfg.core))
            .sum()
    }

    /// Aggregate spatial utilization over all calls (real MACs over
    /// array-slot MACs).
    pub fn spatial_utilization(&self, cfg: &PlatformConfig) -> f64 {
        let real: u64 = self.calls.iter().map(|c| c.block.shape.macs()).sum();
        let padded: u64 = self
            .calls
            .iter()
            .map(|c| c.block.shape.padded_macs(&cfg.core))
            .sum();
        real as f64 / padded as f64
    }
}

/// Compile a GeMM for the platform.
pub fn compile_gemm(
    cfg: &PlatformConfig,
    shape: GemmShape,
    layout: Layout,
    repeats: u32,
    cpl: bool,
) -> Result<CompiledJob, SplitError> {
    let blocks = split_for_capacity(cfg, shape, layout)?;
    let calls: Arc<[CompiledCall]> = blocks
        .into_iter()
        .map(|block| CompiledCall {
            placement: plan(cfg, &block.shape, layout),
            block,
        })
        .collect();
    let images: Vec<CsrImage> = calls.iter().map(|c| c.placement.csr_writes.clone()).collect();
    let program = gen_config_program(&images, repeats, cpl);
    Ok(CompiledJob { shape, layout, repeats, cpl, calls, program })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PlatformConfig;

    #[test]
    fn compile_single_call_job() {
        let cfg = PlatformConfig::case_study();
        let job =
            compile_gemm(&cfg, GemmShape::new(64, 64, 64), Layout::TiledInterleaved, 10, true)
                .unwrap();
        assert_eq!(job.calls.len(), 1);
        assert_eq!(job.ideal_cycles(&cfg), 512);
        assert_eq!(job.spatial_utilization(&cfg), 1.0);
        assert!(!job.program.is_empty());
    }

    #[test]
    fn compile_split_job_has_multiple_calls() {
        let cfg = PlatformConfig::case_study();
        let job = compile_gemm(&cfg, GemmShape::new(256, 256, 256), Layout::RowMajor, 1, false)
            .unwrap();
        assert!(job.calls.len() >= 2);
        // per-repeat ideal cycles equal the unsplit ideal (split changes
        // locality, not work)
        assert_eq!(job.ideal_cycles(&cfg), 32 * 32 * 32);
    }

    #[test]
    fn irregular_shape_su_below_one() {
        let cfg = PlatformConfig::case_study();
        let job = compile_gemm(&cfg, GemmShape::new(13, 22, 17), Layout::TiledInterleaved, 1, true)
            .unwrap();
        let su = job.spatial_utilization(&cfg);
        assert!(su < 1.0 && su > 0.3, "su = {su}");
    }
}
