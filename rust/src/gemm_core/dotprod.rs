//! Functional datapath of the 3D MAC array (Fig. 3).
//!
//! The array is an `(Mu, Nu)` mesh of `Ku`-wide dot-product units. In one
//! cycle it consumes an A' tile `(Mu x Ku)` and a B' tile `(Ku x Nu)` and
//! accumulates into the `(Mu x Nu)` int32 accumulator register file
//! (output-stationary). Products and sums are two's-complement wrapping,
//! like the RTL (no saturation on the accumulate path).
//!
//! ## Vectorization contract
//!
//! [`tile_mac`] is the hot loop of every functional simulation — the
//! event engine (PR 1) removed the idle-cycle overhead, so one tile-MAC
//! per *compute* cycle is what a functional run spends its time on. The
//! kernel is written so LLVM's autovectorizer lifts the inner loop to
//! 8-wide (or wider) i32 SIMD:
//!
//! - **Flat row-major slices.** `a` is `(Mu, Ku)` row-major, `b` is
//!   `(Ku, Nu)` row-major, and each accumulator row is a contiguous
//!   `Nu`-wide `&mut [i32]` — no strided or gathered element access
//!   anywhere on the fast path.
//! - **Branch-free inner loop.** The seed kernel skipped zero A operands
//!   with a *per-element* branch, which blocks vectorization. The
//!   layout packers ([`crate::compiler::layout`]) place all K-padding
//!   zeros at the *tail* of each A' row, so the skip is now a per-row
//!   `ku_nonzero` prefix computed once (`rposition` over the row); the
//!   `j` loop over `Nu` accumulators is a pure
//!   `acc[j] += a_ik * b[k][j]` multiply-add with no data-dependent
//!   control flow.
//! - **Wrapping arithmetic.** All products and sums use `wrapping_*`,
//!   matching the RTL's two's-complement behaviour; this also keeps the
//!   loop free of overflow panics the vectorizer would have to preserve.
//!
//! Zero A operands *inside* the nonzero prefix are multiplied normally
//! (they contribute nothing); only the all-zero suffix is skipped, so
//! the kernel is bit-identical to the naive triple loop for any input.
//!
//! An explicit `std::arch` path (AVX2 `_mm256_madd_epi16`-style) is a
//! follow-up seam behind the `simd-arch` cargo feature: the dispatch
//! point and signature are pinned by [`tile_mac`]'s private kernel
//! split, and [`tile_mac_reference`] plus the `matches_naive_reference`
//! property pin the semantics any intrinsic kernel must reproduce.

use crate::config::GemmCoreParams;

/// The accumulator register file of the DotProd mesh.
#[derive(Debug, Clone)]
pub struct Accumulators {
    pub acc: Vec<i32>,
    mu: usize,
    nu: usize,
}

impl Accumulators {
    pub fn new(core: &GemmCoreParams) -> Accumulators {
        Accumulators {
            acc: vec![0; core.mu * core.nu],
            mu: core.mu,
            nu: core.nu,
        }
    }

    /// Hardware "accumulator reset" issued by the loop controller at
    /// k1 == 0.
    pub fn reset(&mut self) {
        self.acc.fill(0);
    }

    /// Row `i` of the accumulator file as a contiguous `Nu`-wide slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[i32] {
        &self.acc[i * self.nu..(i + 1) * self.nu]
    }

    /// Mutable row access (the tile-MAC kernel's accumulate target).
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [i32] {
        &mut self.acc[i * self.nu..(i + 1) * self.nu]
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> i32 {
        self.row(i)[j]
    }

    /// Copy the accumulators into a reusable output-tile buffer — the
    /// zero-copy staging path ([`crate::streamer::TileArena`] owns the
    /// buffer; nothing is allocated per tile).
    pub fn copy_into(&self, out: &mut [i32]) {
        out.copy_from_slice(&self.acc);
    }

    /// Snapshot the accumulators as a fresh output tile payload
    /// (allocating convenience; the simulator uses [`Self::copy_into`]).
    pub fn snapshot(&self) -> Box<[i32]> {
        self.acc.clone().into_boxed_slice()
    }

    pub fn mu(&self) -> usize {
        self.mu
    }

    pub fn nu(&self) -> usize {
        self.nu
    }
}

/// One array cycle: `acc[i][j] += sum_k a[i][k] * b[k][j]`.
///
/// `a` is row-major `(Mu, Ku)`, `b` is row-major `(Ku, Nu)`. All `Ku`
/// products per DotProd are combinationally summed, exactly one result
/// update per accumulator per cycle. See the module docs for the
/// vectorization contract this entry point upholds.
pub fn tile_mac(acc: &mut Accumulators, core: &GemmCoreParams, a: &[i8], b: &[i8]) {
    let (mu, nu, ku) = (core.mu, core.nu, core.ku);
    debug_assert_eq!(a.len(), mu * ku, "A' tile size");
    debug_assert_eq!(b.len(), ku * nu, "B' tile size");
    tile_mac_kernel(&mut acc.acc, a, b, mu, nu, ku);
}

/// Kernel dispatch: the portable autovectorized kernel today; the
/// `simd-arch` feature routes through the `std::arch` seam instead.
#[cfg(not(all(feature = "simd-arch", target_arch = "x86_64")))]
#[inline]
fn tile_mac_kernel(acc: &mut [i32], a: &[i8], b: &[i8], mu: usize, nu: usize, ku: usize) {
    tile_mac_rows(acc, a, b, mu, nu, ku);
}

#[cfg(all(feature = "simd-arch", target_arch = "x86_64"))]
#[inline]
fn tile_mac_kernel(acc: &mut [i32], a: &[i8], b: &[i8], mu: usize, nu: usize, ku: usize) {
    arch::tile_mac(acc, a, b, mu, nu, ku);
}

/// The portable fast path: per-row zero-suffix skip, branch-free i32
/// multiply-accumulate over contiguous `Nu`-wide rows.
#[inline]
fn tile_mac_rows(acc: &mut [i32], a: &[i8], b: &[i8], mu: usize, nu: usize, ku: usize) {
    for i in 0..mu {
        let arow = &a[i * ku..(i + 1) * ku];
        // K-padding zeros sit at the row tail (layout packer contract);
        // skip the all-zero suffix once instead of branching per MAC.
        let ku_nz = arow.iter().rposition(|&v| v != 0).map_or(0, |last| last + 1);
        let accrow = &mut acc[i * nu..(i + 1) * nu];
        for (k, &av) in arow[..ku_nz].iter().enumerate() {
            let av = av as i32;
            let brow = &b[k * nu..(k + 1) * nu];
            for (c, &bv) in accrow.iter_mut().zip(brow.iter()) {
                *c = c.wrapping_add(av.wrapping_mul(bv as i32));
            }
        }
    }
}

/// Explicit-SIMD seam (`--features simd-arch`, x86_64 only). The
/// intrinsic kernel is intentionally not written yet: this module pins
/// the dispatch point so a `std::arch` implementation can land without
/// touching any caller, and until then it must stay bit-identical to
/// the portable kernel (delegation guarantees that trivially).
#[cfg(all(feature = "simd-arch", target_arch = "x86_64"))]
mod arch {
    #[inline]
    pub(super) fn tile_mac(acc: &mut [i32], a: &[i8], b: &[i8], mu: usize, nu: usize, ku: usize) {
        super::tile_mac_rows(acc, a, b, mu, nu, ku);
    }
}

/// The seed's scalar kernel (per-element zero branch, no row slicing),
/// kept verbatim as the differential reference for the vectorized path
/// and the `BENCH_dotprod_throughput` speedup baseline.
pub fn tile_mac_reference(acc: &mut Accumulators, core: &GemmCoreParams, a: &[i8], b: &[i8]) {
    let (mu, nu, ku) = (core.mu, core.nu, core.ku);
    debug_assert_eq!(a.len(), mu * ku, "A' tile size");
    debug_assert_eq!(b.len(), ku * nu, "B' tile size");
    for i in 0..mu {
        let arow = &a[i * ku..(i + 1) * ku];
        let accrow = &mut acc.acc[i * nu..(i + 1) * nu];
        for (k, &av) in arow.iter().enumerate() {
            if av == 0 {
                continue; // zero operand contributes nothing (incl. padding)
            }
            let av = av as i32;
            let brow = &b[k * nu..(k + 1) * nu];
            for (j, &bv) in brow.iter().enumerate() {
                accrow[j] = accrow[j].wrapping_add(av.wrapping_mul(bv as i32));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GemmCoreParams;
    use crate::util::check::property;
    use crate::util::rng::Pcg32;

    fn core() -> GemmCoreParams {
        GemmCoreParams::CASE_STUDY
    }

    fn naive(a: &[i8], b: &[i8], mu: usize, nu: usize, ku: usize) -> Vec<i32> {
        let mut c = vec![0i32; mu * nu];
        for i in 0..mu {
            for j in 0..nu {
                for k in 0..ku {
                    c[i * nu + j] = c[i * nu + j]
                        .wrapping_add((a[i * ku + k] as i32).wrapping_mul(b[k * nu + j] as i32));
                }
            }
        }
        c
    }

    #[test]
    fn identity_tile() {
        let c = core();
        let mut acc = Accumulators::new(&c);
        let mut a = vec![0i8; 64];
        for i in 0..8 {
            a[i * 8 + i] = 1; // identity
        }
        let b: Vec<i8> = (0..64).map(|i| (i as i8).wrapping_mul(3)).collect();
        tile_mac(&mut acc, &c, &a, &b);
        for i in 0..8 {
            for j in 0..8 {
                assert_eq!(acc.at(i, j), b[i * 8 + j] as i32);
            }
        }
    }

    #[test]
    fn accumulation_across_cycles() {
        let c = core();
        let mut acc = Accumulators::new(&c);
        let a = vec![1i8; 64];
        let b = vec![1i8; 64];
        tile_mac(&mut acc, &c, &a, &b);
        tile_mac(&mut acc, &c, &a, &b);
        assert_eq!(acc.at(0, 0), 16); // 8 per cycle, 2 cycles
        acc.reset();
        assert_eq!(acc.at(0, 0), 0);
    }

    #[test]
    fn wrapping_semantics() {
        let mut p = core();
        p.ku = 1;
        let mut acc = Accumulators::new(&p);
        // pre-load near overflow by repeated max products
        let a = vec![i8::MIN; 8];
        let b = vec![i8::MIN; 8];
        // (-128)^2 = 16384; 131072 iterations exceed i32::MAX -> wraps
        for _ in 0..140_000 {
            tile_mac(&mut acc, &p, &a, &b);
        }
        // must not panic; value defined by wrapping arithmetic
        let expect = (16384i64 * 140_000) as i128;
        let wrapped = (expect % (1i128 << 32)) as i64;
        let wrapped = if wrapped > i32::MAX as i64 { wrapped - (1i64 << 32) } else { wrapped };
        assert_eq!(acc.at(0, 0) as i64, wrapped);
    }

    #[test]
    fn matches_naive_reference() {
        property("tile_mac vs naive", 40, |rng| {
            let c = core();
            let mut a = vec![0i8; c.mu * c.ku];
            let mut b = vec![0i8; c.ku * c.nu];
            rng.fill_i8(&mut a);
            rng.fill_i8(&mut b);
            let mut acc = Accumulators::new(&c);
            tile_mac(&mut acc, &c, &a, &b);
            let want = naive(&a, &b, c.mu, c.nu, c.ku);
            crate::prop_assert_eq!(acc.acc, want, "tile MAC mismatch");
            Ok(())
        });
    }

    #[test]
    fn vectorized_matches_reference_kernel() {
        // Differential proof of the rewrite: the vectorized kernel must
        // be bit-identical to the seed's scalar kernel across random
        // generator instances, random starting accumulators, and rows
        // with zero suffixes (the K-padding pattern) and interior zeros.
        property("tile_mac vectorized vs seed kernel", 60, |rng| {
            let p = GemmCoreParams {
                mu: rng.below(12) as usize + 1,
                nu: rng.below(12) as usize + 1,
                ku: rng.below(20) as usize + 1,
                ..GemmCoreParams::CASE_STUDY
            };
            let mut a = vec![0i8; p.mu * p.ku];
            let mut b = vec![0i8; p.ku * p.nu];
            rng.fill_i8(&mut a);
            rng.fill_i8(&mut b);
            // zero out random row suffixes of A (padding pattern) and a
            // few interior elements (must be multiplied, not skipped,
            // identically in both kernels)
            for i in 0..p.mu {
                let keep = rng.below(p.ku as u32 + 1) as usize;
                for v in &mut a[i * p.ku + keep..(i + 1) * p.ku] {
                    *v = 0;
                }
            }
            for _ in 0..4 {
                a[rng.below((p.mu * p.ku) as u32) as usize] = 0;
            }
            let mut start = Accumulators::new(&p);
            let mut seed_rng = Pcg32::seeded(rng.next_u64());
            for v in start.acc.iter_mut() {
                *v = seed_rng.next_u32() as i32;
            }
            let mut fast = start.clone();
            let mut refr = start;
            tile_mac(&mut fast, &p, &a, &b);
            tile_mac_reference(&mut refr, &p, &a, &b);
            crate::prop_assert_eq!(fast.acc, refr.acc, "kernel divergence for {p:?}");
            Ok(())
        });
    }

    #[test]
    fn non_square_generator_instance() {
        let p = GemmCoreParams { mu: 4, nu: 2, ku: 16, ..GemmCoreParams::CASE_STUDY };
        let mut acc = Accumulators::new(&p);
        let a: Vec<i8> = (0..64).map(|i| (i % 5) as i8 - 2).collect();
        let b: Vec<i8> = (0..32).map(|i| (i % 7) as i8 - 3).collect();
        tile_mac(&mut acc, &p, &a, &b);
        assert_eq!(acc.acc, naive(&a, &b, 4, 2, 16));
    }

    #[test]
    fn row_accessors_and_copy_into() {
        let c = core();
        let mut acc = Accumulators::new(&c);
        let a = vec![1i8; 64];
        let b: Vec<i8> = (0..64).map(|i| i as i8).collect();
        tile_mac(&mut acc, &c, &a, &b);
        // row view matches flat indexing
        for i in 0..8 {
            assert_eq!(acc.row(i), &acc.acc[i * 8..(i + 1) * 8]);
        }
        acc.row_mut(2)[3] = 77;
        assert_eq!(acc.at(2, 3), 77);
        let mut out = vec![0i32; 64];
        acc.copy_into(&mut out);
        assert_eq!(out, acc.acc);
        assert_eq!(&*acc.snapshot(), out.as_slice());
    }
}
