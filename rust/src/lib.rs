//! # OpenGeMM — reproduction library
//!
//! A cycle-accurate, functionally-verified model of the OpenGeMM
//! acceleration platform (Yi et al., ASPDAC'25): a parameterized GeMM
//! accelerator generator with a lightweight RV32I host, tightly-coupled
//! multi-banked scratchpad, and data streamers, plus the paper's three
//! utilization mechanisms (configuration pre-loading, input pre-fetch /
//! output buffering, and strided memory access).
//!
//! See DESIGN.md for the system inventory and experiment index, and
//! EXPERIMENTS.md for reproduced paper results.

pub mod analysis;
pub mod baseline;
pub mod compiler;
pub mod config;
pub mod coordinator;
pub mod csr;
pub mod experiments;
pub mod gemm_core;
pub mod host;
pub mod model;
pub mod power;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod spm;
pub mod streamer;
pub mod util;
pub mod workloads;
