//! Fig. 5: utilization ablation of the three mechanisms over random
//! GeMM workloads.
//!
//! 500 random (M, K, N) sizes from {8, 16, ..., 256}, 10 repeats each;
//! seven architecture variants:
//!   Arch1  baseline (no CPL, no prefetch/output buffering, row-major)
//!   Arch2  + configuration pre-loading
//!   Arch3  + input pre-fetch & output buffering (depth 2)
//!   Arch4  + strided memory access (depth 2)
//!   Arch4 d3 / d4: buffer depth 3 and 4
//! plus the shipping default (depth D_stream = 3).

use crate::compiler::GemmShape;
use crate::config::{Mechanisms, PlatformConfig};
use crate::coordinator::cache::ResultCache;
use crate::coordinator::shard::{run_sweep_cached, SweepOptions};
use crate::coordinator::JobRequest;
use crate::model::prefilter;
use crate::util::stats::BoxStats;
use crate::util::table::{ascii_box, fmt_f, Table};
use crate::workloads::random_suite;

#[derive(Debug, Clone, Copy)]
pub struct Fig5Options {
    pub seed: u64,
    pub workloads: usize,
    pub repeats: u32,
    pub workers: usize,
    /// In-process shards per variant batch (0 or 1 = unsharded; each
    /// batch runs through `coordinator::dispatch` — the multi-process
    /// and cross-host transports are the `sweep` CLI's).
    pub shards: usize,
    /// Event-driven cycle skipping (cycle-exact; off only for
    /// differential checks).
    pub fast_forward: bool,
    /// `Some(k)`: rank the ladder with the analytical cost model and
    /// simulate only the top-k variants; pruned rungs keep their
    /// predicted utilization distribution (marked in the rendering).
    /// `None` simulates every rung.
    pub prefilter_confirm_top: Option<usize>,
}

impl Default for Fig5Options {
    fn default() -> Self {
        Fig5Options {
            seed: 2024,
            workloads: 500,
            repeats: 10,
            workers: 0,
            shards: 1,
            fast_forward: true,
            prefilter_confirm_top: None,
        }
    }
}

/// One variant's label + distribution of overall utilization.
#[derive(Debug, Clone)]
pub struct Fig5Variant {
    pub label: String,
    pub buffer_depth: usize,
    pub stats: BoxStats,
    pub samples: Vec<f64>,
    /// True when the prefilter pruned this rung: `samples`/`stats`
    /// come from the closed-form model, not from simulation.
    pub predicted_only: bool,
}

#[derive(Debug, Clone)]
pub struct Fig5Result {
    pub variants: Vec<Fig5Variant>,
    pub shapes: Vec<GemmShape>,
}

/// The paper's variant ladder: `(label, mechanisms, buffer depth)`.
/// Public because the `sweep` CLI plans its multi-process Fig. 5
/// slices from the same ladder.
pub fn variant_specs() -> Vec<(&'static str, Mechanisms, usize)> {
    vec![
        ("Arch1 baseline", Mechanisms::BASELINE, 2),
        ("Arch2 +CPL", Mechanisms::CPL, 2),
        ("Arch3 +prefetch/outbuf d2", Mechanisms::CPL_BUF, 2),
        ("Arch4 +SMA d2", Mechanisms::ALL, 2),
        ("Arch4 depth 3", Mechanisms::ALL, 3),
        ("Arch4 depth 4", Mechanisms::ALL, 4),
    ]
}

/// The platform instance of one variant: base config at the variant's
/// buffer depth.
pub fn variant_config(base_cfg: &PlatformConfig, depth: usize) -> PlatformConfig {
    let mut cfg = base_cfg.clone();
    cfg.mem.d_stream = depth;
    cfg
}

pub fn fig5_ablation(base_cfg: &PlatformConfig, opts: Fig5Options) -> Fig5Result {
    fig5_ablation_cached(base_cfg, opts, None)
        .expect("uncached fig5 ablation cannot fail in dispatch")
}

/// [`fig5_ablation`] with an optional result cache in front of the
/// simulator: a re-run over an unchanged ladder (or one that shares
/// rungs with an earlier run — the cache composes with the prefilter,
/// which decides WHAT to simulate while the cache decides what still
/// NEEDS simulating) only prices the jobs it has never seen. Fallible
/// because a verify-mode cache hard-errors on divergence.
pub fn fig5_ablation_cached(
    base_cfg: &PlatformConfig,
    opts: Fig5Options,
    cache: Option<&ResultCache>,
) -> Result<Fig5Result, String> {
    let shapes = random_suite(opts.seed, opts.workloads);
    let sweep_opts = SweepOptions {
        shards: opts.shards,
        workers: opts.workers,
        fast_forward: opts.fast_forward,
        ..Default::default()
    };
    let grid: Vec<prefilter::GridVariant> = variant_specs()
        .into_iter()
        .map(|(label, mech, depth)| prefilter::GridVariant {
            label: label.to_string(),
            cfg: variant_config(base_cfg, depth),
            requests: shapes
                .iter()
                .map(|&shape| JobRequest::timing(shape, mech, opts.repeats))
                .collect(),
        })
        .collect();
    // With a prefilter budget, rank the ladder analytically and mark
    // everything outside the frontier as predicted-only.
    let (ranked, confirmed) = match opts.prefilter_confirm_top {
        None => (None, vec![true; grid.len()]),
        Some(k) => {
            let ranked = prefilter::rank_cached(&grid, sweep_opts.csr_latency, cache);
            let k = prefilter::confirm_count(grid.len(), Some(k), None);
            let keep = prefilter::frontier(&ranked, k);
            let mut mask = vec![false; grid.len()];
            for &i in &keep {
                mask[i] = true;
            }
            (Some(ranked), mask)
        }
    };
    let mut variants = Vec::new();
    for (variant, gv) in grid.iter().enumerate() {
        let depth = gv.cfg.mem.d_stream;
        let (samples, predicted_only): (Vec<f64>, bool) = if confirmed[variant] {
            let simulated = run_sweep_cached(&gv.cfg, gv.requests.clone(), sweep_opts, cache)?
                .outcomes
                .into_iter()
                .map(|r| r.expect("fig5 job failed").report.overall)
                .collect();
            (simulated, false)
        } else {
            let ranked = ranked.as_ref().expect("pruned variants imply a ranking");
            let predicted = ranked[variant]
                .predictions
                .iter()
                .map(|p| p.overall_utilization)
                .collect();
            (predicted, true)
        };
        variants.push(Fig5Variant {
            label: gv.label.clone(),
            buffer_depth: depth,
            stats: BoxStats::compute(&samples)
                .expect("fig5 runs at least one workload per variant"),
            samples,
            predicted_only,
        });
    }
    Ok(Fig5Result { variants, shapes })
}

impl Fig5Result {
    /// Median improvement ratios quoted in Sec. 4.2.
    pub fn median_ratios(&self) -> Vec<(String, f64)> {
        let med = |i: usize| self.variants[i].stats.median;
        vec![
            ("Arch2 / Arch1 (CPL)".into(), med(1) / med(0)),
            ("Arch3 / Arch2 (prefetch+outbuf)".into(), med(2) / med(1)),
            ("Arch4 / Arch3 (SMA)".into(), med(3) / med(2)),
            ("Arch4 / Arch1 (all)".into(), med(3) / med(0)),
        ]
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str("## Fig. 5 — utilization ablation (overall utilization)\n\n");
        let mut t = Table::new(&["variant", "min", "q1", "median", "q3", "max", "mean"]);
        for v in &self.variants {
            let s = &v.stats;
            let label = if v.predicted_only {
                format!("{} [predicted]", v.label)
            } else {
                v.label.clone()
            };
            t.row(vec![
                label,
                fmt_f(s.min, 4),
                fmt_f(s.q1, 4),
                fmt_f(s.median, 4),
                fmt_f(s.q3, 4),
                fmt_f(s.max, 4),
                fmt_f(s.mean, 4),
            ]);
        }
        out.push_str(&t.markdown());
        out.push_str("\n```\nutilization  0.0");
        out.push_str(&" ".repeat(48));
        out.push_str("1.0\n");
        for v in &self.variants {
            let s = &v.stats;
            out.push_str(&format!(
                "{:<26} {}\n",
                v.label,
                ascii_box(0.0, 1.0, 52, s.whisker_lo, s.q1, s.median, s.q3, s.whisker_hi)
            ));
        }
        out.push_str("```\n\n### Median improvements (paper: 1.40x / 2.02x / 1.18x / 2.78x)\n\n");
        let mut t = Table::new(&["step", "measured"]);
        for (name, ratio) in self.median_ratios() {
            t.row(vec![name, format!("{:.2}x", ratio)]);
        }
        out.push_str(&t.markdown());
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Reduced-size ablation: the full 500x10 suite runs in the bench;
    /// tests check the qualitative claims on a subsample.
    #[test]
    fn ablation_ordering_holds() {
        let cfg = PlatformConfig::case_study();
        let res = fig5_ablation(
            &cfg,
            Fig5Options { seed: 7, workloads: 40, repeats: 10, ..Default::default() },
        );
        let med: Vec<f64> = res.variants.iter().map(|v| v.stats.median).collect();
        // each mechanism must improve the median
        assert!(med[1] > med[0], "CPL: {} vs {}", med[1], med[0]);
        assert!(med[2] > med[1], "prefetch: {} vs {}", med[2], med[1]);
        assert!(med[3] > med[2], "SMA: {} vs {}", med[3], med[2]);
        // deeper buffers: utilization must not degrade, variance shrinks
        assert!(med[4] >= med[3] * 0.99);
        assert!(med[5] >= med[4] * 0.99);
        let iqr = |i: usize| res.variants[i].stats.q3 - res.variants[i].stats.q1;
        assert!(iqr(5) <= iqr(3) + 1e-9, "depth 4 IQR {} vs d2 {}", iqr(5), iqr(3));
        // overall improvement is substantial (paper: 2.78x)
        assert!(med[3] / med[0] > 1.5, "overall {}x", med[3] / med[0]);
    }

    /// The prefiltered ablation simulates only the confirmed frontier,
    /// marks everything else predicted-only, and still lands on the
    /// same winning rung as the full run.
    #[test]
    fn prefilter_simulates_only_the_frontier() {
        let cfg = PlatformConfig::case_study();
        let opts = Fig5Options { seed: 11, workloads: 12, repeats: 2, ..Default::default() };
        let full = fig5_ablation(&cfg, opts);
        let pruned = fig5_ablation(&cfg, Fig5Options { prefilter_confirm_top: Some(2), ..opts });
        let simulated: Vec<usize> = (0..pruned.variants.len())
            .filter(|&i| !pruned.variants[i].predicted_only)
            .collect();
        assert_eq!(simulated.len(), 2, "confirm-top 2 must simulate exactly 2 rungs");
        // The simulated rungs are byte-for-byte the full run's samples.
        for &i in &simulated {
            assert_eq!(pruned.variants[i].samples, full.variants[i].samples);
        }
        // The confirmed frontier carries the full run's best median (the
        // top Arch4 rungs differ only in buffer depth and sit within a
        // few percent of each other, so the check is on utility, not on
        // an exact index).
        let best_full = full.variants.iter().map(|v| v.stats.median).fold(0.0, f64::max);
        let best_kept = simulated
            .iter()
            .map(|&i| pruned.variants[i].stats.median)
            .fold(0.0, f64::max);
        assert!(
            best_kept >= 0.95 * best_full,
            "frontier best {best_kept} is not within 5% of the full run's best {best_full}"
        );
    }

    #[test]
    fn render_contains_all_variants() {
        let cfg = PlatformConfig::case_study();
        let res = fig5_ablation(
            &cfg,
            Fig5Options {
                seed: 3,
                workloads: 8,
                repeats: 2,
                workers: 2,
                shards: 2,
                ..Default::default()
            },
        );
        let text = res.render();
        for v in &res.variants {
            assert!(text.contains(&v.label));
        }
        assert!(text.contains("Median improvements"));
    }
}
