//! Property-based integration tests over the whole platform: random
//! shapes, layouts and mechanism sets, checking functional correctness
//! against a naive reference and cycle-level invariants.

use opengemm::compiler::{compile_gemm, pack_a, pack_b, plan, GemmShape, Layout};
use opengemm::config::{Mechanisms, PlatformConfig};
use opengemm::coordinator::{Coordinator, JobRequest};
use opengemm::prop_assert;
use opengemm::prop_assert_eq;
use opengemm::sim::{Platform, SimOptions};
use opengemm::spm::{Spm, SpmStats};
use opengemm::util::check::property;
use opengemm::util::rng::Pcg32;

fn naive_gemm(a: &[i8], b: &[i8], m: usize, k: usize, n: usize) -> Vec<i32> {
    let mut c = vec![0i32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0i32;
            for kk in 0..k {
                acc = acc.wrapping_add((a[i * k + kk] as i32).wrapping_mul(b[kk * n + j] as i32));
            }
            c[i * n + j] = acc;
        }
    }
    c
}

fn rand_shape(rng: &mut Pcg32, max: u32) -> GemmShape {
    GemmShape::new(
        rng.below(max) as usize + 1,
        rng.below(max) as usize + 1,
        rng.below(max) as usize + 1,
    )
}

#[test]
fn functional_correctness_over_random_configs() {
    let coord = Coordinator::new(PlatformConfig::case_study());
    property("platform functional == naive", 25, |rng| {
        let shape = rand_shape(rng, 48);
        let layout = *rng.choose(&[
            Layout::RowMajor,
            Layout::TiledContiguous,
            Layout::TiledInterleaved,
        ]);
        let mech = *rng.choose(&[
            Mechanisms::BASELINE,
            Mechanisms::CPL,
            Mechanisms::CPL_BUF,
            Mechanisms::ALL,
        ]);
        let mut a = vec![0i8; shape.m * shape.k];
        let mut b = vec![0i8; shape.k * shape.n];
        rng.fill_i8(&mut a);
        rng.fill_i8(&mut b);
        let req = JobRequest {
            shape,
            layout,
            mechanisms: mech,
            repeats: 1,
            operands: Some((a.clone(), b.clone())),
        };
        let r = coord.run_one(&req).map_err(|e| e)?;
        let want = naive_gemm(&a, &b, shape.m, shape.k, shape.n);
        prop_assert_eq!(
            r.c.as_ref().unwrap(),
            &want,
            "functional mismatch for {shape:?} {layout:?} {mech:?}"
        );
        Ok(())
    });
}

#[test]
fn compute_cycles_always_equal_ideal() {
    // mechanisms/layouts change stalls, never the number of tile-MACs
    let cfg = PlatformConfig::case_study();
    let coord = Coordinator::new(cfg.clone());
    property("compute cycles invariant", 30, |rng| {
        let shape = rand_shape(rng, 120);
        let mech = *rng.choose(&[Mechanisms::BASELINE, Mechanisms::ALL]);
        let repeats = rng.below(4) + 1;
        let req = JobRequest::timing(shape, mech, repeats);
        let r = coord.run_one(&req)?;
        let ideal = shape.ideal_cycles(&cfg.core);
        prop_assert_eq!(
            r.metrics.compute_cycles,
            ideal * repeats as u64,
            "compute != ideal x repeats for {shape:?}"
        );
        prop_assert_eq!(
            r.metrics.runs_completed,
            r.metrics.starts,
            "every start completes"
        );
        Ok(())
    });
}

#[test]
fn mechanisms_never_hurt() {
    let coord = Coordinator::new(PlatformConfig::case_study());
    property("arch ladder is monotone", 15, |rng| {
        let shape = rand_shape(rng, 100);
        let ladder = [
            Mechanisms::BASELINE,
            Mechanisms::CPL,
            Mechanisms::CPL_BUF,
            Mechanisms::ALL,
        ];
        let mut last = 0.0f64;
        for mech in ladder {
            let r = coord.run_one(&JobRequest::timing(shape, mech, 10))?;
            let ou = r.report.overall;
            prop_assert!(
                ou >= last * 0.98,
                "{} regressed: {ou} < {last} on {shape:?}",
                mech.label()
            );
            last = ou.max(last);
        }
        Ok(())
    });
}

#[test]
fn utilization_bounded_and_consistent() {
    let coord = Coordinator::new(PlatformConfig::case_study());
    property("0 < OU <= 1 and OU = SU*TU", 20, |rng| {
        let shape = rand_shape(rng, 200);
        let r = coord.run_one(&JobRequest::timing(shape, Mechanisms::ALL, 3))?;
        let rep = &r.report;
        prop_assert!(rep.spatial > 0.0 && rep.spatial <= 1.0, "SU {}", rep.spatial);
        prop_assert!(rep.temporal > 0.0 && rep.temporal <= 1.0, "TU {}", rep.temporal);
        prop_assert!(
            (rep.overall - rep.spatial * rep.temporal).abs() < 1e-12,
            "OU != SU*TU"
        );
        prop_assert!(
            r.metrics.kernel_cycles <= r.metrics.total_cycles,
            "kernel window exceeds total"
        );
        prop_assert!(
            r.metrics.compute_cycles <= r.metrics.kernel_cycles,
            "compute exceeds kernel window"
        );
        Ok(())
    });
}

#[test]
fn split_jobs_preserve_results_and_work() {
    // shapes that exceed SPM capacity split into multiple calls; the
    // result must be identical and compute cycles unchanged
    let cfg = PlatformConfig::case_study();
    let coord = Coordinator::new(cfg.clone());
    property("capacity splits are transparent", 6, |rng| {
        // big enough that A/B region + C cannot co-reside in 264 KiB
        let shape = GemmShape::new(
            232 + rng.below(24) as usize,
            192 + rng.below(64) as usize,
            232 + rng.below(24) as usize,
        );
        let job = compile_gemm(&cfg, shape, Layout::TiledInterleaved, 1, true)
            .map_err(|e| e.to_string())?;
        prop_assert!(job.calls.len() >= 2, "expected a split for {shape:?}");
        let mut a = vec![0i8; shape.m * shape.k];
        let mut b = vec![0i8; shape.k * shape.n];
        rng.fill_i8(&mut a);
        rng.fill_i8(&mut b);
        let req = JobRequest {
            shape,
            layout: Layout::TiledInterleaved,
            mechanisms: Mechanisms::ALL,
            repeats: 1,
            operands: Some((a.clone(), b.clone())),
        };
        let r = coord.run_one(&req)?;
        let want = naive_gemm(&a, &b, shape.m, shape.k, shape.n);
        prop_assert_eq!(r.c.as_ref().unwrap(), &want, "split-job result mismatch");
        Ok(())
    });
}

#[test]
fn cpl_gain_peaks_where_config_matches_compute() {
    // CPL hides configuration under compute, so the win is largest when
    // the two are comparable: too-small GeMMs are config-serial either
    // way (nothing to hide *under*), huge GeMMs amortize config anyway.
    let coord = Coordinator::new(PlatformConfig::case_study());
    let gain = |shape: GemmShape| {
        let base = coord
            .run_one(&JobRequest::timing(shape, Mechanisms::BASELINE, 10))
            .unwrap();
        let cpl = coord
            .run_one(&JobRequest::timing(shape, Mechanisms::CPL, 10))
            .unwrap();
        base.metrics.total_cycles as f64 / cpl.metrics.total_cycles as f64
    };
    let tiny = gain(GemmShape::new(8, 8, 8));
    let mid = gain(GemmShape::new(48, 48, 48));
    let large = gain(GemmShape::new(192, 192, 192));
    assert!(mid > 1.3, "mid-size CPL gain only {mid:.2}x");
    assert!(mid > tiny, "gain should peak mid-size: tiny {tiny:.2} mid {mid:.2}");
    assert!(mid > large, "gain should peak mid-size: large {large:.2} mid {mid:.2}");
    assert!(tiny >= 0.99 && large >= 0.99, "CPL never hurts");
}

#[test]
fn fast_forward_is_cycle_exact() {
    // The heap-scheduled cycle-skipping engine must produce *bit-identical*
    // SimMetrics (total/compute/stall/idle cycles, host counters, SPM
    // traffic) to the per-cycle lockstep loop, across a randomized
    // shape x layout x mechanisms x functional/timing grid — and across
    // every platform topology the scheduler serves: 1, 2, and 4 GeMM
    // cores, with and without the background-memory DMA engine. This is
    // the differential proof the fast-forward default rests on.
    property("fast-forward == lockstep", 24, |rng| {
        let mut cfg = PlatformConfig::case_study();
        cfg.cores = *rng.choose(&[1usize, 2, 4]);
        cfg.dma = if rng.below(2) == 1 {
            Some(opengemm::config::DmaParams {
                chunk_words: *rng.choose(&[8usize, 16, 64]),
                latency: rng.below(6) as u64,
            })
        } else {
            None
        };
        cfg.validate().map_err(|e| e.to_string())?;
        let shape = rand_shape(rng, 96);
        let layout = *rng.choose(&[
            Layout::RowMajor,
            Layout::TiledContiguous,
            Layout::TiledInterleaved,
        ]);
        let mech = *rng.choose(&[
            Mechanisms::BASELINE,
            Mechanisms::CPL,
            Mechanisms::CPL_BUF,
            Mechanisms::ALL,
        ]);
        let functional = rng.below(2) == 1;
        let repeats = rng.below(3) + 1;
        let job = compile_gemm(&cfg, shape, layout, repeats, mech.config_preloading)
            .map_err(|e| e.to_string())?;
        let operands = if functional {
            let mut a = vec![0i8; shape.m * shape.k];
            let mut b = vec![0i8; shape.k * shape.n];
            rng.fill_i8(&mut a);
            rng.fill_i8(&mut b);
            Some((a, b))
        } else {
            None
        };
        let run = |fast_forward: bool| -> Result<opengemm::sim::JobResult, String> {
            let opts = SimOptions {
                mechanisms: mech,
                functional,
                fast_forward,
                ..Default::default()
            };
            let mut platform = Platform::new(cfg.clone(), opts);
            let (a, b) = match &operands {
                Some((a, b)) => (Some(a.as_slice()), Some(b.as_slice())),
                None => (None, None),
            };
            platform.run_job(&job, a, b).map_err(|e| e.to_string())
        };
        let ff = run(true)?;
        let ls = run(false)?;
        prop_assert_eq!(
            ff.metrics,
            ls.metrics,
            "metrics diverge for {shape:?} {layout:?} {} functional={functional} x{repeats} \
             cores={} dma={:?}",
            mech.label(),
            cfg.cores,
            cfg.dma
        );
        prop_assert_eq!(ff.c, ls.c, "functional results diverge for {shape:?} {layout:?}");
        Ok(())
    });
}

/// The seed's per-byte SPM access path, reimplemented on top of the
/// word-granular primitives — the semantic reference the bulk I/O must
/// reproduce bit-for-bit.
fn read_byte_reference(spm: &Spm, addr: u64) -> u8 {
    (spm.read_word(addr / 8) >> ((addr % 8) * 8)) as u8
}

fn write_bytes_reference(spm: &mut Spm, byte_addr: u64, data: &[u8]) {
    for (i, &b) in data.iter().enumerate() {
        let addr = byte_addr + i as u64;
        let shift = (addr % 8) * 8;
        let word = spm.read_word(addr / 8);
        spm.write_word(addr / 8, (word & !(0xffu64 << shift)) | ((b as u64) << shift));
    }
}

#[test]
fn bulk_spm_io_matches_per_word() {
    // The bulk data plane (whole-word pack writes, gathered tile reads,
    // bulk i32 writeback) must be bit-identical to the seed's per-word/
    // per-byte path across random shapes and all three layouts — and
    // must leave the bank-conflict accounting exactly as the timing
    // calls produce it (functional I/O never touches SpmStats).
    let cfg = PlatformConfig::case_study();
    property("bulk SPM IO == per-word", 12, |rng| {
        let shape = rand_shape(rng, 40);
        let layout = *rng.choose(&[
            Layout::RowMajor,
            Layout::TiledContiguous,
            Layout::TiledInterleaved,
        ]);
        let p = plan(&cfg, &shape, layout);
        let mut a = vec![0i8; shape.m * shape.k];
        let mut b = vec![0i8; shape.k * shape.n];
        rng.fill_i8(&mut a);
        rng.fill_i8(&mut b);

        // pack through the bulk path; mirror the same image per byte
        let mut bulk = Spm::new(cfg.mem);
        pack_a(&mut bulk, &cfg, &p, &a, shape.m, shape.k);
        pack_b(&mut bulk, &cfg, &p, &b, shape.k, shape.n);
        prop_assert_eq!(bulk.stats, SpmStats::default(), "functional pack touched stats");

        // every tile read back two ways: bulk gather vs per-byte decode,
        // with identical bank-conflict accounting on both cost queries
        let regs = p.config_regs();
        let a_agu = regs.a_agu(&cfg.core, 8);
        let b_agu = regs.b_agu(&cfg.core, 8);
        let mut scalar_cost = Spm::new(cfg.mem);
        let mut addrs = Vec::new();
        for pos in 0..p.bounds.total_tiles().min(48) {
            let (m1, n1, k1) = p.bounds.decompose(pos);
            for agu in [&a_agu, &b_agu] {
                agu.tile_word_addrs(m1, n1, k1, 8, &mut addrs);
                let mut fast = vec![0i8; addrs.len() * 8];
                bulk.read_ports_i8(&addrs, 8, &mut fast);
                let slow: Vec<i8> = (0..fast.len())
                    .map(|i| read_byte_reference(&bulk, addrs[i / 8] * 8 + (i % 8) as u64) as i8)
                    .collect();
                prop_assert_eq!(fast, slow, "tile read diverges at {pos} ({layout:?})");
                let c_bulk = bulk.read_cost(&addrs);
                let c_ref = scalar_cost.read_cost(&addrs);
                prop_assert_eq!(c_bulk, c_ref, "read cost diverges at {pos}");
            }
        }
        prop_assert_eq!(
            bulk.stats,
            scalar_cost.stats,
            "bank-conflict accounting diverges ({layout:?})"
        );

        // bulk i32 writeback vs per-byte reference on a second SPM
        let mut scalar = bulk.clone();
        let tile: Vec<i32> = (0..64).map(|i| (i * 2654435761u64 as i64) as i32).collect();
        let c_addr = p.c_base;
        bulk.write_i32(c_addr, &tile);
        let bytes: Vec<u8> = tile.iter().flat_map(|v| v.to_le_bytes()).collect();
        write_bytes_reference(&mut scalar, c_addr, &bytes);
        for w in 0..bulk.n_words() {
            prop_assert_eq!(
                bulk.read_word(w),
                scalar.read_word(w),
                "word {w} diverges after writeback"
            );
        }
        Ok(())
    });
}

#[test]
fn platform_reuse_is_functionally_and_cycle_invariant() {
    // The scratch-arena delivery path + reset_for_job reuse: one
    // long-lived platform serving a random job mix must match a fresh
    // platform bit-for-bit (metrics AND functional results), and a
    // functional run must cost exactly the same simulated cycles as the
    // timing-only run of the same job on the same reused platform.
    let cfg = PlatformConfig::case_study();
    let mut reused: Option<Platform> = None;
    property("reused platform == fresh platform", 12, |rng| {
        let shape = rand_shape(rng, 64);
        let layout = *rng.choose(&[
            Layout::RowMajor,
            Layout::TiledContiguous,
            Layout::TiledInterleaved,
        ]);
        let mech = *rng.choose(&[Mechanisms::BASELINE, Mechanisms::CPL_BUF, Mechanisms::ALL]);
        let job = compile_gemm(&cfg, shape, layout, 2, mech.config_preloading)
            .map_err(|e| e.to_string())?;
        let mut a = vec![0i8; shape.m * shape.k];
        let mut b = vec![0i8; shape.k * shape.n];
        rng.fill_i8(&mut a);
        rng.fill_i8(&mut b);

        let func_opts =
            SimOptions { mechanisms: mech, functional: true, ..Default::default() };
        let mut fresh = Platform::new(cfg.clone(), func_opts);
        let want = fresh.run_job(&job, Some(&a), Some(&b)).map_err(|e| e.to_string())?;

        if let Some(p) = reused.as_mut() {
            p.reset_for_job(func_opts);
        }
        let p = reused.get_or_insert_with(|| Platform::new(cfg.clone(), func_opts));
        let got = p.run_job(&job, Some(&a), Some(&b)).map_err(|e| e.to_string())?;
        prop_assert_eq!(got.metrics, want.metrics, "reused metrics diverge for {shape:?}");
        prop_assert_eq!(got.c, want.c, "reused functional result diverges for {shape:?}");

        // functional vs timing invariance on the SAME reused platform:
        // the arena path must not perturb a single cycle
        p.reset_for_job(SimOptions { mechanisms: mech, functional: false, ..Default::default() });
        let timing = p.run_job(&job, None, None).map_err(|e| e.to_string())?;
        prop_assert_eq!(
            timing.metrics.total_cycles,
            got.metrics.total_cycles,
            "functional/timing cycle divergence for {shape:?} {layout:?}"
        );
        prop_assert_eq!(
            timing.metrics.stall_cycles(),
            got.metrics.stall_cycles(),
            "functional/timing stall divergence for {shape:?} {layout:?}"
        );
        Ok(())
    });
}

#[test]
fn timing_fast_path_matches_functional_timing() {
    // The timing-only bank-pattern fast path must produce exactly the
    // same cycle counts as the fully materialized (functional) path.
    let coord = Coordinator::new(PlatformConfig::case_study());
    property("fast path timing == functional timing", 15, |rng| {
        let shape = rand_shape(rng, 96);
        let layout = *rng.choose(&[
            Layout::RowMajor,
            Layout::TiledContiguous,
            Layout::TiledInterleaved,
        ]);
        let mech = *rng.choose(&[Mechanisms::BASELINE, Mechanisms::CPL_BUF, Mechanisms::ALL]);
        let timing = coord.run_one(&JobRequest {
            shape,
            layout,
            mechanisms: mech,
            repeats: 3,
            operands: None,
        })?;
        let mut a = vec![0i8; shape.m * shape.k];
        let mut b = vec![0i8; shape.k * shape.n];
        rng.fill_i8(&mut a);
        rng.fill_i8(&mut b);
        let functional = coord.run_one(&JobRequest {
            shape,
            layout,
            mechanisms: mech,
            repeats: 3,
            operands: Some((a, b)),
        })?;
        prop_assert_eq!(
            timing.metrics.total_cycles,
            functional.metrics.total_cycles,
            "total cycles diverge for {shape:?} {layout:?}"
        );
        prop_assert_eq!(
            timing.metrics.stall_cycles(),
            functional.metrics.stall_cycles(),
            "stall cycles diverge for {shape:?} {layout:?}"
        );
        Ok(())
    });
}
