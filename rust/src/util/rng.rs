//! Deterministic PRNG (PCG32) — no external `rand` crate is available in
//! this offline environment, and all experiments must be reproducible from
//! a seed anyway (the paper's 500 random workloads are seeded).

/// PCG-XSH-RR 64/32 (O'Neill 2014). Small, fast, statistically solid for
/// workload generation and property-based testing.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.state = rng.inc.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Seed-only constructor with the reference stream.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e39cb94b95bdb)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift with rejection.
    pub fn below(&mut self, bound: u32) -> u32 {
        assert!(bound > 0, "below(0)");
        let mut x = self.next_u32();
        let mut m = (x as u64) * (bound as u64);
        let mut l = m as u32;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u32();
                m = (x as u64) * (bound as u64);
                l = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// Uniform in the inclusive integer range `[lo, hi]`.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        if span == 0 {
            // full u64 span
            return self.next_u64() as i64;
        }
        lo + (self.next_u64() % span) as i64
    }

    /// Uniform f64 in [0, 1).
    pub fn unit_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Random int8 (full range), the accelerator operand distribution.
    pub fn int8(&mut self) -> i8 {
        self.next_u32() as u8 as i8
    }

    /// Choose uniformly from a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u32) as usize]
    }

    /// Fill a buffer with random int8 values.
    pub fn fill_i8(&mut self, buf: &mut [i8]) {
        for b in buf.iter_mut() {
            *b = self.int8();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Pcg32::seeded(1);
        let mut b = Pcg32::seeded(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Pcg32::seeded(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.below(10);
            assert!(v < 10);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit");
    }

    #[test]
    fn range_inclusive_bounds() {
        let mut rng = Pcg32::seeded(9);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..2000 {
            let v = rng.range_i64(-3, 3);
            assert!((-3..=3).contains(&v));
            lo_seen |= v == -3;
            hi_seen |= v == 3;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn unit_f64_in_unit_interval() {
        let mut rng = Pcg32::seeded(11);
        for _ in 0..1000 {
            let v = rng.unit_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn int8_full_range_reachable() {
        let mut rng = Pcg32::seeded(3);
        let mut min = i8::MAX;
        let mut max = i8::MIN;
        for _ in 0..10_000 {
            let v = rng.int8();
            min = min.min(v);
            max = max.max(v);
        }
        assert_eq!(min, i8::MIN);
        assert_eq!(max, i8::MAX);
    }
}
