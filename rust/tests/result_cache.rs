//! Result-cache integration tests: the four guarantees ISSUE 8 pins
//! down, checked through the public dispatch API (the CI `cache-smoke`
//! lane re-checks the first across real process invocations).
//!
//! 1. **Byte identity**: a warm re-run merges to exactly the bytes the
//!    cold run produced — and simulates zero jobs doing it. Keys
//!    exclude the shard/worker split, so a re-sweep at a different
//!    shard count is still all-hits.
//! 2. **Partial hits**: pre-seeded jobs are skipped, the rest simulate,
//!    and `merge` re-interleaves both back into submission order.
//! 3. **Corruption is a miss, never an error**: a truncated entry file
//!    is quarantined to `.poison`, the job re-simulates, and the
//!    repaired entry is republished.
//! 4. **Verify mode is a determinism tripwire**: an intact store
//!    passes (while still re-simulating everything); a tampered entry
//!    is a hard error naming the divergent key.
//!
//! Plus the spool-resume path: a killed spool sweep's published shard
//! results are claimed by the re-run without any executor present.
//! And the retention policy: `--cache-gc-max-entries` bounds the
//! persistent tier (oldest evicted on publish, byte identity intact),
//! while `.poison` quarantine files are never collected — they are
//! counted into `DispatchReport::cache_poison_files` instead.

use std::path::PathBuf;
use std::time::Duration;

use opengemm::compiler::GemmShape;
use opengemm::config::{Mechanisms, PlatformConfig};
use opengemm::coordinator::cache::{shard_fingerprint, shard_job_keys, ResultCache};
use opengemm::coordinator::dispatch::{
    dispatch_plan, dispatch_plan_cached, DispatchOptions, InProcess, SpoolDir,
};
use opengemm::coordinator::shard::{SweepOptions, SweepPlan};
use opengemm::coordinator::JobRequest;

/// Small varied batch: every request maps to a distinct cache key.
fn requests(n: usize) -> Vec<JobRequest> {
    (0..n)
        .map(|i| {
            let shape =
                GemmShape::new(8 + 8 * (i % 3), 8 + 8 * ((i / 3) % 3), 8 + 8 * ((i / 9) % 3));
            JobRequest::timing(shape, Mechanisms::ALL, 1 + (i as u32 % 2))
        })
        .collect()
}

fn plan(shards: usize, jobs: usize) -> SweepPlan {
    let cfg = PlatformConfig::case_study();
    let opts = SweepOptions { shards, workers: 1, ..Default::default() };
    SweepPlan::stride(&cfg, requests(jobs), opts)
}

/// Fresh per-test temp directory (removed up front so a crashed earlier
/// run cannot leak state in).
fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("opengemm-rc-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[test]
fn warm_rerun_is_byte_identical_and_simulates_nothing() {
    let dir = temp_dir("warm");
    let serial = DispatchOptions::serial();
    let (uncached, _) = dispatch_plan(plan(3, 10), &InProcess, &serial).unwrap();
    let bytes = uncached.to_json().pretty();

    let cold_cache = ResultCache::persistent(&dir).unwrap();
    let (cold, cold_report) =
        dispatch_plan_cached(plan(3, 10), &InProcess, &serial, Some(&cold_cache)).unwrap();
    assert_eq!(cold_report.cache_hits, 0);
    assert_eq!(cold_report.cache_misses, 10);
    assert_eq!(cold_report.jobs_simulated, 10);
    assert_eq!(cold.to_json().pretty(), bytes, "cold cached run == uncached run");

    // Fresh instance: the warm tier comes purely from the spool on disk.
    let warm_cache = ResultCache::persistent(&dir).unwrap();
    let (warm, warm_report) =
        dispatch_plan_cached(plan(3, 10), &InProcess, &serial, Some(&warm_cache)).unwrap();
    assert_eq!(warm_report.jobs_simulated, 0, "warm re-run must simulate nothing");
    assert_eq!(warm_report.cache_hits, 10);
    assert_eq!(warm_report.cache_misses, 0);
    assert_eq!(warm.to_json().pretty(), bytes, "warm bytes == cold bytes");
    // the in-memory stats surface the same traffic (wire-excluded)
    assert_eq!(warm.stats.cache_hits, 10);
    assert_eq!(warm.stats.jobs_simulated, 0);

    // Keys exclude the shard/worker split: re-sweeping the same batch
    // at a different shard count is still a full-hit run.
    let resharded_cache = ResultCache::persistent(&dir).unwrap();
    let (resharded, reshard_report) =
        dispatch_plan_cached(plan(2, 10), &InProcess, &serial, Some(&resharded_cache)).unwrap();
    assert_eq!(reshard_report.jobs_simulated, 0, "shard count is not part of the key");
    assert_eq!(resharded.to_json().pretty(), bytes);

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn partial_hits_merge_in_submission_order() {
    let serial = DispatchOptions::serial();
    let (baseline, _) = dispatch_plan(plan(2, 8), &InProcess, &serial).unwrap();

    // Seed every even submission index from the baseline outcomes,
    // using the same per-shard key lists the dispatcher derives.
    let p = plan(2, 8);
    let total = p.total_jobs as u64;
    let cache = ResultCache::in_memory();
    let mut seeded = 0u64;
    for shard in &p.shards {
        for (slot, key) in shard_job_keys(shard).iter().enumerate() {
            let submission = shard.indices[slot];
            if submission % 2 == 0 {
                cache.insert(key, &baseline.outcomes[submission]);
                seeded += 1;
            }
        }
    }
    assert!(seeded > 0 && seeded < total, "test needs a genuine partial hit");

    let (merged, report) = dispatch_plan_cached(p, &InProcess, &serial, Some(&cache)).unwrap();
    assert_eq!(report.cache_hits, seeded);
    assert_eq!(report.cache_misses, total - seeded);
    assert_eq!(report.jobs_simulated, total - seeded, "only the misses simulate");
    assert_eq!(
        merged.to_json().pretty(),
        baseline.to_json().pretty(),
        "cached and fresh outcomes re-interleave into submission order"
    );
}

#[test]
fn corrupt_entry_is_a_miss_not_an_error() {
    let dir = temp_dir("poison");
    let serial = DispatchOptions::serial();
    let cache = ResultCache::persistent(&dir).unwrap();
    let (first, _) = dispatch_plan_cached(plan(1, 4), &InProcess, &serial, Some(&cache)).unwrap();

    // Truncate one entry mid-object — the shape a crashed writer or a
    // torn copy leaves behind.
    let p = plan(1, 4);
    let key = shard_job_keys(&p.shards[0])[0].clone();
    let entry = dir.join(format!("{key}.cache.json"));
    assert!(entry.exists(), "cold run must have published {key}");
    std::fs::write(&entry, "{\"format\": \"opengemm-cache-entry-v1\", \"ke").unwrap();

    let warm = ResultCache::persistent(&dir).unwrap();
    let (second, report) = dispatch_plan_cached(p, &InProcess, &serial, Some(&warm)).unwrap();
    assert_eq!(report.cache_hits, 3, "intact entries still hit");
    assert_eq!(report.jobs_simulated, 1, "the corrupt entry re-simulates");
    assert_eq!(second.to_json().pretty(), first.to_json().pretty());
    assert!(
        dir.join(format!("{key}.cache.json.poison")).exists(),
        "corrupt entry quarantined for post-mortem"
    );
    assert!(entry.exists(), "re-simulated outcome republished under the key");

    // and the repaired store is fully warm again
    let third = ResultCache::persistent(&dir).unwrap();
    let (_, report) = dispatch_plan_cached(plan(1, 4), &InProcess, &serial, Some(&third)).unwrap();
    assert_eq!(report.jobs_simulated, 0);

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn verify_mode_catches_injected_divergence() {
    let dir = temp_dir("verify");
    let serial = DispatchOptions::serial();
    let cache = ResultCache::persistent(&dir).unwrap();
    dispatch_plan_cached(plan(2, 6), &InProcess, &serial, Some(&cache)).unwrap();

    // An intact store passes verification — but nothing is skipped.
    let clean = ResultCache::persistent(&dir).unwrap().with_verify(true);
    let (res, report) =
        dispatch_plan_cached(plan(2, 6), &InProcess, &serial, Some(&clean)).unwrap();
    assert_eq!(report.cache_hits, 6);
    assert_eq!(report.jobs_simulated, 6, "verify mode re-simulates everything");
    assert_eq!(res.stats.jobs_simulated, 6);

    // Tamper with one entry: a well-formed entry (format and key both
    // check out) holding a divergent outcome — exactly the corruption
    // the per-entry validation cannot catch.
    let p = plan(2, 6);
    let key = shard_job_keys(&p.shards[0])[0].clone();
    let tamper = ResultCache::persistent(&dir).unwrap();
    tamper.insert(&key, &Err("tampered result".to_string()));

    let verifying = ResultCache::persistent(&dir).unwrap().with_verify(true);
    let err = dispatch_plan_cached(p, &InProcess, &serial, Some(&verifying)).unwrap_err();
    assert!(err.contains("cache verify FAILED"), "got: {err}");
    assert!(err.contains(&key), "error must name the divergent key: {err}");

    // Non-verify dispatch trusts the store — which is why verify mode
    // exists as a separate, explicit tripwire.
    let trusting = ResultCache::persistent(&dir).unwrap();
    let (tampered, _) =
        dispatch_plan_cached(plan(2, 6), &InProcess, &serial, Some(&trusting)).unwrap();
    assert!(tampered.outcomes.iter().any(|o| o.is_err()), "tampered entry flowed through");

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn gc_bounds_the_store_through_the_dispatch_api() {
    let dir = temp_dir("gc");
    let serial = DispatchOptions::serial();
    let entries = |dir: &PathBuf| {
        std::fs::read_dir(dir)
            .unwrap()
            .filter(|e| {
                e.as_ref().unwrap().file_name().to_string_lossy().ends_with(".cache.json")
            })
            .count()
    };

    let cache = ResultCache::persistent(&dir).unwrap().with_gc_max_entries(4);
    let (first, report) =
        dispatch_plan_cached(plan(2, 10), &InProcess, &serial, Some(&cache)).unwrap();
    assert_eq!(report.jobs_simulated, 10);
    assert!(entries(&dir) <= 4, "GC must bound the store, found {}", entries(&dir));

    // A bounded store is a partial cache, never a correctness hazard:
    // the re-run simulates whatever was evicted and still merges
    // byte-identically.
    let warm = ResultCache::persistent(&dir).unwrap().with_gc_max_entries(4);
    let (second, report) =
        dispatch_plan_cached(plan(2, 10), &InProcess, &serial, Some(&warm)).unwrap();
    assert_eq!(report.cache_hits + report.cache_misses, 10);
    assert_eq!(second.to_json().pretty(), first.to_json().pretty());
    assert!(entries(&dir) <= 4);

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn poison_files_surface_in_the_dispatch_report() {
    let dir = temp_dir("poison-report");
    let serial = DispatchOptions::serial();
    let cache = ResultCache::persistent(&dir).unwrap();
    let (_, report) = dispatch_plan_cached(plan(1, 4), &InProcess, &serial, Some(&cache)).unwrap();
    assert_eq!(report.cache_poison_files, 0, "a clean store reports no quarantine");

    let p = plan(1, 4);
    let key = shard_job_keys(&p.shards[0])[0].clone();
    std::fs::write(dir.join(format!("{key}.cache.json")), "not json").unwrap();

    // Even under an aggressive GC bound the quarantine file must
    // survive collection and be counted for the operator.
    let warm = ResultCache::persistent(&dir).unwrap().with_gc_max_entries(2);
    let (_, report) = dispatch_plan_cached(p, &InProcess, &serial, Some(&warm)).unwrap();
    assert_eq!(report.cache_poison_files, 1);
    assert!(report.summary().contains("poison"), "{}", report.summary());
    assert!(dir.join(format!("{key}.cache.json.poison")).exists());

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn spool_resume_claims_published_results_without_an_executor() {
    let dir = temp_dir("resume");
    std::fs::create_dir_all(&dir).unwrap();
    let serial = DispatchOptions::serial();
    let (baseline, _) = dispatch_plan(plan(2, 6), &InProcess, &serial).unwrap();

    // A prior spool run published every shard's result, then died
    // before merging. Resume stems are content-addressed:
    // {prefix}k{shard_fingerprint}_s{index}_a{attempt}.
    let p = plan(2, 6);
    for shard in &p.shards {
        let stem = format!("v0_k{}_s{}_a0", shard_fingerprint(shard), shard.shard_index);
        let result = shard.clone().run();
        result.write_file(&dir.join(format!("{stem}.result.json"))).unwrap();
    }

    // Without resume, the stems carry a fresh per-run token: nothing
    // matches the published files, and with no executor watching the
    // spool the dispatch must time out.
    let blind = SpoolDir::new(&dir, "v0_", Duration::from_millis(5), Duration::from_millis(100))
        .unwrap();
    let err = dispatch_plan(plan(2, 6), &blind, &serial).unwrap_err();
    assert!(err.contains("not produced"), "got: {err}");

    // With resume, every shard claims its published result — the sweep
    // completes with no executor at all, byte-identical to in-process.
    let spool = SpoolDir::new(&dir, "v0_", Duration::from_millis(5), Duration::from_secs(5))
        .unwrap()
        .with_resume(true);
    let (merged, report) = dispatch_plan(p, &spool, &serial).unwrap();
    assert_eq!(merged.to_json().pretty(), baseline.to_json().pretty());
    assert_eq!(report.shards, 2);

    let _ = std::fs::remove_dir_all(&dir);
}
