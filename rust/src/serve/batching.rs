//! Batching policies: how queued requests are grouped into device
//! dispatches.
//!
//! The queueing engine reduces every policy to two knobs — a maximum
//! batch size and an optional deadline on the oldest queued request —
//! plus one universal rule: when no future arrival can ever join the
//! queue (open loop: schedule exhausted; closed loop: every
//! outstanding request is already queued), the partial batch is
//! flushed instead of waiting forever.

use crate::util::json::Json;

/// How queued requests are grouped into batches.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchPolicy {
    /// Every request dispatches alone, as soon as the device frees up.
    Immediate,
    /// Wait until `n` requests are queued (flushing a partial batch
    /// only when no future arrival can complete it).
    Size(usize),
    /// Close a batch when `max_batch` requests are queued or the
    /// oldest has waited `max_wait_cycles`, whichever comes first.
    Deadline { max_batch: usize, max_wait_cycles: u64 },
}

impl BatchPolicy {
    pub fn label(&self) -> &'static str {
        match self {
            BatchPolicy::Immediate => "immediate",
            BatchPolicy::Size(_) => "size",
            BatchPolicy::Deadline { .. } => "deadline",
        }
    }

    /// Largest number of requests one batch may carry.
    pub fn max_batch(&self) -> usize {
        match *self {
            BatchPolicy::Immediate => 1,
            BatchPolicy::Size(n) => n.max(1),
            BatchPolicy::Deadline { max_batch, .. } => max_batch.max(1),
        }
    }

    /// Longest the oldest queued request may wait before the batch is
    /// closed regardless of fill (deadline policy only).
    pub fn max_wait(&self) -> Option<u64> {
        match *self {
            BatchPolicy::Deadline { max_wait_cycles, .. } => Some(max_wait_cycles),
            _ => None,
        }
    }

    /// Wire encoding (serving report header).
    pub fn to_json(&self) -> Json {
        match *self {
            BatchPolicy::Immediate => Json::obj(vec![("policy", Json::str("immediate"))]),
            BatchPolicy::Size(n) => Json::obj(vec![
                ("policy", Json::str("size")),
                ("batch", Json::num(n as f64)),
            ]),
            BatchPolicy::Deadline { max_batch, max_wait_cycles } => Json::obj(vec![
                ("policy", Json::str("deadline")),
                ("batch", Json::num(max_batch as f64)),
                ("max_wait_cycles", Json::num(max_wait_cycles as f64)),
            ]),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_knobs() {
        assert_eq!(BatchPolicy::Immediate.max_batch(), 1);
        assert_eq!(BatchPolicy::Immediate.max_wait(), None);
        assert_eq!(BatchPolicy::Size(8).max_batch(), 8);
        assert_eq!(BatchPolicy::Size(0).max_batch(), 1, "degenerate size clamps to 1");
        let d = BatchPolicy::Deadline { max_batch: 4, max_wait_cycles: 1000 };
        assert_eq!(d.max_batch(), 4);
        assert_eq!(d.max_wait(), Some(1000));
    }

    #[test]
    fn policy_json_carries_knobs() {
        let d = BatchPolicy::Deadline { max_batch: 4, max_wait_cycles: 1000 };
        let text = d.to_json().pretty();
        assert!(text.contains("deadline") && text.contains("max_wait_cycles"));
        assert!(BatchPolicy::Immediate.to_json().pretty().contains("immediate"));
    }
}
