//! Static-verifier contract tests.
//!
//! Two halves, mirroring the mutual-oracle design of `analysis`:
//!
//! 1. **Property**: every schedule `compile_gemm` produces — over a
//!    randomized shape suite x layout regimes x platform variants —
//!    verifies with zero error-severity diagnostics. The compiler and
//!    the verifier are independent encodings of the same platform
//!    invariants, so a clean pass here regression-checks both at once
//!    (the style of `tests/model_accuracy.rs`).
//! 2. **Goldens**: hand-broken jobs (mutated placements with honestly
//!    regenerated host programs) must yield exactly the pinned
//!    diagnostic codes, severities, and JSON encodings. These pin the
//!    `A00x` catalog as a stable interface for downstream tooling.

use opengemm::analysis::{self, Severity};
use opengemm::compiler::{
    compile_gemm, gen_config_program, CompiledCall, CompiledJob, CsrImage, GemmShape, Layout,
    Placement,
};
use opengemm::config::{Mechanisms, PlatformConfig};
use opengemm::coordinator::JobRequest;
use opengemm::csr::{
    CSR_A_BASE, CSR_BASE, CSR_B_BASE, CSR_COUNT, CSR_C_BASE, CSR_C_SPATIAL1,
};
use opengemm::experiments::fig5::variant_config;
use opengemm::host::encode as enc;
use opengemm::host::reg;
use opengemm::util::json::{get_str, get_u64, Json};
use opengemm::workloads::random_suite;

fn cfg() -> PlatformConfig {
    PlatformConfig::case_study()
}

// ---------------------------------------------------------------------
// Property: compiled schedules verify clean
// ---------------------------------------------------------------------

/// The layout regimes the experiment drivers actually dispatch (same
/// pairs `JobRequest::timing` derives from each mechanism ladder rung).
const REGIMES: [(Mechanisms, Layout); 6] = [
    (Mechanisms::BASELINE, Layout::RowMajor),
    (Mechanisms::BASELINE, Layout::TiledContiguous),
    (Mechanisms::CPL, Layout::TiledContiguous),
    (Mechanisms::CPL_BUF, Layout::TiledContiguous),
    (Mechanisms::CPL_BUF, Layout::TiledInterleaved),
    (Mechanisms::ALL, Layout::TiledInterleaved),
];

#[test]
fn every_compiled_schedule_verifies_clean() {
    let base = cfg();
    // The Fig. 5 ladder's buffer depths; the verifier must not invent
    // violations on any platform variant the sweeps run.
    let configs: Vec<PlatformConfig> =
        [2usize, 3, 4].iter().map(|&d| variant_config(&base, d)).collect();
    // Seeded random suite plus deliberately irregular/edge shapes.
    let mut shapes = random_suite(99, 24);
    shapes.extend([
        GemmShape::new(1, 1, 1),
        GemmShape::new(13, 22, 17),
        GemmShape::new(8, 512, 8),
        GemmShape::new(65, 3, 130),
        GemmShape::new(256, 256, 256),
    ]);
    let mut checked = 0usize;
    for cfg in &configs {
        for (si, &shape) in shapes.iter().enumerate() {
            for &(mech, layout) in &REGIMES {
                let repeats = 1 + (si % 3) as u32;
                let Ok(job) = compile_gemm(cfg, shape, layout, repeats, mech.config_preloading)
                else {
                    continue; // unschedulable: legitimately rejected elsewhere
                };
                let diags = analysis::verify_job(cfg, &job);
                assert!(
                    !analysis::has_errors(&diags),
                    "false positive: shape {}x{}x{} {layout:?} cpl={} d_stream={} -> {:?}",
                    shape.m,
                    shape.k,
                    shape.n,
                    mech.config_preloading,
                    cfg.mem.d_stream,
                    analysis::first_error(&diags)
                );
                checked += 1;
            }
        }
    }
    assert!(checked > 100, "property covered only {checked} compiled jobs");
}

#[test]
fn verify_request_matches_verify_job_on_legal_points() {
    let cfg = cfg();
    let req = JobRequest::timing(GemmShape::new(64, 64, 64), Mechanisms::ALL, 10);
    let diags = analysis::verify_request(&cfg, &req);
    assert!(!analysis::has_errors(&diags), "{diags:?}");
}

// ---------------------------------------------------------------------
// Golden illegal jobs
// ---------------------------------------------------------------------

/// Rebuild a job with call 0's placement mutated and the host program
/// honestly regenerated from the mutated CSR images — the broken jobs
/// stay self-consistent, so each golden isolates ONE invariant
/// violation instead of cascading program/schedule divergence noise.
fn with_mutated_call(job: &CompiledJob, f: impl FnOnce(&mut Placement)) -> CompiledJob {
    let mut calls: Vec<CompiledCall> = job.calls.iter().cloned().collect();
    f(&mut calls[0].placement);
    let images: Vec<CsrImage> = calls.iter().map(|c| c.placement.csr_writes.clone()).collect();
    let program = gen_config_program(&images, job.repeats, job.cpl);
    CompiledJob {
        shape: job.shape,
        layout: job.layout,
        repeats: job.repeats,
        cpl: job.cpl,
        calls: calls.into(),
        program,
    }
}

fn set_csr(p: &mut Placement, addr: u32, value: u32) {
    for w in &mut p.csr_writes {
        if w.0 == addr {
            w.1 = value;
        }
    }
}

fn legal_job() -> CompiledJob {
    compile_gemm(&cfg(), GemmShape::new(64, 64, 64), Layout::TiledInterleaved, 2, true).unwrap()
}

fn error_codes(diags: &[analysis::Diagnostic]) -> Vec<&'static str> {
    diags.iter().filter(|d| d.severity == Severity::Error).map(|d| d.code).collect()
}

#[test]
fn golden_spm_oob_base() {
    let cfg = cfg();
    let cap = cfg.mem.capacity_bytes() as u32; // word-aligned: isolates A001 from A002
    let job = with_mutated_call(&legal_job(), |p| set_csr(p, CSR_A_BASE, cap));
    let diags = analysis::verify_job(&cfg, &job);
    assert_eq!(error_codes(&diags), vec!["A001-spm-oob"]);
    let d = analysis::first_error(&diags).unwrap();
    assert_eq!(d.severity, Severity::Error);
    assert_eq!(d.call, Some(0));
    assert!(d.message.contains("A region"), "{}", d.message);
    // Pin the JSON encoding downstream tooling parses.
    let v = d.to_json();
    assert_eq!(get_str(&v, "code").unwrap(), "A001-spm-oob");
    assert_eq!(get_str(&v, "severity").unwrap(), "error");
    assert_eq!(get_u64(&v, "call").unwrap(), 0);
    assert!(!get_str(&v, "hint").unwrap().is_empty());
    assert_eq!(analysis::Diagnostic::from_json(&v).unwrap(), *d);
}

#[test]
fn golden_spm_misaligned_base() {
    let cfg = cfg();
    let job = with_mutated_call(&legal_job(), |p| set_csr(p, CSR_A_BASE, 4));
    let diags = analysis::verify_job(&cfg, &job);
    let d = analysis::first_error(&diags).unwrap();
    assert_eq!(d.code, "A002-spm-misaligned");
    assert_eq!(d.call, Some(0));
    assert!(d.message.contains("base"), "{}", d.message);
}

#[test]
fn golden_ab_overlap_is_exact_word_evidence() {
    let cfg = cfg();
    // B on top of A: the exact word walk must name a shared word.
    let job = with_mutated_call(&legal_job(), |p| set_csr(p, CSR_B_BASE, 0));
    let diags = analysis::verify_job(&cfg, &job);
    assert_eq!(error_codes(&diags), vec!["A003-spm-overlap"]);
    let d = analysis::first_error(&diags).unwrap();
    assert!(d.message.contains("SPM word"), "{}", d.message);
    assert_eq!(get_str(&d.to_json(), "severity").unwrap(), "error");
}

#[test]
fn golden_missing_config_write() {
    let cfg = cfg();
    let job = with_mutated_call(&legal_job(), |p| {
        p.csr_writes.retain(|&(a, _)| a != CSR_C_SPATIAL1);
    });
    let diags = analysis::verify_job(&cfg, &job);
    assert_eq!(error_codes(&diags), vec!["A004-csr-incomplete-config"]);
    let d = analysis::first_error(&diags).unwrap();
    assert!(d.message.contains("C_SPATIAL1"), "{}", d.message);
    assert_eq!(d.call, Some(0));
}

#[test]
fn golden_out_of_range_loop_bound() {
    let cfg = cfg();
    // The schedule iterates more tiles than BOUNDS can encode. The
    // over-long walk also blows other limits; the pinned part is that
    // the A005 diagnostic itself is reported exactly.
    let job = with_mutated_call(&legal_job(), |p| p.bounds.mt = 2000);
    let diags = analysis::verify_job(&cfg, &job);
    let d = diags
        .iter()
        .find(|d| d.code == "A005-loop-bound-range")
        .expect("out-of-range bound must be diagnosed");
    assert_eq!(d.severity, Severity::Error);
    assert_eq!(d.call, Some(0));
    assert!(d.message.contains("Mt = 2000"), "{}", d.message);
    let v = d.to_json();
    assert_eq!(get_str(&v, "code").unwrap(), "A005-loop-bound-range");
    assert_eq!(get_str(&v, "severity").unwrap(), "error");
}

#[test]
fn golden_unmapped_csr_access() {
    let cfg = cfg();
    let mut job = legal_job();
    let outside = CSR_BASE + CSR_COUNT as u32;
    job.program.insert(0, enc::csrrwi(reg::ZERO, outside, 1));
    let diags = analysis::verify_job(&cfg, &job);
    assert_eq!(error_codes(&diags), vec!["A006-csr-bad-address"]);
    let d = analysis::first_error(&diags).unwrap();
    assert_eq!(d.csr, Some(outside));
}

#[test]
fn golden_wrong_poll_mask_breaks_cpl_chain() {
    let cfg = cfg();
    let mut job = legal_job();
    assert!(job.cpl);
    // Regenerate the program in blocking mode while the job still
    // claims CPL: the polls wait on busy instead of the pre-load slot.
    let images: Vec<CsrImage> =
        job.calls.iter().map(|c| c.placement.csr_writes.clone()).collect();
    job.program = gen_config_program(&images, job.repeats, false);
    let diags = analysis::verify_job(&cfg, &job);
    assert_eq!(error_codes(&diags), vec!["A007-cpl-chain"]);
    let d = analysis::first_error(&diags).unwrap();
    assert!(d.message.contains("CPL chaining requires"), "{}", d.message);
}

#[test]
fn golden_double_buffer_hazard() {
    let cfg = cfg();
    // C written over the live input prefetch windows (base 0 covers
    // both interleaved input lanes, so A and B are each diagnosed).
    let job = with_mutated_call(&legal_job(), |p| set_csr(p, CSR_C_BASE, 0));
    let diags = analysis::verify_job(&cfg, &job);
    let errors = error_codes(&diags);
    assert!(!errors.is_empty() && errors.iter().all(|c| *c == "A008-double-buffer-hazard"),
        "{diags:?}");
    let d = analysis::first_error(&diags).unwrap();
    assert!(d.message.contains("input region A"), "{}", d.message);
}

#[test]
fn golden_unschedulable_and_invalid_config() {
    let cfg = cfg();
    let req = JobRequest::timing(GemmShape::new(8, 300_000, 8), Mechanisms::ALL, 1);
    let diags = analysis::verify_request(&cfg, &req);
    assert_eq!(error_codes(&diags), vec!["A009-unschedulable"]);

    let mut bad = PlatformConfig::case_study();
    bad.mem.n_bank = 3;
    let diags = analysis::verify_config(&bad);
    assert_eq!(error_codes(&diags), vec!["A010-config-invalid"]);
    let v = analysis::first_error(&diags).unwrap().to_json();
    assert_eq!(get_str(&v, "code").unwrap(), "A010-config-invalid");
    assert!(matches!(v.get("call"), None | Some(Json::Null)));
}
