//! Deterministic lint report: the `opengemm lint` wire format.
//!
//! ## `opengemm-lint-report-v1` schema
//!
//! ```json
//! {
//!   "format": "opengemm-lint-report-v1",
//!   "targets": <u64>,            // number of lint targets
//!   "jobs": <u64>,               // compiled jobs verified in total
//!   "errors": <u64>,             // finding counts across all targets
//!   "warnings": <u64>,
//!   "infos": <u64>,
//!   "reports": [
//!     {
//!       "name": "fig5:Arch4 +SMA d=16",   // "<group>:<label>" target id
//!       "jobs": <u64>,
//!       "errors": <u64>, "warnings": <u64>, "infos": <u64>,
//!       "diagnostics": [
//!         {
//!           "code": "A001-spm-oob",      // stable code from analysis::CATALOG
//!           "severity": "error",          // "error" | "warn" | "info"
//!           "call": <u64> | null,         // offending call index, if per-call
//!           "csr": <u64> | null,          // offending CSR address, if per-CSR
//!           "message": "...",             // one-line finding
//!           "hint": "..."                 // one-line fix hint
//!         }, ...
//!       ]
//!     }, ...
//!   ]
//! }
//! ```
//!
//! Determinism contract: target order is lint order (itself fixed by the
//! experiment definitions), diagnostics within a target are sorted by
//! [`sort_diagnostics`](super::sort_diagnostics), and every field is a
//! pure function of `(config, targets)` — two runs over the same tree
//! diff byte-identically, so the report can live in CI artifacts.

use crate::analysis::{has_errors, Diagnostic, Severity};
use crate::util::json::{self, Json};
use crate::util::table::Table;

/// Wire format tag for lint reports.
pub const LINT_REPORT_FORMAT: &str = "opengemm-lint-report-v1";

/// Verification result for one lint target (one experiment grid point
/// or serve workload): every diagnostic across its compiled jobs.
#[derive(Debug, Clone, PartialEq)]
pub struct TargetReport {
    /// Target id, `"<group>:<label>"` (e.g. `"fig7:d=64"`).
    pub name: String,
    /// Compiled jobs verified under this target.
    pub jobs: usize,
    /// Findings, sorted errors-first (see `analysis::sort_diagnostics`).
    pub diagnostics: Vec<Diagnostic>,
}

impl TargetReport {
    pub fn count(&self, severity: Severity) -> usize {
        self.diagnostics.iter().filter(|d| d.severity == severity).count()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("jobs", Json::num(self.jobs as f64)),
            ("errors", Json::num(self.count(Severity::Error) as f64)),
            ("warnings", Json::num(self.count(Severity::Warn) as f64)),
            ("infos", Json::num(self.count(Severity::Info) as f64)),
            ("diagnostics", Json::Arr(self.diagnostics.iter().map(|d| d.to_json()).collect())),
        ])
    }

    pub fn from_json(v: &Json) -> Result<TargetReport, String> {
        let diagnostics = json::get_arr(v, "diagnostics")?
            .iter()
            .map(Diagnostic::from_json)
            .collect::<Result<Vec<_>, _>>()?;
        Ok(TargetReport {
            name: json::get_str(v, "name")?.to_string(),
            jobs: json::get_u64(v, "jobs")? as usize,
            diagnostics,
        })
    }
}

/// The full `opengemm lint` run: one [`TargetReport`] per target, in
/// lint order.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct LintReport {
    pub targets: Vec<TargetReport>,
}

impl LintReport {
    pub fn jobs(&self) -> usize {
        self.targets.iter().map(|t| t.jobs).sum()
    }

    pub fn count(&self, severity: Severity) -> usize {
        self.targets.iter().map(|t| t.count(severity)).sum()
    }

    /// Whether any target carries an error finding (the exit-status
    /// predicate for `opengemm lint`).
    pub fn has_errors(&self) -> bool {
        self.targets.iter().any(|t| has_errors(&t.diagnostics))
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("format", Json::str(LINT_REPORT_FORMAT)),
            ("targets", Json::num(self.targets.len() as f64)),
            ("jobs", Json::num(self.jobs() as f64)),
            ("errors", Json::num(self.count(Severity::Error) as f64)),
            ("warnings", Json::num(self.count(Severity::Warn) as f64)),
            ("infos", Json::num(self.count(Severity::Info) as f64)),
            ("reports", Json::Arr(self.targets.iter().map(|t| t.to_json()).collect())),
        ])
    }

    pub fn from_json(v: &Json) -> Result<LintReport, String> {
        let format = json::get_str(v, "format")?;
        if format != LINT_REPORT_FORMAT {
            return Err(format!("unsupported lint report format {format:?}"));
        }
        let targets = json::get_arr(v, "reports")?
            .iter()
            .map(TargetReport::from_json)
            .collect::<Result<Vec<_>, _>>()?;
        Ok(LintReport { targets })
    }

    /// Human rendering: a per-target count table, then one line per
    /// error/warn finding (info findings appear only as counts).
    pub fn render(&self) -> String {
        let mut t = Table::new(&["target", "jobs", "errors", "warns", "infos"]);
        for tr in &self.targets {
            t.row(vec![
                tr.name.clone(),
                tr.jobs.to_string(),
                tr.count(Severity::Error).to_string(),
                tr.count(Severity::Warn).to_string(),
                tr.count(Severity::Info).to_string(),
            ]);
        }
        let mut out = t.markdown();
        for tr in &self.targets {
            for d in &tr.diagnostics {
                if d.severity != Severity::Info {
                    out.push_str(&format!("\n{}: {}", tr.name, d.render()));
                }
            }
        }
        out.push_str(&format!(
            "\n\n{} error(s), {} warning(s), {} info note(s) across {} job(s) in {} target(s)\n",
            self.count(Severity::Error),
            self.count(Severity::Warn),
            self.count(Severity::Info),
            self.jobs(),
            self.targets.len()
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::{self, sort_diagnostics};
    use crate::compiler::{compile_gemm, GemmShape, Layout};
    use crate::config::PlatformConfig;

    fn report() -> LintReport {
        let cfg = PlatformConfig::case_study();
        let job =
            compile_gemm(&cfg, GemmShape::new(16, 16, 16), Layout::TiledInterleaved, 2, true)
                .unwrap();
        let mut diagnostics = analysis::verify_job(&cfg, &job);
        sort_diagnostics(&mut diagnostics);
        LintReport {
            targets: vec![TargetReport { name: "unit:16^3".to_string(), jobs: 1, diagnostics }],
        }
    }

    #[test]
    fn json_roundtrip_is_lossless() {
        let r = report();
        let v = r.to_json();
        assert_eq!(json::get_str(&v, "format").unwrap(), LINT_REPORT_FORMAT);
        assert_eq!(LintReport::from_json(&v).unwrap(), r);
    }

    #[test]
    fn render_names_every_target() {
        let r = report();
        let text = r.render();
        assert!(text.contains("unit:16^3"), "got: {text}");
        assert!(text.contains("error(s)"), "got: {text}");
    }

    #[test]
    fn bad_format_is_rejected() {
        let v = Json::obj(vec![("format", Json::str("bogus"))]);
        assert!(LintReport::from_json(&v).is_err());
    }
}
