//! End-to-end driver (DESIGN.md E2E requirement): run the complete
//! ResNet18 GeMM stream — every conv (via im2col) and the classifier —
//! through the full stack: compiler -> RV32I host program -> cycle-
//! accurate platform, with the functional datapath enabled on sampled
//! layers and cross-checked against the PJRT golden model.
//!
//! Reports per-layer and aggregate utilization (the Table 2 row) plus
//! simulator wall-clock throughput.
//!
//! Run with:  cargo run --release --example resnet18_e2e -- [--no-fast-forward]

use std::time::Instant;

use opengemm::compiler::{GemmShape, Layout};
use opengemm::config::{Mechanisms, PlatformConfig};
use opengemm::coordinator::{Coordinator, JobRequest};
use opengemm::runtime::Runtime;
use opengemm::util::cli::Args;
use opengemm::util::rng::Pcg32;
use opengemm::util::table::{fmt_f, fmt_sci, Table};
use opengemm::workloads::resnet18;

fn main() -> opengemm::util::error::Result<()> {
    let args = Args::from_env()?;
    let cfg = PlatformConfig::case_study();
    let model = resnet18();
    println!(
        "ResNet18 (batch 1): {} GeMM layers, {:.2} GMACs total",
        model.items.len(),
        model.total_macs() as f64 / 1e9
    );

    let coord =
        Coordinator::new(cfg.clone()).with_fast_forward(args.enabled_unless_no("fast-forward"));
    let t0 = Instant::now();

    // run every unique GeMM shape through the platform
    let unique = model.unique_shapes();
    let requests: Vec<JobRequest> = unique
        .iter()
        .map(|&(shape, count)| {
            JobRequest::timing(shape, Mechanisms::ALL, (count as u32).clamp(1, 10))
        })
        .collect();
    let results = coord.run_batch(requests);

    let mut table = Table::new(&["layer GeMM (M,K,N)", "count", "cycles/exec", "TU", "OU"]);
    let mut total_cycles = 0f64;
    let mut compute_cycles = 0f64;
    for ((shape, count), outcome) in unique.iter().zip(&results) {
        let r = outcome.as_ref().expect("layer simulation");
        // the request ran `repeats` executions (each may be several
        // accelerator calls when the shape splits over the SPM)
        let repeats = (*count as f64).clamp(1.0, 10.0);
        let per_exec = r.metrics.total_cycles as f64 / repeats;
        let per_exec_compute = r.metrics.compute_cycles as f64 / repeats;
        total_cycles += per_exec * *count as f64;
        compute_cycles += per_exec_compute * *count as f64;
        let su = shape.spatial_utilization(&cfg.core);
        table.row(vec![
            format!("({}, {}, {})", shape.m, shape.k, shape.n),
            count.to_string(),
            format!("{:.0}", per_exec),
            fmt_f(r.report.temporal, 3),
            fmt_f(su * r.report.temporal, 3),
        ]);
    }
    println!("{}", table.markdown());

    let su = model.spatial_utilization(&cfg.core);
    let tu = compute_cycles / total_cycles;
    println!(
        "aggregate:  SU {:.2}%  TU {:.2}%  OU {:.2}%  (paper Table 2: 96.01 / 99.72 / 95.74)",
        100.0 * su,
        100.0 * tu,
        100.0 * su * tu
    );
    println!(
        "total cycles {}  -> {:.1} ms inference at {} MHz",
        fmt_sci(total_cycles),
        total_cycles / (cfg.freq_mhz as f64 * 1e3),
        cfg.freq_mhz
    );
    let wall = t0.elapsed();
    println!(
        "simulator wall-clock: {:.2}s ({:.1} M simulated cycles/s)",
        wall.as_secs_f64(),
        coord.stats().simulated_cycles as f64 / wall.as_secs_f64() / 1e6
    );

    // functional spot-check: run conv3 functionally and compare against
    // the PJRT golden GeMM of the same shape (dimension-matched artifact
    // when available, otherwise naive reference)
    let spot = GemmShape::new(100, 60, 40);
    let mut rng = Pcg32::seeded(9);
    let mut a = vec![0i8; spot.m * spot.k];
    let mut b = vec![0i8; spot.k * spot.n];
    rng.fill_i8(&mut a);
    rng.fill_i8(&mut b);
    let req = JobRequest {
        shape: spot,
        layout: Layout::TiledInterleaved,
        mechanisms: Mechanisms::ALL,
        repeats: 1,
        operands: Some((a.clone(), b.clone())),
    };
    let sim = coord.run_one(&req).expect("functional run").c.unwrap();
    let dir = Runtime::default_dir();
    if dir.join("manifest.json").exists() {
        let mut rt = Runtime::load(dir)?;
        let golden = rt.execute_gemm("gemm_100x60x40", &a, &b)?;
        assert_eq!(sim, golden);
        println!("functional spot-check vs PJRT golden model: bit-exact ✓");
    } else {
        println!("artifacts not built; skipped PJRT spot-check");
    }
    Ok(())
}
