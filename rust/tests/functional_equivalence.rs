//! E8 — functional equivalence across the three layers.
//!
//! The cycle-accurate Rust simulator's datapath must agree bit-exactly
//! with the AOT-compiled JAX/Pallas artifacts executed through PJRT.
//! Requires `make artifacts` (tests are skipped with a notice if the
//! artifacts directory is missing).

use opengemm::compiler::{im2col_transform, weights_to_b, ConvShape, GemmShape, Layout};
use opengemm::config::{Mechanisms, PlatformConfig};
use opengemm::coordinator::{Coordinator, JobRequest};
use opengemm::runtime::{Runtime, Value};
use opengemm::util::rng::Pcg32;

fn runtime() -> Option<Runtime> {
    if cfg!(not(feature = "pjrt")) {
        eprintln!("SKIP: built without the `pjrt` feature (no XLA backend available)");
        return None;
    }
    let dir = Runtime::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built at {dir:?} (run `make artifacts`)");
        return None;
    }
    Some(Runtime::load(dir).expect("artifact manifest loads"))
}

fn sim_gemm(m: usize, k: usize, n: usize, a: &[i8], b: &[i8], layout: Layout) -> Vec<i32> {
    let coord = Coordinator::new(PlatformConfig::case_study());
    let req = JobRequest {
        shape: GemmShape::new(m, k, n),
        layout,
        mechanisms: Mechanisms::ALL,
        repeats: 1,
        operands: Some((a.to_vec(), b.to_vec())),
    };
    coord.run_one(&req).expect("sim ok").c.expect("functional data")
}

#[test]
fn simulator_matches_pallas_gemm_artifacts() {
    let Some(mut rt) = runtime() else { return };
    let mut rng = Pcg32::seeded(101);
    let names: Vec<String> = rt
        .artifact_names()
        .iter()
        .filter(|n| n.starts_with("gemm_"))
        .map(|s| s.to_string())
        .collect();
    assert!(names.len() >= 6, "expected several gemm artifacts");
    for name in names {
        let meta = rt.meta(&name).unwrap().clone();
        let (m, k) = (meta.args[0].shape[0], meta.args[0].shape[1]);
        let n = meta.args[1].shape[1];
        let mut a = vec![0i8; m * k];
        let mut b = vec![0i8; k * n];
        rng.fill_i8(&mut a);
        rng.fill_i8(&mut b);
        let golden = rt.execute_gemm(&name, &a, &b).expect("artifact executes");
        for layout in [Layout::RowMajor, Layout::TiledContiguous, Layout::TiledInterleaved] {
            let sim = sim_gemm(m, k, n, &a, &b, layout);
            assert_eq!(sim, golden, "{name} {layout:?}: simulator != Pallas golden");
        }
    }
}

#[test]
fn simulator_matches_conv_artifact_via_im2col() {
    let Some(mut rt) = runtime() else { return };
    let name = "conv_1x16x16x16_3x3x16";
    let Some(meta) = rt.meta(name).cloned() else {
        panic!("conv artifact missing from manifest");
    };
    let mut rng = Pcg32::seeded(55);
    let x_len: usize = meta.args[0].shape.iter().product();
    let w_len: usize = meta.args[1].shape.iter().product();
    let mut x = vec![0i8; x_len];
    let mut w = vec![0i8; w_len];
    rng.fill_i8(&mut x);
    rng.fill_i8(&mut w);

    // golden: the L2 conv graph (im2col inside JAX + Pallas GeMM)
    let outs = rt
        .execute(name, &[Value::I8(x.clone()), Value::I8(w.clone())])
        .expect("conv artifact executes");
    let golden = outs[0].to_vec::<i32>().expect("i32 results");

    // platform path: Rust im2col -> simulator GeMM
    let conv = ConvShape::dense(1, 16, 16, 16, 3, 3, 16, 1, 0);
    let a = im2col_transform(&x, &conv, 0);
    let b = weights_to_b(&w, &conv, 0);
    let g = conv.gemm_shape();
    let sim = sim_gemm(g.m, g.k, g.n, &a, &b, Layout::TiledInterleaved);
    assert_eq!(sim, golden, "conv-as-GeMM mismatch vs JAX conv graph");
}

#[test]
fn linear_artifact_executes_and_requantizes() {
    let Some(mut rt) = runtime() else { return };
    let name = "linear_64x64x64";
    let meta = rt.meta(name).expect("linear artifact").clone();
    assert_eq!(meta.results[0].dtype, "s8");
    let mut rng = Pcg32::seeded(77);
    let mut a = vec![0i8; 64 * 64];
    let mut w = vec![0i8; 64 * 64];
    rng.fill_i8(&mut a);
    rng.fill_i8(&mut w);
    let bias: Vec<i32> = (0..64).map(|i| (i as i32 - 32) * 100).collect();
    let shift = vec![7i32];
    let outs = rt
        .execute(
            name,
            &[Value::I8(a.clone()), Value::I8(w.clone()), Value::I32(bias.clone()), Value::I32(shift)],
        )
        .expect("linear executes");
    let got = Runtime::result_i8(&outs[0]).expect("i8 result");

    // reference: simulator GeMM + host-side requantization
    let acc = sim_gemm(64, 64, 64, &a, &w, Layout::TiledInterleaved);
    let expect: Vec<i8> = acc
        .iter()
        .enumerate()
        .map(|(idx, &v)| {
            let v = v.wrapping_add(bias[idx % 64]);
            let r = (v + (1 << 6)) >> 7;
            r.clamp(-128, 127) as i8
        })
        .collect();
    assert_eq!(got, expect, "fused linear kernel != simulator + host requant");
}

#[test]
fn mha_scores_artifact_matches_sim_plus_requant() {
    let Some(mut rt) = runtime() else { return };
    let name = "mha_scores_s64_d64";
    let mut rng = Pcg32::seeded(91);
    let mut q = vec![0i8; 64 * 64];
    let mut k = vec![0i8; 64 * 64];
    rng.fill_i8(&mut q);
    rng.fill_i8(&mut k);
    let outs = rt
        .execute(name, &[Value::I8(q.clone()), Value::I8(k.clone())])
        .expect("mha executes");
    let got = Runtime::result_i8(&outs[0]).expect("i8 scores");

    // K^T on the host, GeMM on the simulated platform, shift 6
    let mut kt = vec![0i8; 64 * 64];
    for i in 0..64 {
        for j in 0..64 {
            kt[j * 64 + i] = k[i * 64 + j];
        }
    }
    let acc = sim_gemm(64, 64, 64, &q, &kt, Layout::TiledInterleaved);
    let expect: Vec<i8> = acc
        .iter()
        .map(|&v| (((v + (1 << 5)) >> 6).clamp(-128, 127)) as i8)
        .collect();
    assert_eq!(got, expect, "attention scores mismatch");
}
