//! Design-time configuration of the OpenGeMM platform generator.
//!
//! Mirrors Table 1 of the paper: the GeMM-core parameters `(Mu, Nu, Ku,
//! P_A, P_B, P_C)` and the memory-system parameters `(D_stream, R_mem,
//! W_mem, P_word, N_bank, D_mem)`. A `PlatformConfig` is the analogue of
//! one elaborated Chisel instance; `validate()` enforces the same
//! structural constraints elaboration would.

mod toml;

pub use toml::{parse_toml, TomlValue};

use std::fmt;

use crate::util::json::{self, Json};

/// GeMM accelerator generator parameters (paper Table 1, top half).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GemmCoreParams {
    /// Number of rows of the DotProd mesh (spatial unrolling of M).
    pub mu: usize,
    /// Number of columns of the DotProd mesh (spatial unrolling of N).
    pub nu: usize,
    /// Size of each DotProd unit (spatial unrolling of K).
    pub ku: usize,
    /// Integer bit precision of A operands.
    pub pa_bits: usize,
    /// Integer bit precision of B operands.
    pub pb_bits: usize,
    /// Integer bit precision of C accumulators/outputs.
    pub pc_bits: usize,
}

/// Memory subsystem parameters (paper Table 1, bottom half).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MemParams {
    /// Pre-fetch buffer and output buffer depth (in tiles).
    pub d_stream: usize,
    /// Number of SPM read ports feeding the input streamers.
    pub r_mem: usize,
    /// Number of SPM write ports draining the output streamer.
    pub w_mem: usize,
    /// Data width of one memory port, in bits.
    pub p_word_bits: usize,
    /// Number of SPM banks.
    pub n_bank: usize,
    /// Depth of each bank, in words.
    pub d_mem: usize,
    /// SPM read latency in cycles (bank access + interconnect).
    pub read_latency: u64,
    /// SPM write latency in cycles.
    pub write_latency: u64,
}

/// Run-time utilization mechanisms (the paper's Arch(1)..(4) ablation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Mechanisms {
    /// Configuration pre-loading (Sec. 3.2): shadow CSRs let the host
    /// program run n+1 while run n computes.
    pub config_preloading: bool,
    /// Input pre-fetch + output buffering (Sec. 3.3). When false the
    /// streamers fetch on demand and the core stalls on every tile.
    pub prefetch: bool,
    /// Strided memory access / data-layout optimization (Sec. 3.4). When
    /// false, operands sit in naive row-major layout and suffer bank
    /// contention.
    pub strided_layout: bool,
}

impl Mechanisms {
    /// Paper Arch(1): everything off.
    pub const BASELINE: Mechanisms = Mechanisms {
        config_preloading: false,
        prefetch: false,
        strided_layout: false,
    };
    /// Paper Arch(2): + configuration pre-loading.
    pub const CPL: Mechanisms = Mechanisms {
        config_preloading: true,
        prefetch: false,
        strided_layout: false,
    };
    /// Paper Arch(3): + input pre-fetch / output buffering.
    pub const CPL_BUF: Mechanisms = Mechanisms {
        config_preloading: true,
        prefetch: true,
        strided_layout: false,
    };
    /// Paper Arch(4): all three mechanisms.
    pub const ALL: Mechanisms = Mechanisms {
        config_preloading: true,
        prefetch: true,
        strided_layout: true,
    };

    pub fn label(&self) -> String {
        match (self.config_preloading, self.prefetch, self.strided_layout) {
            (false, false, false) => "Arch1 (baseline)".into(),
            (true, false, false) => "Arch2 (+CPL)".into(),
            (true, true, false) => "Arch3 (+prefetch/outbuf)".into(),
            (true, true, true) => "Arch4 (+SMA)".into(),
            (c, p, s) => format!("custom(cpl={c},buf={p},sma={s})"),
        }
    }

    /// Wire encoding (sharded-sweep job serialization).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("config_preloading", Json::Bool(self.config_preloading)),
            ("prefetch", Json::Bool(self.prefetch)),
            ("strided_layout", Json::Bool(self.strided_layout)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Mechanisms, String> {
        Ok(Mechanisms {
            config_preloading: json::get_bool(v, "config_preloading")?,
            prefetch: json::get_bool(v, "prefetch")?,
            strided_layout: json::get_bool(v, "strided_layout")?,
        })
    }
}

/// DMA engine parameters: operands are staged from a modeled
/// background memory into the SPM in `chunk_words`-word bursts (the
/// MosaicSim-style chunk-unit pricing), each burst paying `latency`
/// cycles of background-memory access on top of the SPM bank-conflict
/// cost of the write itself.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DmaParams {
    /// Words moved per burst (>= 1).
    pub chunk_words: usize,
    /// Background-memory latency per burst, in cycles.
    pub latency: u64,
}

impl DmaParams {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("chunk_words", Json::num(self.chunk_words as f64)),
            ("latency", Json::num(self.latency as f64)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<DmaParams, String> {
        Ok(DmaParams {
            chunk_words: json::get_usize(v, "chunk_words")?,
            latency: json::get_u64(v, "latency")?,
        })
    }
}

/// Upper bound on multi-core instantiation (CSR window routing and the
/// SPM partitioner are validated up to this).
pub const MAX_CORES: usize = 8;

/// One elaborated OpenGeMM platform instance.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PlatformConfig {
    pub core: GemmCoreParams,
    pub mem: MemParams,
    /// Core clock frequency in MHz (evaluation point: 200 MHz).
    pub freq_mhz: u64,
    /// Number of GeMM cores sharing the banked SPM (each with its own
    /// streamers and CSR window; the host dispatches calls round-robin).
    pub cores: usize,
    /// Optional DMA engine staging operands from background memory.
    pub dma: Option<DmaParams>,
}

/// Configuration validation error.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ConfigError(pub String);

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "invalid platform config: {}", self.0)
    }
}

impl std::error::Error for ConfigError {}

impl GemmCoreParams {
    /// The paper's case-study core: an 8x8x8 array of int8 MACs with
    /// int32 accumulators.
    pub const CASE_STUDY: GemmCoreParams = GemmCoreParams {
        mu: 8,
        nu: 8,
        ku: 8,
        pa_bits: 8,
        pb_bits: 8,
        pc_bits: 32,
    };

    /// MACs per cycle (array peak).
    pub fn macs_per_cycle(&self) -> u64 {
        (self.mu * self.nu * self.ku) as u64
    }

    /// Bytes of one A' tile (Mu x Ku operands).
    pub fn a_tile_bytes(&self) -> usize {
        self.mu * self.ku * self.pa_bits / 8
    }

    /// Bytes of one B' tile (Ku x Nu operands).
    pub fn b_tile_bytes(&self) -> usize {
        self.ku * self.nu * self.pb_bits / 8
    }

    /// Bytes of one C' tile (Mu x Nu results).
    pub fn c_tile_bytes(&self) -> usize {
        self.mu * self.nu * self.pc_bits / 8
    }
}

impl MemParams {
    /// Paper Table 1 case-study memory system: 270 KiB SPM in 32 banks of
    /// 1056 x 64-bit words; 16 read + 32 write ports; buffer depth 3.
    pub const CASE_STUDY: MemParams = MemParams {
        d_stream: 3,
        r_mem: 16,
        w_mem: 32,
        p_word_bits: 64,
        n_bank: 32,
        d_mem: 1056,
        read_latency: 1,
        write_latency: 1,
    };

    pub fn word_bytes(&self) -> usize {
        self.p_word_bits / 8
    }

    /// Total SPM capacity in bytes.
    pub fn capacity_bytes(&self) -> usize {
        self.n_bank * self.d_mem * self.word_bytes()
    }

    /// Read bandwidth in bytes/cycle.
    pub fn read_bw(&self) -> usize {
        self.r_mem * self.word_bytes()
    }

    /// Write bandwidth in bytes/cycle.
    pub fn write_bw(&self) -> usize {
        self.w_mem * self.word_bytes()
    }
}

impl PlatformConfig {
    /// The paper's evaluated instance (Table 1 case-study column).
    pub fn case_study() -> PlatformConfig {
        PlatformConfig {
            core: GemmCoreParams::CASE_STUDY,
            mem: MemParams::CASE_STUDY,
            freq_mhz: 200,
            cores: 1,
            dma: None,
        }
    }

    /// Bytes of SPM owned by each core: the capacity split `cores` ways
    /// and aligned down to a whole bank row (`word_bytes * n_bank`) so
    /// every partition starts on the same bank-0 boundary. With one
    /// core this is exactly the full capacity.
    pub fn spm_partition_bytes(&self) -> usize {
        let row = self.mem.word_bytes() * self.mem.n_bank;
        (self.mem.capacity_bytes() / self.cores) / row * row
    }

    /// Peak throughput in GOPS (1 MAC = 2 ops), paper Sec. 4.4:
    /// 2 * 8*8*8 * 200 MHz = 204.8 GOPS for the case study.
    pub fn peak_gops(&self) -> f64 {
        2.0 * self.core.macs_per_cycle() as f64 * self.freq_mhz as f64 * 1e6 / 1e9
    }

    /// Validate structural constraints the Chisel generator would check
    /// at elaboration time.
    pub fn validate(&self) -> Result<(), ConfigError> {
        let c = &self.core;
        let m = &self.mem;
        let err = |msg: String| Err(ConfigError(msg));

        if c.mu == 0 || c.nu == 0 || c.ku == 0 {
            return err(format!("array dims must be positive: ({},{},{})", c.mu, c.nu, c.ku));
        }
        if ![2, 4, 8].contains(&c.pa_bits) || ![2, 4, 8].contains(&c.pb_bits) {
            return err(format!(
                "operand precisions must be 2/4/8 bits, got A={} B={}",
                c.pa_bits, c.pb_bits
            ));
        }
        if c.pc_bits < c.pa_bits + c.pb_bits {
            return err(format!(
                "accumulator precision {} too small for {}x{} products",
                c.pc_bits, c.pa_bits, c.pb_bits
            ));
        }
        if m.p_word_bits == 0 || m.p_word_bits % 8 != 0 {
            return err(format!("port width must be a byte multiple: {}", m.p_word_bits));
        }
        if !m.n_bank.is_power_of_two() {
            return err(format!("bank count must be a power of two: {}", m.n_bank));
        }
        if m.d_stream == 0 {
            return err("streamer buffer depth must be >= 1".into());
        }
        // The input ports must sustain one A' + one B' tile per cycle,
        // otherwise the generated core can never reach full utilization
        // (the generator rejects such configurations).
        let per_cycle = c.a_tile_bytes() + c.b_tile_bytes();
        if m.read_bw() < per_cycle {
            return err(format!(
                "read bandwidth {}B/cy < tile demand {}B/cy",
                m.read_bw(),
                per_cycle
            ));
        }
        // Write ports must drain one C' tile in at most K/Ku cycles; the
        // structural requirement checked at elaboration is >= one C' tile
        // per ceil(c_tile/w_bw) <= some bound; we require a full tile
        // within Ku cycles (the minimum K-loop length).
        let c_tile = c.c_tile_bytes();
        if m.write_bw() * c.ku < c_tile {
            return err(format!(
                "write bandwidth {}B/cy cannot drain a {}B C' tile within Ku={} cycles",
                m.write_bw(),
                c_tile,
                c.ku
            ));
        }
        if self.cores == 0 || self.cores > MAX_CORES {
            return err(format!("cores must be in 1..={MAX_CORES}: {}", self.cores));
        }
        if let Some(dma) = &self.dma {
            if dma.chunk_words == 0 {
                return err("dma chunk_words must be >= 1".into());
            }
        }
        // Working set of one double-buffered tile set must fit each
        // core's SPM partition (the full capacity with one core).
        let min_capacity = (c.a_tile_bytes() + c.b_tile_bytes() + c.c_tile_bytes()) * 2;
        if self.spm_partition_bytes() < min_capacity {
            return err(format!(
                "SPM partition {}B ({} cores) below minimum working set {}B",
                self.spm_partition_bytes(),
                self.cores,
                min_capacity
            ));
        }
        Ok(())
    }

    /// Wire encoding (sharded-sweep shard files): the worker process
    /// reconstructs the exact elaborated instance the driver planned
    /// with, so sharded and unsharded runs simulate identical hardware.
    ///
    /// `cores`/`dma` are omitted at their defaults (1 / absent) so the
    /// encoding — and everything fingerprinted from it (result-cache
    /// job keys, experiment JSON) — is byte-identical to the
    /// single-core, DMA-less encoding that predates those knobs.
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            (
                "core",
                Json::obj(vec![
                    ("mu", Json::num(self.core.mu as f64)),
                    ("nu", Json::num(self.core.nu as f64)),
                    ("ku", Json::num(self.core.ku as f64)),
                    ("pa_bits", Json::num(self.core.pa_bits as f64)),
                    ("pb_bits", Json::num(self.core.pb_bits as f64)),
                    ("pc_bits", Json::num(self.core.pc_bits as f64)),
                ]),
            ),
            (
                "mem",
                Json::obj(vec![
                    ("d_stream", Json::num(self.mem.d_stream as f64)),
                    ("r_mem", Json::num(self.mem.r_mem as f64)),
                    ("w_mem", Json::num(self.mem.w_mem as f64)),
                    ("p_word_bits", Json::num(self.mem.p_word_bits as f64)),
                    ("n_bank", Json::num(self.mem.n_bank as f64)),
                    ("d_mem", Json::num(self.mem.d_mem as f64)),
                    ("read_latency", Json::num(self.mem.read_latency as f64)),
                    ("write_latency", Json::num(self.mem.write_latency as f64)),
                ]),
            ),
            ("freq_mhz", Json::num(self.freq_mhz as f64)),
        ];
        if self.cores != 1 {
            pairs.push(("cores", Json::num(self.cores as f64)));
        }
        if let Some(dma) = &self.dma {
            pairs.push(("dma", dma.to_json()));
        }
        Json::obj(pairs)
    }

    pub fn from_json(v: &Json) -> Result<PlatformConfig, String> {
        let core = json::get(v, "core")?;
        let mem = json::get(v, "mem")?;
        let cfg = PlatformConfig {
            core: GemmCoreParams {
                mu: json::get_usize(core, "mu")?,
                nu: json::get_usize(core, "nu")?,
                ku: json::get_usize(core, "ku")?,
                pa_bits: json::get_usize(core, "pa_bits")?,
                pb_bits: json::get_usize(core, "pb_bits")?,
                pc_bits: json::get_usize(core, "pc_bits")?,
            },
            mem: MemParams {
                d_stream: json::get_usize(mem, "d_stream")?,
                r_mem: json::get_usize(mem, "r_mem")?,
                w_mem: json::get_usize(mem, "w_mem")?,
                p_word_bits: json::get_usize(mem, "p_word_bits")?,
                n_bank: json::get_usize(mem, "n_bank")?,
                d_mem: json::get_usize(mem, "d_mem")?,
                read_latency: json::get_u64(mem, "read_latency")?,
                write_latency: json::get_u64(mem, "write_latency")?,
            },
            freq_mhz: json::get_u64(v, "freq_mhz")?,
            cores: match v.get("cores") {
                Some(c) => c.as_usize().ok_or("field \"cores\" is not an unsigned integer")?,
                None => 1,
            },
            dma: match v.get("dma") {
                Some(d) => Some(DmaParams::from_json(d)?),
                None => None,
            },
        };
        cfg.validate().map_err(|e| e.to_string())?;
        Ok(cfg)
    }

    /// Load from a TOML-subset config file (see `config/toml.rs`).
    pub fn from_toml(text: &str) -> Result<PlatformConfig, ConfigError> {
        let doc = parse_toml(text).map_err(|e| ConfigError(format!("toml: {e}")))?;
        let mut cfg = PlatformConfig::case_study();
        let lookup = |section: &str, key: &str| -> Option<i64> {
            doc.get(section).and_then(|s| s.get(key)).and_then(|v| v.as_int())
        };
        macro_rules! set {
            ($field:expr, $section:expr, $key:expr) => {
                if let Some(v) = lookup($section, $key) {
                    $field = v as usize;
                }
            };
        }
        set!(cfg.core.mu, "core", "mu");
        set!(cfg.core.nu, "core", "nu");
        set!(cfg.core.ku, "core", "ku");
        set!(cfg.core.pa_bits, "core", "pa_bits");
        set!(cfg.core.pb_bits, "core", "pb_bits");
        set!(cfg.core.pc_bits, "core", "pc_bits");
        set!(cfg.mem.d_stream, "mem", "d_stream");
        set!(cfg.mem.r_mem, "mem", "r_mem");
        set!(cfg.mem.w_mem, "mem", "w_mem");
        set!(cfg.mem.p_word_bits, "mem", "p_word_bits");
        set!(cfg.mem.n_bank, "mem", "n_bank");
        set!(cfg.mem.d_mem, "mem", "d_mem");
        if let Some(v) = lookup("platform", "freq_mhz") {
            cfg.freq_mhz = v as u64;
        }
        if let Some(v) = lookup("platform", "cores") {
            cfg.cores = v as usize;
        }
        if let Some(chunk) = lookup("dma", "chunk_words") {
            cfg.dma = Some(DmaParams {
                chunk_words: chunk as usize,
                latency: lookup("dma", "latency").unwrap_or(0) as u64,
            });
        } else if lookup("dma", "latency").is_some() {
            return Err(ConfigError("[dma] latency given without chunk_words".into()));
        }
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn case_study_matches_paper() {
        let cfg = PlatformConfig::case_study();
        cfg.validate().expect("case study must validate");
        // 204.8 GOPS peak (Sec. 4.4)
        assert!((cfg.peak_gops() - 204.8).abs() < 1e-9);
        // 270 KiB SPM: 32 banks x 1056 words x 8B = 270336 B
        assert_eq!(cfg.mem.capacity_bytes(), 270336);
        assert_eq!(cfg.mem.capacity_bytes() / 1024, 264); // 264 KiB data array
        // read ports sustain exactly A'+B' per cycle
        assert_eq!(cfg.mem.read_bw(), cfg.core.a_tile_bytes() + cfg.core.b_tile_bytes());
        // write ports drain exactly one C' tile per cycle
        assert_eq!(cfg.mem.write_bw(), cfg.core.c_tile_bytes());
    }

    #[test]
    fn tile_byte_sizes() {
        let c = GemmCoreParams::CASE_STUDY;
        assert_eq!(c.a_tile_bytes(), 64);
        assert_eq!(c.b_tile_bytes(), 64);
        assert_eq!(c.c_tile_bytes(), 256);
        assert_eq!(c.macs_per_cycle(), 512);
    }

    #[test]
    fn rejects_undersized_read_bandwidth() {
        let mut cfg = PlatformConfig::case_study();
        cfg.mem.r_mem = 4; // 32 B/cy < 128 B/cy demand
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn rejects_small_accumulator() {
        let mut cfg = PlatformConfig::case_study();
        cfg.core.pc_bits = 8;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn rejects_non_pow2_banks() {
        let mut cfg = PlatformConfig::case_study();
        cfg.mem.n_bank = 12;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn rejects_zero_buffer_depth() {
        let mut cfg = PlatformConfig::case_study();
        cfg.mem.d_stream = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn accepts_generator_variants() {
        // vector dot-product unit: 1x1 mesh of one big DotProd
        let mut cfg = PlatformConfig::case_study();
        cfg.core.mu = 1;
        cfg.core.nu = 1;
        cfg.core.ku = 64;
        cfg.validate().unwrap();
        // outer-product-ish: Ku = 1 needs pc_bits >= 16 and more write bw
        let mut cfg = PlatformConfig::case_study();
        cfg.core.ku = 1;
        cfg.core.pc_bits = 32;
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn mechanisms_labels() {
        assert!(Mechanisms::BASELINE.label().contains("Arch1"));
        assert!(Mechanisms::ALL.label().contains("Arch4"));
    }

    #[test]
    fn from_toml_overrides() {
        let text = r#"
[core]
mu = 16
nu = 16
ku = 8

[mem]
r_mem = 32
w_mem = 128

[platform]
freq_mhz = 500
"#;
        let cfg = PlatformConfig::from_toml(text).unwrap();
        assert_eq!(cfg.core.mu, 16);
        assert_eq!(cfg.freq_mhz, 500);
        assert!((cfg.peak_gops() - 2.0 * 2048.0 * 0.5).abs() < 1e-9);
    }

    #[test]
    fn default_json_omits_cores_and_dma() {
        let cfg = PlatformConfig::case_study();
        let text = cfg.to_json().pretty();
        assert!(!text.contains("cores"), "cores=1 must be omitted");
        assert!(!text.contains("dma"), "dma=None must be omitted");
        let back = PlatformConfig::from_json(&crate::util::json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, cfg);
    }

    #[test]
    fn multicore_dma_json_round_trips() {
        let mut cfg = PlatformConfig::case_study();
        cfg.cores = 4;
        cfg.dma = Some(DmaParams { chunk_words: 16, latency: 20 });
        let text = cfg.to_json().pretty();
        let back = PlatformConfig::from_json(&crate::util::json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, cfg);
    }

    #[test]
    fn spm_partition_splits_on_bank_rows() {
        let mut cfg = PlatformConfig::case_study();
        assert_eq!(cfg.spm_partition_bytes(), cfg.mem.capacity_bytes());
        cfg.cores = 4;
        let row = cfg.mem.word_bytes() * cfg.mem.n_bank;
        assert_eq!(cfg.spm_partition_bytes() % row, 0);
        assert!(cfg.spm_partition_bytes() * 4 <= cfg.mem.capacity_bytes());
        cfg.validate().unwrap();
    }

    #[test]
    fn rejects_bad_cores_and_dma() {
        let mut cfg = PlatformConfig::case_study();
        cfg.cores = 0;
        assert!(cfg.validate().is_err());
        cfg.cores = MAX_CORES + 1;
        assert!(cfg.validate().is_err());
        let mut cfg = PlatformConfig::case_study();
        cfg.dma = Some(DmaParams { chunk_words: 0, latency: 1 });
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn from_toml_cores_and_dma() {
        let text = "[platform]\ncores = 2\n\n[dma]\nchunk_words = 8\nlatency = 12\n";
        let cfg = PlatformConfig::from_toml(text).unwrap();
        assert_eq!(cfg.cores, 2);
        assert_eq!(cfg.dma, Some(DmaParams { chunk_words: 8, latency: 12 }));
        assert!(PlatformConfig::from_toml("[dma]\nlatency = 3\n").is_err());
    }

    #[test]
    fn from_toml_rejects_invalid() {
        // mu=64 makes the A' tile 512B > 128B read bandwidth
        let text = "[core]\nmu = 64\n";
        assert!(PlatformConfig::from_toml(text).is_err());
    }
}
