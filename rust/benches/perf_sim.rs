//! Microbenchmarks of the simulator hot path (the L3 perf target in
//! EXPERIMENTS.md §Perf): simulated cycles per wall-clock second for
//! representative workloads, plus component microbenches (AGU walk,
//! bank arbitration, tile MAC, RV32I dispatch).
//!
//! Run with:  cargo bench --bench perf_sim

use opengemm::compiler::{compile_gemm, GemmShape, Layout};
use opengemm::config::{GemmCoreParams, Mechanisms, PlatformConfig};
use opengemm::csr::CsrManager;
use opengemm::gemm_core::{tile_mac, tile_mac_reference, Accumulators};
use opengemm::host::{encode as enc, reg, Asm, Cpu};
use opengemm::sim::{Platform, SimOptions};
use opengemm::spm::Spm;
use opengemm::streamer::AguConfig;
use opengemm::util::bench::{black_box, Bencher};
use opengemm::util::json::Json;
use opengemm::util::rng::Pcg32;

fn bench_end_to_end(b: &mut Bencher) {
    let cfg = PlatformConfig::case_study();
    for (label, shape, mech, layout) in [
        ("sim/64^3 all-mech", GemmShape::new(64, 64, 64), Mechanisms::ALL, Layout::TiledInterleaved),
        ("sim/128^3 all-mech", GemmShape::new(128, 128, 128), Mechanisms::ALL, Layout::TiledInterleaved),
        ("sim/128^3 baseline", GemmShape::new(128, 128, 128), Mechanisms::BASELINE, Layout::TiledContiguous),
    ] {
        let job = compile_gemm(&cfg, shape, layout, 2, mech.config_preloading).unwrap();
        let opts = SimOptions { mechanisms: mech, ..Default::default() };
        let mut platform = Platform::new(cfg.clone(), opts);
        let mut cycles = 0u64;
        let r = b.bench(label, || {
            let res = platform.run_job(&job, None, None).unwrap();
            cycles = res.metrics.total_cycles;
        });
        println!(
            "      -> {:.1} M simulated cycles/s ({} cycles/job)",
            r.throughput(cycles as f64) / 1e6,
            cycles
        );
    }
}

/// The event heap's target workload: a configuration-bound job whose
/// fast-forward loop asks the scheduler for the next wakeup on every
/// executed step with frozen streamers. Sources push their wakeups at
/// mutation points, so each query is a heap peek (popping stale
/// entries lazily) rather than a rescan of all event sources; this
/// workload's simulated-cycles-per-second is the heap's tracked
/// metric.
fn bench_event_heap(b: &mut Bencher) {
    let cfg = PlatformConfig::case_study();
    let job = compile_gemm(&cfg, GemmShape::new(8, 8, 8), Layout::RowMajor, 50, false).unwrap();
    let opts = SimOptions {
        mechanisms: Mechanisms::BASELINE,
        csr_latency: 48,
        ..Default::default()
    };
    let mut platform = Platform::new(cfg, opts);
    let mut cycles = 0u64;
    let mut steps = 0u64;
    let r = b.bench("sched/event heap, config-bound ff", || {
        let res = platform.run_job(&job, None, None).unwrap();
        cycles = res.metrics.total_cycles;
        steps = platform.steps_executed;
    });
    println!(
        "      -> {:.1} M simulated cycles/s ({cycles} cycles, {steps} stepped)",
        r.throughput(cycles as f64) / 1e6
    );
}

fn bench_components(b: &mut Bencher) {
    // tile MAC (functional datapath)
    let core = GemmCoreParams::CASE_STUDY;
    let mut acc = Accumulators::new(&core);
    let mut rng = Pcg32::seeded(3);
    let mut a = vec![0i8; 64];
    let mut bb = vec![0i8; 64];
    rng.fill_i8(&mut a);
    rng.fill_i8(&mut bb);
    b.bench("core/tile_mac 8x8x8", || {
        tile_mac(&mut acc, &core, black_box(&a), black_box(&bb));
    });

    // AGU address generation
    let agu = AguConfig {
        base: 0,
        stride_m: 1024,
        stride_n: 0,
        stride_k: 128,
        spatial0_count: 1,
        spatial0_stride: 0,
        spatial1_count: 8,
        spatial1_stride: 8,
    };
    let mut addrs = Vec::with_capacity(8);
    let mut pos = 0u64;
    b.bench("streamer/agu 8-port walk", || {
        pos = (pos + 1) & 0xffff;
        agu.tile_word_addrs(pos % 64, 0, pos / 64, 8, &mut addrs);
        black_box(&addrs);
    });

    // SPM bank arbitration
    let mut spm = Spm::new(PlatformConfig::case_study().mem);
    let words: Vec<u64> = (0..8u64).map(|i| i * 8).collect();
    b.bench("spm/read_cost 8 ports", || {
        black_box(spm.read_cost(black_box(&words)));
    });

    // RV32I dispatch rate
    let mut asm = Asm::new();
    asm.li(reg::T0, 0);
    asm.li(reg::T1, 1_000_000);
    asm.label("loop");
    asm.emit(enc::addi(reg::T0, reg::T0, 1));
    asm.emit(enc::xor(reg::T2, reg::T0, reg::T1));
    asm.emit(enc::and(reg::T3, reg::T2, reg::T0));
    asm.bne_to(reg::T0, reg::T1, "loop");
    asm.emit(enc::ebreak());
    let program = asm.assemble();
    let mut csr = CsrManager::new(false);
    let r = b.bench("host/rv32i 1M-iter loop", || {
        let mut cpu = Cpu::new(program.clone(), 256);
        cpu.run(&mut csr, u64::MAX).unwrap();
        black_box(cpu.cycles);
    });
    println!(
        "      -> {:.1} M host instructions/s",
        r.throughput(4_000_000.0) / 1e6
    );
}

/// One throughput measurement: simulated cycles per host-second for a
/// workload, in lockstep and fast-forward modes.
struct ThroughputEntry {
    label: String,
    stall_heavy: bool,
    simulated_cycles: u64,
    steps_fast_forward: u64,
    lockstep_cps: f64,
    fast_forward_cps: f64,
}

impl ThroughputEntry {
    fn speedup(&self) -> f64 {
        self.fast_forward_cps / self.lockstep_cps
    }
}

#[allow(clippy::too_many_arguments)]
fn measure_throughput(
    b: &mut Bencher,
    label: &str,
    stall_heavy: bool,
    shape: GemmShape,
    layout: Layout,
    mech: Mechanisms,
    repeats: u32,
    csr_latency: u64,
) -> ThroughputEntry {
    let cfg = PlatformConfig::case_study();
    let job = compile_gemm(&cfg, shape, layout, repeats, mech.config_preloading).unwrap();
    let mut rates = [0.0f64; 2];
    let mut cycles = 0u64;
    let mut steps_ff = 0u64;
    for (slot, fast_forward) in [(0usize, false), (1usize, true)] {
        let mode = if fast_forward { "fast-forward" } else { "lockstep" };
        let opts = SimOptions { mechanisms: mech, csr_latency, fast_forward, ..Default::default() };
        let mut platform = Platform::new(cfg.clone(), opts);
        let mut total = 0u64;
        let mut steps = 0u64;
        let r = b.bench(&format!("throughput/{label} {mode}"), || {
            let res = platform.run_job(&job, None, None).unwrap();
            total = res.metrics.total_cycles;
            steps = platform.steps_executed;
        });
        rates[slot] = r.throughput(total as f64);
        cycles = total;
        if fast_forward {
            steps_ff = steps;
        }
        println!(
            "      -> {:.1} M simulated cycles/s ({} cycles, {} stepped)",
            rates[slot] / 1e6,
            total,
            steps
        );
    }
    ThroughputEntry {
        label: label.to_string(),
        stall_heavy,
        simulated_cycles: cycles,
        steps_fast_forward: steps_ff,
        lockstep_cps: rates[0],
        fast_forward_cps: rates[1],
    }
}

/// Simulation-throughput benchmark: fast-forward vs lockstep, emitted
/// as BENCH_sim_throughput.json at the repo root (the perf trajectory's
/// tracked artifact).
///
/// The stall-heavy workloads run Arch1 (prefetch disabled) with a
/// 48-cycle CSR handshake — the operating point where our CPL gain
/// matches the paper's 1.40x median (see `ablation_cpl_sensitivity`),
/// i.e. the calibrated cost of the paper's Snitch configuration path.
fn bench_sim_throughput(b: &mut Bencher) -> Json {
    let entries = vec![
        // deep-K thin GeMM, no prefetch: every tile-MAC waits out a
        // conflicting A-tile fetch, every call re-pays configuration
        measure_throughput(
            b,
            "8x256x8 deepK arch1 csr48",
            true,
            GemmShape::new(8, 256, 8),
            Layout::RowMajor,
            Mechanisms::BASELINE,
            10,
            48,
        ),
        // configuration-bound tiny GeMM (the paper's TU<0.1 corner)
        measure_throughput(
            b,
            "8x8x8 tiny arch1 csr48",
            true,
            GemmShape::new(8, 8, 8),
            Layout::RowMajor,
            Mechanisms::BASELINE,
            20,
            48,
        ),
        // deep-K at the default handshake cost
        measure_throughput(
            b,
            "16x1024x16 deepK arch1 csr8",
            true,
            GemmShape::new(16, 1024, 16),
            Layout::RowMajor,
            Mechanisms::BASELINE,
            4,
            8,
        ),
        // compute-bound control: fast-forward must not slow this down
        measure_throughput(
            b,
            "64x64x64 arch4 csr8",
            false,
            GemmShape::new(64, 64, 64),
            Layout::TiledInterleaved,
            Mechanisms::ALL,
            10,
            8,
        ),
    ];

    let stall_heavy_speedup = entries
        .iter()
        .filter(|e| e.stall_heavy)
        .map(ThroughputEntry::speedup)
        .fold(0.0f64, f64::max);
    println!(
        "      == stall-heavy fast-forward speedup: {stall_heavy_speedup:.1}x \
         (target >= 5x) =="
    );

    let entry_docs: Vec<Json> = entries
        .iter()
        .map(|e| {
            Json::obj(vec![
                ("workload", Json::str(e.label.clone())),
                ("stall_heavy", Json::Bool(e.stall_heavy)),
                ("simulated_cycles", Json::num(e.simulated_cycles as f64)),
                ("steps_fast_forward", Json::num(e.steps_fast_forward as f64)),
                ("lockstep_cycles_per_sec", Json::num(e.lockstep_cps)),
                ("fast_forward_cycles_per_sec", Json::num(e.fast_forward_cps)),
                ("speedup", Json::num(e.speedup())),
            ])
        })
        .collect();
    Json::obj(vec![
        ("bench", Json::str("sim_throughput")),
        ("unit", Json::str("simulated cycles per host-second")),
        ("stall_heavy_speedup", Json::num(stall_heavy_speedup)),
        ("entries", Json::Arr(entry_docs)),
    ])
}

/// One functional-mode throughput measurement (the vectorized data
/// plane's tracked metric: simulated cycles per host-second with real
/// data flowing).
struct FunctionalEntry {
    label: String,
    simulated_cycles: u64,
    cycles_per_sec: f64,
}

fn measure_functional(
    b: &mut Bencher,
    label: &str,
    shape: GemmShape,
    layout: Layout,
    mech: Mechanisms,
    repeats: u32,
) -> FunctionalEntry {
    let cfg = PlatformConfig::case_study();
    let job = compile_gemm(&cfg, shape, layout, repeats, mech.config_preloading).unwrap();
    let opts = SimOptions { mechanisms: mech, functional: true, ..Default::default() };
    let mut platform = Platform::new(cfg.clone(), opts);
    let mut rng = Pcg32::seeded(11);
    let mut a_op = vec![0i8; shape.m * shape.k];
    let mut b_op = vec![0i8; shape.k * shape.n];
    rng.fill_i8(&mut a_op);
    rng.fill_i8(&mut b_op);
    let mut cycles = 0u64;
    let r = b.bench(&format!("functional/{label}"), || {
        let res = platform.run_job(&job, Some(&a_op), Some(&b_op)).unwrap();
        cycles = res.metrics.total_cycles;
        black_box(res.c.as_ref().map(|c| c[0]));
    });
    let cps = r.throughput(cycles as f64);
    println!(
        "      -> {:.1} M functional simulated cycles/s ({} cycles/job)",
        cps / 1e6,
        cycles
    );
    FunctionalEntry { label: label.to_string(), simulated_cycles: cycles, cycles_per_sec: cps }
}

/// The seed's per-byte SPM tile read, kept in the bench as the baseline
/// the bulk gather path is measured against.
fn read_tile_per_byte(spm: &Spm, word_addrs: &[u64], out: &mut [i8]) {
    for (i, &w) in word_addrs.iter().enumerate() {
        for (j, v) in out[i * 8..(i + 1) * 8].iter_mut().enumerate() {
            let addr = w * 8 + j as u64;
            let word = spm.read_word(addr / 8);
            *v = ((word >> ((addr % 8) * 8)) & 0xff) as u8 as i8;
        }
    }
}

/// Functional data-plane benchmark (the ISSUE 2 perf target): kernel and
/// SPM-path speedups vs the seed's scalar implementations, plus
/// end-to-end functional simulation throughput. Emitted as
/// BENCH_dotprod_throughput.json at the repo root.
fn bench_dotprod_throughput(b: &mut Bencher) -> Json {
    // tile-MAC kernel: vectorized vs the seed's scalar branchy kernel
    let core = GemmCoreParams::CASE_STUDY;
    let mut acc = Accumulators::new(&core);
    let mut rng = Pcg32::seeded(7);
    let mut a = vec![0i8; core.mu * core.ku];
    let mut bb = vec![0i8; core.ku * core.nu];
    rng.fill_i8(&mut a);
    rng.fill_i8(&mut bb);
    let r_vec = b
        .bench("kernel/tile_mac vectorized", || {
            tile_mac(&mut acc, &core, black_box(&a), black_box(&bb));
        })
        .median_ns;
    let r_ref = b
        .bench("kernel/tile_mac seed-scalar", || {
            tile_mac_reference(&mut acc, &core, black_box(&a), black_box(&bb));
        })
        .median_ns;
    let kernel_speedup = r_ref / r_vec;
    println!("      == tile_mac kernel speedup vs seed: {kernel_speedup:.2}x ==");

    // SPM tile fetch: bulk word gather vs the seed's per-byte walk
    let mut spm = Spm::new(PlatformConfig::case_study().mem);
    let image: Vec<i8> = (0..2048).map(|i| (i % 249) as i8).collect();
    spm.write_i8(0, &image);
    let addrs: Vec<u64> = (0..8u64).map(|i| i * 9 + 3).collect();
    let mut tile = vec![0i8; 64];
    let r_bulk = b
        .bench("spm/tile fetch bulk gather", || {
            spm.read_ports_i8(black_box(&addrs), 8, &mut tile);
            black_box(&tile);
        })
        .median_ns;
    let r_pb = b
        .bench("spm/tile fetch per-byte (seed)", || {
            read_tile_per_byte(&spm, black_box(&addrs), &mut tile);
            black_box(&tile);
        })
        .median_ns;
    let spm_speedup = r_pb / r_bulk;
    println!("      == SPM tile-fetch speedup vs seed: {spm_speedup:.2}x ==");

    // end-to-end functional simulation throughput
    let entries = vec![
        measure_functional(
            b,
            "64x64x64 arch4",
            GemmShape::new(64, 64, 64),
            Layout::TiledInterleaved,
            Mechanisms::ALL,
            4,
        ),
        measure_functional(
            b,
            "128x128x128 arch4",
            GemmShape::new(128, 128, 128),
            Layout::TiledInterleaved,
            Mechanisms::ALL,
            2,
        ),
        measure_functional(
            b,
            "48x40x56 arch3 contiguous",
            GemmShape::new(48, 40, 56),
            Layout::TiledContiguous,
            Mechanisms::CPL_BUF,
            4,
        ),
        measure_functional(
            b,
            "32x256x32 arch1 row-major",
            GemmShape::new(32, 256, 32),
            Layout::RowMajor,
            Mechanisms::BASELINE,
            2,
        ),
    ];

    let entry_docs: Vec<Json> = entries
        .iter()
        .map(|e| {
            Json::obj(vec![
                ("workload", Json::str(e.label.clone())),
                ("simulated_cycles", Json::num(e.simulated_cycles as f64)),
                ("functional_cycles_per_sec", Json::num(e.cycles_per_sec)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("bench", Json::str("dotprod_throughput")),
        ("unit", Json::str("functional simulated cycles per host-second")),
        ("kernel_speedup_vs_seed", Json::num(kernel_speedup)),
        ("spm_tile_fetch_speedup_vs_seed", Json::num(spm_speedup)),
        ("entries", Json::Arr(entry_docs)),
    ])
}

fn artifact_path(name: &str) -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("package root has a parent")
        .join(name)
}

/// True when `name` holds the committed unmeasured placeholder (its
/// `status` field says so) or does not exist — i.e. overwriting loses
/// no measured data. Unparseable content counts as measured: when in
/// doubt, keep the file.
fn artifact_is_placeholder(name: &str) -> bool {
    let path = artifact_path(name);
    let Ok(text) = std::fs::read_to_string(&path) else { return true };
    match opengemm::util::json::parse(&text) {
        Ok(doc) => doc
            .get("status")
            .and_then(|s| s.as_str())
            .map(|s| s.contains("placeholder"))
            .unwrap_or(false),
        Err(_) => false,
    }
}

/// Write a tracked benchmark artifact. A smoke pass is quick and
/// noisy: it may replace a committed placeholder, but never a measured
/// artifact (full runs always write).
fn write_json_artifact(name: &str, doc: &Json, smoke: bool) {
    if smoke && !artifact_is_placeholder(name) {
        println!(
            "keeping measured {name} (smoke pass refuses to overwrite it; \
             run without --smoke to re-measure)"
        );
        return;
    }
    let out = artifact_path(name);
    match std::fs::write(&out, doc.pretty()) {
        Ok(()) => println!("wrote {}", out.display()),
        Err(e) => eprintln!("could not write {}: {e}", out.display()),
    }
}

fn main() {
    // --smoke: the CI bench lane's quick pass — same measurements,
    // shorter warmup/samples, so the artifact tracks the perf
    // trajectory per PR without burning CI minutes.
    let smoke = std::env::args().any(|arg| arg == "--smoke");
    let mut b = if smoke { Bencher::quick() } else { Bencher::default() };
    println!("== simulator hot-path microbenchmarks ==");
    bench_end_to_end(&mut b);
    bench_event_heap(&mut b);
    bench_components(&mut b);
    println!("== functional data plane: vectorized kernel + bulk SPM I/O ==");
    let dotprod_doc = bench_dotprod_throughput(&mut b);
    write_json_artifact("BENCH_dotprod_throughput.json", &dotprod_doc, smoke);
    println!("== simulation throughput: fast-forward vs lockstep ==");
    let doc = bench_sim_throughput(&mut b);
    write_json_artifact("BENCH_sim_throughput.json", &doc, smoke);
}
