"""L1: output-stationary tiled INT8 GeMM as a Pallas kernel.

This is the paper's compute hot-spot (the 3D MAC array of Fig. 3)
re-expressed for the TPU machine model (DESIGN.md "Hardware-Adaptation"):

- the grid ``(M/bm, N/bn, K/bk)`` is the paper's three *temporal* loops
  ``(m1, n1, k1)`` with ``k1`` innermost -- the output-stationary order;
- the BlockSpecs are the data streamers: the ``index_map`` walks
  HBM->VMEM the way the strided AGUs walk SPM->core;
- each grid step performs one ``(bm,bk) x (bk,bn)`` tile-MAC with int32
  accumulation, the paper's per-cycle DotProd-mesh operation scaled to
  MXU tile size;
- the revisited output block is the DotProd accumulation register file:
  it is zeroed when ``k1 == 0`` and accumulated into otherwise, exactly
  the hardware loop controller's "accumulator reset" behaviour.

The kernel MUST be run with ``interpret=True`` on this setup: real TPU
lowering emits a Mosaic custom-call the CPU PJRT plugin cannot execute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes. (8, 8, 8) mirrors the paper's case-study GeMM array;
# larger tiles (e.g. 128) are the natural MXU-sized choice on real TPUs.
DEFAULT_BM = 8
DEFAULT_BK = 8
DEFAULT_BN = 8


def _gemm_kernel(a_ref, b_ref, o_ref):
    """One grid step: output-stationary tile-MAC.

    o_ref is revisited across the innermost (k) grid dimension; Pallas
    guarantees the block stays resident, so this is the accumulator
    register file of the DotProd units.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():  # accumulator reset at the start of the k1 loop
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...].astype(jnp.int32)
    b = b_ref[...].astype(jnp.int32)
    o_ref[...] += jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )


def _check_tile(dim: int, tile: int, name: str) -> None:
    if tile <= 0:
        raise ValueError(f"tile {name}={tile} must be positive")
    if dim % tile != 0:
        raise ValueError(
            f"dimension {name}={dim} not divisible by tile {tile}; "
            "use gemm_int8 (padding wrapper) instead"
        )


@functools.partial(
    jax.jit, static_argnames=("bm", "bk", "bn", "interpret")
)
def gemm_int8_tiled(
    a: jax.Array,
    b: jax.Array,
    *,
    bm: int = DEFAULT_BM,
    bk: int = DEFAULT_BK,
    bn: int = DEFAULT_BN,
    interpret: bool = True,
) -> jax.Array:
    """INT8 GeMM via the Pallas kernel; shapes must divide the tiles.

    a: (M, K) int8, b: (K, N) int8 -> (M, N) int32.
    """
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: {a.shape} x {b.shape}")
    _check_tile(m, bm, "M")
    _check_tile(k, bk, "K")
    _check_tile(n, bn, "N")

    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _gemm_kernel,
        grid=grid,
        in_specs=[
            # A block depends on (m1, k1): the A-streamer's 2D strided walk.
            pl.BlockSpec((bm, bk), lambda mi, ni, ki: (mi, ki)),
            # B block depends on (k1, n1): the B-streamer's walk.
            pl.BlockSpec((bk, bn), lambda mi, ni, ki: (ki, ni)),
        ],
        # C block depends on (m1, n1) only -> output-stationary residency.
        out_specs=pl.BlockSpec((bm, bn), lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(a, b)


def pad_to_multiple(x: jax.Array, mult_rows: int, mult_cols: int) -> jax.Array:
    """Zero-pad a 2D array up to multiples of (mult_rows, mult_cols).

    Zero padding is exact for integer GeMM: padded lanes contribute 0 to
    every accumulator. This is precisely the paper's *spatial
    under-utilization*: the padded MAC lanes burn cycles on zeros.
    """
    r, c = x.shape
    pr = (-r) % mult_rows
    pc = (-c) % mult_cols
    if pr == 0 and pc == 0:
        return x
    return jnp.pad(x, ((0, pr), (0, pc)))


def gemm_int8(
    a: jax.Array,
    b: jax.Array,
    *,
    bm: int = DEFAULT_BM,
    bk: int = DEFAULT_BK,
    bn: int = DEFAULT_BN,
    interpret: bool = True,
) -> jax.Array:
    """INT8 GeMM for arbitrary (M, K, N): pads to tile multiples, crops back."""
    m, _ = a.shape
    _, n = b.shape
    ap = pad_to_multiple(a, bm, bk)
    bp = pad_to_multiple(b, bk, bn)
    out = gemm_int8_tiled(ap, bp, bm=bm, bk=bk, bn=bn, interpret=interpret)
    return out[:m, :n]


def _linear_kernel(a_ref, w_ref, bias_ref, shift_ref, o_ref, acc_ref):
    """Fused quantized-linear grid step: GeMM + bias + requantize.

    The int32 accumulator lives in a second, revisited output block
    (acc_ref) that the caller discards -- the portable Pallas idiom for a
    VMEM accumulator that works under interpret=True. On the last k step
    the bias is added and the value is requantized into the int8 output
    block, fusing the paper's post-processing (the SNAX requantizer
    sitting after the GeMM core) into the same kernel.
    """
    k = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(jnp.int32)
    w = w_ref[...].astype(jnp.int32)
    acc_ref[...] += jax.lax.dot_general(
        a, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )

    @pl.when(k == nk - 1)
    def _requant():
        acc = acc_ref[...] + bias_ref[...].astype(jnp.int32)[None, :]
        shift = shift_ref[0]
        half = jnp.where(shift > 0, jnp.int32(1) << (shift - 1), 0)
        rounded = jnp.where(shift > 0, (acc + half) >> shift, acc)
        o_ref[...] = jnp.clip(rounded, -128, 127).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def linear_int8_tiled(
    a: jax.Array,
    w: jax.Array,
    bias: jax.Array,
    shift: jax.Array,
    *,
    bm: int = DEFAULT_BM,
    bk: int = DEFAULT_BK,
    bn: int = DEFAULT_BN,
    interpret: bool = True,
) -> jax.Array:
    """Fused quantized linear: requant(A @ W + bias) via one Pallas kernel.

    a: (M, K) int8, w: (K, N) int8, bias: (N,) int32, shift: (1,) int32
    -> (M, N) int8.
    """
    m, k = a.shape
    k2, n = w.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: {a.shape} x {w.shape}")
    _check_tile(m, bm, "M")
    _check_tile(k, bk, "K")
    _check_tile(n, bn, "N")

    grid = (m // bm, n // bn, k // bk)
    out, _acc = pl.pallas_call(
        _linear_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda mi, ni, ki: (mi, ki)),
            pl.BlockSpec((bk, bn), lambda mi, ni, ki: (ki, ni)),
            pl.BlockSpec((bn,), lambda mi, ni, ki: (ni,)),
            pl.BlockSpec((1,), lambda mi, ni, ki: (0,)),
        ],
        out_specs=(
            pl.BlockSpec((bm, bn), lambda mi, ni, ki: (mi, ni)),
            pl.BlockSpec((bm, bn), lambda mi, ni, ki: (mi, ni)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((m, n), jnp.int8),
            jax.ShapeDtypeStruct((m, n), jnp.int32),
        ),
        interpret=interpret,
    )(a, w, bias, shift)
    return out


def linear_int8(
    a: jax.Array,
    w: jax.Array,
    bias: jax.Array,
    shift: jax.Array,
    *,
    bm: int = DEFAULT_BM,
    bk: int = DEFAULT_BK,
    bn: int = DEFAULT_BN,
    interpret: bool = True,
) -> jax.Array:
    """Fused quantized linear for arbitrary shapes (zero-pads, crops back)."""
    m, _ = a.shape
    _, n = w.shape
    ap = pad_to_multiple(a, bm, bk)
    wp = pad_to_multiple(w, bk, bn)
    pad_n = (-n) % bn
    bias_p = jnp.pad(bias, (0, pad_n)) if pad_n else bias
    out = linear_int8_tiled(
        ap, wp, bias_p, shift, bm=bm, bk=bk, bn=bn, interpret=interpret
    )
    return out[:m, :n]
