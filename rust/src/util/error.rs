//! Minimal error plumbing — `anyhow` is unavailable in the offline
//! crate registry, so the binaries, examples and the runtime loader use
//! a boxed dynamic error plus [`anyhow!`]/[`bail!`] macros mirroring
//! the small subset of the anyhow API this codebase needs.
//!
//! [`anyhow!`]: crate::anyhow
//! [`bail!`]: crate::bail

/// A boxed dynamic error.
pub type Error = Box<dyn std::error::Error + Send + Sync + 'static>;

/// Result alias used by the CLI, the examples and the artifact runtime.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Build an [`Error`] from anything displayable.
pub fn msg(m: impl std::fmt::Display) -> Error {
    m.to_string().into()
}

/// Build an [`Error`] from a format string (with implicit capture) or
/// from any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($fmt:literal $(, $arg:expr)* $(,)?) => {
        $crate::util::error::msg(format!($fmt $(, $arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::util::error::msg($err)
    };
}

/// Return early with an [`anyhow!`]-constructed error.
///
/// [`anyhow!`]: crate::anyhow
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn msg_roundtrips_display() {
        let e = msg("boom");
        assert_eq!(e.to_string(), "boom");
    }

    #[test]
    fn anyhow_macro_formats_and_wraps() {
        let code = 7;
        let e = crate::anyhow!("failed with {code}");
        assert_eq!(e.to_string(), "failed with 7");
        let io = std::io::Error::other("io down");
        let e = crate::anyhow!(io);
        assert_eq!(e.to_string(), "io down");
    }

    #[test]
    fn bail_returns_err() {
        fn f(fail: bool) -> Result<u32> {
            if fail {
                crate::bail!("nope: {}", 3);
            }
            Ok(1)
        }
        assert_eq!(f(false).unwrap(), 1);
        assert_eq!(f(true).unwrap_err().to_string(), "nope: 3");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/path")?;
            Ok(s)
        }
        assert!(f().is_err());
    }
}
