//! The lightweight RISC-V host: an RV32I + Zicsr instruction-set
//! simulator (the Snitch-class control core of Sec. 3.1) plus the
//! assembler the compiler uses to generate real configuration programs.
//!
//! The host has **no M extension** — exactly like the paper's compact
//! RV32I core — so address/stride arithmetic in generated config code
//! uses shift-add sequences (see `compiler/codegen.rs`), which is a real
//! contributor to the configuration overhead that configuration
//! pre-loading hides.

pub mod cpu;
pub mod encode;

pub use cpu::{Cpu, CsrBus, Fault, StepResult, BRANCH_TAKEN_CYCLES, DATA_BASE};
pub use encode::{reg, Asm};
