//! CSR-based accelerator programming interface (Sec. 3.1-3.2).
//!
//! The host programs the GeMM core and the three data streamers through
//! standard RISC-V CSR instructions in a dedicated address range — no ISA
//! extension, no custom compiler. A `CSRManager` mediates between the
//! Snitch core and the accelerator at 32 bits/cycle, and implements
//! **configuration pre-loading (CPL)**: CSR writes land in a *staging*
//! bank while the accelerator runs, and a start command issued during a
//! run is latched and fires the moment the current run finishes,
//! overlapping configuration time with compute (Fig. 4(b)(1)).
//!
//! Register map (offsets within the accelerator CSR window):
//!
//! | off  | name        | meaning                                        |
//! |------|-------------|------------------------------------------------|
//! | 0x00 | BOUNDS      | packed loop bounds: Mt | Nt<<10 | Kt<<20        |
//! | 0x01 | A_BASE      | A operand byte base                            |
//! | 0x02 | A_STRIDE_M  | A byte stride per m1                           |
//! | 0x03 | A_STRIDE_K  | A byte stride per k1                           |
//! | 0x04 | A_SPATIAL0  | A inner spatial byte stride                    |
//! | 0x05 | A_SPATIAL1  | A outer spatial byte stride                    |
//! | 0x06 | B_BASE      | B operand byte base                            |
//! | 0x07 | B_STRIDE_N  | B byte stride per n1                           |
//! | 0x08 | B_STRIDE_K  | B byte stride per k1                           |
//! | 0x09 | B_SPATIAL0  | B inner spatial byte stride                    |
//! | 0x0a | B_SPATIAL1  | B outer spatial byte stride                    |
//! | 0x0b | C_BASE      | C result byte base                             |
//! | 0x0c | C_STRIDE_M  | C byte stride per m1                           |
//! | 0x0d | C_STRIDE_N  | C byte stride per n1                           |
//! | 0x0e | C_SPATIAL0  | C inner spatial byte stride                    |
//! | 0x0f | C_SPATIAL1  | C outer spatial byte stride                    |
//! | 0x10 | CTRL        | write 1: start                                 |
//! | 0x11 | STATUS      | read-only: bit0 busy, bit1 start-pending       |
//!
//! Spatial loop *counts* are design-time constants derived from the core
//! geometry (Sec. 3.4: "at design time we configure the AGU ... how many
//! nested loops are needed"); only the strides are run-time CSRs.
//!
//! BOUNDS packs all three bounds in one CSR ("multiple accelerator
//! configurations can be consolidated into a single CSR to optimize
//! configuration cycles"), 10 bits each.

use crate::config::GemmCoreParams;
use crate::streamer::{AguConfig, LoopBounds};

/// Base CSR address of the accelerator window (the platform allocates a
/// custom-range block, as SNAX does).
pub const CSR_BASE: u32 = 0x3c0;
/// Number of implemented CSRs.
pub const CSR_COUNT: usize = 18;

pub const CSR_BOUNDS: u32 = CSR_BASE;
pub const CSR_A_BASE: u32 = CSR_BASE + 0x1;
pub const CSR_A_STRIDE_M: u32 = CSR_BASE + 0x2;
pub const CSR_A_STRIDE_K: u32 = CSR_BASE + 0x3;
pub const CSR_A_SPATIAL0: u32 = CSR_BASE + 0x4;
pub const CSR_A_SPATIAL1: u32 = CSR_BASE + 0x5;
pub const CSR_B_BASE: u32 = CSR_BASE + 0x6;
pub const CSR_B_STRIDE_N: u32 = CSR_BASE + 0x7;
pub const CSR_B_STRIDE_K: u32 = CSR_BASE + 0x8;
pub const CSR_B_SPATIAL0: u32 = CSR_BASE + 0x9;
pub const CSR_B_SPATIAL1: u32 = CSR_BASE + 0xa;
pub const CSR_C_BASE: u32 = CSR_BASE + 0xb;
pub const CSR_C_STRIDE_M: u32 = CSR_BASE + 0xc;
pub const CSR_C_STRIDE_N: u32 = CSR_BASE + 0xd;
pub const CSR_C_SPATIAL0: u32 = CSR_BASE + 0xe;
pub const CSR_C_SPATIAL1: u32 = CSR_BASE + 0xf;
pub const CSR_CTRL: u32 = CSR_BASE + 0x10;
pub const CSR_STATUS: u32 = CSR_BASE + 0x11;

/// Base CSR address of core `core_idx`'s window: the windows of a
/// multi-core platform are stacked contiguously above `CSR_BASE`, one
/// `CSR_COUNT`-register block per core (core 0's window is the
/// single-core map above, so one-core platforms are unchanged).
pub fn core_csr_base(core_idx: usize) -> u32 {
    CSR_BASE + (core_idx * CSR_COUNT) as u32
}

/// Design-time spatial counts for each streamer's AGU, derived from the
/// core geometry and the memory word size.
pub fn spatial_counts(core: &GemmCoreParams, word_bytes: usize) -> ((usize, usize), (usize, usize), (usize, usize)) {
    let row = |bytes: usize| (bytes / word_bytes).max(1);
    // A': Mu rows of Ku*P_A/8 bytes; B': Ku rows of Nu*P_B/8 bytes;
    // C': Mu rows of Nu*P_C/8 bytes.
    let a = (row(core.ku * core.pa_bits / 8), core.mu);
    let b = (row(core.nu * core.pb_bits / 8), core.ku);
    let c = (row(core.nu * core.pc_bits / 8), core.mu);
    (a, b, c)
}

pub const STATUS_BUSY: u32 = 1 << 0;
pub const STATUS_PENDING: u32 = 1 << 1;

/// The sixteen run-time configuration CSRs, in programming order — the
/// complete write set one launch consumes (CTRL and STATUS are command/
/// status, not configuration). The static verifier checks every launch
/// window against this list.
pub const CONFIG_CSR_ADDRS: [u32; 16] = [
    CSR_BOUNDS,
    CSR_A_BASE,
    CSR_A_STRIDE_M,
    CSR_A_STRIDE_K,
    CSR_A_SPATIAL0,
    CSR_A_SPATIAL1,
    CSR_B_BASE,
    CSR_B_STRIDE_N,
    CSR_B_STRIDE_K,
    CSR_B_SPATIAL0,
    CSR_B_SPATIAL1,
    CSR_C_BASE,
    CSR_C_STRIDE_M,
    CSR_C_STRIDE_N,
    CSR_C_SPATIAL0,
    CSR_C_SPATIAL1,
];

/// Human-readable register name for diagnostics.
pub fn csr_name(addr: u32) -> &'static str {
    match addr {
        CSR_BOUNDS => "BOUNDS",
        CSR_A_BASE => "A_BASE",
        CSR_A_STRIDE_M => "A_STRIDE_M",
        CSR_A_STRIDE_K => "A_STRIDE_K",
        CSR_A_SPATIAL0 => "A_SPATIAL0",
        CSR_A_SPATIAL1 => "A_SPATIAL1",
        CSR_B_BASE => "B_BASE",
        CSR_B_STRIDE_N => "B_STRIDE_N",
        CSR_B_STRIDE_K => "B_STRIDE_K",
        CSR_B_SPATIAL0 => "B_SPATIAL0",
        CSR_B_SPATIAL1 => "B_SPATIAL1",
        CSR_C_BASE => "C_BASE",
        CSR_C_STRIDE_M => "C_STRIDE_M",
        CSR_C_STRIDE_N => "C_STRIDE_N",
        CSR_C_SPATIAL0 => "C_SPATIAL0",
        CSR_C_SPATIAL1 => "C_SPATIAL1",
        CSR_CTRL => "CTRL",
        CSR_STATUS => "STATUS",
        _ => "unmapped",
    }
}

/// Pack (Mt, Nt, Kt) into the BOUNDS register (10 bits each).
pub fn pack_bounds(b: LoopBounds) -> u32 {
    debug_assert!(b.mt <= 1024 && b.nt <= 1024 && b.kt <= 1024);
    // A bound of 1024 encodes as 0 is ambiguous with 0; hardware encodes
    // bound-1 per field.
    (((b.mt - 1) & 0x3ff) | (((b.nt - 1) & 0x3ff) << 10) | (((b.kt - 1) & 0x3ff) << 20)) as u32
}

/// Unpack the BOUNDS register.
pub fn unpack_bounds(v: u32) -> LoopBounds {
    LoopBounds {
        mt: ((v as u64) & 0x3ff) + 1,
        nt: ((v as u64 >> 10) & 0x3ff) + 1,
        kt: ((v as u64 >> 20) & 0x3ff) + 1,
    }
}

/// A complete accelerator configuration snapshot (one staging bank).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ConfigRegs {
    pub regs: [u32; CSR_COUNT],
}

impl ConfigRegs {
    fn idx(addr: u32) -> usize {
        (addr - CSR_BASE) as usize
    }

    pub fn bounds(&self) -> LoopBounds {
        unpack_bounds(self.regs[Self::idx(CSR_BOUNDS)])
    }

    /// Build the A-streamer AGU program. Spatial counts are design-time
    /// properties derived from the core geometry.
    pub fn a_agu(&self, core: &GemmCoreParams, word_bytes: usize) -> AguConfig {
        let ((c0, c1), _, _) = spatial_counts(core, word_bytes);
        AguConfig {
            base: self.regs[Self::idx(CSR_A_BASE)] as u64,
            stride_m: self.regs[Self::idx(CSR_A_STRIDE_M)] as i32 as i64,
            stride_n: 0, // A is reused along n1 (design-time pattern)
            stride_k: self.regs[Self::idx(CSR_A_STRIDE_K)] as i32 as i64,
            spatial0_count: c0,
            spatial0_stride: self.regs[Self::idx(CSR_A_SPATIAL0)] as i32 as i64,
            spatial1_count: c1,
            spatial1_stride: self.regs[Self::idx(CSR_A_SPATIAL1)] as i32 as i64,
        }
    }

    pub fn b_agu(&self, core: &GemmCoreParams, word_bytes: usize) -> AguConfig {
        let (_, (c0, c1), _) = spatial_counts(core, word_bytes);
        AguConfig {
            base: self.regs[Self::idx(CSR_B_BASE)] as u64,
            stride_m: 0, // B is reused along m1
            stride_n: self.regs[Self::idx(CSR_B_STRIDE_N)] as i32 as i64,
            stride_k: self.regs[Self::idx(CSR_B_STRIDE_K)] as i32 as i64,
            spatial0_count: c0,
            spatial0_stride: self.regs[Self::idx(CSR_B_SPATIAL0)] as i32 as i64,
            spatial1_count: c1,
            spatial1_stride: self.regs[Self::idx(CSR_B_SPATIAL1)] as i32 as i64,
        }
    }

    pub fn c_agu(&self, core: &GemmCoreParams, word_bytes: usize) -> AguConfig {
        let (_, _, (c0, c1)) = spatial_counts(core, word_bytes);
        AguConfig {
            base: self.regs[Self::idx(CSR_C_BASE)] as u64,
            stride_m: self.regs[Self::idx(CSR_C_STRIDE_M)] as i32 as i64,
            stride_n: self.regs[Self::idx(CSR_C_STRIDE_N)] as i32 as i64,
            stride_k: 0, // C is output-stationary: no k1 dependence
            spatial0_count: c0,
            spatial0_stride: self.regs[Self::idx(CSR_C_SPATIAL0)] as i32 as i64,
            spatial1_count: c1,
            spatial1_stride: self.regs[Self::idx(CSR_C_SPATIAL1)] as i32 as i64,
        }
    }
}

/// Error on CSR access.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CsrError {
    /// Address outside the accelerator window.
    BadAddress(u32),
    /// Start issued while busy with CPL disabled (the host must poll).
    StartWhileBusy,
    /// Start issued while a pre-loaded start is already pending.
    DoublePending,
}

impl std::fmt::Display for CsrError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CsrError::BadAddress(a) => write!(f, "CSR address {a:#x} not mapped"),
            CsrError::StartWhileBusy => write!(f, "start while busy without CPL"),
            CsrError::DoublePending => write!(f, "start while a start is already pending"),
        }
    }
}

impl std::error::Error for CsrError {}

/// The CSRManager: staging bank + pre-load latch.
#[derive(Debug, Clone)]
pub struct CsrManager {
    /// Configuration pre-loading enabled (design-time mechanism toggle
    /// for the ablation; always true in the shipping platform).
    pub cpl: bool,
    /// Base address of this manager's CSR window (per-core on
    /// multi-core platforms; [`CSR_BASE`] on core 0 / single core).
    pub base: u32,
    staging: ConfigRegs,
    /// Latched (config, ) waiting for the current run to finish.
    pending: Option<ConfigRegs>,
    /// Set for one platform poll after a start fires.
    start_fired: Option<ConfigRegs>,
    /// Mirrors the accelerator busy state (updated by the platform).
    busy: bool,
    /// Cycles the host spent on accepted CSR accesses.
    pub access_cycles: u64,
}

impl CsrManager {
    pub fn new(cpl: bool) -> CsrManager {
        CsrManager::with_base(cpl, CSR_BASE)
    }

    /// A manager whose window starts at `base` (per-core windows on
    /// multi-core platforms; see [`core_csr_base`]).
    pub fn with_base(cpl: bool, base: u32) -> CsrManager {
        CsrManager {
            cpl,
            base,
            staging: ConfigRegs::default(),
            pending: None,
            start_fired: None,
            busy: false,
            access_cycles: 0,
        }
    }

    /// Host-side CSR write (one cycle per accepted write).
    pub fn write(&mut self, addr: u32, value: u32) -> Result<(), CsrError> {
        if !(self.base..self.base + CSR_COUNT as u32).contains(&addr) {
            return Err(CsrError::BadAddress(addr));
        }
        self.access_cycles += 1;
        let off = addr - self.base;
        if off == CSR_CTRL - CSR_BASE {
            if value & 1 == 0 {
                return Ok(()); // no-op control write
            }
            return self.request_start();
        }
        if off == CSR_STATUS - CSR_BASE {
            return Ok(()); // read-only: writes ignored
        }
        self.staging.regs[off as usize] = value;
        Ok(())
    }

    /// Host-side CSR read.
    pub fn read(&mut self, addr: u32) -> Result<u32, CsrError> {
        if !(self.base..self.base + CSR_COUNT as u32).contains(&addr) {
            return Err(CsrError::BadAddress(addr));
        }
        self.access_cycles += 1;
        let off = addr - self.base;
        if off == CSR_STATUS - CSR_BASE {
            let mut v = 0;
            if self.busy {
                v |= STATUS_BUSY;
            }
            if self.pending.is_some() {
                v |= STATUS_PENDING;
            }
            return Ok(v);
        }
        Ok(self.staging.regs[off as usize])
    }

    fn request_start(&mut self) -> Result<(), CsrError> {
        if self.busy {
            if !self.cpl {
                return Err(CsrError::StartWhileBusy);
            }
            if self.pending.is_some() {
                return Err(CsrError::DoublePending);
            }
            // CPL: snapshot the staging bank; fires on run completion.
            self.pending = Some(self.staging);
            return Ok(());
        }
        self.start_fired = Some(self.staging);
        self.busy = true;
        Ok(())
    }

    /// Platform side: the accelerator finished its run. If a pre-loaded
    /// start is pending it fires immediately (the 1-cycle CPL swap).
    pub fn notify_done(&mut self) {
        self.busy = false;
        if let Some(cfg) = self.pending.take() {
            self.start_fired = Some(cfg);
            self.busy = true;
        }
    }

    /// Platform side: poll for a fired start (consumed once).
    pub fn take_start(&mut self) -> Option<ConfigRegs> {
        self.start_fired.take()
    }

    /// Platform side: is a fired start waiting to be consumed? (Lets
    /// the fast-forward engine see the launch coming without taking
    /// it.)
    pub fn has_fired_start(&self) -> bool {
        self.start_fired.is_some()
    }

    pub fn is_busy(&self) -> bool {
        self.busy
    }

    pub fn has_pending(&self) -> bool {
        self.pending.is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bounds_pack_roundtrip() {
        for b in [
            LoopBounds { mt: 1, nt: 1, kt: 1 },
            LoopBounds { mt: 32, nt: 17, kt: 1024 },
            LoopBounds { mt: 1024, nt: 1024, kt: 1024 },
        ] {
            assert_eq!(unpack_bounds(pack_bounds(b)), b);
        }
    }

    #[test]
    fn write_then_start_fires() {
        let mut csr = CsrManager::new(false);
        csr.write(CSR_BOUNDS, pack_bounds(LoopBounds { mt: 2, nt: 3, kt: 4 })).unwrap();
        csr.write(CSR_A_BASE, 0x100).unwrap();
        csr.write(CSR_CTRL, 1).unwrap();
        let cfg = csr.take_start().expect("start fired");
        assert_eq!(cfg.bounds(), LoopBounds { mt: 2, nt: 3, kt: 4 });
        assert!(csr.is_busy());
        assert!(csr.take_start().is_none(), "consumed once");
    }

    #[test]
    fn start_while_busy_without_cpl_rejected() {
        let mut csr = CsrManager::new(false);
        csr.write(CSR_CTRL, 1).unwrap();
        csr.take_start().unwrap();
        assert_eq!(csr.write(CSR_CTRL, 1), Err(CsrError::StartWhileBusy));
    }

    #[test]
    fn cpl_latches_and_fires_on_done() {
        let mut csr = CsrManager::new(true);
        csr.write(CSR_BOUNDS, pack_bounds(LoopBounds { mt: 1, nt: 1, kt: 1 })).unwrap();
        csr.write(CSR_CTRL, 1).unwrap();
        csr.take_start().unwrap();
        // pre-load the next run while busy
        csr.write(CSR_BOUNDS, pack_bounds(LoopBounds { mt: 5, nt: 6, kt: 7 })).unwrap();
        csr.write(CSR_CTRL, 1).unwrap();
        assert!(csr.has_pending());
        assert_eq!(csr.read(CSR_STATUS).unwrap(), STATUS_BUSY | STATUS_PENDING);
        // double pre-load is a programming error
        assert_eq!(csr.write(CSR_CTRL, 1), Err(CsrError::DoublePending));
        // run completes -> pending start fires with the *new* config
        csr.notify_done();
        let cfg = csr.take_start().expect("pre-loaded start fired");
        assert_eq!(cfg.bounds(), LoopBounds { mt: 5, nt: 6, kt: 7 });
        assert!(csr.is_busy());
    }

    #[test]
    fn staging_isolated_from_running_config() {
        let mut csr = CsrManager::new(true);
        csr.write(CSR_A_BASE, 111).unwrap();
        csr.write(CSR_CTRL, 1).unwrap();
        let run1 = csr.take_start().unwrap();
        // mutate staging during the run; run1's snapshot must not change
        csr.write(CSR_A_BASE, 222).unwrap();
        assert_eq!(run1.regs[1], 111);
        assert_eq!(csr.read(CSR_A_BASE).unwrap(), 222);
    }

    #[test]
    fn status_reflects_done() {
        let mut csr = CsrManager::new(false);
        csr.write(CSR_CTRL, 1).unwrap();
        csr.take_start().unwrap();
        assert_eq!(csr.read(CSR_STATUS).unwrap() & STATUS_BUSY, STATUS_BUSY);
        csr.notify_done();
        assert_eq!(csr.read(CSR_STATUS).unwrap() & STATUS_BUSY, 0);
    }

    #[test]
    fn bad_address_rejected() {
        let mut csr = CsrManager::new(false);
        assert!(matches!(csr.write(0x100, 0), Err(CsrError::BadAddress(_))));
        assert!(matches!(csr.read(0x7ff), Err(CsrError::BadAddress(_))));
    }

    #[test]
    fn windowed_manager_routes_by_base() {
        let base = core_csr_base(2);
        assert_eq!(base, CSR_BASE + 2 * CSR_COUNT as u32);
        let mut csr = CsrManager::with_base(true, base);
        // core-0 addresses are outside core 2's window
        assert!(matches!(csr.write(CSR_A_BASE, 1), Err(CsrError::BadAddress(_))));
        csr.write(base + (CSR_A_BASE - CSR_BASE), 77).unwrap();
        csr.write(base + (CSR_CTRL - CSR_BASE), 1).unwrap();
        let cfg = csr.take_start().expect("start fired in window");
        assert_eq!(cfg.regs[1], 77);
        assert_eq!(
            csr.read(base + (CSR_STATUS - CSR_BASE)).unwrap() & STATUS_BUSY,
            STATUS_BUSY
        );
    }

    #[test]
    fn agu_builders_use_design_time_pattern() {
        let core = GemmCoreParams::CASE_STUDY;
        let mut csr = CsrManager::new(false);
        csr.write(CSR_A_BASE, 0).unwrap();
        csr.write(CSR_A_STRIDE_M, 512).unwrap();
        csr.write(CSR_A_STRIDE_K, 8).unwrap();
        csr.write(CSR_A_SPATIAL1, 64).unwrap();
        csr.write(CSR_CTRL, 1).unwrap();
        let cfg = csr.take_start().unwrap();
        let a = cfg.a_agu(&core, 8);
        assert_eq!(a.ports(), 8);
        assert_eq!(a.stride_n, 0, "A has no n1 dependence by construction");
        let c = cfg.c_agu(&core, 8);
        assert_eq!(c.ports(), 32);
        assert_eq!(c.stride_k, 0, "C is output-stationary");
    }

    #[test]
    fn spatial_counts_match_geometry() {
        let core = GemmCoreParams::CASE_STUDY;
        let (a, b, c) = spatial_counts(&core, 8);
        assert_eq!(a, (1, 8));
        assert_eq!(b, (1, 8));
        assert_eq!(c, (4, 8));
        // 16-bit accumulator variant: C rows are 16B = 2 words
        let mut core16 = core;
        core16.pc_bits = 16;
        let (_, _, c16) = spatial_counts(&core16, 8);
        assert_eq!(c16, (2, 8));
    }

    #[test]
    fn access_cycles_counted() {
        let mut csr = CsrManager::new(false);
        csr.write(CSR_A_BASE, 1).unwrap();
        csr.read(CSR_A_BASE).unwrap();
        csr.read(CSR_STATUS).unwrap();
        assert_eq!(csr.access_cycles, 3);
    }
}
