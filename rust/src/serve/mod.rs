//! Sustained-traffic serving harness: the platform as an inference
//! service under load, not just a per-kernel simulator.
//!
//! OpenGeMM's pitch is system-level efficiency under real DNN
//! workloads (the paper evaluates full model streams, like its Gemmini
//! baseline). This module turns the one-shot `bert_serving` example
//! loop into a proper serving-systems harness:
//!
//! 1. **Request kinds** ([`workload`]): a request is a named GeMM
//!    stream — a BERT encoder layer at a sampled sequence length, or a
//!    full CNN (ResNet-18) inference.
//! 2. **Service model** ([`service`]): each distinct `(shape,
//!    repeats)` point is simulated once, cycle-accurately, through the
//!    coordinator pool; repeat counts are honored exactly up to a cap
//!    (no more silent 12-repeat clamping — BERT-Large's 16 heads are
//!    measured as 16) and extrapolated by marginal cost beyond it.
//! 3. **Arrival process** ([`arrival`]): open-loop Poisson or
//!    closed-loop N-clients, seeded via [`Pcg32`].
//! 4. **Queueing model** ([`queue`]): a virtual-time single-device
//!    timeline under a pluggable [`BatchPolicy`] ([`batching`]),
//!    yielding per-request queueing + service latency in device
//!    cycles.
//! 5. **Report** ([`report`]): p50/p90/p95/p99/max latency
//!    percentiles as a table and as deterministic JSON (same seed =>
//!    byte-identical bytes, enforced by tests and the `serve-smoke` CI
//!    lane).
//!
//! Everything is a pure function of `(PlatformConfig, ServeOptions)`;
//! no wall clock enters the report.

pub mod arrival;
pub mod batching;
pub mod queue;
pub mod report;
pub mod service;
pub mod workload;

pub use arrival::ArrivalSpec;
pub use batching::BatchPolicy;
pub use report::{KindSummary, ServeReport, SERVE_REPORT_FORMAT};
pub use service::ServiceModel;
pub use workload::{RequestKind, WorkloadSpec};

use crate::config::PlatformConfig;
use crate::util::rng::Pcg32;
use crate::util::stats::TailSummary;

use arrival::poisson_arrival_cycles;
use queue::{simulate_queue, ArrivalSource};

/// RNG stream selectors (see [`Pcg32::new`]): arrival timing and
/// request-kind sampling draw from independent deterministic streams
/// of the same seed, so changing the request count perturbs neither.
const ARRIVAL_STREAM: u64 = 0x5e7e_a221;
const KIND_STREAM: u64 = 0x5e7e_71fe;

/// Everything one serving run depends on (besides the platform).
#[derive(Debug, Clone, PartialEq)]
pub struct ServeOptions {
    pub workload: WorkloadSpec,
    pub arrival: ArrivalSpec,
    pub batching: BatchPolicy,
    /// Requests to schedule (0 = an idle window, which must produce an
    /// empty report rather than a panic).
    pub requests: usize,
    pub seed: u64,
    /// Worker threads for the measurement coordinator (0 = auto).
    pub workers: usize,
    pub fast_forward: bool,
    /// Service-model exact-measurement cap (see [`ServiceModel`]).
    pub repeat_cap: u32,
    /// Host dispatch cost paid once per batch, in device cycles —
    /// what size/deadline batching amortizes.
    pub dispatch_overhead_cycles: u64,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            workload: WorkloadSpec::BertBase {
                seq_choices: WorkloadSpec::DEFAULT_SEQS.to_vec(),
            },
            arrival: ArrivalSpec::OpenPoisson { rate_rps: 200.0 },
            batching: BatchPolicy::Immediate,
            requests: 64,
            seed: 1,
            workers: 0,
            fast_forward: true,
            repeat_cap: 16,
            dispatch_overhead_cycles: 0,
        }
    }
}

/// Milliseconds of virtual time to device cycles at `freq_mhz`.
pub fn ms_to_cycles(ms: f64, freq_mhz: u64) -> u64 {
    (ms * freq_mhz as f64 * 1e3).round() as u64
}

fn validate(opts: &ServeOptions) -> Result<(), String> {
    match opts.arrival {
        ArrivalSpec::OpenPoisson { rate_rps } => {
            if !rate_rps.is_finite() || rate_rps <= 0.0 {
                return Err(format!("arrival rate must be a positive rate, got {rate_rps}"));
            }
        }
        ArrivalSpec::ClosedLoop { clients, .. } => {
            if clients == 0 {
                return Err("closed-loop arrival needs at least 1 client".into());
            }
        }
    }
    Ok(())
}

/// Run the serving harness end to end.
pub fn run_serve(cfg: &PlatformConfig, opts: &ServeOptions) -> Result<ServeReport, String> {
    validate(opts)?;
    let kinds = opts.workload.kinds();
    if kinds.is_empty() {
        return Err("workload has no request kinds".into());
    }

    // 1. measure service times (the only simulation work)
    let mut model = ServiceModel::new(opts.repeat_cap);
    let measurement = model.measure(cfg, opts.workers, opts.fast_forward, &kinds)?;
    let service_by_kind: Vec<u64> = kinds
        .iter()
        .map(|k| model.stream_cycles(&k.stream))
        .collect::<Result<_, _>>()?;

    // 2. generate arrivals and run the virtual-time queueing model
    let mut source = match opts.arrival {
        ArrivalSpec::OpenPoisson { rate_rps } => {
            let mut arrival_rng = Pcg32::new(opts.seed, ARRIVAL_STREAM);
            let mut kind_rng = Pcg32::new(opts.seed, KIND_STREAM);
            let times =
                poisson_arrival_cycles(rate_rps, cfg.freq_mhz, opts.requests, &mut arrival_rng);
            let arrivals: Vec<(u64, usize)> = times
                .into_iter()
                .map(|t| (t, kind_rng.below(kinds.len() as u32) as usize))
                .collect();
            ArrivalSource::open(arrivals)
        }
        ArrivalSpec::ClosedLoop { clients, think_cycles } => ArrivalSource::closed(
            clients,
            think_cycles,
            opts.requests,
            kinds.len(),
            Pcg32::new(opts.seed, KIND_STREAM),
        ),
    };
    let overhead = opts.dispatch_overhead_cycles;
    let outcome = simulate_queue(&mut source, &service_by_kind, opts.batching, overhead);

    // 3. aggregate into the report (virtual time only)
    let to_ms = |c: u64| c as f64 / (cfg.freq_mhz as f64 * 1e3);
    let n = outcome.records.len();
    let mut latency = Vec::with_capacity(n);
    let mut queueing = Vec::with_capacity(n);
    let mut service = Vec::with_capacity(n);
    let mut served_by_kind = vec![0usize; kinds.len()];
    for r in &outcome.records {
        latency.push(to_ms(r.completion - r.arrival));
        queueing.push(to_ms(r.start - r.arrival));
        service.push(to_ms(r.completion - r.start));
        served_by_kind[r.kind] += 1;
    }
    let kind_summaries: Vec<KindSummary> = kinds
        .iter()
        .zip(&served_by_kind)
        .zip(&service_by_kind)
        .map(|((k, &served), &service_cycles)| KindSummary {
            label: k.label.clone(),
            served,
            service_cycles,
        })
        .collect();

    Ok(ServeReport {
        workload: opts.workload.to_json(),
        arrival: opts.arrival,
        batching: opts.batching,
        seed: opts.seed,
        freq_mhz: cfg.freq_mhz,
        requests: outcome.records.len(),
        batches: outcome.batches.len(),
        duration_cycles: outcome.records.iter().map(|r| r.completion).max().unwrap_or(0),
        device_busy_cycles: outcome.batches.iter().map(|b| b.completion - b.start).sum(),
        latency_ms: TailSummary::compute(&latency),
        queueing_ms: TailSummary::compute(&queueing),
        service_ms: TailSummary::compute(&service),
        kinds: kind_summaries,
        measurement,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_opts() -> ServeOptions {
        ServeOptions {
            workload: WorkloadSpec::BertBase { seq_choices: vec![64] },
            arrival: ArrivalSpec::OpenPoisson { rate_rps: 2000.0 },
            requests: 8,
            seed: 11,
            workers: 2,
            ..Default::default()
        }
    }

    #[test]
    fn serve_produces_percentiles() {
        let cfg = PlatformConfig::case_study();
        let report = run_serve(&cfg, &tiny_opts()).unwrap();
        assert_eq!(report.requests, 8);
        let lat = report.latency_ms.as_ref().expect("non-empty window");
        assert!(lat.p50 > 0.0 && lat.p99 >= lat.p50 && lat.max >= lat.p99);
        assert!(report.duration_cycles > 0);
        assert!(report.device_utilization() > 0.0);
    }

    #[test]
    fn idle_window_yields_empty_report() {
        let cfg = PlatformConfig::case_study();
        let idle = ServeOptions { requests: 0, ..tiny_opts() };
        let report = run_serve(&cfg, &idle).unwrap();
        assert_eq!(report.requests, 0);
        assert_eq!(report.latency_ms, None);
        assert_eq!(report.duration_cycles, 0);
        assert!(report.to_json().pretty().contains("null"));
    }

    #[test]
    fn invalid_options_are_rejected() {
        let cfg = PlatformConfig::case_study();
        let bad_rate = ServeOptions {
            arrival: ArrivalSpec::OpenPoisson { rate_rps: 0.0 },
            ..tiny_opts()
        };
        assert!(run_serve(&cfg, &bad_rate).is_err());
        let no_clients = ServeOptions {
            arrival: ArrivalSpec::ClosedLoop { clients: 0, think_cycles: 0 },
            ..tiny_opts()
        };
        assert!(run_serve(&cfg, &no_clients).is_err());
    }

    #[test]
    fn ms_to_cycles_at_200mhz() {
        assert_eq!(ms_to_cycles(1.0, 200), 200_000);
        assert_eq!(ms_to_cycles(0.0, 200), 0);
    }
}
