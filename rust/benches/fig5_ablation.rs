//! Bench: regenerate Fig. 5 — the full 500-workload x 10-repeat x
//! 6-variant utilization ablation. Prints the box-plot statistics and
//! the median-improvement ratios next to the paper's quoted values.
//!
//! Run with:  cargo bench --bench fig5_ablation
//! Env: FIG5_WORKLOADS=500 FIG5_SEED=2024 to override.

use std::time::Instant;

use opengemm::config::PlatformConfig;
use opengemm::experiments::{fig5_ablation, Fig5Options};

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let cfg = PlatformConfig::case_study();
    let opts = Fig5Options {
        seed: env_usize("FIG5_SEED", 2024) as u64,
        workloads: env_usize("FIG5_WORKLOADS", 500),
        repeats: 10,
        workers: env_usize("FIG5_WORKERS", 0),
        ..Default::default()
    };
    eprintln!(
        "fig5: {} workloads x {} repeats x 6 variants",
        opts.workloads, opts.repeats
    );
    let t0 = Instant::now();
    let res = fig5_ablation(&cfg, opts);
    let wall = t0.elapsed();
    println!("{}", res.render());
    println!(
        "bench fig5_ablation: {:.2}s wall for {} simulations",
        wall.as_secs_f64(),
        opts.workloads * 6
    );
}
