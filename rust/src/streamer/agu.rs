//! Programmable strided address-generation unit (AGU).
//!
//! Sec. 3.4: each data streamer has a configurable strided AGU. At design
//! time the *pattern* is fixed — how many nested spatial loops the access
//! needs and the port count; at run time the host programs *hardware loop
//! bounds, a base address, and two-dimensional memory strides*. The AGU
//! follows the GeMM core's three temporal loops `(m1, n1, k1)` and emits,
//! per tile, one word address per port:
//!
//! ```text
//! addr(port, m1, n1, k1) = base + m1*stride_m + n1*stride_n + k1*stride_k
//!                               + (port % c0)*spatial0 + (port / c0)*spatial1
//! ```
//!
//! where `(c0, c1)` are the design-time spatial counts (`port` ranges
//! over `c0*c1`). A-/B-streamers use a degenerate 1D pattern (`c0 = 1`);
//! the C-streamer writes `Mu` rows of `Nu*P_C` bits and needs the full
//! 2D form. A zero temporal stride expresses operand reuse along that
//! loop (A does not depend on n1, B does not depend on m1) — the same
//! trick the paper's streamers use to rewalk a tile without host
//! involvement.

/// Precomputed bank-occupancy pattern of one tile access (timing-only
/// fast path): rotate by the tile base to get the actual bank set.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BankPattern {
    /// Banks touched with the tile base at bank 0.
    pub mask: u64,
    /// True if two ports of one access land in the same bank.
    pub self_conflict: bool,
    pub n_bank: u32,
}

impl BankPattern {
    /// Bank mask for a tile whose base word sits in `base_bank`.
    #[inline]
    pub fn mask_at(&self, base_bank: u32) -> u64 {
        let n = self.n_bank;
        debug_assert!(base_bank < n);
        let all = if n == 64 { u64::MAX } else { (1u64 << n) - 1 };
        ((self.mask << base_bank) | (self.mask >> (n - base_bank).min(63))) & all
    }
}

/// Run-time AGU program (one per streamer).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct AguConfig {
    /// Base byte address of the operand in SPM.
    pub base: u64,
    /// Byte stride applied per m1 step.
    pub stride_m: i64,
    /// Byte stride applied per n1 step.
    pub stride_n: i64,
    /// Byte stride applied per k1 step.
    pub stride_k: i64,
    /// Inner spatial count (design-time; words per row of the access).
    pub spatial0_count: usize,
    /// Inner spatial byte stride (run-time).
    pub spatial0_stride: i64,
    /// Outer spatial count (design-time; rows of the access).
    pub spatial1_count: usize,
    /// Outer spatial byte stride (run-time).
    pub spatial1_stride: i64,
}

impl AguConfig {
    /// A degenerate 1D spatial pattern with `ports` words.
    pub fn linear(base: u64, ports: usize, spatial_stride: i64) -> AguConfig {
        AguConfig {
            base,
            spatial0_count: 1,
            spatial0_stride: 0,
            spatial1_count: ports,
            spatial1_stride: spatial_stride,
            ..Default::default()
        }
    }

    /// Total ports (words per tile access).
    #[inline]
    pub fn ports(&self) -> usize {
        self.spatial0_count * self.spatial1_count
    }

    /// Byte address of `port` at temporal position `(m1, n1, k1)`.
    #[inline]
    pub fn byte_addr(&self, m1: u64, n1: u64, k1: u64, port: u64) -> u64 {
        let s0 = (port % self.spatial0_count as u64) as i64;
        let s1 = (port / self.spatial0_count as u64) as i64;
        let off = m1 as i64 * self.stride_m
            + n1 as i64 * self.stride_n
            + k1 as i64 * self.stride_k
            + s0 * self.spatial0_stride
            + s1 * self.spatial1_stride;
        (self.base as i64 + off) as u64
    }

    /// Emit the word addresses of one tile access into `out`
    /// (`out.len() == ports()`), given the word size in bytes.
    ///
    /// Hot path: walks the two spatial loops incrementally (no per-port
    /// multiply) and uses a shift for the byte->word conversion
    /// (`word_bytes` is a power of two for every valid MemParams).
    pub fn tile_word_addrs(
        &self,
        m1: u64,
        n1: u64,
        k1: u64,
        word_bytes: u64,
        out: &mut Vec<u64>,
    ) {
        out.clear();
        debug_assert!(word_bytes.is_power_of_two());
        let shift = word_bytes.trailing_zeros();
        let tile_base = self.base as i64
            + m1 as i64 * self.stride_m
            + n1 as i64 * self.stride_n
            + k1 as i64 * self.stride_k;
        let mut row = tile_base;
        for _ in 0..self.spatial1_count {
            let mut addr = row;
            for _ in 0..self.spatial0_count {
                out.push((addr as u64) >> shift);
                addr += self.spatial0_stride;
            }
            row += self.spatial1_stride;
        }
    }

    /// Byte address of port 0 at `(m1, n1, k1)` (the tile base).
    #[inline]
    pub fn tile_base(&self, m1: u64, n1: u64, k1: u64) -> i64 {
        self.base as i64
            + m1 as i64 * self.stride_m
            + n1 as i64 * self.stride_n
            + k1 as i64 * self.stride_k
    }

    /// Precompute the bank-occupancy pattern of one tile access for
    /// timing-only simulation: the set of banks touched relative to the
    /// tile base, valid for any word-aligned tile base (every layout the
    /// compiler emits is word-aligned). Returns `None` when the spatial
    /// strides are not word multiples (the simulator then falls back to
    /// materializing addresses).
    pub fn bank_pattern(&self, word_bytes: u64, n_bank: usize) -> Option<BankPattern> {
        if n_bank > 64 || !n_bank.is_power_of_two() {
            return None;
        }
        let mut mask = 0u64;
        let mut self_conflict = false;
        for s1 in 0..self.spatial1_count as i64 {
            for s0 in 0..self.spatial0_count as i64 {
                let off = s0 * self.spatial0_stride + s1 * self.spatial1_stride;
                if off % word_bytes as i64 != 0 {
                    return None;
                }
                let bank = (off / word_bytes as i64).rem_euclid(n_bank as i64) as u32;
                let bit = 1u64 << bank;
                self_conflict |= mask & bit != 0;
                mask |= bit;
            }
        }
        // temporal strides must also be word multiples for the rotation
        // trick to stay exact
        for st in [self.stride_m, self.stride_n, self.stride_k, self.base as i64] {
            if st % word_bytes as i64 != 0 {
                return None;
            }
        }
        Some(BankPattern { mask, self_conflict, n_bank: n_bank as u32 })
    }

    /// Highest byte address touched over the loop volume (for bounds
    /// validation against SPM capacity). Assumes non-negative strides.
    pub fn max_byte_addr(&self, bound_m: u64, bound_n: u64, bound_k: u64) -> u64 {
        let last = |b: u64| b.saturating_sub(1) as i64;
        let off = last(bound_m) * self.stride_m.max(0)
            + last(bound_n) * self.stride_n.max(0)
            + last(bound_k) * self.stride_k.max(0)
            + last(self.spatial0_count as u64) * self.spatial0_stride.max(0)
            + last(self.spatial1_count as u64) * self.spatial1_stride.max(0);
        (self.base as i64 + off) as u64
    }

    /// Lowest byte address touched over the loop volume — the
    /// negative-stride counterpart of [`Self::max_byte_addr`]. A
    /// negative result means the walk escapes the SPM below address
    /// zero (the static verifier's `A001-spm-oob` condition).
    pub fn min_byte_addr(&self, bound_m: u64, bound_n: u64, bound_k: u64) -> i64 {
        let last = |b: u64| b.saturating_sub(1) as i64;
        self.base as i64
            + last(bound_m) * self.stride_m.min(0)
            + last(bound_n) * self.stride_n.min(0)
            + last(bound_k) * self.stride_k.min(0)
            + last(self.spatial0_count as u64) * self.spatial0_stride.min(0)
            + last(self.spatial1_count as u64) * self.spatial1_stride.min(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A-streamer over a row-major (M,K) int8 matrix, case-study tiles:
    /// 8 ports, each reading one 8-byte row segment of the A' tile.
    fn row_major_a(k: u64) -> AguConfig {
        AguConfig {
            base: 0,
            stride_m: (8 * k) as i64, // next tile row block: 8 rows down
            stride_n: 0,              // A reused across n1
            stride_k: 8,              // next 8 columns
            spatial0_count: 1,
            spatial0_stride: 0,
            spatial1_count: 8,
            spatial1_stride: k as i64, // consecutive rows within the tile
        }
    }

    #[test]
    fn row_major_walk_matches_manual_indexing() {
        let k = 64u64;
        let agu = row_major_a(k);
        // tile (m1=2, k1=3), port 5 -> element A[2*8+5][3*8] at byte
        // (2*8+5)*64 + 24
        let expect = (2 * 8 + 5) * 64 + 3 * 8;
        assert_eq!(agu.byte_addr(2, 9, 3, 5), expect);
        // n1 must not affect A addresses
        assert_eq!(agu.byte_addr(2, 0, 3, 5), agu.byte_addr(2, 7, 3, 5));
    }

    #[test]
    fn word_addrs_divide_by_word_size() {
        let agu = row_major_a(64);
        let mut out = Vec::new();
        agu.tile_word_addrs(0, 0, 0, 8, &mut out);
        assert_eq!(out, vec![0, 8, 16, 24, 32, 40, 48, 56]);
    }

    #[test]
    fn two_level_spatial_walk() {
        // C-streamer, row-major C (M, N=32) int32: C' tile = 8 rows of 4
        // words; rows are 32*4 = 128 bytes apart.
        let agu = AguConfig {
            base: 0,
            stride_m: 8 * 128,
            stride_n: 32,
            stride_k: 0,
            spatial0_count: 4,
            spatial0_stride: 8,
            spatial1_count: 8,
            spatial1_stride: 128,
        };
        assert_eq!(agu.ports(), 32);
        // port 5 = row 1, word 1 -> byte 128 + 8
        assert_eq!(agu.byte_addr(0, 0, 0, 5), 136);
        // tile (m1=1, n1=2): base offset 1024 + 64
        assert_eq!(agu.byte_addr(1, 2, 0, 0), 1024 + 64);
    }

    #[test]
    fn linear_constructor() {
        let agu = AguConfig::linear(100, 8, 8);
        assert_eq!(agu.ports(), 8);
        assert_eq!(agu.byte_addr(0, 0, 0, 3), 124);
    }

    #[test]
    fn zero_stride_reuse() {
        let agu = AguConfig::linear(128, 1, 0);
        assert_eq!(agu.byte_addr(5, 6, 7, 0), 128);
    }

    #[test]
    fn max_addr_covers_loop_volume() {
        let agu = row_major_a(64);
        // M=32 -> bound_m = 4, K=64 -> bound_k = 8
        let max = agu.max_byte_addr(4, 10, 8);
        // last element: (3*8+7)*64 + 7*8 = 31*64+56 = 2040
        assert_eq!(max, 2040);
    }

    #[test]
    fn min_addr_tracks_negative_strides() {
        let agu = row_major_a(64);
        // all strides non-negative: the minimum is the base
        assert_eq!(agu.min_byte_addr(4, 10, 8), 0);
        // a negative k stride walks below the base
        let down = AguConfig { base: 64, stride_k: -16, ..AguConfig::linear(64, 8, 8) };
        assert_eq!(down.min_byte_addr(1, 1, 8), 64 - 7 * 16);
        assert_eq!(down.min_byte_addr(1, 1, 16), 64 - 15 * 16); // below zero
    }

    #[test]
    fn tiled_contiguous_layout() {
        // SMA tiled layout: tile t at byte 64*t (A iterated (m1, k1)),
        // Kt = 8 tiles per row-block.
        let agu = AguConfig {
            base: 0,
            stride_m: 64 * 8,
            stride_n: 0,
            stride_k: 64,
            spatial0_count: 1,
            spatial0_stride: 0,
            spatial1_count: 8,
            spatial1_stride: 8,
        };
        let mut out = Vec::new();
        agu.tile_word_addrs(1, 0, 2, 8, &mut out);
        // tile index = 1*8+2 = 10 -> words 80..88
        assert_eq!(out, (80..88).collect::<Vec<u64>>());
    }
}
