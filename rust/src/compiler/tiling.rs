//! GeMM shapes, padding, and software tiling over the SPM capacity.
//!
//! The hardware loop controller handles matrices up to the on-chip
//! buffer capacity; larger problems are split by software into multiple
//! accelerator calls ("extra tiling as more nested temporal loops on
//! higher-level memories", Sec. 2.3). The split shrinks N first, then M,
//! keeping K whole — output-stationary dataflow wants the full K
//! reduction inside one call so partial sums never leave the
//! accumulators.

use crate::config::{GemmCoreParams, PlatformConfig};
use crate::gemm_core::MAX_LOOP_BOUND;
use crate::streamer::LoopBounds;

use super::layout::Layout;

/// A GeMM problem in element space: C[M,N] = A[M,K] x B[K,N].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct GemmShape {
    pub m: usize,
    pub k: usize,
    pub n: usize,
}

impl GemmShape {
    pub fn new(m: usize, k: usize, n: usize) -> GemmShape {
        assert!(m > 0 && k > 0 && n > 0, "degenerate GeMM ({m},{k},{n})");
        GemmShape { m, k, n }
    }

    /// Real multiply-accumulates.
    pub fn macs(&self) -> u64 {
        self.m as u64 * self.k as u64 * self.n as u64
    }

    /// Operations (1 MAC = 2 ops), the paper's GOPS numerator.
    pub fn ops(&self) -> u64 {
        2 * self.macs()
    }

    /// Dimensions padded up to array-tile multiples.
    pub fn padded(&self, core: &GemmCoreParams) -> GemmShape {
        let up = |d: usize, u: usize| d.div_ceil(u) * u;
        GemmShape {
            m: up(self.m, core.mu),
            k: up(self.k, core.ku),
            n: up(self.n, core.nu),
        }
    }

    /// Temporal loop bounds on the array.
    pub fn bounds(&self, core: &GemmCoreParams) -> LoopBounds {
        LoopBounds {
            mt: self.m.div_ceil(core.mu) as u64,
            nt: self.n.div_ceil(core.nu) as u64,
            kt: self.k.div_ceil(core.ku) as u64,
        }
    }

    /// MACs the padded execution burns (tiles x full array).
    pub fn padded_macs(&self, core: &GemmCoreParams) -> u64 {
        self.bounds(core).total_tiles() * core.macs_per_cycle()
    }

    /// Spatial utilization of this shape on the array: real MACs over
    /// padded MACs (Sec. 4.3, "SU").
    pub fn spatial_utilization(&self, core: &GemmCoreParams) -> f64 {
        self.macs() as f64 / self.padded_macs(core) as f64
    }

    /// Ideal compute cycles (one tile-MAC per cycle, zero stalls).
    pub fn ideal_cycles(&self, core: &GemmCoreParams) -> u64 {
        self.bounds(core).total_tiles()
    }
}

/// One accelerator call produced by the software tiler: a sub-GeMM and
/// its offsets inside the parent problem.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GemmBlock {
    pub shape: GemmShape,
    pub m_off: usize,
    pub n_off: usize,
}

/// Split error.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SplitError(pub String);

impl std::fmt::Display for SplitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "cannot tile workload onto SPM: {}", self.0)
    }
}

impl std::error::Error for SplitError {}

/// SPM bytes one call occupies under `layout` for padded dims.
pub fn call_footprint(
    cfg: &PlatformConfig,
    padded: &GemmShape,
    layout: Layout,
) -> u64 {
    let bounds = padded.bounds(&cfg.core);
    let a_bytes = (padded.m * padded.k) as u64;
    let b_bytes = (padded.k * padded.n) as u64;
    let c_bytes = 4 * (padded.m * padded.n) as u64;
    match layout {
        Layout::RowMajor | Layout::TiledContiguous => a_bytes + b_bytes + c_bytes,
        Layout::TiledInterleaved => {
            // A and B tiles interleave on a 2-tile pitch; the region spans
            // 2 * tile_bytes * max(At, Bt), then C tiles packed densely.
            let at = bounds.mt * bounds.kt;
            let bt = bounds.kt * bounds.nt;
            let tile = cfg.core.a_tile_bytes().max(cfg.core.b_tile_bytes()) as u64;
            2 * tile * at.max(bt) + c_bytes
        }
    }
}

/// Split a GeMM into blocks that each fit the SPM and the hardware loop
/// bounds. Blocks cover the (M, N) space; K stays whole.
pub fn split_for_capacity(
    cfg: &PlatformConfig,
    shape: GemmShape,
    layout: Layout,
) -> Result<Vec<GemmBlock>, SplitError> {
    let core = &cfg.core;
    // Each call must fit one core's SPM partition (the full capacity on
    // single-core platforms).
    let capacity = cfg.spm_partition_bytes() as u64;
    let padded = shape.padded(core);

    // Candidate block dims: shrink N by halving (tile-aligned), then M.
    let mut bm = padded.m;
    let mut bn = padded.n;
    let fits = |bm: usize, bn: usize| {
        let blk = GemmShape { m: bm, k: padded.k, n: bn };
        let b = blk.bounds(core);
        call_footprint(cfg, &blk, layout) <= capacity
            && b.mt <= MAX_LOOP_BOUND
            && b.nt <= MAX_LOOP_BOUND
            && b.kt <= MAX_LOOP_BOUND
    };
    let halve = |d: usize, unit: usize| -> usize {
        let tiles = d / unit;
        ((tiles + 1) / 2).max(1) * unit
    };
    while !fits(bm, bn) {
        if bn > core.nu {
            bn = halve(bn, core.nu);
        } else if bm > core.mu {
            bm = halve(bm, core.mu);
        } else {
            return Err(SplitError(format!(
                "K={} too large: a single ({},{K},{}) tile exceeds SPM capacity {capacity}B",
                padded.k,
                core.mu,
                core.nu,
                K = padded.k,
            )));
        }
    }

    // Enumerate blocks in (m, n) row-major order; edge blocks shrink to
    // the true (unpadded) extent so SU accounting stays exact.
    let mut blocks = Vec::new();
    let mut m_off = 0;
    while m_off < shape.m {
        let bm_real = bm.min(shape.m - m_off);
        let mut n_off = 0;
        while n_off < shape.n {
            let bn_real = bn.min(shape.n - n_off);
            blocks.push(GemmBlock {
                shape: GemmShape::new(bm_real, shape.k, bn_real),
                m_off,
                n_off,
            });
            n_off += bn;
        }
        m_off += bm;
    }
    Ok(blocks)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PlatformConfig;

    fn cfg() -> PlatformConfig {
        PlatformConfig::case_study()
    }

    #[test]
    fn padding_and_su() {
        let core = GemmCoreParams::CASE_STUDY;
        let s = GemmShape::new(13, 22, 17);
        let p = s.padded(&core);
        assert_eq!((p.m, p.k, p.n), (16, 24, 24));
        assert_eq!(s.bounds(&core), LoopBounds { mt: 2, nt: 3, kt: 3 });
        let su = s.spatial_utilization(&core);
        let expect = (13.0 * 22.0 * 17.0) / (16.0 * 24.0 * 24.0);
        assert!((su - expect).abs() < 1e-12);
    }

    #[test]
    fn aligned_shape_full_su() {
        let core = GemmCoreParams::CASE_STUDY;
        let s = GemmShape::new(64, 64, 64);
        assert_eq!(s.spatial_utilization(&core), 1.0);
        assert_eq!(s.ideal_cycles(&core), 512);
    }

    #[test]
    fn small_gemm_single_block() {
        let cfg = cfg();
        let blocks =
            split_for_capacity(&cfg, GemmShape::new(64, 64, 64), Layout::TiledInterleaved)
                .unwrap();
        assert_eq!(blocks.len(), 1);
        assert_eq!(blocks[0].shape, GemmShape::new(64, 64, 64));
    }

    #[test]
    fn capacity_split_256_cubed() {
        let cfg = cfg();
        let shape = GemmShape::new(256, 256, 256);
        let blocks = split_for_capacity(&cfg, shape, Layout::TiledInterleaved).unwrap();
        assert!(blocks.len() >= 2, "256^3 exceeds 264 KiB SPM; got {blocks:?}");
        // blocks tile the output space exactly
        let covered: u64 = blocks.iter().map(|b| (b.shape.m * b.shape.n) as u64).sum();
        assert_eq!(covered, 256 * 256);
        // every block fits
        for b in &blocks {
            let padded = b.shape.padded(&cfg.core);
            assert!(
                call_footprint(&cfg, &padded, Layout::TiledInterleaved)
                    <= cfg.mem.capacity_bytes() as u64
            );
        }
    }

    #[test]
    fn blocks_cover_without_overlap() {
        let cfg = cfg();
        let shape = GemmShape::new(250, 256, 250); // irregular edges
        let blocks = split_for_capacity(&cfg, shape, Layout::RowMajor).unwrap();
        let mut covered = vec![false; shape.m * shape.n];
        for b in &blocks {
            for i in 0..b.shape.m {
                for j in 0..b.shape.n {
                    let idx = (b.m_off + i) * shape.n + (b.n_off + j);
                    assert!(!covered[idx], "overlap at ({},{})", b.m_off + i, b.n_off + j);
                    covered[idx] = true;
                }
            }
        }
        assert!(covered.iter().all(|&c| c));
    }

    #[test]
    fn huge_k_is_rejected() {
        let cfg = cfg();
        // K so large that even an 8x8 output tile cannot fit its operands
        let shape = GemmShape::new(8, 300_000, 8);
        assert!(split_for_capacity(&cfg, shape, Layout::RowMajor).is_err());
    }

    #[test]
    fn footprint_interleaved_larger_when_unbalanced() {
        let cfg = cfg();
        let shape = GemmShape::new(8, 64, 256).padded(&cfg.core);
        let dense = call_footprint(&cfg, &shape, Layout::RowMajor);
        let inter = call_footprint(&cfg, &shape, Layout::TiledInterleaved);
        assert!(inter >= dense);
    }
}
