//! Multi-banked scratchpad memory (SPM) model.
//!
//! The paper tightly couples a wide, software-managed, word-interleaved
//! multi-bank SRAM to the GeMM core (Sec. 3.1, Table 1): `N_bank` banks of
//! `D_mem` words of `P_word` bits. Banks are 1R1W SRAM macros (the
//! platform exposes separate `R_mem` read and `W_mem` write port
//! networks): two *reads* (or two *writes*) landing in the same bank in
//! the same cycle serialize — this is precisely the contention that the
//! strided memory access mechanism (Sec. 3.4, Fig. 4(c)) exists to
//! avoid.
//!
//! The model is functional + timing:
//! - functional: a flat word array with bounds-checked read/write;
//! - timing: [`Spm::epoch_cost`] computes how many cycles a batch of
//!   simultaneous port requests takes (max per-bank load), and records
//!   conflict statistics.
//!
//! ## Bulk tile I/O contract
//!
//! Functional storage is a flat array of 64-bit little-endian words
//! (`word_addr` indexes it directly; byte address = `word_addr * 8`).
//! The seed resolved that mapping *per byte* — every operand byte of
//! every tile fetch paid a divide, a shift, and a bounds check. The
//! bulk APIs resolve it once per run instead:
//!
//! - [`Spm::read_ports_i8`]: gather one word per port address into a
//!   flat i8 tile buffer — this is what `Platform::read_tile` (the
//!   functional tile-fetch path) runs on.
//! - [`Spm::read_bytes`] / [`Spm::write_bytes`]: arbitrary byte runs,
//!   split once into an unaligned head, a whole-word body
//!   (`to_le_bytes`/`from_le_bytes` per 8-byte chunk, which LLVM lowers
//!   to single moves), and a tail. [`Spm::read_i8`]/[`Spm::write_i8`]
//!   and the i32 variants layer on top — the `compiler::layout`
//!   pack/unpack helpers and the output-tile commit
//!   (`Platform::commit_output_tile`) route through these.
//! - [`Spm::read_words`] / [`Spm::write_words`]: word-granular
//!   contiguous slice copies (one bounds check for the whole run) —
//!   the primitive for word-addressed bulk movement, e.g. future
//!   DMA-burst modeling (no data-plane caller yet).
//!
//! None of the functional-storage APIs touch [`SpmStats`]; all timing
//! and bank-conflict accounting goes through [`Spm::epoch_cost`] /
//! [`Spm::read_cost`] / [`Spm::write_cost`] exactly as before (pinned
//! by the `bulk_spm_io_matches_per_word` differential property test).

use crate::config::MemParams;
use crate::util::json::{self, Json};

/// Accumulated SPM traffic statistics.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct SpmStats {
    /// Total word-granularity requests served.
    pub word_requests: u64,
    /// Total access epochs (batches of simultaneous requests).
    pub epochs: u64,
    /// Cycles spent serving epochs (>= epochs; surplus is conflict cost).
    pub busy_cycles: u64,
    /// Extra cycles caused by bank conflicts.
    pub conflict_cycles: u64,
}

impl SpmStats {
    /// Wire encoding (sharded-sweep result files).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("word_requests", Json::num(self.word_requests as f64)),
            ("epochs", Json::num(self.epochs as f64)),
            ("busy_cycles", Json::num(self.busy_cycles as f64)),
            ("conflict_cycles", Json::num(self.conflict_cycles as f64)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<SpmStats, String> {
        Ok(SpmStats {
            word_requests: json::get_u64(v, "word_requests")?,
            epochs: json::get_u64(v, "epochs")?,
            busy_cycles: json::get_u64(v, "busy_cycles")?,
            conflict_cycles: json::get_u64(v, "conflict_cycles")?,
        })
    }
}

/// The scratchpad: word-interleaved banks of 64-bit words.
#[derive(Debug, Clone)]
pub struct Spm {
    params: MemParams,
    words: Vec<u64>,
    /// Scratch per-bank counters reused across epochs (no per-epoch alloc).
    bank_load: Vec<u16>,
    bank_wload: Vec<u16>,
    pub stats: SpmStats,
}

/// A single port request: word address plus direction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Req {
    Read(u64),
    Write(u64),
}

impl Req {
    #[inline]
    pub fn word_addr(&self) -> u64 {
        match *self {
            Req::Read(a) | Req::Write(a) => a,
        }
    }
}

impl Spm {
    pub fn new(params: MemParams) -> Spm {
        let n_words = params.n_bank * params.d_mem;
        Spm {
            bank_load: vec![0; params.n_bank],
            bank_wload: vec![0; params.n_bank],
            words: vec![0; n_words],
            params,
            stats: SpmStats::default(),
        }
    }

    pub fn params(&self) -> &MemParams {
        &self.params
    }

    pub fn n_words(&self) -> u64 {
        self.words.len() as u64
    }

    /// Bank index of a word address (word-interleaved mapping).
    #[inline]
    pub fn bank_of(&self, word_addr: u64) -> usize {
        // n_bank is validated to be a power of two.
        (word_addr as usize) & (self.params.n_bank - 1)
    }

    /// Byte address -> word address (word size = P_word/8).
    #[inline]
    pub fn word_of_byte(&self, byte_addr: u64) -> u64 {
        byte_addr / self.params.word_bytes() as u64
    }

    /// log2 of the word size in bytes — the byte->word shift the
    /// simulator's per-epoch hot path uses instead of re-deriving it
    /// from the config every access.
    #[inline]
    pub fn word_shift(&self) -> u32 {
        debug_assert!(self.params.word_bytes().is_power_of_two());
        (self.params.word_bytes() as u64).trailing_zeros()
    }

    // ---------------------------------------------------------------
    // Timing
    // ---------------------------------------------------------------

    /// Cost in cycles of serving `reqs` issued in the same cycle.
    ///
    /// Banks are 1R1W SRAM macros (the platform exposes separate read
    /// ports `R_mem` and write ports `W_mem`, Table 1): reads arbitrate
    /// against reads and writes against writes, independently. The
    /// epoch cost is the worse of the two per-bank maxima, plus the
    /// pipelined access latency minus one.
    ///
    /// Records statistics. An empty batch costs 0.
    pub fn epoch_cost(&mut self, reqs: &[Req]) -> u64 {
        if reqs.is_empty() {
            return 0;
        }
        self.bank_load.iter_mut().for_each(|c| *c = 0);
        self.bank_wload.iter_mut().for_each(|c| *c = 0);
        for r in reqs {
            let b = self.bank_of(r.word_addr());
            match r {
                Req::Read(_) => self.bank_load[b] += 1,
                Req::Write(_) => self.bank_wload[b] += 1,
            }
        }
        let max_r = self.bank_load.iter().max().copied().unwrap_or(0) as u64;
        let max_w = self.bank_wload.iter().max().copied().unwrap_or(0) as u64;
        let max_load = max_r.max(max_w).max(1);
        let latency = self.params.read_latency.max(self.params.write_latency);
        let cost = max_load + latency - 1;
        self.stats.word_requests += reqs.len() as u64;
        self.stats.epochs += 1;
        self.stats.busy_cycles += cost;
        self.stats.conflict_cycles += max_load - 1;
        cost
    }

    /// Cost of one read burst (cycles the read ports of the touched
    /// banks stay busy): max per-bank read load. Records statistics.
    pub fn read_cost(&mut self, word_addrs: &[u64]) -> u64 {
        self.port_cost(word_addrs)
    }

    /// Cost of one write burst on the independent write-port network.
    pub fn write_cost(&mut self, word_addrs: &[u64]) -> u64 {
        self.port_cost(word_addrs)
    }

    fn port_cost(&mut self, word_addrs: &[u64]) -> u64 {
        if word_addrs.is_empty() {
            return 0;
        }
        // Fast path: banks fit a u64 bitmask (n_bank <= 64, the common
        // case); a batch with all-distinct banks costs exactly 1 cycle,
        // no per-bank counters needed. This is the hot path of the
        // simulator (every tile fetch goes through here).
        let cost = if self.params.n_bank <= 64 {
            let mut mask = 0u64;
            let mut dup = false;
            for &a in word_addrs {
                let bit = 1u64 << self.bank_of(a);
                dup |= mask & bit != 0;
                mask |= bit;
            }
            if !dup {
                1
            } else {
                self.slow_max_load(word_addrs)
            }
        } else {
            self.slow_max_load(word_addrs)
        };
        self.stats.word_requests += word_addrs.len() as u64;
        self.stats.epochs += 1;
        self.stats.busy_cycles += cost;
        self.stats.conflict_cycles += cost - 1;
        cost
    }

    #[cold]
    fn slow_max_load(&mut self, word_addrs: &[u64]) -> u64 {
        self.bank_load.iter_mut().for_each(|c| *c = 0);
        for &a in word_addrs {
            let b = self.bank_of(a);
            self.bank_load[b] += 1;
        }
        *self.bank_load.iter().max().unwrap() as u64
    }

    /// Record a conflict-free access served via the precomputed bank
    /// pattern (timing fast path; keeps traffic statistics coherent).
    #[inline]
    pub fn note_fast_access(&mut self, words: u64, cost: u64) {
        self.stats.word_requests += words;
        self.stats.epochs += 1;
        self.stats.busy_cycles += cost;
    }

    /// Pure conflict analysis (no stats): max per-bank load of a batch.
    pub fn max_bank_load(&self, word_addrs: &[u64]) -> u64 {
        let mut load = vec![0u16; self.params.n_bank];
        for &a in word_addrs {
            load[self.bank_of(a)] += 1;
        }
        load.into_iter().max().unwrap_or(0) as u64
    }

    // ---------------------------------------------------------------
    // Functional storage
    // ---------------------------------------------------------------

    pub fn read_word(&self, word_addr: u64) -> u64 {
        self.words[word_addr as usize]
    }

    pub fn write_word(&mut self, word_addr: u64, value: u64) {
        self.words[word_addr as usize] = value;
    }

    /// Bulk read of a contiguous word run (one bounds check and one
    /// `memcpy` for the whole slice).
    pub fn read_words(&self, word_addr: u64, out: &mut [u64]) {
        let s = word_addr as usize;
        out.copy_from_slice(&self.words[s..s + out.len()]);
    }

    /// Bulk write of a contiguous word run.
    pub fn write_words(&mut self, word_addr: u64, data: &[u64]) {
        let s = word_addr as usize;
        self.words[s..s + data.len()].copy_from_slice(data);
    }

    /// Gather one SPM word per port address into a flat i8 tile buffer
    /// (`out.len() == word_addrs.len() * word_bytes`) — the functional
    /// tile-fetch path: the word mapping is resolved once per *port*,
    /// never per byte.
    pub fn read_ports_i8(&self, word_addrs: &[u64], word_bytes: usize, out: &mut [i8]) {
        debug_assert_eq!(out.len(), word_addrs.len() * word_bytes);
        if word_bytes == 8 {
            for (chunk, &w) in out.chunks_exact_mut(8).zip(word_addrs) {
                let bytes = self.words[w as usize].to_le_bytes();
                for (d, s) in chunk.iter_mut().zip(bytes) {
                    *d = s as i8;
                }
            }
        } else {
            // non-64-bit ports: fall back to the byte-run path per port
            for (i, &w) in word_addrs.iter().enumerate() {
                let span = &mut out[i * word_bytes..(i + 1) * word_bytes];
                self.read_i8(w * word_bytes as u64, span);
            }
        }
    }

    /// Read a run of bytes (little-endian within words). Split once
    /// into head / whole-word body / tail; see the module docs.
    pub fn read_bytes(&self, byte_addr: u64, out: &mut [u8]) {
        if out.is_empty() {
            return;
        }
        let off = (byte_addr & 7) as usize;
        let head_len = if off == 0 { 0 } else { (8 - off).min(out.len()) };
        let mut widx = (byte_addr >> 3) as usize;
        if head_len > 0 {
            let bytes = self.words[widx].to_le_bytes();
            out[..head_len].copy_from_slice(&bytes[off..off + head_len]);
            widx += 1;
        }
        let mut chunks = out[head_len..].chunks_exact_mut(8);
        for chunk in chunks.by_ref() {
            chunk.copy_from_slice(&self.words[widx].to_le_bytes());
            widx += 1;
        }
        let tail = chunks.into_remainder();
        if !tail.is_empty() {
            let bytes = self.words[widx].to_le_bytes();
            tail.copy_from_slice(&bytes[..tail.len()]);
        }
    }

    /// Write a run of bytes (little-endian within words); word-aligned
    /// interior words are stored whole, head/tail read-modify-write.
    pub fn write_bytes(&mut self, byte_addr: u64, data: &[u8]) {
        if data.is_empty() {
            return;
        }
        let off = (byte_addr & 7) as usize;
        let head_len = if off == 0 { 0 } else { (8 - off).min(data.len()) };
        let mut widx = (byte_addr >> 3) as usize;
        if head_len > 0 {
            let word = &mut self.words[widx];
            let mut bytes = word.to_le_bytes();
            bytes[off..off + head_len].copy_from_slice(&data[..head_len]);
            *word = u64::from_le_bytes(bytes);
            widx += 1;
        }
        let mut chunks = data[head_len..].chunks_exact(8);
        for chunk in chunks.by_ref() {
            self.words[widx] = u64::from_le_bytes(chunk.try_into().unwrap());
            widx += 1;
        }
        let tail = chunks.remainder();
        if !tail.is_empty() {
            let word = &mut self.words[widx];
            let mut bytes = word.to_le_bytes();
            bytes[..tail.len()].copy_from_slice(tail);
            *word = u64::from_le_bytes(bytes);
        }
    }

    /// Write a slice of i8 (operand matrices are int8).
    pub fn write_i8(&mut self, byte_addr: u64, data: &[i8]) {
        // Safety: i8 and u8 have identical layout.
        let bytes: &[u8] =
            unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len()) };
        self.write_bytes(byte_addr, bytes);
    }

    /// Read a slice of i8.
    pub fn read_i8(&self, byte_addr: u64, out: &mut [i8]) {
        let bytes: &mut [u8] = unsafe {
            std::slice::from_raw_parts_mut(out.as_mut_ptr() as *mut u8, out.len())
        };
        self.read_bytes(byte_addr, bytes);
    }

    /// Write a slice of i32 little-endian (C result tiles).
    pub fn write_i32(&mut self, byte_addr: u64, data: &[i32]) {
        #[cfg(target_endian = "little")]
        {
            // Safety: on a little-endian host the in-memory i32 bytes
            // are exactly the little-endian byte image the SPM stores.
            let bytes: &[u8] = unsafe {
                std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
            };
            self.write_bytes(byte_addr, bytes);
        }
        #[cfg(target_endian = "big")]
        for (i, &v) in data.iter().enumerate() {
            self.write_bytes(byte_addr + 4 * i as u64, &v.to_le_bytes());
        }
    }

    /// Read a slice of i32.
    pub fn read_i32(&self, byte_addr: u64, out: &mut [i32]) {
        #[cfg(target_endian = "little")]
        {
            let bytes: &mut [u8] = unsafe {
                std::slice::from_raw_parts_mut(out.as_mut_ptr() as *mut u8, out.len() * 4)
            };
            self.read_bytes(byte_addr, bytes);
        }
        #[cfg(target_endian = "big")]
        {
            let mut buf = [0u8; 4];
            for (i, v) in out.iter_mut().enumerate() {
                self.read_bytes(byte_addr + 4 * i as u64, &mut buf);
                *v = i32::from_le_bytes(buf);
            }
        }
    }

    pub fn reset_stats(&mut self) {
        self.stats = SpmStats::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MemParams;

    fn spm() -> Spm {
        Spm::new(MemParams::CASE_STUDY)
    }

    #[test]
    fn bank_mapping_is_word_interleaved() {
        let s = spm();
        assert_eq!(s.bank_of(0), 0);
        assert_eq!(s.bank_of(1), 1);
        assert_eq!(s.bank_of(31), 31);
        assert_eq!(s.bank_of(32), 0);
        assert_eq!(s.bank_of(33), 1);
    }

    #[test]
    fn conflict_free_batch_costs_latency() {
        let mut s = spm();
        // 16 reads to 16 distinct banks
        let reqs: Vec<Req> = (0..16).map(Req::Read).collect();
        assert_eq!(s.epoch_cost(&reqs), 1);
        assert_eq!(s.stats.conflict_cycles, 0);
    }

    #[test]
    fn same_bank_requests_serialize() {
        let mut s = spm();
        // 4 reads all hitting bank 0 (addresses 0, 32, 64, 96)
        let reqs: Vec<Req> = (0..4).map(|i| Req::Read(i * 32)).collect();
        assert_eq!(s.epoch_cost(&reqs), 4);
        assert_eq!(s.stats.conflict_cycles, 3);
    }

    #[test]
    fn read_and_write_to_same_bank_do_not_conflict() {
        // banks are 1R1W: one read + one write to bank 0 in one cycle
        let mut s = spm();
        let reqs = [Req::Read(0), Req::Write(32)];
        assert_eq!(s.epoch_cost(&reqs), 1);
    }

    #[test]
    fn writes_conflict_with_writes() {
        let mut s = spm();
        let reqs = [Req::Write(0), Req::Write(32), Req::Write(64)];
        assert_eq!(s.epoch_cost(&reqs), 3);
    }

    #[test]
    fn empty_batch_is_free() {
        let mut s = spm();
        assert_eq!(s.epoch_cost(&[]), 0);
        assert_eq!(s.stats.epochs, 0);
    }

    #[test]
    fn byte_rw_roundtrip() {
        let mut s = spm();
        let data: Vec<u8> = (0..37).map(|i| (i * 7 + 3) as u8).collect();
        s.write_bytes(13, &data);
        let mut out = vec![0u8; 37];
        s.read_bytes(13, &mut out);
        assert_eq!(out, data);
    }

    #[test]
    fn i8_and_i32_roundtrip() {
        let mut s = spm();
        let xs: Vec<i8> = (-64..64).collect();
        s.write_i8(100, &xs);
        let mut got = vec![0i8; xs.len()];
        s.read_i8(100, &mut got);
        assert_eq!(got, xs);

        let ys = [i32::MIN, -1, 0, 1, i32::MAX];
        s.write_i32(1000, &ys);
        let mut got32 = [0i32; 5];
        s.read_i32(1000, &mut got32);
        assert_eq!(got32, ys);
    }

    #[test]
    fn unaligned_bytes_cross_words() {
        let mut s = spm();
        s.write_bytes(6, &[0xaa, 0xbb, 0xcc, 0xdd]); // spans words 0 and 1
        let w0 = s.read_word(0);
        let w1 = s.read_word(1);
        assert_eq!((w0 >> 48) & 0xffff, 0xbbaa);
        assert_eq!(w1 & 0xffff, 0xddcc);
    }

    #[test]
    fn capacity_matches_params() {
        let s = spm();
        assert_eq!(s.n_words(), 32 * 1056);
    }

    #[test]
    #[should_panic]
    fn out_of_bounds_read_panics() {
        let s = spm();
        s.read_word(s.n_words());
    }

    /// The seed's per-byte storage path, kept as the semantic reference
    /// for the bulk head/body/tail implementation.
    fn read_byte_reference(s: &Spm, addr: u64) -> u8 {
        (s.read_word(addr / 8) >> ((addr % 8) * 8)) as u8
    }

    fn write_byte_reference(s: &mut Spm, addr: u64, b: u8) {
        let shift = (addr % 8) * 8;
        let word = s.read_word(addr / 8);
        s.write_word(addr / 8, (word & !(0xffu64 << shift)) | ((b as u64) << shift));
    }

    #[test]
    fn bulk_byte_io_matches_per_byte_reference() {
        use crate::util::check::property;
        property("bulk bytes == per-byte reference", 40, |rng| {
            let mut bulk = spm();
            let mut scalar = spm();
            for _ in 0..12 {
                let len = rng.below(64) as usize + 1;
                let addr = rng.below(4096 - 64) as u64;
                let data: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
                bulk.write_bytes(addr, &data);
                for (i, &b) in data.iter().enumerate() {
                    write_byte_reference(&mut scalar, addr + i as u64, b);
                }
                let raddr = rng.below(4096 - 64) as u64;
                let rlen = rng.below(64) as usize + 1;
                let mut got = vec![0u8; rlen];
                bulk.read_bytes(raddr, &mut got);
                let want: Vec<u8> =
                    (0..rlen).map(|i| read_byte_reference(&bulk, raddr + i as u64)).collect();
                crate::prop_assert_eq!(got, want, "read divergence at {raddr}+{rlen}");
            }
            for w in 0..512u64 {
                crate::prop_assert_eq!(
                    bulk.read_word(w),
                    scalar.read_word(w),
                    "word {w} diverged"
                );
            }
            // functional storage never touches timing statistics
            crate::prop_assert_eq!(bulk.stats, SpmStats::default(), "stats perturbed");
            Ok(())
        });
    }

    #[test]
    fn word_slice_io_roundtrip() {
        let mut s = spm();
        let data: Vec<u64> = (0..17).map(|i| i * 0x0101_0101_0101_0101).collect();
        s.write_words(33, &data);
        let mut got = vec![0u64; 17];
        s.read_words(33, &mut got);
        assert_eq!(got, data);
        // agrees with the byte view
        let mut bytes = vec![0u8; 8];
        s.read_bytes(34 * 8, &mut bytes);
        assert_eq!(bytes, data[1].to_le_bytes());
    }

    #[test]
    fn read_ports_i8_matches_per_port_read_i8() {
        let mut s = spm();
        let image: Vec<i8> = (0..1024).map(|i| (i % 251) as i8 - 100).collect();
        s.write_i8(0, &image);
        // scattered, deliberately non-contiguous port addresses
        let addrs: Vec<u64> = (0..8u64).map(|i| i * 13 + 2).collect();
        let mut bulk = vec![0i8; 64];
        s.read_ports_i8(&addrs, 8, &mut bulk);
        let mut per_word = vec![0i8; 64];
        for (i, &w) in addrs.iter().enumerate() {
            s.read_i8(w * 8, &mut per_word[i * 8..(i + 1) * 8]);
        }
        assert_eq!(bulk, per_word);
    }
}
