//! L3 coordinator: a multi-worker simulation service.
//!
//! The evaluation workloads are embarrassingly parallel across GeMM
//! shapes (Fig. 5 runs 500 workloads x 7 architecture variants), so the
//! coordinator owns a pool of worker threads, each with its own
//! [`Platform`] instance, and distributes compiled jobs over a work
//! queue (tokio is unavailable offline; std threads + channels carry
//! the same architecture). Results come back over a bounded channel in
//! submission order.

pub mod cache;
pub mod dispatch;
pub mod shard;

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::compiler::{compile_gemm, GemmShape, Layout, SplitError};
use crate::config::{Mechanisms, PlatformConfig};
use crate::sim::{JobResult, Platform, SimError, SimOptions};
use crate::util::json::{self, Json};

/// A simulation request.
#[derive(Debug, Clone, PartialEq)]
pub struct JobRequest {
    pub shape: GemmShape,
    pub layout: Layout,
    pub mechanisms: Mechanisms,
    pub repeats: u32,
    /// Functional operands (A, B); None = timing-only.
    pub operands: Option<(Vec<i8>, Vec<i8>)>,
}

impl JobRequest {
    pub fn timing(shape: GemmShape, mechanisms: Mechanisms, repeats: u32) -> JobRequest {
        // Without SMA the DMA still places operand tiles contiguously in
        // streaming order (the paper's Fig. 4(c)(2) baseline) but cannot
        // avoid cross-operand bank-group collisions; SMA interleaves A
        // and B on disjoint bank groups (Fig. 4(c)(3)).
        let layout = if mechanisms.strided_layout {
            Layout::TiledInterleaved
        } else {
            Layout::TiledContiguous
        };
        JobRequest { shape, layout, mechanisms, repeats, operands: None }
    }

    /// Wire encoding (sharded-sweep shard files). Functional operands
    /// are carried inline, so a worker process can run functional jobs
    /// bit-identically to the in-process path.
    pub fn to_json(&self) -> Json {
        let operands = match &self.operands {
            None => Json::Null,
            Some((a, b)) => Json::obj(vec![
                ("a", Json::Arr(a.iter().map(|&x| Json::num(x as f64)).collect())),
                ("b", Json::Arr(b.iter().map(|&x| Json::num(x as f64)).collect())),
            ]),
        };
        Json::obj(vec![
            (
                "shape",
                Json::obj(vec![
                    ("m", Json::num(self.shape.m as f64)),
                    ("k", Json::num(self.shape.k as f64)),
                    ("n", Json::num(self.shape.n as f64)),
                ]),
            ),
            ("layout", Json::str(self.layout.name())),
            ("mechanisms", self.mechanisms.to_json()),
            ("repeats", Json::num(self.repeats as f64)),
            ("operands", operands),
        ])
    }

    pub fn from_json(v: &Json) -> Result<JobRequest, String> {
        let shape = json::get(v, "shape")?;
        let (m, k, n) = (
            json::get_usize(shape, "m")?,
            json::get_usize(shape, "k")?,
            json::get_usize(shape, "n")?,
        );
        if m == 0 || k == 0 || n == 0 {
            return Err(format!("degenerate shape ({m},{k},{n})"));
        }
        let layout_name = json::get_str(v, "layout")?;
        let layout = Layout::from_name(layout_name)
            .ok_or_else(|| format!("unknown layout {layout_name:?}"))?;
        let operands = match json::get(v, "operands")? {
            Json::Null => None,
            obj => {
                let a = parse_i8_array(obj, "a")?;
                let b = parse_i8_array(obj, "b")?;
                // reject rather than panic later in a pool thread: the
                // simulator asserts these sizes (checked_mul: shard
                // files may come from other hosts, so even the
                // validation arithmetic must not trust the shape)
                let want = m
                    .checked_mul(k)
                    .zip(k.checked_mul(n))
                    .ok_or_else(|| format!("shape ({m},{k},{n}) overflows operand sizes"))?;
                if (a.len(), b.len()) != want {
                    return Err(format!(
                        "operand sizes {}/{} do not match shape ({m},{k},{n})",
                        a.len(),
                        b.len()
                    ));
                }
                Some((a, b))
            }
        };
        let repeats = json::get_u64(v, "repeats")?;
        let repeats = u32::try_from(repeats)
            .map_err(|_| format!("repeats {repeats} out of u32 range"))?;
        Ok(JobRequest {
            shape: GemmShape::new(m, k, n),
            layout,
            mechanisms: Mechanisms::from_json(json::get(v, "mechanisms")?)?,
            repeats,
            operands,
        })
    }
}

fn parse_i8_array(v: &Json, key: &str) -> Result<Vec<i8>, String> {
    json::get_arr(v, key)?
        .iter()
        .map(|x| {
            x.as_i64()
                .and_then(|n| i8::try_from(n).ok())
                .ok_or_else(|| format!("bad i8 in operand {key:?}"))
        })
        .collect()
}

/// Outcome of one request.
pub type JobOutcome = Result<JobResult, String>;

/// Wire encoding of a [`JobOutcome`] (sharded-sweep result files):
/// success carries the full [`JobResult`], failure carries the error
/// string — both merge transparently with in-process outcomes.
pub fn outcome_to_json(outcome: &JobOutcome) -> Json {
    match outcome {
        Ok(r) => Json::obj(vec![("ok", r.to_json())]),
        Err(e) => Json::obj(vec![("err", Json::str(e.clone()))]),
    }
}

pub fn outcome_from_json(v: &Json) -> Result<JobOutcome, String> {
    if let Some(r) = v.get("ok") {
        return Ok(Ok(JobResult::from_json(r)?));
    }
    if let Some(e) = v.get("err") {
        return Ok(Err(e.as_str().ok_or("field \"err\" is not a string")?.to_string()));
    }
    Err("outcome has neither \"ok\" nor \"err\"".into())
}

struct WorkItem {
    index: usize,
    request: JobRequest,
}

/// Aggregated coordinator statistics.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct CoordinatorStats {
    pub jobs_completed: u64,
    pub jobs_failed: u64,
    pub simulated_cycles: u64,
    /// Result-cache traffic (`coordinator::cache`). These three are
    /// deliberately EXCLUDED from the wire encoding below: the merged
    /// sweep document must depend only on the simulated work, so a
    /// warm-cache re-run stays byte-identical to the cold run. The
    /// dispatch layer reports them on the wire via `DispatchReport`,
    /// which is diagnostics by design.
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// Jobs that actually reached a simulator (as opposed to being
    /// answered from cache). Counted by the dispatch/cache layer, not
    /// by `run_batch` — the wire exclusion above would otherwise make
    /// the counter inconsistent across transports.
    pub jobs_simulated: u64,
}

impl CoordinatorStats {
    /// Count one outcome, exactly as the `run_batch` worker pool does —
    /// the cache layer uses this to derive the stats a cached job would
    /// have contributed, which is what keeps warm and cold runs
    /// byte-identical.
    pub fn record(&mut self, outcome: &JobOutcome) {
        match outcome {
            Ok(r) => {
                self.jobs_completed += 1;
                self.simulated_cycles += r.metrics.total_cycles;
            }
            Err(_) => self.jobs_failed += 1,
        }
    }

    /// Fold another coordinator's counters in (shard merging). Plain
    /// u64 sums, so the merge is order-independent.
    pub fn accumulate(&mut self, other: &CoordinatorStats) {
        self.jobs_completed += other.jobs_completed;
        self.jobs_failed += other.jobs_failed;
        self.simulated_cycles += other.simulated_cycles;
        self.cache_hits += other.cache_hits;
        self.cache_misses += other.cache_misses;
        self.jobs_simulated += other.jobs_simulated;
    }

    /// Wire encoding (sharded-sweep result files). Cache counters are
    /// intentionally absent — see the field docs.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("jobs_completed", Json::num(self.jobs_completed as f64)),
            ("jobs_failed", Json::num(self.jobs_failed as f64)),
            ("simulated_cycles", Json::num(self.simulated_cycles as f64)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<CoordinatorStats, String> {
        Ok(CoordinatorStats {
            jobs_completed: json::get_u64(v, "jobs_completed")?,
            jobs_failed: json::get_u64(v, "jobs_failed")?,
            simulated_cycles: json::get_u64(v, "simulated_cycles")?,
            ..CoordinatorStats::default()
        })
    }
}

/// The worker pool.
pub struct Coordinator {
    cfg: PlatformConfig,
    csr_latency: u64,
    workers: usize,
    fast_forward: bool,
    stats: Arc<Mutex<CoordinatorStats>>,
}

/// Parse an `OPENGEMM_WORKERS` value. `None` input (variable unset)
/// means "auto-size"; a set-but-invalid value (unparsable, or zero — a
/// pool needs at least one worker) is a hard error rather than a silent
/// fallback: an operator who set the variable meant it.
pub fn parse_workers_env(value: Option<&str>) -> Result<Option<usize>, String> {
    let Some(v) = value else { return Ok(None) };
    match v.trim().parse::<usize>() {
        Ok(0) => Err(format!(
            "OPENGEMM_WORKERS={v:?}: worker count must be >= 1 (unset the \
             variable for auto-sizing)"
        )),
        Ok(n) => Ok(Some(n)),
        Err(_) => Err(format!(
            "OPENGEMM_WORKERS={v:?} is not a positive integer (unset the \
             variable for auto-sizing)"
        )),
    }
}

impl Coordinator {
    /// Build a coordinator with the default worker-count policy:
    /// `OPENGEMM_WORKERS` overrides outright (no upper clamp — a sweep
    /// host with 96 cores may use them all); otherwise size to the
    /// machine, clamped to a pool that doesn't oversubscribe small
    /// jobs. `with_workers` overrides both.
    ///
    /// Panics on an invalid `OPENGEMM_WORKERS` value: misconfiguration
    /// fails fast instead of silently auto-sizing (see
    /// [`parse_workers_env`]).
    pub fn new(cfg: PlatformConfig) -> Coordinator {
        let env = std::env::var("OPENGEMM_WORKERS").ok();
        let workers = match parse_workers_env(env.as_deref()) {
            Ok(Some(n)) => n,
            Ok(None) => std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4)
                .clamp(1, 32),
            Err(e) => panic!("{e}"),
        };
        Coordinator {
            cfg,
            csr_latency: SimOptions::default().csr_latency,
            workers,
            fast_forward: SimOptions::default().fast_forward,
            stats: Arc::new(Mutex::new(CoordinatorStats::default())),
        }
    }

    pub fn with_workers(mut self, workers: usize) -> Coordinator {
        self.workers = workers.max(1);
        self
    }

    pub fn with_csr_latency(mut self, latency: u64) -> Coordinator {
        self.csr_latency = latency;
        self
    }

    /// Toggle the event-driven cycle-skipping engine (default on; the
    /// lockstep mode exists for differential verification and the
    /// `--no-fast-forward` escape hatch).
    pub fn with_fast_forward(mut self, fast_forward: bool) -> Coordinator {
        self.fast_forward = fast_forward;
        self
    }

    pub fn stats(&self) -> CoordinatorStats {
        self.stats.lock().unwrap().clone()
    }

    /// Run a batch of requests in parallel; results in request order.
    pub fn run_batch(&self, requests: Vec<JobRequest>) -> Vec<JobOutcome> {
        let n = requests.len();
        if n == 0 {
            return Vec::new();
        }
        let (work_tx, work_rx) = mpsc::channel::<WorkItem>();
        let work_rx = Arc::new(Mutex::new(work_rx));
        let (done_tx, done_rx) = mpsc::channel::<(usize, JobOutcome)>();

        for (index, request) in requests.into_iter().enumerate() {
            work_tx.send(WorkItem { index, request }).unwrap();
        }
        drop(work_tx);

        let workers = self.workers.min(n);
        let mut handles: Vec<JoinHandle<()>> = Vec::with_capacity(workers);
        for _ in 0..workers {
            let work_rx = Arc::clone(&work_rx);
            let done_tx = done_tx.clone();
            let cfg = self.cfg.clone();
            let stats = Arc::clone(&self.stats);
            let csr_latency = self.csr_latency;
            let fast_forward = self.fast_forward;
            handles.push(std::thread::spawn(move || {
                // One long-lived platform per worker, re-armed per job
                // via `Platform::reset_for_job`: Fig. 5-scale sweeps
                // (500 workloads x 7 variants) stop paying a fresh SPM
                // + scratch allocation for every job.
                let mut platform: Option<Platform> = None;
                loop {
                    let item = {
                        let rx = work_rx.lock().unwrap();
                        rx.recv()
                    };
                    let Ok(WorkItem { index, request }) = item else { break };
                    let outcome =
                        run_one(&mut platform, &cfg, csr_latency, fast_forward, &request);
                    stats.lock().unwrap().record(&outcome);
                    let _ = done_tx.send((index, outcome));
                }
            }));
        }
        drop(done_tx);

        let mut results: Vec<Option<JobOutcome>> = (0..n).map(|_| None).collect();
        for (index, outcome) in done_rx {
            results[index] = Some(outcome);
        }
        for h in handles {
            h.join().expect("worker panicked");
        }
        results
            .into_iter()
            .map(|r| r.unwrap_or_else(|| Err("worker dropped the job".into())))
            .collect()
    }

    /// Run a single request inline (no pool, fresh platform).
    pub fn run_one(&self, request: &JobRequest) -> JobOutcome {
        run_one(&mut None, &self.cfg, self.csr_latency, self.fast_forward, request)
    }
}

/// Run one request on a worker's long-lived platform slot: the first
/// job builds the `Platform` (SPM allocation included), every later job
/// re-arms it with [`Platform::reset_for_job`].
fn run_one(
    platform: &mut Option<Platform>,
    cfg: &PlatformConfig,
    csr_latency: u64,
    fast_forward: bool,
    request: &JobRequest,
) -> JobOutcome {
    let job = compile_gemm(
        cfg,
        request.shape,
        request.layout,
        request.repeats,
        request.mechanisms.config_preloading,
    )
    .map_err(|e: SplitError| e.to_string())?;
    let opts = SimOptions {
        mechanisms: request.mechanisms,
        functional: request.operands.is_some(),
        csr_latency,
        fast_forward,
        ..Default::default()
    };
    if let Some(p) = platform.as_mut() {
        p.reset_for_job(opts);
    }
    let p = platform.get_or_insert_with(|| Platform::new(cfg.clone(), opts));
    let (a, b) = match &request.operands {
        Some((a, b)) => (Some(a.as_slice()), Some(b.as_slice())),
        None => (None, None),
    };
    p.run_job(&job, a, b).map_err(|e: SimError| e.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn coordinator() -> Coordinator {
        Coordinator::new(PlatformConfig::case_study()).with_workers(4)
    }

    #[test]
    fn batch_preserves_order_and_completes() {
        let c = coordinator();
        let shapes = [(8, 8, 8), (16, 16, 16), (24, 8, 40), (64, 64, 64)];
        let reqs: Vec<JobRequest> = shapes
            .iter()
            .map(|&(m, k, n)| {
                JobRequest::timing(GemmShape::new(m, k, n), Mechanisms::ALL, 2)
            })
            .collect();
        let results = c.run_batch(reqs);
        assert_eq!(results.len(), 4);
        for (i, r) in results.iter().enumerate() {
            let r = r.as_ref().expect("job ok");
            let (m, k, n) = shapes[i];
            let ideal = (m.div_ceil(8) * k.div_ceil(8) * n.div_ceil(8)) as u64;
            assert_eq!(r.metrics.compute_cycles, ideal * 2, "shape {i}");
        }
        let stats = c.stats();
        assert_eq!(stats.jobs_completed, 4);
        assert_eq!(stats.jobs_failed, 0);
    }

    #[test]
    fn parallel_matches_serial() {
        let c = coordinator();
        let req = JobRequest::timing(GemmShape::new(40, 48, 56), Mechanisms::CPL_BUF, 3);
        let serial = c.run_one(&req).unwrap();
        let batch = c.run_batch(vec![req.clone(), req.clone()]);
        for r in batch {
            let r = r.unwrap();
            assert_eq!(r.metrics.total_cycles, serial.metrics.total_cycles);
            assert_eq!(r.report.overall, serial.report.overall);
        }
    }

    #[test]
    fn functional_batch_returns_data() {
        let c = coordinator();
        let shape = GemmShape::new(12, 20, 9);
        let mut rng = Pcg32::seeded(5);
        let mut a = vec![0i8; shape.m * shape.k];
        let mut b = vec![0i8; shape.k * shape.n];
        rng.fill_i8(&mut a);
        rng.fill_i8(&mut b);
        let req = JobRequest {
            shape,
            layout: Layout::TiledInterleaved,
            mechanisms: Mechanisms::ALL,
            repeats: 1,
            operands: Some((a.clone(), b.clone())),
        };
        let results = c.run_batch(vec![req]);
        let c_mat = results[0].as_ref().unwrap().c.as_ref().unwrap().clone();
        // spot-check one element
        let (i, j) = (3, 4);
        let expect: i32 = (0..shape.k)
            .map(|kk| a[i * shape.k + kk] as i32 * b[kk * shape.n + j] as i32)
            .sum();
        assert_eq!(c_mat[i * shape.n + j], expect);
    }

    #[test]
    fn fast_forward_toggle_is_cycle_exact_through_the_pool() {
        let req = JobRequest::timing(GemmShape::new(56, 72, 40), Mechanisms::BASELINE, 3);
        let ff = coordinator().run_one(&req).unwrap();
        let ls = Coordinator::new(PlatformConfig::case_study())
            .with_fast_forward(false)
            .run_one(&req)
            .unwrap();
        assert_eq!(ff.metrics, ls.metrics, "fast-forward must be bit-identical");
    }

    #[test]
    fn worker_platform_reuse_is_transparent() {
        // A single worker serves every job below on ONE reused platform
        // (reset_for_job between jobs); results must be bit-identical to
        // fresh-platform runs, across functional/timing and mechanism
        // switches (no state may leak through the SPM or the arena).
        let c = Coordinator::new(PlatformConfig::case_study()).with_workers(1);
        let mut rng = Pcg32::seeded(77);
        let mut reqs = Vec::new();
        for i in 0..6usize {
            let shape = GemmShape::new(8 + 8 * i, 16 + 8 * (i % 3), 24);
            let mech = if i % 2 == 0 { Mechanisms::ALL } else { Mechanisms::BASELINE };
            let operands = if i % 3 != 2 {
                let mut a = vec![0i8; shape.m * shape.k];
                let mut b = vec![0i8; shape.k * shape.n];
                rng.fill_i8(&mut a);
                rng.fill_i8(&mut b);
                Some((a, b))
            } else {
                None
            };
            let layout = if mech.strided_layout {
                Layout::TiledInterleaved
            } else {
                Layout::RowMajor
            };
            reqs.push(JobRequest { shape, layout, mechanisms: mech, repeats: 1, operands });
        }
        let batch = c.run_batch(reqs.clone());
        for (req, got) in reqs.iter().zip(&batch) {
            let got = got.as_ref().expect("batch job ok");
            let fresh = c.run_one(req).expect("fresh job ok");
            assert_eq!(got.metrics, fresh.metrics, "metrics leak for {:?}", req.shape);
            assert_eq!(got.c, fresh.c, "functional result leak for {:?}", req.shape);
        }
    }

    #[test]
    fn workers_env_parsing_is_strict() {
        // unset -> auto-size
        assert_eq!(parse_workers_env(None), Ok(None));
        // a set value is honored exactly (no clamp)
        assert_eq!(parse_workers_env(Some("1")), Ok(Some(1)));
        assert_eq!(parse_workers_env(Some("96")), Ok(Some(96)));
        assert_eq!(parse_workers_env(Some(" 8 ")), Ok(Some(8)), "whitespace tolerated");
        // 0 and garbage are hard errors, not silent auto-sizing
        assert!(parse_workers_env(Some("0")).unwrap_err().contains(">= 1"));
        assert!(parse_workers_env(Some("four")).unwrap_err().contains("not a positive"));
        assert!(parse_workers_env(Some("")).is_err());
        assert!(parse_workers_env(Some("-2")).is_err());
        assert!(parse_workers_env(Some("2.5")).is_err());
    }

    #[test]
    fn failed_jobs_reported_not_panicked() {
        let c = coordinator();
        // oversized K fails the tiler
        let req = JobRequest::timing(GemmShape::new(8, 300_000, 8), Mechanisms::ALL, 1);
        let results = c.run_batch(vec![req]);
        assert!(results[0].is_err());
        assert_eq!(c.stats().jobs_failed, 1);
    }
}
