//! Serving-harness integration tests: the three guarantees ISSUE 4
//! pins down.
//!
//! 1. **Determinism**: the same `(config, options, seed)` produces a
//!    byte-identical JSON report — the property the CI `serve-smoke`
//!    lane re-checks across real process invocations.
//! 2. **Closed-loop differential**: one client, zero think time,
//!    immediate batching and no dispatch overhead degenerates to the
//!    plain sequential loop — every request's latency equals its own
//!    service time and the makespan is their sum.
//! 3. **Honest amortization**: per-head repeat counts are simulated
//!    exactly (BERT-Large's 16 heads — the case the old example's
//!    12-repeat clamp silently mismeasured), and the beyond-cap
//!    affine extrapolation tracks an exact simulation closely.
//!
//! ISSUE 8 adds a fourth: **persistent pricing** — a `ServiceModel`
//! backed by a result cache re-prices a workload from the store instead
//! of re-simulating it, without perturbing the report bytes.

use opengemm::compiler::GemmShape;
use opengemm::config::{Mechanisms, PlatformConfig};
use opengemm::coordinator::cache::ResultCache;
use opengemm::coordinator::{Coordinator, JobRequest};
use opengemm::serve::{
    run_serve, ArrivalSpec, BatchPolicy, FaultKind, FaultSpec, PlacementPolicy, RequestKind,
    ServeOptions, ServiceModel, WorkloadSpec, SERVE_REPORT_FORMAT,
};

fn base_opts() -> ServeOptions {
    ServeOptions {
        workload: WorkloadSpec::BertBase { seq_choices: vec![64, 128] },
        arrival: ArrivalSpec::OpenPoisson { rate_rps: 3000.0 },
        requests: 16,
        seed: 42,
        workers: 2,
        ..Default::default()
    }
}

#[test]
fn same_seed_is_byte_identical() {
    let cfg = PlatformConfig::case_study();
    let opts = base_opts();
    let a = run_serve(&cfg, &opts).unwrap().to_json().pretty();
    let b = run_serve(&cfg, &opts).unwrap().to_json().pretty();
    assert_eq!(a, b, "same seed must serialize byte-identically");
    // and the report really carries the tail percentiles
    for key in ["\"p50\"", "\"p95\"", "\"p99\"", "\"max\""] {
        assert!(a.contains(key), "report missing {key}");
    }
    // a different seed must actually change the timeline
    let reseeded = ServeOptions { seed: 43, ..opts };
    let c = run_serve(&cfg, &reseeded).unwrap().to_json().pretty();
    assert_ne!(a, c, "different seed, different schedule");
}

#[test]
fn workers_do_not_change_the_report() {
    // The measurement pool size is a throughput knob, not a semantic
    // one: 1-worker and 4-worker runs must emit identical bytes.
    let cfg = PlatformConfig::case_study();
    let one = ServeOptions { requests: 8, workers: 1, ..base_opts() };
    let four = ServeOptions { workers: 4, ..one.clone() };
    let w1 = run_serve(&cfg, &one).unwrap();
    let w4 = run_serve(&cfg, &four).unwrap();
    assert_eq!(w1.to_json().pretty(), w4.to_json().pretty());
}

#[test]
fn closed_loop_degenerates_to_sequential() {
    let cfg = PlatformConfig::case_study();
    let opts = ServeOptions {
        workload: WorkloadSpec::BertBase { seq_choices: vec![64] },
        arrival: ArrivalSpec::ClosedLoop { clients: 1, think_cycles: 0 },
        batching: BatchPolicy::Immediate,
        requests: 6,
        seed: 9,
        workers: 2,
        dispatch_overhead_cycles: 0,
        // at seq 64 the scores and context GeMMs fold onto one shape
        // with 24 repeats; a cap above that keeps every point exact
        repeat_cap: 32,
        ..Default::default()
    };
    let report = run_serve(&cfg, &opts).unwrap();
    assert_eq!(report.requests, 6);
    assert_eq!(report.batches, 6, "immediate batching: one batch per request");

    // single kind: its stream cost, measured independently by the
    // plain sequential loop the harness replaced
    let kinds = opts.workload.kinds();
    let kind = &kinds[0];
    let coord = Coordinator::new(cfg.clone()).with_workers(2);
    let mut sequential_cycles = 0u64;
    for &(shape, count) in &kind.stream {
        let r = coord
            .run_one(&JobRequest::timing(shape, Mechanisms::ALL, count as u32))
            .unwrap();
        sequential_cycles += r.metrics.total_cycles;
    }
    assert_eq!(report.kinds.len(), 1);
    assert_eq!(
        report.kinds[0].service_cycles, sequential_cycles,
        "harness service time == plain sequential loop"
    );
    // back-to-back service: makespan = 6 sequential requests, and
    // every request's latency is exactly one service time
    assert_eq!(report.duration_cycles, 6 * sequential_cycles);
    assert_eq!(report.device_busy_cycles, 6 * sequential_cycles);
    let ms = sequential_cycles as f64 / (cfg.freq_mhz as f64 * 1e3);
    let lat = report.latency_ms.as_ref().unwrap();
    assert!((lat.p50 - ms).abs() < 1e-9, "p50 {} vs service {ms}", lat.p50);
    assert!((lat.max - ms).abs() < 1e-9);
    let queueing = report.queueing_ms.as_ref().unwrap();
    assert_eq!(queueing.max, 0.0, "closed loop with 1 client never queues");
}

#[test]
fn bert_large_heads_are_measured_unclamped() {
    // BERT-Large: 16 attention heads. The old example simulated
    // min(16, 12) repeats and rescaled; the harness must price the
    // 16-repeat stream from an exact 16-repeat simulation.
    let cfg = PlatformConfig::case_study();
    let spec = WorkloadSpec::BertLarge { seq_choices: vec![128] };
    let kinds = spec.kinds();
    let kind = &kinds[0];
    let heads = kind.stream.iter().find(|&&(_, c)| c == 16);
    assert!(heads.is_some(), "per-head GeMMs carry count 16");

    let mut model = ServiceModel::new(16);
    model.measure(&cfg, 2, true, std::slice::from_ref(kind)).unwrap();
    let got = model.stream_cycles(&kind.stream).unwrap();

    let coord = Coordinator::new(cfg).with_workers(2);
    let mut exact = 0u64;
    for &(shape, count) in &kind.stream {
        let r = coord
            .run_one(&JobRequest::timing(shape, Mechanisms::ALL, count as u32))
            .unwrap();
        exact += r.metrics.total_cycles;
    }
    assert_eq!(got, exact, "16-head stream priced from exact 16-repeat runs");
}

#[test]
fn beyond_cap_extrapolation_tracks_exact_simulation() {
    // Cap the model at 4 repeats and price a 12-repeat stream; the
    // marginal-cost extrapolation must track the exact 12-repeat
    // simulation closely (config pre-loading makes repeat cost affine
    // in steady state).
    let cfg = PlatformConfig::case_study();
    let shape = GemmShape::new(64, 96, 64);
    let kind = RequestKind { label: "t".into(), stream: vec![(shape, 12)] };
    let mut model = ServiceModel::new(4);
    model.measure(&cfg, 2, true, std::slice::from_ref(&kind)).unwrap();
    let extrapolated = model.stream_cycles(&kind.stream).unwrap();

    let exact = Coordinator::new(cfg)
        .run_one(&JobRequest::timing(shape, Mechanisms::ALL, 12))
        .unwrap()
        .metrics
        .total_cycles;
    let rel = (extrapolated as f64 - exact as f64).abs() / exact as f64;
    assert!(
        rel < 0.05,
        "affine extrapolation {extrapolated} vs exact {exact} ({:.2}% off)",
        rel * 100.0
    );
}

#[test]
fn batching_policies_reshape_the_timeline() {
    let cfg = PlatformConfig::case_study();
    let opts = ServeOptions {
        requests: 10,
        arrival: ArrivalSpec::OpenPoisson { rate_rps: 50_000.0 },
        ..base_opts()
    };
    let immediate = run_serve(&cfg, &opts).unwrap();
    assert_eq!(immediate.batches, 10);

    let sized_opts = ServeOptions { batching: BatchPolicy::Size(4), ..opts.clone() };
    let sized = run_serve(&cfg, &sized_opts).unwrap();
    // 10 requests in batches of 4: 4 + 4 + flushed 2
    assert_eq!(sized.batches, 3);
    assert_eq!(sized.requests, 10, "flush serves the partial remainder");

    let deadline_policy = BatchPolicy::Deadline { max_batch: 4, max_wait_cycles: 1 };
    let deadline_opts = ServeOptions { batching: deadline_policy, ..opts };
    let deadline = run_serve(&cfg, &deadline_opts).unwrap();
    assert!(
        deadline.batches >= 3,
        "a 1-cycle deadline can only shrink batches: {}",
        deadline.batches
    );
    assert_eq!(deadline.requests, 10);
}

#[test]
fn fleet_knobs_without_faults_do_not_perturb_the_single_device_timeline() {
    // The v2 differential at the report level: explicit 1-device fleet
    // options (placement choice, unused retry budget) must serialize
    // byte-identically to the defaults — the fleet layer is invisible
    // until it has more than one device or an injected fault.
    let cfg = PlatformConfig::case_study();
    let baseline = run_serve(&cfg, &base_opts()).unwrap().to_json().pretty();
    assert!(baseline.contains(SERVE_REPORT_FORMAT), "v2 schema marker present");
    let explicit = ServeOptions {
        devices: 1,
        placement: PlacementPolicy::LeastWork,
        retries: 9,
        ..base_opts()
    };
    let fleet = run_serve(&cfg, &explicit).unwrap().to_json().pretty();
    // the placement label is reported; everything timeline-derived is
    // identical
    assert_eq!(baseline.replace("round-robin", "least-work"), fleet);
}

#[test]
fn fail_stop_drives_failover_counters_into_the_report() {
    let cfg = PlatformConfig::case_study();
    let opts = ServeOptions {
        workload: WorkloadSpec::BertBase { seq_choices: vec![64] },
        requests: 8,
        devices: 2,
        placement: PlacementPolicy::RoundRobin,
        faults: vec![FaultSpec { device: 0, at_cycle: 1, kind: FaultKind::FailStop }],
        retries: 4,
        ..base_opts()
    };
    let report = run_serve(&cfg, &opts).unwrap();
    assert!(report.fleet.failovers > 0, "round-robin must hit the dead device");
    assert!(report.fleet.retries >= report.fleet.failovers, "retries count batch members");
    assert_eq!(report.requests, 8, "every request survives the failovers");
    assert_eq!(report.devices[0].failed_at_cycle, Some(1));
    assert_eq!(report.devices[1].failed_at_cycle, None);
    assert_eq!(report.devices[1].batches, report.batches, "the survivor won every batch");
    // the counters are in the JSON, with their non-zero values
    let json = report.to_json();
    let fleet = json.get("fleet").expect("fleet object");
    assert!(fleet.get("failovers").and_then(|v| v.as_f64()).unwrap() > 0.0);
    assert!(fleet.get("retries").and_then(|v| v.as_f64()).unwrap() > 0.0);
    for key in ["hedges", "shed", "wasted_cycles", "goodput_rps", "placement"] {
        assert!(fleet.get(key).is_some(), "fleet JSON missing {key}");
    }
    let devices = json.get("devices").and_then(|d| d.as_arr()).expect("devices array");
    assert_eq!(devices.len(), 2);
    assert!(devices.iter().all(|d| d.get("utilization").is_some()));

    // and the faulted run still replays byte-identically in-process
    // (the CI fleet-smoke lane re-checks this across real processes)
    let again = run_serve(&cfg, &opts).unwrap();
    assert_eq!(json.pretty(), again.to_json().pretty());
}

#[test]
fn slo_admission_control_sheds_and_reports_offered_load() {
    let cfg = PlatformConfig::case_study();
    // heavy overload (BERT service runs ~ms; arrivals every ~20us) with
    // a tight SLO: most arrivals must be shed, loudly
    let opts = ServeOptions {
        workload: WorkloadSpec::BertBase { seq_choices: vec![64] },
        arrival: ArrivalSpec::OpenPoisson { rate_rps: 10_000.0 },
        requests: 12,
        slo_ms: Some(0.01),
        ..base_opts()
    };
    let report = run_serve(&cfg, &opts).unwrap();
    assert!(report.fleet.shed > 0, "overload past the SLO must shed");
    assert_eq!(report.fleet.offered, 12);
    assert_eq!(report.requests + report.fleet.shed, report.fleet.offered);
    assert!(report.requests > 0, "the first arrival always meets an idle device");
    let json = report.to_json();
    let fleet = json.get("fleet").expect("fleet object");
    assert!(fleet.get("shed").and_then(|v| v.as_f64()).unwrap() > 0.0);
    assert_eq!(fleet.get("offered").and_then(|v| v.as_f64()), Some(12.0));
    assert!(fleet.get("slo_cycles").and_then(|v| v.as_f64()).unwrap() > 0.0);
    // shedding caps goodput below offered load
    let goodput = fleet.get("goodput_rps").and_then(|v| v.as_f64()).unwrap();
    assert!(goodput > 0.0);
}

#[test]
fn service_model_pricing_persists_across_invocations() {
    let cfg = PlatformConfig::case_study();
    let dir = std::env::temp_dir().join(format!("opengemm-serve-price-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let kinds = WorkloadSpec::BertBase { seq_choices: vec![64] }.kinds();

    let cold_store = ResultCache::persistent(&dir).unwrap();
    let mut cold = ServiceModel::new(16);
    let cold_stats = cold.measure_cached(&cfg, 2, true, &kinds, Some(&cold_store)).unwrap();
    assert!(cold_stats.jobs_simulated > 0, "first invocation must simulate");
    assert_eq!(cold_stats.cache_hits, 0);
    assert_eq!(cold_stats.cache_misses, cold_stats.jobs_simulated);

    // Fresh model, fresh cache instance: the second "process" prices
    // the same workload purely from the store on disk.
    let warm_store = ResultCache::persistent(&dir).unwrap();
    let mut warm = ServiceModel::new(16);
    let warm_stats = warm.measure_cached(&cfg, 2, true, &kinds, Some(&warm_store)).unwrap();
    assert_eq!(warm_stats.jobs_simulated, 0, "re-invocation must price from the store");
    assert_eq!(warm_stats.cache_hits, cold_stats.jobs_simulated);
    for kind in &kinds {
        assert_eq!(
            warm.stream_cycles(&kind.stream).unwrap(),
            cold.stream_cycles(&kind.stream).unwrap(),
            "cached pricing == simulated pricing for {}",
            kind.label
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn serve_report_is_byte_identical_with_a_warm_cache() {
    let cfg = PlatformConfig::case_study();
    let dir = std::env::temp_dir().join(format!("opengemm-serve-cache-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let baseline = run_serve(&cfg, &base_opts()).unwrap().to_json().pretty();

    let cached_opts = ServeOptions { cache_dir: Some(dir.clone()), ..base_opts() };
    let cold = run_serve(&cfg, &cached_opts).unwrap().to_json().pretty();
    assert_eq!(cold, baseline, "an empty cache must not perturb the report");
    let warm = run_serve(&cfg, &cached_opts).unwrap().to_json().pretty();
    assert_eq!(warm, baseline, "a warm cache must not perturb the report");

    // verify mode over the intact store re-simulates and passes
    let verify_opts = ServeOptions { cache_verify: true, ..cached_opts };
    let verified = run_serve(&cfg, &verify_opts).unwrap().to_json().pretty();
    assert_eq!(verified, baseline);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn overhead_amortization_favors_batching() {
    // With a heavy per-batch dispatch cost, size-4 batching must beat
    // immediate dispatch on makespan (that is the point of batching).
    let cfg = PlatformConfig::case_study();
    let opts = ServeOptions {
        requests: 12,
        arrival: ArrivalSpec::OpenPoisson { rate_rps: 100_000.0 },
        dispatch_overhead_cycles: 100_000,
        ..base_opts()
    };
    let immediate = run_serve(&cfg, &opts).unwrap();
    let sized_opts = ServeOptions { batching: BatchPolicy::Size(4), ..opts };
    let sized = run_serve(&cfg, &sized_opts).unwrap();
    assert!(
        sized.duration_cycles < immediate.duration_cycles,
        "batched {} vs immediate {}",
        sized.duration_cycles,
        immediate.duration_cycles
    );
}
