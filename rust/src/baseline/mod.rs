//! Gemmini baseline timing model (Sec. 4.5, Fig. 7).
//!
//! The paper benchmarks against Gemmini [12] using the performance data
//! of the 22nm SoC measurement in [32], in output-stationary (OS) and
//! weight-stationary (WS) modes. We model Gemmini behaviourally at the
//! instruction level: a 16x16 systolic array fed through RoCC
//! instructions (`mvin` / `preload` / `compute` / `mvout`) issued by an
//! in-order Rocket host over a 128-bit memory path, with no overlap
//! between data movement and compute in the measured configuration —
//! the regime [32] reports, where Gemmini's *temporal* utilization
//! averages ~6.25% because of memory stalls and issue overhead.
//!
//! Model parameters are documented constants calibrated against that
//! published average; the Fig. 7 comparison cares about the *shape* of
//! the normalized-throughput curves, not exact absolute numbers.

use crate::compiler::GemmShape;

/// Gemmini dataflow mode.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GemminiMode {
    OutputStationary,
    WeightStationary,
}

/// The modeled Gemmini instance (defaults follow [12]/[32]).
#[derive(Debug, Clone, Copy)]
pub struct GemminiModel {
    /// Systolic array dimension (16x16 PEs).
    pub dim: usize,
    /// Clock frequency in MHz (1 GHz in [32]).
    pub freq_mhz: u64,
    /// Layout area in mm^2 (22nm, Table 3).
    pub area_mm2: f64,
    /// Cycles to move one 16x16 int8 tile over the 128-bit port.
    pub mvin_tile_cycles: u64,
    /// Cycles to move one 16x16 int32 accumulator tile out.
    pub mvout_tile_cycles: u64,
    /// Pipeline cycles for one 16-deep systolic compute pass.
    pub compute_tile_cycles: u64,
    /// Host issue + ROB + dependency overhead per RoCC instruction.
    pub issue_overhead: u64,
}

impl Default for GemminiModel {
    fn default() -> Self {
        GemminiModel {
            dim: 16,
            freq_mhz: 1000,
            area_mm2: 1.03,
            // 16 rows x 16 B per row over 16 B/cycle:
            mvin_tile_cycles: 16,
            // 16 rows x 64 B per row over 16 B/cycle:
            mvout_tile_cycles: 64,
            // fill + drain of a 16-deep array:
            compute_tile_cycles: 32,
            // Rocket RoCC round-trip incl. dependency stalls, calibrated
            // so the Fig. 7 normalized-throughput ratios land in the
            // paper's band (3.58x at (128)^3, ~16x at (8)^3) while the
            // sweep-average temporal utilization stays in the published
            // ~6% regime:
            issue_overhead: 19,
        }
    }
}

/// Cycle estimate for one GeMM.
#[derive(Debug, Clone, Copy)]
pub struct GemminiResult {
    pub cycles: u64,
    pub ideal_cycles: u64,
    pub temporal_utilization: f64,
    /// Achieved GOPS on *real* (unpadded) operations.
    pub gops: f64,
    /// Area-normalized throughput (GOPS/mm^2), the Fig. 7 metric.
    pub gops_per_mm2: f64,
}

impl GemminiModel {
    /// Peak throughput in GOPS.
    pub fn peak_gops(&self) -> f64 {
        2.0 * (self.dim * self.dim) as f64 * self.freq_mhz as f64 * 1e6 / 1e9
    }

    fn tiles(&self, d: usize) -> u64 {
        d.div_ceil(self.dim) as u64
    }

    /// Estimate the execution cycles of `shape` in `mode`.
    pub fn run(&self, shape: GemmShape, mode: GemminiMode) -> GemminiResult {
        let (mt, kt, nt) = (self.tiles(shape.m), self.tiles(shape.k), self.tiles(shape.n));
        let i = self.issue_overhead;
        let cycles = match mode {
            GemminiMode::WeightStationary => {
                // for each (k, n): preload B tile once; for each m:
                // mvin A + compute; mvout C per (m, n) after the k loop.
                let preload = kt * nt * (i + self.mvin_tile_cycles + self.compute_tile_cycles / 2);
                let inner = kt * nt * mt * (2 * i + self.mvin_tile_cycles + self.compute_tile_cycles);
                let out = mt * nt * (i + self.mvout_tile_cycles);
                preload + inner + out
            }
            GemminiMode::OutputStationary => {
                // partial sums stay in the array; both operands stream in
                // per k step: mvin A + mvin B + compute, then one mvout.
                let inner = mt * nt * kt
                    * (3 * i + 2 * self.mvin_tile_cycles + self.compute_tile_cycles);
                let out = mt * nt * (i + self.mvout_tile_cycles);
                inner + out
            }
        };
        // ideal: one 16-wide column of MACs per cycle per tile pass
        let ideal_cycles = mt * nt * kt * self.dim as u64;
        let tu = ideal_cycles as f64 / cycles as f64;
        let gops =
            shape.ops() as f64 / cycles as f64 * self.freq_mhz as f64 * 1e6 / 1e9;
        GemminiResult {
            cycles,
            ideal_cycles,
            temporal_utilization: tu,
            gops,
            gops_per_mm2: gops / self.area_mm2,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sweep() -> Vec<GemmShape> {
        // Fig. 7 sweep: square sizes 8..128
        [8usize, 16, 32, 64, 128]
            .iter()
            .map(|&d| GemmShape::new(d, d, d))
            .collect()
    }

    #[test]
    fn peak_is_512_gops() {
        assert!((GemminiModel::default().peak_gops() - 512.0).abs() < 1e-9);
    }

    #[test]
    fn average_tu_matches_published_band() {
        // the paper quotes ~6.25% average temporal utilization on these
        // workloads; our model must land near that
        let model = GemminiModel::default();
        let tus: Vec<f64> = sweep()
            .into_iter()
            .flat_map(|s| {
                [
                    model.run(s, GemminiMode::OutputStationary).temporal_utilization,
                    model.run(s, GemminiMode::WeightStationary).temporal_utilization,
                ]
            })
            .collect();
        let avg = tus.iter().sum::<f64>() / tus.len() as f64;
        assert!(
            (0.04..0.11).contains(&avg),
            "average Gemmini TU should be ~6%, got {avg:.4}"
        );
    }

    #[test]
    fn os_slower_than_ws_on_large_k() {
        // the paper's speedups vs OS exceed those vs WS -> OS is slower
        let model = GemminiModel::default();
        let s = GemmShape::new(128, 128, 128);
        let os = model.run(s, GemminiMode::OutputStationary);
        let ws = model.run(s, GemminiMode::WeightStationary);
        assert!(os.cycles > ws.cycles, "{} vs {}", os.cycles, ws.cycles);
    }

    #[test]
    fn throughput_grows_with_size() {
        let model = GemminiModel::default();
        let small = model.run(GemmShape::new(8, 8, 8), GemminiMode::WeightStationary);
        let large = model.run(GemmShape::new(128, 128, 128), GemminiMode::WeightStationary);
        assert!(large.gops > small.gops);
        assert!(large.gops < model.peak_gops());
    }

    #[test]
    fn padding_wastes_throughput() {
        let model = GemminiModel::default();
        let aligned = model.run(GemmShape::new(32, 32, 32), GemminiMode::WeightStationary);
        let ragged = model.run(GemmShape::new(17, 17, 17), GemminiMode::WeightStationary);
        assert!(ragged.gops < aligned.gops / 2.0, "padding to 32 halves effective work");
    }
}
