"""AOT compiler: lower every L2 graph to an HLO-text artifact.

Interchange format is HLO **text**, not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage (from python/):  python -m compile.aot --out-dir ../artifacts

Emits one ``<name>.hlo.txt`` per registry entry plus ``manifest.json``
describing argument/result shapes and dtypes for the Rust loader.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

from .model import artifact_registry

_DTYPE_NAMES = {
    "int8": "s8",
    "int32": "s32",
    "float32": "f32",
}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(name: str, factory, args) -> tuple[str, dict]:
    fn, specs = factory(*args)
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    out_specs = jax.eval_shape(fn, *specs)
    meta = {
        "file": f"{name}.hlo.txt",
        "args": [
            {"shape": list(s.shape), "dtype": _DTYPE_NAMES[str(s.dtype)]}
            for s in specs
        ],
        "results": [
            {"shape": list(s.shape), "dtype": _DTYPE_NAMES[str(s.dtype)]}
            for s in out_specs
        ],
        "sha256": hashlib.sha256(text.encode()).hexdigest(),
    }
    return text, meta


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument(
        "--only", default=None, help="comma-separated artifact names"
    )
    ns = parser.parse_args()
    os.makedirs(ns.out_dir, exist_ok=True)

    registry = artifact_registry()
    if ns.only:
        wanted = set(ns.only.split(","))
        unknown = wanted - set(registry)
        if unknown:
            raise SystemExit(f"unknown artifacts: {sorted(unknown)}")
        registry = {k: v for k, v in registry.items() if k in wanted}

    manifest = {}
    for name, (factory, args) in sorted(registry.items()):
        text, meta = lower_entry(name, factory, args)
        path = os.path.join(ns.out_dir, meta["file"])
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = meta
        print(f"  aot: {name:<28s} {len(text):>9d} chars")

    with open(os.path.join(ns.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {len(manifest)} artifacts to {ns.out_dir}")


if __name__ == "__main__":
    main()
