//! Sustained-traffic serving harness: the platform as an inference
//! service under load, not just a per-kernel simulator.
//!
//! OpenGeMM's pitch is system-level efficiency under real DNN
//! workloads (the paper evaluates full model streams, like its Gemmini
//! baseline). This module turns the one-shot `bert_serving` example
//! loop into a proper serving-systems harness:
//!
//! 1. **Request kinds** ([`workload`]): a request is a named GeMM
//!    stream — a BERT encoder layer at a sampled sequence length, or a
//!    full CNN (ResNet-18) inference.
//! 2. **Service model** ([`service`]): each distinct `(shape,
//!    repeats)` point is simulated once, cycle-accurately, through the
//!    coordinator pool; repeat counts are honored exactly up to a cap
//!    (no more silent 12-repeat clamping — BERT-Large's 16 heads are
//!    measured as 16) and extrapolated by marginal cost beyond it.
//! 3. **Arrival process** ([`arrival`]): open-loop Poisson or
//!    closed-loop N-clients, seeded via [`Pcg32`].
//! 4. **Queueing model** ([`queue`]): a virtual-time single-device
//!    timeline under a pluggable [`BatchPolicy`] ([`batching`]),
//!    yielding per-request queueing + service latency in device
//!    cycles.
//! 5. **Fleet** ([`fleet`] + [`router`]): N simulated devices behind a
//!    placement policy, with deterministic fault injection, timeout
//!    failover, hedged re-issue and SLO load shedding. One device and
//!    no faults reproduces the [`queue`] timeline exactly.
//! 6. **Report** ([`report`]): p50/p90/p95/p99/max latency
//!    percentiles plus per-device utilization and robustness counters,
//!    as a table and as deterministic JSON (same seed =>
//!    byte-identical bytes, enforced by tests and the `serve-smoke` /
//!    `fleet-smoke` CI lanes).
//!
//! Everything is a pure function of `(PlatformConfig, ServeOptions)`;
//! no wall clock enters the report.

pub mod arrival;
pub mod batching;
pub mod fleet;
pub mod queue;
pub mod report;
pub mod router;
pub mod service;
pub mod workload;

pub use arrival::ArrivalSpec;
pub use batching::BatchPolicy;
pub use fleet::{
    simulate_fleet, AttemptOutcome, AttemptRecord, FaultKind, FaultSpec, FleetCounters,
    FleetOutcome, FleetSpec,
};
pub use queue::{simulate_queue, ArrivalSource, RequestRecord};
pub use report::{DeviceReport, FleetStats, KindSummary, ServeReport, SERVE_REPORT_FORMAT};
pub use router::PlacementPolicy;
pub use service::ServiceModel;
pub use workload::{RequestKind, WorkloadSpec};

use crate::config::PlatformConfig;
use crate::util::rng::Pcg32;
use crate::util::stats::TailSummary;

use arrival::poisson_arrival_cycles;

/// RNG stream selectors (see [`Pcg32::new`]): arrival timing and
/// request-kind sampling draw from independent deterministic streams
/// of the same seed, so changing the request count perturbs neither.
const ARRIVAL_STREAM: u64 = 0x5e7e_a221;
const KIND_STREAM: u64 = 0x5e7e_71fe;

/// Everything one serving run depends on (besides the platform).
#[derive(Debug, Clone, PartialEq)]
pub struct ServeOptions {
    pub workload: WorkloadSpec,
    pub arrival: ArrivalSpec,
    pub batching: BatchPolicy,
    /// Requests to schedule (0 = an idle window, which must produce an
    /// empty report rather than a panic).
    pub requests: usize,
    pub seed: u64,
    /// Worker threads for the measurement coordinator (0 = auto).
    pub workers: usize,
    pub fast_forward: bool,
    /// Service-model exact-measurement cap (see [`ServiceModel`]).
    pub repeat_cap: u32,
    /// Host dispatch cost paid once per batch, in device cycles —
    /// what size/deadline batching amortizes.
    pub dispatch_overhead_cycles: u64,
    /// Simulated devices behind the router (1 = the classic
    /// single-device timeline).
    pub devices: usize,
    /// How the router maps batches onto devices.
    pub placement: PlacementPolicy,
    /// Deterministic device faults, in virtual cycles.
    pub faults: Vec<FaultSpec>,
    /// Shed arrivals whose predicted queueing delay exceeds this SLO.
    pub slo_ms: Option<f64>,
    /// Hedged re-issue after a p99-derived delay.
    pub hedge: bool,
    /// Failover re-dispatch budget per batch.
    pub retries: usize,
    /// Persist the service model's `(shape, repeats)` measurements via
    /// the content-addressed result cache (`coordinator::cache`) in
    /// this directory, so re-pricing a workload across process
    /// invocations simulates nothing.
    pub cache_dir: Option<std::path::PathBuf>,
    /// Re-simulate cache hits and hard-error on divergence (requires
    /// `cache_dir`).
    pub cache_verify: bool,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            workload: WorkloadSpec::BertBase {
                seq_choices: WorkloadSpec::DEFAULT_SEQS.to_vec(),
            },
            arrival: ArrivalSpec::OpenPoisson { rate_rps: 200.0 },
            batching: BatchPolicy::Immediate,
            requests: 64,
            seed: 1,
            workers: 0,
            fast_forward: true,
            repeat_cap: 16,
            dispatch_overhead_cycles: 0,
            devices: 1,
            placement: PlacementPolicy::RoundRobin,
            faults: Vec::new(),
            slo_ms: None,
            hedge: false,
            retries: 2,
            cache_dir: None,
            cache_verify: false,
        }
    }
}

/// Milliseconds of virtual time to device cycles at `freq_mhz`.
pub fn ms_to_cycles(ms: f64, freq_mhz: u64) -> u64 {
    (ms * freq_mhz as f64 * 1e3).round() as u64
}

fn validate(opts: &ServeOptions) -> Result<(), String> {
    match opts.arrival {
        ArrivalSpec::OpenPoisson { rate_rps } => {
            if !rate_rps.is_finite() || rate_rps <= 0.0 {
                return Err(format!("arrival rate must be a positive rate, got {rate_rps}"));
            }
        }
        ArrivalSpec::ClosedLoop { clients, .. } => {
            if clients == 0 {
                return Err("closed-loop arrival needs at least 1 client".into());
            }
        }
    }
    if let Some(slo) = opts.slo_ms {
        if !slo.is_finite() || slo < 0.0 {
            return Err(format!("--slo-ms must be a finite non-negative latency, got {slo}"));
        }
    }
    if opts.cache_verify && opts.cache_dir.is_none() {
        return Err("--cache-verify needs --cache DIR (no cache to verify against)".into());
    }
    Ok(())
}

/// Run the serving harness end to end.
pub fn run_serve(cfg: &PlatformConfig, opts: &ServeOptions) -> Result<ServeReport, String> {
    validate(opts)?;
    let kinds = opts.workload.kinds();
    if kinds.is_empty() {
        return Err("workload has no request kinds".into());
    }

    // 1. measure service times (the only simulation work), through the
    // persistent result cache when one is configured
    let cache = match &opts.cache_dir {
        Some(dir) => Some(
            crate::coordinator::cache::ResultCache::persistent(dir)?
                .with_verify(opts.cache_verify),
        ),
        None => None,
    };
    let mut model = ServiceModel::new(opts.repeat_cap);
    let measurement =
        model.measure_cached(cfg, opts.workers, opts.fast_forward, &kinds, cache.as_ref())?;
    let service_by_kind: Vec<u64> = kinds
        .iter()
        .map(|k| model.stream_cycles(&k.stream))
        .collect::<Result<_, _>>()?;

    // 2. generate arrivals and run the virtual-time queueing model
    let mut source = match opts.arrival {
        ArrivalSpec::OpenPoisson { rate_rps } => {
            let mut arrival_rng = Pcg32::new(opts.seed, ARRIVAL_STREAM);
            let mut kind_rng = Pcg32::new(opts.seed, KIND_STREAM);
            let times =
                poisson_arrival_cycles(rate_rps, cfg.freq_mhz, opts.requests, &mut arrival_rng);
            let arrivals: Vec<(u64, usize)> = times
                .into_iter()
                .map(|t| (t, kind_rng.below(kinds.len() as u32) as usize))
                .collect();
            ArrivalSource::open(arrivals)
        }
        ArrivalSpec::ClosedLoop { clients, think_cycles } => ArrivalSource::closed(
            clients,
            think_cycles,
            opts.requests,
            kinds.len(),
            Pcg32::new(opts.seed, KIND_STREAM),
        ),
    };
    let overhead = opts.dispatch_overhead_cycles;
    let fleet_spec = FleetSpec {
        devices: opts.devices,
        placement: opts.placement,
        faults: opts.faults.clone(),
        slo_cycles: opts.slo_ms.map(|ms| ms_to_cycles(ms, cfg.freq_mhz)),
        hedge: opts.hedge,
        retries: opts.retries,
    };
    let outcome = simulate_fleet(&mut source, &service_by_kind, opts.batching, overhead, &fleet_spec)?;

    // 3. aggregate into the report (virtual time only)
    let to_ms = |c: u64| c as f64 / (cfg.freq_mhz as f64 * 1e3);
    let n = outcome.records.len();
    let mut latency = Vec::with_capacity(n);
    let mut queueing = Vec::with_capacity(n);
    let mut service = Vec::with_capacity(n);
    let mut served_by_kind = vec![0usize; kinds.len()];
    for r in &outcome.records {
        latency.push(to_ms(r.completion - r.arrival));
        queueing.push(to_ms(r.start - r.arrival));
        service.push(to_ms(r.completion - r.start));
        served_by_kind[r.kind] += 1;
    }
    let kind_summaries: Vec<KindSummary> = kinds
        .iter()
        .zip(&served_by_kind)
        .zip(&service_by_kind)
        .map(|((k, &served), &service_cycles)| KindSummary {
            label: k.label.clone(),
            served,
            service_cycles,
        })
        .collect();

    let device_reports: Vec<DeviceReport> = outcome
        .devices
        .iter()
        .enumerate()
        .map(|(i, d)| DeviceReport {
            device: i,
            busy_cycles: d.busy_cycles,
            batches: d.batches_won,
            failed_at_cycle: d.failed_at,
            degraded: d.degraded,
        })
        .collect();
    let fleet_stats = FleetStats {
        devices: opts.devices,
        placement: opts.placement.label().to_string(),
        offered: outcome.offered,
        shed: outcome.shed.len(),
        failovers: outcome.counters.failovers,
        retries: outcome.counters.retries,
        hedges: outcome.counters.hedges,
        wasted_cycles: outcome.counters.wasted_cycles,
        slo_cycles: fleet_spec.slo_cycles,
        hedge: opts.hedge,
    };

    Ok(ServeReport {
        workload: opts.workload.to_json(),
        arrival: opts.arrival,
        batching: opts.batching,
        seed: opts.seed,
        freq_mhz: cfg.freq_mhz,
        requests: outcome.records.len(),
        batches: outcome.batches.len(),
        // attempts never outlive the winning completion, so the last
        // served completion is the fleet makespan
        duration_cycles: outcome.records.iter().map(|r| r.completion).max().unwrap_or(0),
        device_busy_cycles: outcome.devices.iter().map(|d| d.busy_cycles).sum(),
        latency_ms: TailSummary::compute(&latency),
        queueing_ms: TailSummary::compute(&queueing),
        service_ms: TailSummary::compute(&service),
        kinds: kind_summaries,
        devices: device_reports,
        fleet: fleet_stats,
        measurement,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_opts() -> ServeOptions {
        ServeOptions {
            workload: WorkloadSpec::BertBase { seq_choices: vec![64] },
            arrival: ArrivalSpec::OpenPoisson { rate_rps: 2000.0 },
            requests: 8,
            seed: 11,
            workers: 2,
            ..Default::default()
        }
    }

    #[test]
    fn serve_produces_percentiles() {
        let cfg = PlatformConfig::case_study();
        let report = run_serve(&cfg, &tiny_opts()).unwrap();
        assert_eq!(report.requests, 8);
        let lat = report.latency_ms.as_ref().expect("non-empty window");
        assert!(lat.p50 > 0.0 && lat.p99 >= lat.p50 && lat.max >= lat.p99);
        assert!(report.duration_cycles > 0);
        assert!(report.device_utilization() > 0.0);
    }

    #[test]
    fn idle_window_yields_empty_report() {
        let cfg = PlatformConfig::case_study();
        let idle = ServeOptions { requests: 0, ..tiny_opts() };
        let report = run_serve(&cfg, &idle).unwrap();
        assert_eq!(report.requests, 0);
        assert_eq!(report.latency_ms, None);
        assert_eq!(report.duration_cycles, 0);
        assert!(report.to_json().pretty().contains("null"));
    }

    #[test]
    fn invalid_options_are_rejected() {
        let cfg = PlatformConfig::case_study();
        let bad_rate = ServeOptions {
            arrival: ArrivalSpec::OpenPoisson { rate_rps: 0.0 },
            ..tiny_opts()
        };
        assert!(run_serve(&cfg, &bad_rate).is_err());
        let no_clients = ServeOptions {
            arrival: ArrivalSpec::ClosedLoop { clients: 0, think_cycles: 0 },
            ..tiny_opts()
        };
        assert!(run_serve(&cfg, &no_clients).is_err());
        let bad_slo = ServeOptions { slo_ms: Some(f64::NAN), ..tiny_opts() };
        assert!(run_serve(&cfg, &bad_slo).is_err());
        let no_devices = ServeOptions { devices: 0, ..tiny_opts() };
        assert!(run_serve(&cfg, &no_devices).is_err());
        let bad_fault = ServeOptions {
            devices: 2,
            faults: vec![FaultSpec { device: 5, at_cycle: 0, kind: FaultKind::FailStop }],
            ..tiny_opts()
        };
        assert!(run_serve(&cfg, &bad_fault).is_err());
    }

    #[test]
    fn fleet_report_carries_devices_and_counters() {
        let cfg = PlatformConfig::case_study();
        let opts = ServeOptions { devices: 2, placement: PlacementPolicy::LeastWork, ..tiny_opts() };
        let report = run_serve(&cfg, &opts).unwrap();
        assert_eq!(report.devices.len(), 2);
        assert_eq!(report.fleet.devices, 2);
        assert_eq!(report.fleet.placement, "least-work");
        assert_eq!(report.fleet.offered, report.requests + report.fleet.shed);
        assert_eq!(report.fleet.shed, 0);
        assert_eq!(
            report.device_busy_cycles,
            report.devices.iter().map(|d| d.busy_cycles).sum::<u64>()
        );
    }

    #[test]
    fn ms_to_cycles_at_200mhz() {
        assert_eq!(ms_to_cycles(1.0, 200), 200_000);
        assert_eq!(ms_to_cycles(0.0, 200), 0);
    }
}
