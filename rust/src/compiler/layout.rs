//! Data-layout planning: the strided-memory-access mechanism (Sec. 3.4).
//!
//! Three layouts are supported, in increasing order of bank-conflict
//! freedom:
//!
//! - [`Layout::RowMajor`]: operands stored exactly as the host produced
//!   them. Tile rows land `K/8` (or `N/8`) words apart, so a single tile
//!   fetch can hit the same bank repeatedly (Fig. 4(c)(2))
//! - [`Layout::TiledContiguous`]: each array tile is one contiguous
//!   64-byte burst; fetches are conflict-free *within* a streamer but A
//!   and B fetches still collide whenever their tile indices land in the
//!   same bank group.
//! - [`Layout::TiledInterleaved`]: A and B tiles interleave on a two-tile
//!   pitch so A only ever occupies even 8-word bank groups and B odd
//!   groups — the contention-free layout of Fig. 4(c)(3).
//!
//! `plan()` resolves a padded GeMM call to base addresses + the sixteen
//! run-time CSR values; `pack_a`/`pack_b`/`unpack_c` are the functional
//! (data-moving) counterparts used by functional simulation, standing in
//! for the DMA/host writing the SPM image.
//!
//! The packers move whole rows/tiles through the SPM's bulk byte APIs
//! ([`Spm::write_i8`] / [`Spm::read_i32`], which resolve the word
//! mapping once per run, not per byte); every address they emit is
//! word-aligned with word-multiple lengths (padded dims are `Mu/Nu/Ku`
//! multiples), so each pack/unpack lowers to whole-word stores. They
//! also uphold the tile-MAC vectorization contract
//! (`gemm_core::dotprod`): all K-padding zeros land at the *tail* of an
//! A' row, never interleaved.

use crate::config::PlatformConfig;
use crate::csr::{
    pack_bounds, ConfigRegs, CSR_A_BASE, CSR_A_SPATIAL0, CSR_A_SPATIAL1, CSR_A_STRIDE_K,
    CSR_A_STRIDE_M, CSR_BOUNDS, CSR_B_BASE, CSR_B_SPATIAL0, CSR_B_SPATIAL1, CSR_B_STRIDE_K,
    CSR_B_STRIDE_N, CSR_BASE, CSR_C_BASE, CSR_C_SPATIAL0, CSR_C_SPATIAL1, CSR_C_STRIDE_M,
    CSR_C_STRIDE_N,
};
use crate::spm::Spm;
use crate::streamer::LoopBounds;

use super::tiling::GemmShape;

/// SPM data layout for one accelerator call.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Layout {
    RowMajor,
    TiledContiguous,
    TiledInterleaved,
}

impl Layout {
    /// Stable wire name (sharded-sweep job serialization).
    pub fn name(self) -> &'static str {
        match self {
            Layout::RowMajor => "row-major",
            Layout::TiledContiguous => "tiled-contiguous",
            Layout::TiledInterleaved => "tiled-interleaved",
        }
    }

    pub fn from_name(name: &str) -> Option<Layout> {
        match name {
            "row-major" => Some(Layout::RowMajor),
            "tiled-contiguous" => Some(Layout::TiledContiguous),
            "tiled-interleaved" => Some(Layout::TiledInterleaved),
            _ => None,
        }
    }
}

/// A resolved call: padded shape, loop bounds, and the CSR programming
/// image (the values the host must write).
#[derive(Debug, Clone)]
pub struct Placement {
    pub layout: Layout,
    /// Padded (tile-aligned) dimensions of this call.
    pub padded: GemmShape,
    pub bounds: LoopBounds,
    pub a_base: u64,
    pub b_base: u64,
    pub c_base: u64,
    /// Run-time CSR (address, value) pairs in programming order.
    pub csr_writes: Vec<(u32, u32)>,
}

impl Placement {
    /// Rebuild a ConfigRegs bank from the CSR write list (what the
    /// hardware would hold after the host ran the config program).
    pub fn config_regs(&self) -> ConfigRegs {
        let mut regs = ConfigRegs::default();
        for &(addr, value) in &self.csr_writes {
            regs.regs[(addr - CSR_BASE) as usize] = value;
        }
        regs
    }

    /// Total SPM footprint in bytes (exclusive upper bound address).
    pub fn footprint(&self) -> u64 {
        self.c_base + 4 * (self.padded.m * self.padded.n) as u64
    }

    /// Relocate the placement `bytes` higher in the SPM (a core's
    /// partition base on multi-core platforms). Base addresses move;
    /// strides and bounds are translation-invariant. Only the *values*
    /// of the three base-register writes change — the CSR addresses
    /// stay in the canonical window; codegen adds the per-core window
    /// offset when emitting the program.
    pub fn offset_by(&mut self, bytes: u64) {
        if bytes == 0 {
            return;
        }
        self.a_base += bytes;
        self.b_base += bytes;
        self.c_base += bytes;
        for (addr, value) in &mut self.csr_writes {
            if *addr == CSR_A_BASE || *addr == CSR_B_BASE || *addr == CSR_C_BASE {
                *value += bytes as u32;
            }
        }
    }
}

/// Resolve a padded GeMM call to addresses and CSR values.
pub fn plan(cfg: &PlatformConfig, shape: &GemmShape, layout: Layout) -> Placement {
    let core = &cfg.core;
    let padded = shape.padded(core);
    let bounds = shape.bounds(core);
    let (mp, kp, np) = (padded.m as u64, padded.k as u64, padded.n as u64);
    let (mu, nu, ku) = (core.mu as u64, core.nu as u64, core.ku as u64);
    let word = cfg.mem.word_bytes() as u64;
    let a_tile = core.a_tile_bytes() as u64;
    let b_tile = core.b_tile_bytes() as u64;
    let c_tile = core.c_tile_bytes() as u64;
    let (at, bt) = (bounds.mt * bounds.kt, bounds.kt * bounds.nt);

    // (a_base, b_base, c_base, per-streamer strides)
    struct S {
        base: u64,
        m: u64,
        n: u64,
        k: u64,
        sp0: u64,
        sp1: u64,
    }
    let (a, b, c) = match layout {
        Layout::RowMajor => {
            let a_base = 0;
            let b_base = mp * kp;
            let c_base = b_base + kp * np;
            (
                S { base: a_base, m: mu * kp, n: 0, k: ku, sp0: word, sp1: kp },
                S { base: b_base, m: 0, n: nu, k: ku * np, sp0: word, sp1: np },
                S { base: c_base, m: 4 * mu * np, n: 4 * nu, k: 0, sp0: word, sp1: 4 * np },
            )
        }
        Layout::TiledContiguous => {
            let a_base = 0;
            let b_base = a_tile * at;
            let c_base = b_base + b_tile * bt;
            (
                S { base: a_base, m: a_tile * bounds.kt, n: 0, k: a_tile, sp0: word, sp1: word * (ku * core.pa_bits as u64 / 8 / word).max(1) },
                S { base: b_base, m: 0, n: b_tile, k: b_tile * bounds.nt, sp0: word, sp1: word * (nu * core.pb_bits as u64 / 8 / word).max(1) },
                S { base: c_base, m: c_tile * bounds.nt, n: c_tile, k: 0, sp0: word, sp1: nu * core.pc_bits as u64 / 8 },
            )
        }
        Layout::TiledInterleaved => {
            let pitch = 2 * a_tile.max(b_tile);
            let a_base = 0;
            let b_base = a_tile.max(b_tile);
            let c_base = pitch * at.max(bt);
            (
                S { base: a_base, m: pitch * bounds.kt, n: 0, k: pitch, sp0: word, sp1: word * (ku * core.pa_bits as u64 / 8 / word).max(1) },
                S { base: b_base, m: 0, n: pitch, k: pitch * bounds.nt, sp0: word, sp1: word * (nu * core.pb_bits as u64 / 8 / word).max(1) },
                S { base: c_base, m: c_tile * bounds.nt, n: c_tile, k: 0, sp0: word, sp1: nu * core.pc_bits as u64 / 8 },
            )
        }
    };

    let csr_writes = vec![
        (CSR_BOUNDS, pack_bounds(bounds)),
        (CSR_A_BASE, a.base as u32),
        (CSR_A_STRIDE_M, a.m as u32),
        (CSR_A_STRIDE_K, a.k as u32),
        (CSR_A_SPATIAL0, a.sp0 as u32),
        (CSR_A_SPATIAL1, a.sp1 as u32),
        (CSR_B_BASE, b.base as u32),
        (CSR_B_STRIDE_N, b.n as u32),
        (CSR_B_STRIDE_K, b.k as u32),
        (CSR_B_SPATIAL0, b.sp0 as u32),
        (CSR_B_SPATIAL1, b.sp1 as u32),
        (CSR_C_BASE, c.base as u32),
        (CSR_C_STRIDE_M, c.m as u32),
        (CSR_C_STRIDE_N, c.n as u32),
        (CSR_C_SPATIAL0, c.sp0 as u32),
        (CSR_C_SPATIAL1, c.sp1 as u32),
    ];

    Placement {
        layout,
        padded,
        bounds,
        a_base: a.base,
        b_base: b.base,
        c_base: c.base,
        csr_writes,
    }
}

// ---------------------------------------------------------------------
// Functional SPM image construction (the DMA's job in the real system)
// ---------------------------------------------------------------------

/// Write operand A (row-major `m x k`, true dims) into the SPM under the
/// placement's layout, zero-padding to the padded dims.
pub fn pack_a(spm: &mut Spm, cfg: &PlatformConfig, p: &Placement, a: &[i8], m: usize, k: usize) {
    assert_eq!(a.len(), m * k, "A size mismatch");
    let core = &cfg.core;
    let (mu, ku) = (core.mu, core.ku);
    let kp = p.padded.k;
    match p.layout {
        Layout::RowMajor => {
            let mut row = vec![0i8; kp];
            for i in 0..p.padded.m {
                row.fill(0);
                if i < m {
                    row[..k].copy_from_slice(&a[i * k..(i + 1) * k]);
                }
                spm.write_i8(p.a_base + (i * kp) as u64, &row);
            }
        }
        Layout::TiledContiguous | Layout::TiledInterleaved => {
            let stride_k = tile_stride_k_a(cfg, p);
            let stride_m = stride_k * p.bounds.kt;
            let mut tile = vec![0i8; mu * ku];
            for m1 in 0..p.bounds.mt as usize {
                for k1 in 0..p.bounds.kt as usize {
                    tile.fill(0);
                    for r in 0..mu {
                        let src_r = m1 * mu + r;
                        if src_r >= m {
                            continue;
                        }
                        for c in 0..ku {
                            let src_c = k1 * ku + c;
                            if src_c < k {
                                tile[r * ku + c] = a[src_r * k + src_c];
                            }
                        }
                    }
                    let addr = p.a_base + stride_m * m1 as u64 + stride_k * k1 as u64;
                    spm.write_i8(addr, &tile);
                }
            }
        }
    }
}

/// Write operand B (row-major `k x n`, true dims) into the SPM.
pub fn pack_b(spm: &mut Spm, cfg: &PlatformConfig, p: &Placement, b: &[i8], k: usize, n: usize) {
    assert_eq!(b.len(), k * n, "B size mismatch");
    let core = &cfg.core;
    let (ku, nu) = (core.ku, core.nu);
    let np = p.padded.n;
    match p.layout {
        Layout::RowMajor => {
            let mut row = vec![0i8; np];
            for i in 0..p.padded.k {
                row.fill(0);
                if i < k {
                    row[..n].copy_from_slice(&b[i * n..(i + 1) * n]);
                }
                spm.write_i8(p.b_base + (i * np) as u64, &row);
            }
        }
        Layout::TiledContiguous | Layout::TiledInterleaved => {
            let stride_n = tile_stride_n_b(cfg, p);
            let stride_k = stride_n * p.bounds.nt;
            let mut tile = vec![0i8; ku * nu];
            for k1 in 0..p.bounds.kt as usize {
                for n1 in 0..p.bounds.nt as usize {
                    tile.fill(0);
                    for r in 0..ku {
                        let src_r = k1 * ku + r;
                        if src_r >= k {
                            continue;
                        }
                        for c in 0..nu {
                            let src_c = n1 * nu + c;
                            if src_c < n {
                                tile[r * nu + c] = b[src_r * n + src_c];
                            }
                        }
                    }
                    let addr = p.b_base + stride_k * k1 as u64 + stride_n * n1 as u64;
                    spm.write_i8(addr, &tile);
                }
            }
        }
    }
}

/// Read result C (true dims `m x n`, row-major) back out of the SPM.
pub fn unpack_c(spm: &Spm, cfg: &PlatformConfig, p: &Placement, m: usize, n: usize) -> Vec<i32> {
    let core = &cfg.core;
    let (mu, nu) = (core.mu, core.nu);
    let np = p.padded.n;
    let mut out = vec![0i32; m * n];
    match p.layout {
        Layout::RowMajor => {
            let mut row = vec![0i32; n];
            for i in 0..m {
                spm.read_i32(p.c_base + 4 * (i * np) as u64, &mut row);
                out[i * n..(i + 1) * n].copy_from_slice(&row);
            }
        }
        Layout::TiledContiguous | Layout::TiledInterleaved => {
            let c_tile = core.c_tile_bytes() as u64;
            let stride_n = c_tile;
            let stride_m = c_tile * p.bounds.nt;
            let mut tile = vec![0i32; mu * nu];
            for m1 in 0..p.bounds.mt as usize {
                for n1 in 0..p.bounds.nt as usize {
                    let addr = p.c_base + stride_m * m1 as u64 + stride_n * n1 as u64;
                    spm.read_i32(addr, &mut tile);
                    for r in 0..mu {
                        let dst_r = m1 * mu + r;
                        if dst_r >= m {
                            continue;
                        }
                        for c in 0..nu {
                            let dst_c = n1 * nu + c;
                            if dst_c < n {
                                out[dst_r * n + dst_c] = tile[r * nu + c];
                            }
                        }
                    }
                }
            }
        }
    }
    out
}

fn tile_stride_k_a(cfg: &PlatformConfig, p: &Placement) -> u64 {
    let a_tile = cfg.core.a_tile_bytes() as u64;
    let b_tile = cfg.core.b_tile_bytes() as u64;
    match p.layout {
        Layout::RowMajor => unreachable!("tiled helper on row-major"),
        Layout::TiledContiguous => a_tile,
        Layout::TiledInterleaved => 2 * a_tile.max(b_tile),
    }
}

fn tile_stride_n_b(cfg: &PlatformConfig, p: &Placement) -> u64 {
    let a_tile = cfg.core.a_tile_bytes() as u64;
    let b_tile = cfg.core.b_tile_bytes() as u64;
    match p.layout {
        Layout::RowMajor => unreachable!("tiled helper on row-major"),
        Layout::TiledContiguous => b_tile,
        Layout::TiledInterleaved => 2 * a_tile.max(b_tile),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PlatformConfig;

    fn cfg() -> PlatformConfig {
        PlatformConfig::case_study()
    }

    fn all_layouts() -> [Layout; 3] {
        [Layout::RowMajor, Layout::TiledContiguous, Layout::TiledInterleaved]
    }

    #[test]
    fn placement_fits_and_regions_disjoint() {
        let cfg = cfg();
        for layout in all_layouts() {
            let p = plan(&cfg, &GemmShape::new(64, 64, 64), layout);
            assert!(p.footprint() <= cfg.mem.capacity_bytes() as u64, "{layout:?}");
            assert!(p.a_base < p.c_base);
            assert!(p.b_base < p.c_base);
        }
    }

    #[test]
    fn interleaved_ab_never_share_bank_group() {
        let cfg = cfg();
        let p = plan(&cfg, &GemmShape::new(64, 64, 64), Layout::TiledInterleaved);
        let regs = p.config_regs();
        let a = regs.a_agu(&cfg.core, 8);
        let b = regs.b_agu(&cfg.core, 8);
        let bounds = p.bounds;
        let mut aw = Vec::new();
        let mut bw = Vec::new();
        // For every temporal position, the 8+8 word addresses must map to
        // 16 distinct banks.
        for pos in 0..bounds.total_tiles() {
            let (m1, n1, k1) = bounds.decompose(pos);
            a.tile_word_addrs(m1, n1, k1, 8, &mut aw);
            b.tile_word_addrs(m1, n1, k1, 8, &mut bw);
            let mut banks: Vec<usize> =
                aw.iter().chain(bw.iter()).map(|&w| (w % 32) as usize).collect();
            banks.sort_unstable();
            banks.dedup();
            assert_eq!(banks.len(), 16, "conflict at {:?}", (m1, n1, k1));
        }
    }

    #[test]
    fn row_major_has_conflicts_for_wide_k() {
        let cfg = cfg();
        // K = 256 -> A tile rows are 32 words apart -> all 8 in one bank
        let p = plan(&cfg, &GemmShape::new(64, 256, 64), Layout::RowMajor);
        let regs = p.config_regs();
        let a = regs.a_agu(&cfg.core, 8);
        let mut aw = Vec::new();
        a.tile_word_addrs(0, 0, 0, 8, &mut aw);
        let banks: std::collections::HashSet<u64> = aw.iter().map(|&w| w % 32).collect();
        assert_eq!(banks.len(), 1, "expected full serialization");
    }

    #[test]
    fn pack_unpack_roundtrip_c_layouts() {
        let cfg = cfg();
        for layout in all_layouts() {
            let shape = GemmShape::new(13, 22, 17);
            let p = plan(&cfg, &shape, layout);
            let mut spm = Spm::new(cfg.mem);
            // write a known C image through the C AGU the way the output
            // streamer would, then unpack
            let regs = p.config_regs();
            let c_agu = regs.c_agu(&cfg.core, 8);
            for m1 in 0..p.bounds.mt {
                for n1 in 0..p.bounds.nt {
                    let tile: Vec<i32> = (0..64)
                        .map(|i| (m1 * 1000 + n1 * 100) as i32 + i)
                        .collect();
                    // write word-by-word through the AGU ports, exactly
                    // like the output streamer's writeback epoch
                    for port in 0..c_agu.ports() as u64 {
                        let byte = c_agu.byte_addr(m1, n1, 0, port);
                        let idx = (port * 2) as usize;
                        spm.write_i32(byte, &tile[idx..idx + 2]);
                    }
                }
            }
            let c = unpack_c(&spm, &cfg, &p, 13, 17);
            // element (i, j) lives in tile (i/8, j/8) at offset (i%8)*8+(j%8)
            for i in 0..13 {
                for j in 0..17 {
                    let expect = ((i / 8) * 1000 + (j / 8) * 100 + (i % 8) * 8 + (j % 8)) as i32;
                    assert_eq!(c[i * 17 + j], expect, "{layout:?} at ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn pack_a_matches_agu_view() {
        let cfg = cfg();
        for layout in all_layouts() {
            let shape = GemmShape::new(20, 30, 10);
            let p = plan(&cfg, &shape, layout);
            let mut spm = Spm::new(cfg.mem);
            let a: Vec<i8> = (0..20 * 30).map(|i| (i % 251) as i8).collect();
            pack_a(&mut spm, &cfg, &p, &a, 20, 30);
            // read every tile through the AGU and check elements
            let regs = p.config_regs();
            let agu = regs.a_agu(&cfg.core, 8);
            let mut tile = vec![0i8; 64];
            for m1 in 0..p.bounds.mt {
                for k1 in 0..p.bounds.kt {
                    // port r reads row r of the tile (8 bytes)
                    for r in 0..8u64 {
                        let byte = agu.byte_addr(m1, 0, k1, r);
                        spm.read_i8(byte, &mut tile[(r as usize) * 8..(r as usize + 1) * 8]);
                    }
                    for r in 0..8usize {
                        for c in 0..8usize {
                            let gr = m1 as usize * 8 + r;
                            let gc = k1 as usize * 8 + c;
                            let expect = if gr < 20 && gc < 30 {
                                ((gr * 30 + gc) % 251) as i8
                            } else {
                                0
                            };
                            assert_eq!(
                                tile[r * 8 + c],
                                expect,
                                "{layout:?} tile ({m1},{k1}) elem ({r},{c})"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn pack_b_matches_agu_view() {
        let cfg = cfg();
        for layout in all_layouts() {
            let shape = GemmShape::new(8, 19, 23);
            let p = plan(&cfg, &shape, layout);
            let mut spm = Spm::new(cfg.mem);
            let b: Vec<i8> = (0..19 * 23).map(|i| ((i * 7) % 127) as i8).collect();
            pack_b(&mut spm, &cfg, &p, &b, 19, 23);
            let regs = p.config_regs();
            let agu = regs.b_agu(&cfg.core, 8);
            let mut tile = vec![0i8; 64];
            for k1 in 0..p.bounds.kt {
                for n1 in 0..p.bounds.nt {
                    for r in 0..8u64 {
                        let byte = agu.byte_addr(0, n1, k1, r);
                        spm.read_i8(byte, &mut tile[(r as usize) * 8..(r as usize + 1) * 8]);
                    }
                    for r in 0..8usize {
                        for c in 0..8usize {
                            let gr = k1 as usize * 8 + r;
                            let gc = n1 as usize * 8 + c;
                            let expect = if gr < 19 && gc < 23 {
                                (((gr * 23 + gc) * 7) % 127) as i8
                            } else {
                                0
                            };
                            assert_eq!(tile[r * 8 + c], expect, "{layout:?} ({k1},{n1})");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn offset_by_relocates_bases_only() {
        let cfg = cfg();
        let base = plan(&cfg, &GemmShape::new(64, 64, 64), Layout::TiledInterleaved);
        let mut moved = base.clone();
        moved.offset_by(0x8000);
        assert_eq!(moved.a_base, base.a_base + 0x8000);
        assert_eq!(moved.b_base, base.b_base + 0x8000);
        assert_eq!(moved.c_base, base.c_base + 0x8000);
        assert_eq!(moved.footprint(), base.footprint() + 0x8000);
        for (&(a0, v0), &(a1, v1)) in base.csr_writes.iter().zip(&moved.csr_writes) {
            assert_eq!(a0, a1, "CSR addresses stay in the canonical window");
            if a0 == CSR_A_BASE || a0 == CSR_B_BASE || a0 == CSR_C_BASE {
                assert_eq!(v1, v0 + 0x8000);
            } else {
                assert_eq!(v1, v0, "non-base register {a0:#x} changed");
            }
        }
        // the AGU view shifts uniformly
        let r0 = base.config_regs();
        let r1 = moved.config_regs();
        let a0 = r0.a_agu(&cfg.core, 8);
        let a1 = r1.a_agu(&cfg.core, 8);
        assert_eq!(a1.base, a0.base + 0x8000);
        assert_eq!(a1.stride_m, a0.stride_m);
    }

    #[test]
    fn csr_write_list_covers_all_config_regs() {
        let cfg = cfg();
        let p = plan(&cfg, &GemmShape::new(8, 8, 8), Layout::TiledInterleaved);
        assert_eq!(p.csr_writes.len(), 16);
        let addrs: std::collections::HashSet<u32> =
            p.csr_writes.iter().map(|&(a, _)| a).collect();
        assert_eq!(addrs.len(), 16, "no duplicate CSR addresses");
    }
}
