//! Static program verifier: legality analysis for compiled tile
//! schedules, CSR configuration programs, and DSE grid points — without
//! running the event engine.
//!
//! The platform's invariants (SPM bounds, word alignment, operand
//! aliasing, complete CSR write sets, CPL chaining, double-buffer
//! hazards) are otherwise enforced only dynamically, by the simulator
//! panicking or silently mis-simulating mid-run. This module checks any
//! [`CompiledJob`] against them in microseconds and produces structured,
//! severity-ranked diagnostics instead:
//!
//! - [`verify_config`] — grid-point legality (the DSE/prefilter entry
//!   point: statically prune illegal variants with a named diagnostic);
//! - [`verify_request`] — config + schedulability + the full job check
//!   for one `(PlatformConfig, JobRequest)` point;
//! - [`verify_job`] — the four analysis passes over an already-compiled
//!   job: SPM legality, CSR program legality, hazard analysis, and
//!   program/schedule consistency.
//!
//! Every finding carries a stable code from [`CATALOG`] (e.g.
//! `A001-spm-oob`), a severity, the offending call/CSR where known, and
//! a one-line fix hint. `coordinator::shard::run_sweep_cached` runs the
//! verifier as a default-on admission gate (`--no-lint` bypasses it),
//! and the `opengemm lint` subcommand reports over every in-repo
//! experiment grid ([`report`] holds the wire format).
//!
//! The SPM pass reuses the exact AGU stride tables the streamers
//! execute ([`AguConfig`] rebuilt from the placement's CSR image via
//! [`ConfigRegs`](crate::csr::ConfigRegs)), and the CSR pass decodes the
//! generated RV32I program with the same encodings `host::encode`
//! emits — the compiler and verifier are mutual regression oracles
//! (pinned by `tests/static_verifier.rs`).

pub mod report;

pub use report::{LintReport, TargetReport, LINT_REPORT_FORMAT};

use std::collections::BTreeMap;

use crate::compiler::{compile_gemm, CompiledCall, CompiledJob};
use crate::config::PlatformConfig;
use crate::coordinator::JobRequest;
use crate::csr::{
    core_csr_base, csr_name, unpack_bounds, CONFIG_CSR_ADDRS, CSR_BASE, CSR_BOUNDS, CSR_COUNT,
    CSR_CTRL, CSR_STATUS, STATUS_BUSY, STATUS_PENDING,
};
use crate::gemm_core::MAX_LOOP_BOUND;
use crate::streamer::{AguConfig, LoopBounds};
use crate::util::json::{self, Json};

// ---------------------------------------------------------------------
// Diagnostic codes (stable: tests and downstream tooling pin them)
// ---------------------------------------------------------------------

/// SPM access outside `[0, capacity)` over the call's loop volume.
pub const SPM_OOB: &str = "A001-spm-oob";
/// AGU base or stride not a multiple of the SPM word size.
pub const SPM_MISALIGNED: &str = "A002-spm-misaligned";
/// A and B operand regions alias each other.
pub const SPM_OVERLAP: &str = "A003-spm-overlap";
/// Launch without a complete (or with a redundant) config write set.
pub const CSR_INCOMPLETE_CONFIG: &str = "A004-csr-incomplete-config";
/// Loop bound outside the encodable range, or repeat count zero, or
/// BOUNDS register inconsistent with the schedule.
pub const LOOP_BOUND_RANGE: &str = "A005-loop-bound-range";
/// CSR access outside the accelerator window, or a write to STATUS.
pub const CSR_BAD_ADDRESS: &str = "A006-csr-bad-address";
/// Launch/poll/drain chaining malformed for the job's CPL mode.
pub const CPL_CHAIN: &str = "A007-cpl-chain";
/// Double-buffer RAW/WAR: output window overlaps an input region.
pub const DOUBLE_BUFFER_HAZARD: &str = "A008-double-buffer-hazard";
/// The request does not schedule onto this platform instance at all.
pub const UNSCHEDULABLE: &str = "A009-unschedulable";
/// The platform config itself fails elaboration-time validation.
pub const CONFIG_INVALID: &str = "A010-config-invalid";
/// A call has fewer tiles than the prefetch pipeline is deep.
pub const UNDERFILLED_PIPELINE: &str = "A011-underfilled-pipeline";
/// The decoded program writes CSR values the schedule disagrees with.
pub const PROGRAM_DIVERGENCE: &str = "A012-program-schedule-divergence";
/// On a multi-core platform, a call's operand regions escape its
/// core's SPM partition into another core's live data.
pub const CROSS_CORE_OVERLAP: &str = "A013-cross-core-spm-overlap";

/// The full diagnostic-code catalog: `(code, one-line description)`.
/// ROADMAP.md's "Static verification" section mirrors this table.
pub const CATALOG: [(&str, &str); 13] = [
    (SPM_OOB, "SPM access outside [0, capacity) over the call's loop volume"),
    (SPM_MISALIGNED, "AGU base or stride not a multiple of the SPM word size"),
    (SPM_OVERLAP, "A and B operand regions alias each other"),
    (CSR_INCOMPLETE_CONFIG, "launch without a complete config write set"),
    (LOOP_BOUND_RANGE, "loop bound or repeat count outside the encodable range"),
    (CSR_BAD_ADDRESS, "CSR access outside the accelerator window"),
    (CPL_CHAIN, "launch/poll/drain chaining malformed for the CPL mode"),
    (DOUBLE_BUFFER_HAZARD, "output streamer window overlaps a live input region"),
    (UNSCHEDULABLE, "request does not schedule onto this platform instance"),
    (CONFIG_INVALID, "platform config fails elaboration-time validation"),
    (UNDERFILLED_PIPELINE, "call has fewer tiles than the prefetch pipeline is deep"),
    (PROGRAM_DIVERGENCE, "decoded program disagrees with the compiled schedule"),
    (CROSS_CORE_OVERLAP, "call's operand regions escape its core's SPM partition"),
];

/// Resolve a code string back to its static catalog entry.
pub fn code_from_name(name: &str) -> Option<&'static str> {
    CATALOG.iter().map(|&(code, _)| code).find(|&code| code == name)
}

// ---------------------------------------------------------------------
// Diagnostics
// ---------------------------------------------------------------------

/// Finding severity. `Error` findings make a job inadmissible; `Warn`
/// findings are conservative (the analysis could not prove legality);
/// `Info` findings are performance/structure notes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Info,
    Warn,
    Error,
}

impl Severity {
    pub fn name(self) -> &'static str {
        match self {
            Severity::Info => "info",
            Severity::Warn => "warn",
            Severity::Error => "error",
        }
    }

    pub fn from_name(name: &str) -> Option<Severity> {
        match name {
            "info" => Some(Severity::Info),
            "warn" => Some(Severity::Warn),
            "error" => Some(Severity::Error),
            _ => None,
        }
    }
}

/// One verifier finding: a stable code, a severity, the offending
/// call/CSR where the finding is that specific, and a one-line hint.
#[derive(Debug, Clone, PartialEq)]
pub struct Diagnostic {
    pub code: &'static str,
    pub severity: Severity,
    /// Offending call index within the compiled schedule, if per-call.
    pub call: Option<usize>,
    /// Offending CSR address, if per-CSR.
    pub csr: Option<u32>,
    pub message: String,
    pub hint: String,
}

impl Diagnostic {
    fn new(
        code: &'static str,
        severity: Severity,
        message: impl Into<String>,
        hint: impl Into<String>,
    ) -> Diagnostic {
        Diagnostic {
            code,
            severity,
            call: None,
            csr: None,
            message: message.into(),
            hint: hint.into(),
        }
    }

    fn at_call(mut self, call: usize) -> Diagnostic {
        self.call = Some(call);
        self
    }

    fn at_csr(mut self, csr: u32) -> Diagnostic {
        self.csr = Some(csr);
        self
    }

    /// One-line rendering: `[code] severity: message (hint)`.
    pub fn render(&self) -> String {
        let wh = match self.call {
            Some(c) => format!(" call {c}:"),
            None => String::new(),
        };
        format!(
            "[{}] {}:{wh} {} (hint: {})",
            self.code,
            self.severity.name(),
            self.message,
            self.hint
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("code", Json::str(self.code)),
            ("severity", Json::str(self.severity.name())),
            (
                "call",
                match self.call {
                    Some(c) => Json::num(c as f64),
                    None => Json::Null,
                },
            ),
            (
                "csr",
                match self.csr {
                    Some(c) => Json::num(c as f64),
                    None => Json::Null,
                },
            ),
            ("message", Json::str(&self.message)),
            ("hint", Json::str(&self.hint)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Diagnostic, String> {
        let code_name = json::get_str(v, "code")?;
        let code = code_from_name(code_name)
            .ok_or_else(|| format!("unknown diagnostic code {code_name:?}"))?;
        let severity_name = json::get_str(v, "severity")?;
        let severity = Severity::from_name(severity_name)
            .ok_or_else(|| format!("unknown severity {severity_name:?}"))?;
        let opt_num = |key: &str| -> Result<Option<u64>, String> {
            match v.get(key) {
                None | Some(Json::Null) => Ok(None),
                Some(x) => x
                    .as_u64()
                    .map(Some)
                    .ok_or_else(|| format!("diagnostic field {key:?} is not an integer")),
            }
        };
        Ok(Diagnostic {
            code,
            severity,
            call: opt_num("call")?.map(|c| c as usize),
            csr: opt_num("csr")?.map(|c| c as u32),
            message: json::get_str(v, "message")?.to_string(),
            hint: json::get_str(v, "hint")?.to_string(),
        })
    }
}

/// Sort findings for reporting: errors first, then by call (job-level
/// findings lead), code, and message — a total, deterministic order.
pub fn sort_diagnostics(diags: &mut [Diagnostic]) {
    diags.sort_by(|a, b| {
        let call = |d: &Diagnostic| d.call.map_or(-1i64, |c| c as i64);
        b.severity
            .cmp(&a.severity)
            .then(call(a).cmp(&call(b)))
            .then(a.code.cmp(b.code))
            .then(a.message.cmp(&b.message))
    });
}

/// Whether any finding is an error (the admission-gate predicate).
pub fn has_errors(diags: &[Diagnostic]) -> bool {
    diags.iter().any(|d| d.severity == Severity::Error)
}

/// The most severe error finding, if any (diags need not be sorted).
pub fn first_error(diags: &[Diagnostic]) -> Option<&Diagnostic> {
    diags.iter().find(|d| d.severity == Severity::Error)
}

// ---------------------------------------------------------------------
// Entry points
// ---------------------------------------------------------------------

/// Pass 4 — config legality for DSE grids: is this platform instance
/// elaborable at all? The prefilter calls this per grid variant and
/// reports `statically_rejected` instead of simulating the point.
pub fn verify_config(cfg: &PlatformConfig) -> Vec<Diagnostic> {
    match cfg.validate() {
        Ok(()) => Vec::new(),
        Err(e) => vec![Diagnostic::new(
            CONFIG_INVALID,
            Severity::Error,
            format!("platform config fails elaboration: {}", e.0),
            "fix the named structural parameter before sweeping this grid point",
        )],
    }
}

/// Verify one `(config, request)` grid point: config legality, then
/// schedulability, then the full compiled-job check.
pub fn verify_request(cfg: &PlatformConfig, request: &JobRequest) -> Vec<Diagnostic> {
    let mut diags = verify_config(cfg);
    if has_errors(&diags) {
        return diags;
    }
    let s = request.shape;
    match compile_gemm(
        cfg,
        s,
        request.layout,
        request.repeats,
        request.mechanisms.config_preloading,
    ) {
        Err(e) => diags.push(Diagnostic::new(
            UNSCHEDULABLE,
            Severity::Error,
            format!("shape {}x{}x{} does not schedule: {}", s.m, s.k, s.n, e.0),
            "shrink the shape or grow the SPM so a capacity split exists",
        )),
        Ok(job) => diags.extend(verify_job(cfg, &job)),
    }
    sort_diagnostics(&mut diags);
    diags
}

/// Verify a compiled job: SPM legality (pass 1), CSR program legality
/// (pass 2), and hazard analysis (pass 3). Returns findings sorted
/// errors-first; an empty vector means the job is provably legal.
pub fn verify_job(cfg: &PlatformConfig, job: &CompiledJob) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    if job.repeats == 0 {
        diags.push(Diagnostic::new(
            LOOP_BOUND_RANGE,
            Severity::Error,
            "repeat count 0 compiles to a non-terminating host repeat loop".to_string(),
            "request at least one repeat",
        ));
    }
    let mut regions = Vec::with_capacity(job.calls.len());
    for (ci, call) in job.calls.iter().enumerate() {
        check_bounds(ci, call, &mut diags);
        regions.push(check_spm(cfg, ci, call, &mut diags));
    }
    check_hazards(cfg, job, &regions, &mut diags);
    check_partitions(cfg, job, &regions, &mut diags);
    check_program(job, &mut diags);
    sort_diagnostics(&mut diags);
    diags
}

// ---------------------------------------------------------------------
// Pass 1 — SPM legality (bounds, alignment, aliasing)
// ---------------------------------------------------------------------

/// Address-enumeration budget per call (word visits). Every real
/// placement sits far under it; the cap only exists so a pathological
/// hand-built schedule degrades to the conservative interval check
/// (reported at `Warn`) instead of an unbounded walk.
const OVERLAP_VISIT_BUDGET: u64 = 1 << 22;

/// One operand's touched SPM region: its byte interval, plus the exact
/// word set when enumeration was legal and within budget.
struct OperandRegion {
    name: &'static str,
    /// Lowest touched byte (may be negative for a broken schedule).
    lo: i64,
    /// One past the highest touched byte.
    hi: i64,
    /// Exact word-index bitset over the SPM, when available.
    words: Option<Vec<u64>>,
}

struct CallRegions {
    a: OperandRegion,
    b: OperandRegion,
    c: OperandRegion,
}

fn check_bounds(ci: usize, call: &CompiledCall, diags: &mut Vec<Diagnostic>) {
    let b = call.placement.bounds;
    let mut in_range = true;
    for (name, v) in [("Mt", b.mt), ("Nt", b.nt), ("Kt", b.kt)] {
        if v < 1 || v > MAX_LOOP_BOUND {
            diags.push(
                Diagnostic::new(
                    LOOP_BOUND_RANGE,
                    Severity::Error,
                    format!("loop bound {name} = {v} outside the encodable range 1..={MAX_LOOP_BOUND}"),
                    "split the call further; BOUNDS packs 10-bit fields",
                )
                .at_call(ci)
                .at_csr(CSR_BOUNDS),
            );
            in_range = false;
        }
    }
    if !in_range {
        return;
    }
    if let Some(&(_, packed)) = call.placement.csr_writes.iter().find(|&&(a, _)| a == CSR_BOUNDS) {
        let decoded = unpack_bounds(packed);
        if decoded != b {
            diags.push(
                Diagnostic::new(
                    LOOP_BOUND_RANGE,
                    Severity::Error,
                    format!(
                        "BOUNDS register encodes (Mt,Nt,Kt) = ({},{},{}), the schedule iterates ({},{},{})",
                        decoded.mt, decoded.nt, decoded.kt, b.mt, b.nt, b.kt
                    ),
                    "re-pack BOUNDS from the placement's loop bounds",
                )
                .at_call(ci)
                .at_csr(CSR_BOUNDS),
            );
        }
    }
}

fn check_spm(
    cfg: &PlatformConfig,
    ci: usize,
    call: &CompiledCall,
    diags: &mut Vec<Diagnostic>,
) -> CallRegions {
    let word = cfg.mem.word_bytes();
    let regs = call.placement.config_regs();
    let bounds = call.placement.bounds;
    let mut budget = OVERLAP_VISIT_BUDGET;
    let a = operand_region(cfg, ci, "A", &regs.a_agu(&cfg.core, word), bounds, &mut budget, diags);
    let b = operand_region(cfg, ci, "B", &regs.b_agu(&cfg.core, word), bounds, &mut budget, diags);
    let c = operand_region(cfg, ci, "C", &regs.c_agu(&cfg.core, word), bounds, &mut budget, diags);

    // A/B aliasing: the input streamers walk both regions concurrently
    // every tile; any shared word reads the wrong operand.
    match overlap_evidence(&a, &b) {
        Some(OverlapEvidence::Exact(word_idx)) => diags.push(
            Diagnostic::new(
                SPM_OVERLAP,
                Severity::Error,
                format!(
                    "A and B operand regions alias: both touch SPM word {word_idx} (byte {:#x})",
                    word_idx * word as u64
                ),
                "give A and B disjoint base addresses (see compiler::layout::plan)",
            )
            .at_call(ci),
        ),
        Some(OverlapEvidence::Interval(byte)) => diags.push(
            Diagnostic::new(
                SPM_OVERLAP,
                Severity::Warn,
                format!(
                    "A and B byte intervals overlap near byte {byte:#x} \
                     (exact word walk skipped; cannot prove disjointness)"
                ),
                "give A and B disjoint byte intervals, or shrink the loop volume",
            )
            .at_call(ci),
        ),
        None => {}
    }
    CallRegions { a, b, c }
}

#[allow(clippy::too_many_arguments)]
fn operand_region(
    cfg: &PlatformConfig,
    ci: usize,
    name: &'static str,
    agu: &AguConfig,
    bounds: LoopBounds,
    budget: &mut u64,
    diags: &mut Vec<Diagnostic>,
) -> OperandRegion {
    let word = cfg.mem.word_bytes() as i64;
    let cap = cfg.mem.capacity_bytes() as i64;

    // Word alignment: the same conditions under which the streamer's
    // precomputed bank pattern is exact (AguConfig::bank_pattern).
    let fields = [
        ("base", agu.base as i64),
        ("stride_m", agu.stride_m),
        ("stride_n", agu.stride_n),
        ("stride_k", agu.stride_k),
        ("spatial0_stride", agu.spatial0_stride),
        ("spatial1_stride", agu.spatial1_stride),
    ];
    let misaligned: Vec<&str> =
        fields.iter().filter(|&&(_, v)| v % word != 0).map(|&(f, _)| f).collect();
    if !misaligned.is_empty() {
        diags.push(
            Diagnostic::new(
                SPM_MISALIGNED,
                Severity::Error,
                format!(
                    "{name} streamer address pattern is not word-aligned: {} not a multiple of \
                     the {word}-byte SPM word",
                    misaligned.join(", ")
                ),
                "make every base and stride a word multiple so each port access is one bank word",
            )
            .at_call(ci),
        );
    }

    let lo = agu.min_byte_addr(bounds.mt, bounds.nt, bounds.kt);
    let hi = agu.max_byte_addr(bounds.mt, bounds.nt, bounds.kt) as i64 + word;
    let mut legal = misaligned.is_empty();
    if lo < 0 {
        diags.push(
            Diagnostic::new(
                SPM_OOB,
                Severity::Error,
                format!("{name} region reaches byte {lo} below SPM address zero"),
                "raise the base address or drop the negative stride",
            )
            .at_call(ci),
        );
        legal = false;
    } else if hi > cap {
        diags.push(
            Diagnostic::new(
                SPM_OOB,
                Severity::Error,
                format!("{name} region ends at byte {hi:#x}, SPM capacity is {cap:#x}"),
                "lower the base address or split the call over a smaller loop volume",
            )
            .at_call(ci),
        );
        legal = false;
    }

    let words = if legal {
        enumerate_words(agu, bounds, word as u64, (cap / word) as u64, budget)
    } else {
        None
    };
    OperandRegion { name, lo, hi, words }
}

/// Exact word-set enumeration of one operand over the call's effective
/// loop volume (a zero-stride dimension contributes one step — the
/// streamer re-reads the same words there). `None` when the walk would
/// exceed the remaining visit budget.
fn enumerate_words(
    agu: &AguConfig,
    bounds: LoopBounds,
    word_bytes: u64,
    cap_words: u64,
    budget: &mut u64,
) -> Option<Vec<u64>> {
    let eff = |bound: u64, stride: i64| if stride == 0 { 1 } else { bound };
    let (em, en, ek) = (
        eff(bounds.mt, agu.stride_m),
        eff(bounds.nt, agu.stride_n),
        eff(bounds.kt, agu.stride_k),
    );
    let visits = em
        .checked_mul(en)
        .and_then(|v| v.checked_mul(ek))
        .and_then(|v| v.checked_mul(agu.ports() as u64))?;
    if visits > *budget {
        return None;
    }
    *budget -= visits;
    let mut bits = vec![0u64; cap_words.div_ceil(64) as usize];
    let mut addrs = Vec::with_capacity(agu.ports());
    for m1 in 0..em {
        for n1 in 0..en {
            for k1 in 0..ek {
                agu.tile_word_addrs(m1, n1, k1, word_bytes, &mut addrs);
                for &w in &addrs {
                    if w < cap_words {
                        bits[(w / 64) as usize] |= 1u64 << (w % 64);
                    }
                }
            }
        }
    }
    Some(bits)
}

enum OverlapEvidence {
    /// Both word sets were exact: the first shared word index.
    Exact(u64),
    /// Interval-level overlap only (a walk was skipped): a byte inside
    /// the shared interval.
    Interval(i64),
}

fn overlap_evidence(x: &OperandRegion, y: &OperandRegion) -> Option<OverlapEvidence> {
    if let (Some(a), Some(b)) = (&x.words, &y.words) {
        for (i, (wa, wb)) in a.iter().zip(b.iter()).enumerate() {
            let both = wa & wb;
            if both != 0 {
                return Some(OverlapEvidence::Exact(i as u64 * 64 + both.trailing_zeros() as u64));
            }
        }
        return None;
    }
    if x.lo < y.hi && y.lo < x.hi {
        return Some(OverlapEvidence::Interval(x.lo.max(y.lo)));
    }
    None
}

fn intervals_overlap(x: &OperandRegion, y: &OperandRegion) -> bool {
    x.lo < y.hi && y.lo < x.hi
}

// ---------------------------------------------------------------------
// Pass 3 — hazard analysis (double-buffer RAW/WAR windows)
// ---------------------------------------------------------------------

fn check_hazards(
    cfg: &PlatformConfig,
    job: &CompiledJob,
    regions: &[CallRegions],
    diags: &mut Vec<Diagnostic>,
) {
    // Within a call, the d_stream-deep input prefetch reads A/B tiles
    // while the output buffer drains C words of earlier tiles — the
    // windows the Fig. 5 prefetch/output-buffering mechanism overlaps.
    // If C shares any word with a live input region, that overlap is a
    // RAW/WAR hazard, not a buffering win.
    for (ci, r) in regions.iter().enumerate() {
        for input in [&r.a, &r.b] {
            match overlap_evidence(&r.c, input) {
                Some(OverlapEvidence::Exact(word_idx)) => diags.push(
                    Diagnostic::new(
                        DOUBLE_BUFFER_HAZARD,
                        Severity::Error,
                        format!(
                            "output streamer window (C) overwrites live input region {} at \
                             SPM word {word_idx} while the prefetcher still reads it",
                            input.name
                        ),
                        "place c_base above the input regions; the prefetch and writeback \
                         windows overlap in time by design",
                    )
                    .at_call(ci),
                ),
                Some(OverlapEvidence::Interval(byte)) => diags.push(
                    Diagnostic::new(
                        DOUBLE_BUFFER_HAZARD,
                        Severity::Warn,
                        format!(
                            "C and {} byte intervals overlap near byte {byte:#x} \
                             (exact word walk skipped; cannot prove the windows disjoint)",
                            input.name
                        ),
                        "separate the C interval from the inputs, or shrink the loop volume",
                    )
                    .at_call(ci),
                ),
                None => {}
            }
        }
    }

    // Across calls (and across the repeat wrap), the next call's input
    // load reuses bytes the previous call's C window wrote. That refill
    // serializes on the DMA between launches, so it is a note, not a
    // hazard — but it marks where back-to-back CPL launches cannot
    // overlap data movement.
    let n = regions.len();
    if n > 0 {
        let wrap = job.repeats > 1 || (job.cpl && n > 1);
        let transitions = if wrap { n } else { n.saturating_sub(1) };
        let mut refills = 0usize;
        for i in 0..transitions {
            let next = &regions[(i + 1) % n];
            let c = &regions[i].c;
            if intervals_overlap(c, &next.a) || intervals_overlap(c, &next.b) {
                refills += 1;
            }
        }
        if refills > 0 {
            diags.push(Diagnostic::new(
                UNDERFILLED_PIPELINE,
                Severity::Info,
                format!(
                    "{refills} of {transitions} call transitions reload input bytes the previous \
                     call's output window wrote (the inter-call refill serializes there)"
                ),
                "expected for capacity-split jobs; irrelevant to single-call schedules",
            ));
        }
    }

    // Underfilled prefetch pipeline: a call with fewer tiles than the
    // buffer is deep never reaches steady state (small-shape cliff).
    let depth = cfg.mem.d_stream as u64;
    let shallow: Vec<usize> = job
        .calls
        .iter()
        .enumerate()
        .filter(|(_, call)| call.placement.bounds.total_tiles() < depth)
        .map(|(i, _)| i)
        .collect();
    if let Some(&first) = shallow.first() {
        diags.push(
            Diagnostic::new(
                UNDERFILLED_PIPELINE,
                Severity::Info,
                format!(
                    "{} call(s) iterate fewer than d_stream = {depth} tiles; the prefetch \
                     pipeline never fills",
                    shallow.len()
                ),
                "expected for small shapes; utilization is bounded by pipeline fill",
            )
            .at_call(first),
        );
    }
}

// ---------------------------------------------------------------------
// Pass 3b — multi-core partition confinement (A013)
// ---------------------------------------------------------------------

/// On a multi-core platform every call runs on core `ci % cores`
/// inside that core's SPM partition, concurrently with calls on every
/// other core. A region that escapes its partition can alias another
/// core's *live* operands — unlike the intra-call overlaps of pass 3,
/// there is no launch ordering to serialize the accesses, so any
/// escape is an error.
fn check_partitions(
    cfg: &PlatformConfig,
    job: &CompiledJob,
    regions: &[CallRegions],
    diags: &mut Vec<Diagnostic>,
) {
    let cores = job.cores.max(1);
    if cores <= 1 {
        return;
    }
    let partition = cfg.spm_partition_bytes() as i64;
    for (ci, r) in regions.iter().enumerate() {
        let core = ci % cores;
        let (lo, hi) = (core as i64 * partition, (core as i64 + 1) * partition);
        for region in [&r.a, &r.b, &r.c] {
            // regions already flagged oob (lo<0 / hi>cap) still get
            // attributed here when they cross a partition boundary —
            // both findings are real
            if region.lo < lo || region.hi > hi {
                diags.push(
                    Diagnostic::new(
                        CROSS_CORE_OVERLAP,
                        Severity::Error,
                        format!(
                            "{} region [{:#x}, {:#x}) escapes core {core}'s SPM partition \
                             [{lo:#x}, {hi:#x}); cores run concurrently, so this aliases \
                             another core's live data",
                            region.name, region.lo, region.hi
                        ),
                        "offset the placement by core * spm_partition_bytes() \
                         (see compiler::compile_gemm's round-robin dispatch)",
                    )
                    .at_call(ci),
                );
            }
        }
    }
}

// ---------------------------------------------------------------------
// Pass 2 — CSR program legality (decode the generated RV32I program)
// ---------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Event {
    /// Write of a config CSR (absolute address, i.e. including the
    /// core-window offset), with the value when statically known.
    Config { csr: u32, value: Option<u32> },
    /// CTRL write with bit 0 set: an accelerator launch. `csr` is the
    /// absolute CTRL address, which names the launched core's window.
    Launch { csr: u32 },
    /// STATUS read immediately masked with `andi`: a poll loop head.
    /// `csr` is the absolute STATUS address (names the polled core).
    Poll { csr: u32, mask: u32 },
    Ebreak,
}

/// Whether `csr` falls inside any of the platform's `cores` CSR
/// windows (window `k` spans `core_csr_base(k) .. + CSR_COUNT`; the
/// windows are contiguous).
fn csr_mapped(csr: u32, cores: usize) -> bool {
    (CSR_BASE..CSR_BASE + (cores * CSR_COUNT) as u32).contains(&csr)
}

/// The register's offset inside its core window (callers guarantee
/// `csr_mapped`). `CSR_STATUS - CSR_BASE` names any core's STATUS.
fn window_rel(csr: u32) -> u32 {
    (csr - CSR_BASE) % CSR_COUNT as u32
}

fn bad_csr(csr: u32, cores: usize) -> Diagnostic {
    Diagnostic::new(
        CSR_BAD_ADDRESS,
        Severity::Error,
        format!("program accesses CSR {csr:#x} outside the accelerator window(s)"),
        format!(
            "accelerator CSRs live at {CSR_BASE:#x}..{:#x} ({cores} core window(s))",
            CSR_BASE + (cores * CSR_COUNT) as u32
        ),
    )
    .at_csr(csr)
}

fn record_csr_write(
    csr: u32,
    value: Option<u32>,
    cores: usize,
    events: &mut Vec<Event>,
    diags: &mut Vec<Diagnostic>,
) {
    if !csr_mapped(csr, cores) {
        diags.push(bad_csr(csr, cores));
        return;
    }
    if window_rel(csr) == CSR_STATUS - CSR_BASE {
        diags.push(
            Diagnostic::new(
                CSR_BAD_ADDRESS,
                Severity::Error,
                "program writes the read-only STATUS register".to_string(),
                "poll STATUS with csrrs; only CTRL accepts commands",
            )
            .at_csr(csr),
        );
        return;
    }
    if window_rel(csr) == CSR_CTRL - CSR_BASE {
        match value {
            Some(v) if v & 1 == 1 => events.push(Event::Launch { csr }),
            Some(_) => {} // no-op control write
            None => diags.push(
                Diagnostic::new(
                    CPL_CHAIN,
                    Severity::Warn,
                    "CTRL written with a value the verifier cannot resolve; \
                     launch chaining is unverifiable"
                        .to_string(),
                    "launch with csrrwi CTRL, 1 (an immediate the verifier can follow)",
                )
                .at_csr(csr),
            ),
        }
        return;
    }
    events.push(Event::Config { csr, value });
}

/// Linear abstract interpretation of the host program: track
/// statically-known register values (x0 is hardwired), record every
/// CSR-visible event in order, stop at `ebreak`. Branches are not
/// followed — the generator emits one repeat body in straight-line
/// order, which is exactly the per-repeat event sequence.
fn decode_events(program: &[u32], cores: usize, diags: &mut Vec<Diagnostic>) -> Vec<Event> {
    let mut regs: [Option<u32>; 32] = [None; 32];
    regs[0] = Some(0);
    let mut events = Vec::new();
    // a STATUS read waiting for its andi: (destination reg, STATUS addr)
    let mut pending_poll: Option<(usize, u32)> = None;
    for &w in program {
        let poll_reg = pending_poll.take();
        let opcode = w & 0x7f;
        let rd = ((w >> 7) & 0x1f) as usize;
        let rs1 = ((w >> 15) & 0x1f) as usize;
        let funct3 = (w >> 12) & 0x7;
        match opcode {
            // OP-IMM: addi carries li/loop arithmetic, andi the poll mask
            0x13 => {
                let imm = (w as i32) >> 20;
                let new = match funct3 {
                    0x0 => regs[rs1].map(|v| v.wrapping_add(imm as u32)),
                    0x7 => {
                        if let Some((preg, csr)) = poll_reg {
                            if preg == rs1 && rd == rs1 {
                                events.push(Event::Poll { csr, mask: imm as u32 });
                            }
                        }
                        regs[rs1].map(|v| v & imm as u32)
                    }
                    _ => None,
                };
                if rd != 0 {
                    regs[rd] = new;
                }
            }
            // lui: the high half of a li expansion
            0x37 => {
                if rd != 0 {
                    regs[rd] = Some(w & 0xffff_f000);
                }
            }
            // SYSTEM: csr ops and ebreak
            0x73 => {
                if w == 0x0010_0073 {
                    events.push(Event::Ebreak);
                    break;
                }
                let csr = (w >> 20) & 0xfff;
                match funct3 {
                    // csrrw: write the rs1 value
                    0x1 => record_csr_write(csr, regs[rs1], cores, &mut events, diags),
                    // csrrwi: write the 5-bit immediate
                    0x5 => record_csr_write(csr, Some(rs1 as u32), cores, &mut events, diags),
                    // csrrs/csrrc: pure read when rs1 = x0, else a
                    // read-modify-write with unverifiable bits
                    0x2 | 0x3 => {
                        if !csr_mapped(csr, cores) {
                            diags.push(bad_csr(csr, cores));
                        } else if rs1 != 0 {
                            record_csr_write(csr, None, cores, &mut events, diags);
                        } else if window_rel(csr) == CSR_STATUS - CSR_BASE {
                            pending_poll = Some((rd, csr));
                        }
                    }
                    _ => {}
                }
                if rd != 0 {
                    regs[rd] = None;
                }
            }
            // branches write no register
            0x63 => {}
            // every other writing instruction clobbers rd with an
            // unknown value (conservative)
            _ => {
                if rd != 0 {
                    regs[rd] = None;
                }
            }
        }
    }
    events
}

fn check_program(job: &CompiledJob, diags: &mut Vec<Diagnostic>) {
    let cores = job.cores.max(1);
    let events = decode_events(&job.program, cores, diags);
    let launches: Vec<(usize, u32)> = events
        .iter()
        .enumerate()
        .filter_map(|(i, e)| match e {
            Event::Launch { csr } => Some((i, *csr)),
            _ => None,
        })
        .collect();
    if launches.len() != job.calls.len() {
        diags.push(Diagnostic::new(
            CPL_CHAIN,
            Severity::Error,
            format!(
                "host program launches {} accelerator run(s) per repeat, the schedule has {} \
                 call(s)",
                launches.len(),
                job.calls.len()
            ),
            "regenerate the program with compiler::gen_config_program over the full call list",
        ));
        return; // window partitioning below would misattribute findings
    }

    let expected_mask = if job.cpl { STATUS_PENDING } else { STATUS_BUSY };
    let mut start = 0usize;
    for (ci, &(lpos, launch_csr)) in launches.iter().enumerate() {
        // round-robin dispatch: launch ci must pulse CTRL in core
        // (ci % cores)'s window
        let win = core_csr_base(ci % cores) - CSR_BASE;
        if launch_csr != CSR_CTRL + win {
            diags.push(
                Diagnostic::new(
                    PROGRAM_DIVERGENCE,
                    Severity::Error,
                    format!(
                        "launch {ci} pulses CTRL at {launch_csr:#x}; the round-robin schedule \
                         dispatches call {ci} to core {} (CTRL {:#x})",
                        ci % cores,
                        CSR_CTRL + win
                    ),
                    "regenerate the program so call i launches core i % cores",
                )
                .at_call(ci)
                .at_csr(launch_csr),
            );
        }
        let window = &events[start..lpos];
        check_launch_window(job, ci, window, expected_mask, win, diags);
        start = lpos + 1;
    }

    // The tail must drain EVERY core (poll its STATUS until neither
    // busy nor pending) and halt — otherwise the host returns while an
    // accelerator core still runs.
    let tail = &events[start..];
    for core in 0..cores {
        let status = CSR_STATUS + (core_csr_base(core) - CSR_BASE);
        let drained = tail.iter().any(|e| {
            matches!(e, Event::Poll { csr, mask }
                     if *csr == status && *mask == STATUS_BUSY | STATUS_PENDING)
        });
        if !drained {
            diags.push(
                Diagnostic::new(
                    CPL_CHAIN,
                    Severity::Error,
                    format!(
                        "program ends without draining core {core} \
                         (no final poll on its busy|pending)"
                    ),
                    "poll every core's STATUS for busy|pending == 0 after the last launch",
                )
                .at_csr(status),
            );
        }
    }
    if !tail.iter().any(|e| matches!(e, Event::Ebreak)) {
        diags.push(Diagnostic::new(
            CPL_CHAIN,
            Severity::Error,
            "program does not terminate with ebreak".to_string(),
            "end the host program with ebreak so the simulator observes completion",
        ));
    }
}

fn check_launch_window(
    job: &CompiledJob,
    ci: usize,
    window: &[Event],
    expected_mask: u32,
    win: u32,
    diags: &mut Vec<Diagnostic>,
) {
    // Chaining: every launch waits for the previous run ON ITS CORE
    // (busy without CPL; the pre-load slot — pending — with CPL).
    // Polls of other cores' STATUS inside this window belong to their
    // own calls and are ignored here.
    let status = CSR_STATUS + win;
    let polls: Vec<u32> = window
        .iter()
        .filter_map(|e| match e {
            Event::Poll { csr, mask } if *csr == status => Some(*mask),
            _ => None,
        })
        .collect();
    if polls.is_empty() {
        diags.push(
            Diagnostic::new(
                CPL_CHAIN,
                Severity::Error,
                "launch is not preceded by a status poll".to_string(),
                format!(
                    "poll STATUS on mask {expected_mask:#x} before launching ({} mode)",
                    if job.cpl { "CPL" } else { "blocking" }
                ),
            )
            .at_call(ci),
        );
    } else if !polls.contains(&expected_mask) {
        diags.push(
            Diagnostic::new(
                CPL_CHAIN,
                Severity::Error,
                format!(
                    "status poll waits on mask {:#x}; {} chaining requires {expected_mask:#x}",
                    polls[0],
                    if job.cpl { "CPL" } else { "blocking" }
                ),
                "with CPL poll start-pending (bit 1); without it poll busy (bit 0)",
            )
            .at_call(ci),
        );
    }

    // Completeness: a launch consumes the full staging bank; every
    // config CSR of THIS call's core window must have been written
    // since the previous launch. Keys are normalized back to canonical
    // (window-0) addresses so the placement comparison below — whose
    // CSR image is canonical by construction — stays address-stable.
    let mut written: BTreeMap<u32, Vec<Option<u32>>> = BTreeMap::new();
    for e in window {
        if let Event::Config { csr, value } = e {
            if (CSR_BASE + win..CSR_BASE + win + CSR_COUNT as u32).contains(csr) {
                written.entry(*csr - win).or_default().push(*value);
            }
        }
    }
    let missing: Vec<&str> = CONFIG_CSR_ADDRS
        .iter()
        .filter(|a| !written.contains_key(a))
        .map(|&a| csr_name(a))
        .collect();
    if let Some(&first) = missing.first() {
        diags.push(
            Diagnostic::new(
                CSR_INCOMPLETE_CONFIG,
                Severity::Error,
                format!(
                    "launch without a complete config write set: {} register(s) missing ({})",
                    missing.len(),
                    missing.join(", ")
                ),
                format!("write {first} (and every other config CSR) before the launch"),
            )
            .at_call(ci),
        );
    }
    for (&csr, writes) in &written {
        if writes.len() > 1 {
            diags.push(
                Diagnostic::new(
                    CSR_INCOMPLETE_CONFIG,
                    Severity::Warn,
                    format!(
                        "{} written {} times before one launch; only the last value lands",
                        csr_name(csr),
                        writes.len()
                    ),
                    "drop the redundant writes to save configuration cycles",
                )
                .at_call(ci)
                .at_csr(csr),
            );
        }
    }

    // Consistency: where the decoded value is statically known, it must
    // equal what the schedule's placement planned.
    for &(csr, want) in &job.calls[ci].placement.csr_writes {
        if let Some(&Some(got)) = written.get(&csr).and_then(|w| w.last()) {
            if got != want {
                diags.push(
                    Diagnostic::new(
                        PROGRAM_DIVERGENCE,
                        Severity::Error,
                        format!(
                            "program writes {} = {got:#x}, the compiled schedule says {want:#x}",
                            csr_name(csr)
                        ),
                        "regenerate the program from the placement's CSR image",
                    )
                    .at_call(ci)
                    .at_csr(csr),
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{GemmShape, Layout};
    use crate::config::Mechanisms;

    fn cfg() -> PlatformConfig {
        PlatformConfig::case_study()
    }

    #[test]
    fn compiled_jobs_verify_clean() {
        let cfg = cfg();
        for layout in [Layout::RowMajor, Layout::TiledContiguous, Layout::TiledInterleaved] {
            for cpl in [false, true] {
                let job = compile_gemm(&cfg, GemmShape::new(64, 64, 64), layout, 10, cpl).unwrap();
                let diags = verify_job(&cfg, &job);
                assert!(!has_errors(&diags), "{layout:?} cpl={cpl}: {diags:?}");
            }
        }
    }

    #[test]
    fn catalog_codes_resolve_and_are_unique() {
        let mut seen = std::collections::BTreeSet::new();
        for (code, _) in CATALOG {
            assert_eq!(code_from_name(code), Some(code));
            assert!(seen.insert(code), "duplicate code {code}");
        }
        assert_eq!(code_from_name("A999-nope"), None);
    }

    #[test]
    fn severity_orders_error_first() {
        let mut diags = vec![
            Diagnostic::new(UNDERFILLED_PIPELINE, Severity::Info, "i", "h"),
            Diagnostic::new(SPM_OOB, Severity::Error, "e", "h"),
            Diagnostic::new(SPM_OVERLAP, Severity::Warn, "w", "h"),
        ];
        sort_diagnostics(&mut diags);
        let sevs: Vec<Severity> = diags.iter().map(|d| d.severity).collect();
        assert_eq!(sevs, vec![Severity::Error, Severity::Warn, Severity::Info]);
        assert_eq!(first_error(&diags).unwrap().code, SPM_OOB);
    }

    #[test]
    fn diagnostic_json_roundtrip() {
        let d = Diagnostic::new(SPM_OOB, Severity::Error, "msg", "hint").at_call(3).at_csr(0x3c1);
        let back = Diagnostic::from_json(&d.to_json()).unwrap();
        assert_eq!(back, d);
        let jobless = Diagnostic::new(CONFIG_INVALID, Severity::Error, "m", "h");
        assert_eq!(Diagnostic::from_json(&jobless.to_json()).unwrap(), jobless);
    }

    #[test]
    fn verify_config_flags_invalid_instance() {
        let mut bad = cfg();
        bad.mem.n_bank = 3; // not a power of two
        let diags = verify_config(&bad);
        assert_eq!(first_error(&diags).map(|d| d.code), Some(CONFIG_INVALID));
        assert!(verify_config(&cfg()).is_empty());
    }

    #[test]
    fn verify_request_flags_unschedulable_shape() {
        let req = JobRequest::timing(GemmShape::new(8, 300_000, 8), Mechanisms::ALL, 1);
        let diags = verify_request(&cfg(), &req);
        assert_eq!(first_error(&diags).map(|d| d.code), Some(UNSCHEDULABLE));
    }

    #[test]
    fn multicore_jobs_verify_clean() {
        let mut cfg2 = cfg();
        cfg2.cores = 2;
        for cpl in [false, true] {
            let job = compile_gemm(&cfg2, GemmShape::new(256, 256, 256), Layout::RowMajor, 2, cpl)
                .unwrap();
            assert!(job.calls.len() >= 2, "needs a real round-robin split");
            let diags = verify_job(&cfg2, &job);
            assert!(!has_errors(&diags), "cpl={cpl}: {diags:?}");
        }
    }

    #[test]
    fn single_core_program_on_multicore_platform_diverges() {
        // Compile on one core (every placement at partition 0, every
        // CSR access in window 0), then claim the job targets 2 cores:
        // the verifier must flag the launch targeting, the missing
        // per-core drain, and the partition escape of core 1's calls.
        let cfg1 = cfg();
        let mut cfg2 = cfg();
        cfg2.cores = 2;
        let job1 =
            compile_gemm(&cfg1, GemmShape::new(256, 256, 256), Layout::RowMajor, 1, true).unwrap();
        assert!(job1.calls.len() >= 2);
        let forged = CompiledJob { cores: 2, ..job1 };
        let diags = verify_job(&cfg2, &forged);
        let codes: Vec<&str> = diags.iter().map(|d| d.code).collect();
        assert!(codes.contains(&PROGRAM_DIVERGENCE), "launch targets wrong window: {codes:?}");
        assert!(codes.contains(&CPL_CHAIN), "core 1 never drained: {codes:?}");
        assert!(codes.contains(&CROSS_CORE_OVERLAP), "partition escape: {codes:?}");
    }

    #[test]
    fn cross_core_escape_names_the_call_and_partition() {
        let mut cfg2 = cfg();
        cfg2.cores = 2;
        let job = compile_gemm(&cfg2, GemmShape::new(256, 256, 256), Layout::RowMajor, 1, true)
            .unwrap();
        // regions honoring the round-robin partitions verify clean
        assert!(!verify_job(&cfg2, &job).iter().any(|d| d.code == CROSS_CORE_OVERLAP));
    }

    #[test]
    fn zero_repeats_is_an_error() {
        let cfg = cfg();
        let job =
            compile_gemm(&cfg, GemmShape::new(32, 32, 32), Layout::TiledInterleaved, 0, true)
                .unwrap();
        let diags = verify_job(&cfg, &job);
        assert_eq!(first_error(&diags).map(|d| d.code), Some(LOOP_BOUND_RANGE));
    }
}
