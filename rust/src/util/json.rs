//! Minimal JSON reader/writer — serde is not available offline, and the
//! only JSON we handle is our own: `artifacts/manifest.json` (read),
//! experiment result dumps (write), and the sharded-sweep wire format
//! (read + write: serialized `JobRequest` shards and per-shard result
//! files exchanged between the `sweep` driver and worker processes).
//!
//! Wire-format note: `f64` values round-trip bit-identically because
//! the writer uses Rust's shortest round-trip `Display` formatting and
//! the parser delegates to `str::parse::<f64>`; `u64`/`i64` values
//! round-trip exactly as long as they stay below 2^53, where `f64`
//! integers are exact (simulation counters are far below that).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Largest integer accepted by the exact-integer wire contract
/// (`as_u64`/`as_i64`): 2^53 - 1, JavaScript's MAX_SAFE_INTEGER. 2^53
/// itself is representable but excluded — 2^53 + 1 rounds onto it
/// during parsing, so accepting it would let a collision pass as
/// "exact".
const MAX_SAFE_INT: f64 = 9_007_199_254_740_991.0;

/// A JSON value. BTreeMap keeps key order deterministic for diffs.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Exact unsigned integer, if the number is one (within the 2^53
    /// range `f64` represents exactly).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= MAX_SAFE_INT => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    /// Exact signed integer, if the number is one.
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 && n.abs() <= MAX_SAFE_INT => Some(*n as i64),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Serialize with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        let pad = "  ".repeat(indent);
        let pad1 = "  ".repeat(indent + 1);
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => {
                let _ = write!(out, "{b}");
            }
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) if a.is_empty() => out.push_str("[]"),
            Json::Arr(a) => {
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    out.push_str(&pad1);
                    v.write_pretty(out, indent + 1);
                    if i + 1 < a.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                out.push_str(&pad);
                out.push(']');
            }
            Json::Obj(m) if m.is_empty() => out.push_str("{}"),
            Json::Obj(m) => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    out.push_str(&pad1);
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 1);
                    if i + 1 < m.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                out.push_str(&pad);
                out.push('}');
            }
        }
    }
}

/// Typed field accessors for decoding our own wire formats: each
/// returns a descriptive error naming the missing/mistyped key, so a
/// corrupt shard or result file fails loudly instead of defaulting.
pub fn get<'a>(v: &'a Json, key: &str) -> Result<&'a Json, String> {
    v.get(key).ok_or_else(|| format!("missing field {key:?}"))
}

pub fn get_u64(v: &Json, key: &str) -> Result<u64, String> {
    get(v, key)?.as_u64().ok_or_else(|| format!("field {key:?} is not an unsigned integer"))
}

pub fn get_usize(v: &Json, key: &str) -> Result<usize, String> {
    get_u64(v, key).map(|n| n as usize)
}

pub fn get_f64(v: &Json, key: &str) -> Result<f64, String> {
    get(v, key)?.as_f64().ok_or_else(|| format!("field {key:?} is not a number"))
}

pub fn get_bool(v: &Json, key: &str) -> Result<bool, String> {
    get(v, key)?.as_bool().ok_or_else(|| format!("field {key:?} is not a bool"))
}

pub fn get_str<'a>(v: &'a Json, key: &str) -> Result<&'a str, String> {
    get(v, key)?.as_str().ok_or_else(|| format!("field {key:?} is not a string"))
}

pub fn get_arr<'a>(v: &'a Json, key: &str) -> Result<&'a [Json], String> {
    get(v, key)?.as_arr().ok_or_else(|| format!("field {key:?} is not an array"))
}

/// Optional string field: `null` decodes to `None` (dispatch-report
/// attempt records encode "no error" as `null`). The key itself must
/// still be present — an absent key stays a loud decode error.
pub fn get_opt_str(v: &Json, key: &str) -> Result<Option<String>, String> {
    match get(v, key)? {
        Json::Null => Ok(None),
        Json::Str(s) => Ok(Some(s.clone())),
        _ => Err(format!("field {key:?} is neither a string nor null")),
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.fract() == 0.0 && n.abs() < 9.0e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Recursive-descent JSON parser. Strict enough for our manifests.
pub fn parse(input: &str) -> Result<Json, String> {
    let bytes = input.as_bytes();
    let mut pos = 0usize;
    let v = parse_value(bytes, &mut pos)?;
    skip_ws(bytes, &mut pos);
    if pos != bytes.len() {
        return Err(format!("trailing data at byte {pos}"));
    }
    Ok(v)
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        return Err("unexpected end of input".into());
    }
    match b[*pos] {
        b'{' => parse_obj(b, pos),
        b'[' => parse_arr(b, pos),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b't' => parse_lit(b, pos, "true", Json::Bool(true)),
        b'f' => parse_lit(b, pos, "false", Json::Bool(false)),
        b'n' => parse_lit(b, pos, "null", Json::Null),
        _ => parse_num(b, pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, v: Json) -> Result<Json, String> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(v)
    } else {
        Err(format!("bad literal at byte {pos:?}"))
    }
}

fn parse_num(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    std::str::from_utf8(&b[start..*pos])
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .map(Json::Num)
        .ok_or_else(|| format!("bad number at byte {start}"))
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, String> {
    debug_assert_eq!(b[*pos], b'"');
    *pos += 1;
    let mut out = String::new();
    while *pos < b.len() {
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                if *pos >= b.len() {
                    break;
                }
                match b[*pos] {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'u' => {
                        if *pos + 4 >= b.len() {
                            return Err("bad \\u escape".into());
                        }
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])
                            .map_err(|_| "bad \\u escape")?;
                        let code = u32::from_str_radix(hex, 16)
                            .map_err(|_| "bad \\u escape")?;
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        *pos += 4;
                    }
                    c => return Err(format!("bad escape \\{}", c as char)),
                }
                *pos += 1;
            }
            c => {
                // copy UTF-8 sequences verbatim
                let len = utf8_len(c);
                out.push_str(
                    std::str::from_utf8(&b[*pos..*pos + len])
                        .map_err(|_| "bad utf8")?,
                );
                *pos += len;
            }
        }
    }
    Err("unterminated string".into())
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    *pos += 1; // [
    let mut items = Vec::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b']' {
        *pos += 1;
        return Ok(Json::Arr(items));
    }
    loop {
        items.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            _ => return Err(format!("expected ',' or ']' at byte {pos:?}")),
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    *pos += 1; // {
    let mut map = BTreeMap::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b'}' {
        *pos += 1;
        return Ok(Json::Obj(map));
    }
    loop {
        skip_ws(b, pos);
        if *pos >= b.len() || b[*pos] != b'"' {
            return Err(format!("expected object key at byte {pos:?}"));
        }
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        if b.get(*pos) != Some(&b':') {
            return Err(format!("expected ':' at byte {pos:?}"));
        }
        *pos += 1;
        map.insert(key, parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {
                *pos += 1;
                return Ok(Json::Obj(map));
            }
            _ => return Err(format!("expected ',' or '}}' at byte {pos:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let src = r#"{
  "gemm_8x8x8": {
    "args": [{"shape": [8, 8], "dtype": "s8"}],
    "file": "gemm_8x8x8.hlo.txt",
    "sha256": "abc"
  }
}"#;
        let v = parse(src).unwrap();
        let entry = v.get("gemm_8x8x8").unwrap();
        assert_eq!(entry.get("file").unwrap().as_str(), Some("gemm_8x8x8.hlo.txt"));
        let args = entry.get("args").unwrap().as_arr().unwrap();
        let shape = args[0].get("shape").unwrap().as_arr().unwrap();
        assert_eq!(shape[0].as_usize(), Some(8));
        // re-serialize and re-parse must be stable
        let text = v.pretty();
        assert_eq!(parse(&text).unwrap(), v);
    }

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse("{} x").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn escapes_in_writer() {
        let v = Json::str("quote\" slash\\ nl\n");
        let text = v.pretty();
        assert_eq!(parse(&text).unwrap(), v);
    }

    #[test]
    fn integers_serialized_without_fraction() {
        assert_eq!(Json::num(42.0).pretty(), "42");
        assert_eq!(Json::num(0.5).pretty(), "0.5");
    }

    #[test]
    fn unicode_string_roundtrip() {
        let v = parse(r#""héllo é""#).unwrap();
        assert_eq!(v.as_str(), Some("héllo é"));
    }

    #[test]
    fn typed_accessors_and_errors() {
        let v = parse(r#"{"n": 42, "x": 0.5, "b": true, "s": "hi", "a": [1]}"#).unwrap();
        assert_eq!(get_u64(&v, "n").unwrap(), 42);
        assert_eq!(get_f64(&v, "x").unwrap(), 0.5);
        assert!(get_bool(&v, "b").unwrap());
        assert_eq!(get_str(&v, "s").unwrap(), "hi");
        assert_eq!(get_arr(&v, "a").unwrap().len(), 1);
        assert!(get_u64(&v, "x").is_err(), "fractional is not u64");
        assert!(get_u64(&v, "missing").unwrap_err().contains("missing"));
        assert_eq!(Json::num(-3.0).as_i64(), Some(-3));
        assert_eq!(Json::num(-3.0).as_u64(), None);
        // the full exact-integer range up to 2^53 - 1 is accepted ...
        let max = 9_007_199_254_740_991u64;
        assert_eq!(Json::num(max as f64).as_u64(), Some(max));
        assert_eq!(parse("9007199254740991").unwrap().as_u64(), Some(max));
        // ... and 2^53 itself is rejected: "9007199254740993" parses to
        // the same f64, so accepting it would pass off a collision as
        // exact
        assert_eq!(Json::num(9_007_199_254_740_992.0).as_u64(), None);
        assert_eq!(
            parse("9007199254740993").unwrap(),
            parse("9007199254740992").unwrap(),
            "the collision the exclusive bound guards against"
        );
    }

    #[test]
    fn optional_string_fields() {
        let v = parse(r#"{"e": null, "s": "boom", "n": 3}"#).unwrap();
        assert_eq!(get_opt_str(&v, "e").unwrap(), None);
        assert_eq!(get_opt_str(&v, "s").unwrap(), Some("boom".into()));
        assert!(get_opt_str(&v, "n").is_err(), "a number is neither string nor null");
        assert!(get_opt_str(&v, "missing").unwrap_err().contains("missing"));
        assert_eq!(Json::arr(vec![Json::num(1.0)]).pretty(), "[\n  1\n]");
    }

    #[test]
    fn f64_roundtrip_is_exact() {
        for x in [0.123456789123456789f64, 1.0 / 3.0, 1234567890.0625, 1e-300] {
            let text = Json::num(x).pretty();
            assert_eq!(parse(&text).unwrap().as_f64(), Some(x), "value {x} must round-trip");
        }
    }
}
