//! Request arrival processes for the serving harness.
//!
//! Two canonical serving-evaluation regimes:
//!
//! - **Open-loop Poisson**: requests arrive at an offered rate that
//!   does not react to the system (the "traffic from millions of
//!   users" model). Inter-arrival gaps are exponential, sampled by
//!   inverse-CDF from the deterministic [`Pcg32`] stream, so the same
//!   seed always produces the same arrival schedule.
//! - **Closed-loop N clients**: each client issues one request, waits
//!   for its completion, thinks for a fixed time, and re-issues — the
//!   latency-limited regime (with 1 client and zero think time it
//!   degenerates to the plain sequential loop, which the differential
//!   test exploits).
//!
//! All times are **virtual device cycles** of the simulated platform;
//! nothing here reads a wall clock.

use crate::util::json::Json;
use crate::util::rng::Pcg32;

/// How requests arrive at the serving queue.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalSpec {
    /// Open loop: Poisson arrivals at `rate_rps` requests per second
    /// (converted to cycles at the platform clock).
    OpenPoisson { rate_rps: f64 },
    /// Closed loop: `clients` clients, each re-issuing `think_cycles`
    /// after its previous request completes.
    ClosedLoop { clients: usize, think_cycles: u64 },
}

impl ArrivalSpec {
    pub fn label(&self) -> &'static str {
        match self {
            ArrivalSpec::OpenPoisson { .. } => "poisson",
            ArrivalSpec::ClosedLoop { .. } => "closed",
        }
    }

    /// Wire encoding (serving report header).
    pub fn to_json(&self) -> Json {
        match *self {
            ArrivalSpec::OpenPoisson { rate_rps } => Json::obj(vec![
                ("mode", Json::str("poisson")),
                ("rate_rps", Json::num(rate_rps)),
            ]),
            ArrivalSpec::ClosedLoop { clients, think_cycles } => Json::obj(vec![
                ("mode", Json::str("closed")),
                ("clients", Json::num(clients as f64)),
                ("think_cycles", Json::num(think_cycles as f64)),
            ]),
        }
    }
}

/// `n` Poisson arrival times in device cycles at `rate_rps` requests
/// per second on a `freq_mhz` clock. Monotone non-decreasing; the
/// caller validates `rate_rps > 0`.
pub fn poisson_arrival_cycles(
    rate_rps: f64,
    freq_mhz: u64,
    n: usize,
    rng: &mut Pcg32,
) -> Vec<u64> {
    // mean inter-arrival gap in cycles
    let mean_gap = freq_mhz as f64 * 1e6 / rate_rps;
    let mut t = 0.0f64;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        // inverse CDF of Exp(1): -ln(1 - u), u in [0, 1) so the
        // argument stays in (0, 1] and the gap is finite and >= 0
        let u = rng.unit_f64();
        t += -(1.0 - u).ln() * mean_gap;
        out.push(t.round() as u64);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_is_deterministic_and_monotone() {
        let a = poisson_arrival_cycles(1000.0, 200, 500, &mut Pcg32::seeded(9));
        let b = poisson_arrival_cycles(1000.0, 200, 500, &mut Pcg32::seeded(9));
        assert_eq!(a, b, "same seed, same schedule");
        assert!(a.windows(2).all(|w| w[0] <= w[1]), "arrivals sorted");
    }

    #[test]
    fn poisson_mean_gap_matches_rate() {
        // 1000 req/s at 200 MHz -> mean gap 200_000 cycles
        let arr = poisson_arrival_cycles(1000.0, 200, 4000, &mut Pcg32::seeded(3));
        let mean = *arr.last().unwrap() as f64 / arr.len() as f64;
        assert!(
            (mean - 200_000.0).abs() < 20_000.0,
            "empirical mean gap {mean} vs expected 200000"
        );
    }

    #[test]
    fn spec_json_has_mode() {
        let open = ArrivalSpec::OpenPoisson { rate_rps: 500.0 };
        assert!(open.to_json().pretty().contains("poisson"));
        let closed = ArrivalSpec::ClosedLoop { clients: 4, think_cycles: 100 };
        let text = closed.to_json().pretty();
        assert!(text.contains("closed") && text.contains("think_cycles"));
        assert_eq!(closed.label(), "closed");
    }
}
