//! Fig. 5: utilization ablation of the three mechanisms over random
//! GeMM workloads.
//!
//! 500 random (M, K, N) sizes from {8, 16, ..., 256}, 10 repeats each;
//! seven architecture variants:
//!   Arch1  baseline (no CPL, no prefetch/output buffering, row-major)
//!   Arch2  + configuration pre-loading
//!   Arch3  + input pre-fetch & output buffering (depth 2)
//!   Arch4  + strided memory access (depth 2)
//!   Arch4 d3 / d4: buffer depth 3 and 4
//! plus the shipping default (depth D_stream = 3).

use crate::compiler::GemmShape;
use crate::config::{Mechanisms, PlatformConfig};
use crate::coordinator::shard::{run_sweep, SweepOptions};
use crate::coordinator::JobRequest;
use crate::util::stats::BoxStats;
use crate::util::table::{ascii_box, fmt_f, Table};
use crate::workloads::random_suite;

#[derive(Debug, Clone, Copy)]
pub struct Fig5Options {
    pub seed: u64,
    pub workloads: usize,
    pub repeats: u32,
    pub workers: usize,
    /// In-process shards per variant batch (0 or 1 = unsharded; each
    /// batch runs through `coordinator::dispatch` — the multi-process
    /// and cross-host transports are the `sweep` CLI's).
    pub shards: usize,
    /// Event-driven cycle skipping (cycle-exact; off only for
    /// differential checks).
    pub fast_forward: bool,
}

impl Default for Fig5Options {
    fn default() -> Self {
        Fig5Options {
            seed: 2024,
            workloads: 500,
            repeats: 10,
            workers: 0,
            shards: 1,
            fast_forward: true,
        }
    }
}

/// One variant's label + distribution of overall utilization.
#[derive(Debug, Clone)]
pub struct Fig5Variant {
    pub label: String,
    pub buffer_depth: usize,
    pub stats: BoxStats,
    pub samples: Vec<f64>,
}

#[derive(Debug, Clone)]
pub struct Fig5Result {
    pub variants: Vec<Fig5Variant>,
    pub shapes: Vec<GemmShape>,
}

/// The paper's variant ladder: `(label, mechanisms, buffer depth)`.
/// Public because the `sweep` CLI plans its multi-process Fig. 5
/// slices from the same ladder.
pub fn variant_specs() -> Vec<(&'static str, Mechanisms, usize)> {
    vec![
        ("Arch1 baseline", Mechanisms::BASELINE, 2),
        ("Arch2 +CPL", Mechanisms::CPL, 2),
        ("Arch3 +prefetch/outbuf d2", Mechanisms::CPL_BUF, 2),
        ("Arch4 +SMA d2", Mechanisms::ALL, 2),
        ("Arch4 depth 3", Mechanisms::ALL, 3),
        ("Arch4 depth 4", Mechanisms::ALL, 4),
    ]
}

/// The platform instance of one variant: base config at the variant's
/// buffer depth.
pub fn variant_config(base_cfg: &PlatformConfig, depth: usize) -> PlatformConfig {
    let mut cfg = base_cfg.clone();
    cfg.mem.d_stream = depth;
    cfg
}

pub fn fig5_ablation(base_cfg: &PlatformConfig, opts: Fig5Options) -> Fig5Result {
    let shapes = random_suite(opts.seed, opts.workloads);
    let sweep_opts = SweepOptions {
        shards: opts.shards,
        workers: opts.workers,
        fast_forward: opts.fast_forward,
        ..Default::default()
    };
    let mut variants = Vec::new();
    for (label, mech, depth) in variant_specs() {
        let cfg = variant_config(base_cfg, depth);
        let requests: Vec<JobRequest> = shapes
            .iter()
            .map(|&shape| JobRequest::timing(shape, mech, opts.repeats))
            .collect();
        let samples: Vec<f64> = run_sweep(&cfg, requests, sweep_opts)
            .outcomes
            .into_iter()
            .map(|r| r.expect("fig5 job failed").report.overall)
            .collect();
        variants.push(Fig5Variant {
            label: label.to_string(),
            buffer_depth: depth,
            stats: BoxStats::compute(&samples)
                .expect("fig5 runs at least one workload per variant"),
            samples,
        });
    }
    Fig5Result { variants, shapes }
}

impl Fig5Result {
    /// Median improvement ratios quoted in Sec. 4.2.
    pub fn median_ratios(&self) -> Vec<(String, f64)> {
        let med = |i: usize| self.variants[i].stats.median;
        vec![
            ("Arch2 / Arch1 (CPL)".into(), med(1) / med(0)),
            ("Arch3 / Arch2 (prefetch+outbuf)".into(), med(2) / med(1)),
            ("Arch4 / Arch3 (SMA)".into(), med(3) / med(2)),
            ("Arch4 / Arch1 (all)".into(), med(3) / med(0)),
        ]
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str("## Fig. 5 — utilization ablation (overall utilization)\n\n");
        let mut t = Table::new(&["variant", "min", "q1", "median", "q3", "max", "mean"]);
        for v in &self.variants {
            let s = &v.stats;
            t.row(vec![
                v.label.clone(),
                fmt_f(s.min, 4),
                fmt_f(s.q1, 4),
                fmt_f(s.median, 4),
                fmt_f(s.q3, 4),
                fmt_f(s.max, 4),
                fmt_f(s.mean, 4),
            ]);
        }
        out.push_str(&t.markdown());
        out.push_str("\n```\nutilization  0.0");
        out.push_str(&" ".repeat(48));
        out.push_str("1.0\n");
        for v in &self.variants {
            let s = &v.stats;
            out.push_str(&format!(
                "{:<26} {}\n",
                v.label,
                ascii_box(0.0, 1.0, 52, s.whisker_lo, s.q1, s.median, s.q3, s.whisker_hi)
            ));
        }
        out.push_str("```\n\n### Median improvements (paper: 1.40x / 2.02x / 1.18x / 2.78x)\n\n");
        let mut t = Table::new(&["step", "measured"]);
        for (name, ratio) in self.median_ratios() {
            t.row(vec![name, format!("{:.2}x", ratio)]);
        }
        out.push_str(&t.markdown());
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Reduced-size ablation: the full 500x10 suite runs in the bench;
    /// tests check the qualitative claims on a subsample.
    #[test]
    fn ablation_ordering_holds() {
        let cfg = PlatformConfig::case_study();
        let res = fig5_ablation(
            &cfg,
            Fig5Options { seed: 7, workloads: 40, repeats: 10, ..Default::default() },
        );
        let med: Vec<f64> = res.variants.iter().map(|v| v.stats.median).collect();
        // each mechanism must improve the median
        assert!(med[1] > med[0], "CPL: {} vs {}", med[1], med[0]);
        assert!(med[2] > med[1], "prefetch: {} vs {}", med[2], med[1]);
        assert!(med[3] > med[2], "SMA: {} vs {}", med[3], med[2]);
        // deeper buffers: utilization must not degrade, variance shrinks
        assert!(med[4] >= med[3] * 0.99);
        assert!(med[5] >= med[4] * 0.99);
        let iqr = |i: usize| res.variants[i].stats.q3 - res.variants[i].stats.q1;
        assert!(iqr(5) <= iqr(3) + 1e-9, "depth 4 IQR {} vs d2 {}", iqr(5), iqr(3));
        // overall improvement is substantial (paper: 2.78x)
        assert!(med[3] / med[0] > 1.5, "overall {}x", med[3] / med[0]);
    }

    #[test]
    fn render_contains_all_variants() {
        let cfg = PlatformConfig::case_study();
        let res = fig5_ablation(
            &cfg,
            Fig5Options {
                seed: 3,
                workloads: 8,
                repeats: 2,
                workers: 2,
                shards: 2,
                ..Default::default()
            },
        );
        let text = res.render();
        for v in &res.variants {
            assert!(text.contains(&v.label));
        }
        assert!(text.contains("Median improvements"));
    }
}
