//! Bench: regenerate Fig. 6 — area & power breakdown, peak performance
//! and system efficiency at the (32,32,32) power workload.
//!
//! Run with:  cargo bench --bench fig6_area_power

use std::time::Instant;

use opengemm::config::PlatformConfig;
use opengemm::experiments::{fig6_area_power, Fig6Options};

fn main() {
    let cfg = PlatformConfig::case_study();
    let t0 = Instant::now();
    let res = fig6_area_power(&cfg, Fig6Options::default());
    println!("{}", res.render());
    println!("bench fig6_area_power: {:.3}s wall", t0.elapsed().as_secs_f64());
}
