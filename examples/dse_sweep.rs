//! Design-space exploration: sweep the generator parameters (Mu, Ku,
//! Nu array geometry and buffer depth) and chart utilization, area,
//! power and efficiency per instance — the "hardware generator"
//! workflow the paper's Chisel design enables (Sec. 2.2: dot-product
//! units to matrix-matrix accelerators from one generator).
//!
//! Run with:  cargo run --release --example dse_sweep -- [--shards N]
//!            [--workers N] [--no-fast-forward]
//!            [--prefilter analytical [--confirm-top K]]
//!
//! `--prefilter analytical` prices every generator point with the
//! closed-form cost model and simulates only the top-K instances by
//! predicted efficiency; pruned rows are marked `*` and keep their
//! predicted utilization.

use opengemm::bail;
use opengemm::compiler::GemmShape;
use opengemm::config::{Mechanisms, PlatformConfig};
use opengemm::coordinator::shard::{run_sweep, SweepOptions};
use opengemm::coordinator::JobRequest;
use opengemm::model::prefilter;
use opengemm::power::PowerModel;
use opengemm::util::cli::Args;
use opengemm::util::table::{fmt_f, Table};
use opengemm::workloads::random_suite;

fn instance(mu: usize, nu: usize, ku: usize) -> Option<PlatformConfig> {
    let mut cfg = PlatformConfig::case_study();
    cfg.core.mu = mu;
    cfg.core.nu = nu;
    cfg.core.ku = ku;
    // scale the memory ports so the instance still elaborates: read BW
    // must cover A'+B' per cycle, write BW one C' tile per Ku cycles
    let need_read = cfg.core.a_tile_bytes() + cfg.core.b_tile_bytes();
    cfg.mem.r_mem = need_read.div_ceil(cfg.mem.word_bytes()).next_power_of_two();
    cfg.mem.w_mem = (cfg.core.c_tile_bytes().div_ceil(cfg.mem.word_bytes()))
        .next_power_of_two()
        .max(4);
    cfg.mem.n_bank = cfg.mem.n_bank.max(cfg.mem.r_mem.next_power_of_two());
    cfg.validate().ok()?;
    Some(cfg)
}

fn main() -> opengemm::util::error::Result<()> {
    let args = Args::from_env()?;
    // every per-instance batch goes through the sharded sweep engine
    // and its fault-tolerant dispatch scheduler — the same code path
    // the `opengemm sweep` driver distributes over worker processes
    // and spool-dir hosts
    let sweep_opts = SweepOptions {
        shards: args.usize_or("shards", 1)?,
        workers: args.usize_or("workers", 0)?,
        fast_forward: args.enabled_unless_no("fast-forward"),
        ..Default::default()
    };
    // generator points: vector unit, outer-product-ish, square arrays
    let points = [
        (1usize, 1usize, 64usize), // big dot-product unit
        (4, 4, 8),                 // small square array
        (8, 8, 8),                 // the paper's case study
        (16, 16, 8),               // wider mesh
        (8, 8, 16),                // deeper DotProds
        (16, 16, 16),              // large array
    ];
    let prefilter_on = match args.get("prefilter") {
        None | Some("none") => false,
        Some("analytical") => true,
        Some(other) => bail!("--prefilter must be none|analytical, got {other:?}"),
    };
    let confirm_top = args.usize_or("confirm-top", 2)?;
    let workloads = random_suite(77, 40);
    let model = PowerModel::default();

    let mut table = Table::new(&[
        "(Mu,Nu,Ku)", "peak GOPS", "mean OU", "eff GOPS", "area mm^2", "power mW",
        "TOPS/W", "GOPS/mm^2",
    ]);

    // elaborate every generator point first: the prefilter ranks the
    // whole grid of viable instances before anything is simulated
    let mut grid: Vec<prefilter::GridVariant> = Vec::new();
    let mut geometry: Vec<(usize, usize, usize)> = Vec::new();
    for &(mu, nu, ku) in &points {
        let Some(cfg) = instance(mu, nu, ku) else {
            println!("skipping ({mu},{nu},{ku}): does not elaborate");
            continue;
        };
        grid.push(prefilter::GridVariant {
            label: format!("({mu},{nu},{ku})"),
            cfg,
            requests: workloads
                .iter()
                .map(|&s| JobRequest::timing(s, Mechanisms::ALL, 5))
                .collect(),
        });
        geometry.push((mu, nu, ku));
    }
    let (ranked, confirmed) = if prefilter_on {
        let ranked = prefilter::rank(&grid, sweep_opts.csr_latency);
        let keep = prefilter::frontier(&ranked, confirm_top);
        let labels: Vec<&str> = keep.iter().map(|&i| grid[i].label.as_str()).collect();
        println!(
            "prefilter: simulating {}/{} instances: {}",
            keep.len(),
            grid.len(),
            labels.join(", ")
        );
        let mut mask = vec![false; grid.len()];
        for &i in &keep {
            mask[i] = true;
        }
        (Some(ranked), mask)
    } else {
        (None, vec![true; grid.len()])
    };

    for (i, gv) in grid.iter().enumerate() {
        let (mu, nu, ku) = geometry[i];
        let cfg = &gv.cfg;
        let (mean_ou, simulated) = if confirmed[i] {
            let results = run_sweep(cfg, gv.requests.clone(), sweep_opts).outcomes;
            let mut ou_sum = 0.0;
            let mut n = 0usize;
            for r in results.into_iter().flatten() {
                ou_sum += r.report.overall;
                n += 1;
            }
            (ou_sum / n as f64, true)
        } else {
            let ranked = ranked.as_ref().expect("pruned instances imply a ranking");
            let ps = &ranked[i].predictions;
            let mean = ps.iter().map(|p| p.overall_utilization).sum::<f64>() / ps.len() as f64;
            (mean, false)
        };
        let peak = cfg.peak_gops();
        let area = model.total_area(cfg);
        let power = model.total_power(cfg, mean_ou);
        table.row(vec![
            format!("({mu},{nu},{ku}){}", if simulated { "" } else { " *" }),
            fmt_f(peak, 1),
            fmt_f(mean_ou, 3),
            fmt_f(peak * mean_ou, 1),
            fmt_f(area, 3),
            fmt_f(power, 1),
            fmt_f(peak * mean_ou / power, 2),
            fmt_f(peak * mean_ou / (area * 1.1676), 1), // layout factor
        ]);
    }
    println!("{}", table.markdown());
    if prefilter_on {
        println!("* predicted by the analytical cost model (not simulated)");
    }
    println!(
        "note: larger arrays raise peak GOPS but lose utilization on the random\n\
         workload mix (more padding waste) — the paper's rationale for choosing\n\
         8x8x8 as the balanced case-study instance (Sec. 4.1)."
    );
    Ok(())
}
