//! Virtual-time queueing model: map per-request service cycles onto a
//! single-device serving timeline.
//!
//! The device serves one batch at a time, FIFO. Time is virtual device
//! cycles; the engine is a pure function of (arrival source, per-kind
//! service cycles, batching policy, per-batch overhead), so the same
//! seed always reproduces the same timeline byte-for-byte.
//!
//! The multi-device generalization lives in [`super::fleet`]; with one
//! device and no faults injected its timeline is *identical* to this
//! engine — a differential the tests pin, which is why the batch-close
//! rules below are the single source of truth for both.
//!
//! ## Batch semantics
//!
//! A batch *closes* per the [`BatchPolicy`] (full, deadline expiry, or
//! the universal no-future-arrivals flush), *starts* when both closed
//! and the device is free, and *completes* after the per-batch
//! dispatch overhead plus the sum of its members' service cycles (the
//! device still executes member streams sequentially — batching
//! amortizes the dispatch overhead and trades queueing delay for it).
//! Deadline tie-break: an arrival landing *exactly on* the expiry
//! cycle is admitted before the batch closes (up to `max_batch`) — the
//! batch closes at `expiry` either way, so the rider costs the batch
//! no extra wait while saving itself a full batch window. Only
//! arrivals strictly after the expiry cycle start the next batch.
//! Every member of a batch completes at the batch's completion cycle:
//!
//! - request latency   = completion - arrival
//! - queueing latency  = start - arrival   (close wait + device wait)
//! - service latency   = completion - start (the batch service window)
//!
//! ## Closed-loop arrivals
//!
//! Closed-loop clients re-issue `think` cycles after their previous
//! request completes. Completion times are known at dispatch (the
//! model is deterministic), so follow-up arrivals are scheduled
//! eagerly when the batch is dispatched; "no future arrivals" is then
//! simply an empty schedule, which makes the partial-batch flush rule
//! exact and deadlock-free (a size-N batch can never wait on an
//! arrival that itself waits on the batch).

use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

use crate::util::rng::Pcg32;

use super::batching::BatchPolicy;

/// One served request's timeline, all in device cycles.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RequestRecord {
    /// Arrival order (0-based).
    pub id: usize,
    /// Index into the workload's request kinds.
    pub kind: usize,
    pub arrival: u64,
    /// This request's own stream cost (not the batch window).
    pub service_cycles: u64,
    /// Cycle the containing batch began service.
    pub start: u64,
    /// Cycle the containing batch completed.
    pub completion: u64,
    /// Index of the containing batch.
    pub batch: usize,
}

/// One dispatched batch.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BatchRecord {
    pub close: u64,
    pub start: u64,
    pub completion: u64,
    pub size: usize,
}

/// The full simulated timeline.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct QueueOutcome {
    /// In arrival (= id) order.
    pub records: Vec<RequestRecord>,
    /// In dispatch order.
    pub batches: Vec<BatchRecord>,
}

/// Where arrivals come from.
pub enum ArrivalSource {
    /// Precomputed `(cycle, kind)` schedule, sorted by cycle.
    Open { arrivals: Vec<(u64, usize)>, next: usize },
    /// Closed loop: re-issues are scheduled on batch dispatch.
    Closed {
        /// `(cycle, tie-break seq)` of not-yet-admitted arrivals.
        schedule: BinaryHeap<Reverse<(u64, u64)>>,
        seq: u64,
        think: u64,
        /// Requests not yet scheduled (the issue budget).
        remaining: usize,
        kind_rng: Pcg32,
        n_kinds: u32,
    },
}

impl ArrivalSource {
    pub fn open(arrivals: Vec<(u64, usize)>) -> ArrivalSource {
        debug_assert!(arrivals.windows(2).all(|w| w[0].0 <= w[1].0), "sorted schedule");
        ArrivalSource::Open { arrivals, next: 0 }
    }

    pub fn closed(
        clients: usize,
        think: u64,
        total_requests: usize,
        n_kinds: usize,
        kind_rng: Pcg32,
    ) -> ArrivalSource {
        let mut schedule = BinaryHeap::new();
        let initial = clients.min(total_requests);
        for seq in 0..initial as u64 {
            schedule.push(Reverse((0u64, seq)));
        }
        ArrivalSource::Closed {
            schedule,
            seq: initial as u64,
            think,
            remaining: total_requests - initial,
            kind_rng,
            n_kinds: n_kinds as u32,
        }
    }

    /// Cycle of the next arrival, if any can still occur.
    pub(super) fn peek(&self) -> Option<u64> {
        match self {
            ArrivalSource::Open { arrivals, next } => arrivals.get(*next).map(|a| a.0),
            ArrivalSource::Closed { schedule, .. } => schedule.peek().map(|r| r.0 .0),
        }
    }

    /// Admit the next arrival: `(cycle, kind)`.
    pub(super) fn pop(&mut self) -> Option<(u64, usize)> {
        match self {
            ArrivalSource::Open { arrivals, next } => {
                let a = arrivals.get(*next).copied();
                if a.is_some() {
                    *next += 1;
                }
                a
            }
            ArrivalSource::Closed { schedule, kind_rng, n_kinds, .. } => {
                let Reverse((cycle, _)) = schedule.pop()?;
                Some((cycle, kind_rng.below(*n_kinds) as usize))
            }
        }
    }

    /// A batch of `size` members completed at `completion`: closed-loop
    /// clients schedule their next issue. The fleet engine also calls
    /// this with `size == 1` when it sheds an arrival — the rejection
    /// is an instant completion from the client's point of view.
    pub(super) fn on_batch_dispatched(&mut self, size: usize, completion: u64) {
        if let ArrivalSource::Closed { schedule, seq, think, remaining, .. } = self {
            let reissues = size.min(*remaining);
            for _ in 0..reissues {
                schedule.push(Reverse((completion + *think, *seq)));
                *seq += 1;
            }
            *remaining -= reissues;
        }
    }
}

/// Run the queueing model to completion: every scheduled request is
/// admitted, batched and served. `service_by_kind[kind]` is the stream
/// cost of one request of that kind.
pub fn simulate_queue(
    source: &mut ArrivalSource,
    service_by_kind: &[u64],
    policy: BatchPolicy,
    overhead_cycles: u64,
) -> QueueOutcome {
    let max_batch = policy.max_batch();
    let max_wait = policy.max_wait();
    // (id, kind, arrival)
    let mut queue: VecDeque<(usize, usize, u64)> = VecDeque::new();
    let mut device_free = 0u64;
    let mut next_id = 0usize;
    let mut out = QueueOutcome::default();

    loop {
        let next_arrival = source.peek();
        // When does the queue close into a batch?
        let close: Option<u64> = if queue.len() >= max_batch {
            // full: closed the moment the max_batch-th member arrived
            Some(queue[max_batch - 1].2)
        } else if !queue.is_empty() && next_arrival.is_none() {
            // flush: nothing can ever join this batch
            Some(queue.back().unwrap().2)
        } else if let (Some(wait), Some(front)) = (max_wait, queue.front()) {
            // deadline: expiry closes the batch only once no arrival at
            // or before the expiry cycle remains — an arrival landing
            // exactly on the expiry cycle still joins (admit-at-expiry;
            // see the module docs for the tie-break rationale)
            let expiry = front.2.saturating_add(wait);
            match next_arrival {
                Some(a) if a <= expiry => None,
                _ => Some(expiry),
            }
        } else {
            None
        };

        if let Some(close_at) = close {
            let size = queue.len().min(max_batch);
            let members: Vec<(usize, usize, u64)> = queue.drain(..size).collect();
            let start = device_free.max(close_at);
            let service: u64 = members.iter().map(|&(_, k, _)| service_by_kind[k]).sum();
            let completion = start + overhead_cycles + service;
            device_free = completion;
            let batch = out.batches.len();
            for (id, kind, arrival) in members {
                out.records.push(RequestRecord {
                    id,
                    kind,
                    arrival,
                    service_cycles: service_by_kind[kind],
                    start,
                    completion,
                    batch,
                });
            }
            out.batches.push(BatchRecord { close: close_at, start, completion, size });
            source.on_batch_dispatched(size, completion);
        } else if let Some((cycle, kind)) = source.pop() {
            queue.push_back((next_id, kind, cycle));
            next_id += 1;
        } else {
            debug_assert!(queue.is_empty());
            break;
        }
    }
    debug_assert!(out.records.windows(2).all(|w| w[0].id < w[1].id), "id order");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn open(arrivals: &[(u64, usize)]) -> ArrivalSource {
        ArrivalSource::open(arrivals.to_vec())
    }

    #[test]
    fn immediate_is_fifo_sequential() {
        let mut src = open(&[(0, 0), (5, 0), (100, 0)]);
        let out = simulate_queue(&mut src, &[10], BatchPolicy::Immediate, 0);
        let c: Vec<u64> = out.records.iter().map(|r| r.completion).collect();
        // req0 serves 0..10; req1 arrives at 5, waits, serves 10..20;
        // req2 arrives at 100 on an idle device, serves 100..110
        assert_eq!(c, vec![10, 20, 110]);
        let lat: Vec<u64> = out.records.iter().map(|r| r.completion - r.arrival).collect();
        assert_eq!(lat, vec![10, 15, 10]);
        assert_eq!(out.batches.len(), 3);
    }

    #[test]
    fn size_batches_fill_then_flush() {
        let mut src = open(&[(0, 0), (1, 0), (2, 0), (3, 0), (4, 0)]);
        let out = simulate_queue(&mut src, &[10], BatchPolicy::Size(2), 0);
        let sizes: Vec<usize> = out.batches.iter().map(|b| b.size).collect();
        assert_eq!(sizes, vec![2, 2, 1], "two full batches, flushed remainder");
        // batch 0 closes when request 1 arrives (cycle 1)
        assert_eq!(out.batches[0].close, 1);
        assert_eq!(out.batches[0].completion, 21);
        // all members of one batch share its completion
        assert_eq!(out.records[0].completion, out.records[1].completion);
    }

    #[test]
    fn deadline_expires_partial_batch() {
        // one request at 0, the next only at 100; max_wait 10 closes a
        // size-1 batch at cycle 10
        let mut src = open(&[(0, 0), (100, 0)]);
        let policy = BatchPolicy::Deadline { max_batch: 4, max_wait_cycles: 10 };
        let out = simulate_queue(&mut src, &[5], policy, 0);
        assert_eq!(out.batches[0].close, 10);
        assert_eq!(out.batches[0].start, 10);
        assert_eq!(out.batches[0].size, 1);
        assert_eq!(out.records[0].completion - out.records[0].arrival, 15);
    }

    #[test]
    fn deadline_boundary_admits_at_expiry_excludes_after() {
        // first request at 0, wait 10 -> expiry 10. An arrival exactly
        // at cycle 10 joins the closing batch ...
        let policy = BatchPolicy::Deadline { max_batch: 4, max_wait_cycles: 10 };
        let mut src = open(&[(0, 0), (10, 0), (100, 0)]);
        let out = simulate_queue(&mut src, &[5], policy, 0);
        assert_eq!(out.batches[0].close, 10, "batch still closes at its expiry");
        assert_eq!(out.batches[0].size, 2, "the at-expiry arrival rides along");
        // ... but one cycle past the expiry starts the next batch
        let mut src = open(&[(0, 0), (11, 0), (100, 0)]);
        let out = simulate_queue(&mut src, &[5], policy, 0);
        let sizes: Vec<usize> = out.batches.iter().map(|b| b.size).collect();
        assert_eq!(sizes, vec![1, 1, 1]);
        assert_eq!(out.batches[0].close, 10);
        assert_eq!(out.batches[1].close, 21, "second batch expires 10 after its own front");
    }

    #[test]
    fn deadline_full_batch_closes_early() {
        let mut src = open(&[(0, 0), (1, 0), (50, 0)]);
        let policy = BatchPolicy::Deadline { max_batch: 2, max_wait_cycles: 1000 };
        let out = simulate_queue(&mut src, &[5], policy, 0);
        assert_eq!(out.batches[0].close, 1, "full at second arrival, not at expiry");
        assert_eq!(out.batches[0].size, 2);
    }

    #[test]
    fn per_batch_overhead_is_paid_once() {
        let mut src = open(&[(0, 0), (0, 0)]);
        let out = simulate_queue(&mut src, &[10], BatchPolicy::Size(2), 7);
        assert_eq!(out.batches[0].completion, 27, "overhead + 2 services");
    }

    #[test]
    fn closed_loop_single_client_is_sequential_with_think() {
        let mut src = ArrivalSource::closed(1, 5, 3, 1, Pcg32::seeded(1));
        let out = simulate_queue(&mut src, &[10], BatchPolicy::Immediate, 0);
        let a: Vec<u64> = out.records.iter().map(|r| r.arrival).collect();
        let c: Vec<u64> = out.records.iter().map(|r| r.completion).collect();
        assert_eq!(a, vec![0, 15, 30], "issue -> complete(10) -> think(5) -> reissue");
        assert_eq!(c, vec![10, 25, 40]);
    }

    #[test]
    fn closed_loop_partial_batch_flushes_not_deadlocks() {
        // 2 clients but size-4 batching: the batch can never fill, so
        // the flush rule must dispatch pairs
        let mut src = ArrivalSource::closed(2, 0, 4, 1, Pcg32::seeded(1));
        let out = simulate_queue(&mut src, &[10], BatchPolicy::Size(4), 0);
        assert_eq!(out.records.len(), 4, "all requests served");
        let sizes: Vec<usize> = out.batches.iter().map(|b| b.size).collect();
        assert_eq!(sizes, vec![2, 2]);
    }

    #[test]
    fn empty_schedule_serves_nothing() {
        let mut src = open(&[]);
        let out = simulate_queue(&mut src, &[10], BatchPolicy::Immediate, 0);
        assert!(out.records.is_empty() && out.batches.is_empty());
        let mut src = ArrivalSource::closed(4, 0, 0, 1, Pcg32::seeded(1));
        let out = simulate_queue(&mut src, &[10], BatchPolicy::Size(2), 0);
        assert!(out.records.is_empty());
    }

    #[test]
    fn mixed_kinds_use_their_own_service_cost() {
        let mut src = open(&[(0, 1), (0, 0)]);
        let out = simulate_queue(&mut src, &[10, 100], BatchPolicy::Immediate, 0);
        assert_eq!(out.records[0].service_cycles, 100);
        assert_eq!(out.records[1].service_cycles, 10);
        assert_eq!(out.records[1].completion, 110);
    }
}
