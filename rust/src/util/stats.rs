//! Summary statistics for experiment reporting: the paper's Fig. 5 is a
//! box plot over 500 utilization samples, so we need exact quantiles,
//! whiskers and outlier fences.

/// Five-number summary plus mean, matching a Tukey box plot.
#[derive(Debug, Clone, PartialEq)]
pub struct BoxStats {
    pub n: usize,
    pub min: f64,
    pub q1: f64,
    pub median: f64,
    pub q3: f64,
    pub max: f64,
    pub mean: f64,
    /// Whisker ends at the last data point within 1.5*IQR of the box.
    pub whisker_lo: f64,
    pub whisker_hi: f64,
    pub outliers: usize,
}

/// Linear-interpolated quantile (type 7, the numpy default) of a sorted
/// slice.
pub fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty(), "quantile of empty slice");
    assert!((0.0..=1.0).contains(&q), "q out of range: {q}");
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

impl BoxStats {
    pub fn compute(samples: &[f64]) -> BoxStats {
        assert!(!samples.is_empty(), "BoxStats of empty sample set");
        let mut sorted: Vec<f64> = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN in samples"));
        let q1 = quantile_sorted(&sorted, 0.25);
        let median = quantile_sorted(&sorted, 0.5);
        let q3 = quantile_sorted(&sorted, 0.75);
        let iqr = q3 - q1;
        let lo_fence = q1 - 1.5 * iqr;
        let hi_fence = q3 + 1.5 * iqr;
        let whisker_lo = sorted
            .iter()
            .copied()
            .find(|&v| v >= lo_fence)
            .unwrap_or(sorted[0]);
        let whisker_hi = sorted
            .iter()
            .rev()
            .copied()
            .find(|&v| v <= hi_fence)
            .unwrap_or(*sorted.last().unwrap());
        let outliers = sorted
            .iter()
            .filter(|&&v| v < lo_fence || v > hi_fence)
            .count();
        BoxStats {
            n: sorted.len(),
            min: sorted[0],
            q1,
            median,
            q3,
            max: *sorted.last().unwrap(),
            mean: sorted.iter().sum::<f64>() / sorted.len() as f64,
            whisker_lo,
            whisker_hi,
            outliers,
        }
    }
}

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Geometric mean (used for speedup aggregation across workloads).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    assert!(xs.iter().all(|&x| x > 0.0), "geomean needs positive values");
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_of_known_sequence() {
        let xs: Vec<f64> = (1..=5).map(|i| i as f64).collect();
        assert_eq!(quantile_sorted(&xs, 0.0), 1.0);
        assert_eq!(quantile_sorted(&xs, 0.5), 3.0);
        assert_eq!(quantile_sorted(&xs, 1.0), 5.0);
        assert_eq!(quantile_sorted(&xs, 0.25), 2.0);
    }

    #[test]
    fn quantile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((quantile_sorted(&xs, 0.3) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn box_stats_basic() {
        let xs: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let s = BoxStats::compute(&xs);
        assert_eq!(s.n, 100);
        assert_eq!(s.min, 0.0);
        assert_eq!(s.max, 99.0);
        assert!((s.median - 49.5).abs() < 1e-12);
        assert_eq!(s.outliers, 0);
    }

    #[test]
    fn box_stats_detects_outliers() {
        let mut xs: Vec<f64> = vec![10.0; 50];
        xs.push(1000.0);
        let s = BoxStats::compute(&xs);
        assert_eq!(s.outliers, 1);
        assert_eq!(s.whisker_hi, 10.0);
    }

    #[test]
    fn geomean_of_powers() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn stddev_constant_is_zero() {
        assert_eq!(stddev(&[5.0, 5.0, 5.0]), 0.0);
    }

    #[test]
    #[should_panic]
    fn empty_samples_panic() {
        BoxStats::compute(&[]);
    }

    #[test]
    fn single_sample() {
        let s = BoxStats::compute(&[3.5]);
        assert_eq!(s.median, 3.5);
        assert_eq!(s.q1, 3.5);
        assert_eq!(s.q3, 3.5);
    }
}
