"""L2 correctness: model graphs vs oracles, and AOT lowering sanity."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.aot import lower_entry, to_hlo_text
from compile.model import (
    artifact_registry,
    make_conv_im2col,
    make_gemm,
    make_linear,
    make_mha_scores,
    make_mlp_block,
)
from compile.kernels.ref import (
    conv2d_im2col_ref,
    gemm_int8_ref,
    linear_ref,
    mha_scores_ref,
    mlp_block_ref,
)

RNG = np.random.default_rng(7)


def rand_for(spec):
    if spec.dtype == jnp.int8:
        return jnp.asarray(RNG.integers(-128, 128, spec.shape, dtype=np.int8))
    if spec.dtype == jnp.int32:
        return jnp.asarray(RNG.integers(-512, 512, spec.shape, dtype=np.int32))
    raise NotImplementedError(spec.dtype)


class TestFactories:
    @pytest.mark.parametrize("m,k,n", [(8, 8, 8), (13, 22, 17), (32, 64, 16)])
    def test_gemm_factory(self, m, k, n):
        fn, specs = make_gemm(m, k, n)
        a, b = (rand_for(s) for s in specs)
        (out,) = fn(a, b)
        np.testing.assert_array_equal(out, gemm_int8_ref(a, b))

    def test_linear_factory(self):
        fn, specs = make_linear(16, 32, 8)
        a, w, bias, _ = (rand_for(s) for s in specs)
        shift = jnp.asarray([7], jnp.int32)
        (out,) = fn(a, w, bias, shift)
        np.testing.assert_array_equal(out, linear_ref(a, w, bias, 7))

    def test_conv_factory(self):
        fn, specs = make_conv_im2col(1, 8, 8, 4, 3, 3, 8)
        x, w = (rand_for(s) for s in specs)
        (out,) = fn(x, w)
        np.testing.assert_array_equal(out, conv2d_im2col_ref(x, w))

    def test_mha_factory(self):
        fn, specs = make_mha_scores(32, 64, shift=6)
        q, k = (rand_for(s) for s in specs)
        (out,) = fn(q, k)
        np.testing.assert_array_equal(out, mha_scores_ref(q, k, 6))

    def test_mlp_factory(self):
        fn, specs = make_mlp_block(16, 32, 64, shift1=7, shift2=7)
        args = [rand_for(s) for s in specs]
        (out,) = fn(*args)
        np.testing.assert_array_equal(out, mlp_block_ref(*args, 7, 7))


class TestAot:
    def test_registry_nonempty_and_unique_files(self):
        reg = artifact_registry()
        assert len(reg) >= 10
        files = [f"{k}.hlo.txt" for k in reg]
        assert len(set(files)) == len(files)

    def test_lower_gemm_has_dot_and_loop(self):
        text, meta = lower_entry("gemm_32x32x32", make_gemm, (32, 32, 32))
        assert "dot(" in text or "dot " in text
        # pallas grid lowers to an HLO while loop, not an unrolled body
        assert "while" in text
        assert meta["args"][0]["dtype"] == "s8"
        assert meta["results"][0]["dtype"] == "s32"

    def test_lowered_text_is_parseable_header(self):
        text, _ = lower_entry("gemm_8x8x8", make_gemm, (8, 8, 8))
        assert text.startswith("HloModule")

    def test_manifest_shapes_roundtrip(self):
        _, meta = lower_entry("gemm_13x22x17", make_gemm, (13, 22, 17))
        assert meta["args"][0]["shape"] == [13, 22]
        assert meta["args"][1]["shape"] == [22, 17]
        assert meta["results"][0]["shape"] == [13, 17]

    def test_lowering_is_deterministic(self):
        t1, m1 = lower_entry("gemm_8x8x8", make_gemm, (8, 8, 8))
        t2, m2 = lower_entry("gemm_8x8x8", make_gemm, (8, 8, 8))
        assert m1["sha256"] == m2["sha256"]

    @pytest.mark.skipif(
        not os.path.exists(os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")),
        reason="artifacts not built (run `make artifacts`)",
    )
    def test_built_manifest_matches_registry(self):
        path = os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")
        with open(path) as f:
            manifest = json.load(f)
        assert set(manifest) == set(artifact_registry())
        for name, meta in manifest.items():
            art = os.path.join(os.path.dirname(path), meta["file"])
            assert os.path.exists(art), f"missing artifact {art}"


class TestExecutedArtifacts:
    """Compile the lowered HLO back through XLA and check numerics.

    This closes the loop python-side: what Rust will execute (the HLO
    text) is functionally identical to the oracle.
    """

    def _run_hlo(self, text, args):
        from jax._src.lib import xla_client as xc

        backend = jax.devices("cpu")[0].client
        # Text -> computation via the HLO parser used by the Rust loader.
        comp = xc._xla.hlo_module_from_text(text)
        exe = backend.compile(
            xc._xla.XlaComputation(comp.as_serialized_hlo_module_proto())
        )
        bufs = [backend.buffer_from_pyval(np.asarray(a)) for a in args]
        out = exe.execute(bufs)
        return [np.asarray(o) for o in out]

    def test_gemm_hlo_numerics(self):
        text, _ = lower_entry("gemm_13x22x17", make_gemm, (13, 22, 17))
        a = RNG.integers(-128, 128, (13, 22), dtype=np.int8)
        b = RNG.integers(-128, 128, (22, 17), dtype=np.int8)
        try:
            outs = self._run_hlo(text, [a, b])
        except Exception as e:  # pragma: no cover - API drift guard
            pytest.skip(f"in-process HLO exec unavailable: {e}")
        ref = np.asarray(gemm_int8_ref(jnp.asarray(a), jnp.asarray(b)))
        np.testing.assert_array_equal(outs[0], ref)
