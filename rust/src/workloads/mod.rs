//! DNN workload extraction: the GeMM streams of the paper's four
//! benchmark models (Sec. 4.3, Table 2) plus the random workload suite
//! of the Fig. 5 ablation.
//!
//! Each model is expressed as a list of [`WorkloadItem`]s — a GeMM shape
//! with a repetition count (identical layers, attention heads, or
//! depthwise channel groups). Convolutions are lowered via im2col
//! exactly as the platform executes them.

pub mod models;
pub mod random;

pub use models::{
    bert_base, bert_large, encoder_layer, mobilenet_v2, mobilenet_v2_host_dw, resnet18, vit_b16,
};
pub use random::random_suite;

use crate::compiler::GemmShape;
use crate::config::GemmCoreParams;

/// One GeMM shape appearing `count` times in a model's execution.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WorkloadItem {
    pub name: String,
    pub shape: GemmShape,
    pub count: u64,
}

/// A full model workload.
#[derive(Debug, Clone)]
pub struct ModelWorkload {
    pub name: String,
    pub items: Vec<WorkloadItem>,
}

impl ModelWorkload {
    /// Total real MACs across the model.
    pub fn total_macs(&self) -> u64 {
        self.items.iter().map(|i| i.shape.macs() * i.count).sum()
    }

    /// Aggregate spatial utilization: MAC-weighted over items (real MACs
    /// over array-slot MACs), the Table 2 "SU" definition.
    pub fn spatial_utilization(&self, core: &GemmCoreParams) -> f64 {
        let real: u64 = self.total_macs();
        let padded: u64 = self
            .items
            .iter()
            .map(|i| i.shape.padded_macs(core) * i.count)
            .sum();
        real as f64 / padded as f64
    }

    /// Unique shapes with their total counts (simulate once, scale).
    pub fn unique_shapes(&self) -> Vec<(GemmShape, u64)> {
        let mut map: std::collections::BTreeMap<(usize, usize, usize), u64> =
            std::collections::BTreeMap::new();
        for item in &self.items {
            *map.entry((item.shape.m, item.shape.k, item.shape.n)).or_default() += item.count;
        }
        map.into_iter()
            .map(|((m, k, n), c)| (GemmShape::new(m, k, n), c))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GemmCoreParams;

    #[test]
    fn model_macs_are_plausible() {
        // batch-1 inference MAC counts, cross-checked against published
        // model statistics (tolerances cover head/padding details):
        let r18 = resnet18().total_macs() as f64;
        assert!((1.5e9..2.2e9).contains(&r18), "ResNet18 ~1.8 GMACs, got {r18:e}");
        let mnv2 = mobilenet_v2().total_macs() as f64;
        assert!((2.5e8..4.5e8).contains(&mnv2), "MobileNetV2 ~0.3 GMACs, got {mnv2:e}");
        let vit = vit_b16().total_macs() as f64;
        assert!((1.5e10..2.0e10).contains(&vit), "ViT-B/16 ~17.5 GMACs, got {vit:e}");
        let bert = bert_base(512).total_macs() as f64;
        assert!((4.0e10..5.0e10).contains(&bert), "BERT-Base(512) ~43 GMACs, got {bert:e}");
    }

    #[test]
    fn su_ordering_matches_paper() {
        // Table 2: SU(MobileNetV2) < SU(ResNet18) < SU(ViT) < SU(BERT)
        let core = GemmCoreParams::CASE_STUDY;
        let su_mnv2 = mobilenet_v2().spatial_utilization(&core);
        let su_r18 = resnet18().spatial_utilization(&core);
        let su_vit = vit_b16().spatial_utilization(&core);
        let su_bert = bert_base(512).spatial_utilization(&core);
        assert!(su_mnv2 < su_r18, "{su_mnv2} vs {su_r18}");
        assert!(su_r18 < su_vit, "{su_r18} vs {su_vit}");
        assert!(su_vit <= su_bert, "{su_vit} vs {su_bert}");
        // With the naive per-channel depthwise lowering (K=9, N=1) the
        // MobileNetV2 SU is ~0.50; the paper's 87.36% implies a more
        // efficient depthwise mapping (see EXPERIMENTS.md deviation
        // notes). The host-offloaded-depthwise variant lands near the
        // published number.
        assert!(su_mnv2 > 0.45, "MobileNetV2 SU sane: {su_mnv2}");
        let su_host_dw = mobilenet_v2_host_dw().spatial_utilization(&core);
        assert!(
            (0.82..0.97).contains(&su_host_dw),
            "MobileNetV2 (host dw) near paper's 87.36%: {su_host_dw}"
        );
        assert!(su_bert > 0.97, "BERT SU near 1: {su_bert}");
    }

    #[test]
    fn unique_shapes_fold_counts() {
        let m = ModelWorkload {
            name: "t".into(),
            items: vec![
                WorkloadItem { name: "a".into(), shape: GemmShape::new(8, 8, 8), count: 2 },
                WorkloadItem { name: "b".into(), shape: GemmShape::new(8, 8, 8), count: 3 },
                WorkloadItem { name: "c".into(), shape: GemmShape::new(16, 8, 8), count: 1 },
            ],
        };
        let u = m.unique_shapes();
        assert_eq!(u.len(), 2);
        assert_eq!(u[0].1, 5);
    }
}
