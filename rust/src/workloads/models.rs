//! GeMM-stream definitions of the four benchmark DNNs (batch 1).
//!
//! Convolutional layers lower via im2col (`ConvShape::gemm_shape`);
//! attention lowers head-by-head; depthwise convolutions lower to
//! per-channel thin GeMMs (K = 9, N = 1) — the "tick channel" case the
//! paper calls out for MobileNetV2. Layer dimensioning follows the
//! original papers ([28][29][30][31]).

use crate::compiler::{ConvShape, GemmShape};

use super::{ModelWorkload, WorkloadItem};

fn conv_item(name: &str, s: ConvShape) -> WorkloadItem {
    WorkloadItem {
        name: name.to_string(),
        shape: s.gemm_shape(),
        count: s.gemm_count() as u64,
    }
}

fn gemm_item(name: &str, m: usize, k: usize, n: usize, count: u64) -> WorkloadItem {
    WorkloadItem { name: name.to_string(), shape: GemmShape::new(m, k, n), count }
}

/// ResNet-18 (ImageNet 224x224, batch 1) [28].
pub fn resnet18() -> ModelWorkload {
    let mut items = Vec::new();
    // stem: 7x7/2 conv, 3 -> 64
    items.push(conv_item("conv1", ConvShape::dense(1, 224, 224, 3, 7, 7, 64, 2, 3)));
    // (after 3x3/2 maxpool: 56x56)
    // layer1: 4 convs 3x3 64->64 @ 56
    let c = ConvShape::dense(1, 56, 56, 64, 3, 3, 64, 1, 1);
    for i in 0..4 {
        items.push(conv_item(&format!("layer1.conv{i}"), c));
    }
    // layer2: 64->128 @ 28
    items.push(conv_item("layer2.conv_down", ConvShape::dense(1, 56, 56, 64, 3, 3, 128, 2, 1)));
    items.push(conv_item("layer2.shortcut", ConvShape::dense(1, 56, 56, 64, 1, 1, 128, 2, 0)));
    let c = ConvShape::dense(1, 28, 28, 128, 3, 3, 128, 1, 1);
    for i in 0..3 {
        items.push(conv_item(&format!("layer2.conv{i}"), c));
    }
    // layer3: 128->256 @ 14
    items.push(conv_item("layer3.conv_down", ConvShape::dense(1, 28, 28, 128, 3, 3, 256, 2, 1)));
    items.push(conv_item("layer3.shortcut", ConvShape::dense(1, 28, 28, 128, 1, 1, 256, 2, 0)));
    let c = ConvShape::dense(1, 14, 14, 256, 3, 3, 256, 1, 1);
    for i in 0..3 {
        items.push(conv_item(&format!("layer3.conv{i}"), c));
    }
    // layer4: 256->512 @ 7
    items.push(conv_item("layer4.conv_down", ConvShape::dense(1, 14, 14, 256, 3, 3, 512, 2, 1)));
    items.push(conv_item("layer4.shortcut", ConvShape::dense(1, 14, 14, 256, 1, 1, 512, 2, 0)));
    let c = ConvShape::dense(1, 7, 7, 512, 3, 3, 512, 1, 1);
    for i in 0..3 {
        items.push(conv_item(&format!("layer4.conv{i}"), c));
    }
    // classifier
    items.push(gemm_item("fc", 1, 512, 1000, 1));
    ModelWorkload { name: "ResNet18".into(), items }
}

/// MobileNetV2 (ImageNet 224x224, batch 1) [29].
pub fn mobilenet_v2() -> ModelWorkload {
    let mut items = Vec::new();
    // stem: 3x3/2 conv 3 -> 32
    items.push(conv_item("stem", ConvShape::dense(1, 224, 224, 3, 3, 3, 32, 2, 1)));

    // inverted residual table: (expansion t, out channels c, repeats n, stride s)
    let table: [(usize, usize, usize, usize); 7] = [
        (1, 16, 1, 1),
        (6, 24, 2, 2),
        (6, 32, 3, 2),
        (6, 64, 4, 2),
        (6, 96, 3, 1),
        (6, 160, 3, 2),
        (6, 320, 1, 1),
    ];
    let mut cin = 32usize;
    let mut hw = 112usize;
    for (bi, &(t, cout, n, s)) in table.iter().enumerate() {
        for r in 0..n {
            let stride = if r == 0 { s } else { 1 };
            let hidden = cin * t;
            let tag = format!("b{bi}.{r}");
            // 1x1 expand (skipped when t == 1: the first block has no expansion)
            if t != 1 {
                items.push(conv_item(
                    &format!("{tag}.expand"),
                    ConvShape::dense(1, hw, hw, cin, 1, 1, hidden, 1, 0),
                ));
            }
            // 3x3 depthwise
            let hw_out = if stride == 2 { hw / 2 } else { hw };
            items.push(conv_item(
                &format!("{tag}.dw"),
                ConvShape::depthwise(1, hw, hw, hidden, 3, 3, stride, 1),
            ));
            // 1x1 project
            items.push(conv_item(
                &format!("{tag}.project"),
                ConvShape::dense(1, hw_out, hw_out, hidden, 1, 1, cout, 1, 0),
            ));
            cin = cout;
            hw = hw_out;
        }
    }
    // final 1x1 conv 320 -> 1280 @ 7
    items.push(conv_item("head.conv", ConvShape::dense(1, 7, 7, 320, 1, 1, 1280, 1, 0)));
    items.push(gemm_item("fc", 1, 1280, 1000, 1));
    ModelWorkload { name: "MobileNetV2".into(), items }
}

/// MobileNetV2 with depthwise convolutions executed on the host (the
/// platform accelerates only the GeMM-friendly dense layers). The naive
/// per-channel depthwise lowering (K=9, N=1) wastes 7/8 of the array's
/// N lanes and most of the K depth; a deployment that cares about
/// utilization runs those thin kernels on the Snitch core (or a
/// dedicated depthwise unit) instead. This variant reproduces the
/// paper's reported SU band for MobileNetV2.
pub fn mobilenet_v2_host_dw() -> ModelWorkload {
    let full = mobilenet_v2();
    ModelWorkload {
        name: "MobileNetV2(host-dw)".into(),
        items: full.items.into_iter().filter(|i| !i.name.ends_with(".dw")).collect(),
    }
}

/// ViT-B/16 (224x224, batch 1): 196 patches + CLS = 197 tokens, 12
/// layers, 12 heads of 64, MLP 3072 [30].
pub fn vit_b16() -> ModelWorkload {
    let mut items = Vec::new();
    let (s, d, h, dh, mlp, layers) = (197usize, 768usize, 12u64, 64usize, 3072usize, 12u64);
    // patch embedding: 196 patches x (16*16*3) -> 768
    items.push(gemm_item("patch_embed", 196, 768, 768, 1));
    items.push(gemm_item("attn.qkv", s, d, 3 * d, layers));
    items.push(gemm_item("attn.scores", s, dh, s, layers * h));
    items.push(gemm_item("attn.context", s, s, dh, layers * h));
    items.push(gemm_item("attn.proj", s, d, d, layers));
    items.push(gemm_item("mlp.fc1", s, d, mlp, layers));
    items.push(gemm_item("mlp.fc2", s, mlp, d, layers));
    items.push(gemm_item("head", 1, d, 1000, 1));
    ModelWorkload { name: "ViT-B-16".into(), items }
}

/// The GeMM stream of ONE transformer encoder layer at sequence length
/// `seq`: hidden size `d`, `h` attention heads (head dim `d / h`), FFN
/// inner dim `ffn`. The serving harness uses this as its BERT request
/// unit; the full BERT models below are stacked copies of it. The
/// per-head attention GeMMs carry their true `h` repeat count — a
/// 16-head model really repeats them 16 times (no clamping; the old
/// `bert_serving` example clamped at 12 and silently mismeasured
/// BERT-Large).
pub fn encoder_layer(name: &str, seq: usize, d: usize, h: u64, ffn: usize) -> ModelWorkload {
    let dh = d / h as usize;
    let items = vec![
        gemm_item("attn.qkv", seq, d, 3 * d, 1),
        gemm_item("attn.scores", seq, dh, seq, h),
        gemm_item("attn.context", seq, seq, dh, h),
        gemm_item("attn.proj", seq, d, d, 1),
        gemm_item("ffn.fc1", seq, d, ffn, 1),
        gemm_item("ffn.fc2", seq, ffn, d, 1),
    ];
    ModelWorkload { name: name.to_string(), items }
}

/// Stack one encoder layer `layers` times (identical layers fold into
/// repeat counts, preserving `unique_shapes` semantics).
fn stacked(name: &str, layer: ModelWorkload, layers: u64) -> ModelWorkload {
    ModelWorkload {
        name: name.to_string(),
        items: layer
            .items
            .into_iter()
            .map(|mut item| {
                item.count *= layers;
                item
            })
            .collect(),
    }
}

/// BERT-Base (sequence length `seq`, batch 1): hidden 768, 12 layers,
/// 12 heads, FFN 3072 [31].
pub fn bert_base(seq: usize) -> ModelWorkload {
    stacked("BERT-Base", encoder_layer("BERT-Base layer", seq, 768, 12, 3072), 12)
}

/// BERT-Large (sequence length `seq`, batch 1): hidden 1024, 24 layers,
/// 16 heads, FFN 4096 [31]. The 16-head attention is the case the old
/// serving example's 12-repeat clamp silently mismeasured.
pub fn bert_large(seq: usize) -> ModelWorkload {
    stacked("BERT-Large", encoder_layer("BERT-Large layer", seq, 1024, 16, 4096), 24)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet18_conv1_shape() {
        let r = resnet18();
        let conv1 = &r.items[0];
        assert_eq!(conv1.shape, GemmShape::new(112 * 112, 147, 64));
    }

    #[test]
    fn mobilenet_has_thin_depthwise_gemms() {
        let m = mobilenet_v2();
        let dw: Vec<_> = m.items.iter().filter(|i| i.name.ends_with(".dw")).collect();
        assert!(!dw.is_empty());
        for item in dw {
            assert_eq!(item.shape.k, 9, "depthwise K = 3*3*1");
            assert_eq!(item.shape.n, 1, "depthwise N = 1 per group");
            assert!(item.count >= 16, "one GeMM per channel");
        }
    }

    #[test]
    fn mobilenet_channel_progression() {
        let m = mobilenet_v2();
        // last projection outputs 320 channels at 7x7
        let proj = m.items.iter().rev().find(|i| i.name.ends_with(".project")).unwrap();
        assert_eq!(proj.shape.n, 320);
        assert_eq!(proj.shape.m, 49);
    }

    #[test]
    fn vit_head_dims() {
        let v = vit_b16();
        let scores = v.items.iter().find(|i| i.name == "attn.scores").unwrap();
        assert_eq!(scores.shape, GemmShape::new(197, 64, 197));
        assert_eq!(scores.count, 144);
    }

    #[test]
    fn bert_scales_with_seq() {
        let b128 = bert_base(128).total_macs();
        let b512 = bert_base(512).total_macs();
        assert!(b512 > 4 * b128, "attention is superlinear in seq");
    }

    #[test]
    fn bert_base_is_twelve_stacked_layers() {
        let layer = encoder_layer("l", 256, 768, 12, 3072);
        let full = bert_base(256);
        assert_eq!(layer.total_macs() * 12, full.total_macs());
        let scores = full.items.iter().find(|i| i.name == "attn.scores").unwrap();
        assert_eq!(scores.count, 12 * 12, "12 layers x 12 heads");
        assert_eq!(scores.shape, GemmShape::new(256, 64, 256));
    }

    #[test]
    fn bert_large_keeps_true_head_count() {
        let full = bert_large(512);
        let scores = full.items.iter().find(|i| i.name == "attn.scores").unwrap();
        assert_eq!(scores.count, 24 * 16, "24 layers x 16 heads, unclamped");
        assert_eq!(scores.shape, GemmShape::new(512, 64, 512), "head dim 1024/16");
        let layer = encoder_layer("l", 512, 1024, 16, 4096);
        let heads = layer.items.iter().find(|i| i.name == "attn.context").unwrap();
        assert_eq!(heads.count, 16, "one encoder layer carries all 16 heads");
        // ~170 GMACs at seq 512 (published model statistics ballpark)
        let macs = full.total_macs() as f64;
        assert!((1.4e11..2.0e11).contains(&macs), "BERT-Large(512) ~170 GMACs, got {macs:e}");
    }
}
