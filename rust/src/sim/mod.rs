//! The cycle-accurate OpenGeMM platform simulator.
//!
//! One [`Platform`] instance wires together the RV32I host, the
//! CSRManager, the GeMM core, the three data streamers and the
//! multi-banked SPM, and advances them in lock-step, one clock cycle per
//! [`Platform::cycle`]. This is the evaluation vehicle standing in for
//! the paper's Verilator RTL simulation (Sec. 4.1): every utilization
//! number in the reproduced figures/tables comes out of this loop.
//!
//! ## Memory model
//!
//! SPM accesses are *epochs*: all port requests issued in the same cycle
//! (A-tile fetch, B-tile fetch, C-tile writeback) are arbitrated
//! together; the epoch occupies the interconnect for `max bank load`
//! cycles (single-ported banks). Streamers hold at most one outstanding
//! tile access each — exactly one request pipeline per streamer, as in
//! the RTL.
//!
//! ## DMA / data loading
//!
//! Operand data appears in the SPM "for free" at run start and results
//! are collected at run completion: the paper excludes DRAM<->SPM
//! movement from all cycle counts (Sec. 4.3 footnote), and so do we.
//!
//! ## Event model: cycle-skipping fast-forward
//!
//! Long stretches of simulated time are *provably inert*: the core is
//! stalled or idle, every streamer is waiting on an SPM access whose
//! completion cycle is already scheduled, and the host is sleeping off
//! a CSR-handshake stall with a known expiry. Stepping such stretches
//! one [`Platform::cycle`] at a time only increments counters.
//!
//! With [`SimOptions::fast_forward`] (default on), [`Platform`] runs an
//! event-driven engine instead: `next_event` computes the earliest
//! future cycle at which the frozen platform state can change — the
//! minimum over
//!
//! - the oldest in-flight fetch completion of each input streamer
//!   ([`InputStreamer::next_delivery`]),
//! - the outstanding writeback completion
//!   ([`OutputStreamer::next_delivery`]),
//! - each streamer's bank-gate expiry, when a new access is otherwise
//!   issuable ([`InputStreamer::next_issue`] /
//!   [`OutputStreamer::next_issue`]),
//! - the host's stall horizon ([`crate::host::Cpu::next_active_cycle`]),
//!
//! and `advance_to` jumps the clock there in one step, batch-accounting
//! the skipped cycles into the same [`SimMetrics`] / core-stall
//! counters the lockstep loop would have incremented. Whenever
//! anything *can* happen next cycle (a tile-MAC would issue, a latched
//! start is waiting, a run is completing, the host is runnable), the
//! engine degrades to plain single-cycle stepping, so the two modes are
//! **bit-identical** in every counter — a property enforced by the
//! `fast_forward_is_cycle_exact` differential test in
//! `tests/platform_properties.rs`.

pub mod metrics;

pub use metrics::{SimMetrics, UtilizationReport};

use std::sync::Arc;

use crate::compiler::{layout, CompiledCall, CompiledJob};
use crate::config::{Mechanisms, PlatformConfig};
use crate::csr::{CsrError, CsrManager};
use crate::gemm_core::{CoreEvent, CorePending, GemmCore};
use crate::host::{Cpu, CsrBus, StepResult};
use crate::spm::Spm;
use crate::streamer::{InputStreamer, OutputStreamer, TileArena};
use crate::util::json::{self, Json};

/// Simulation options.
#[derive(Debug, Clone, Copy)]
pub struct SimOptions {
    pub mechanisms: Mechanisms,
    /// Carry and verify real data through the datapath.
    pub functional: bool,
    /// Extra host-stall cycles per accelerator CSR access (CSRManager
    /// handshake / clock-domain crossing). 1 access = 1 + this.
    pub csr_latency: u64,
    /// Runaway guard.
    pub max_cycles: u64,
    /// Event-driven cycle skipping (see the module docs). Cycle-exact
    /// vs the lockstep loop; disable only to cross-check timing.
    pub fast_forward: bool,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions {
            mechanisms: Mechanisms::ALL,
            functional: false,
            csr_latency: 8,
            max_cycles: 2_000_000_000,
            fast_forward: true,
        }
    }
}

/// Result of running one compiled job.
#[derive(Debug, Clone, PartialEq)]
pub struct JobResult {
    pub metrics: SimMetrics,
    pub report: UtilizationReport,
    /// Result matrix (row-major M x N), functional mode only.
    pub c: Option<Vec<i32>>,
}

impl JobResult {
    /// Wire encoding (sharded-sweep result files): metrics, report and
    /// the functional result matrix all survive the round-trip, so a
    /// worker process's output merges transparently with in-process
    /// runs.
    pub fn to_json(&self) -> Json {
        let c = match &self.c {
            None => Json::Null,
            Some(c) => Json::Arr(c.iter().map(|&x| Json::num(x as f64)).collect()),
        };
        Json::obj(vec![
            ("metrics", self.metrics.to_json()),
            ("report", self.report.to_json()),
            ("c", c),
        ])
    }

    pub fn from_json(v: &Json) -> Result<JobResult, String> {
        let c = match json::get(v, "c")? {
            Json::Null => None,
            Json::Arr(items) => Some(
                items
                    .iter()
                    .map(|x| {
                        x.as_i64()
                            .and_then(|n| i32::try_from(n).ok())
                            .ok_or_else(|| "bad i32 in result matrix".to_string())
                    })
                    .collect::<Result<Vec<i32>, String>>()?,
            ),
            _ => return Err("field \"c\" is neither null nor an array".into()),
        };
        Ok(JobResult {
            metrics: SimMetrics::from_json(json::get(v, "metrics")?)?,
            report: UtilizationReport::from_json(json::get(v, "report")?)?,
            c,
        })
    }
}

/// Simulation failure.
#[derive(Debug)]
pub enum SimError {
    HostFault(crate::host::Fault),
    Csr(CsrError),
    CycleLimit(u64),
}

impl std::fmt::Display for SimError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SimError::HostFault(e) => write!(f, "host fault: {e}"),
            SimError::Csr(e) => write!(f, "csr error: {e}"),
            SimError::CycleLimit(c) => write!(f, "cycle limit {c} exceeded (deadlock?)"),
        }
    }
}

impl std::error::Error for SimError {}

/// Counting CSR bus: forwards to the CsrManager and counts accelerator
/// accesses so the platform can charge handshake latency.
struct CountingBus<'a> {
    csr: &'a mut CsrManager,
    accesses: u64,
}

impl CsrBus for CountingBus<'_> {
    fn csr_read(&mut self, addr: u32) -> Result<u32, CsrError> {
        self.accesses += 1;
        self.csr.read(addr)
    }
    fn csr_write(&mut self, addr: u32, value: u32) -> Result<(), CsrError> {
        self.accesses += 1;
        self.csr.write(addr, value)
    }
}

/// The platform.
pub struct Platform {
    pub cfg: PlatformConfig,
    pub opts: SimOptions,
    spm: Spm,
    core: GemmCore,
    csr: CsrManager,
    a_stream: InputStreamer,
    b_stream: InputStreamer,
    c_stream: OutputStreamer,
    host: Option<Cpu>,
    host_stall: u64,
    now: u64,
    addr_a: Vec<u64>,
    addr_b: Vec<u64>,
    addr_c: Vec<u64>,
    /// Operand-staging scratch: recycled tile buffers for the
    /// functional data plane (see [`TileArena`]). Survives
    /// [`Platform::reset_for_job`] so back-to-back jobs allocate
    /// nothing.
    arena: TileArena,
    pub metrics: SimMetrics,
    /// `cycle()` invocations actually executed this run — equals
    /// `metrics.total_cycles` in lockstep mode, (much) smaller with
    /// fast-forward. Host-effort telemetry only; not part of the
    /// simulated-hardware metrics.
    pub steps_executed: u64,
    /// Memoized raw streamer wake: the unclamped minimum over the six
    /// scheduled streamer event sources of [`Platform::next_event`]
    /// (deliveries and gated issues; the host horizon is NOT included
    /// — it shrinks on every `advance_to`). `None` = stale, recompute;
    /// `Some(w)` = the min is `w` until a streamer mutates (delivery
    /// fired, fetch/write committed, tile consumed, launch, run end).
    /// Every mutation site resets this to `None`. Events are absolute
    /// cycles, so the cache survives clock advances unchanged.
    sched_wake: Option<Option<u64>>,
    // job state
    job: Option<JobState>,
}

struct JobState {
    /// Shared with the [`CompiledJob`] — cloning the `Arc` per
    /// `run_job` call replaces the per-run deep copy of every call's
    /// placement and CSR image (benches re-run the same job thousands
    /// of times).
    calls: Arc<[CompiledCall]>,
    /// Which call the *next* start corresponds to.
    next_call: usize,
    /// Which call is currently running.
    running_call: Option<usize>,
    functional_inputs: Option<FunctionalInputs>,
    /// Assembled output (row-major m x n of the parent shape).
    c_out: Option<Vec<i32>>,
    parent_n: usize,
    parent_m: usize,
    run_active: bool,
    run_start_cycle: u64,
}

/// Per-call operand sub-blocks for functional mode, pre-sliced once per
/// job into two flat buffers (instead of two fresh `Vec`s per call).
struct FunctionalInputs {
    a: Vec<i8>,
    b: Vec<i8>,
    /// Per call: (range into `a`, range into `b`).
    spans: Vec<(std::ops::Range<usize>, std::ops::Range<usize>)>,
}

impl FunctionalInputs {
    /// Slice the parent operands into each call's blocks (the DMA's
    /// work list).
    fn slice(job: &CompiledJob, a: &[i8], b: &[i8]) -> FunctionalInputs {
        let (k, n) = (job.shape.k, job.shape.n);
        let a_total: usize = job.calls.iter().map(|c| c.block.shape.m * k).sum();
        let b_total: usize = job.calls.iter().map(|c| k * c.block.shape.n).sum();
        let mut a_buf = Vec::with_capacity(a_total);
        let mut b_buf = Vec::with_capacity(b_total);
        let mut spans = Vec::with_capacity(job.calls.len());
        for call in job.calls.iter() {
            let blk = &call.block;
            let a_start = a_buf.len();
            for i in 0..blk.shape.m {
                let src = (blk.m_off + i) * k;
                a_buf.extend_from_slice(&a[src..src + k]);
            }
            let b_start = b_buf.len();
            for i in 0..k {
                let src = i * n + blk.n_off;
                b_buf.extend_from_slice(&b[src..src + blk.shape.n]);
            }
            spans.push((a_start..a_buf.len(), b_start..b_buf.len()));
        }
        FunctionalInputs { a: a_buf, b: b_buf, spans }
    }

    /// The (A-block, B-block) slices of one call.
    fn call(&self, idx: usize) -> (&[i8], &[i8]) {
        let (ra, rb) = &self.spans[idx];
        (&self.a[ra.clone()], &self.b[rb.clone()])
    }
}

impl Platform {
    pub fn new(cfg: PlatformConfig, opts: SimOptions) -> Platform {
        cfg.validate().expect("invalid platform config");
        let mech = opts.mechanisms;
        let depth = if mech.prefetch { cfg.mem.d_stream.max(2) } else { 1 };
        let out_depth = if mech.prefetch { cfg.mem.d_stream.max(2) } else { 1 };
        Platform {
            spm: Spm::new(cfg.mem),
            core: GemmCore::new(cfg.core, opts.functional),
            csr: CsrManager::new(mech.config_preloading),
            a_stream: InputStreamer::new(depth, mech.prefetch),
            b_stream: InputStreamer::new(depth, mech.prefetch),
            c_stream: OutputStreamer::new(out_depth),
            host: None,
            host_stall: 0,
            now: 0,
            addr_a: Vec::with_capacity(64),
            addr_b: Vec::with_capacity(64),
            addr_c: Vec::with_capacity(64),
            arena: TileArena::new(),
            metrics: SimMetrics::default(),
            steps_executed: 0,
            sched_wake: None,
            cfg,
            opts,
            job: None,
        }
    }

    /// Run a compiled job to completion. `a`/`b` are the parent operand
    /// matrices (row-major, true dims) in functional mode.
    pub fn run_job(
        &mut self,
        job: &CompiledJob,
        a: Option<&[i8]>,
        b: Option<&[i8]>,
    ) -> Result<JobResult, SimError> {
        let (m, k, n) = (job.shape.m, job.shape.k, job.shape.n);
        let functional = self.opts.functional;
        if functional {
            assert_eq!(a.map(|x| x.len()), Some(m * k), "A operand size");
            assert_eq!(b.map(|x| x.len()), Some(k * n), "B operand size");
        }

        // Pre-slice per-call operand blocks once, into flat buffers.
        let functional_inputs =
            functional.then(|| FunctionalInputs::slice(job, a.unwrap(), b.unwrap()));

        self.reset_run_state();
        self.job = Some(JobState {
            calls: Arc::clone(&job.calls),
            next_call: 0,
            running_call: None,
            functional_inputs,
            c_out: functional.then(|| vec![0i32; m * n]),
            parent_m: m,
            parent_n: n,
            run_active: false,
            run_start_cycle: 0,
        });
        self.host = Some(Cpu::new(job.program.clone(), 1 << 16));

        let fast_forward = self.opts.fast_forward;
        while !self.finished() {
            if fast_forward {
                if let Some(t) = self.next_event() {
                    self.advance_to(t);
                }
            }
            self.cycle()?;
            if self.metrics.total_cycles > self.opts.max_cycles {
                return Err(SimError::CycleLimit(self.opts.max_cycles));
            }
        }

        let job_state = self.job.take().unwrap();
        let su = job.spatial_utilization(&self.cfg);
        self.metrics.spm = self.spm.stats.clone();
        let report = UtilizationReport::from_metrics(su, &self.metrics);
        Ok(JobResult { metrics: self.metrics.clone(), report, c: job_state.c_out })
    }

    /// Re-arm this platform for a new job with new options — the
    /// Coordinator's per-worker reuse path. Equivalent to constructing
    /// a fresh `Platform::new(cfg, opts)` except that the SPM storage,
    /// the address scratch vectors, and the tile arena keep their
    /// allocations; `run_job` rebuilds every piece of per-run state
    /// (core, CSRs, streamers, metrics) regardless, and the layout
    /// packers fully overwrite every SPM region a functional run reads.
    pub fn reset_for_job(&mut self, opts: SimOptions) {
        self.opts = opts;
        self.host = None;
        self.job = None;
        self.sched_wake = None;
    }

    fn reset_run_state(&mut self) {
        let mech = self.opts.mechanisms;
        let depth = if mech.prefetch { self.cfg.mem.d_stream.max(2) } else { 1 };
        self.core = GemmCore::new(self.cfg.core, self.opts.functional);
        self.csr = CsrManager::new(mech.config_preloading);
        self.a_stream = InputStreamer::new(depth, mech.prefetch);
        self.b_stream = InputStreamer::new(depth, mech.prefetch);
        self.c_stream = OutputStreamer::new(depth);
        self.host_stall = 0;
        self.now = 0;
        self.metrics = SimMetrics::default();
        self.steps_executed = 0;
        self.sched_wake = None;
        self.spm.reset_stats();
    }

    fn finished(&self) -> bool {
        let host_done = self.host.as_ref().map(|h| h.halted()).unwrap_or(true);
        let job_quiet = self
            .job
            .as_ref()
            .map(|j| !j.run_active)
            .unwrap_or(true);
        host_done && !self.csr.is_busy() && job_quiet
    }

    /// Advance the platform one clock cycle.
    pub fn cycle(&mut self) -> Result<(), SimError> {
        self.now += 1;
        self.metrics.total_cycles += 1;
        self.steps_executed += 1;
        let now = self.now;

        // ---- 1. deliver completed memory traffic --------------------
        // a delivery that fires consumes a scheduled event and frees a
        // pipeline slot — the memoized streamer wake is stale
        if self.a_stream.next_delivery().is_some_and(|t| t <= now)
            || self.b_stream.next_delivery().is_some_and(|t| t <= now)
        {
            self.sched_wake = None;
        }
        self.a_stream.deliver_ready(now);
        self.b_stream.deliver_ready(now);
        if let Some(tile) = self.c_stream.deliver_ready(now) {
            self.sched_wake = None;
            self.commit_output_tile(tile);
        }

        // ---- 2. issue new memory requests (per-streamer pipelines) --
        self.issue_memory(now);

        // ---- 3. core cycle -------------------------------------------
        match self.core.step(
            &mut self.a_stream,
            &mut self.b_stream,
            &mut self.c_stream,
            &mut self.arena,
        ) {
            CoreEvent::Idle => self.metrics.idle_cycles += 1,
            CoreEvent::Stalled(reason) => {
                use crate::gemm_core::StallReason::*;
                match reason {
                    InputA => self.metrics.stall_input_a += 1,
                    InputB => self.metrics.stall_input_b += 1,
                    Output => self.metrics.stall_output += 1,
                }
            }
            CoreEvent::Computed { finished, .. } => {
                // a tile-MAC consumed input heads and may have queued
                // an output tile — streamer occupancy changed
                self.sched_wake = None;
                self.metrics.compute_cycles += 1;
                if finished {
                    // run completion is gated on the output drain below
                    if let Some(job) = self.job.as_mut() {
                        debug_assert!(job.run_active);
                    }
                }
            }
        }

        // ---- 4. run completion --------------------------------------
        let run_done = self
            .job
            .as_ref()
            .map(|j| j.run_active && !self.core.busy() && self.c_stream.is_drained())
            .unwrap_or(false);
        if run_done {
            self.finish_run();
        }

        // ---- 5. accelerator start -----------------------------------
        if !self.core.busy() {
            if let Some(regs) = self.csr.take_start() {
                self.launch(regs);
            }
        }

        // ---- 6. host cycle -------------------------------------------
        if self.host_stall > 0 {
            self.host_stall -= 1;
            self.metrics.host_csr_stall += 1;
        } else if let Some(host) = self.host.as_mut() {
            if !host.halted() {
                let mut bus = CountingBus { csr: &mut self.csr, accesses: 0 };
                match host.step(&mut bus) {
                    StepResult::Ran { cycles } => {
                        let extra = bus.accesses * self.opts.csr_latency;
                        self.host_stall = (cycles - 1) + extra;
                        self.metrics.host_instret += 1;
                    }
                    StepResult::Halted => {}
                    StepResult::Fault(f) => return Err(SimError::HostFault(f)),
                }
            }
        }

        Ok(())
    }

    /// The earliest absolute cycle `> self.now` at which the platform
    /// state can change, or `None` when no event is scheduled (a
    /// deadlocked platform; the caller then falls back to lockstep
    /// stepping and the runaway guard).
    ///
    /// Returning `self.now + 1` means "something can happen next cycle
    /// — simulate it"; any later value proves every cycle before it is
    /// a pure counter increment (see [`Platform::advance_to`]).
    ///
    /// The six streamer sources are scanned only when a streamer has
    /// mutated since the last call (`sched_wake` memo); on the long
    /// config-bound stretches where the platform calls this every
    /// simulated step with frozen streamers, the scan collapses to a
    /// memo read plus the host horizon. Takes `&mut self` only for the
    /// memo — observable state is untouched.
    fn next_event(&mut self) -> Option<u64> {
        let next = self.now + 1;

        // Immediately-actionable states: the coming cycle must be
        // simulated for real.
        if self.core.pending(&self.a_stream, &self.b_stream, &self.c_stream)
            == CorePending::Compute
        {
            return Some(next);
        }
        if self.csr.has_fired_start() && !self.core.busy() {
            return Some(next); // a latched start launches next cycle
        }
        let run_completing = self
            .job
            .as_ref()
            .map(|j| j.run_active && !self.core.busy() && self.c_stream.is_drained())
            .unwrap_or(false);
        if run_completing {
            return Some(next);
        }
        if let Some(host) = self.host.as_ref() {
            if !host.halted() && self.host_stall == 0 {
                return Some(next); // host retires an instruction
            }
        }

        // Otherwise the state is frozen until the earliest scheduled
        // event: a delivery, a bank-gate expiry that unblocks an issue,
        // or the host's stall horizon. The streamer minimum is memoized
        // RAW (unclamped): since min(max(e_i, next)) == max(min(e_i),
        // next), clamping the cached minimum once is identical to
        // clamping each source, and the raw value stays valid across
        // clock advances.
        let streamer_wake = match self.sched_wake {
            Some(w) => w,
            None => {
                let mut wake: Option<u64> = None;
                let mut consider = |e: Option<u64>| {
                    if let Some(e) = e {
                        wake = Some(wake.map_or(e, |w: u64| w.min(e)));
                    }
                };
                let a_starved = self.core.busy() && self.a_stream.head().is_none();
                let b_starved = self.core.busy() && self.b_stream.head().is_none();
                consider(self.a_stream.next_delivery());
                consider(self.b_stream.next_delivery());
                consider(self.c_stream.next_delivery());
                consider(self.a_stream.next_issue(a_starved));
                consider(self.b_stream.next_issue(b_starved));
                consider(self.c_stream.next_issue());
                self.sched_wake = Some(wake);
                wake
            }
        };
        // The host horizon shrinks with every advance (the stall budget
        // drains), so it is always computed fresh.
        let mut wake = streamer_wake.map(|e| e.max(next));
        if let Some(host) = self.host.as_ref() {
            if let Some(e) = host.next_active_cycle(self.now, self.host_stall) {
                let e = e.max(next);
                wake = Some(wake.map_or(e, |w| w.min(e)));
            }
        }
        wake
    }

    /// Fast-forward the clock to just before event time `t`,
    /// batch-accounting the skipped cycles exactly as `t - now - 1`
    /// no-op invocations of [`Platform::cycle`] would have: total /
    /// idle / stall counters (platform *and* core statistics) and the
    /// host's CSR-stall budget. Must only be called with the `t`
    /// returned by [`Platform::next_event`].
    fn advance_to(&mut self, t: u64) {
        debug_assert!(t > self.now);
        let skip = t - (self.now + 1);
        if skip == 0 {
            return;
        }
        match self.core.pending(&self.a_stream, &self.b_stream, &self.c_stream) {
            CorePending::Idle => self.metrics.add_idle(skip),
            CorePending::Stalled(reason) => {
                self.metrics.add_stalls(reason, skip);
                self.core.account_stalls(reason, skip);
            }
            CorePending::Compute => unreachable!("fast-forward across a compute cycle"),
        }
        if let Some(host) = self.host.as_ref() {
            if !host.halted() {
                debug_assert!(self.host_stall >= skip, "host wakes inside a fast-forward window");
                self.host_stall -= skip;
                self.metrics.add_host_csr_stalls(skip);
            }
        }
        self.now += skip;
        self.metrics.total_cycles += skip;
    }

    /// Per-streamer memory issue. Each input streamer pipelines up to
    /// its buffer depth of outstanding tile fetches; its banks are busy
    /// for `max own-bank load` cycles per fetch, and a fetch issued the
    /// same cycle as the other input streamer pays one arbitration
    /// cycle per shared bank group (the read crossbar serializes them).
    /// The output writer runs on the independent write-port network
    /// (banks are 1R1W).
    fn issue_memory(&mut self, now: u64) {
        let word = self.cfg.mem.word_bytes() as u64;
        let word_shift = self.spm.word_shift();
        let n_bank = self.cfg.mem.n_bank as u32;
        let rd_lat = self.cfg.mem.read_latency;
        let wr_lat = self.cfg.mem.write_latency;
        let a_starved = self.core.busy() && self.a_stream.head().is_none();
        let b_starved = self.core.busy() && self.b_stream.head().is_none();
        let functional = self.opts.functional;

        let a_issues = self.a_stream.wants_fetch(now, a_starved);
        let b_issues = self.b_stream.wants_fetch(now, b_starved);

        // Timing-only fast path: the precomputed bank pattern gives the
        // access cost and bank mask without materializing addresses.
        let mut a_banks = 0u64; // banks touched by A this cycle
        if a_issues {
            self.sched_wake = None; // a new fetch schedules new events
            let (cost, mask, pos, data) = match (functional, self.a_stream.pattern) {
                (false, Some(p)) if !p.self_conflict => {
                    let (pos, base) = self.a_stream.begin_fetch_timing();
                    let base_bank = ((base as u64) >> word_shift) & (n_bank - 1) as u64;
                    let mask = p.mask_at(base_bank as u32);
                    self.spm.note_fast_access(self.a_stream.agu.ports() as u64, 1);
                    (1, mask, pos, None)
                }
                _ => {
                    let pos = self.a_stream.begin_fetch(word, &mut self.addr_a);
                    let cost = self.spm.read_cost(&self.addr_a);
                    let mut mask = 0u64;
                    for &w in &self.addr_a {
                        mask |= 1u64 << self.spm.bank_of(w);
                    }
                    let data = functional
                        .then(|| Self::read_tile(&self.spm, &mut self.arena, word, &self.addr_a));
                    (cost, mask, pos, data)
                }
            };
            a_banks = mask;
            self.a_stream
                .commit_fetch(pos, data, now + cost + rd_lat - 1, now + cost);
        }
        if b_issues {
            self.sched_wake = None;
            let (mut cost, mask, pos, data) = match (functional, self.b_stream.pattern) {
                (false, Some(p)) if !p.self_conflict => {
                    let (pos, base) = self.b_stream.begin_fetch_timing();
                    let base_bank = ((base as u64) >> word_shift) & (n_bank - 1) as u64;
                    let mask = p.mask_at(base_bank as u32);
                    self.spm.note_fast_access(self.b_stream.agu.ports() as u64, 1);
                    (1u64, mask, pos, None)
                }
                _ => {
                    let pos = self.b_stream.begin_fetch(word, &mut self.addr_b);
                    let cost = self.spm.read_cost(&self.addr_b);
                    let mut mask = 0u64;
                    for &w in &self.addr_b {
                        mask |= 1u64 << self.spm.bank_of(w);
                    }
                    let data = functional
                        .then(|| Self::read_tile(&self.spm, &mut self.arena, word, &self.addr_b));
                    (cost, mask, pos, data)
                }
            };
            if a_issues && a_banks & mask != 0 {
                // same-cycle arbitration against A on shared banks
                cost += 1;
                self.spm.stats.conflict_cycles += 1;
            }
            self.b_stream
                .commit_fetch(pos, data, now + cost + rd_lat - 1, now + cost);
        }
        if self.c_stream.wants_write(now) {
            self.sched_wake = None;
            match (functional, self.c_stream.pattern) {
                (false, Some(p)) if !p.self_conflict => {
                    let (tile, _base) = self.c_stream.begin_write_timing();
                    self.spm.note_fast_access(self.c_stream.agu.ports() as u64, 1);
                    self.c_stream.commit_write(tile, now + wr_lat, now + 1);
                }
                _ => {
                    let tile = self.c_stream.begin_write(word, &mut self.addr_c);
                    let cost = self.spm.write_cost(&self.addr_c);
                    self.c_stream.commit_write(tile, now + cost + wr_lat - 1, now + cost);
                }
            }
        }
    }

    /// Functional commit of a completed C' tile through the C AGU; the
    /// tile buffer returns to the arena afterwards.
    fn commit_output_tile(&mut self, tile: crate::streamer::OutTile) {
        let Some(data) = tile.data else { return };
        let word = self.cfg.mem.word_bytes() as u64;
        let agu = self.c_stream.agu;
        let per_word = (word / 4) as usize;
        for port in 0..agu.ports() as u64 {
            let byte = agu.byte_addr(tile.m1, tile.n1, 0, port);
            let idx = port as usize * per_word;
            if idx < data.len() {
                let end = (idx + per_word).min(data.len());
                self.spm.write_i32(byte, &data[idx..end]);
            }
        }
        self.arena.release_i32(data);
    }

    /// Bulk functional tile fetch: one gathered word read per port into
    /// an arena-recycled buffer (the seed allocated a fresh `Box` and
    /// resolved the word mapping per byte).
    fn read_tile(
        spm: &Spm,
        arena: &mut TileArena,
        word: u64,
        word_addrs: &[u64],
    ) -> Box<[i8]> {
        let mut out = arena.acquire_i8(word_addrs.len() * word as usize);
        spm.read_ports_i8(word_addrs, word as usize, &mut out);
        out
    }

    fn launch(&mut self, regs: crate::csr::ConfigRegs) {
        let word = self.cfg.mem.word_bytes();
        let bounds = regs.bounds();
        let job = self.job.as_mut().expect("start without a job");
        let call_idx = job.next_call;
        job.next_call = (job.next_call + 1) % job.calls.len();
        job.running_call = Some(call_idx);
        job.run_active = true;
        job.run_start_cycle = self.metrics.total_cycles;
        self.metrics.starts += 1;

        // "DMA": place this call's operands (functional mode only; zero
        // simulated cycles per the paper's accounting).
        if let Some(inputs) = job.functional_inputs.as_ref() {
            let call = &job.calls[call_idx];
            let (asub, bsub) = inputs.call(call_idx);
            layout::pack_a(
                &mut self.spm,
                &self.cfg,
                &call.placement,
                asub,
                call.block.shape.m,
                call.block.shape.k,
            );
            layout::pack_b(
                &mut self.spm,
                &self.cfg,
                &call.placement,
                bsub,
                call.block.shape.k,
                call.block.shape.n,
            );
        }

        let wb = word as u64;
        let nb = self.cfg.mem.n_bank;
        self.a_stream.configure2(regs.a_agu(&self.cfg.core, word), bounds, wb, nb);
        self.b_stream.configure2(regs.b_agu(&self.cfg.core, word), bounds, wb, nb);
        self.c_stream.configure2(regs.c_agu(&self.cfg.core, word), wb, nb);
        self.core.start(bounds).expect("loop bounds validated at compile time");
        self.sched_wake = None; // reconfigured streamers, core now busy
    }

    fn finish_run(&mut self) {
        let job = self.job.as_mut().expect("run completion without a job");
        let call_idx = job.running_call.take().expect("no running call");
        job.run_active = false;
        self.metrics.kernel_cycles += self.metrics.total_cycles - job.run_start_cycle;
        self.metrics.runs_completed += 1;

        // collect functional results into the parent C
        if let Some(c_out) = job.c_out.as_mut() {
            let call = &job.calls[call_idx];
            let c = layout::unpack_c(
                &self.spm,
                &self.cfg,
                &call.placement,
                call.block.shape.m,
                call.block.shape.n,
            );
            let n = job.parent_n;
            for i in 0..call.block.shape.m {
                for j in 0..call.block.shape.n {
                    c_out[(call.block.m_off + i) * n + (call.block.n_off + j)] =
                        c[i * call.block.shape.n + j];
                }
            }
            debug_assert!(call.block.m_off + call.block.shape.m <= job.parent_m);
        }

        // CPL: a pre-loaded start may fire instantly
        self.csr.notify_done();
        self.sched_wake = None; // core no longer busy: starvation gates flip
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile_gemm, GemmShape, Layout};
    use crate::util::rng::Pcg32;

    fn run(
        shape: GemmShape,
        layout: Layout,
        mech: Mechanisms,
        repeats: u32,
        functional: bool,
    ) -> (JobResult, CompiledJob) {
        run_mode(shape, layout, mech, repeats, functional, true)
    }

    fn run_mode(
        shape: GemmShape,
        layout: Layout,
        mech: Mechanisms,
        repeats: u32,
        functional: bool,
        fast_forward: bool,
    ) -> (JobResult, CompiledJob) {
        let cfg = PlatformConfig::case_study();
        let job = compile_gemm(&cfg, shape, layout, repeats, mech.config_preloading).unwrap();
        let opts = SimOptions { mechanisms: mech, functional, fast_forward, ..Default::default() };
        let mut platform = Platform::new(cfg, opts);
        let (a, b) = if functional {
            let mut rng = Pcg32::seeded(42);
            let mut a = vec![0i8; shape.m * shape.k];
            let mut b = vec![0i8; shape.k * shape.n];
            rng.fill_i8(&mut a);
            rng.fill_i8(&mut b);
            (Some(a), Some(b))
        } else {
            (None, None)
        };
        let res = platform.run_job(&job, a.as_deref(), b.as_deref()).unwrap();
        (res, job)
    }

    fn naive_gemm(a: &[i8], b: &[i8], m: usize, k: usize, n: usize) -> Vec<i32> {
        let mut c = vec![0i32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0i32;
                for kk in 0..k {
                    acc = acc
                        .wrapping_add((a[i * k + kk] as i32).wrapping_mul(b[kk * n + j] as i32));
                }
                c[i * n + j] = acc;
            }
        }
        c
    }

    #[test]
    fn functional_gemm_matches_naive() {
        let shape = GemmShape::new(13, 22, 17);
        let (res, _) = run(shape, Layout::TiledInterleaved, Mechanisms::ALL, 1, true);
        let mut rng = Pcg32::seeded(42);
        let mut a = vec![0i8; shape.m * shape.k];
        let mut b = vec![0i8; shape.k * shape.n];
        rng.fill_i8(&mut a);
        rng.fill_i8(&mut b);
        assert_eq!(res.c.unwrap(), naive_gemm(&a, &b, 13, 22, 17));
    }

    #[test]
    fn functional_gemm_row_major_layout() {
        let shape = GemmShape::new(32, 40, 24);
        let (res, _) = run(shape, Layout::RowMajor, Mechanisms::BASELINE, 1, true);
        let mut rng = Pcg32::seeded(42);
        let mut a = vec![0i8; shape.m * shape.k];
        let mut b = vec![0i8; shape.k * shape.n];
        rng.fill_i8(&mut a);
        rng.fill_i8(&mut b);
        assert_eq!(res.c.unwrap(), naive_gemm(&a, &b, 32, 40, 24));
    }

    #[test]
    fn functional_split_job_matches_naive() {
        // 256^3 splits into multiple calls
        let shape = GemmShape::new(256, 64, 256);
        let (res, job) = run(shape, Layout::TiledInterleaved, Mechanisms::ALL, 1, true);
        assert!(job.calls.len() >= 1);
        let mut rng = Pcg32::seeded(42);
        let mut a = vec![0i8; shape.m * shape.k];
        let mut b = vec![0i8; shape.k * shape.n];
        rng.fill_i8(&mut a);
        rng.fill_i8(&mut b);
        assert_eq!(res.c.unwrap(), naive_gemm(&a, &b, 256, 64, 256));
    }

    #[test]
    fn mechanisms_strictly_improve_utilization() {
        let shape = GemmShape::new(128, 128, 128);
        let (r1, _) = run(shape, Layout::RowMajor, Mechanisms::BASELINE, 10, false);
        let (r2, _) = run(shape, Layout::RowMajor, Mechanisms::CPL, 10, false);
        let (r3, _) = run(shape, Layout::RowMajor, Mechanisms::CPL_BUF, 10, false);
        let (r4, _) = run(shape, Layout::TiledInterleaved, Mechanisms::ALL, 10, false);
        let u = |r: &JobResult| r.report.overall;
        assert!(u(&r2) >= u(&r1), "CPL must not hurt: {} vs {}", u(&r2), u(&r1));
        assert!(u(&r3) > u(&r2), "prefetch must help: {} vs {}", u(&r3), u(&r2));
        assert!(u(&r4) > u(&r3), "SMA must help: {} vs {}", u(&r4), u(&r3));
        assert!(u(&r4) > 0.85, "full mechanisms should approach peak: {}", u(&r4));
    }

    #[test]
    fn compute_cycles_equal_ideal_times_repeats() {
        let shape = GemmShape::new(64, 64, 64);
        let (res, job) = run(shape, Layout::TiledInterleaved, Mechanisms::ALL, 10, false);
        let cfg = PlatformConfig::case_study();
        assert_eq!(res.metrics.compute_cycles, job.ideal_cycles(&cfg) * 10);
        assert_eq!(res.metrics.starts, 10);
        assert_eq!(res.metrics.runs_completed, 10);
    }

    #[test]
    fn aligned_all_mech_utilization_near_one() {
        let shape = GemmShape::new(128, 128, 128);
        let (res, _) = run(shape, Layout::TiledInterleaved, Mechanisms::ALL, 10, false);
        assert!(
            res.report.overall > 0.9,
            "expected near-peak utilization, got {:?}",
            res.report
        );
    }

    #[test]
    fn baseline_utilization_is_much_lower() {
        let shape = GemmShape::new(64, 64, 64);
        let (res, _) = run(shape, Layout::RowMajor, Mechanisms::BASELINE, 10, false);
        assert!(
            res.report.overall < 0.5,
            "baseline should be slow, got {:?}",
            res.report
        );
    }

    #[test]
    fn fast_forward_matches_lockstep_smoke() {
        // the exhaustive randomized grid lives in
        // tests/platform_properties.rs; this pins a few known-tricky
        // corners (deep-K stalls, config-bound tiny shapes, splits)
        let cases = [
            (GemmShape::new(16, 256, 16), Layout::RowMajor, Mechanisms::BASELINE, 3),
            (GemmShape::new(8, 8, 8), Layout::TiledInterleaved, Mechanisms::BASELINE, 10),
            (GemmShape::new(64, 64, 64), Layout::TiledInterleaved, Mechanisms::ALL, 10),
            (GemmShape::new(48, 40, 56), Layout::TiledContiguous, Mechanisms::CPL_BUF, 2),
            (GemmShape::new(256, 64, 256), Layout::TiledInterleaved, Mechanisms::ALL, 1),
        ];
        for (shape, layout, mech, repeats) in cases {
            let (ff, _) = run_mode(shape, layout, mech, repeats, false, true);
            let (ls, _) = run_mode(shape, layout, mech, repeats, false, false);
            assert_eq!(
                ff.metrics, ls.metrics,
                "fast-forward metrics diverge for {shape:?} {layout:?} {}",
                mech.label()
            );
            assert_eq!(ff.report, ls.report, "reports diverge for {shape:?}");
        }
    }

    #[test]
    fn fast_forward_skips_cycles_in_bulk() {
        // on a stall-heavy workload (no prefetch, deep K, conflicting
        // row-major layout) the engine must execute far fewer `cycle()`
        // steps than simulated cycles — that ratio is the speedup lever
        let cfg = PlatformConfig::case_study();
        let job =
            compile_gemm(&cfg, GemmShape::new(16, 256, 16), Layout::RowMajor, 3, false).unwrap();
        let opts = SimOptions {
            mechanisms: Mechanisms::BASELINE,
            fast_forward: true,
            ..Default::default()
        };
        let mut platform = Platform::new(cfg, opts);
        platform.run_job(&job, None, None).unwrap();
        let total = platform.metrics.total_cycles;
        let steps = platform.steps_executed;
        assert!(
            steps * 2 < total,
            "expected >50% of cycles skipped, got {steps} steps for {total} cycles"
        );
    }

    #[test]
    fn tiny_gemm_dominated_by_config() {
        let shape = GemmShape::new(8, 8, 8);
        let (res, _) = run(shape, Layout::TiledInterleaved, Mechanisms::BASELINE, 10, false);
        // 10 tile-MACs of work under hundreds of config cycles
        assert!(res.report.temporal < 0.1, "{:?}", res.report);
    }
}
