//! RV32I + Zicsr instruction-set simulator: the lightweight Snitch-class
//! host core (Sec. 3.1).
//!
//! Single-issue, in-order: one instruction per cycle, taken
//! control-transfers cost [`BRANCH_TAKEN_CYCLES`] (no branch predictor —
//! the fetch bubble of a tiny in-order core). Accelerator CSRs in the
//! custom window are routed to a [`CsrBus`] (the platform's CSRManager);
//! `mcycle`/`mcycleh` read the core cycle counter.

use crate::csr::{CsrError, CsrManager};

/// Cycles charged for a taken branch/jump (fetch bubble).
pub const BRANCH_TAKEN_CYCLES: u64 = 2;
/// Data-RAM base address (host-local TCDM slice for stack/locals).
pub const DATA_BASE: u32 = 0x1000_0000;

/// Where the host's CSR traffic goes.
pub trait CsrBus {
    fn csr_read(&mut self, addr: u32) -> Result<u32, CsrError>;
    fn csr_write(&mut self, addr: u32, value: u32) -> Result<(), CsrError>;
}

impl CsrBus for CsrManager {
    fn csr_read(&mut self, addr: u32) -> Result<u32, CsrError> {
        self.read(addr)
    }
    fn csr_write(&mut self, addr: u32, value: u32) -> Result<(), CsrError> {
        self.write(addr, value)
    }
}

/// Execution fault.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Fault {
    BadInstruction { pc: u32, word: u32 },
    BadFetch { pc: u32 },
    BadLoad { pc: u32, addr: u32 },
    BadStore { pc: u32, addr: u32 },
    Csr { pc: u32, err: CsrError },
}

impl std::fmt::Display for Fault {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Fault::BadInstruction { pc, word } => {
                write!(f, "illegal instruction {word:#010x} at pc={pc:#x}")
            }
            Fault::BadFetch { pc } => write!(f, "fetch outside program at pc={pc:#x}"),
            Fault::BadLoad { pc, addr } => write!(f, "bad load {addr:#x} at pc={pc:#x}"),
            Fault::BadStore { pc, addr } => write!(f, "bad store {addr:#x} at pc={pc:#x}"),
            Fault::Csr { pc, err } => write!(f, "CSR fault at pc={pc:#x}: {err}"),
        }
    }
}

impl std::error::Error for Fault {}

/// Outcome of one step.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StepResult {
    /// Executed an instruction, consuming `cycles`.
    Ran { cycles: u64 },
    /// Hit `ebreak`/`ecall` — the program is done.
    Halted,
    /// Execution fault (model/program bug).
    Fault(Fault),
}

/// The host core.
#[derive(Debug, Clone)]
pub struct Cpu {
    pub regs: [u32; 32],
    pub pc: u32,
    program: Vec<u32>,
    data: Vec<u8>,
    /// Total cycles retired (including branch bubbles).
    pub cycles: u64,
    /// Instructions retired.
    pub instret: u64,
    halted: bool,
}

impl Cpu {
    /// Create a CPU with the given program (loaded at address 0) and a
    /// data RAM of `data_size` bytes at [`DATA_BASE`].
    pub fn new(program: Vec<u32>, data_size: usize) -> Cpu {
        let mut cpu = Cpu {
            regs: [0; 32],
            pc: 0,
            program,
            data: vec![0; data_size],
            cycles: 0,
            instret: 0,
            halted: false,
        };
        // stack pointer at top of data RAM
        cpu.regs[2] = DATA_BASE + data_size as u32;
        cpu
    }

    pub fn halted(&self) -> bool {
        self.halted
    }

    /// Stall-horizon report for the event-driven platform: the next
    /// absolute cycle at which this core retires an instruction, given
    /// the current cycle and the platform's remaining external-stall
    /// budget (CSR handshake / multi-cycle-op debt). `None` once
    /// halted — a halted core never wakes the platform again. The
    /// platform fast-forwards to this horizon instead of polling the
    /// stalled core every cycle.
    pub fn next_active_cycle(&self, now: u64, stall: u64) -> Option<u64> {
        if self.halted {
            None
        } else {
            Some(now + stall + 1)
        }
    }

    /// Restart the program counter (for re-running the same program).
    pub fn restart(&mut self) {
        self.pc = 0;
        self.halted = false;
    }

    #[inline]
    fn x(&self, r: u32) -> u32 {
        self.regs[r as usize]
    }

    #[inline]
    fn set_x(&mut self, r: u32, v: u32) {
        if r != 0 {
            self.regs[r as usize] = v;
        }
    }

    fn load(&self, pc: u32, addr: u32, size: u32, signed: bool) -> Result<u32, Fault> {
        let off = addr.wrapping_sub(DATA_BASE) as usize;
        if off + size as usize > self.data.len() {
            return Err(Fault::BadLoad { pc, addr });
        }
        let mut v = 0u32;
        for i in 0..size {
            v |= (self.data[off + i as usize] as u32) << (8 * i);
        }
        if signed {
            let shift = 32 - 8 * size;
            v = (((v << shift) as i32) >> shift) as u32;
        }
        Ok(v)
    }

    fn store(&mut self, pc: u32, addr: u32, size: u32, value: u32) -> Result<(), Fault> {
        let off = addr.wrapping_sub(DATA_BASE) as usize;
        if off + size as usize > self.data.len() {
            return Err(Fault::BadStore { pc, addr });
        }
        for i in 0..size {
            self.data[off + i as usize] = (value >> (8 * i)) as u8;
        }
        Ok(())
    }

    /// Execute one instruction. CSR traffic goes to `bus`.
    pub fn step<B: CsrBus>(&mut self, bus: &mut B) -> StepResult {
        if self.halted {
            return StepResult::Halted;
        }
        let pc = self.pc;
        let idx = (pc / 4) as usize;
        if pc % 4 != 0 || idx >= self.program.len() {
            return StepResult::Fault(Fault::BadFetch { pc });
        }
        let w = self.program[idx];
        let opcode = w & 0x7f;
        let rd = (w >> 7) & 0x1f;
        let funct3 = (w >> 12) & 0x7;
        let rs1 = (w >> 15) & 0x1f;
        let rs2 = (w >> 20) & 0x1f;
        let funct7 = w >> 25;
        let imm_i = (w as i32) >> 20;
        let mut next_pc = pc.wrapping_add(4);
        let mut cycles = 1u64;

        macro_rules! fault {
            () => {
                return StepResult::Fault(Fault::BadInstruction { pc, word: w })
            };
        }

        match opcode {
            0x37 => self.set_x(rd, w & 0xffff_f000), // LUI
            0x17 => self.set_x(rd, pc.wrapping_add(w & 0xffff_f000)), // AUIPC
            0x6f => {
                // JAL
                let imm = (((w >> 31) & 1) << 20)
                    | (((w >> 12) & 0xff) << 12)
                    | (((w >> 20) & 1) << 11)
                    | (((w >> 21) & 0x3ff) << 1);
                let imm = ((imm << 11) as i32) >> 11;
                self.set_x(rd, next_pc);
                next_pc = pc.wrapping_add(imm as u32);
                cycles = BRANCH_TAKEN_CYCLES;
            }
            0x67 => {
                // JALR
                if funct3 != 0 {
                    fault!();
                }
                let target = self.x(rs1).wrapping_add(imm_i as u32) & !1;
                self.set_x(rd, next_pc);
                next_pc = target;
                cycles = BRANCH_TAKEN_CYCLES;
            }
            0x63 => {
                // branches
                let imm = (((w >> 31) & 1) << 12)
                    | (((w >> 7) & 1) << 11)
                    | (((w >> 25) & 0x3f) << 5)
                    | (((w >> 8) & 0xf) << 1);
                let imm = ((imm << 19) as i32) >> 19;
                let (a, b) = (self.x(rs1), self.x(rs2));
                let taken = match funct3 {
                    0x0 => a == b,
                    0x1 => a != b,
                    0x4 => (a as i32) < (b as i32),
                    0x5 => (a as i32) >= (b as i32),
                    0x6 => a < b,
                    0x7 => a >= b,
                    _ => fault!(),
                };
                if taken {
                    next_pc = pc.wrapping_add(imm as u32);
                    cycles = BRANCH_TAKEN_CYCLES;
                }
            }
            0x03 => {
                // loads
                let addr = self.x(rs1).wrapping_add(imm_i as u32);
                let v = match funct3 {
                    0x0 => self.load(pc, addr, 1, true),
                    0x1 => self.load(pc, addr, 2, true),
                    0x2 => self.load(pc, addr, 4, false),
                    0x4 => self.load(pc, addr, 1, false),
                    0x5 => self.load(pc, addr, 2, false),
                    _ => fault!(),
                };
                match v {
                    Ok(v) => self.set_x(rd, v),
                    Err(f) => return StepResult::Fault(f),
                }
            }
            0x23 => {
                // stores
                let imm = ((funct7 << 5) | rd) as i32;
                let imm = (imm << 20) >> 20;
                let addr = self.x(rs1).wrapping_add(imm as u32);
                let size = match funct3 {
                    0x0 => 1,
                    0x1 => 2,
                    0x2 => 4,
                    _ => fault!(),
                };
                if let Err(f) = self.store(pc, addr, size, self.x(rs2)) {
                    return StepResult::Fault(f);
                }
            }
            0x13 => {
                // op-imm
                let a = self.x(rs1);
                let v = match funct3 {
                    0x0 => a.wrapping_add(imm_i as u32),
                    0x2 => ((a as i32) < imm_i) as u32,
                    0x3 => (a < imm_i as u32) as u32,
                    0x4 => a ^ imm_i as u32,
                    0x6 => a | imm_i as u32,
                    0x7 => a & imm_i as u32,
                    0x1 => {
                        if funct7 != 0 {
                            fault!();
                        }
                        a << (rs2 & 0x1f)
                    }
                    0x5 => match funct7 {
                        0x00 => a >> (rs2 & 0x1f),
                        0x20 => ((a as i32) >> (rs2 & 0x1f)) as u32,
                        _ => fault!(),
                    },
                    _ => fault!(),
                };
                self.set_x(rd, v);
            }
            0x33 => {
                // op (RV32I only: no M extension on this host!)
                let (a, b) = (self.x(rs1), self.x(rs2));
                let v = match (funct7, funct3) {
                    (0x00, 0x0) => a.wrapping_add(b),
                    (0x20, 0x0) => a.wrapping_sub(b),
                    (0x00, 0x1) => a << (b & 0x1f),
                    (0x00, 0x2) => ((a as i32) < (b as i32)) as u32,
                    (0x00, 0x3) => (a < b) as u32,
                    (0x00, 0x4) => a ^ b,
                    (0x00, 0x5) => a >> (b & 0x1f),
                    (0x20, 0x5) => ((a as i32) >> (b & 0x1f)) as u32,
                    (0x00, 0x6) => a | b,
                    (0x00, 0x7) => a & b,
                    _ => fault!(),
                };
                self.set_x(rd, v);
            }
            0x0f => {} // FENCE: nop on this single-hart platform
            0x73 => {
                let csr = w >> 20;
                match funct3 {
                    0x0 => {
                        // ECALL / EBREAK: halt the host program
                        self.halted = true;
                        self.cycles += 1;
                        self.instret += 1;
                        return StepResult::Halted;
                    }
                    0x1 | 0x2 | 0x3 | 0x5 | 0x6 | 0x7 => {
                        let write_val = if funct3 >= 0x5 { rs1 } else { self.x(rs1) };
                        let res = self.csr_op(bus, pc, csr, funct3 & 0x3, rd, rs1, write_val);
                        match res {
                            Ok(read_val) => self.set_x(rd, read_val),
                            Err(f) => return StepResult::Fault(f),
                        }
                    }
                    _ => fault!(),
                }
            }
            _ => fault!(),
        }

        self.pc = next_pc;
        self.cycles += cycles;
        self.instret += 1;
        StepResult::Ran { cycles }
    }

    fn csr_op<B: CsrBus>(
        &mut self,
        bus: &mut B,
        pc: u32,
        csr: u32,
        op: u32, // 1=rw 2=rs 3=rc
        rd: u32,
        rs1: u32,
        write_val: u32,
    ) -> Result<u32, Fault> {
        // Host-local performance counters.
        if csr == 0xb00 || csr == 0xc00 {
            return Ok(self.cycles as u32); // mcycle / cycle
        }
        if csr == 0xb80 || csr == 0xc80 {
            return Ok((self.cycles >> 32) as u32); // mcycleh / cycleh
        }
        if csr == 0xc02 {
            return Ok(self.instret as u32); // instret
        }
        let maperr = |err| Fault::Csr { pc, err };
        // CSRRW with rd=x0 skips the read (spec); CSRRS/RC with rs1=x0
        // skip the write.
        let old = if op == 1 && rd == 0 {
            0
        } else {
            bus.csr_read(csr).map_err(maperr)?
        };
        let new = match op {
            1 => Some(write_val),
            2 if rs1 != 0 => Some(old | write_val),
            3 if rs1 != 0 => Some(old & !write_val),
            _ => None,
        };
        if let Some(v) = new {
            bus.csr_write(csr, v).map_err(maperr)?;
        }
        Ok(old)
    }

    /// Run to completion against `bus`, with a cycle limit (deadlock
    /// guard). Returns total cycles.
    pub fn run<B: CsrBus>(&mut self, bus: &mut B, max_cycles: u64) -> Result<u64, Fault> {
        let start = self.cycles;
        while !self.halted {
            match self.step(bus) {
                StepResult::Ran { .. } => {}
                StepResult::Halted => break,
                StepResult::Fault(f) => return Err(f),
            }
            if self.cycles - start > max_cycles {
                return Err(Fault::BadFetch { pc: self.pc }); // treated as runaway
            }
        }
        Ok(self.cycles - start)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::host::encode::{self as enc, reg, Asm};

    /// A CsrBus that records accesses into a simple map.
    #[derive(Default)]
    struct TestBus {
        regs: std::collections::HashMap<u32, u32>,
        writes: Vec<(u32, u32)>,
    }

    impl CsrBus for TestBus {
        fn csr_read(&mut self, addr: u32) -> Result<u32, CsrError> {
            Ok(*self.regs.get(&addr).unwrap_or(&0))
        }
        fn csr_write(&mut self, addr: u32, value: u32) -> Result<(), CsrError> {
            self.regs.insert(addr, value);
            self.writes.push((addr, value));
            Ok(())
        }
    }

    fn run_asm(build: impl FnOnce(&mut Asm)) -> (Cpu, TestBus) {
        let mut asm = Asm::new();
        build(&mut asm);
        asm.emit(enc::ebreak());
        let mut cpu = Cpu::new(asm.assemble(), 4096);
        let mut bus = TestBus::default();
        cpu.run(&mut bus, 1_000_000).expect("program fault");
        (cpu, bus)
    }

    #[test]
    fn arithmetic_and_li() {
        let (cpu, _) = run_asm(|a| {
            a.li(reg::T0, 0x12345678);
            a.li(reg::T1, -1000);
            a.emit(enc::add(reg::T2, reg::T0, reg::T1));
        });
        assert_eq!(cpu.regs[reg::T2 as usize], 0x12345678u32.wrapping_add(-1000i32 as u32));
    }

    #[test]
    fn branch_loop_counts() {
        // for (i = 0; i != 10; i++);
        let (cpu, _) = run_asm(|a| {
            a.li(reg::T0, 0);
            a.li(reg::T1, 10);
            a.label("loop");
            a.emit(enc::addi(reg::T0, reg::T0, 1));
            a.bne_to(reg::T0, reg::T1, "loop");
        });
        assert_eq!(cpu.regs[reg::T0 as usize], 10);
        // 2 li + 10 addi + 9 taken (2cy) + 1 not-taken + ebreak(1)
        assert_eq!(cpu.cycles, 2 + 10 + 9 * 2 + 1 + 1);
    }

    #[test]
    fn memory_roundtrip_and_sign_extension() {
        let (cpu, _) = run_asm(|a| {
            a.li(reg::T0, DATA_BASE as i32);
            a.li(reg::T1, -5i32);
            a.emit(enc::sb(reg::T1, reg::T0, 0));
            a.emit(enc::lb(reg::T2, reg::T0, 0)); // sign-extended
            a.emit(enc::lbu(reg::T3, reg::T0, 0)); // zero-extended
            a.emit(enc::sw(reg::T1, reg::T0, 8));
            a.emit(enc::lw(reg::T4, reg::T0, 8));
        });
        assert_eq!(cpu.regs[reg::T2 as usize] as i32, -5);
        assert_eq!(cpu.regs[reg::T3 as usize], 0xfb);
        assert_eq!(cpu.regs[reg::T4 as usize] as i32, -5);
    }

    #[test]
    fn call_ret_and_stack() {
        let (cpu, _) = run_asm(|a| {
            a.li(reg::A0, 7);
            a.call("double");
            a.beq_to(reg::ZERO, reg::ZERO, "end");
            a.label("double");
            a.emit(enc::add(reg::A0, reg::A0, reg::A0));
            a.ret();
            a.label("end");
        });
        assert_eq!(cpu.regs[reg::A0 as usize], 14);
    }

    #[test]
    fn csr_instructions_hit_the_bus() {
        let (cpu, bus) = run_asm(|a| {
            a.li(reg::T0, 0xbeef);
            a.emit(enc::csrrw(reg::ZERO, 0x3c1, reg::T0));
            a.emit(enc::csrrs(reg::T1, 0x3c1, reg::ZERO)); // read back
        });
        assert_eq!(bus.writes, vec![(0x3c1, 0xbeef)]);
        assert_eq!(cpu.regs[reg::T1 as usize], 0xbeef);
    }

    #[test]
    fn mcycle_reads_cycle_counter() {
        let (cpu, _) = run_asm(|a| {
            a.emit(enc::nop());
            a.emit(enc::nop());
            a.emit(enc::csrrs(reg::T0, 0xb00, reg::ZERO));
        });
        // two nops retired before the csr read
        assert_eq!(cpu.regs[reg::T0 as usize], 2);
        assert!(cpu.cycles >= 3);
    }

    #[test]
    fn shift_ops() {
        let (cpu, _) = run_asm(|a| {
            a.li(reg::T0, -64);
            a.emit(enc::srai(reg::T1, reg::T0, 3));
            a.emit(enc::srli(reg::T2, reg::T0, 3));
            a.li(reg::T3, 5);
            a.emit(enc::slli(reg::T3, reg::T3, 4));
        });
        assert_eq!(cpu.regs[reg::T1 as usize] as i32, -8);
        assert_eq!(cpu.regs[reg::T2 as usize], (-64i32 as u32) >> 3);
        assert_eq!(cpu.regs[reg::T3 as usize], 80);
    }

    #[test]
    fn sltu_and_slt() {
        let (cpu, _) = run_asm(|a| {
            a.li(reg::T0, -1); // 0xffffffff
            a.li(reg::T1, 1);
            a.emit(enc::slt(reg::T2, reg::T0, reg::T1)); // -1 < 1 -> 1
            a.emit(enc::sltu(reg::T3, reg::T0, reg::T1)); // max_u32 < 1 -> 0
        });
        assert_eq!(cpu.regs[reg::T2 as usize], 1);
        assert_eq!(cpu.regs[reg::T3 as usize], 0);
    }

    #[test]
    fn x0_is_hardwired_zero() {
        let (cpu, _) = run_asm(|a| {
            a.emit(enc::addi(reg::ZERO, reg::ZERO, 42));
            a.emit(enc::add(reg::T0, reg::ZERO, reg::ZERO));
        });
        assert_eq!(cpu.regs[0], 0);
        assert_eq!(cpu.regs[reg::T0 as usize], 0);
    }

    #[test]
    fn fault_on_bad_memory() {
        let mut asm = Asm::new();
        asm.li(reg::T0, 0x4000_0000u32 as i32);
        asm.emit(enc::lw(reg::T1, reg::T0, 0));
        asm.emit(enc::ebreak());
        let mut cpu = Cpu::new(asm.assemble(), 64);
        let mut bus = TestBus::default();
        assert!(matches!(cpu.run(&mut bus, 1000), Err(Fault::BadLoad { .. })));
    }

    #[test]
    fn runaway_guard_trips() {
        let mut asm = Asm::new();
        asm.label("spin");
        asm.beq_to(reg::ZERO, reg::ZERO, "spin");
        let mut cpu = Cpu::new(asm.assemble(), 64);
        let mut bus = TestBus::default();
        assert!(cpu.run(&mut bus, 100).is_err());
    }
}
