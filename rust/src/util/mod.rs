//! Dependency-free utility substrates: PRNG, statistics, JSON, tables,
//! CLI parsing, micro-benchmarking and property testing. These replace
//! `rand`, `serde`, `clap`, `criterion` and `proptest`, none of which are
//! available in the offline crate registry.

pub mod bench;
pub mod check;
pub mod cli;
pub mod json;
pub mod rng;
pub mod stats;
pub mod table;
