//! PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`) and executes them on the XLA CPU client.
//!
//! This is the *functional golden model* of the platform: the Pallas
//! output-stationary GeMM kernel (L1), lowered through the JAX graphs
//! (L2), executed from Rust (L3). Integration tests cross-check the
//! cycle-accurate simulator's datapath bit-exactly against these
//! executables. Python never runs here — the HLO text was produced once
//! by `make artifacts`.
//!
//! The XLA backend needs the `xla` crate, which is not available in the
//! offline crate registry. It is therefore gated behind the `pjrt`
//! cargo feature: without it (the default), manifest loading and
//! metadata queries still work, but executing an artifact returns an
//! error, and the golden-model integration tests skip themselves via
//! `cfg!(feature = "pjrt")`.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::anyhow;
use crate::bail;
use crate::util::error::Result;
use crate::util::json::{self, Json};

#[cfg(feature = "pjrt")]
pub use xla::Literal;

/// Stand-in for `xla::Literal` in builds without the PJRT backend.
/// Never constructed; it only keeps caller code compiling.
#[cfg(not(feature = "pjrt"))]
#[derive(Debug, Clone)]
pub struct Literal(());

#[cfg(not(feature = "pjrt"))]
impl Literal {
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        bail!(NO_BACKEND)
    }
}

#[cfg(not(feature = "pjrt"))]
const NO_BACKEND: &str = "PJRT backend unavailable: vendor the `xla` crate, add it to \
     rust/Cargo.toml as a dependency, and rebuild with `--features pjrt`";

/// Argument/result metadata from `manifest.json`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorMeta {
    pub shape: Vec<usize>,
    pub dtype: String, // "s8" | "s32" | "f32"
}

impl TensorMeta {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One artifact's manifest entry.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub file: String,
    pub args: Vec<TensorMeta>,
    pub results: Vec<TensorMeta>,
}

/// A typed input value.
pub enum Value {
    I8(Vec<i8>),
    I32(Vec<i32>),
}

/// The runtime: artifact manifest plus (with the `pjrt` feature) the
/// PJRT client and compiled-executable cache.
pub struct Runtime {
    #[cfg_attr(not(feature = "pjrt"), allow(dead_code))]
    dir: PathBuf,
    manifest: HashMap<String, ArtifactMeta>,
    #[cfg(feature = "pjrt")]
    client: xla::PjRtClient,
    #[cfg(feature = "pjrt")]
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

fn tensor_meta(v: &Json) -> Result<TensorMeta> {
    let shape = v
        .get("shape")
        .and_then(|s| s.as_arr())
        .ok_or_else(|| anyhow!("manifest entry missing shape"))?
        .iter()
        .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
        .collect::<Result<Vec<_>>>()?;
    let dtype = v
        .get("dtype")
        .and_then(|d| d.as_str())
        .ok_or_else(|| anyhow!("manifest entry missing dtype"))?
        .to_string();
    Ok(TensorMeta { shape, dtype })
}

/// Parse `manifest.json` under `dir` into artifact metadata.
fn load_manifest(dir: &Path) -> Result<HashMap<String, ArtifactMeta>> {
    let manifest_path = dir.join("manifest.json");
    let text = std::fs::read_to_string(&manifest_path)
        .map_err(|e| anyhow!("reading {manifest_path:?} (run `make artifacts`): {e}"))?;
    let doc = json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
    let obj = doc.as_obj().ok_or_else(|| anyhow!("manifest is not an object"))?;
    let mut manifest = HashMap::new();
    for (name, entry) in obj {
        let file = entry
            .get("file")
            .and_then(|f| f.as_str())
            .ok_or_else(|| anyhow!("artifact {name} missing file"))?
            .to_string();
        let args = entry
            .get("args")
            .and_then(|a| a.as_arr())
            .ok_or_else(|| anyhow!("artifact {name} missing args"))?
            .iter()
            .map(tensor_meta)
            .collect::<Result<Vec<_>>>()?;
        let results = entry
            .get("results")
            .and_then(|a| a.as_arr())
            .ok_or_else(|| anyhow!("artifact {name} missing results"))?
            .iter()
            .map(tensor_meta)
            .collect::<Result<Vec<_>>>()?;
        manifest.insert(name.clone(), ArtifactMeta { file, args, results });
    }
    Ok(manifest)
}

impl Runtime {
    /// Load the manifest (and, with the `pjrt` feature, create the
    /// PJRT CPU client).
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = load_manifest(&dir)?;
        Ok(Runtime {
            #[cfg(feature = "pjrt")]
            client: xla::PjRtClient::cpu()?,
            #[cfg(feature = "pjrt")]
            cache: HashMap::new(),
            dir,
            manifest,
        })
    }

    /// Default artifacts directory (repo-root/artifacts), overridable
    /// with OPENGEMM_ARTIFACTS.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("OPENGEMM_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
    }

    pub fn artifact_names(&self) -> Vec<&str> {
        let mut names: Vec<&str> = self.manifest.keys().map(|s| s.as_str()).collect();
        names.sort_unstable();
        names
    }

    pub fn meta(&self, name: &str) -> Option<&ArtifactMeta> {
        self.manifest.get(name)
    }
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Compile (and cache) an artifact.
    fn executable(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(name) {
            let meta = self
                .manifest
                .get(name)
                .ok_or_else(|| anyhow!("unknown artifact {name:?}"))?;
            let path = self.dir.join(&meta.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.cache.insert(name.to_string(), exe);
        }
        Ok(self.cache.get(name).unwrap())
    }

    fn literal(value: &Value, meta: &TensorMeta) -> Result<xla::Literal> {
        use xla::PrimitiveType;
        let dims: Vec<i64> = meta.shape.iter().map(|&d| d as i64).collect();
        match (value, meta.dtype.as_str()) {
            (Value::I8(v), "s8") => {
                if v.len() != meta.elements() {
                    bail!("arg size {} != expected {}", v.len(), meta.elements());
                }
                // the xla crate has no native i8 literal constructor;
                // build i32 and convert (exact for the int8 range)
                let v32: Vec<i32> = v.iter().map(|&x| x as i32).collect();
                Ok(xla::Literal::vec1(&v32).reshape(&dims)?.convert(PrimitiveType::S8)?)
            }
            (Value::I32(v), "s32") => {
                if v.len() != meta.elements() {
                    bail!("arg size {} != expected {}", v.len(), meta.elements());
                }
                Ok(xla::Literal::vec1(v).reshape(&dims)?)
            }
            (_, d) => bail!("unsupported arg dtype {d:?}"),
        }
    }

    /// Execute an artifact with typed inputs; returns raw result
    /// literals (tuple-unpacked).
    pub fn execute(&mut self, name: &str, args: &[Value]) -> Result<Vec<Literal>> {
        let meta = self
            .manifest
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name:?}"))?
            .clone();
        if args.len() != meta.args.len() {
            bail!("artifact {name}: {} args given, {} expected", args.len(), meta.args.len());
        }
        let literals: Vec<Literal> = args
            .iter()
            .zip(&meta.args)
            .map(|(v, m)| Self::literal(v, m))
            .collect::<Result<Vec<_>>>()?;
        let exe = self.executable(name)?;
        let result = exe.execute::<Literal>(&literals)?[0][0].to_literal_sync()?;
        // lowered with return_tuple=True
        Ok(result.to_tuple()?)
    }

    /// Execute an int8 GeMM artifact: `C[M,N] = A[M,K] @ B[K,N]`.
    pub fn execute_gemm(&mut self, name: &str, a: &[i8], b: &[i8]) -> Result<Vec<i32>> {
        let outs = self.execute(name, &[Value::I8(a.to_vec()), Value::I8(b.to_vec())])?;
        let out = outs
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("artifact {name} returned no results"))?;
        Ok(out.to_vec::<i32>()?)
    }

    /// Read back an int8 result literal (requantized outputs).
    pub fn result_i8(lit: &Literal) -> Result<Vec<i8>> {
        // no native i8 reader either: convert to s32 first
        let as32 = lit.convert(xla::PrimitiveType::S32)?;
        Ok(as32.to_vec::<i32>()?.into_iter().map(|v| v as i8).collect())
    }
}

#[cfg(not(feature = "pjrt"))]
impl Runtime {
    pub fn execute(&mut self, _name: &str, _args: &[Value]) -> Result<Vec<Literal>> {
        bail!(NO_BACKEND)
    }

    pub fn execute_gemm(&mut self, _name: &str, _a: &[i8], _b: &[i8]) -> Result<Vec<i32>> {
        bail!(NO_BACKEND)
    }

    pub fn result_i8(_lit: &Literal) -> Result<Vec<i8>> {
        bail!(NO_BACKEND)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_dir_points_at_artifacts() {
        // The OPENGEMM_ARTIFACTS override is exercised by callers, not
        // here: mutating process env races the parallel test harness.
        if std::env::var_os("OPENGEMM_ARTIFACTS").is_none() {
            assert!(Runtime::default_dir().ends_with("artifacts"));
        }
    }

    #[test]
    fn load_fails_cleanly_without_manifest() {
        let err = Runtime::load("/definitely/not/a/dir").unwrap_err();
        assert!(err.to_string().contains("manifest.json"), "{err}");
    }

    #[test]
    fn tensor_meta_parses_shape_and_dtype() {
        let doc = json::parse(r#"{"shape": [2, 3], "dtype": "s8"}"#).unwrap();
        let meta = tensor_meta(&doc).unwrap();
        assert_eq!(meta.shape, vec![2, 3]);
        assert_eq!(meta.dtype, "s8");
        assert_eq!(meta.elements(), 6);
    }
}
