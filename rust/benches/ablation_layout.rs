//! Extension ablation: data-layout comparison (DESIGN.md design-choice
//! ablation). The Fig. 5 SMA step compares the paper's contiguous
//! baseline against the interleaved layout; this bench adds the naive
//! row-major layout (worst case: intra-tile bank serialization grows
//! with K) and reports the utilization distribution plus the SPM
//! conflict statistics for each.
//!
//! Run with:  cargo bench --bench ablation_layout -- [--no-fast-forward]

use std::time::Instant;

use opengemm::compiler::Layout;
use opengemm::config::{Mechanisms, PlatformConfig};
use opengemm::coordinator::{Coordinator, JobRequest};
use opengemm::util::cli::Args;
use opengemm::util::stats::BoxStats;
use opengemm::util::table::Table;
use opengemm::workloads::random_suite;

fn main() {
    let args = Args::from_env().expect("args");
    let cfg = PlatformConfig::case_study();
    let coord =
        Coordinator::new(cfg.clone()).with_fast_forward(args.enabled_unless_no("fast-forward"));
    let shapes = random_suite(99, 200);
    let t0 = Instant::now();

    let mut table = Table::new(&[
        "layout", "median OU", "q1", "q3", "mean conflict cyc / job",
    ]);
    let mut medians = Vec::new();
    for layout in [Layout::RowMajor, Layout::TiledContiguous, Layout::TiledInterleaved] {
        let reqs: Vec<JobRequest> = shapes
            .iter()
            .map(|&shape| JobRequest {
                shape,
                layout,
                mechanisms: Mechanisms::ALL,
                repeats: 10,
                operands: None,
            })
            .collect();
        let results = coord.run_batch(reqs);
        let mut samples = Vec::new();
        let mut conflicts = 0u64;
        let mut n = 0u64;
        for r in results {
            let r = r.expect("job");
            samples.push(r.report.overall);
            conflicts += r.metrics.spm.conflict_cycles;
            n += 1;
        }
        let stats = BoxStats::compute(&samples).expect("nonempty sample set");
        medians.push(stats.median);
        table.row(vec![
            format!("{layout:?}"),
            format!("{:.4}", stats.median),
            format!("{:.4}", stats.q1),
            format!("{:.4}", stats.q3),
            format!("{:.0}", conflicts as f64 / n as f64),
        ]);
    }
    println!("## Layout ablation (200 workloads x 10 repeats, all mechanisms)\n");
    println!("{}", table.markdown());
    println!(
        "\nrow-major -> contiguous -> interleaved median OU: {:.3} -> {:.3} -> {:.3}\n\
         (the interleaved layout is the paper's Fig. 4(c)(3) optimization)",
        medians[0], medians[1], medians[2]
    );
    assert!(medians[0] < medians[1] && medians[1] < medians[2], "layout ladder must be monotone");
    println!("bench ablation_layout: {:.1}s wall", t0.elapsed().as_secs_f64());
}
