"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes, tiles, and value distributions; assertions are
exact integer equality (the datapath is exact int8 x int8 -> int32).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.gemm_pallas import (
    gemm_int8,
    gemm_int8_tiled,
    linear_int8,
    linear_int8_tiled,
    pad_to_multiple,
)
from compile.kernels.ref import (
    conv2d_im2col_ref,
    gemm_int8_ref,
    im2col_ref,
    linear_ref,
    mha_scores_ref,
    mlp_block_ref,
    requantize_ref,
)

RNG = np.random.default_rng(1234)


def rand_i8(*shape):
    return jnp.asarray(RNG.integers(-128, 128, shape, dtype=np.int8))


def rand_i32(*shape, lo=-(1 << 20), hi=1 << 20):
    return jnp.asarray(RNG.integers(lo, hi, shape, dtype=np.int32))


# ---------------------------------------------------------------------------
# Tiled kernel, divisible shapes
# ---------------------------------------------------------------------------

class TestGemmTiled:
    @pytest.mark.parametrize("m,k,n", [(8, 8, 8), (16, 8, 24), (32, 64, 8)])
    def test_matches_ref(self, m, k, n):
        a, b = rand_i8(m, k), rand_i8(k, n)
        out = gemm_int8_tiled(a, b)
        np.testing.assert_array_equal(out, gemm_int8_ref(a, b))

    def test_output_dtype_is_i32(self):
        out = gemm_int8_tiled(rand_i8(8, 8), rand_i8(8, 8))
        assert out.dtype == jnp.int32

    def test_extreme_values_accumulate_exactly(self):
        # worst case: -128 * -128 * K summed; must not lose bits
        a = jnp.full((8, 64), -128, dtype=jnp.int8)
        b = jnp.full((64, 8), -128, dtype=jnp.int8)
        out = gemm_int8_tiled(a, b, bm=8, bk=8, bn=8)
        assert int(out[0, 0]) == (-128) * (-128) * 64

    def test_identity(self):
        eye = jnp.eye(16, dtype=jnp.int8)
        a = rand_i8(16, 16)
        np.testing.assert_array_equal(gemm_int8_tiled(a, eye, bm=8, bk=8, bn=8), a.astype(jnp.int32))

    def test_zero_inputs(self):
        z = jnp.zeros((8, 8), dtype=jnp.int8)
        np.testing.assert_array_equal(gemm_int8_tiled(z, z), jnp.zeros((8, 8), jnp.int32))

    @pytest.mark.parametrize("bm,bk,bn", [(8, 8, 8), (16, 16, 16), (8, 16, 32)])
    def test_tile_shapes_agree(self, bm, bk, bn):
        a, b = rand_i8(32, 32), rand_i8(32, 32)
        out = gemm_int8_tiled(a, b, bm=bm, bk=bk, bn=bn)
        np.testing.assert_array_equal(out, gemm_int8_ref(a, b))

    def test_rejects_indivisible(self):
        with pytest.raises(ValueError, match="not divisible"):
            gemm_int8_tiled(rand_i8(9, 8), rand_i8(8, 8))

    def test_rejects_contraction_mismatch(self):
        with pytest.raises(ValueError, match="contraction"):
            gemm_int8_tiled(rand_i8(8, 16), rand_i8(8, 8))

    def test_rejects_non_int8(self):
        with pytest.raises(TypeError):
            gemm_int8_ref(
                jnp.zeros((8, 8), jnp.int32), jnp.zeros((8, 8), jnp.int8)
            )


# ---------------------------------------------------------------------------
# Padding wrapper, arbitrary shapes (hypothesis)
# ---------------------------------------------------------------------------

dims = st.integers(min_value=1, max_value=48)


class TestGemmArbitrary:
    @settings(max_examples=40, deadline=None)
    @given(m=dims, k=dims, n=dims, seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, m, k, n, seed):
        rng = np.random.default_rng(seed)
        a = jnp.asarray(rng.integers(-128, 128, (m, k), dtype=np.int8))
        b = jnp.asarray(rng.integers(-128, 128, (k, n), dtype=np.int8))
        np.testing.assert_array_equal(gemm_int8(a, b), gemm_int8_ref(a, b))

    @settings(max_examples=20, deadline=None)
    @given(m=dims, k=dims)
    def test_pad_roundtrip(self, m, k):
        a = rand_i8(m, k)
        p = pad_to_multiple(a, 8, 8)
        assert p.shape[0] % 8 == 0 and p.shape[1] % 8 == 0
        np.testing.assert_array_equal(p[:m, :k], a)
        # padding is zeros
        assert int(jnp.abs(p).sum()) == int(jnp.abs(a).sum())

    def test_single_element(self):
        a, b = rand_i8(1, 1), rand_i8(1, 1)
        out = gemm_int8(a, b)
        assert int(out[0, 0]) == int(a[0, 0]) * int(b[0, 0])


# ---------------------------------------------------------------------------
# Fused quantized linear
# ---------------------------------------------------------------------------

class TestLinear:
    @pytest.mark.parametrize("shift", [0, 1, 7, 15])
    def test_matches_ref(self, shift):
        a, w = rand_i8(16, 24), rand_i8(24, 8)
        bias = rand_i32(8)
        out = linear_int8_tiled(
            a, w, bias, jnp.asarray([shift], jnp.int32), bm=8, bk=8, bn=8
        )
        np.testing.assert_array_equal(out, linear_ref(a, w, bias, shift))

    @settings(max_examples=25, deadline=None)
    @given(m=dims, k=dims, n=dims, shift=st.integers(0, 20), seed=st.integers(0, 2**31 - 1))
    def test_arbitrary_shapes(self, m, k, n, shift, seed):
        rng = np.random.default_rng(seed)
        a = jnp.asarray(rng.integers(-128, 128, (m, k), dtype=np.int8))
        w = jnp.asarray(rng.integers(-128, 128, (k, n), dtype=np.int8))
        bias = jnp.asarray(rng.integers(-1000, 1000, (n,), dtype=np.int32))
        out = linear_int8(a, w, bias, jnp.asarray([shift], jnp.int32))
        np.testing.assert_array_equal(out, linear_ref(a, w, bias, shift))

    def test_output_dtype_is_i8(self):
        out = linear_int8(
            rand_i8(8, 8), rand_i8(8, 8), rand_i32(8), jnp.asarray([7], jnp.int32)
        )
        assert out.dtype == jnp.int8

    def test_saturation(self):
        # large accumulations with shift 0 must clip to [-128, 127]
        a = jnp.full((8, 8), 127, jnp.int8)
        w = jnp.full((8, 8), 127, jnp.int8)
        out = linear_int8(a, w, jnp.zeros((8,), jnp.int32), jnp.asarray([0], jnp.int32))
        assert int(out.max()) == 127 and int(out.min()) == 127


# ---------------------------------------------------------------------------
# Requantizer oracle properties
# ---------------------------------------------------------------------------

class TestRequantize:
    def test_shift_zero_is_clip(self):
        acc = jnp.asarray([-300, -128, 0, 127, 300], jnp.int32)
        out = requantize_ref(acc, 0)
        np.testing.assert_array_equal(out, jnp.asarray([-128, -128, 0, 127, 127], jnp.int8))

    def test_round_half_up(self):
        # (3 + 2) >> 2 = 1 ; (-3 + 2) >> 2 = (-1) >> 2 = -1 (arithmetic shift)
        acc = jnp.asarray([3, -3], jnp.int32)
        out = requantize_ref(acc, 2)
        np.testing.assert_array_equal(out, jnp.asarray([1, -1], jnp.int8))

    def test_rejects_bad_shift(self):
        with pytest.raises(ValueError):
            requantize_ref(jnp.zeros((1,), jnp.int32), 40)

    @settings(max_examples=30, deadline=None)
    @given(shift=st.integers(1, 24), seed=st.integers(0, 2**31 - 1))
    def test_monotone(self, shift, seed):
        rng = np.random.default_rng(seed)
        acc = np.sort(rng.integers(-(1 << 28), 1 << 28, 64).astype(np.int32))
        out = np.asarray(requantize_ref(jnp.asarray(acc), shift))
        assert (np.diff(out.astype(np.int32)) >= 0).all()


# ---------------------------------------------------------------------------
# im2col / conv oracle
# ---------------------------------------------------------------------------

class TestIm2col:
    def test_conv_matches_direct(self):
        x = rand_i8(1, 8, 8, 4)
        w = rand_i8(3, 3, 4, 8)
        out = conv2d_im2col_ref(x, w)
        # direct int conv via float64 lax.conv (exact for these magnitudes)
        ref = np.zeros((1, 6, 6, 8), dtype=np.int64)
        xn, wn = np.asarray(x, np.int64), np.asarray(w, np.int64)
        for oy in range(6):
            for ox in range(6):
                patch = xn[0, oy : oy + 3, ox : ox + 3, :]
                ref[0, oy, ox, :] = np.tensordot(patch, wn, axes=([0, 1, 2], [0, 1, 2]))
        np.testing.assert_array_equal(np.asarray(out, np.int64), ref)

    def test_im2col_shape(self):
        x = rand_i8(2, 10, 12, 3)
        a = im2col_ref(x, 3, 3, stride=1)
        assert a.shape == (2 * 8 * 10, 3 * 3 * 3)
        assert a.dtype == jnp.int8

    @pytest.mark.parametrize("stride", [1, 2])
    def test_strided(self, stride):
        x = rand_i8(1, 9, 9, 2)
        w = rand_i8(3, 3, 2, 4)
        out = conv2d_im2col_ref(x, w, stride=stride)
        o = (9 - 3) // stride + 1
        assert out.shape == (1, o, o, 4)


# ---------------------------------------------------------------------------
# Transformer blocks
# ---------------------------------------------------------------------------

class TestBlocks:
    def test_mha_scores_range(self):
        q, k = rand_i8(32, 64), rand_i8(32, 64)
        out = mha_scores_ref(q, k, shift=6)
        assert out.dtype == jnp.int8
        assert out.shape == (32, 32)

    def test_mlp_block_shapes(self):
        x = rand_i8(16, 32)
        w1, w2 = rand_i8(32, 64), rand_i8(64, 32)
        b1, b2 = rand_i32(64), rand_i32(32)
        out = mlp_block_ref(x, w1, b1, w2, b2, 7, 7)
        assert out.shape == (16, 32)
        assert out.dtype == jnp.int8

    def test_mlp_relu_applied(self):
        # with huge negative bias on layer 1, hidden is all zeros ->
        # output equals requant(bias2)
        x = rand_i8(8, 8)
        w1, w2 = rand_i8(8, 8), rand_i8(8, 8)
        b1 = jnp.full((8,), -(1 << 24), jnp.int32)
        b2 = rand_i32(8, lo=-100, hi=100)
        out = mlp_block_ref(x, w1, b1, w2, b2, 0, 0)
        expect = requantize_ref(b2.astype(jnp.int32), 0)
        np.testing.assert_array_equal(out, jnp.broadcast_to(expect, (8, 8)))
