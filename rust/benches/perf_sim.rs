//! Microbenchmarks of the simulator hot path (the L3 perf target in
//! EXPERIMENTS.md §Perf): simulated cycles per wall-clock second for
//! representative workloads, plus component microbenches (AGU walk,
//! bank arbitration, tile MAC, RV32I dispatch).
//!
//! Run with:  cargo bench --bench perf_sim

use opengemm::compiler::{compile_gemm, GemmShape, Layout};
use opengemm::config::{GemmCoreParams, Mechanisms, PlatformConfig};
use opengemm::gemm_core::{tile_mac, Accumulators};
use opengemm::host::{encode as enc, reg, Asm, Cpu};
use opengemm::csr::CsrManager;
use opengemm::sim::{Platform, SimOptions};
use opengemm::spm::Spm;
use opengemm::streamer::AguConfig;
use opengemm::util::bench::{black_box, Bencher};
use opengemm::util::rng::Pcg32;

fn bench_end_to_end(b: &mut Bencher) {
    let cfg = PlatformConfig::case_study();
    for (label, shape, mech, layout) in [
        ("sim/64^3 all-mech", GemmShape::new(64, 64, 64), Mechanisms::ALL, Layout::TiledInterleaved),
        ("sim/128^3 all-mech", GemmShape::new(128, 128, 128), Mechanisms::ALL, Layout::TiledInterleaved),
        ("sim/128^3 baseline", GemmShape::new(128, 128, 128), Mechanisms::BASELINE, Layout::TiledContiguous),
    ] {
        let job = compile_gemm(&cfg, shape, layout, 2, mech.config_preloading).unwrap();
        let opts = SimOptions { mechanisms: mech, ..Default::default() };
        let mut platform = Platform::new(cfg.clone(), opts);
        let mut cycles = 0u64;
        let r = b.bench(label, || {
            let res = platform.run_job(&job, None, None).unwrap();
            cycles = res.metrics.total_cycles;
        });
        println!(
            "      -> {:.1} M simulated cycles/s ({} cycles/job)",
            r.throughput(cycles as f64) / 1e6,
            cycles
        );
    }
}

fn bench_components(b: &mut Bencher) {
    // tile MAC (functional datapath)
    let core = GemmCoreParams::CASE_STUDY;
    let mut acc = Accumulators::new(&core);
    let mut rng = Pcg32::seeded(3);
    let mut a = vec![0i8; 64];
    let mut bb = vec![0i8; 64];
    rng.fill_i8(&mut a);
    rng.fill_i8(&mut bb);
    b.bench("core/tile_mac 8x8x8", || {
        tile_mac(&mut acc, &core, black_box(&a), black_box(&bb));
    });

    // AGU address generation
    let agu = AguConfig {
        base: 0,
        stride_m: 1024,
        stride_n: 0,
        stride_k: 128,
        spatial0_count: 1,
        spatial0_stride: 0,
        spatial1_count: 8,
        spatial1_stride: 8,
    };
    let mut addrs = Vec::with_capacity(8);
    let mut pos = 0u64;
    b.bench("streamer/agu 8-port walk", || {
        pos = (pos + 1) & 0xffff;
        agu.tile_word_addrs(pos % 64, 0, pos / 64, 8, &mut addrs);
        black_box(&addrs);
    });

    // SPM bank arbitration
    let mut spm = Spm::new(PlatformConfig::case_study().mem);
    let words: Vec<u64> = (0..8u64).map(|i| i * 8).collect();
    b.bench("spm/read_cost 8 ports", || {
        black_box(spm.read_cost(black_box(&words)));
    });

    // RV32I dispatch rate
    let mut asm = Asm::new();
    asm.li(reg::T0, 0);
    asm.li(reg::T1, 1_000_000);
    asm.label("loop");
    asm.emit(enc::addi(reg::T0, reg::T0, 1));
    asm.emit(enc::xor(reg::T2, reg::T0, reg::T1));
    asm.emit(enc::and(reg::T3, reg::T2, reg::T0));
    asm.bne_to(reg::T0, reg::T1, "loop");
    asm.emit(enc::ebreak());
    let program = asm.assemble();
    let mut csr = CsrManager::new(false);
    let r = b.bench("host/rv32i 1M-iter loop", || {
        let mut cpu = Cpu::new(program.clone(), 256);
        cpu.run(&mut csr, u64::MAX).unwrap();
        black_box(cpu.cycles);
    });
    println!(
        "      -> {:.1} M host instructions/s",
        r.throughput(4_000_000.0) / 1e6
    );
}

fn main() {
    println!("== simulator hot-path microbenchmarks ==");
    let mut b = Bencher::default();
    bench_end_to_end(&mut b);
    bench_components(&mut b);
}
