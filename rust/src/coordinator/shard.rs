//! Sharded sweep engine: plan, run and merge shard-parallel batches.
//!
//! The evaluation sweeps are embarrassingly parallel (Fig. 5 runs 500
//! workloads x 6 architecture variants; the DSE grids are the same
//! shape), and one process's worker pool is the scaling ceiling. This
//! layer splits one request batch into [`Shard`]s that are
//!
//! - **deterministic**: [`SweepPlan::stride`] / [`SweepPlan::contiguous`]
//!   depend only on the request count and shard count;
//! - **self-contained**: a serialized shard carries the elaborated
//!   [`PlatformConfig`], the simulation options and every job (operands
//!   included), so any process — or, tomorrow, any host — can run it
//!   with no other context;
//! - **mergeable**: [`merge`] reassembles per-shard outcomes into
//!   submission order and sums the per-shard [`CoordinatorStats`].
//!
//! ## Why `merge` equals the unsharded run
//!
//! Every job is a deterministic function of `(cfg, sim options,
//! request)` alone — workers never share mutable state and job results
//! never feed back into later jobs. A plan covers each submission index
//! exactly once (enforced by `merge`), so reordering outcomes by index
//! reproduces `Coordinator::run_batch`'s output element-for-element,
//! and the stats counters are per-job sums, so summing them over any
//! partition gives the unsharded totals. The
//! `sharded_sweep_matches_unsharded` differential test (and the CI
//! `sweep-smoke` lane, across real processes) pins this property.

use std::path::Path;

use crate::config::PlatformConfig;
use crate::coordinator::cache::ResultCache;
use crate::coordinator::dispatch::{
    dispatch_plan, dispatch_plan_cached, DispatchOptions, InProcess,
};
use crate::coordinator::{
    outcome_from_json, outcome_to_json, parse_workers_env, Coordinator, CoordinatorStats,
    JobOutcome, JobRequest,
};
use crate::sim::SimOptions;
use crate::util::json::{self, Json};

/// Wire-format markers, so a worker fed the wrong file fails loudly.
const SHARD_FORMAT: &str = "opengemm-shard-v1";
const SHARD_RESULT_FORMAT: &str = "opengemm-shard-result-v1";

/// How a sweep is split and simulated.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SweepOptions {
    /// Number of shards to split the batch into (0 or 1 = unsharded).
    pub shards: usize,
    /// Worker threads per shard coordinator (0 = auto-size).
    pub workers: usize,
    /// Event-driven cycle skipping (cycle-exact; off only for
    /// differential checks).
    pub fast_forward: bool,
    /// Host-stall cycles per accelerator CSR access.
    pub csr_latency: u64,
    /// Static admission gate (default on): verify every compilable job
    /// with [`crate::analysis::verify_job`] before dispatch and reject
    /// the sweep loudly on an error-severity diagnostic. Like `shards`,
    /// this is a planning knob — it is not part of the shard wire
    /// format, so toggling it cannot perturb shard files or cache keys.
    pub lint: bool,
}

impl Default for SweepOptions {
    fn default() -> Self {
        SweepOptions {
            shards: 1,
            workers: 0,
            fast_forward: SimOptions::default().fast_forward,
            csr_latency: SimOptions::default().csr_latency,
            lint: true,
        }
    }
}

impl SweepOptions {
    fn to_json(self) -> Json {
        Json::obj(vec![
            ("workers", Json::num(self.workers as f64)),
            ("fast_forward", Json::Bool(self.fast_forward)),
            ("csr_latency", Json::num(self.csr_latency as f64)),
        ])
    }

    fn from_json(v: &Json) -> Result<SweepOptions, String> {
        Ok(SweepOptions {
            // `shards` and `lint` are planning knobs, not per-shard
            // properties; a deserialized shard is always run as-is (its
            // jobs were already admitted by the planning process).
            shards: 1,
            lint: true,
            workers: json::get_usize(v, "workers")?,
            fast_forward: json::get_bool(v, "fast_forward")?,
            csr_latency: json::get_u64(v, "csr_latency")?,
        })
    }
}

/// One self-contained slice of a sweep.
#[derive(Debug, Clone, PartialEq)]
pub struct Shard {
    /// Position of this shard in its plan (0-based).
    pub shard_index: usize,
    /// Total shards in the plan this shard came from.
    pub num_shards: usize,
    /// The elaborated platform instance every job runs on.
    pub cfg: PlatformConfig,
    pub options: SweepOptions,
    /// Original submission indices, parallel to `requests`.
    pub indices: Vec<usize>,
    pub requests: Vec<JobRequest>,
}

/// The outcome of running one shard.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardResult {
    pub shard_index: usize,
    /// Original submission indices, parallel to `outcomes`.
    pub indices: Vec<usize>,
    pub outcomes: Vec<JobOutcome>,
    pub stats: CoordinatorStats,
}

/// A merged sweep: outcomes in submission order plus summed stats.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepResult {
    pub outcomes: Vec<JobOutcome>,
    pub stats: CoordinatorStats,
}

/// A deterministic partition of one request batch.
#[derive(Debug, Clone)]
pub struct SweepPlan {
    pub total_jobs: usize,
    pub shards: Vec<Shard>,
}

impl SweepPlan {
    /// Stride (round-robin) partition: request `i` lands in shard
    /// `i % shards`. Sweep generators emit workloads in size-correlated
    /// order, so striding balances shard runtimes.
    pub fn stride(
        cfg: &PlatformConfig,
        requests: Vec<JobRequest>,
        opts: SweepOptions,
    ) -> SweepPlan {
        Self::partition(cfg, requests, opts, |i, _n, shards| i % shards)
    }

    /// Contiguous partition: the batch is cut into `shards` consecutive
    /// runs. Less balanced than [`SweepPlan::stride`], but keeps
    /// submission locality when jobs share staged operands.
    pub fn contiguous(
        cfg: &PlatformConfig,
        requests: Vec<JobRequest>,
        opts: SweepOptions,
    ) -> SweepPlan {
        Self::partition(cfg, requests, opts, |i, n, shards| {
            // first `n % shards` shards take one extra job
            let (base, extra) = (n / shards, n % shards);
            let boundary = extra * (base + 1);
            if i < boundary {
                i / (base + 1)
            } else {
                extra + (i - boundary) / base
            }
        })
    }

    /// `assign(i, total_jobs, num_shards)` picks the shard of job `i`;
    /// `num_shards` arrives pre-clamped to `1..=total_jobs.max(1)`.
    fn partition(
        cfg: &PlatformConfig,
        requests: Vec<JobRequest>,
        opts: SweepOptions,
        assign: impl Fn(usize, usize, usize) -> usize,
    ) -> SweepPlan {
        let n = requests.len();
        let num_shards = opts.shards.clamp(1, n.max(1));
        // Each shard stores `shards: 1`: the split already happened, and
        // a shard is always run as-is (this also keeps the shard-file
        // round-trip lossless — the wire format carries no planning
        // knobs).
        let shard_options = SweepOptions { shards: 1, ..opts };
        let mut shards: Vec<Shard> = (0..num_shards)
            .map(|shard_index| Shard {
                shard_index,
                num_shards,
                cfg: cfg.clone(),
                options: shard_options,
                indices: Vec::new(),
                requests: Vec::new(),
            })
            .collect();
        for (i, request) in requests.into_iter().enumerate() {
            let s = assign(i, n, num_shards);
            shards[s].indices.push(i);
            shards[s].requests.push(request);
        }
        SweepPlan { total_jobs: n, shards }
    }
}

impl Shard {
    /// Run this shard on its own [`Coordinator`]. Consumes the shard:
    /// the request batch (inline functional operands included) moves
    /// straight into the coordinator instead of being cloned.
    pub fn run(self) -> ShardResult {
        let Shard { shard_index, cfg, options, indices, requests, .. } = self;
        let mut coord = Coordinator::new(cfg)
            .with_fast_forward(options.fast_forward)
            .with_csr_latency(options.csr_latency);
        if options.workers > 0 {
            coord = coord.with_workers(options.workers);
        }
        let outcomes = coord.run_batch(requests);
        ShardResult { shard_index, indices, outcomes, stats: coord.stats() }
    }

    /// Wire encoding: the complete context a worker process needs.
    pub fn to_json(&self) -> Json {
        let jobs: Vec<Json> = self
            .indices
            .iter()
            .zip(&self.requests)
            .map(|(&index, request)| {
                Json::obj(vec![
                    ("index", Json::num(index as f64)),
                    ("request", request.to_json()),
                ])
            })
            .collect();
        Json::obj(vec![
            ("format", Json::str(SHARD_FORMAT)),
            ("shard_index", Json::num(self.shard_index as f64)),
            ("num_shards", Json::num(self.num_shards as f64)),
            ("cfg", self.cfg.to_json()),
            ("options", self.options.to_json()),
            ("jobs", Json::Arr(jobs)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Shard, String> {
        let format = json::get_str(v, "format")?;
        if format != SHARD_FORMAT {
            return Err(format!("not a shard file: format {format:?}, want {SHARD_FORMAT:?}"));
        }
        let mut indices = Vec::new();
        let mut requests = Vec::new();
        for job in json::get_arr(v, "jobs")? {
            indices.push(json::get_usize(job, "index")?);
            requests.push(JobRequest::from_json(json::get(job, "request")?)?);
        }
        Ok(Shard {
            shard_index: json::get_usize(v, "shard_index")?,
            num_shards: json::get_usize(v, "num_shards")?,
            cfg: PlatformConfig::from_json(json::get(v, "cfg")?)?,
            options: SweepOptions::from_json(json::get(v, "options")?)?,
            indices,
            requests,
        })
    }

    pub fn write_file(&self, path: &Path) -> Result<(), String> {
        std::fs::write(path, self.to_json().pretty())
            .map_err(|e| format!("write shard {}: {e}", path.display()))
    }

    pub fn read_file(path: &Path) -> Result<Shard, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("read shard {}: {e}", path.display()))?;
        Shard::from_json(&json::parse(&text)?)
    }
}

impl ShardResult {
    /// Wire encoding (worker process -> driver).
    pub fn to_json(&self) -> Json {
        let jobs: Vec<Json> = self
            .indices
            .iter()
            .zip(&self.outcomes)
            .map(|(&index, outcome)| {
                Json::obj(vec![
                    ("index", Json::num(index as f64)),
                    ("outcome", outcome_to_json(outcome)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("format", Json::str(SHARD_RESULT_FORMAT)),
            ("shard_index", Json::num(self.shard_index as f64)),
            ("jobs", Json::Arr(jobs)),
            ("stats", self.stats.to_json()),
        ])
    }

    pub fn from_json(v: &Json) -> Result<ShardResult, String> {
        let format = json::get_str(v, "format")?;
        if format != SHARD_RESULT_FORMAT {
            return Err(format!(
                "not a shard result file: format {format:?}, want {SHARD_RESULT_FORMAT:?}"
            ));
        }
        let mut indices = Vec::new();
        let mut outcomes = Vec::new();
        for job in json::get_arr(v, "jobs")? {
            indices.push(json::get_usize(job, "index")?);
            outcomes.push(outcome_from_json(json::get(job, "outcome")?)?);
        }
        Ok(ShardResult {
            shard_index: json::get_usize(v, "shard_index")?,
            indices,
            outcomes,
            stats: CoordinatorStats::from_json(json::get(v, "stats")?)?,
        })
    }

    pub fn write_file(&self, path: &Path) -> Result<(), String> {
        std::fs::write(path, self.to_json().pretty())
            .map_err(|e| format!("write shard result {}: {e}", path.display()))
    }

    pub fn read_file(path: &Path) -> Result<ShardResult, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("read shard result {}: {e}", path.display()))?;
        ShardResult::from_json(&json::parse(&text)?)
    }
}

impl SweepResult {
    /// Wire encoding of a merged sweep. Deliberately free of
    /// wall-clock, host or process-count fields: the bytes depend only
    /// on the simulated work, so sharded and unsharded runs of the
    /// same sweep serialize identically (the CI smoke lane diffs them).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "outcomes",
                Json::Arr(self.outcomes.iter().map(outcome_to_json).collect()),
            ),
            ("stats", self.stats.to_json()),
        ])
    }

    pub fn from_json(v: &Json) -> Result<SweepResult, String> {
        let outcomes = json::get_arr(v, "outcomes")?
            .iter()
            .map(outcome_from_json)
            .collect::<Result<Vec<_>, _>>()?;
        Ok(SweepResult {
            outcomes,
            stats: CoordinatorStats::from_json(json::get(v, "stats")?)?,
        })
    }
}

/// Resolve the worker-pool size a shard should run with on THIS host.
///
/// A serialized shard embeds the `workers` count its *origin* host
/// planned with, which is wrong the moment the file ships to a machine
/// with a different core count. Precedence, highest first:
///
/// 1. **CLI** — a `--workers` flag passed to the worker process;
/// 2. **env** — this host's `OPENGEMM_WORKERS`;
/// 3. **shard file** — the origin host's embedded value;
/// 4. **auto** — `0`, deferring to the coordinator's host policy.
///
/// A resolved `0` (from an explicit `--workers 0` or an unconfigured
/// host) means "this host's default policy": the coordinator then
/// applies `OPENGEMM_WORKERS` if set, else machine auto-sizing — so
/// `--workers 0` discards the shard-embedded value but does NOT
/// suppress the env variable. A set-but-invalid `OPENGEMM_WORKERS` is
/// always a hard error (even under a CLI override): misconfiguration
/// fails fast, per [`parse_workers_env`].
pub fn resolve_worker_override(
    cli: Option<usize>,
    env: Option<&str>,
    shard_embedded: usize,
) -> Result<usize, String> {
    let env_workers = parse_workers_env(env)?;
    Ok(cli.or(env_workers).unwrap_or(shard_embedded))
}

/// Merge per-shard results back into submission order.
///
/// Fails (rather than guessing) if the shards do not form an exact
/// cover of `0..total_jobs` — the property the equality proof in the
/// module docs rests on.
pub fn merge(total_jobs: usize, shard_results: Vec<ShardResult>) -> Result<SweepResult, String> {
    let mut slots: Vec<Option<JobOutcome>> = (0..total_jobs).map(|_| None).collect();
    let mut stats = CoordinatorStats::default();
    for sr in shard_results {
        let ShardResult { shard_index, indices, outcomes, stats: shard_stats } = sr;
        if indices.len() != outcomes.len() {
            return Err(format!(
                "shard {shard_index}: {} indices vs {} outcomes",
                indices.len(),
                outcomes.len()
            ));
        }
        stats.accumulate(&shard_stats);
        for (index, outcome) in indices.into_iter().zip(outcomes) {
            if index >= total_jobs {
                return Err(format!(
                    "shard {shard_index}: job index {index} out of range (total {total_jobs})"
                ));
            }
            if slots[index].replace(outcome).is_some() {
                return Err(format!("job {index} covered by more than one shard"));
            }
        }
    }
    let outcomes = slots
        .into_iter()
        .enumerate()
        .map(|(i, slot)| slot.ok_or_else(|| format!("job {i} not covered by any shard")))
        .collect::<Result<Vec<_>, String>>()?;
    Ok(SweepResult { outcomes, stats })
}

/// Run an already-built plan in-process through the fault-tolerant
/// dispatcher ([`crate::coordinator::dispatch`]): every shard on its
/// own coordinator, one at a time (each shard already owns a worker
/// pool; process- and host-level parallelism come from the
/// `Subprocess`/`SpoolDir` transports in the `sweep` CLI), then merge.
pub fn run_plan(plan: SweepPlan) -> SweepResult {
    let (result, _report) = dispatch_plan(plan, &InProcess, &DispatchOptions::serial())
        .expect("in-process dispatch of an exact cover cannot fail");
    result
}

/// Run a whole sweep in-process through the shard machinery: plan with
/// a stride partition, run, merge.
///
/// With `opts.shards <= 1` this is exactly one `Coordinator::run_batch`
/// behind the shard API — the single code path all experiment drivers
/// now route through.
pub fn run_sweep(
    cfg: &PlatformConfig,
    requests: Vec<JobRequest>,
    opts: SweepOptions,
) -> SweepResult {
    // In-process dispatch of an exact cover cannot fail; the only
    // remaining failure is the static admission gate, which IS fatal
    // here (use run_sweep_cached for a recoverable error).
    run_sweep_cached(cfg, requests, opts, None).expect("sweep failed static admission")
}

/// [`run_sweep`] with an optional result cache in front of the
/// simulator (see [`crate::coordinator::cache`]): each job is looked up
/// before dispatch and only the misses are simulated, with the merged
/// result byte-identical to the uncached run. Fallible because a cache
/// in verify mode hard-errors on a divergent entry, and because the
/// default-on admission gate ([`SweepOptions::lint`]) rejects a job
/// carrying an error-severity static diagnostic before any dispatch.
pub fn run_sweep_cached(
    cfg: &PlatformConfig,
    requests: Vec<JobRequest>,
    opts: SweepOptions,
    cache: Option<&ResultCache>,
) -> Result<SweepResult, String> {
    if opts.lint {
        admit_requests(cfg, &requests)?;
    }
    let plan = SweepPlan::stride(cfg, requests, opts);
    let (result, _report) =
        dispatch_plan_cached(plan, &InProcess, &DispatchOptions::serial(), cache)?;
    Ok(result)
}

/// The static admission firewall: verify every *compilable* job before
/// dispatch. A job with an error-severity diagnostic fails the whole
/// sweep loudly, pre-dispatch, naming the diagnostic — never a worker
/// crash hours in. Jobs that do not compile pass through untouched:
/// they become per-job `Err` outcomes downstream, which DSE sweeps
/// legitimately record and rank.
fn admit_requests(cfg: &PlatformConfig, requests: &[JobRequest]) -> Result<(), String> {
    for (i, request) in requests.iter().enumerate() {
        let job = match crate::compiler::compile_gemm(
            cfg,
            request.shape,
            request.layout,
            request.repeats,
            request.mechanisms.config_preloading,
        ) {
            Ok(job) => job,
            Err(_) => continue, // recorded as a per-job Err outcome
        };
        let diags = crate::analysis::verify_job(cfg, &job);
        if let Some(d) = crate::analysis::first_error(&diags) {
            let s = request.shape;
            return Err(format!(
                "lint: job {i} (shape {}x{}x{}) rejected at admission: {} \
                 (run with --no-lint to bypass the static verifier)",
                s.m,
                s.k,
                s.n,
                d.render()
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::GemmShape;
    use crate::config::Mechanisms;

    fn requests(n: usize) -> Vec<JobRequest> {
        (0..n)
            .map(|i| {
                JobRequest::timing(
                    GemmShape::new(8 + 8 * (i % 4), 8 + 8 * (i % 3), 8 + 8 * (i % 5)),
                    if i % 2 == 0 { Mechanisms::ALL } else { Mechanisms::CPL_BUF },
                    1 + (i % 2) as u32,
                )
            })
            .collect()
    }

    #[test]
    fn stride_partition_is_an_exact_round_robin_cover() {
        let cfg = PlatformConfig::case_study();
        let opts = SweepOptions { shards: 3, ..Default::default() };
        let plan = SweepPlan::stride(&cfg, requests(10), opts);
        assert_eq!(plan.total_jobs, 10);
        assert_eq!(plan.shards.len(), 3);
        let mut seen = vec![false; 10];
        for shard in &plan.shards {
            assert_eq!(shard.indices.len(), shard.requests.len());
            for &i in &shard.indices {
                assert_eq!(i % 3, shard.shard_index, "stride assignment");
                assert!(!seen[i], "index {i} assigned twice");
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "every index covered");
    }

    #[test]
    fn contiguous_partition_is_an_exact_ordered_cover() {
        let cfg = PlatformConfig::case_study();
        let opts = SweepOptions { shards: 4, ..Default::default() };
        let plan = SweepPlan::contiguous(&cfg, requests(10), opts);
        // 10 jobs over 4 shards: 3, 3, 2, 2
        let sizes: Vec<usize> = plan.shards.iter().map(|s| s.indices.len()).collect();
        assert_eq!(sizes, vec![3, 3, 2, 2]);
        let flat: Vec<usize> =
            plan.shards.iter().flat_map(|s| s.indices.iter().copied()).collect();
        assert_eq!(flat, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn more_shards_than_jobs_collapses_to_job_count() {
        let cfg = PlatformConfig::case_study();
        let opts = SweepOptions { shards: 8, ..Default::default() };
        let plan = SweepPlan::stride(&cfg, requests(3), opts);
        assert_eq!(plan.shards.len(), 3);
        let plan = SweepPlan::stride(&cfg, Vec::new(), opts);
        assert_eq!(plan.shards.len(), 1);
        assert_eq!(plan.total_jobs, 0);
    }

    #[test]
    fn shard_file_roundtrip_is_lossless() {
        let cfg = PlatformConfig::case_study();
        let mut reqs = requests(5);
        reqs[1].operands = Some((vec![1i8, -2, 127, -128], vec![0i8, 5]));
        let opts = SweepOptions { shards: 2, workers: 3, ..Default::default() };
        let plan = SweepPlan::stride(&cfg, reqs, opts);
        for shard in &plan.shards {
            let text = shard.to_json().pretty();
            let back = Shard::from_json(&json::parse(&text).unwrap()).unwrap();
            assert_eq!(&back, shard);
        }
    }

    #[test]
    fn merge_rejects_gaps_and_overlaps() {
        let cfg = PlatformConfig::case_study();
        let opts = SweepOptions { shards: 2, ..Default::default() };
        let plan = SweepPlan::stride(&cfg, requests(4), opts);
        let results: Vec<ShardResult> = plan.shards.iter().cloned().map(Shard::run).collect();

        // exact cover merges
        assert!(merge(4, results.clone()).is_ok());
        // a missing shard is a gap
        let err = merge(4, vec![results[0].clone()]).unwrap_err();
        assert!(err.contains("not covered"), "{err}");
        // a duplicated shard is an overlap
        let err = merge(4, vec![results[0].clone(), results[0].clone(), results[1].clone()])
            .unwrap_err();
        assert!(err.contains("more than one shard"), "{err}");
        // an out-of-range index is rejected
        let err = merge(2, results.clone()).unwrap_err();
        assert!(err.contains("out of range"), "{err}");
    }

    #[test]
    fn worker_override_precedence_is_cli_env_shard_auto() {
        // CLI beats everything below it
        assert_eq!(resolve_worker_override(Some(6), Some("4"), 2), Ok(6));
        // --workers 0 resets to the HOST's default policy: it discards
        // the shard-embedded value, and the coordinator then applies
        // env (if set) or machine auto-sizing
        assert_eq!(resolve_worker_override(Some(0), Some("4"), 2), Ok(0));
        // env beats the shard-embedded origin-host value
        assert_eq!(resolve_worker_override(None, Some("4"), 2), Ok(4));
        // the shard file only applies when this host says nothing
        assert_eq!(resolve_worker_override(None, None, 2), Ok(2));
        // ... and auto-sizing (0) survives when nobody overrides
        assert_eq!(resolve_worker_override(None, None, 0), Ok(0));
        // a set-but-invalid env is a hard error even under a CLI
        // override: misconfiguration never passes silently
        assert!(resolve_worker_override(Some(6), Some("zero"), 2).is_err());
        assert!(resolve_worker_override(None, Some("0"), 2).is_err());
    }

    #[test]
    fn admission_gate_rejects_statically_illegal_jobs() {
        let cfg = PlatformConfig::case_study();
        // repeats = 0 compiles fine but the host repeat loop never
        // terminates — the A005 diagnostic the gate must surface
        // pre-dispatch instead of hanging a worker.
        let bad = vec![
            JobRequest::timing(GemmShape::new(16, 16, 16), Mechanisms::ALL, 1),
            JobRequest::timing(GemmShape::new(16, 16, 16), Mechanisms::ALL, 0),
        ];
        let err = run_sweep_cached(&cfg, bad, SweepOptions::default(), None).unwrap_err();
        assert!(err.contains("A005-loop-bound-range"), "got: {err}");
        assert!(err.contains("job 1"), "error names the offending job: {err}");
        assert!(err.contains("--no-lint"), "error names the bypass: {err}");

        // An uncompilable job is NOT a gate rejection: it flows through
        // as a per-job Err outcome (DSE sweeps record those).
        let huge = vec![JobRequest::timing(GemmShape::new(8, 300_000, 8), Mechanisms::ALL, 1)];
        let res = run_sweep_cached(&cfg, huge, SweepOptions::default(), None).unwrap();
        assert!(res.outcomes[0].is_err());
    }

    #[test]
    fn sharded_sweep_matches_unsharded_batch() {
        let cfg = PlatformConfig::case_study();
        let reqs = requests(8);

        let unsharded = Coordinator::new(cfg.clone()).with_workers(2);
        let want = unsharded.run_batch(reqs.clone());
        let want_stats = unsharded.stats();

        for shards in [2usize, 3] {
            let opts = SweepOptions { shards, workers: 2, ..Default::default() };
            let got = run_sweep(&cfg, reqs.clone(), opts);
            assert_eq!(got.outcomes, want, "{shards}-shard outcomes");
            assert_eq!(got.stats, want_stats, "{shards}-shard stats");
        }
    }
}
