//! Service-time model: device cycles to serve one request.
//!
//! A request's stream repeats each GeMM shape `count` times
//! (attention heads, stacked layers). Simulating every repetition of
//! every request would be wasteful — identical repetitions cost
//! identical cycles — so the model measures each distinct
//! `(shape, repeats)` point once through the coordinator and reuses
//! it.
//!
//! ## Honest amortization (the repeat-clamp fix)
//!
//! The old `bert_serving` example clamped the simulated repeat count
//! to 12 and rescaled by `count`, i.e. it priced `count` runs at
//! `count * T(12) / 12`. That bakes `1/12`th of the one-time
//! configuration cost into *every* run, so any stream with more than
//! 12 repetitions (BERT-Large has 16 heads) was silently mismeasured.
//! This model is exact up to [`ServiceModel::cap`] repetitions —
//! `count <= cap` streams are simulated with their true repeat count,
//! no clamp — and beyond the cap extrapolates affinely from two
//! measured points:
//!
//! ```text
//! T(count) ~= T(cap) + (count - cap) * (T(cap) - T(1)) / (cap - 1)
//! ```
//!
//! The first run pays the cold-start cost, every later run the
//! steady-state marginal cost — exact when cycles are affine in the
//! repeat count, which configuration pre-loading makes true once the
//! pipeline reaches steady state (the `serving_harness` integration
//! test checks the extrapolation against an exact simulation).

use std::collections::{BTreeMap, BTreeSet};

use crate::compiler::GemmShape;
use crate::config::{Mechanisms, PlatformConfig};
use crate::coordinator::cache::{derive_stats, job_key, ResultCache};
use crate::coordinator::{outcome_to_json, Coordinator, CoordinatorStats, JobOutcome, JobRequest};
use crate::sim::SimOptions;

use super::workload::RequestKind;

type ShapeKey = (usize, usize, usize, u32);

/// Cached per-`(shape, repeats)` cycle measurements.
#[derive(Debug, Clone)]
pub struct ServiceModel {
    /// Largest repeat count measured exactly (>= 2: the extrapolation
    /// needs two distinct measured points).
    cap: u32,
    cache: BTreeMap<ShapeKey, u64>,
}

fn key(shape: GemmShape, repeats: u32) -> ShapeKey {
    (shape.m, shape.k, shape.n, repeats)
}

impl ServiceModel {
    pub fn new(cap: u32) -> ServiceModel {
        ServiceModel { cap: cap.max(2), cache: BTreeMap::new() }
    }

    pub fn cap(&self) -> u32 {
        self.cap
    }

    /// The repeat counts that must be measured to price `count`
    /// repetitions of one shape.
    fn repeats_needed(&self, count: u64) -> Vec<u32> {
        if count <= self.cap as u64 {
            vec![count as u32]
        } else {
            vec![1, self.cap]
        }
    }

    /// Measure every `(shape, repeats)` point the given request kinds
    /// need, batching all simulations through one coordinator pool.
    /// Returns the coordinator's (deterministic) simulation counters.
    ///
    /// The cache commit is all-or-nothing: if any job in the batch
    /// fails, no measurement from the batch is cached and the model is
    /// exactly as it was — a retry after fixing the workload re-measures
    /// from a clean slate instead of trusting a half-populated batch.
    pub fn measure(
        &mut self,
        cfg: &PlatformConfig,
        workers: usize,
        fast_forward: bool,
        kinds: &[RequestKind],
    ) -> Result<CoordinatorStats, String> {
        self.measure_cached(cfg, workers, fast_forward, kinds, None)
    }

    /// [`ServiceModel::measure`] with an optional persistent result
    /// cache (`coordinator::cache`) in front of the coordinator: the
    /// per-`(shape, repeats)` measurements of one serve process become
    /// warm entries for the next, so re-pricing a workload after a
    /// restart simulates nothing. The returned counters are derived
    /// per-outcome exactly as `run_batch` counts them, so a warm run's
    /// serve report is byte-identical to the cold run's. In verify mode
    /// every point re-simulates and a divergent cached entry is a hard
    /// error.
    pub fn measure_cached(
        &mut self,
        cfg: &PlatformConfig,
        workers: usize,
        fast_forward: bool,
        kinds: &[RequestKind],
        cache: Option<&ResultCache>,
    ) -> Result<CoordinatorStats, String> {
        // BTreeSet dedup: a large mixed workload repeats the same
        // (shape, repeats) point across kinds, and `Vec::contains` made
        // this scan O(n^2). Sorted iteration keeps the batch order (and
        // so the coordinator's deterministic counters) reproducible.
        let mut wanted: BTreeSet<ShapeKey> = BTreeSet::new();
        for kind in kinds {
            for &(shape, count) in &kind.stream {
                if count == 0 {
                    continue;
                }
                for repeats in self.repeats_needed(count) {
                    let k = key(shape, repeats);
                    if !self.cache.contains_key(&k) {
                        wanted.insert(k);
                    }
                }
            }
        }
        let requests: Vec<JobRequest> = wanted
            .iter()
            .map(|&(m, k, n, repeats)| {
                JobRequest::timing(GemmShape::new(m, k, n), Mechanisms::ALL, repeats)
            })
            .collect();
        // Coordinator::new runs with the default CSR latency; the cache
        // key must say so, or serve entries would alias sweep entries
        // measured under a different host coupling.
        let csr_latency = SimOptions::default().csr_latency;
        let keys: Vec<String> = match cache {
            Some(_) => requests
                .iter()
                .map(|r| job_key(cfg, fast_forward, csr_latency, r))
                .collect(),
            None => Vec::new(),
        };
        let verify = cache.is_some_and(ResultCache::verify);

        // Resolve what we can from the cache; everything else (all
        // points, in verify mode) goes to the coordinator in one batch.
        let mut slot_outcomes: Vec<Option<JobOutcome>> = vec![None; requests.len()];
        let mut cold_slots: Vec<usize> = Vec::new();
        let (mut hits, mut misses) = (0u64, 0u64);
        match cache {
            Some(cache) if !verify => {
                for (slot, k) in keys.iter().enumerate() {
                    match cache.lookup(k) {
                        Some(outcome) => {
                            hits += 1;
                            slot_outcomes[slot] = Some(outcome);
                        }
                        None => {
                            misses += 1;
                            cold_slots.push(slot);
                        }
                    }
                }
            }
            _ => cold_slots = (0..requests.len()).collect(),
        }
        let mut coord = Coordinator::new(cfg.clone()).with_fast_forward(fast_forward);
        if workers > 0 {
            coord = coord.with_workers(workers);
        }
        let fresh =
            coord.run_batch(cold_slots.iter().map(|&s| requests[s].clone()).collect());
        if let Some(cache) = cache {
            for (&slot, outcome) in cold_slots.iter().zip(&fresh) {
                let k = &keys[slot];
                if verify {
                    match cache.lookup(k) {
                        Some(cached) => {
                            hits += 1;
                            if outcome_to_json(&cached).pretty()
                                != outcome_to_json(outcome).pretty()
                            {
                                return Err(format!(
                                    "cache verify FAILED for key {k}: cached outcome \
                                     diverges from re-simulation (determinism \
                                     regression, or a corrupted store evading the \
                                     entry checks)"
                                ));
                            }
                        }
                        None => {
                            misses += 1;
                            cache.insert(k, outcome);
                        }
                    }
                } else {
                    cache.insert(k, outcome);
                }
            }
        }
        let jobs_simulated = cold_slots.len() as u64;
        for (&slot, outcome) in cold_slots.iter().zip(fresh) {
            slot_outcomes[slot] = Some(outcome);
        }
        let outcomes: Vec<JobOutcome> =
            slot_outcomes.into_iter().map(|o| o.expect("every slot resolved")).collect();

        let mut measured: Vec<(ShapeKey, u64)> = Vec::with_capacity(wanted.len());
        for (&(m, k, n, repeats), outcome) in wanted.iter().zip(&outcomes) {
            let result = outcome
                .as_ref()
                .map_err(|e| format!("measuring ({m}, {k}, {n}) x{repeats}: {e}"))?;
            measured.push(((m, k, n, repeats), result.metrics.total_cycles));
        }
        // every job succeeded: commit the whole batch
        for (k, cycles) in measured {
            self.cache.insert(k, cycles);
        }
        let mut stats = derive_stats(outcomes.iter());
        stats.cache_hits = hits;
        stats.cache_misses = misses;
        stats.jobs_simulated = jobs_simulated;
        Ok(stats)
    }

    fn lookup(&self, shape: GemmShape, repeats: u32) -> Result<u64, String> {
        self.cache.get(&key(shape, repeats)).copied().ok_or_else(|| {
            format!(
                "({}, {}, {}) x{repeats} not measured — call measure() first",
                shape.m, shape.k, shape.n
            )
        })
    }

    /// Device cycles for `count` back-to-back repetitions of one shape:
    /// exact for `count <= cap`, affine extrapolation beyond.
    pub fn shape_cycles(&self, shape: GemmShape, count: u64) -> Result<u64, String> {
        if count == 0 {
            return Ok(0);
        }
        if count <= self.cap as u64 {
            return self.lookup(shape, count as u32);
        }
        let t1 = self.lookup(shape, 1)?;
        let tc = self.lookup(shape, self.cap)?;
        let marginal = tc.saturating_sub(t1) as f64 / (self.cap - 1) as f64;
        Ok(tc + ((count - self.cap as u64) as f64 * marginal).round() as u64)
    }

    /// Device cycles to serve one request of this stream: the sum of
    /// its per-shape costs (the GeMMs of one request run sequentially
    /// on the single device).
    pub fn stream_cycles(&self, stream: &[(GemmShape, u64)]) -> Result<u64, String> {
        let mut total = 0u64;
        for &(shape, count) in stream {
            total += self.shape_cycles(shape, count)?;
        }
        Ok(total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_up_to_cap_no_clamp() {
        // A 16-repeat stream on a cap-16 model must be priced from the
        // exact T(16) measurement, not a clamped-and-rescaled one.
        let cfg = PlatformConfig::case_study();
        let mut model = ServiceModel::new(16);
        let shape = GemmShape::new(24, 64, 24);
        let kind = RequestKind { label: "t".into(), stream: vec![(shape, 16)] };
        model.measure(&cfg, 2, true, std::slice::from_ref(&kind)).unwrap();
        let got = model.stream_cycles(&kind.stream).unwrap();
        let exact = Coordinator::new(cfg.clone())
            .run_one(&JobRequest::timing(shape, Mechanisms::ALL, 16))
            .unwrap()
            .metrics
            .total_cycles;
        assert_eq!(got, exact, "16 repeats measured exactly, no 12-clamp");
    }

    #[test]
    fn zero_count_items_cost_nothing() {
        let model = ServiceModel::new(4);
        assert_eq!(model.shape_cycles(GemmShape::new(8, 8, 8), 0).unwrap(), 0);
    }

    #[test]
    fn unmeasured_shape_is_an_error_not_a_panic() {
        let model = ServiceModel::new(4);
        let err = model.shape_cycles(GemmShape::new(8, 8, 8), 2).unwrap_err();
        assert!(err.contains("not measured"), "{err}");
    }

    #[test]
    fn cap_is_at_least_two() {
        assert_eq!(ServiceModel::new(0).cap(), 2);
        assert_eq!(ServiceModel::new(1).cap(), 2);
        assert_eq!(ServiceModel::new(16).cap(), 16);
    }

    #[test]
    fn failed_measure_commits_nothing_and_retry_recovers() {
        let cfg = PlatformConfig::case_study();
        let mut model = ServiceModel::new(4);
        let good = GemmShape::new(16, 16, 16);
        let bad = GemmShape::new(8, 300_000, 8); // oversized K fails the tiler
        let kinds = vec![
            RequestKind { label: "good".into(), stream: vec![(good, 2)] },
            RequestKind { label: "bad".into(), stream: vec![(bad, 1)] },
        ];
        let err = model.measure(&cfg, 2, true, &kinds).unwrap_err();
        assert!(err.contains("300000"), "{err}");
        // all-or-nothing: the good shape ran in the same batch but must
        // NOT have been cached alongside the failure
        let err = model.shape_cycles(good, 2).unwrap_err();
        assert!(err.contains("not measured"), "{err}");

        // retry with the bad kind dropped: measures from a clean slate
        // and prices the good shape identically to a fresh model
        model.measure(&cfg, 2, true, &kinds[..1]).unwrap();
        let got = model.shape_cycles(good, 2).unwrap();
        let mut fresh = ServiceModel::new(4);
        fresh.measure(&cfg, 2, true, &kinds[..1]).unwrap();
        assert_eq!(got, fresh.shape_cycles(good, 2).unwrap());
    }

    #[test]
    fn duplicate_points_across_kinds_are_measured_once() {
        let cfg = PlatformConfig::case_study();
        let mut model = ServiceModel::new(4);
        let shape = GemmShape::new(16, 16, 16);
        // the same (shape, repeats) point appears in many kinds (the
        // O(n^2) Vec::contains hot spot); the batch must dedup it
        let kinds: Vec<RequestKind> = (0..6)
            .map(|i| RequestKind { label: format!("k{i}"), stream: vec![(shape, 2)] })
            .collect();
        let stats = model.measure(&cfg, 2, true, &kinds).unwrap();
        assert_eq!(stats.jobs_completed, 1, "one measurement for six kinds");
    }

    #[test]
    fn extrapolation_uses_marginal_cost() {
        // Synthetic affine cache: T(1) = 100, T(4) = 250 -> marginal 50.
        let mut model = ServiceModel::new(4);
        let shape = GemmShape::new(8, 8, 8);
        model.cache.insert(key(shape, 1), 100);
        model.cache.insert(key(shape, 4), 250);
        // T(10) = 250 + 6 * 50 = 550 — NOT 10 * (250/4) = 625, which is
        // what clamp-and-rescale would report
        assert_eq!(model.shape_cycles(shape, 10).unwrap(), 550);
    }
}
