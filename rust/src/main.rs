//! `opengemm` — command-line launcher for the OpenGeMM reproduction
//! platform.
//!
//! Subcommands map one-to-one to the paper's experiments (DESIGN.md
//! experiment index):
//!
//! ```text
//! opengemm simulate  --shape MxKxN [--arch 1..4] [--repeats R] [--layout L]
//! opengemm ablation  [--workloads N] [--seed S] [--repeats R]      # Fig. 5
//! opengemm dnn       [--bert-seq S]                                # Table 2
//! opengemm area-power                                              # Fig. 6
//! opengemm sota                                                    # Table 3
//! opengemm compare-gemmini [--repeats R]                           # Fig. 7
//! opengemm verify    [--artifacts DIR]     # simulator vs PJRT golden model
//! opengemm info      [--config FILE.toml]  # show an instance's parameters
//! ```

use opengemm::util::error::Result;
use opengemm::{anyhow, bail};

use opengemm::compiler::{GemmShape, Layout};
use opengemm::config::{Mechanisms, PlatformConfig};
use opengemm::coordinator::{Coordinator, JobRequest};
use opengemm::experiments::{
    fig5_ablation, fig6_area_power, fig7_gemmini, table2_dnn, table3_sota, Fig5Options,
    Fig7Options, Table2Options,
};
use opengemm::power::PowerModel;
use opengemm::runtime::Runtime;
use opengemm::util::cli::Args;
use opengemm::util::rng::Pcg32;

const USAGE: &str = "\
opengemm — cycle-accurate OpenGeMM platform (ASPDAC'25 reproduction)

USAGE:
  opengemm <subcommand> [flags]

SUBCOMMANDS:
  simulate          run one GeMM through the platform simulator
                    --shape MxKxN  --arch 1|2|3|4  --repeats N
                    --layout row|tiled|interleaved  --functional
  ablation          Fig. 5: mechanism ablation over random workloads
                    --workloads N  --seed S  --repeats N  --workers N
  dnn               Table 2: DNN benchmark (MobileNetV2/ResNet18/ViT/BERT)
                    --bert-seq N  --workers N
  area-power        Fig. 6: area & power breakdown, TOPS/W
  sota              Table 3: state-of-the-art comparison
  compare-gemmini   Fig. 7: normalized throughput vs Gemmini OS/WS
                    --repeats N
  verify            functional equivalence: simulator vs AOT artifacts
                    --artifacts DIR
  info              print platform instance parameters
                    --config FILE.toml

GLOBAL FLAGS:
  --no-fast-forward run the simulator in per-cycle lockstep instead of
                    the event-driven cycle-skipping engine (slow; the
                    two are verified cycle-exact against each other)
";

fn mechanisms_for(arch: usize) -> Result<Mechanisms> {
    Ok(match arch {
        1 => Mechanisms::BASELINE,
        2 => Mechanisms::CPL,
        3 => Mechanisms::CPL_BUF,
        4 => Mechanisms::ALL,
        a => bail!("--arch must be 1..4, got {a}"),
    })
}

fn layout_for(name: &str) -> Result<Layout> {
    Ok(match name {
        "row" => Layout::RowMajor,
        "tiled" => Layout::TiledContiguous,
        "interleaved" => Layout::TiledInterleaved,
        other => bail!("--layout must be row|tiled|interleaved, got {other}"),
    })
}

fn load_config(args: &Args) -> Result<PlatformConfig> {
    match args.get("config") {
        None => Ok(PlatformConfig::case_study()),
        Some(path) => {
            let text = std::fs::read_to_string(path)?;
            PlatformConfig::from_toml(&text).map_err(|e| anyhow!("{e}"))
        }
    }
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let shape = args.shape_or("shape", (64, 64, 64))?;
    let shape = GemmShape::new(shape.0, shape.1, shape.2);
    let mech = mechanisms_for(args.usize_or("arch", 4)?)?;
    let repeats = args.usize_or("repeats", 10)? as u32;
    let layout = match args.get("layout") {
        Some(l) => layout_for(l)?,
        None => {
            if mech.strided_layout {
                Layout::TiledInterleaved
            } else {
                Layout::RowMajor
            }
        }
    };
    let functional = args.has("functional");

    let coord =
        Coordinator::new(cfg.clone()).with_fast_forward(args.enabled_unless_no("fast-forward"));
    let operands = if functional {
        let mut rng = Pcg32::seeded(args.u64_or("seed", 42)?);
        let mut a = vec![0i8; shape.m * shape.k];
        let mut b = vec![0i8; shape.k * shape.n];
        rng.fill_i8(&mut a);
        rng.fill_i8(&mut b);
        Some((a, b))
    } else {
        None
    };
    let req = JobRequest { shape, layout, mechanisms: mech, repeats, operands };
    let r = coord.run_one(&req).map_err(|e| anyhow!(e))?;
    println!("shape          ({}, {}, {})", shape.m, shape.k, shape.n);
    println!("arch           {}", mech.label());
    println!("layout         {layout:?}  repeats {repeats}");
    println!("total cycles   {}", r.metrics.total_cycles);
    println!("compute cycles {}", r.metrics.compute_cycles);
    println!(
        "stalls         A {} / B {} / out {}",
        r.metrics.stall_input_a, r.metrics.stall_input_b, r.metrics.stall_output
    );
    println!("host instret   {}", r.metrics.host_instret);
    println!(
        "SU {:.4}  TU {:.4}  OU {:.4}  (kernel TU {:.4})",
        r.report.spatial,
        r.report.temporal,
        r.report.overall,
        r.metrics.kernel_utilization()
    );
    let gops = r.report.achieved_gops(shape.ops() * repeats as u64, cfg.freq_mhz);
    println!("achieved       {gops:.2} GOPS of {:.1} peak", cfg.peak_gops());
    if let Some(c) = r.c {
        let checksum: i64 = c.iter().map(|&v| v as i64).sum();
        println!("functional     C checksum {checksum}");
    }
    Ok(())
}

fn cmd_ablation(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let opts = Fig5Options {
        seed: args.u64_or("seed", 2024)?,
        workloads: args.usize_or("workloads", 500)?,
        repeats: args.usize_or("repeats", 10)? as u32,
        workers: args.usize_or("workers", 0)?,
        fast_forward: args.enabled_unless_no("fast-forward"),
    };
    eprintln!(
        "running {} workloads x 10 repeats x 6 variants ...",
        opts.workloads
    );
    let res = fig5_ablation(&cfg, opts);
    println!("{}", res.render());
    maybe_write(args, "fig5", &res.render())
}

fn cmd_dnn(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let opts = Table2Options {
        bert_seq: args.usize_or("bert-seq", 512)?,
        workers: args.usize_or("workers", 0)?,
        max_repeats: args.usize_or("max-repeats", 10)? as u32,
        fast_forward: args.enabled_unless_no("fast-forward"),
    };
    let res = table2_dnn(&cfg, opts);
    println!("{}", res.render());
    maybe_write(args, "table2", &res.render())
}

fn cmd_area_power(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let res = fig6_area_power(&cfg);
    println!("{}", res.render());
    maybe_write(args, "fig6", &res.render())
}

fn cmd_sota(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let res = table3_sota(&cfg);
    println!("{}", res.render());
    maybe_write(args, "table3", &res.render())
}

fn cmd_compare_gemmini(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let opts = Fig7Options {
        repeats: args.usize_or("repeats", 10)? as u32,
        workers: args.usize_or("workers", 0)?,
        fast_forward: args.enabled_unless_no("fast-forward"),
    };
    let res = fig7_gemmini(&cfg, opts);
    println!("{}", res.render());
    maybe_write(args, "fig7", &res.render())
}

fn cmd_verify(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let dir = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(Runtime::default_dir);
    let mut rt = Runtime::load(&dir)?;
    let coord =
        Coordinator::new(cfg.clone()).with_fast_forward(args.enabled_unless_no("fast-forward"));
    let mut rng = Pcg32::seeded(args.u64_or("seed", 7)?);
    let mut checked = 0;
    for name in rt.artifact_names().iter().map(|s| s.to_string()).collect::<Vec<_>>() {
        if !name.starts_with("gemm_") {
            continue;
        }
        let meta = rt.meta(&name).unwrap().clone();
        let (m, k) = (meta.args[0].shape[0], meta.args[0].shape[1]);
        let n = meta.args[1].shape[1];
        let mut a = vec![0i8; m * k];
        let mut b = vec![0i8; k * n];
        rng.fill_i8(&mut a);
        rng.fill_i8(&mut b);
        let golden = rt.execute_gemm(&name, &a, &b)?;
        let req = JobRequest {
            shape: GemmShape::new(m, k, n),
            layout: Layout::TiledInterleaved,
            mechanisms: Mechanisms::ALL,
            repeats: 1,
            operands: Some((a, b)),
        };
        let sim = coord.run_one(&req).map_err(|e| anyhow!(e))?;
        let c = sim.c.expect("functional result");
        if c != golden {
            bail!("MISMATCH on {name}: simulator != AOT golden model");
        }
        println!("  {name:<24} ({m} x {k} x {n})  OK — bit-exact");
        checked += 1;
    }
    println!("verified {checked} GeMM artifacts: simulator == JAX/Pallas golden model");
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let model = PowerModel::default();
    println!("OpenGeMM platform instance");
    println!("  core     (Mu, Nu, Ku) = ({}, {}, {})", cfg.core.mu, cfg.core.nu, cfg.core.ku);
    println!(
        "  precision A/B/C       = {}/{}/{} bit",
        cfg.core.pa_bits, cfg.core.pb_bits, cfg.core.pc_bits
    );
    println!("  SPM      {} banks x {} x {}B = {} KiB",
        cfg.mem.n_bank, cfg.mem.d_mem, cfg.mem.word_bytes(),
        cfg.mem.capacity_bytes() / 1024);
    println!("  ports    R {} / W {}  buffers depth {}", cfg.mem.r_mem, cfg.mem.w_mem, cfg.mem.d_stream);
    println!("  clock    {} MHz", cfg.freq_mhz);
    println!("  peak     {:.1} GOPS", cfg.peak_gops());
    println!("  area     {:.3} mm^2 cell / {:.3} mm^2 layout (modeled)",
        model.total_area(&cfg), model.layout_area(&cfg));
    println!("  power    {:.1} mW @ full load -> {:.2} TOPS/W",
        model.total_power(&cfg, 1.0), model.tops_per_watt(&cfg, 1.0));
    Ok(())
}

fn maybe_write(args: &Args, name: &str, content: &str) -> Result<()> {
    if let Some(dir) = args.get("out-dir") {
        std::fs::create_dir_all(dir)?;
        let path = std::path::Path::new(dir).join(format!("{name}.md"));
        std::fs::write(&path, content)?;
        eprintln!("wrote {path:?}");
    }
    Ok(())
}

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}\n{USAGE}");
            std::process::exit(2);
        }
    };
    let sub = args.positional().first().map(|s| s.as_str()).unwrap_or("help");
    let result = match sub {
        "simulate" => cmd_simulate(&args),
        "ablation" => cmd_ablation(&args),
        "dnn" => cmd_dnn(&args),
        "area-power" => cmd_area_power(&args),
        "sota" => cmd_sota(&args),
        "compare-gemmini" => cmd_compare_gemmini(&args),
        "verify" => cmd_verify(&args),
        "info" => cmd_info(&args),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => {
            eprintln!("unknown subcommand {other:?}\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
