//! The Fig. 5 random workload suite: 500 GeMM sizes with M, K, N drawn
//! uniformly from {8, 16, 24, ..., 256} (Sec. 4.2), seeded for
//! reproducibility.

use crate::compiler::GemmShape;
use crate::util::rng::Pcg32;

/// The paper's dimension grid: multiples of 8 in [8, 256].
pub const DIM_CHOICES: usize = 32;

/// Generate `count` random shapes from the paper's grid.
pub fn random_suite(seed: u64, count: usize) -> Vec<GemmShape> {
    let mut rng = Pcg32::seeded(seed);
    (0..count)
        .map(|_| {
            let dim = |rng: &mut Pcg32| (rng.below(DIM_CHOICES as u32) as usize + 1) * 8;
            GemmShape::new(dim(&mut rng), dim(&mut rng), dim(&mut rng))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_is_reproducible() {
        assert_eq!(random_suite(7, 100), random_suite(7, 100));
        assert_ne!(random_suite(7, 100), random_suite(8, 100));
    }

    #[test]
    fn dims_on_the_grid() {
        for s in random_suite(123, 500) {
            for d in [s.m, s.k, s.n] {
                assert!(d % 8 == 0 && (8..=256).contains(&d), "dim {d}");
            }
        }
    }

    #[test]
    fn coverage_of_extremes() {
        let suite = random_suite(42, 500);
        assert!(suite.iter().any(|s| s.m == 8 || s.k == 8 || s.n == 8));
        assert!(suite.iter().any(|s| s.m == 256 || s.k == 256 || s.n == 256));
    }
}
