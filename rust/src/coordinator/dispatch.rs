//! Fault-tolerant sweep dispatcher: drive a [`SweepPlan`] to completion
//! over a pluggable [`Transport`], with bounded retry and straggler
//! re-dispatch.
//!
//! The shard files from the sharded sweep engine are self-contained
//! (config + options + jobs), so distributing a sweep across processes
//! or hosts needs no new wire format — only transport and policy. This
//! module supplies both:
//!
//! - **Transports** move one [`Shard`] to an executor and its
//!   [`ShardResult`] back: [`InProcess`] (run on a local coordinator),
//!   [`Subprocess`] (spawn a worker process of this binary — the old
//!   `sweep --processes N` driver path), and [`SpoolDir`] (serialize
//!   the shard into a watched directory and poll for the result file —
//!   the cross-host primitive: any remote host running `opengemm sweep
//!   --spool-serve DIR`, or plain `--shard FILE --out FILE`, against a
//!   shared directory participates). [`FaultInjector`] wraps any
//!   transport with deterministic transient failures for testing.
//! - **Policy** ([`dispatch_plan`]) retries a failed shard up to
//!   `max_retries` times (error provenance lands in the
//!   [`DispatchReport`]), speculatively re-dispatches stragglers (a
//!   shard exceeding `straggler_factor x` the median completed-shard
//!   wall time gets a second in-flight copy; the first result wins and
//!   duplicates are discarded by `shard_index`), and fails loudly with
//!   the full per-attempt error chain once a shard exhausts its budget.
//!
//! ## Why retries and duplicates cannot change the answer
//!
//! Every shard is a deterministic function of its serialized bytes, so
//! any two successful runs of the same shard return identical results;
//! keeping the first and discarding duplicates is therefore a pure
//! de-dup, not a choice of answer. The scheduler validates each result
//! against the shard it dispatched (matching `shard_index` and index
//! cover) before accepting it, and [`merge`] re-checks that accepted
//! results form an exact cover of the submission order. The merged
//! [`SweepResult`] is consequently byte-identical to the unsharded run
//! regardless of retries, speculation, or arrival order — pinned by the
//! `dispatch_fault_injection` integration tests and the CI
//! `sched-smoke` lane.

use std::collections::{BTreeMap, VecDeque};
use std::path::{Path, PathBuf};
use std::process::{Command, Stdio};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::cache::{derive_stats, shard_job_keys, ResultCache};
use crate::coordinator::shard::{
    merge, resolve_worker_override, Shard, ShardResult, SweepPlan, SweepResult,
};
use crate::coordinator::{outcome_to_json, parse_workers_env};
use crate::util::json::{self, Json};
use crate::util::stats::quantile_sorted;

/// Cooperative cancellation for in-flight dispatches. Set when the
/// attempt's result can no longer matter (its shard already completed
/// via another attempt, or the whole dispatch is over); transports that
/// wait on external executors should poll it and bail out early.
pub type CancelFlag = AtomicBool;

/// Moves one shard to an executor and its result back.
///
/// `attempt` is 0-based and unique per shard within one dispatch, so
/// file-based transports can name artifacts per attempt and a retry
/// never reads a stale or half-written file from an earlier try.
/// Implementations must be [`Sync`]: the scheduler calls `dispatch`
/// from several threads at once.
pub trait Transport: Sync {
    fn dispatch(
        &self,
        shard: &Shard,
        attempt: u32,
        cancel: &CancelFlag,
    ) -> Result<ShardResult, String>;

    /// Short label for reports and error messages.
    fn name(&self) -> &'static str;
}

impl<T: Transport + ?Sized> Transport for Box<T> {
    fn dispatch(
        &self,
        shard: &Shard,
        attempt: u32,
        cancel: &CancelFlag,
    ) -> Result<ShardResult, String> {
        (**self).dispatch(shard, attempt, cancel)
    }

    fn name(&self) -> &'static str {
        (**self).name()
    }
}

/// Run the shard on a coordinator inside this process.
///
/// Dispatch clones the shard: [`Shard::run`] consumes its input, but
/// the scheduler must retain every shard until an attempt succeeds —
/// the retry and straggler policies re-dispatch from the same shard.
/// All experiment sweeps are timing-only (no inline operands), so the
/// clone is a few hundred shapes, not operand payloads.
pub struct InProcess;

impl Transport for InProcess {
    fn dispatch(
        &self,
        shard: &Shard,
        _attempt: u32,
        _cancel: &CancelFlag,
    ) -> Result<ShardResult, String> {
        Ok(shard.clone().run())
    }

    fn name(&self) -> &'static str {
        "in-process"
    }
}

/// Spawn a worker process of this binary per shard (`opengemm sweep
/// --shard FILE --out FILE`) — the multi-process driver path.
pub struct Subprocess {
    exe: PathBuf,
    dir: PathBuf,
    /// File-name prefix, so several dispatches can share one directory.
    prefix: String,
    /// Leave shard/result files behind (the hand-a-shard-to-another-host
    /// workflow needs them to survive the run).
    keep_files: bool,
    /// The driver's own `--workers` flag, forwarded to every child so
    /// the documented precedence (CLI > `OPENGEMM_WORKERS` > shard
    /// file) holds on the children too — driver and children share one
    /// host, so the operator's explicit flag must beat the inherited
    /// env variable.
    cli_workers: Option<usize>,
}

impl Subprocess {
    pub fn new(
        dir: &Path,
        prefix: &str,
        keep_files: bool,
        cli_workers: Option<usize>,
    ) -> Result<Subprocess, String> {
        let exe = std::env::current_exe()
            .map_err(|e| format!("subprocess transport: current_exe: {e}"))?;
        std::fs::create_dir_all(dir)
            .map_err(|e| format!("subprocess transport: create {}: {e}", dir.display()))?;
        Ok(Subprocess {
            exe,
            dir: dir.to_path_buf(),
            prefix: prefix.to_string(),
            keep_files,
            cli_workers,
        })
    }
}

impl Transport for Subprocess {
    fn dispatch(
        &self,
        shard: &Shard,
        attempt: u32,
        cancel: &CancelFlag,
    ) -> Result<ShardResult, String> {
        let stem = format!("{}s{}_a{}", self.prefix, shard.shard_index, attempt);
        let shard_path = self.dir.join(format!("{stem}.shard.json"));
        let result_path = self.dir.join(format!("{stem}.result.json"));
        shard.write_file(&shard_path)?;
        let mut command = Command::new(&self.exe);
        command
            .arg("sweep")
            .arg("--shard")
            .arg(&shard_path)
            .arg("--out")
            .arg(&result_path)
            .stdin(Stdio::null())
            .stdout(Stdio::null());
        if let Some(workers) = self.cli_workers {
            command.arg("--workers").arg(workers.to_string());
        }
        let mut child =
            command.spawn().map_err(|e| format!("spawn worker for {stem}: {e}"))?;
        // Poll rather than block in `wait`, so a cancelled duplicate
        // releases its slot (and its child) promptly.
        let status = loop {
            match child.try_wait() {
                Ok(Some(status)) => break status,
                Ok(None) => {
                    if cancel.load(Ordering::Relaxed) {
                        let _ = child.kill();
                        let _ = child.wait();
                        return Err(format!("worker for {stem} cancelled"));
                    }
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(e) => return Err(format!("wait on worker for {stem}: {e}")),
            }
        };
        let outcome = if status.success() {
            ShardResult::read_file(&result_path)
        } else {
            Err(format!("worker for {stem} failed with {status}"))
        };
        if !self.keep_files {
            let _ = std::fs::remove_file(&shard_path);
            let _ = std::fs::remove_file(&result_path);
        }
        outcome
    }

    fn name(&self) -> &'static str {
        "subprocess"
    }
}

/// Serialize the shard into a watched directory and poll for its result
/// file — the cross-host primitive. Any executor that can see the
/// directory (a shared filesystem, or an object store mounted/synced to
/// one) participates by running `opengemm sweep --spool-serve DIR`, or
/// by hand: `opengemm sweep --shard X.shard.json --out X.result.json`.
///
/// Protocol (all writes are temp-file + rename, so readers never see a
/// partial file):
/// - driver publishes `{stem}.shard.json`;
/// - an executor claims it by renaming to `{stem}.shard.json.claimed`
///   (atomic: exactly one claimant wins), runs it, publishes
///   `{stem}.result.json`;
/// - the driver polls for the result until `timeout`, then retracts the
///   offer and reports a transport failure (which the retry/straggler
///   policy may re-dispatch under a fresh attempt number).
///
/// Execution is at-least-once by design: if a timeout or cancellation
/// races an executor that already claimed the offer, the executor
/// still finishes and publishes a result nobody reads. Duplicated
/// work is bounded by the retry budget, correctness is unaffected
/// (results are deterministic and keyed by unique stems), but a
/// long-lived spool directory accumulates orphan `*.result.json`
/// files — operators should sweep old files periodically.
pub struct SpoolDir {
    dir: PathBuf,
    prefix: String,
    /// Unique per `SpoolDir` instance, embedded in every stem: a
    /// persistent spool directory (the recommended cross-host setup)
    /// may hold result files from earlier sweeps with the same variant
    /// / shard / attempt numbering, and reading one of those as this
    /// run's answer would merge stale data without any error.
    run_token: String,
    /// Resume mode ([`Self::with_resume`]): replace the per-run token
    /// with the shard's content fingerprint, so a re-run of a killed
    /// sweep produces the SAME stems and can claim results the dead
    /// run's executors already published. Content addressing is what
    /// makes this safe where token reuse would not be: a stale result
    /// can only be read under a stem that hashes the identical shard
    /// bytes, and determinism says that result is the answer.
    resume: bool,
    poll: Duration,
    timeout: Duration,
}

/// Distinguishes `SpoolDir` instances created by the same process.
static SPOOL_RUN_COUNTER: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);

impl SpoolDir {
    pub fn new(
        dir: &Path,
        prefix: &str,
        poll: Duration,
        timeout: Duration,
    ) -> Result<SpoolDir, String> {
        std::fs::create_dir_all(dir)
            .map_err(|e| format!("spool transport: create {}: {e}", dir.display()))?;
        // pid + boot-time nanos + counter: unique across runs AND
        // across driver hosts sharing one spool directory (pids alone
        // can collide between machines)
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos())
            .unwrap_or(0);
        let run_token = format!(
            "r{}x{:x}x{}",
            std::process::id(),
            nanos,
            SPOOL_RUN_COUNTER.fetch_add(1, Ordering::Relaxed)
        );
        Ok(SpoolDir {
            dir: dir.to_path_buf(),
            prefix: prefix.to_string(),
            run_token,
            resume: false,
            poll: poll.max(Duration::from_millis(1)),
            timeout,
        })
    }

    /// Content-addressed stems: offers are named by shard fingerprint
    /// instead of the per-run token, and an already-published result
    /// under that stem is claimed without re-dispatching. This is the
    /// killed-sweep resume path (`sweep --transport spool --cache`).
    pub fn with_resume(mut self, resume: bool) -> SpoolDir {
        self.resume = resume;
        self
    }
}

impl Transport for SpoolDir {
    fn dispatch(
        &self,
        shard: &Shard,
        attempt: u32,
        cancel: &CancelFlag,
    ) -> Result<ShardResult, String> {
        let token = if self.resume {
            format!("k{}", crate::coordinator::cache::shard_fingerprint(shard))
        } else {
            self.run_token.clone()
        };
        let stem = format!("{}{}_s{}_a{}", self.prefix, token, shard.shard_index, attempt);
        let shard_path = self.dir.join(format!("{stem}.shard.json"));
        let result_path = self.dir.join(format!("{stem}.result.json"));
        if self.resume && result_path.exists() {
            // A prior (possibly killed) run of this exact shard already
            // published its result — claim it instead of re-dispatching.
            // The scheduler still validates it like any other result; a
            // corrupt file is quarantined here so the retry (fresh
            // attempt number, fresh stem) re-dispatches cleanly.
            match ShardResult::read_file(&result_path) {
                Ok(result) => return Ok(result),
                Err(e) => {
                    eprintln!(
                        "spool resume: quarantining poison result {}: {e}",
                        result_path.display()
                    );
                    let poison = self.dir.join(format!("{stem}.result.json.poison"));
                    let _ = std::fs::rename(&result_path, poison);
                }
            }
        }
        write_atomically(&shard_path, &shard.to_json().pretty())?;
        let deadline = Instant::now() + self.timeout;
        loop {
            if result_path.exists() {
                // the executor also publishes via rename, so an
                // existing file is complete
                return ShardResult::read_file(&result_path);
            }
            if cancel.load(Ordering::Relaxed) {
                let _ = std::fs::remove_file(&shard_path);
                return Err(format!("spool offer {stem} cancelled"));
            }
            if Instant::now() >= deadline {
                // retract the offer so a dead executor's backlog does
                // not pile up; a claimed shard is already renamed away
                let _ = std::fs::remove_file(&shard_path);
                return Err(format!(
                    "spool result {} not produced within {:?} (is a worker \
                     watching the spool directory?)",
                    result_path.display(),
                    self.timeout
                ));
            }
            std::thread::sleep(self.poll);
        }
    }

    fn name(&self) -> &'static str {
        "spool"
    }
}

/// Write `text` to `path` via a temp file + rename, so concurrent
/// readers (spool executors, the dispatch driver) never observe a
/// partially-written JSON document.
pub fn write_atomically(path: &Path, text: &str) -> Result<(), String> {
    let tmp = path.with_extension("tmp");
    std::fs::write(&tmp, text).map_err(|e| format!("write {}: {e}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .map_err(|e| format!("rename {} -> {}: {e}", tmp.display(), path.display()))
}

/// Options for a spool-directory executor loop.
#[derive(Debug, Clone)]
pub struct SpoolWorkerOptions {
    /// Directory scan period.
    pub poll: Duration,
    /// Stop after serving this many shards (0 = run until `stop` is
    /// set or the process is killed).
    pub max_shards: usize,
    /// Worker-pool override from this host's command line (`None` =
    /// flag absent); combined with `OPENGEMM_WORKERS` and the
    /// shard-embedded value per [`resolve_worker_override`].
    pub cli_workers: Option<usize>,
}

impl Default for SpoolWorkerOptions {
    fn default() -> Self {
        SpoolWorkerOptions { poll: Duration::from_millis(25), max_shards: 0, cli_workers: None }
    }
}

/// Serve shards out of a spool directory until `stop` is set (or
/// `max_shards` are done): claim each `*.shard.json` by renaming it,
/// run it on a local coordinator, and publish the result file
/// atomically. Returns the number of shards served.
///
/// This is the executor side of the [`SpoolDir`] transport; `opengemm
/// sweep --spool-serve DIR` is a thin wrapper around it, and any number
/// of hosts may run it against the same directory (the claim rename
/// keeps them from double-running a shard).
pub fn spool_worker_loop(
    dir: &Path,
    opts: &SpoolWorkerOptions,
    stop: &AtomicBool,
) -> Result<usize, String> {
    let env = std::env::var("OPENGEMM_WORKERS").ok();
    // Fail fast on a misconfigured host BEFORE claiming anything: a
    // per-shard failure here would strand an already-claimed offer
    // until the driver's spool timeout expires.
    parse_workers_env(env.as_deref())?;
    let mut served = 0usize;
    while !stop.load(Ordering::Relaxed) && (opts.max_shards == 0 || served < opts.max_shards) {
        let mut claimed: Option<(String, PathBuf, PathBuf)> = None;
        let entries = std::fs::read_dir(dir)
            .map_err(|e| format!("spool worker: read {}: {e}", dir.display()))?;
        // Sibling paths are derived from the UTF-8 FILE NAME only (our
        // stems are generated ASCII), never from a lossy conversion of
        // the whole path: the spool DIRECTORY may contain non-UTF-8
        // bytes (legal on POSIX) that a lossy round-trip would mangle
        // into paths the driver never polls.
        let mut offers: Vec<(String, PathBuf)> = entries
            .filter_map(|e| e.ok())
            .filter_map(|e| {
                let name = e.file_name().into_string().ok()?;
                name.ends_with(".shard.json").then(|| (name, e.path()))
            })
            .collect();
        offers.sort(); // deterministic pickup order across scans
        for (name, offer) in offers {
            let claim = offer.with_file_name(format!("{name}.claimed"));
            // atomic claim: exactly one worker wins the rename
            if std::fs::rename(&offer, &claim).is_ok() {
                claimed = Some((name, offer, claim));
                break;
            }
        }
        let Some((name, offer, claim)) = claimed else {
            std::thread::sleep(opts.poll);
            continue;
        };
        let mut shard = match Shard::read_file(&claim) {
            Ok(shard) => shard,
            Err(e) => {
                // A corrupt or incompatible offer must not kill a
                // long-lived executor that other drivers depend on:
                // quarantine the file (evidence for the operator, and
                // the rename stops rescan loops) and keep serving.
                eprintln!(
                    "spool worker: quarantining poison shard {}: parse error: {e}",
                    offer.display()
                );
                let poison = offer.with_file_name(format!("{name}.poison"));
                let _ = std::fs::rename(&claim, poison);
                continue;
            }
        };
        // a misconfigured host (bad OPENGEMM_WORKERS) is fatal on
        // purpose: every shard it served would use the wrong pool
        shard.options.workers = resolve_worker_override(
            opts.cli_workers,
            env.as_deref(),
            shard.options.workers,
        )?;
        let result = shard.run();
        // `X.shard.json` -> `X.result.json`
        let stem = name.strip_suffix(".shard.json").expect("offer matched *.shard.json");
        let result_path = offer.with_file_name(format!("{stem}.result.json"));
        if let Err(e) = write_atomically(&result_path, &result.to_json().pretty()) {
            // transient filesystem trouble: surrender the claim so the
            // driver's retry can re-dispatch, and keep serving
            eprintln!("spool worker: could not publish {}: {e}", result_path.display());
            let _ = std::fs::remove_file(&claim);
            continue;
        }
        let _ = std::fs::remove_file(&claim);
        served += 1;
    }
    Ok(served)
}

/// Wrap a transport with deterministic transient failures: the first
/// `fail_attempts` dispatches of each listed shard index return an
/// error before reaching the inner transport. Used by the
/// fault-injection tests and the `sweep --inject-fail` CLI knob the CI
/// `sched-smoke` lane drives.
pub struct FaultInjector<T> {
    inner: T,
    shard_indices: Vec<usize>,
    fail_attempts: u32,
    counts: Mutex<BTreeMap<usize, u32>>,
}

impl<T: Transport> FaultInjector<T> {
    pub fn new(inner: T, shard_indices: Vec<usize>, fail_attempts: u32) -> FaultInjector<T> {
        FaultInjector { inner, shard_indices, fail_attempts, counts: Mutex::new(BTreeMap::new()) }
    }
}

impl<T: Transport> Transport for FaultInjector<T> {
    fn dispatch(
        &self,
        shard: &Shard,
        attempt: u32,
        cancel: &CancelFlag,
    ) -> Result<ShardResult, String> {
        if self.shard_indices.contains(&shard.shard_index) {
            let mut counts = self.counts.lock().unwrap();
            let n = counts.entry(shard.shard_index).or_insert(0);
            if *n < self.fail_attempts {
                *n += 1;
                return Err(format!(
                    "injected transient fault (shard {}, injected failure {} of {})",
                    shard.shard_index, *n, self.fail_attempts
                ));
            }
        }
        self.inner.dispatch(shard, attempt, cancel)
    }

    fn name(&self) -> &'static str {
        self.inner.name()
    }
}

/// Scheduler policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct DispatchOptions {
    /// Extra dispatch attempts per shard after the first failure.
    pub max_retries: u32,
    /// Speculatively re-dispatch a shard once its in-flight time
    /// exceeds this multiple of the median completed-shard wall time
    /// (values <= 0 disable straggler re-dispatch).
    pub straggler_factor: f64,
    /// Concurrent dispatches (scheduler threads; for [`Subprocess`]
    /// this is the worker-process cap). Clamped to >= 1.
    pub concurrency: usize,
    /// Straggler-check period while dispatches are in flight.
    pub poll: Duration,
}

impl Default for DispatchOptions {
    fn default() -> Self {
        DispatchOptions {
            max_retries: 1,
            straggler_factor: 0.0,
            concurrency: 1,
            poll: Duration::from_millis(50),
        }
    }
}

impl DispatchOptions {
    /// One shard at a time, no retries, no speculation — the in-process
    /// experiment path, where a transport error is a bug rather than a
    /// transient.
    pub fn serial() -> DispatchOptions {
        DispatchOptions { max_retries: 0, ..Default::default() }
    }
}

/// Provenance of one dispatch attempt.
#[derive(Debug, Clone, PartialEq)]
pub struct AttemptRecord {
    pub shard_index: usize,
    pub attempt: u32,
    /// Launched by straggler re-dispatch rather than arrival/retry.
    pub speculative: bool,
    /// Wall time of the attempt (diagnostic; never part of merged
    /// sweep output).
    pub wall_ms: f64,
    /// `None` = the attempt succeeded.
    pub error: Option<String>,
    /// The attempt succeeded, but another attempt of the same shard had
    /// already won; its (identical) result was discarded.
    pub discarded_duplicate: bool,
}

impl AttemptRecord {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("shard_index", Json::num(self.shard_index as f64)),
            ("attempt", Json::num(self.attempt as f64)),
            ("speculative", Json::Bool(self.speculative)),
            ("wall_ms", Json::num(self.wall_ms)),
            (
                "error",
                match &self.error {
                    Some(e) => Json::str(e.clone()),
                    None => Json::Null,
                },
            ),
            ("discarded_duplicate", Json::Bool(self.discarded_duplicate)),
        ])
    }

    fn from_json(v: &Json) -> Result<AttemptRecord, String> {
        Ok(AttemptRecord {
            shard_index: json::get_usize(v, "shard_index")?,
            attempt: json::get_u64(v, "attempt")? as u32,
            speculative: json::get_bool(v, "speculative")?,
            wall_ms: json::get_f64(v, "wall_ms")?,
            error: json::get_opt_str(v, "error")?,
            discarded_duplicate: json::get_bool(v, "discarded_duplicate")?,
        })
    }
}

/// What the scheduler did to complete one plan: every attempt with its
/// outcome, plus summary counters. Diagnostics only — wall times and
/// attempt ordering are nondeterministic, so this never feeds the
/// merged sweep document (which stays byte-identical across runs).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct DispatchReport {
    pub transport: String,
    pub shards: usize,
    /// Sorted by (shard_index, attempt).
    pub attempts: Vec<AttemptRecord>,
    pub retries: u64,
    pub speculative_dispatches: u64,
    pub duplicates_discarded: u64,
    /// Jobs answered by the result cache (0 when no cache is in play).
    pub cache_hits: u64,
    /// Jobs the cache was consulted about and could not answer.
    pub cache_misses: u64,
    /// Jobs actually shipped to executors. Without a cache this equals
    /// the plan's total job count; a fully warm cache drives it to 0 —
    /// the counter the CI `cache-smoke` lane asserts on.
    pub jobs_simulated: u64,
    /// `.poison` quarantine files accumulated in the persistent cache
    /// directory (0 when no persistent cache is in play). Nonzero means
    /// corrupt entries were quarantined at some point and await an
    /// operator look — they are never garbage-collected.
    pub cache_poison_files: u64,
}

/// v2 added the cache counters (`cache_hits`/`cache_misses`/
/// `jobs_simulated`); v3 added `cache_poison_files`. The report is
/// diagnostics-only, so the bump only guards against parsing an older
/// report file with current code.
const DISPATCH_REPORT_FORMAT: &str = "opengemm-dispatch-report-v3";

impl DispatchReport {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("format", Json::str(DISPATCH_REPORT_FORMAT)),
            ("transport", Json::str(self.transport.clone())),
            ("shards", Json::num(self.shards as f64)),
            ("attempts", Json::arr(self.attempts.iter().map(AttemptRecord::to_json).collect())),
            ("retries", Json::num(self.retries as f64)),
            ("speculative_dispatches", Json::num(self.speculative_dispatches as f64)),
            ("duplicates_discarded", Json::num(self.duplicates_discarded as f64)),
            ("cache_hits", Json::num(self.cache_hits as f64)),
            ("cache_misses", Json::num(self.cache_misses as f64)),
            ("jobs_simulated", Json::num(self.jobs_simulated as f64)),
            ("cache_poison_files", Json::num(self.cache_poison_files as f64)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<DispatchReport, String> {
        let format = json::get_str(v, "format")?;
        if format != DISPATCH_REPORT_FORMAT {
            return Err(format!(
                "not a dispatch report: format {format:?}, want {DISPATCH_REPORT_FORMAT:?}"
            ));
        }
        Ok(DispatchReport {
            transport: json::get_str(v, "transport")?.to_string(),
            shards: json::get_usize(v, "shards")?,
            attempts: json::get_arr(v, "attempts")?
                .iter()
                .map(AttemptRecord::from_json)
                .collect::<Result<_, _>>()?,
            retries: json::get_u64(v, "retries")?,
            speculative_dispatches: json::get_u64(v, "speculative_dispatches")?,
            duplicates_discarded: json::get_u64(v, "duplicates_discarded")?,
            cache_hits: json::get_u64(v, "cache_hits")?,
            cache_misses: json::get_u64(v, "cache_misses")?,
            jobs_simulated: json::get_u64(v, "jobs_simulated")?,
            cache_poison_files: json::get_u64(v, "cache_poison_files")?,
        })
    }

    /// One-line summary for driver stderr.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "{} shard(s) over {} transport: {} attempt(s), {} retried, \
             {} speculative, {} duplicate(s) discarded, {} job(s) simulated \
             ({} cache hit(s))",
            self.shards,
            self.transport,
            self.attempts.len(),
            self.retries,
            self.speculative_dispatches,
            self.duplicates_discarded,
            self.jobs_simulated,
            self.cache_hits
        );
        if self.cache_poison_files > 0 {
            s.push_str(&format!(
                "; {} poison file(s) in the cache dir await inspection",
                self.cache_poison_files
            ));
        }
        s
    }
}

/// A queued dispatch attempt.
struct Task {
    shard: Arc<Shard>,
    /// Position in `plan.shards` (== `shard.shard_index` for plans from
    /// `SweepPlan::partition`; kept separate so validation can catch a
    /// transport echoing back the wrong shard).
    slot: usize,
    attempt: u32,
    speculative: bool,
    cancel: Arc<CancelFlag>,
}

enum Event {
    Started {
        slot: usize,
        attempt: u32,
        at: Instant,
    },
    Finished {
        slot: usize,
        attempt: u32,
        speculative: bool,
        wall: Duration,
        result: Result<ShardResult, String>,
    },
}

/// Scheduler-side view of one shard's progress.
struct ShardState {
    shard: Arc<Shard>,
    /// Next attempt number (== attempts launched so far).
    attempts_started: u32,
    failures: u32,
    /// Cancel flags of launched-but-unfinished attempts, by attempt.
    in_flight: BTreeMap<u32, Arc<CancelFlag>>,
    /// Dispatch start instants of in-flight attempts (straggler clock).
    started: BTreeMap<u32, Instant>,
    speculated: bool,
    result: Option<ShardResult>,
    errors: Vec<String>,
}

impl ShardState {
    fn cancel_in_flight(&self) {
        for cancel in self.in_flight.values() {
            cancel.store(true, Ordering::Relaxed);
        }
    }
}

/// Shared work queue: scheduler threads block on the condvar until a
/// task (or shutdown) arrives.
struct WorkQueue {
    tasks: Mutex<VecDeque<Task>>,
    ready: Condvar,
    shutdown: AtomicBool,
}

impl WorkQueue {
    fn push(&self, task: Task) {
        self.tasks.lock().unwrap().push_back(task);
        self.ready.notify_one();
    }

    fn pop(&self) -> Option<Task> {
        let mut tasks = self.tasks.lock().unwrap();
        loop {
            if self.shutdown.load(Ordering::Relaxed) {
                return None;
            }
            if let Some(task) = tasks.pop_front() {
                return Some(task);
            }
            tasks = self.ready.wait(tasks).unwrap();
        }
    }

    /// Stop the workers: drop queued-but-unstarted tasks (they can only
    /// be duplicates or work for an aborted dispatch) and wake everyone.
    fn close(&self) {
        self.shutdown.store(true, Ordering::Relaxed);
        self.tasks.lock().unwrap().clear();
        self.ready.notify_all();
    }
}

/// Check that a transport's result is the answer to the shard we asked
/// about: matching shard index and the exact index cover we dispatched.
/// A corrupt or mixed-up result is a transport failure (retryable), not
/// silent data corruption in the merge.
fn validate_result(shard: &Shard, result: &ShardResult) -> Result<(), String> {
    if result.shard_index != shard.shard_index {
        return Err(format!(
            "transport returned shard {} for shard {}",
            result.shard_index, shard.shard_index
        ));
    }
    if result.indices != shard.indices {
        return Err(format!(
            "transport returned a result covering {} job(s) with mismatched indices \
             (want the shard's {} submission indices)",
            result.indices.len(),
            shard.indices.len()
        ));
    }
    if result.outcomes.len() != result.indices.len() {
        return Err(format!(
            "transport returned {} outcomes for {} indices",
            result.outcomes.len(),
            result.indices.len()
        ));
    }
    Ok(())
}

fn median_secs(samples: &mut [f64]) -> f64 {
    samples.sort_by(f64::total_cmp);
    quantile_sorted(samples, 0.5).unwrap_or(0.0)
}

/// Drive a plan to completion over `transport` under the retry /
/// straggler policy in `opts`. On success returns the merged
/// [`SweepResult`] — byte-identical to the unsharded run — plus the
/// [`DispatchReport`] provenance. On failure (a shard exhausted its
/// retry budget, or the transport produced an unmergeable cover) the
/// error carries the failing shard's full per-attempt error chain.
pub fn dispatch_plan(
    plan: SweepPlan,
    transport: &dyn Transport,
    opts: &DispatchOptions,
) -> Result<(SweepResult, DispatchReport), String> {
    let SweepPlan { total_jobs, shards } = plan;
    let (results, mut report) = dispatch_shards(shards, transport, opts)?;
    report.jobs_simulated = total_jobs as u64;
    let merged = merge(total_jobs, results)?;
    Ok((merged, report))
}

/// [`dispatch_plan`] with an optional result cache in front of the
/// transport. `None` is a plain [`dispatch_plan`]. With a cache:
///
/// - every job is looked up before dispatch; a shard whose jobs all hit
///   never reaches the transport (no worker spawned, no spool offer);
/// - a partial-hit shard ships a reduced shard holding only the missing
///   jobs, and [`merge`] re-interleaves cached and fresh outcomes back
///   into submission order (it checks exact index cover, not
///   one-result-per-shard, so the split is invisible downstream);
/// - fresh outcomes are published back to the cache;
/// - in verify mode ([`ResultCache::with_verify`]) nothing is skipped:
///   every job re-simulates and any divergence from a cached entry is a
///   hard error — a standing determinism regression check.
///
/// The merged [`SweepResult`] is byte-identical to the uncached run:
/// cached outcomes are the bytes a simulator produced earlier, and the
/// merged stats are re-derived from outcomes exactly as `run_batch`
/// counts them ([`CoordinatorStats::record`]).
///
/// [`CoordinatorStats::record`]: crate::coordinator::CoordinatorStats::record
pub fn dispatch_plan_cached(
    plan: SweepPlan,
    transport: &dyn Transport,
    opts: &DispatchOptions,
    cache: Option<&ResultCache>,
) -> Result<(SweepResult, DispatchReport), String> {
    let Some(cache) = cache else {
        return dispatch_plan(plan, transport, opts);
    };
    if cache.verify() {
        return dispatch_plan_verifying(plan, transport, opts, cache);
    }
    let SweepPlan { total_jobs, shards } = plan;
    let mut warm: Vec<ShardResult> = Vec::new();
    let mut cold: Vec<Shard> = Vec::new();
    let mut cold_keys: Vec<Vec<String>> = Vec::new();
    let (mut hits, mut misses) = (0u64, 0u64);
    for shard in shards {
        let keys = shard_job_keys(&shard);
        let mut hit_indices = Vec::new();
        let mut hit_outcomes = Vec::new();
        let mut miss_indices = Vec::new();
        let mut miss_requests = Vec::new();
        let mut miss_keys = Vec::new();
        for ((&index, request), key) in shard.indices.iter().zip(&shard.requests).zip(&keys) {
            match cache.lookup(key) {
                Some(outcome) => {
                    hit_indices.push(index);
                    hit_outcomes.push(outcome);
                }
                None => {
                    miss_indices.push(index);
                    miss_requests.push(request.clone());
                    miss_keys.push(key.clone());
                }
            }
        }
        hits += hit_indices.len() as u64;
        misses += miss_indices.len() as u64;
        if !hit_indices.is_empty() || miss_indices.is_empty() {
            // The hits become a synthetic ShardResult (an all-hit or
            // empty shard resolves entirely here — nothing dispatches).
            warm.push(ShardResult {
                shard_index: shard.shard_index,
                stats: derive_stats(hit_outcomes.iter()),
                indices: hit_indices,
                outcomes: hit_outcomes,
            });
        }
        if !miss_indices.is_empty() {
            cold.push(Shard { indices: miss_indices, requests: miss_requests, ..shard });
            cold_keys.push(miss_keys);
        }
    }
    let (fresh, mut report) = dispatch_shards(cold, transport, opts)?;
    report.cache_hits = hits;
    report.cache_misses = misses;
    report.jobs_simulated = misses;
    // dispatch_shards returns results in input order, so fresh outcomes
    // line up with the keys recorded at split time
    for (result, keys) in fresh.iter().zip(&cold_keys) {
        for (key, outcome) in keys.iter().zip(&result.outcomes) {
            cache.insert(key, outcome);
        }
    }
    report.cache_poison_files = cache.poison_files();
    let mut results = warm;
    results.extend(fresh);
    let mut merged = merge(total_jobs, results)?;
    // surface the traffic on the in-memory stats too (these fields are
    // excluded from the wire encoding, so byte-identity is unaffected)
    merged.stats.cache_hits = report.cache_hits;
    merged.stats.cache_misses = report.cache_misses;
    merged.stats.jobs_simulated = report.jobs_simulated;
    Ok((merged, report))
}

/// Verify-mode dispatch: simulate everything, then hard-error if any
/// cached entry disagrees with its re-simulation (comparison is on
/// canonical outcome bytes — exactly what the byte-identity pin
/// guarantees). Jobs with no cached entry are published as usual, so a
/// verify pass also warms the cache.
fn dispatch_plan_verifying(
    plan: SweepPlan,
    transport: &dyn Transport,
    opts: &DispatchOptions,
    cache: &ResultCache,
) -> Result<(SweepResult, DispatchReport), String> {
    let SweepPlan { total_jobs, shards } = plan;
    let keys: Vec<Vec<String>> = shards.iter().map(shard_job_keys).collect();
    let (results, mut report) = dispatch_shards(shards, transport, opts)?;
    report.jobs_simulated = total_jobs as u64;
    for (result, keys) in results.iter().zip(&keys) {
        for (key, fresh) in keys.iter().zip(&result.outcomes) {
            match cache.lookup(key) {
                Some(cached) => {
                    report.cache_hits += 1;
                    let want = outcome_to_json(fresh).pretty();
                    let got = outcome_to_json(&cached).pretty();
                    if want != got {
                        return Err(format!(
                            "cache verify FAILED for key {key}: cached outcome \
                             diverges from re-simulation (determinism regression, \
                             or a corrupted store evading the entry checks)"
                        ));
                    }
                }
                None => {
                    report.cache_misses += 1;
                    cache.insert(key, fresh);
                }
            }
        }
    }
    report.cache_poison_files = cache.poison_files();
    let mut merged = merge(total_jobs, results)?;
    merged.stats.cache_hits = report.cache_hits;
    merged.stats.cache_misses = report.cache_misses;
    merged.stats.jobs_simulated = report.jobs_simulated;
    Ok((merged, report))
}

/// Scheduler core: drive a bare shard list over `transport`, returning
/// each shard's validated result **in input order** plus the report.
/// [`dispatch_plan`] layers the merge on top; the cached variants
/// dispatch reduced shard lists through this and merge hits back in.
pub fn dispatch_shards(
    shards: Vec<Shard>,
    transport: &dyn Transport,
    opts: &DispatchOptions,
) -> Result<(Vec<ShardResult>, DispatchReport), String> {
    let mut report = DispatchReport {
        transport: transport.name().to_string(),
        shards: shards.len(),
        ..Default::default()
    };
    if shards.is_empty() {
        return Ok((Vec::new(), report));
    }

    let queue = WorkQueue {
        tasks: Mutex::new(VecDeque::new()),
        ready: Condvar::new(),
        shutdown: AtomicBool::new(false),
    };
    let (event_tx, event_rx) = mpsc::channel::<Event>();

    let mut states: Vec<ShardState> = shards
        .into_iter()
        .map(|shard| ShardState {
            shard: Arc::new(shard),
            attempts_started: 0,
            failures: 0,
            in_flight: BTreeMap::new(),
            started: BTreeMap::new(),
            speculated: false,
            result: None,
            errors: Vec::new(),
        })
        .collect();

    let outcome: Result<(), String> = std::thread::scope(|scope| {
        for _ in 0..opts.concurrency.max(1) {
            let queue = &queue;
            let event_tx = event_tx.clone();
            scope.spawn(move || {
                while let Some(task) = queue.pop() {
                    let started = Instant::now();
                    let _ = event_tx.send(Event::Started {
                        slot: task.slot,
                        attempt: task.attempt,
                        at: started,
                    });
                    let result = transport.dispatch(&task.shard, task.attempt, &task.cancel);
                    let _ = event_tx.send(Event::Finished {
                        slot: task.slot,
                        attempt: task.attempt,
                        speculative: task.speculative,
                        wall: started.elapsed(),
                        result,
                    });
                }
            });
        }
        drop(event_tx);

        let launch = |state: &mut ShardState, slot: usize, speculative: bool| {
            let attempt = state.attempts_started;
            let cancel = Arc::new(CancelFlag::new(false));
            queue.push(Task {
                shard: Arc::clone(&state.shard),
                slot,
                attempt,
                speculative,
                cancel: Arc::clone(&cancel),
            });
            state.attempts_started = attempt + 1;
            state.in_flight.insert(attempt, cancel);
        };
        for (slot, state) in states.iter_mut().enumerate() {
            launch(state, slot, false);
        }

        let mut remaining = states.len();
        let mut completed_secs: Vec<f64> = Vec::new();
        let scheduler_result = loop {
            if remaining == 0 {
                break Ok(());
            }
            let event = match event_rx.recv_timeout(opts.poll) {
                Ok(event) => Some(event),
                Err(mpsc::RecvTimeoutError::Timeout) => None,
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    break Err("dispatch workers exited early".to_string());
                }
            };
            match event {
                Some(Event::Started { slot, attempt, at }) => {
                    states[slot].started.insert(attempt, at);
                }
                Some(Event::Finished { slot, attempt, speculative, wall, result }) => {
                    let state = &mut states[slot];
                    state.in_flight.remove(&attempt);
                    state.started.remove(&attempt);
                    let wall_ms = wall.as_secs_f64() * 1e3;
                    // a valid result for an already-done shard is a
                    // discarded duplicate, not a failure
                    let result =
                        result.and_then(|r| validate_result(&state.shard, &r).map(|()| r));
                    match result {
                        Ok(r) => {
                            let duplicate = state.result.is_some();
                            report.attempts.push(AttemptRecord {
                                shard_index: state.shard.shard_index,
                                attempt,
                                speculative,
                                wall_ms,
                                error: None,
                                discarded_duplicate: duplicate,
                            });
                            if duplicate {
                                report.duplicates_discarded += 1;
                            } else {
                                state.result = Some(r);
                                remaining -= 1;
                                completed_secs.push(wall.as_secs_f64());
                                // in-flight duplicates can stop now
                                state.cancel_in_flight();
                            }
                        }
                        Err(e) => {
                            report.attempts.push(AttemptRecord {
                                shard_index: state.shard.shard_index,
                                attempt,
                                speculative,
                                wall_ms,
                                error: Some(e.clone()),
                                discarded_duplicate: false,
                            });
                            if state.result.is_some() {
                                // a late duplicate failing after the
                                // shard already completed changes
                                // nothing
                                continue;
                            }
                            state.failures += 1;
                            state.errors.push(format!("attempt {attempt}: {e}"));
                            if state.failures <= opts.max_retries {
                                report.retries += 1;
                                launch(state, slot, false);
                            } else if state.in_flight.is_empty() {
                                break Err(format!(
                                    "shard {} failed after {} attempt(s) over {} \
                                     transport: {}",
                                    state.shard.shard_index,
                                    state.attempts_started,
                                    transport.name(),
                                    state.errors.join("; ")
                                ));
                            }
                            // else: budget exhausted but a speculative
                            // copy is still running — it may yet win
                        }
                    }
                }
                None => {} // poll tick: fall through to straggler check
            }
            // Straggler re-dispatch: one speculative copy per shard once
            // its oldest in-flight attempt exceeds `factor x` the median
            // completed wall time.
            if opts.straggler_factor > 0.0 && !completed_secs.is_empty() {
                let threshold = median_secs(&mut completed_secs) * opts.straggler_factor;
                let now = Instant::now();
                for (slot, state) in states.iter_mut().enumerate() {
                    if state.result.is_some() || state.speculated || state.in_flight.is_empty() {
                        continue;
                    }
                    let Some(oldest) = state.started.values().min().copied() else { continue };
                    if now.duration_since(oldest).as_secs_f64() > threshold {
                        state.speculated = true;
                        report.speculative_dispatches += 1;
                        launch(state, slot, true);
                    }
                }
            }
        };
        // cancel whatever is still in flight, release the workers
        for state in &states {
            state.cancel_in_flight();
        }
        queue.close();
        // Drain events from attempts that were already running when the
        // scheduler finished, so late duplicates (a straggler's
        // original completing after its speculative twin won) and late
        // failures still land in the report. The scope join waits for
        // those threads regardless; recording them costs nothing.
        while let Ok(event) = event_rx.recv() {
            let Event::Finished { slot, attempt, speculative, wall, result } = event else {
                continue;
            };
            let state = &mut states[slot];
            state.in_flight.remove(&attempt);
            state.started.remove(&attempt);
            let result = result.and_then(|r| validate_result(&state.shard, &r).map(|()| r));
            let error = result.as_ref().err().cloned();
            let duplicate = result.is_ok() && state.result.is_some();
            report.attempts.push(AttemptRecord {
                shard_index: state.shard.shard_index,
                attempt,
                speculative,
                wall_ms: wall.as_secs_f64() * 1e3,
                error,
                discarded_duplicate: duplicate,
            });
            if duplicate {
                report.duplicates_discarded += 1;
            }
        }
        scheduler_result
    });
    outcome?;

    report.attempts.sort_by_key(|a| (a.shard_index, a.attempt));
    let results: Vec<ShardResult> = states
        .into_iter()
        .map(|s| s.result.expect("scheduler completed every shard"))
        .collect();
    Ok((results, report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::GemmShape;
    use crate::config::{Mechanisms, PlatformConfig};
    use crate::coordinator::shard::SweepOptions;
    use crate::coordinator::{Coordinator, JobRequest};

    fn requests(n: usize) -> Vec<JobRequest> {
        (0..n)
            .map(|i| {
                JobRequest::timing(
                    GemmShape::new(8 + 8 * (i % 3), 8 + 8 * (i % 2), 8 + 8 * (i % 4)),
                    if i % 2 == 0 { Mechanisms::ALL } else { Mechanisms::CPL },
                    1,
                )
            })
            .collect()
    }

    fn plan(shards: usize, jobs: usize) -> SweepPlan {
        let cfg = PlatformConfig::case_study();
        let opts = SweepOptions { shards, workers: 1, ..Default::default() };
        SweepPlan::stride(&cfg, requests(jobs), opts)
    }

    fn unsharded(jobs: usize) -> SweepResult {
        let cfg = PlatformConfig::case_study();
        let coord = Coordinator::new(cfg).with_workers(1);
        let outcomes = coord.run_batch(requests(jobs));
        SweepResult { outcomes, stats: coord.stats() }
    }

    #[test]
    fn in_process_dispatch_matches_unsharded_run() {
        let want = unsharded(7);
        for concurrency in [1usize, 3] {
            let opts = DispatchOptions { concurrency, ..Default::default() };
            let (got, report) = dispatch_plan(plan(3, 7), &InProcess, &opts).unwrap();
            assert_eq!(got.to_json().pretty(), want.to_json().pretty());
            assert_eq!(report.shards, 3);
            assert_eq!(report.attempts.len(), 3);
            assert_eq!(report.retries, 0);
            assert!(report.attempts.iter().all(|a| a.error.is_none()));
        }
    }

    #[test]
    fn transient_faults_are_retried_transparently() {
        let want = unsharded(6);
        let transport = FaultInjector::new(InProcess, vec![0, 2], 1);
        let opts = DispatchOptions { max_retries: 1, concurrency: 2, ..Default::default() };
        let (got, report) = dispatch_plan(plan(3, 6), &transport, &opts).unwrap();
        assert_eq!(got.to_json().pretty(), want.to_json().pretty());
        assert_eq!(report.retries, 2, "both injected faults retried");
        let failed: Vec<usize> = report
            .attempts
            .iter()
            .filter(|a| a.error.is_some())
            .map(|a| a.shard_index)
            .collect();
        assert_eq!(failed, vec![0, 2]);
    }

    #[test]
    fn exhausted_retries_carry_the_error_chain() {
        struct AlwaysFails;
        impl Transport for AlwaysFails {
            fn dispatch(
                &self,
                shard: &Shard,
                attempt: u32,
                _cancel: &CancelFlag,
            ) -> Result<ShardResult, String> {
                Err(format!("boom shard={} attempt={attempt}", shard.shard_index))
            }
            fn name(&self) -> &'static str {
                "always-fails"
            }
        }
        let opts = DispatchOptions { max_retries: 2, ..Default::default() };
        let err = dispatch_plan(plan(1, 2), &AlwaysFails, &opts).unwrap_err();
        assert!(err.contains("shard 0 failed after 3 attempt(s)"), "{err}");
        for attempt in 0..3 {
            assert!(err.contains(&format!("boom shard=0 attempt={attempt}")), "{err}");
        }
        assert!(err.contains("always-fails"), "{err}");
    }

    #[test]
    fn corrupt_results_are_rejected_and_retried() {
        /// Mangles the shard index on the first attempt of every shard.
        struct CorruptsFirst;
        impl Transport for CorruptsFirst {
            fn dispatch(
                &self,
                shard: &Shard,
                attempt: u32,
                cancel: &CancelFlag,
            ) -> Result<ShardResult, String> {
                let mut result = InProcess.dispatch(shard, attempt, cancel)?;
                if attempt == 0 {
                    result.shard_index += 100;
                }
                Ok(result)
            }
            fn name(&self) -> &'static str {
                "corrupts-first"
            }
        }
        let want = unsharded(4);
        let opts = DispatchOptions { max_retries: 1, concurrency: 2, ..Default::default() };
        let (got, report) = dispatch_plan(plan(2, 4), &CorruptsFirst, &opts).unwrap();
        assert_eq!(got.to_json().pretty(), want.to_json().pretty());
        assert_eq!(report.retries, 2);
        let first_attempts_rejected = report
            .attempts
            .iter()
            .filter(|a| a.attempt == 0)
            .all(|a| a.error.as_deref().is_some_and(|e| e.contains("returned shard")));
        assert!(first_attempts_rejected, "corrupt first attempts must fail validation");
    }

    #[test]
    fn empty_plan_dispatches_to_an_empty_merge() {
        let cfg = PlatformConfig::case_study();
        let plan = SweepPlan::stride(&cfg, Vec::new(), SweepOptions::default());
        let (got, report) = dispatch_plan(plan, &InProcess, &DispatchOptions::serial()).unwrap();
        assert!(got.outcomes.is_empty());
        assert_eq!(report.attempts.len(), 1, "the one empty shard still runs");
    }

    #[test]
    fn dispatch_report_json_roundtrip() {
        let report = DispatchReport {
            transport: "spool".into(),
            shards: 3,
            attempts: vec![
                AttemptRecord {
                    shard_index: 0,
                    attempt: 0,
                    speculative: false,
                    wall_ms: 12.5,
                    error: Some("timed out".into()),
                    discarded_duplicate: false,
                },
                AttemptRecord {
                    shard_index: 0,
                    attempt: 1,
                    speculative: true,
                    wall_ms: 3.25,
                    error: None,
                    discarded_duplicate: true,
                },
            ],
            retries: 1,
            speculative_dispatches: 1,
            duplicates_discarded: 1,
            cache_hits: 4,
            cache_misses: 2,
            jobs_simulated: 2,
            cache_poison_files: 1,
        };
        let text = report.to_json().pretty();
        let back = DispatchReport::from_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, report);
        assert!(report.summary().contains("1 duplicate(s) discarded"));
        // a non-report document fails loudly
        let err = DispatchReport::from_json(&json::parse("{\"format\": \"x\"}").unwrap())
            .unwrap_err();
        assert!(err.contains("not a dispatch report"), "{err}");
    }

    #[test]
    fn fault_injector_counts_per_shard() {
        let cfg = PlatformConfig::case_study();
        let opts = SweepOptions { shards: 2, workers: 1, ..Default::default() };
        let plan = SweepPlan::stride(&cfg, requests(2), opts);
        let injector = FaultInjector::new(InProcess, vec![1], 2);
        let cancel = CancelFlag::new(false);
        let s0 = &plan.shards[0];
        let s1 = &plan.shards[1];
        assert!(injector.dispatch(s0, 0, &cancel).is_ok(), "unlisted shard unaffected");
        assert!(injector.dispatch(s1, 0, &cancel).is_err());
        assert!(injector.dispatch(s1, 1, &cancel).is_err());
        assert!(injector.dispatch(s1, 2, &cancel).is_ok(), "injection budget spent");
    }

    #[test]
    fn median_is_total_and_even_aware() {
        assert_eq!(median_secs(&mut vec![3.0]), 3.0);
        assert_eq!(median_secs(&mut vec![4.0, 1.0, 3.0]), 3.0);
        assert_eq!(median_secs(&mut vec![4.0, 1.0, 3.0, 2.0]), 2.5);
    }
}
