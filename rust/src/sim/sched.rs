//! Event-scheduler substrate: a lazy-deletion binary heap of
//! `(cycle, SourceId)` wakeups.
//!
//! The fast-forward engine needs "the earliest cycle at which anything
//! can happen". The previous engine re-derived that by scanning a
//! hard-coded list of sources inside `Platform::next_event` and cached
//! the scan behind a `sched_wake` memo that every mutation site had to
//! remember to invalidate — the most error-prone pattern in the
//! simulator. This module inverts the flow: each event source
//! *registers* once, *pushes* its next wakeup at the point it becomes
//! known, and the engine asks the heap for the minimum.
//!
//! Lazy deletion: re-arming a source does not search the heap for the
//! stale entry; it just records the new armed time and pushes a fresh
//! entry. [`EventHeap::next_wake`] pops entries whose `(cycle, source)`
//! no longer matches the source's armed time until it finds a live one.
//! The invariant making that sound: whenever `armed[s] == Some(t)`,
//! an entry `(t, s)` is present in the heap (every arming push keeps
//! it; duplicates are harmless — the extras are stale by definition).
//!
//! Armed times are *raw*: a source may legitimately stay armed at a
//! cycle that is already in the past (e.g. a streamer whose bank gate
//! expired but whose fetch has not been issued yet). The engine clamps
//! the returned minimum to `now + 1`, exactly as the old memoized scan
//! did, so past wakeups resolve on the next simulated cycle.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Index of a registered event source (dense, allocation-order).
pub type SourceId = usize;

/// Min-heap of pending wakeups with lazy deletion.
#[derive(Debug, Default)]
pub struct EventHeap {
    heap: BinaryHeap<Reverse<(u64, SourceId)>>,
    /// Authoritative next-wake time per source; heap entries that
    /// disagree are stale and skipped on pop.
    armed: Vec<Option<u64>>,
    names: Vec<&'static str>,
}

impl EventHeap {
    pub fn new() -> EventHeap {
        EventHeap::default()
    }

    /// Register an event source; the returned id is its address for
    /// [`EventHeap::set`]. Names are for diagnostics only.
    pub fn register(&mut self, name: &'static str) -> SourceId {
        self.names.push(name);
        self.armed.push(None);
        self.names.len() - 1
    }

    pub fn source_name(&self, src: SourceId) -> &'static str {
        self.names[src]
    }

    pub fn n_sources(&self) -> usize {
        self.names.len()
    }

    /// Currently armed wake time of a source (raw, possibly past).
    pub fn armed(&self, src: SourceId) -> Option<u64> {
        self.armed[src]
    }

    /// Arm (`Some(cycle)`) or disarm (`None`) a source. A no-op when
    /// the armed time is unchanged, so sources may push unconditionally
    /// from their refresh points without flooding the heap.
    pub fn set(&mut self, src: SourceId, wake: Option<u64>) {
        if self.armed[src] == wake {
            return;
        }
        self.armed[src] = wake;
        if let Some(t) = wake {
            self.heap.push(Reverse((t, src)));
        }
    }

    /// Earliest live wakeup across all sources, or `None` when every
    /// source is disarmed. Pops stale entries (lazy deletion); live
    /// entries are left in place, so the call is idempotent.
    pub fn next_wake(&mut self) -> Option<u64> {
        while let Some(&Reverse((t, s))) = self.heap.peek() {
            if self.armed[s] == Some(t) {
                return Some(t);
            }
            self.heap.pop();
        }
        None
    }

    /// Disarm every source and drop all pending entries (run reset).
    pub fn clear(&mut self) {
        self.heap.clear();
        self.armed.iter_mut().for_each(|a| *a = None);
    }

    /// Pending heap entries, stale included (telemetry / tests).
    pub fn pending_entries(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::property;
    use crate::util::rng::Pcg32;
    use crate::{prop_assert, prop_assert_eq};

    /// Naive reference: the armed vector itself, min scanned fresh.
    fn naive_min(armed: &[Option<u64>]) -> Option<u64> {
        armed.iter().filter_map(|&a| a).min()
    }

    fn random_ops(rng: &mut Pcg32, h: &mut EventHeap, armed: &mut Vec<Option<u64>>, n: usize) {
        for _ in 0..n {
            let src = rng.below(armed.len() as u32) as usize;
            let wake = if rng.below(4) == 0 {
                None
            } else {
                Some(rng.below(1000) as u64)
            };
            h.set(src, wake);
            armed[src] = wake;
        }
    }

    #[test]
    fn heap_min_matches_naive_reference() {
        property("sched-heap-vs-naive", 64, |rng| {
            let mut h = EventHeap::new();
            let n_src = 1 + rng.below(8) as usize;
            for _ in 0..n_src {
                h.register("src");
            }
            let mut armed: Vec<Option<u64>> = vec![None; n_src];
            for step in 0..200 {
                random_ops(rng, &mut h, &mut armed, 1 + rng.below(3) as usize);
                prop_assert_eq!(
                    h.next_wake(),
                    naive_min(&armed),
                    "divergence at step {step}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn pops_are_monotone_and_stale_never_surface() {
        property("sched-monotone-pops", 64, |rng| {
            let mut h = EventHeap::new();
            let n_src = 1 + rng.below(6) as usize;
            for _ in 0..n_src {
                h.register("src");
            }
            let mut armed: Vec<Option<u64>> = vec![None; n_src];
            // Arm, churn (creating stale entries), then drain: the
            // drained sequence must be nondecreasing and every value
            // must be a currently-armed time, never a stale one.
            random_ops(rng, &mut h, &mut armed, 40);
            let mut last = 0u64;
            while let Some(t) = h.next_wake() {
                prop_assert!(t >= last, "pop went backwards: {t} after {last}");
                prop_assert!(
                    armed.iter().any(|&a| a == Some(t)),
                    "stale wakeup surfaced: {t} not armed in {armed:?}"
                );
                last = t;
                // Retire every source due at t, as the engine does by
                // advancing time and refreshing the fired sources.
                for (s, a) in armed.iter_mut().enumerate() {
                    if *a == Some(t) {
                        *a = None;
                        h.set(s, None);
                    }
                }
            }
            prop_assert_eq!(naive_min(&armed), None, "drain left sources armed");
            Ok(())
        });
    }

    #[test]
    fn rearm_same_time_is_noop() {
        let mut h = EventHeap::new();
        let s = h.register("a");
        h.set(s, Some(5));
        let entries = h.pending_entries();
        h.set(s, Some(5));
        assert_eq!(h.pending_entries(), entries, "unchanged arm must not push");
        assert_eq!(h.next_wake(), Some(5));
    }

    #[test]
    fn clear_disarms_everything() {
        let mut h = EventHeap::new();
        let a = h.register("a");
        let b = h.register("b");
        h.set(a, Some(3));
        h.set(b, Some(7));
        h.clear();
        assert_eq!(h.next_wake(), None);
        assert_eq!(h.armed(a), None);
        assert_eq!(h.armed(b), None);
        h.set(b, Some(2));
        assert_eq!(h.next_wake(), Some(2), "heap usable after clear");
    }

    #[test]
    fn past_times_stay_live_until_disarmed() {
        // A source armed in the past keeps surfacing (the engine clamps
        // to now+1); it must not be treated as stale.
        let mut h = EventHeap::new();
        let s = h.register("gate");
        h.set(s, Some(1));
        assert_eq!(h.next_wake(), Some(1));
        assert_eq!(h.next_wake(), Some(1), "idempotent peek");
        h.set(s, None);
        assert_eq!(h.next_wake(), None);
    }
}
