//! Design-space exploration: sweep the generator parameters (Mu, Ku,
//! Nu array geometry and buffer depth) and chart utilization, area,
//! power and efficiency per instance — the "hardware generator"
//! workflow the paper's Chisel design enables (Sec. 2.2: dot-product
//! units to matrix-matrix accelerators from one generator).
//!
//! Run with:  cargo run --release --example dse_sweep -- [--shards N]
//!            [--workers N] [--no-fast-forward]

use opengemm::compiler::GemmShape;
use opengemm::config::{Mechanisms, PlatformConfig};
use opengemm::coordinator::shard::{run_sweep, SweepOptions};
use opengemm::coordinator::JobRequest;
use opengemm::power::PowerModel;
use opengemm::util::cli::Args;
use opengemm::util::table::{fmt_f, Table};
use opengemm::workloads::random_suite;

fn instance(mu: usize, nu: usize, ku: usize) -> Option<PlatformConfig> {
    let mut cfg = PlatformConfig::case_study();
    cfg.core.mu = mu;
    cfg.core.nu = nu;
    cfg.core.ku = ku;
    // scale the memory ports so the instance still elaborates: read BW
    // must cover A'+B' per cycle, write BW one C' tile per Ku cycles
    let need_read = cfg.core.a_tile_bytes() + cfg.core.b_tile_bytes();
    cfg.mem.r_mem = need_read.div_ceil(cfg.mem.word_bytes()).next_power_of_two();
    cfg.mem.w_mem = (cfg.core.c_tile_bytes().div_ceil(cfg.mem.word_bytes()))
        .next_power_of_two()
        .max(4);
    cfg.mem.n_bank = cfg.mem.n_bank.max(cfg.mem.r_mem.next_power_of_two());
    cfg.validate().ok()?;
    Some(cfg)
}

fn main() -> opengemm::util::error::Result<()> {
    let args = Args::from_env()?;
    // every per-instance batch goes through the sharded sweep engine
    // and its fault-tolerant dispatch scheduler — the same code path
    // the `opengemm sweep` driver distributes over worker processes
    // and spool-dir hosts
    let sweep_opts = SweepOptions {
        shards: args.usize_or("shards", 1)?,
        workers: args.usize_or("workers", 0)?,
        fast_forward: args.enabled_unless_no("fast-forward"),
        ..Default::default()
    };
    // generator points: vector unit, outer-product-ish, square arrays
    let points = [
        (1usize, 1usize, 64usize), // big dot-product unit
        (4, 4, 8),                 // small square array
        (8, 8, 8),                 // the paper's case study
        (16, 16, 8),               // wider mesh
        (8, 8, 16),                // deeper DotProds
        (16, 16, 16),              // large array
    ];
    let workloads = random_suite(77, 40);
    let model = PowerModel::default();

    let mut table = Table::new(&[
        "(Mu,Nu,Ku)", "peak GOPS", "mean OU", "eff GOPS", "area mm^2", "power mW",
        "TOPS/W", "GOPS/mm^2",
    ]);

    for &(mu, nu, ku) in &points {
        let Some(cfg) = instance(mu, nu, ku) else {
            println!("skipping ({mu},{nu},{ku}): does not elaborate");
            continue;
        };
        let reqs: Vec<JobRequest> = workloads
            .iter()
            .map(|&s| JobRequest::timing(s, Mechanisms::ALL, 5))
            .collect();
        let results = run_sweep(&cfg, reqs, sweep_opts).outcomes;
        let mut ou_sum = 0.0;
        let mut n = 0usize;
        for r in results.into_iter().flatten() {
            ou_sum += r.report.overall;
            n += 1;
        }
        let mean_ou = ou_sum / n as f64;
        let peak = cfg.peak_gops();
        let area = model.total_area(&cfg);
        let power = model.total_power(&cfg, mean_ou);
        table.row(vec![
            format!("({mu},{nu},{ku})"),
            fmt_f(peak, 1),
            fmt_f(mean_ou, 3),
            fmt_f(peak * mean_ou, 1),
            fmt_f(area, 3),
            fmt_f(power, 1),
            fmt_f(peak * mean_ou / power, 2),
            fmt_f(peak * mean_ou / (area * 1.1676), 1), // layout factor
        ]);
    }
    println!("{}", table.markdown());
    println!(
        "note: larger arrays raise peak GOPS but lose utilization on the random\n\
         workload mix (more padding waste) — the paper's rationale for choosing\n\
         8x8x8 as the balanced case-study instance (Sec. 4.1)."
    );
    Ok(())
}
