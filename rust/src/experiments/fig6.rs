//! Fig. 6 + Sec. 4.4: cell area and total power breakdown, peak
//! performance and system efficiency, at the (32,32,32) block-GeMM
//! power workload.

use crate::compiler::GemmShape;
use crate::config::{Mechanisms, PlatformConfig};
use crate::coordinator::{Coordinator, JobRequest};
use crate::power::{Breakdown, PowerModel};
use crate::util::table::{fmt_f, Table};

#[derive(Debug, Clone, Copy)]
pub struct Fig6Options {
    /// Event-driven cycle skipping (cycle-exact; off only for
    /// differential checks). The seed dropped this option here, so
    /// `--no-fast-forward` never reached the power-workload simulation.
    pub fast_forward: bool,
}

impl Default for Fig6Options {
    fn default() -> Self {
        Fig6Options { fast_forward: true }
    }
}

#[derive(Debug, Clone)]
pub struct Fig6Result {
    pub area: Breakdown,
    pub power: Breakdown,
    pub total_area_mm2: f64,
    pub layout_area_mm2: f64,
    pub total_power_mw: f64,
    pub peak_gops: f64,
    pub tops_per_watt: f64,
    /// Utilization of the (32,32,32) power workload the breakdown is
    /// evaluated at.
    pub workload_utilization: f64,
}

pub fn fig6_area_power(cfg: &PlatformConfig, opts: Fig6Options) -> Fig6Result {
    let model = PowerModel::default();
    // the paper's power workload: block GeMM of size (32,32,32),
    // steady-state (repeats amortize configuration)
    let coord = Coordinator::new(cfg.clone()).with_fast_forward(opts.fast_forward);
    let req = JobRequest::timing(GemmShape::new(32, 32, 32), Mechanisms::ALL, 10);
    // kernel-window utilization: the power measurement's steady state
    // (configuration is programmed once and amortized)
    let util = coord
        .run_one(&req)
        .map(|r| r.report.spatial * r.metrics.kernel_utilization())
        .unwrap_or(1.0);
    let area = model.area(cfg);
    // The published 43.8 mW is the full-activity operating point; the
    // dynamic terms scale with the measured workload utilization.
    let power = model.power(cfg, util);
    Fig6Result {
        total_area_mm2: area.total(),
        layout_area_mm2: model.layout_area(cfg),
        total_power_mw: model.total_power(cfg, 1.0),
        peak_gops: cfg.peak_gops(),
        tops_per_watt: model.tops_per_watt(cfg, 1.0),
        workload_utilization: util,
        area,
        power,
    }
}

impl Fig6Result {
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str("## Fig. 6 — area and power breakdown\n\n");
        let mut t = Table::new(&["component", "area mm^2", "area %", "power mW", "power %"]);
        let ap = self.area.percentages();
        let pp = self.power.percentages();
        for ((name, a_pct), (_, p_pct)) in ap.iter().zip(&pp) {
            let a_abs = a_pct / 100.0 * self.area.total();
            let p_abs = p_pct / 100.0 * self.power.total();
            t.row(vec![
                name.to_string(),
                fmt_f(a_abs, 4),
                fmt_f(*a_pct, 2),
                fmt_f(p_abs, 2),
                fmt_f(*p_pct, 2),
            ]);
        }
        out.push_str(&t.markdown());
        out.push_str(&format!(
            "\ncell area {:.3} mm^2 (paper 0.531) | layout {:.2} mm^2 (paper 0.62) | \
             power @ full load {:.1} mW (paper 43.8) | peak {:.1} GOPS (paper 204.8) | \
             {:.2} TOPS/W (paper 4.68) | (32,32,32) workload OU {:.1}%\n",
            self.total_area_mm2,
            self.layout_area_mm2,
            self.total_power_mw,
            self.peak_gops,
            self.tops_per_watt,
            100.0 * self.workload_utilization,
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn headline_numbers_match_paper() {
        let cfg = PlatformConfig::case_study();
        let r = fig6_area_power(&cfg, Fig6Options::default());
        // the fast-forward toggle must not change the measured workload
        // utilization (cycle-exactness through this driver)
        let lockstep = fig6_area_power(&cfg, Fig6Options { fast_forward: false });
        assert_eq!(r.workload_utilization, lockstep.workload_utilization);
        assert!((r.total_area_mm2 - 0.531).abs() < 1e-6);
        assert!((r.total_power_mw - 43.8).abs() < 1e-6);
        assert!((r.peak_gops - 204.8).abs() < 1e-9);
        assert!((r.tops_per_watt - 4.675).abs() < 0.02);
        assert!(r.workload_utilization > 0.8, "32^3 should run near peak");
    }

    #[test]
    fn breakdown_percentages_sum_to_100() {
        let cfg = PlatformConfig::case_study();
        let r = fig6_area_power(&cfg, Fig6Options::default());
        let sum_a: f64 = r.area.percentages().iter().map(|(_, p)| p).sum();
        let sum_p: f64 = r.power.percentages().iter().map(|(_, p)| p).sum();
        assert!((sum_a - 100.0).abs() < 1e-9);
        assert!((sum_p - 100.0).abs() < 1e-9);
    }
}
