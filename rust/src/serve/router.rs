//! Placement policies: which device a closed batch is dispatched to.
//!
//! The router sees the fleet exactly as a real load balancer would —
//! through its *beliefs* ([`DeviceView`]): the cycle each device is
//! expected to free up, and whether a failure has already been
//! detected. It never peeks at the fault schedule; a device that is
//! doomed but not yet detected looks healthy and busy, which is what
//! makes the failover path in `serve::fleet` honest.
//!
//! Three policies:
//!
//! - **round-robin**: rotate over schedulable devices; the baseline.
//! - **least-work**: the device expected to free up first (ties break
//!   to the lowest index, keeping the choice deterministic).
//! - **affinity** (shape affinity): the first batch of each request
//!   kind pins that kind to the least-loaded device, and later batches
//!   of the kind stick to it — so a device keeps receiving the shapes
//!   it has already been serving (re-pinned elsewhere only when the
//!   pinned device's failure has been detected).

/// The router's belief about one device at a decision cycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DeviceView {
    /// Cycle the device is expected to become free.
    pub free_at: u64,
    /// False once the router has detected this device's failure.
    pub schedulable: bool,
}

/// How the router maps batches onto devices.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacementPolicy {
    RoundRobin,
    LeastWork,
    ShapeAffinity,
}

impl PlacementPolicy {
    /// The `--placement` names, for CLI error messages.
    pub const VALID_NAMES: &'static str = "round-robin|least-work|affinity";

    pub fn from_name(name: &str) -> Option<PlacementPolicy> {
        match name {
            "round-robin" | "rr" => Some(PlacementPolicy::RoundRobin),
            "least-work" => Some(PlacementPolicy::LeastWork),
            "affinity" | "shape-affinity" => Some(PlacementPolicy::ShapeAffinity),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            PlacementPolicy::RoundRobin => "round-robin",
            PlacementPolicy::LeastWork => "least-work",
            PlacementPolicy::ShapeAffinity => "affinity",
        }
    }
}

/// Deterministic placement state (rotation cursor, affinity pins).
#[derive(Debug, Clone)]
pub struct Router {
    policy: PlacementPolicy,
    rr_next: usize,
    /// kind -> pinned device (affinity policy only), grown on demand.
    affinity: Vec<Option<usize>>,
}

impl Router {
    pub fn new(policy: PlacementPolicy) -> Router {
        Router { policy, rr_next: 0, affinity: Vec::new() }
    }

    /// Pick a device for a batch whose first member is `kind`.
    /// `exclude` bars one device (the hedge primary). `None` when no
    /// schedulable device remains.
    pub fn pick(
        &mut self,
        devices: &[DeviceView],
        kind: usize,
        exclude: Option<usize>,
    ) -> Option<usize> {
        let ok = |i: usize| devices[i].schedulable && Some(i) != exclude;
        match self.policy {
            PlacementPolicy::RoundRobin => {
                let n = devices.len();
                (0..n).map(|s| (self.rr_next + s) % n).find(|&i| ok(i)).inspect(|&i| {
                    self.rr_next = (i + 1) % n;
                })
            }
            PlacementPolicy::LeastWork => least_work(devices, &ok),
            PlacementPolicy::ShapeAffinity => {
                if kind >= self.affinity.len() {
                    self.affinity.resize(kind + 1, None);
                }
                if let Some(d) = self.affinity[kind] {
                    if ok(d) {
                        return Some(d);
                    }
                }
                let pick = least_work(devices, &ok)?;
                self.affinity[kind] = Some(pick);
                Some(pick)
            }
        }
    }
}

/// Schedulable device expected to free up first; ties break to the
/// lowest index.
fn least_work(devices: &[DeviceView], ok: &dyn Fn(usize) -> bool) -> Option<usize> {
    devices
        .iter()
        .enumerate()
        .filter(|&(i, _)| ok(i))
        .min_by_key(|&(i, v)| (v.free_at, i))
        .map(|(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn views(free: &[u64]) -> Vec<DeviceView> {
        free.iter().map(|&f| DeviceView { free_at: f, schedulable: true }).collect()
    }

    #[test]
    fn round_robin_rotates_and_skips_dead() {
        let mut r = Router::new(PlacementPolicy::RoundRobin);
        let mut v = views(&[0, 0, 0]);
        assert_eq!(r.pick(&v, 0, None), Some(0));
        assert_eq!(r.pick(&v, 0, None), Some(1));
        assert_eq!(r.pick(&v, 0, None), Some(2));
        assert_eq!(r.pick(&v, 0, None), Some(0), "wraps around");
        v[1].schedulable = false;
        assert_eq!(r.pick(&v, 0, None), Some(2), "skips the dead device");
        assert_eq!(r.pick(&v, 0, None), Some(0));
    }

    #[test]
    fn least_work_prefers_earliest_free_lowest_index() {
        let mut r = Router::new(PlacementPolicy::LeastWork);
        assert_eq!(r.pick(&views(&[50, 10, 10]), 0, None), Some(1), "tie -> lowest index");
        assert_eq!(r.pick(&views(&[50, 10, 5]), 0, None), Some(2));
        assert_eq!(r.pick(&views(&[50, 10, 5]), 0, Some(2)), Some(1), "exclusion honored");
    }

    #[test]
    fn affinity_pins_then_repins_on_death() {
        let mut r = Router::new(PlacementPolicy::ShapeAffinity);
        let mut v = views(&[100, 0]);
        assert_eq!(r.pick(&v, 3, None), Some(1), "first pin is least-work");
        v[1].free_at = 1_000_000;
        assert_eq!(r.pick(&v, 3, None), Some(1), "sticks even when loaded");
        assert_eq!(r.pick(&v, 0, None), Some(0), "other kind pins elsewhere");
        v[1].schedulable = false;
        assert_eq!(r.pick(&v, 3, None), Some(0), "re-pins off a detected failure");
        v[1].schedulable = true;
        assert_eq!(r.pick(&v, 3, None), Some(0), "the new pin is sticky too");
    }

    #[test]
    fn no_schedulable_device_is_none() {
        let mut r = Router::new(PlacementPolicy::RoundRobin);
        let mut v = views(&[0]);
        v[0].schedulable = false;
        assert_eq!(r.pick(&v, 0, None), None);
        let mut r = Router::new(PlacementPolicy::LeastWork);
        assert_eq!(r.pick(&views(&[0]), 0, Some(0)), None, "exclusion can empty the fleet");
    }

    #[test]
    fn names_round_trip() {
        for name in ["round-robin", "least-work", "affinity"] {
            assert_eq!(PlacementPolicy::from_name(name).unwrap().label(), name);
        }
        assert_eq!(PlacementPolicy::from_name("bogus"), None);
        assert!(PlacementPolicy::VALID_NAMES.contains("least-work"));
    }
}
