//! Serving request kinds: what one request *is*.
//!
//! A request kind is a named GeMM stream (shape + repeat count pairs,
//! the `ModelWorkload::unique_shapes` form). The BERT kinds model one
//! encoder layer at a given sequence length — the request unit the old
//! `bert_serving` example used — while the CNN kind is the full
//! ResNet-18 stream, so a mixed workload exercises both short
//! transformer requests and long convolutional ones. Request kinds are
//! sampled uniformly per request from the seeded RNG stream.

use crate::compiler::GemmShape;
use crate::util::json::Json;
use crate::workloads::{encoder_layer, resnet18};

/// One request class: a label plus the GeMM stream a request of this
/// class pushes through the device.
#[derive(Debug, Clone, PartialEq)]
pub struct RequestKind {
    pub label: String,
    /// `(shape, count)` pairs: the stream executes each shape `count`
    /// times (attention heads, repeated layers, channel groups).
    pub stream: Vec<(GemmShape, u64)>,
}

/// Which request mix the harness serves.
#[derive(Debug, Clone, PartialEq)]
pub enum WorkloadSpec {
    /// BERT-Base encoder layers (hidden 768, 12 heads); one request =
    /// one layer at a sequence length sampled from `seq_choices`.
    BertBase { seq_choices: Vec<usize> },
    /// BERT-Large encoder layers (hidden 1024, 16 heads) — the
    /// >12-head case the old example's repeat clamp mismeasured.
    BertLarge { seq_choices: Vec<usize> },
    /// One request = the full ResNet-18 GeMM stream (batch 1).
    Resnet18,
    /// Union of the BERT-Base kinds and the ResNet-18 stream.
    Mixed { seq_choices: Vec<usize> },
}

impl WorkloadSpec {
    /// The sequence lengths a BERT serving queue mixes by default.
    pub const DEFAULT_SEQS: [usize; 5] = [64, 128, 256, 384, 512];

    pub fn label(&self) -> &'static str {
        match self {
            WorkloadSpec::BertBase { .. } => "bert",
            WorkloadSpec::BertLarge { .. } => "bert-large",
            WorkloadSpec::Resnet18 => "resnet18",
            WorkloadSpec::Mixed { .. } => "mixed",
        }
    }

    /// CLI name -> spec, with the BERT kinds drawing from `seqs`.
    pub fn from_name(name: &str, seqs: &[usize]) -> Option<WorkloadSpec> {
        let seq_choices = seqs.to_vec();
        match name {
            "bert" | "bert-base" => Some(WorkloadSpec::BertBase { seq_choices }),
            "bert-large" => Some(WorkloadSpec::BertLarge { seq_choices }),
            "resnet18" | "resnet" => Some(WorkloadSpec::Resnet18),
            "mixed" => Some(WorkloadSpec::Mixed { seq_choices }),
            _ => None,
        }
    }

    fn seq_choices(&self) -> &[usize] {
        match self {
            WorkloadSpec::BertBase { seq_choices }
            | WorkloadSpec::BertLarge { seq_choices }
            | WorkloadSpec::Mixed { seq_choices } => seq_choices,
            WorkloadSpec::Resnet18 => &[],
        }
    }

    /// Elaborate the request kinds this workload samples from.
    pub fn kinds(&self) -> Vec<RequestKind> {
        let bert = |family: &str, d: usize, h: u64, ffn: usize, seqs: &[usize]| {
            seqs.iter()
                .map(|&seq| RequestKind {
                    label: format!("{family}-layer/seq{seq}"),
                    stream: encoder_layer(family, seq, d, h, ffn).unique_shapes(),
                })
                .collect::<Vec<_>>()
        };
        let resnet = || RequestKind {
            label: "resnet18".to_string(),
            stream: resnet18().unique_shapes(),
        };
        match self {
            WorkloadSpec::BertBase { seq_choices } => {
                bert("bert-base", 768, 12, 3072, seq_choices)
            }
            WorkloadSpec::BertLarge { seq_choices } => {
                bert("bert-large", 1024, 16, 4096, seq_choices)
            }
            WorkloadSpec::Resnet18 => vec![resnet()],
            WorkloadSpec::Mixed { seq_choices } => {
                let mut kinds = bert("bert-base", 768, 12, 3072, seq_choices);
                kinds.push(resnet());
                kinds
            }
        }
    }

    /// Wire encoding (serving report header).
    pub fn to_json(&self) -> Json {
        let seqs: Vec<Json> = self.seq_choices().iter().map(|&s| Json::num(s as f64)).collect();
        Json::obj(vec![("name", Json::str(self.label())), ("seq_choices", Json::Arr(seqs))])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bert_kinds_one_per_seq() {
        let spec = WorkloadSpec::BertBase { seq_choices: vec![64, 128] };
        let kinds = spec.kinds();
        assert_eq!(kinds.len(), 2);
        assert!(kinds[0].label.contains("seq64"));
        assert!(!kinds[0].stream.is_empty());
    }

    #[test]
    fn bert_large_kind_carries_sixteen_heads() {
        let spec = WorkloadSpec::BertLarge { seq_choices: vec![128] };
        let kinds = spec.kinds();
        // attention scores shape (seq, dh, seq) = (128, 64, 128) must
        // repeat once per head — 16 for BERT-Large, unclamped
        let (_, count) = kinds[0]
            .stream
            .iter()
            .find(|(s, _)| *s == GemmShape::new(128, 64, 128))
            .copied()
            .expect("scores shape present");
        assert_eq!(count, 16, "one scores GeMM per head");
    }

    #[test]
    fn mixed_adds_resnet() {
        let spec = WorkloadSpec::Mixed { seq_choices: vec![64] };
        let kinds = spec.kinds();
        assert_eq!(kinds.len(), 2);
        assert_eq!(kinds[1].label, "resnet18");
    }

    #[test]
    fn names_roundtrip() {
        for name in ["bert", "bert-large", "resnet18", "mixed"] {
            let spec = WorkloadSpec::from_name(name, &[64]).unwrap();
            assert!(!spec.kinds().is_empty(), "{name}");
        }
        assert!(WorkloadSpec::from_name("gpt", &[64]).is_none());
    }
}
