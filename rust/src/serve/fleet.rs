//! Fleet simulator: N virtual devices behind a router, with
//! deterministic fault injection, failover, hedging and SLO admission
//! control — the single-device engine of [`super::queue`] generalized
//! to the "how many devices do I need and what happens when one dies"
//! question.
//!
//! One virtual-time event loop drives everything, and every mechanism
//! is a pure function of `(arrival source, service table, batching
//! policy, FleetSpec)` — a same-seed trace replays byte-identically,
//! faults included (the CI `fleet-smoke` lane diffs two real process
//! invocations). With one device and no faults the timeline is
//! *identical* to [`super::queue::simulate_queue`] — the differential
//! the tests pin.
//!
//! ## Mechanisms
//!
//! - **Fault injection** ([`FaultSpec`]): a device **fail-stops** at a
//!   chosen virtual cycle (it silently stops executing, mid-batch work
//!   is lost), or **slow-degrades** (cycles executed after the fault
//!   cycle take `factor`× as long — a thermally throttled or
//!   contended device).
//! - **Timeout failure detection + failover**: the router never sees
//!   the fault schedule. It learns a device died when the expected
//!   completion of an in-flight batch passes without a result — the
//!   expected completion *is* the timeout — and then re-dispatches the
//!   batch to a surviving device, bounded by the per-batch
//!   [`FleetSpec::retries`] budget. Cycles the dead device burned
//!   before dying are accounted as waste. Batches queued behind a
//!   doomed attempt re-route at the detection cycle without paying
//!   another timeout.
//! - **Hedging** ([`FleetSpec::hedge`]): once enough batch windows
//!   have completed, an attempt expected to run longer than the p99 of
//!   observed windows gets a duplicate issued on another device after
//!   that p99 delay. First completion wins; the loser is cancelled at
//!   the winner's completion and every cycle it burned is waste.
//! - **SLO admission control** ([`FleetSpec::slo_cycles`]): an arrival
//!   whose predicted queueing delay (earliest believed device
//!   availability) exceeds the SLO is shed at admission — counted and
//!   reported, never silently dropped. Closed-loop clients treat the
//!   rejection as an instant completion and re-issue after thinking.
//!
//! Detection knowledge is cycle-stamped: a dead-but-undetected device
//! still looks healthy (and busy until its doomed batch's timeout) to
//! both placement and the SLO predictor.

use std::collections::VecDeque;

use crate::util::stats::quantile_sorted;

use super::batching::BatchPolicy;
use super::queue::{ArrivalSource, RequestRecord};
use super::router::{DeviceView, PlacementPolicy, Router};

/// Completed batch windows needed before the p99 hedge delay is
/// considered meaningful.
const HEDGE_MIN_SAMPLES: usize = 4;

/// What a deterministic device fault does.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// The device stops executing at the fault cycle and never
    /// recovers; in-flight work is lost.
    FailStop,
    /// Cycles executed after the fault cycle take `factor`× as long.
    Degrade { factor: f64 },
}

/// One injected device fault, scheduled in virtual cycles.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultSpec {
    pub device: usize,
    pub at_cycle: u64,
    pub kind: FaultKind,
}

impl FaultSpec {
    /// Parse a `--fail-device` item: `IDX@CYCLE`, e.g. `2@50000`.
    pub fn parse_fail(s: &str) -> Result<FaultSpec, String> {
        let (d, c) = s
            .split_once('@')
            .ok_or_else(|| format!("--fail-device expects IDX@CYCLE, got {s:?}"))?;
        Ok(FaultSpec {
            device: parse_num(d, "--fail-device", "device index")?,
            at_cycle: parse_num(c, "--fail-device", "cycle")?,
            kind: FaultKind::FailStop,
        })
    }

    /// Parse a `--degrade-device` item: `IDX@CYCLE:FACTOR`, e.g.
    /// `1@50000:8`.
    pub fn parse_degrade(s: &str) -> Result<FaultSpec, String> {
        let usage = || format!("--degrade-device expects IDX@CYCLE:FACTOR, got {s:?}");
        let (d, rest) = s.split_once('@').ok_or_else(usage)?;
        let (c, f) = rest.split_once(':').ok_or_else(usage)?;
        let factor: f64 = f
            .trim()
            .parse()
            .map_err(|_| format!("--degrade-device: bad slow-down factor {f:?}"))?;
        if !factor.is_finite() || factor < 1.0 {
            return Err(format!(
                "--degrade-device: factor must be a finite slow-down >= 1, got {factor}"
            ));
        }
        Ok(FaultSpec {
            device: parse_num(d, "--degrade-device", "device index")?,
            at_cycle: parse_num(c, "--degrade-device", "cycle")?,
            kind: FaultKind::Degrade { factor },
        })
    }
}

fn parse_num<T: std::str::FromStr>(s: &str, flag: &str, what: &str) -> Result<T, String> {
    s.trim().parse().map_err(|_| format!("{flag}: bad {what} {s:?}"))
}

/// Fleet-level serving knobs.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetSpec {
    pub devices: usize,
    pub placement: PlacementPolicy,
    pub faults: Vec<FaultSpec>,
    /// Shed an arrival when its predicted queueing delay exceeds this.
    pub slo_cycles: Option<u64>,
    /// Hedged re-issue after a p99-derived delay.
    pub hedge: bool,
    /// Failover re-dispatch budget per batch.
    pub retries: usize,
}

impl Default for FleetSpec {
    fn default() -> Self {
        FleetSpec {
            devices: 1,
            placement: PlacementPolicy::RoundRobin,
            faults: Vec::new(),
            slo_cycles: None,
            hedge: false,
            retries: 2,
        }
    }
}

/// Why a device attempt at a batch ended.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AttemptOutcome {
    /// Completed and its result was used.
    Won,
    /// A hedge duplicate (or hedged primary) cancelled when the other
    /// attempt completed first.
    Cancelled,
    /// The device fail-stopped during the attempt.
    Failed,
}

/// One device's occupancy window for one batch attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AttemptRecord {
    pub batch: usize,
    pub device: usize,
    pub start: u64,
    /// Cycle the device stopped working on this attempt (completion,
    /// cancellation, or death).
    pub end: u64,
    pub outcome: AttemptOutcome,
}

/// One dispatched batch, with its winning attempt's timeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FleetBatchRecord {
    pub close: u64,
    pub start: u64,
    pub completion: u64,
    pub size: usize,
    /// Device whose attempt won.
    pub device: usize,
    /// Device attempts this batch needed (1 = clean dispatch).
    pub attempts: usize,
}

/// An arrival rejected by SLO admission control.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShedRecord {
    pub id: usize,
    pub kind: usize,
    pub arrival: u64,
}

/// One device's totals over the run.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct DeviceOutcome {
    /// Cycles spent executing attempts (won, cancelled or failed).
    pub busy_cycles: u64,
    /// Batches whose winning attempt ran here.
    pub batches_won: usize,
    /// The injected fail-stop cycle, if any.
    pub failed_at: Option<u64>,
    /// The injected `(cycle, factor)` degradation, if any.
    pub degraded: Option<(u64, f64)>,
}

/// Robustness counters — every one reported, none silently dropped.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct FleetCounters {
    /// Batch-level failover re-dispatches after a failure detection.
    pub failovers: usize,
    /// Request-level re-dispatches (members of failed-over batches).
    pub retries: usize,
    /// Hedged duplicates issued.
    pub hedges: usize,
    /// Arrivals shed by SLO admission control.
    pub sheds: usize,
    /// Device cycles burned by attempts whose result was not used
    /// (died mid-batch, or lost a hedge race).
    pub wasted_cycles: u64,
}

/// The full simulated fleet timeline.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FleetOutcome {
    /// Served requests, in arrival (= id) order.
    pub records: Vec<RequestRecord>,
    /// In dispatch order.
    pub batches: Vec<FleetBatchRecord>,
    /// Every device occupancy window, in resolution order.
    pub attempts: Vec<AttemptRecord>,
    /// Arrivals rejected at admission, in arrival order.
    pub shed: Vec<ShedRecord>,
    pub devices: Vec<DeviceOutcome>,
    pub counters: FleetCounters,
    /// Total arrivals offered (= served + shed).
    pub offered: usize,
}

#[derive(Debug, Clone, Copy, Default)]
struct Device {
    free_at: u64,
    busy_cycles: u64,
    batches_won: usize,
    fail_at: Option<u64>,
    degrade: Option<(u64, f64)>,
    /// Cycle the router detected the fail-stop (the missed timeout).
    fail_detected_at: Option<u64>,
}

impl Device {
    fn schedulable_at(&self, now: u64) -> bool {
        self.fail_detected_at.is_none_or(|t| t > now)
    }

    /// Degrade-aware completion of `work` cycles starting at `start`.
    fn finish(&self, start: u64, work: u64) -> u64 {
        match self.degrade {
            None => start + work,
            Some((at, factor)) => {
                let scale = |c: u64| (c as f64 * factor).round() as u64;
                if start >= at {
                    start + scale(work)
                } else {
                    let fast = at - start;
                    if work <= fast {
                        start + work
                    } else {
                        at + scale(work - fast)
                    }
                }
            }
        }
    }
}

fn views_at(devs: &[Device], now: u64) -> Vec<DeviceView> {
    devs.iter()
        .map(|d| DeviceView { free_at: d.free_at, schedulable: d.schedulable_at(now) })
        .collect()
}

/// Earliest believed device availability relative to `now` — the
/// admission controller's queueing-delay prediction. `u64::MAX` when
/// every device's failure has been detected.
fn predicted_wait(devs: &[Device], now: u64) -> u64 {
    devs.iter()
        .filter(|d| d.schedulable_at(now))
        .map(|d| d.free_at.saturating_sub(now))
        .min()
        .unwrap_or(u64::MAX)
}

/// p99 of completed batch windows, the hedge trigger/delay. `None`
/// until hedging can act (enabled, a device to hedge onto, history).
fn hedge_delay(spec: &FleetSpec, windows: &[f64]) -> Option<u64> {
    if !spec.hedge || spec.devices < 2 || windows.len() < HEDGE_MIN_SAMPLES {
        return None;
    }
    let mut sorted = windows.to_vec();
    sorted.sort_by(f64::total_cmp);
    quantile_sorted(&sorted, 0.99).map(|p| p.ceil().max(1.0) as u64)
}

fn validate(spec: &FleetSpec) -> Result<(), String> {
    if spec.devices == 0 {
        return Err("fleet needs at least 1 device".into());
    }
    let mut seen = vec![(false, false); spec.devices]; // (fail, degrade)
    for f in &spec.faults {
        if f.device >= spec.devices {
            return Err(format!(
                "fault targets device {} but the fleet has devices 0..{}",
                f.device,
                spec.devices - 1
            ));
        }
        let slot = &mut seen[f.device];
        match f.kind {
            FaultKind::FailStop => {
                if slot.0 {
                    return Err(format!("device {} has two fail-stop faults", f.device));
                }
                slot.0 = true;
            }
            FaultKind::Degrade { factor } => {
                if !factor.is_finite() || factor < 1.0 {
                    return Err(format!(
                        "degrade factor must be a finite slow-down >= 1, got {factor}"
                    ));
                }
                if slot.1 {
                    return Err(format!("device {} has two degrade faults", f.device));
                }
                slot.1 = true;
            }
        }
    }
    Ok(())
}

struct Resolved {
    device: usize,
    start: u64,
    completion: u64,
    attempts: usize,
}

/// Run the fleet queueing model to completion. Batching semantics are
/// exactly [`super::queue::simulate_queue`]'s; each closed batch is
/// placed by the router and resolved through failover/hedging. Errors
/// when a batch exhausts its failover budget or outlives the fleet.
pub fn simulate_fleet(
    source: &mut ArrivalSource,
    service_by_kind: &[u64],
    policy: BatchPolicy,
    overhead_cycles: u64,
    spec: &FleetSpec,
) -> Result<FleetOutcome, String> {
    validate(spec)?;
    let mut devs: Vec<Device> = vec![Device::default(); spec.devices];
    for f in &spec.faults {
        match f.kind {
            FaultKind::FailStop => devs[f.device].fail_at = Some(f.at_cycle),
            FaultKind::Degrade { factor } => devs[f.device].degrade = Some((f.at_cycle, factor)),
        }
    }
    let mut router = Router::new(spec.placement);
    let max_batch = policy.max_batch();
    let max_wait = policy.max_wait();
    // (id, kind, arrival)
    let mut queue: VecDeque<(usize, usize, u64)> = VecDeque::new();
    let mut next_id = 0usize;
    let mut out = FleetOutcome::default();
    // completed (winning) batch windows, feeding the p99 hedge delay
    let mut windows: Vec<f64> = Vec::new();

    loop {
        let next_arrival = source.peek();
        // batch-close rules, identical to simulate_queue
        let close: Option<u64> = if queue.len() >= max_batch {
            Some(queue[max_batch - 1].2)
        } else if !queue.is_empty() && next_arrival.is_none() {
            Some(queue.back().unwrap().2)
        } else if let (Some(wait), Some(front)) = (max_wait, queue.front()) {
            let expiry = front.2.saturating_add(wait);
            match next_arrival {
                Some(a) if a <= expiry => None,
                _ => Some(expiry),
            }
        } else {
            None
        };

        if let Some(close_at) = close {
            let size = queue.len().min(max_batch);
            let members: Vec<(usize, usize, u64)> = queue.drain(..size).collect();
            let service: u64 = members.iter().map(|&(_, k, _)| service_by_kind[k]).sum();
            let work = overhead_cycles + service;
            let lead_kind = members[0].1;
            let delay = hedge_delay(spec, &windows);
            let batch_idx = out.batches.len();
            let r = dispatch_batch(
                &mut devs,
                &mut router,
                close_at,
                work,
                lead_kind,
                size,
                spec,
                delay,
                batch_idx,
                &mut out,
            )?;
            for (id, kind, arrival) in members {
                out.records.push(RequestRecord {
                    id,
                    kind,
                    arrival,
                    service_cycles: service_by_kind[kind],
                    start: r.start,
                    completion: r.completion,
                    batch: batch_idx,
                });
            }
            windows.push((r.completion - r.start) as f64);
            out.batches.push(FleetBatchRecord {
                close: close_at,
                start: r.start,
                completion: r.completion,
                size,
                device: r.device,
                attempts: r.attempts,
            });
            source.on_batch_dispatched(size, r.completion);
        } else if let Some((cycle, kind)) = source.pop() {
            let id = next_id;
            next_id += 1;
            if let Some(slo) = spec.slo_cycles {
                if predicted_wait(&devs, cycle) > slo {
                    out.counters.sheds += 1;
                    out.shed.push(ShedRecord { id, kind, arrival: cycle });
                    // the rejection is an instant completion from the
                    // client's point of view: closed-loop clients
                    // re-issue after their think time
                    source.on_batch_dispatched(1, cycle);
                    continue;
                }
            }
            queue.push_back((id, kind, cycle));
        } else {
            debug_assert!(queue.is_empty());
            break;
        }
    }
    out.offered = next_id;
    out.devices = devs
        .iter()
        .map(|d| DeviceOutcome {
            busy_cycles: d.busy_cycles,
            batches_won: d.batches_won,
            failed_at: d.fail_at,
            degraded: d.degrade,
        })
        .collect();
    debug_assert_eq!(out.records.len() + out.shed.len(), out.offered);
    Ok(out)
}

/// Place one closed batch and resolve it to a winning attempt,
/// walking failovers and at most one hedge duplicate.
#[allow(clippy::too_many_arguments)]
fn dispatch_batch(
    devs: &mut [Device],
    router: &mut Router,
    close_at: u64,
    work: u64,
    lead_kind: usize,
    size: usize,
    spec: &FleetSpec,
    hedge_delay: Option<u64>,
    batch_idx: usize,
    out: &mut FleetOutcome,
) -> Result<Resolved, String> {
    let mut ready = close_at;
    let mut redispatches = 0usize;
    let mut attempts = 0usize;
    let budget = |redispatches: &mut usize| -> Result<(), String> {
        *redispatches += 1;
        if *redispatches > spec.retries {
            return Err(format!(
                "batch {batch_idx}: failover budget exhausted after {} re-dispatches \
                 (raise --retries or keep more devices alive)",
                spec.retries
            ));
        }
        Ok(())
    };
    loop {
        let views = views_at(devs, ready);
        let Some(d) = router.pick(&views, lead_kind, None) else {
            return Err(format!(
                "batch {batch_idx}: no live device remains (all {} failed)",
                devs.len()
            ));
        };
        let start = devs[d].free_at.max(ready);
        if let Some(t) = devs[d].fail_detected_at {
            // assigned behind a doomed attempt: by the time this batch
            // would start, the failure is already detected — re-route
            // at the detection cycle without another timeout window
            debug_assert!(t <= start);
            out.counters.failovers += 1;
            out.counters.retries += size;
            budget(&mut redispatches)?;
            ready = ready.max(t);
            continue;
        }
        attempts += 1;
        let completion = devs[d].finish(start, work);
        if let Some(fail_at) = devs[d].fail_at {
            if fail_at < completion {
                // the device dies mid-attempt; the router only learns
                // when the expected completion passes without a result
                let worked_until = fail_at.clamp(start, completion);
                let did = worked_until - start;
                devs[d].busy_cycles += did;
                devs[d].free_at = completion;
                devs[d].fail_detected_at = Some(completion);
                out.counters.wasted_cycles += did;
                out.counters.failovers += 1;
                out.counters.retries += size;
                out.attempts.push(AttemptRecord {
                    batch: batch_idx,
                    device: d,
                    start,
                    end: worked_until,
                    outcome: AttemptOutcome::Failed,
                });
                budget(&mut redispatches)?;
                ready = completion;
                continue;
            }
        }
        // this attempt will complete; optionally race a hedge duplicate
        if let Some(delay) = hedge_delay {
            if completion - start > delay {
                let issue = start.saturating_add(delay);
                let views = views_at(devs, issue);
                if let Some(alt) = router.pick(&views, lead_kind, Some(d)) {
                    out.counters.hedges += 1;
                    attempts += 1;
                    let alt_start = devs[alt].free_at.max(issue);
                    let alt_completion = devs[alt].finish(alt_start, work);
                    let alt_dies = devs[alt].fail_at.is_some_and(|f| f < alt_completion);
                    if !alt_dies && alt_completion < completion {
                        // duplicate wins: the primary is cancelled at the
                        // winner's completion, its cycles are waste
                        devs[d].busy_cycles += alt_completion - start;
                        devs[d].free_at = alt_completion;
                        out.counters.wasted_cycles += alt_completion - start;
                        out.attempts.push(AttemptRecord {
                            batch: batch_idx,
                            device: d,
                            start,
                            end: alt_completion,
                            outcome: AttemptOutcome::Cancelled,
                        });
                        devs[alt].busy_cycles += alt_completion - alt_start;
                        devs[alt].free_at = alt_completion;
                        devs[alt].batches_won += 1;
                        out.attempts.push(AttemptRecord {
                            batch: batch_idx,
                            device: alt,
                            start: alt_start,
                            end: alt_completion,
                            outcome: AttemptOutcome::Won,
                        });
                        return Ok(Resolved {
                            device: alt,
                            start: alt_start,
                            completion: alt_completion,
                            attempts,
                        });
                    }
                    // primary wins: cancel the duplicate at the primary's
                    // completion (or the duplicate device's death, if
                    // sooner); cycles it burned are waste
                    if alt_start < completion {
                        let alt_end = match devs[alt].fail_at {
                            Some(f) if f < completion => f.max(alt_start),
                            _ => completion,
                        };
                        if alt_end > alt_start {
                            devs[alt].busy_cycles += alt_end - alt_start;
                            devs[alt].free_at = alt_end;
                            out.counters.wasted_cycles += alt_end - alt_start;
                            out.attempts.push(AttemptRecord {
                                batch: batch_idx,
                                device: alt,
                                start: alt_start,
                                end: alt_end,
                                outcome: AttemptOutcome::Cancelled,
                            });
                        }
                    }
                }
            }
        }
        devs[d].busy_cycles += completion - start;
        devs[d].free_at = completion;
        devs[d].batches_won += 1;
        out.attempts.push(AttemptRecord {
            batch: batch_idx,
            device: d,
            start,
            end: completion,
            outcome: AttemptOutcome::Won,
        });
        return Ok(Resolved { device: d, start, completion, attempts });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn open(arrivals: &[(u64, usize)]) -> ArrivalSource {
        ArrivalSource::open(arrivals.to_vec())
    }

    fn fleet(devices: usize, placement: PlacementPolicy) -> FleetSpec {
        FleetSpec { devices, placement, ..FleetSpec::default() }
    }

    #[test]
    fn one_device_no_faults_matches_simulate_queue() {
        use super::super::queue::simulate_queue;
        // a mixed schedule exercising full, flush and deadline closes
        let policies = [
            BatchPolicy::Immediate,
            BatchPolicy::Size(3),
            BatchPolicy::Deadline { max_batch: 4, max_wait_cycles: 40 },
        ];
        let arrivals: Vec<(u64, usize)> =
            vec![(0, 0), (5, 1), (9, 0), (9, 1), (70, 0), (71, 1), (400, 0)];
        for policy in policies {
            for overhead in [0u64, 13] {
                let q = simulate_queue(&mut open(&arrivals), &[50, 90], policy, overhead);
                let f = simulate_fleet(
                    &mut open(&arrivals),
                    &[50, 90],
                    policy,
                    overhead,
                    &FleetSpec::default(),
                )
                .unwrap();
                assert_eq!(q.records, f.records, "records differ under {policy:?}");
                assert_eq!(q.batches.len(), f.batches.len());
                for (a, b) in q.batches.iter().zip(&f.batches) {
                    assert_eq!(
                        (a.close, a.start, a.completion, a.size),
                        (b.close, b.start, b.completion, b.size)
                    );
                    assert_eq!((b.device, b.attempts), (0, 1));
                }
                assert_eq!(f.offered, f.records.len());
                assert_eq!(f.counters, FleetCounters::default());
            }
        }
    }

    #[test]
    fn two_devices_overlap_batches() {
        // two long requests arriving back to back: one device serializes
        // them, two devices serve them concurrently
        let arrivals = [(0, 0), (1, 0)];
        let single =
            simulate_fleet(&mut open(&arrivals), &[1000], BatchPolicy::Immediate, 0, &fleet(1, PlacementPolicy::RoundRobin))
                .unwrap();
        let dual =
            simulate_fleet(&mut open(&arrivals), &[1000], BatchPolicy::Immediate, 0, &fleet(2, PlacementPolicy::LeastWork))
                .unwrap();
        assert_eq!(single.records[1].completion, 2000);
        assert_eq!(dual.records[1].completion, 1001, "second device starts immediately");
        assert_eq!(dual.batches[0].device, 0);
        assert_eq!(dual.batches[1].device, 1);
    }

    #[test]
    fn fail_stop_fails_over_with_waste_and_timeout_detection() {
        let mut spec = fleet(2, PlacementPolicy::LeastWork);
        spec.faults.push(FaultSpec { device: 0, at_cycle: 400, kind: FaultKind::FailStop });
        // one request at cycle 0, service 1000: device 0 runs 0..400 and
        // dies; the timeout fires at the expected completion (1000) and
        // the batch re-runs on device 1 from there
        let out =
            simulate_fleet(&mut open(&[(0, 0)]), &[1000], BatchPolicy::Immediate, 0, &spec)
                .unwrap();
        assert_eq!(out.counters.failovers, 1);
        assert_eq!(out.counters.retries, 1);
        assert_eq!(out.counters.wasted_cycles, 400, "work burned before dying");
        let r = &out.records[0];
        assert_eq!((r.start, r.completion), (1000, 2000), "timeout then full re-run");
        assert_eq!(out.batches[0].device, 1);
        assert_eq!(out.batches[0].attempts, 2);
        assert_eq!(out.devices[0].busy_cycles, 400);
        assert_eq!(out.devices[1].busy_cycles, 1000);
        assert_eq!(
            out.attempts[0],
            AttemptRecord {
                batch: 0,
                device: 0,
                start: 0,
                end: 400,
                outcome: AttemptOutcome::Failed
            }
        );
    }

    #[test]
    fn batches_behind_a_doomed_attempt_reroute_at_detection() {
        let mut spec = fleet(2, PlacementPolicy::RoundRobin);
        spec.faults.push(FaultSpec { device: 0, at_cycle: 100, kind: FaultKind::FailStop });
        // round-robin sends batch 0 -> dev0 (dies), batch 1 -> dev1,
        // batch 2 -> dev0 again (not yet detected at close 2): it would
        // start at the doomed batch's timeout (1000), where the failure
        // is known, so it re-routes without a second timeout
        let out = simulate_fleet(
            &mut open(&[(0, 0), (1, 0), (2, 0)]),
            &[1000],
            BatchPolicy::Immediate,
            0,
            &spec,
        )
        .unwrap();
        // batch 0 failed over to dev1, after dev1's own batch
        assert!(out.counters.failovers >= 2, "mid-flight + queued-behind failovers");
        assert!(out.records.iter().all(|r| r.completion <= 4000));
        // every surviving record ran on device 1
        assert!(out.batches.iter().all(|b| b.device == 1));
    }

    #[test]
    fn degrade_stretches_only_post_fault_cycles() {
        let mut spec = fleet(1, PlacementPolicy::RoundRobin);
        spec.faults.push(FaultSpec {
            device: 0,
            at_cycle: 600,
            kind: FaultKind::Degrade { factor: 3.0 },
        });
        // service 1000 starting at 0: 600 fast cycles, remaining 400 at
        // 3x -> completes at 600 + 1200 = 1800
        let out =
            simulate_fleet(&mut open(&[(0, 0)]), &[1000], BatchPolicy::Immediate, 0, &spec)
                .unwrap();
        assert_eq!(out.records[0].completion, 1800);
        assert_eq!(out.counters, FleetCounters::default(), "degradation is not a failure");
    }

    #[test]
    fn hedge_races_a_degraded_primary_and_first_completion_wins() {
        let mut spec = fleet(2, PlacementPolicy::RoundRobin);
        spec.hedge = true;
        spec.faults.push(FaultSpec {
            device: 0,
            at_cycle: 50_000,
            kind: FaultKind::Degrade { factor: 10.0 },
        });
        // round-robin alternates devices; every pre-degradation batch is
        // fast and builds a p99 history of ~1000-cycle windows. Once
        // device 0 degrades 10x, its windows blow past that p99 and get
        // hedged onto the healthy device, whose duplicate finishes first.
        let arrivals: Vec<(u64, usize)> = (0..12).map(|i| (i * 12_000, 0)).collect();
        let out =
            simulate_fleet(&mut open(&arrivals), &[1000], BatchPolicy::Immediate, 0, &spec)
                .unwrap();
        assert!(out.counters.hedges > 0, "degraded windows exceed the fleet p99");
        assert!(out.counters.wasted_cycles > 0, "the cancelled loser burned cycles");
        assert_eq!(out.counters.failovers, 0, "no device died");
        // hedged batches were won by the healthy device
        let hedged: Vec<_> = out.batches.iter().filter(|b| b.attempts > 1).collect();
        assert!(!hedged.is_empty());
        assert!(hedged.iter().all(|b| b.device == 1));
        // the winning window is the fast one
        for b in hedged {
            assert_eq!(b.completion - b.start, 1000);
        }
    }

    #[test]
    fn slo_sheds_arrivals_and_conserves_offered() {
        let mut spec = fleet(1, PlacementPolicy::RoundRobin);
        spec.slo_cycles = Some(500);
        // service 1000, arrivals every 100 cycles: the queue builds and
        // later arrivals see predicted waits beyond the SLO
        let arrivals: Vec<(u64, usize)> = (0..10).map(|i| (i * 100, 0)).collect();
        let out =
            simulate_fleet(&mut open(&arrivals), &[1000], BatchPolicy::Immediate, 0, &spec)
                .unwrap();
        assert!(out.counters.sheds > 0, "admission control engaged");
        assert_eq!(out.records.len() + out.shed.len(), 10, "shed + served == offered");
        assert_eq!(out.offered, 10);
        // every admitted request met the SLO on queueing delay
        for r in &out.records {
            assert!(r.start - r.arrival <= 500 + 1000, "waited at most slo + one window");
        }
        // shed arrivals are recorded, not silently dropped
        assert_eq!(out.counters.sheds, out.shed.len());
    }

    #[test]
    fn all_devices_dead_is_a_loud_error() {
        let mut spec = fleet(1, PlacementPolicy::RoundRobin);
        spec.faults.push(FaultSpec { device: 0, at_cycle: 10, kind: FaultKind::FailStop });
        let err = simulate_fleet(&mut open(&[(0, 0)]), &[1000], BatchPolicy::Immediate, 0, &spec)
            .unwrap_err();
        assert!(err.contains("no live device"), "{err}");
    }

    #[test]
    fn failover_budget_is_enforced() {
        let mut spec = fleet(2, PlacementPolicy::LeastWork);
        spec.retries = 0;
        spec.faults.push(FaultSpec { device: 0, at_cycle: 10, kind: FaultKind::FailStop });
        let err = simulate_fleet(&mut open(&[(0, 0)]), &[1000], BatchPolicy::Immediate, 0, &spec)
            .unwrap_err();
        assert!(err.contains("failover budget"), "{err}");
    }

    #[test]
    fn fleet_replay_is_deterministic_with_faults() {
        let mut spec = fleet(3, PlacementPolicy::LeastWork);
        spec.hedge = true;
        spec.slo_cycles = Some(5_000);
        spec.faults.push(FaultSpec { device: 1, at_cycle: 3_000, kind: FaultKind::FailStop });
        spec.faults.push(FaultSpec {
            device: 2,
            at_cycle: 0,
            kind: FaultKind::Degrade { factor: 4.0 },
        });
        let run = |seed: u64| {
            let mut src = ArrivalSource::closed(4, 50, 40, 2, Pcg32::seeded(seed));
            simulate_fleet(&mut src, &[700, 900], BatchPolicy::Size(2), 11, &spec).unwrap()
        };
        assert_eq!(run(9), run(9), "same seed, same faulted timeline");
        assert_ne!(run(9).records, run(10).records, "different seed, different timeline");
    }

    #[test]
    fn fault_specs_parse_and_validate() {
        let f = FaultSpec::parse_fail("2@50000").unwrap();
        assert_eq!((f.device, f.at_cycle, f.kind), (2, 50_000, FaultKind::FailStop));
        let d = FaultSpec::parse_degrade("1@9:2.5").unwrap();
        assert_eq!(d.kind, FaultKind::Degrade { factor: 2.5 });
        assert!(FaultSpec::parse_fail("nope").is_err());
        assert!(FaultSpec::parse_degrade("1@9").is_err());
        assert!(FaultSpec::parse_degrade("1@9:0.5").is_err(), "speed-ups are not faults");

        let bad = FleetSpec {
            devices: 2,
            faults: vec![FaultSpec { device: 7, at_cycle: 0, kind: FaultKind::FailStop }],
            ..FleetSpec::default()
        };
        assert!(simulate_fleet(
            &mut open(&[]),
            &[1],
            BatchPolicy::Immediate,
            0,
            &bad
        )
        .is_err());
    }
}
