//! Content-addressed result cache: never price the same job bytes
//! twice.
//!
//! Every job in this repo is a deterministic function of
//! `(PlatformConfig, sim options, JobRequest)` — the property the whole
//! sharded-sweep equality proof rests on (see [`crate::coordinator::
//! shard`]). This module turns that determinism into reuse: the cache
//! key is a stable digest ([`crate::util::digest`]) over the canonical
//! `util::json` encoding of exactly those inputs, so two runs that
//! would simulate the same bytes share one cache entry — across
//! processes, sweeps, and (through a shared directory) hosts.
//!
//! Two tiers:
//! - **in-memory**: a map from key to [`JobOutcome`], always on;
//! - **persistent** (optional): one `{key}.cache.json` file per entry
//!   in a spool-style directory, published with the same atomic
//!   temp-file + rename protocol as [`super::dispatch::SpoolDir`]
//!   shards, so concurrent readers never observe a partial entry.
//!
//! Alongside simulated job outcomes the cache stores the DSE
//! prefilter's **analytical predictions** (`{key}.pred.json`, keyed by
//! [`prediction_key`] — a disjoint key space), so re-ranking an
//! unchanged grid under `--cache DIR` re-prices nothing.
//!
//! Failure policy mirrors the spool executor: a corrupt, truncated or
//! mismatched entry is quarantined to `{name}.poison` and treated as a
//! **miss**, never an error — a damaged cache can cost re-simulation
//! but can never fail a sweep or corrupt a result. Divergence checking
//! is the opposite, opt-in mode ([`ResultCache::with_verify`]): hits
//! are re-simulated and a mismatch is a hard error, which turns a
//! populated cache into a standing determinism regression check.
//!
//! What the key deliberately EXCLUDES: worker counts, shard counts,
//! transports, retry budgets — anything the determinism doctrine says
//! cannot change the bytes of a result. Including them would shatter
//! the cache across equivalent runs; excluding anything that *does*
//! affect results would alias distinct jobs, which is why the key
//! covers the full elaborated config and the per-job simulation
//! options (`fast_forward` affects no results either, but it selects a
//! different engine, so it stays in the key to keep `--no-fast-forward`
//! differential runs from short-circuiting through cached
//! fast-forward entries).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::config::PlatformConfig;
use crate::coordinator::dispatch::write_atomically;
use crate::coordinator::shard::{Shard, SweepOptions};
use crate::coordinator::{
    outcome_from_json, outcome_to_json, CoordinatorStats, JobOutcome, JobRequest,
};
use crate::model::Prediction;
use crate::util::digest::fingerprint;
use crate::util::json::{self, Json};

/// Wire-format marker of one persistent cache entry.
const CACHE_ENTRY_FORMAT: &str = "opengemm-cache-entry-v1";

/// Wire-format marker of one persistent analytical-prediction entry.
const PRED_ENTRY_FORMAT: &str = "opengemm-pred-entry-v1";

/// Cache key of one job: a digest over the canonical encoding of the
/// elaborated platform config, the result-relevant simulation options,
/// and the complete request (operands included).
pub fn job_key(
    cfg: &PlatformConfig,
    fast_forward: bool,
    csr_latency: u64,
    request: &JobRequest,
) -> String {
    let doc = Json::obj(vec![
        ("cfg", cfg.to_json()),
        (
            "options",
            Json::obj(vec![
                ("csr_latency", Json::num(csr_latency as f64)),
                ("fast_forward", Json::Bool(fast_forward)),
            ]),
        ),
        ("request", request.to_json()),
    ]);
    fingerprint(doc.pretty().as_bytes())
}

/// Cache key of one *analytical prediction* (the DSE prefilter's
/// per-job closed-form price). A distinct `kind` marker keeps the key
/// space disjoint from [`job_key`]: a prediction and a simulation of
/// the same job share inputs but not outputs, so they must never alias
/// one cache entry. `fast_forward` is deliberately absent — the
/// analytical model has no engine choice.
pub fn prediction_key(cfg: &PlatformConfig, csr_latency: u64, request: &JobRequest) -> String {
    let doc = Json::obj(vec![
        ("kind", Json::str("analytical-prediction")),
        ("cfg", cfg.to_json()),
        (
            "options",
            Json::obj(vec![("csr_latency", Json::num(csr_latency as f64))]),
        ),
        ("request", request.to_json()),
    ]);
    fingerprint(doc.pretty().as_bytes())
}

/// The cache key of every job in a shard, parallel to `shard.requests`.
pub fn shard_job_keys(shard: &Shard) -> Vec<String> {
    shard
        .requests
        .iter()
        .map(|r| job_key(&shard.cfg, shard.options.fast_forward, shard.options.csr_latency, r))
        .collect()
}

/// Content fingerprint of a whole shard — the spool transport's
/// resumable stem. The shard's `workers` knob is masked out before
/// hashing: it tunes the executor host's thread pool and cannot change
/// the result bytes, so a re-run with a different `--workers` must
/// still claim the killed run's published results.
pub fn shard_fingerprint(shard: &Shard) -> String {
    let canonical = Shard {
        options: SweepOptions { workers: 0, ..shard.options },
        ..shard.clone()
    };
    fingerprint(canonical.to_json().pretty().as_bytes())
}

/// Derive the coordinator counters a run of these outcomes would have
/// produced. Exact by construction — [`Coordinator::run_batch`] counts
/// per-outcome through the same [`CoordinatorStats::record`] — which is
/// what keeps a warm-cache merged document byte-identical to the cold
/// run's.
///
/// [`Coordinator::run_batch`]: crate::coordinator::Coordinator::run_batch
pub fn derive_stats<'a>(outcomes: impl IntoIterator<Item = &'a JobOutcome>) -> CoordinatorStats {
    let mut stats = CoordinatorStats::default();
    for outcome in outcomes {
        stats.record(outcome);
    }
    stats
}

/// A content-addressed job-result cache (in-memory tier, plus an
/// optional directory-backed persistent tier).
pub struct ResultCache {
    dir: Option<PathBuf>,
    verify: bool,
    /// Persistent-tier entry budget (0 = unlimited): after each publish
    /// the oldest entries are evicted down to this count.
    gc_max_entries: usize,
    mem: Mutex<BTreeMap<String, JobOutcome>>,
    pred_mem: Mutex<BTreeMap<String, Prediction>>,
    hits: AtomicU64,
    misses: AtomicU64,
    pred_hits: AtomicU64,
    pred_misses: AtomicU64,
}

impl ResultCache {
    /// Memory-only cache: reuse within one process, nothing persisted.
    pub fn in_memory() -> ResultCache {
        ResultCache {
            dir: None,
            verify: false,
            gc_max_entries: 0,
            mem: Mutex::new(BTreeMap::new()),
            pred_mem: Mutex::new(BTreeMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            pred_hits: AtomicU64::new(0),
            pred_misses: AtomicU64::new(0),
        }
    }

    /// Directory-backed cache: entries persist across process
    /// invocations as `{key}.cache.json` files under `dir` (created if
    /// absent).
    pub fn persistent(dir: &Path) -> Result<ResultCache, String> {
        std::fs::create_dir_all(dir)
            .map_err(|e| format!("result cache: create {}: {e}", dir.display()))?;
        Ok(ResultCache { dir: Some(dir.to_path_buf()), ..ResultCache::in_memory() })
    }

    /// Verify mode: hits are re-simulated and compared instead of
    /// short-circuiting dispatch; a divergence is a hard error.
    pub fn with_verify(mut self, verify: bool) -> ResultCache {
        self.verify = verify;
        self
    }

    pub fn verify(&self) -> bool {
        self.verify
    }

    /// Bound the persistent tier to `max` entries (0 = unlimited, the
    /// default). On every publish, the oldest `{key}.cache.json` files
    /// — by (mtime, name), so ties break deterministically — are
    /// evicted until the store fits. The just-published entry is never
    /// the eviction victim, so a sweep always ends with its own results
    /// resident. `.poison` quarantine files are deliberately NOT
    /// collected: they are operator evidence ([`Self::poison_files`]
    /// counts them so they cannot rot unnoticed).
    pub fn with_gc_max_entries(mut self, max: usize) -> ResultCache {
        self.gc_max_entries = max;
        self
    }

    pub fn gc_max_entries(&self) -> usize {
        self.gc_max_entries
    }

    /// Number of `.poison` quarantine files accumulated in the
    /// persistent directory (0 for a memory-only cache). Surfaced in
    /// `DispatchReport` so damaged entries demand an operator look.
    pub fn poison_files(&self) -> u64 {
        let Some(dir) = &self.dir else { return 0 };
        let Ok(entries) = std::fs::read_dir(dir) else { return 0 };
        entries
            .flatten()
            .filter(|e| e.file_name().to_string_lossy().ends_with(".poison"))
            .count() as u64
    }

    pub fn dir(&self) -> Option<&Path> {
        self.dir.as_deref()
    }

    /// Lookups answered from a tier (counted even in verify mode).
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Lookups that found nothing (quarantined entries included).
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Prediction-tier lookups answered from a tier.
    pub fn prediction_hits(&self) -> u64 {
        self.pred_hits.load(Ordering::Relaxed)
    }

    /// Prediction-tier lookups that found nothing.
    pub fn prediction_misses(&self) -> u64 {
        self.pred_misses.load(Ordering::Relaxed)
    }

    fn entry_path(dir: &Path, key: &str) -> PathBuf {
        dir.join(format!("{key}.cache.json"))
    }

    fn pred_entry_path(dir: &Path, key: &str) -> PathBuf {
        dir.join(format!("{key}.pred.json"))
    }

    /// Fetch the outcome stored under `key`, consulting memory first,
    /// then the persistent directory (a disk hit is promoted into the
    /// memory tier). A corrupt or mismatched persistent entry is
    /// quarantined to `.poison` and reported as a miss.
    pub fn lookup(&self, key: &str) -> Option<JobOutcome> {
        if let Some(outcome) = self.mem.lock().unwrap().get(key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Some(outcome.clone());
        }
        if let Some(dir) = &self.dir {
            let path = Self::entry_path(dir, key);
            if let Ok(text) = std::fs::read_to_string(&path) {
                match parse_entry(key, &text) {
                    Ok(outcome) => {
                        self.mem.lock().unwrap().insert(key.to_string(), outcome.clone());
                        self.hits.fetch_add(1, Ordering::Relaxed);
                        return Some(outcome);
                    }
                    Err(e) => {
                        // Same policy as poison spool shards: quarantine
                        // (evidence for the operator; the rename also
                        // stops every later lookup from re-parsing it)
                        // and treat as a miss — the job re-simulates.
                        eprintln!(
                            "result cache: quarantining poison entry {}: {e}",
                            path.display()
                        );
                        let poison = path.with_file_name(format!("{key}.cache.json.poison"));
                        let _ = std::fs::rename(&path, poison);
                    }
                }
            }
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        None
    }

    /// Publish an outcome under `key` in both tiers. A persistent-tier
    /// write failure is a warning, not an error: losing cache
    /// durability must never fail the sweep that produced the result.
    pub fn insert(&self, key: &str, outcome: &JobOutcome) {
        let first =
            self.mem.lock().unwrap().insert(key.to_string(), outcome.clone()).is_none();
        if !first {
            return;
        }
        if let Some(dir) = &self.dir {
            let doc = Json::obj(vec![
                ("format", Json::str(CACHE_ENTRY_FORMAT)),
                ("key", Json::str(key)),
                ("outcome", outcome_to_json(outcome)),
            ]);
            if let Err(e) = write_atomically(&Self::entry_path(dir, key), &doc.pretty()) {
                eprintln!("result cache: could not persist entry {key}: {e}");
            } else if self.gc_max_entries > 0 {
                self.gc(dir, key);
            }
        }
    }

    /// Fetch the analytical prediction stored under `key` (a
    /// [`prediction_key`]), memory tier first, then `{key}.pred.json`
    /// in the persistent directory. Same failure policy as job
    /// outcomes: a corrupt or mismatched entry is quarantined to
    /// `.poison` and reported as a miss, so a damaged store costs one
    /// closed-form re-price (microseconds), never an error.
    pub fn lookup_prediction(&self, key: &str) -> Option<Prediction> {
        if let Some(p) = self.pred_mem.lock().unwrap().get(key) {
            self.pred_hits.fetch_add(1, Ordering::Relaxed);
            return Some(p.clone());
        }
        if let Some(dir) = &self.dir {
            let path = Self::pred_entry_path(dir, key);
            if let Ok(text) = std::fs::read_to_string(&path) {
                match parse_pred_entry(key, &text) {
                    Ok(p) => {
                        self.pred_mem.lock().unwrap().insert(key.to_string(), p.clone());
                        self.pred_hits.fetch_add(1, Ordering::Relaxed);
                        return Some(p);
                    }
                    Err(e) => {
                        eprintln!(
                            "result cache: quarantining poison prediction {}: {e}",
                            path.display()
                        );
                        let poison = path.with_file_name(format!("{key}.pred.json.poison"));
                        let _ = std::fs::rename(&path, poison);
                    }
                }
            }
        }
        self.pred_misses.fetch_add(1, Ordering::Relaxed);
        None
    }

    /// Publish an analytical prediction under `key` in both tiers.
    /// Prediction entries are a few hundred bytes and deliberately
    /// exempt from [`Self::with_gc_max_entries`] eviction (which
    /// bounds the simulation-result tier): evicting one saves nothing
    /// and re-pricing a grid is exactly the work the tier exists to
    /// skip.
    pub fn insert_prediction(&self, key: &str, prediction: &Prediction) {
        let first = self
            .pred_mem
            .lock()
            .unwrap()
            .insert(key.to_string(), prediction.clone())
            .is_none();
        if !first {
            return;
        }
        if let Some(dir) = &self.dir {
            let doc = Json::obj(vec![
                ("format", Json::str(PRED_ENTRY_FORMAT)),
                ("key", Json::str(key)),
                ("prediction", prediction.to_json()),
            ]);
            if let Err(e) = write_atomically(&Self::pred_entry_path(dir, key), &doc.pretty()) {
                eprintln!("result cache: could not persist prediction {key}: {e}");
            }
        }
    }

    /// Evict the oldest persistent entries down to `gc_max_entries`,
    /// never touching the entry just published under `keep_key`. Best
    /// effort throughout: GC failures cost disk, not sweeps.
    fn gc(&self, dir: &Path, keep_key: &str) {
        let Ok(read) = std::fs::read_dir(dir) else { return };
        let keep_name = format!("{keep_key}.cache.json");
        let mut entries: Vec<(std::time::SystemTime, String)> = read
            .flatten()
            .filter_map(|e| {
                let name = e.file_name().to_string_lossy().into_owned();
                if !name.ends_with(".cache.json") || name == keep_name {
                    return None;
                }
                let mtime = e.metadata().and_then(|m| m.modified()).ok()?;
                Some((mtime, name))
            })
            .collect();
        // the published entry occupies one slot of the budget
        let budget = self.gc_max_entries.saturating_sub(1);
        if entries.len() <= budget {
            return;
        }
        entries.sort();
        for (_, name) in &entries[..entries.len() - budget] {
            let _ = std::fs::remove_file(dir.join(name));
        }
    }
}

fn parse_entry(key: &str, text: &str) -> Result<JobOutcome, String> {
    let v = json::parse(text)?;
    let format = json::get_str(&v, "format")?;
    if format != CACHE_ENTRY_FORMAT {
        return Err(format!(
            "not a cache entry: format {format:?}, want {CACHE_ENTRY_FORMAT:?}"
        ));
    }
    let stored = json::get_str(&v, "key")?;
    if stored != key {
        return Err(format!("entry holds key {stored:?}, file name says {key:?}"));
    }
    outcome_from_json(json::get(&v, "outcome")?)
}

fn parse_pred_entry(key: &str, text: &str) -> Result<Prediction, String> {
    let v = json::parse(text)?;
    let format = json::get_str(&v, "format")?;
    if format != PRED_ENTRY_FORMAT {
        return Err(format!(
            "not a prediction entry: format {format:?}, want {PRED_ENTRY_FORMAT:?}"
        ));
    }
    let stored = json::get_str(&v, "key")?;
    if stored != key {
        return Err(format!("entry holds key {stored:?}, file name says {key:?}"));
    }
    Prediction::from_json(json::get(&v, "prediction")?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::GemmShape;
    use crate::config::Mechanisms;
    use crate::coordinator::Coordinator;

    fn temp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("opengemm-cache-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn request(i: usize) -> JobRequest {
        JobRequest::timing(GemmShape::new(8 + 8 * i, 16, 8), Mechanisms::ALL, 1)
    }

    #[test]
    fn key_separates_config_options_and_request() {
        let cfg = PlatformConfig::case_study();
        let base = job_key(&cfg, true, 8, &request(0));
        assert_eq!(base, job_key(&cfg, true, 8, &request(0)), "deterministic");
        assert_ne!(base, job_key(&cfg, true, 8, &request(1)), "request in key");
        assert_ne!(base, job_key(&cfg, true, 16, &request(0)), "csr latency in key");
        assert_ne!(base, job_key(&cfg, false, 8, &request(0)), "engine choice in key");
        let mut deep = cfg.clone();
        deep.mem.d_stream += 1;
        assert_ne!(base, job_key(&deep, true, 8, &request(0)), "config in key");
    }

    #[test]
    fn shard_fingerprint_ignores_worker_count_only() {
        let cfg = PlatformConfig::case_study();
        let opts = SweepOptions { workers: 2, ..Default::default() };
        let plan = crate::coordinator::shard::SweepPlan::stride(
            &cfg,
            vec![request(0), request(1)],
            opts,
        );
        let shard = plan.shards[0].clone();
        let mut retuned = shard.clone();
        retuned.options.workers = 7;
        assert_eq!(
            shard_fingerprint(&shard),
            shard_fingerprint(&retuned),
            "a host-tuning knob must not re-address the shard"
        );
        let mut other = shard.clone();
        other.requests[0] = request(3);
        assert_ne!(shard_fingerprint(&shard), shard_fingerprint(&other));
    }

    #[test]
    fn memory_tier_round_trip_counts_hits_and_misses() {
        let cache = ResultCache::in_memory();
        assert!(cache.lookup("k1").is_none());
        let outcome: JobOutcome = Err("boom".into());
        cache.insert("k1", &outcome);
        assert_eq!(cache.lookup("k1"), Some(outcome));
        assert_eq!((cache.hits(), cache.misses()), (1, 1));
    }

    #[test]
    fn persistent_tier_survives_a_new_cache_instance() {
        let dir = temp_dir("persist");
        let cfg = PlatformConfig::case_study();
        let req = request(0);
        let outcome = Coordinator::new(cfg.clone()).with_workers(1).run_one(&req);
        let key = job_key(&cfg, true, 8, &req);

        let warm = ResultCache::persistent(&dir).unwrap();
        warm.insert(&key, &outcome);
        drop(warm);

        let cold = ResultCache::persistent(&dir).unwrap();
        assert_eq!(cold.lookup(&key), Some(outcome), "entry read back from disk");
        assert_eq!((cold.hits(), cold.misses()), (1, 0));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_truncated_and_mismatched_entries_are_quarantined_misses() {
        let dir = temp_dir("poison");
        let cache = ResultCache::persistent(&dir).unwrap();
        let ok: JobOutcome = Err("placeholder".into());

        // syntactically broken
        std::fs::write(dir.join("bad.cache.json"), "{ not json").unwrap();
        // truncated mid-write (no atomic publish)
        cache.insert("donor", &ok);
        let full = std::fs::read_to_string(dir.join("donor.cache.json")).unwrap();
        std::fs::write(dir.join("cut.cache.json"), &full[..full.len() / 2]).unwrap();
        // well-formed but filed under the wrong name
        std::fs::write(
            dir.join("moved.cache.json"),
            full.replace("donor", "elsewhere"),
        )
        .unwrap();

        for key in ["bad", "cut", "moved"] {
            assert!(cache.lookup(key).is_none(), "{key} must be a miss, not an error");
            assert!(
                dir.join(format!("{key}.cache.json.poison")).exists(),
                "{key} quarantined"
            );
            assert!(!dir.join(format!("{key}.cache.json")).exists(), "{key} renamed away");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn gc_bounds_the_persistent_tier_keeping_newest() {
        let dir = temp_dir("gc");
        let cache = ResultCache::persistent(&dir).unwrap().with_gc_max_entries(3);
        let outcome: JobOutcome = Err("placeholder".into());
        for i in 0..6 {
            cache.insert(&format!("k{i}"), &outcome);
            // distinct mtimes so age ordering is unambiguous even on a
            // coarse filesystem clock
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        let mut names: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .flatten()
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .filter(|n| n.ends_with(".cache.json"))
            .collect();
        names.sort();
        assert_eq!(names, vec!["k3.cache.json", "k4.cache.json", "k5.cache.json"]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn poison_files_are_counted_not_collected() {
        let dir = temp_dir("gc-poison");
        let cache = ResultCache::persistent(&dir).unwrap().with_gc_max_entries(1);
        assert_eq!(cache.poison_files(), 0);
        assert_eq!(ResultCache::in_memory().poison_files(), 0);
        std::fs::write(dir.join("bad.cache.json"), "{ not json").unwrap();
        assert!(cache.lookup("bad").is_none());
        assert_eq!(cache.poison_files(), 1);
        // GC never removes quarantine evidence, however tight the budget
        let out: JobOutcome = Err("x".into());
        cache.insert("fresh", &out);
        assert_eq!(cache.poison_files(), 1);
        assert!(dir.join("bad.cache.json.poison").exists());
        assert!(dir.join("fresh.cache.json").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn prediction_keys_are_disjoint_from_job_keys() {
        let cfg = PlatformConfig::case_study();
        let req = request(0);
        let pk = prediction_key(&cfg, 8, &req);
        assert_eq!(pk, prediction_key(&cfg, 8, &req), "deterministic");
        assert_ne!(pk, job_key(&cfg, true, 8, &req), "kinds never alias");
        assert_ne!(pk, job_key(&cfg, false, 8, &req));
        assert_ne!(pk, prediction_key(&cfg, 16, &req), "csr latency in key");
        assert_ne!(pk, prediction_key(&cfg, 8, &request(1)), "request in key");
        let mut multi = cfg.clone();
        multi.cores = 2;
        assert_ne!(pk, prediction_key(&multi, 8, &req), "config (cores) in key");
    }

    #[test]
    fn prediction_tier_round_trips_and_quarantines_poison() {
        let dir = temp_dir("pred");
        let cfg = PlatformConfig::case_study();
        let req = request(0);
        let p = crate::model::predict_with(&cfg, &req, 8).unwrap();
        let key = prediction_key(&cfg, 8, &req);

        let warm = ResultCache::persistent(&dir).unwrap();
        assert!(warm.lookup_prediction(&key).is_none());
        warm.insert_prediction(&key, &p);
        assert_eq!(warm.lookup_prediction(&key), Some(p.clone()));
        assert_eq!((warm.prediction_hits(), warm.prediction_misses()), (1, 1));
        // outcome counters untouched by the prediction tier
        assert_eq!((warm.hits(), warm.misses()), (0, 0));
        drop(warm);

        let cold = ResultCache::persistent(&dir).unwrap();
        assert_eq!(cold.lookup_prediction(&key), Some(p), "read back from disk");

        std::fs::write(dir.join("bad.pred.json"), "{ not json").unwrap();
        assert!(cold.lookup_prediction("bad").is_none(), "poison is a miss");
        assert!(dir.join("bad.pred.json.poison").exists());
        assert_eq!(cold.poison_files(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn gc_never_evicts_prediction_entries() {
        let dir = temp_dir("gc-pred");
        let cache = ResultCache::persistent(&dir).unwrap().with_gc_max_entries(1);
        let p = Prediction::unschedulable();
        cache.insert_prediction("p0", &p);
        cache.insert_prediction("p1", &p);
        let out: JobOutcome = Err("x".into());
        cache.insert("o0", &out);
        cache.insert("o1", &out);
        assert!(dir.join("p0.pred.json").exists());
        assert!(dir.join("p1.pred.json").exists());
        // the outcome tier respected its budget
        let outcomes = std::fs::read_dir(&dir)
            .unwrap()
            .flatten()
            .filter(|e| e.file_name().to_string_lossy().ends_with(".cache.json"))
            .count();
        assert_eq!(outcomes, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn derived_stats_match_a_real_run() {
        let cfg = PlatformConfig::case_study();
        let coord = Coordinator::new(cfg).with_workers(2);
        let reqs = vec![
            request(0),
            request(1),
            // oversized K fails the tiler — failures must count too
            JobRequest::timing(GemmShape::new(8, 300_000, 8), Mechanisms::ALL, 1),
        ];
        let outcomes = coord.run_batch(reqs);
        assert_eq!(derive_stats(outcomes.iter()), coord.stats());
    }
}
