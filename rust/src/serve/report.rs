//! Serving report: percentile tables + JSON for trend tracking.
//!
//! Deliberately free of wall-clock, host or worker-count fields: every
//! number is a deterministic function of (config, options, seed), so
//! two runs with the same seed serialize **byte-identically** — the
//! property the `serve-smoke` and `fleet-smoke` CI lanes diff, and
//! what makes these reports usable as regression baselines. The JSON
//! shares `util::json` with the sweep wire format, so trend tooling
//! can ingest both.
//!
//! ## `opengemm-serve-report-v2` schema
//!
//! Top-level object (keys serialize alphabetically — `util::json`
//! uses a BTreeMap — so diffs are stable):
//!
//! | key                  | meaning                                          |
//! |----------------------|--------------------------------------------------|
//! | `format`             | [`SERVE_REPORT_FORMAT`] marker                   |
//! | `workload`           | workload spec (name + knobs)                     |
//! | `arrival`            | arrival spec (poisson rate / closed-loop)        |
//! | `batching`           | batching policy + knobs                          |
//! | `seed`               | RNG seed the whole timeline derives from         |
//! | `freq_mhz`           | platform clock, for cycle⇄ms conversion          |
//! | `requests`           | requests **served** (shed arrivals excluded)     |
//! | `batches`            | batches dispatched                               |
//! | `duration_cycles`    | makespan (last completion cycle)                 |
//! | `device_busy_cycles` | busy cycles summed across **all** devices,       |
//! |                      | wasted attempts included                         |
//! | `throughput_rps`     | served requests per second of virtual time       |
//! | `device_utilization` | busy / (makespan × device count)                 |
//! | `latency_ms`         | end-to-end tails (`null` when nothing served)    |
//! | `queueing_ms`        | queueing-delay tails                             |
//! | `service_ms`         | batch-window tails                               |
//! | `kinds`              | per-request-kind served counts + stream cost     |
//! | `devices`            | per-device array: `busy_cycles`, `batches`,      |
//! |                      | `utilization`, injected fault cycles (or `null`) |
//! | `fleet`              | router + robustness counters: `placement`,       |
//! |                      | `offered`, `shed`, `goodput_rps`, `failovers`,   |
//! |                      | `retries`, `hedges`, `wasted_cycles`,            |
//! |                      | `slo_cycles`, `hedge`                            |
//! | `measurement`        | measurement-side simulation counters             |
//!
//! ### v1 → v2 changelog
//!
//! - `format` bumped to `opengemm-serve-report-v2`.
//! - Every v1 field is kept with its meaning unchanged; a 1-device
//!   no-fault run carries the same values v1 did on the same seed
//!   (the differential `serving_harness` pins).
//! - New `devices` array: per-device utilization, batches won and the
//!   injected fault schedule.
//! - New `fleet` object: placement policy, offered-vs-shed load
//!   accounting (`goodput_rps` vs `throughput_rps` over offered), and
//!   the robustness counters (`failovers`, `retries`, `hedges`,
//!   `wasted_cycles`) — all driven by deterministic fault injection.
//! - `device_busy_cycles` / `device_utilization` now aggregate across
//!   the fleet (identical to v1 when there is one device).

use crate::coordinator::CoordinatorStats;
use crate::util::json::Json;
use crate::util::stats::TailSummary;
use crate::util::table::{fmt_f, Table};

use super::arrival::ArrivalSpec;
use super::batching::BatchPolicy;

/// Wire-format marker, so downstream tooling fed the wrong file fails
/// loudly.
pub const SERVE_REPORT_FORMAT: &str = "opengemm-serve-report-v2";

/// Per-request-kind serving outcome.
#[derive(Debug, Clone, PartialEq)]
pub struct KindSummary {
    pub label: String,
    /// Requests of this kind served.
    pub served: usize,
    /// Stream cost of one request of this kind, in device cycles.
    pub service_cycles: u64,
}

/// Per-device serving outcome (v2).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeviceReport {
    pub device: usize,
    /// Cycles spent executing attempts, wasted ones included.
    pub busy_cycles: u64,
    /// Batches whose winning attempt ran here.
    pub batches: usize,
    /// Injected fail-stop cycle, if any.
    pub failed_at_cycle: Option<u64>,
    /// Injected `(cycle, factor)` degradation, if any.
    pub degraded: Option<(u64, f64)>,
}

/// Router configuration + robustness counters (v2).
#[derive(Debug, Clone, PartialEq)]
pub struct FleetStats {
    pub devices: usize,
    pub placement: String,
    /// Arrivals offered (= served + shed).
    pub offered: usize,
    /// Arrivals rejected by SLO admission control.
    pub shed: usize,
    /// Batch-level failover re-dispatches.
    pub failovers: usize,
    /// Request-level re-dispatches (members of failed-over batches).
    pub retries: usize,
    /// Hedged duplicates issued.
    pub hedges: usize,
    /// Device cycles burned by attempts whose result was unused.
    pub wasted_cycles: u64,
    /// Admission-control SLO in device cycles, if set.
    pub slo_cycles: Option<u64>,
    /// Whether hedged re-issue was enabled.
    pub hedge: bool,
}

impl Default for FleetStats {
    fn default() -> Self {
        FleetStats {
            devices: 1,
            placement: "round-robin".into(),
            offered: 0,
            shed: 0,
            failovers: 0,
            retries: 0,
            hedges: 0,
            wasted_cycles: 0,
            slo_cycles: None,
            hedge: false,
        }
    }
}

/// The complete serving-harness result.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeReport {
    pub workload: Json,
    pub arrival: ArrivalSpec,
    pub batching: BatchPolicy,
    pub seed: u64,
    pub freq_mhz: u64,
    /// Requests served (shed arrivals are counted in `fleet`, not here).
    pub requests: usize,
    pub batches: usize,
    /// Makespan: cycle of the last batch completion (0 when idle).
    pub duration_cycles: u64,
    /// Cycles spent serving batches across all devices, wasted
    /// attempts included.
    pub device_busy_cycles: u64,
    /// `None` when the window served no requests — an idle window is a
    /// legitimate outcome, not a panic (see `util::stats`).
    pub latency_ms: Option<TailSummary>,
    pub queueing_ms: Option<TailSummary>,
    pub service_ms: Option<TailSummary>,
    pub kinds: Vec<KindSummary>,
    /// Per-device utilization (v2; one entry per simulated device).
    pub devices: Vec<DeviceReport>,
    /// Router + robustness counters (v2).
    pub fleet: FleetStats,
    /// Measurement-side simulation counters (deterministic: the set of
    /// measured jobs and their cycle counts depend only on the
    /// workload, not on pool size or timing).
    pub measurement: CoordinatorStats,
}

impl ServeReport {
    /// Completed requests per second of virtual device time.
    pub fn throughput_rps(&self) -> f64 {
        if self.duration_cycles == 0 {
            return 0.0;
        }
        self.requests as f64 * self.freq_mhz as f64 * 1e6 / self.duration_cycles as f64
    }

    /// Fraction of the fleet's makespan capacity spent serving:
    /// busy / (makespan × device count).
    pub fn device_utilization(&self) -> f64 {
        let n = self.fleet.devices.max(1);
        if self.duration_cycles == 0 {
            return 0.0;
        }
        self.device_busy_cycles as f64 / (self.duration_cycles as f64 * n as f64)
    }

    /// One device's fraction of the makespan spent busy.
    fn one_device_utilization(&self, d: &DeviceReport) -> f64 {
        if self.duration_cycles == 0 {
            return 0.0;
        }
        d.busy_cycles as f64 / self.duration_cycles as f64
    }

    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            return 0.0;
        }
        self.requests as f64 / self.batches as f64
    }

    /// Wire encoding.
    pub fn to_json(&self) -> Json {
        let tail = |t: &Option<TailSummary>| match t {
            Some(t) => t.to_json(),
            None => Json::Null,
        };
        let opt_num = |v: Option<u64>| match v {
            Some(v) => Json::num(v as f64),
            None => Json::Null,
        };
        let kinds: Vec<Json> = self
            .kinds
            .iter()
            .map(|k| {
                Json::obj(vec![
                    ("label", Json::str(k.label.clone())),
                    ("served", Json::num(k.served as f64)),
                    ("service_cycles", Json::num(k.service_cycles as f64)),
                ])
            })
            .collect();
        let devices: Vec<Json> = self
            .devices
            .iter()
            .map(|d| {
                Json::obj(vec![
                    ("device", Json::num(d.device as f64)),
                    ("busy_cycles", Json::num(d.busy_cycles as f64)),
                    ("batches", Json::num(d.batches as f64)),
                    ("utilization", Json::num(self.one_device_utilization(d))),
                    ("failed_at_cycle", opt_num(d.failed_at_cycle)),
                    ("degraded_at_cycle", opt_num(d.degraded.map(|(c, _)| c))),
                    (
                        "degrade_factor",
                        match d.degraded {
                            Some((_, f)) => Json::num(f),
                            None => Json::Null,
                        },
                    ),
                ])
            })
            .collect();
        let fleet = Json::obj(vec![
            ("devices", Json::num(self.fleet.devices as f64)),
            ("placement", Json::str(self.fleet.placement.clone())),
            ("offered", Json::num(self.fleet.offered as f64)),
            ("shed", Json::num(self.fleet.shed as f64)),
            ("goodput_rps", Json::num(self.throughput_rps())),
            ("failovers", Json::num(self.fleet.failovers as f64)),
            ("retries", Json::num(self.fleet.retries as f64)),
            ("hedges", Json::num(self.fleet.hedges as f64)),
            ("wasted_cycles", Json::num(self.fleet.wasted_cycles as f64)),
            ("slo_cycles", opt_num(self.fleet.slo_cycles)),
            ("hedge", Json::Bool(self.fleet.hedge)),
        ]);
        Json::obj(vec![
            ("format", Json::str(SERVE_REPORT_FORMAT)),
            ("workload", self.workload.clone()),
            ("arrival", self.arrival.to_json()),
            ("batching", self.batching.to_json()),
            ("seed", Json::num(self.seed as f64)),
            ("freq_mhz", Json::num(self.freq_mhz as f64)),
            ("requests", Json::num(self.requests as f64)),
            ("batches", Json::num(self.batches as f64)),
            ("duration_cycles", Json::num(self.duration_cycles as f64)),
            ("device_busy_cycles", Json::num(self.device_busy_cycles as f64)),
            ("throughput_rps", Json::num(self.throughput_rps())),
            ("device_utilization", Json::num(self.device_utilization())),
            ("latency_ms", tail(&self.latency_ms)),
            ("queueing_ms", tail(&self.queueing_ms)),
            ("service_ms", tail(&self.service_ms)),
            ("kinds", Json::Arr(kinds)),
            ("devices", Json::Arr(devices)),
            ("fleet", fleet),
            ("measurement", self.measurement.to_json()),
        ])
    }

    /// Human-readable report: header lines + percentile table.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str("## Serving report\n\n");
        out.push_str(&format!(
            "workload {}  arrival {}  batching {}  seed {}\n",
            self.workload.get("name").and_then(|n| n.as_str()).unwrap_or("?"),
            self.arrival.label(),
            self.batching.label(),
            self.seed
        ));
        out.push_str(&format!(
            "fleet: {} device(s), placement {} | offered {}, shed {}, \
             failovers {}, retries {}, hedges {}, wasted {} cycles\n",
            self.fleet.devices,
            self.fleet.placement,
            self.fleet.offered,
            self.fleet.shed,
            self.fleet.failovers,
            self.fleet.retries,
            self.fleet.hedges,
            self.fleet.wasted_cycles
        ));
        out.push_str(&format!(
            "{} requests in {} batches (mean size {:.2}), makespan {:.2} ms @ {} MHz\n",
            self.requests,
            self.batches,
            self.mean_batch_size(),
            self.duration_cycles as f64 / (self.freq_mhz as f64 * 1e3),
            self.freq_mhz
        ));
        out.push_str(&format!(
            "goodput {:.1} req/s, fleet utilization {:.1}%\n\n",
            self.throughput_rps(),
            100.0 * self.device_utilization()
        ));
        match (&self.latency_ms, &self.queueing_ms, &self.service_ms) {
            (Some(lat), Some(que), Some(srv)) => {
                let mut t =
                    Table::new(&["latency (ms)", "p50", "p90", "p95", "p99", "max", "mean"]);
                for (name, s) in [("end-to-end", lat), ("queueing", que), ("service", srv)] {
                    t.row(vec![
                        name.to_string(),
                        fmt_f(s.p50, 3),
                        fmt_f(s.p90, 3),
                        fmt_f(s.p95, 3),
                        fmt_f(s.p99, 3),
                        fmt_f(s.max, 3),
                        fmt_f(s.mean, 3),
                    ]);
                }
                out.push_str(&t.markdown());
            }
            _ => out.push_str("(no requests served in this window)\n"),
        }
        if self.devices.len() > 1 {
            out.push('\n');
            let mut t = Table::new(&["device", "batches", "busy cycles", "utilization", "fault"]);
            for d in &self.devices {
                let fault = match (d.failed_at_cycle, d.degraded) {
                    (Some(c), _) => format!("fail-stop @ {c}"),
                    (None, Some((c, f))) => format!("degrade {f}x @ {c}"),
                    (None, None) => "-".into(),
                };
                t.row(vec![
                    d.device.to_string(),
                    d.batches.to_string(),
                    d.busy_cycles.to_string(),
                    format!("{:.1}%", 100.0 * self.one_device_utilization(d)),
                    fault,
                ]);
            }
            out.push_str(&t.markdown());
        }
        if !self.kinds.is_empty() {
            out.push('\n');
            let mut t = Table::new(&["request kind", "served", "service ms/req"]);
            for k in &self.kinds {
                t.row(vec![
                    k.label.clone(),
                    k.served.to_string(),
                    fmt_f(k.service_cycles as f64 / (self.freq_mhz as f64 * 1e3), 3),
                ]);
            }
            out.push_str(&t.markdown());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn report(requests: usize) -> ServeReport {
        let samples: Vec<f64> = (0..requests).map(|i| i as f64 + 1.0).collect();
        let tail = TailSummary::compute(&samples);
        ServeReport {
            workload: Json::obj(vec![("name", Json::str("bert"))]),
            arrival: ArrivalSpec::OpenPoisson { rate_rps: 100.0 },
            batching: BatchPolicy::Immediate,
            seed: 7,
            freq_mhz: 200,
            requests,
            batches: requests,
            duration_cycles: requests as u64 * 1000,
            device_busy_cycles: requests as u64 * 900,
            latency_ms: tail.clone(),
            queueing_ms: tail.clone(),
            service_ms: tail,
            kinds: vec![KindSummary {
                label: "bert-base-layer/seq64".into(),
                served: requests,
                service_cycles: 900,
            }],
            devices: vec![DeviceReport {
                device: 0,
                busy_cycles: requests as u64 * 900,
                batches: requests,
                failed_at_cycle: None,
                degraded: None,
            }],
            fleet: FleetStats { offered: requests, ..FleetStats::default() },
            measurement: CoordinatorStats::default(),
        }
    }

    #[test]
    fn json_roundtrips_and_has_percentiles() {
        let r = report(10);
        let text = r.to_json().pretty();
        let back = json::parse(&text).unwrap();
        assert_eq!(back.pretty(), text, "stable serialization");
        assert!(text.contains("\"p99\"") && text.contains(SERVE_REPORT_FORMAT));
    }

    #[test]
    fn v2_carries_every_robustness_counter_and_device_entries() {
        let mut r = report(10);
        r.fleet = FleetStats {
            devices: 2,
            placement: "least-work".into(),
            offered: 13,
            shed: 3,
            failovers: 1,
            retries: 4,
            hedges: 2,
            wasted_cycles: 777,
            slo_cycles: Some(5000),
            hedge: true,
        };
        r.devices.push(DeviceReport {
            device: 1,
            busy_cycles: 100,
            batches: 1,
            failed_at_cycle: Some(50_000),
            degraded: Some((10, 2.5)),
        });
        let text = r.to_json().pretty();
        for key in
            ["\"failovers\"", "\"retries\"", "\"hedges\"", "\"shed\"", "\"wasted_cycles\""]
        {
            assert!(text.contains(key), "v2 report missing {key}");
        }
        assert!(text.contains("\"utilization\""), "per-device utilization present");
        assert!(text.contains("\"failed_at_cycle\": 50000"));
        assert!(text.contains("\"goodput_rps\""));
        let back = json::parse(&text).unwrap();
        assert_eq!(back.get("fleet").and_then(|f| f.get("shed")).unwrap(), &Json::Num(3.0));
        assert_eq!(back.get("devices").map(|d| d.as_arr().unwrap().len()), Some(2));
        // render mentions the fleet line and the per-device table
        let rendered = r.render();
        assert!(rendered.contains("failovers 1"));
        assert!(rendered.contains("fail-stop @ 50000"));
    }

    #[test]
    fn empty_window_is_null_not_panic() {
        let r = report(0);
        assert_eq!(r.latency_ms, None);
        assert_eq!(r.throughput_rps(), 0.0);
        assert_eq!(r.device_utilization(), 0.0);
        assert_eq!(r.mean_batch_size(), 0.0);
        let text = r.to_json().pretty();
        assert!(text.contains("\"latency_ms\": null"));
        assert!(r.render().contains("no requests served"));
    }

    #[test]
    fn render_mentions_all_percentile_columns() {
        let text = report(5).render();
        for col in ["p50", "p90", "p95", "p99", "end-to-end", "queueing", "service"] {
            assert!(text.contains(col), "missing {col}");
        }
    }
}
