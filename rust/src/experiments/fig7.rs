//! Fig. 7: area-normalized throughput (GOPS/mm^2) of OpenGeMM vs
//! Gemmini in OS and WS modes, across square GeMM sizes 8..128.
//!
//! OpenGeMM throughput is measured in the simulator on the *kernel
//! window* (start pulse to completion, configuration amortized — the
//! steady-state view with CPL that the paper's comparison uses).
//! Gemmini numbers come from the behavioural model calibrated to [32]
//! (see `baseline/`). Both sides are normalized by layout area.

use crate::baseline::{GemminiMode, GemminiModel};
use crate::compiler::GemmShape;
use crate::config::{Mechanisms, PlatformConfig};
use crate::coordinator::shard::{run_sweep, SweepOptions};
use crate::coordinator::JobRequest;
use crate::power::PowerModel;
use crate::util::table::{fmt_f, Table};

#[derive(Debug, Clone, Copy)]
pub struct Fig7Options {
    pub repeats: u32,
    pub workers: usize,
    /// Event-driven cycle skipping (cycle-exact; off only for
    /// differential checks).
    pub fast_forward: bool,
}

impl Default for Fig7Options {
    fn default() -> Self {
        Fig7Options { repeats: 10, workers: 0, fast_forward: true }
    }
}

#[derive(Debug, Clone)]
pub struct Fig7Point {
    pub size: usize,
    pub opengemm_gops_mm2: f64,
    pub gemmini_os_gops_mm2: f64,
    pub gemmini_ws_gops_mm2: f64,
    pub speedup_vs_os: f64,
    pub speedup_vs_ws: f64,
    pub opengemm_kernel_utilization: f64,
}

#[derive(Debug, Clone)]
pub struct Fig7Result {
    pub points: Vec<Fig7Point>,
}

/// The paper's sweep: square sizes from 8 to 128.
pub const SIZES: [usize; 5] = [8, 16, 32, 64, 128];

pub fn fig7_gemmini(cfg: &PlatformConfig, opts: Fig7Options) -> Fig7Result {
    let power = PowerModel::default();
    let area = power.layout_area(cfg);
    let gemmini = GemminiModel::default();
    let sweep_opts = SweepOptions {
        workers: opts.workers,
        fast_forward: opts.fast_forward,
        ..Default::default()
    };
    let requests: Vec<JobRequest> = SIZES
        .iter()
        .map(|&d| JobRequest::timing(GemmShape::new(d, d, d), Mechanisms::ALL, opts.repeats))
        .collect();
    let results = run_sweep(cfg, requests, sweep_opts).outcomes;

    let points = SIZES
        .iter()
        .zip(results)
        .map(|(&d, outcome)| {
            let r = outcome.expect("fig7 job failed");
            let shape = GemmShape::new(d, d, d);
            // steady-state kernel throughput: real ops over the kernel
            // window (config amortized by CPL across the repeats)
            let reps = r.metrics.runs_completed.max(1);
            let ops = shape.ops() * reps;
            let gops = ops as f64 / r.metrics.kernel_cycles.max(1) as f64
                * cfg.freq_mhz as f64
                * 1e6
                / 1e9;
            let og = gops / area;
            let os = gemmini.run(shape, GemminiMode::OutputStationary).gops_per_mm2;
            let ws = gemmini.run(shape, GemminiMode::WeightStationary).gops_per_mm2;
            Fig7Point {
                size: d,
                opengemm_gops_mm2: og,
                gemmini_os_gops_mm2: os,
                gemmini_ws_gops_mm2: ws,
                speedup_vs_os: og / os,
                speedup_vs_ws: og / ws,
                opengemm_kernel_utilization: r.metrics.kernel_utilization(),
            }
        })
        .collect();
    Fig7Result { points }
}

impl Fig7Result {
    pub fn speedup_range_os(&self) -> (f64, f64) {
        let mut lo = f64::INFINITY;
        let mut hi = 0.0f64;
        for p in &self.points {
            lo = lo.min(p.speedup_vs_os);
            hi = hi.max(p.speedup_vs_os);
        }
        (lo, hi)
    }

    pub fn speedup_range_ws(&self) -> (f64, f64) {
        let mut lo = f64::INFINITY;
        let mut hi = 0.0f64;
        for p in &self.points {
            lo = lo.min(p.speedup_vs_ws);
            hi = hi.max(p.speedup_vs_ws);
        }
        (lo, hi)
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str("## Fig. 7 — normalized throughput vs Gemmini (GOPS/mm^2)\n\n");
        let mut t = Table::new(&[
            "size", "OpenGeMM", "Gemmini-OS", "Gemmini-WS", "speedup vs OS", "speedup vs WS",
            "OG kernel util",
        ]);
        for p in &self.points {
            t.row(vec![
                format!("({0},{0},{0})", p.size),
                fmt_f(p.opengemm_gops_mm2, 1),
                fmt_f(p.gemmini_os_gops_mm2, 1),
                fmt_f(p.gemmini_ws_gops_mm2, 1),
                format!("{:.2}x", p.speedup_vs_os),
                format!("{:.2}x", p.speedup_vs_ws),
                fmt_f(p.opengemm_kernel_utilization, 3),
            ]);
        }
        out.push_str(&t.markdown());
        let (os_lo, os_hi) = self.speedup_range_os();
        let (ws_lo, ws_hi) = self.speedup_range_ws();
        out.push_str(&format!(
            "\nspeedup vs OS: {os_lo:.2}x..{os_hi:.2}x (paper 3.75x..16.40x) | \
             vs WS: {ws_lo:.2}x..{ws_hi:.2}x (paper 3.58x..15.66x)\n",
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn opengemm_wins_everywhere_in_paper_band() {
        let cfg = PlatformConfig::case_study();
        let res = fig7_gemmini(&cfg, Fig7Options { repeats: 10, workers: 0, fast_forward: true });
        for p in &res.points {
            assert!(
                p.speedup_vs_os > 1.5,
                "size {}: speedup vs OS only {:.2}",
                p.size,
                p.speedup_vs_os
            );
            assert!(p.speedup_vs_ws > 1.5, "size {} vs WS {:.2}", p.size, p.speedup_vs_ws);
        }
        // the band should overlap the paper's 3.58..16.40 range
        let (lo_os, hi_os) = res.speedup_range_os();
        assert!(hi_os > 3.0, "max speedup too small: {hi_os:.2}");
        assert!(lo_os < 20.0, "min speedup implausibly large: {lo_os:.2}");
        // large aligned GeMMs run near peak on OpenGeMM
        let p128 = res.points.last().unwrap();
        assert!(p128.opengemm_kernel_utilization > 0.9);
    }

    #[test]
    fn gemmini_improves_with_size_but_stays_low() {
        let cfg = PlatformConfig::case_study();
        let res = fig7_gemmini(&cfg, Fig7Options { repeats: 4, workers: 0, fast_forward: true });
        let first = res.points.first().unwrap();
        let last = res.points.last().unwrap();
        assert!(last.gemmini_ws_gops_mm2 > first.gemmini_ws_gops_mm2);
        // Gemmini stays far from its 497 GOPS/mm^2 peak (paper: ~6% TU)
        assert!(last.gemmini_ws_gops_mm2 < 100.0);
    }
}
