//! Functional datapath of the 3D MAC array (Fig. 3).
//!
//! The array is an `(Mu, Nu)` mesh of `Ku`-wide dot-product units. In one
//! cycle it consumes an A' tile `(Mu x Ku)` and a B' tile `(Ku x Nu)` and
//! accumulates into the `(Mu x Nu)` int32 accumulator register file
//! (output-stationary). Products and sums are two's-complement wrapping,
//! like the RTL (no saturation on the accumulate path).

use crate::config::GemmCoreParams;

/// The accumulator register file of the DotProd mesh.
#[derive(Debug, Clone)]
pub struct Accumulators {
    pub acc: Vec<i32>,
    mu: usize,
    nu: usize,
}

impl Accumulators {
    pub fn new(core: &GemmCoreParams) -> Accumulators {
        Accumulators {
            acc: vec![0; core.mu * core.nu],
            mu: core.mu,
            nu: core.nu,
        }
    }

    /// Hardware "accumulator reset" issued by the loop controller at
    /// k1 == 0.
    pub fn reset(&mut self) {
        self.acc.iter_mut().for_each(|v| *v = 0);
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> i32 {
        self.acc[i * self.nu + j]
    }

    /// Snapshot the accumulators as an output tile payload.
    pub fn snapshot(&self) -> Box<[i32]> {
        self.acc.clone().into_boxed_slice()
    }

    pub fn mu(&self) -> usize {
        self.mu
    }

    pub fn nu(&self) -> usize {
        self.nu
    }
}

/// One array cycle: `acc[i][j] += sum_k a[i][k] * b[k][j]`.
///
/// `a` is row-major `(Mu, Ku)`, `b` is row-major `(Ku, Nu)`. All `Ku`
/// products per DotProd are combinationally summed, exactly one result
/// update per accumulator per cycle.
pub fn tile_mac(acc: &mut Accumulators, core: &GemmCoreParams, a: &[i8], b: &[i8]) {
    let (mu, nu, ku) = (core.mu, core.nu, core.ku);
    debug_assert_eq!(a.len(), mu * ku, "A' tile size");
    debug_assert_eq!(b.len(), ku * nu, "B' tile size");
    for i in 0..mu {
        let arow = &a[i * ku..(i + 1) * ku];
        let accrow = &mut acc.acc[i * nu..(i + 1) * nu];
        for (k, &av) in arow.iter().enumerate() {
            if av == 0 {
                continue; // zero operand contributes nothing (incl. padding)
            }
            let av = av as i32;
            let brow = &b[k * nu..(k + 1) * nu];
            for (j, &bv) in brow.iter().enumerate() {
                accrow[j] = accrow[j].wrapping_add(av.wrapping_mul(bv as i32));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GemmCoreParams;
    use crate::util::check::property;

    fn core() -> GemmCoreParams {
        GemmCoreParams::CASE_STUDY
    }

    fn naive(a: &[i8], b: &[i8], mu: usize, nu: usize, ku: usize) -> Vec<i32> {
        let mut c = vec![0i32; mu * nu];
        for i in 0..mu {
            for j in 0..nu {
                for k in 0..ku {
                    c[i * nu + j] = c[i * nu + j]
                        .wrapping_add((a[i * ku + k] as i32).wrapping_mul(b[k * nu + j] as i32));
                }
            }
        }
        c
    }

    #[test]
    fn identity_tile() {
        let c = core();
        let mut acc = Accumulators::new(&c);
        let mut a = vec![0i8; 64];
        for i in 0..8 {
            a[i * 8 + i] = 1; // identity
        }
        let b: Vec<i8> = (0..64).map(|i| (i as i8).wrapping_mul(3)).collect();
        tile_mac(&mut acc, &c, &a, &b);
        for i in 0..8 {
            for j in 0..8 {
                assert_eq!(acc.at(i, j), b[i * 8 + j] as i32);
            }
        }
    }

    #[test]
    fn accumulation_across_cycles() {
        let c = core();
        let mut acc = Accumulators::new(&c);
        let a = vec![1i8; 64];
        let b = vec![1i8; 64];
        tile_mac(&mut acc, &c, &a, &b);
        tile_mac(&mut acc, &c, &a, &b);
        assert_eq!(acc.at(0, 0), 16); // 8 per cycle, 2 cycles
        acc.reset();
        assert_eq!(acc.at(0, 0), 0);
    }

    #[test]
    fn wrapping_semantics() {
        let mut p = core();
        p.ku = 1;
        let mut acc = Accumulators::new(&p);
        // pre-load near overflow by repeated max products
        let a = vec![i8::MIN; 8];
        let b = vec![i8::MIN; 8];
        // (-128)^2 = 16384; 131072 iterations exceed i32::MAX -> wraps
        for _ in 0..140_000 {
            tile_mac(&mut acc, &p, &a, &b);
        }
        // must not panic; value defined by wrapping arithmetic
        let expect = (16384i64 * 140_000) as i128;
        let wrapped = (expect % (1i128 << 32)) as i64;
        let wrapped = if wrapped > i32::MAX as i64 { wrapped - (1i64 << 32) } else { wrapped };
        assert_eq!(acc.at(0, 0) as i64, wrapped);
    }

    #[test]
    fn matches_naive_reference() {
        property("tile_mac vs naive", 40, |rng| {
            let c = core();
            let mut a = vec![0i8; c.mu * c.ku];
            let mut b = vec![0i8; c.ku * c.nu];
            rng.fill_i8(&mut a);
            rng.fill_i8(&mut b);
            let mut acc = Accumulators::new(&c);
            tile_mac(&mut acc, &c, &a, &b);
            let want = naive(&a, &b, c.mu, c.nu, c.ku);
            crate::prop_assert_eq!(acc.acc, want, "tile MAC mismatch");
            Ok(())
        });
    }

    #[test]
    fn non_square_generator_instance() {
        let p = GemmCoreParams { mu: 4, nu: 2, ku: 16, ..GemmCoreParams::CASE_STUDY };
        let mut acc = Accumulators::new(&p);
        let a: Vec<i8> = (0..64).map(|i| (i % 5) as i8 - 2).collect();
        let b: Vec<i8> = (0..32).map(|i| (i % 7) as i8 - 3).collect();
        tile_mac(&mut acc, &p, &a, &b);
        assert_eq!(acc.acc, naive(&a, &b, 4, 2, 16));
    }
}
