//! Utilization accounting (Sec. 4.2/4.3 definitions).
//!
//! - **Spatial utilization (SU)**: real MACs over array-slot MACs burned
//!   (padding waste), a static property of the tiling.
//! - **Temporal utilization (TU)**: array-active cycles over total
//!   cycles (config exposure, memory stalls, drain).
//! - **Overall utilization (OU)**: SU x TU — fraction of peak MACs
//!   actually used.

use crate::gemm_core::StallReason;
use crate::spm::SpmStats;
use crate::util::json::{self, Json};

/// Cycle-level counters accumulated by one simulation.
///
/// Equality intentionally excludes the `ff_*` observability counters
/// (see the manual `PartialEq` below): they describe how the engine
/// *got* to the result, not the result, and necessarily differ between
/// the fast-forward and lockstep engines whose bit-identity the
/// differential tests assert.
#[derive(Debug, Default, Clone)]
pub struct SimMetrics {
    /// Total platform cycles from program start to full drain.
    pub total_cycles: u64,
    /// Cycles the MAC array issued a tile-MAC.
    pub compute_cycles: u64,
    /// Core started but starved on the A streamer.
    pub stall_input_a: u64,
    /// Core started but starved on the B streamer.
    pub stall_input_b: u64,
    /// Core started but blocked on the output buffer.
    pub stall_output: u64,
    /// Core idle (configuration exposure, inter-run gaps, drain).
    pub idle_cycles: u64,
    /// Accelerator runs launched / completed.
    pub starts: u64,
    pub runs_completed: u64,
    /// Sum over runs of (completion cycle - start cycle): the kernel
    /// window, excluding host configuration gaps between runs. This is
    /// the "accelerator busy window" view used for throughput
    /// comparisons (Fig. 7), where configuration is amortized or
    /// excluded by measurement.
    pub kernel_cycles: u64,
    /// Host instructions retired.
    pub host_instret: u64,
    /// Host cycles stalled on accelerator-CSR handshakes.
    pub host_csr_stall: u64,
    /// SPM traffic stats snapshot.
    pub spm: SpmStats,
    /// Fast-forward jumps taken (engine observability; wire-excluded
    /// and equality-excluded, like the coordinator's cache counters).
    pub ff_jumps: u64,
    /// Cycles skipped by fast-forward jumps (wire/equality-excluded).
    pub ff_skipped_cycles: u64,
}

impl PartialEq for SimMetrics {
    fn eq(&self, other: &Self) -> bool {
        // every field except ff_jumps / ff_skipped_cycles
        self.total_cycles == other.total_cycles
            && self.compute_cycles == other.compute_cycles
            && self.stall_input_a == other.stall_input_a
            && self.stall_input_b == other.stall_input_b
            && self.stall_output == other.stall_output
            && self.idle_cycles == other.idle_cycles
            && self.starts == other.starts
            && self.runs_completed == other.runs_completed
            && self.kernel_cycles == other.kernel_cycles
            && self.host_instret == other.host_instret
            && self.host_csr_stall == other.host_csr_stall
            && self.spm == other.spm
    }
}

impl Eq for SimMetrics {}

impl SimMetrics {
    pub fn stall_cycles(&self) -> u64 {
        self.stall_input_a + self.stall_input_b + self.stall_output
    }

    /// Bulk-account `n` skipped *stalled* cycles (fast-forward engine):
    /// equivalent to `n` lockstep cycles in which the core reported the
    /// same stall reason. Does not touch `total_cycles` — the caller
    /// advances the clock.
    pub fn add_stalls(&mut self, reason: StallReason, n: u64) {
        match reason {
            StallReason::InputA => self.stall_input_a += n,
            StallReason::InputB => self.stall_input_b += n,
            StallReason::Output => self.stall_output += n,
        }
    }

    /// Bulk-account `n` skipped *idle* cycles (fast-forward engine).
    pub fn add_idle(&mut self, n: u64) {
        self.idle_cycles += n;
    }

    /// Bulk-account `n` skipped host-CSR-stall cycles (fast-forward
    /// engine).
    pub fn add_host_csr_stalls(&mut self, n: u64) {
        self.host_csr_stall += n;
    }

    /// Temporal utilization.
    pub fn temporal_utilization(&self) -> f64 {
        if self.total_cycles == 0 {
            return 0.0;
        }
        self.compute_cycles as f64 / self.total_cycles as f64
    }

    /// Kernel-window temporal utilization (config excluded).
    pub fn kernel_utilization(&self) -> f64 {
        if self.kernel_cycles == 0 {
            return 0.0;
        }
        self.compute_cycles as f64 / self.kernel_cycles as f64
    }

    /// Wire encoding (sharded-sweep result files): every counter is
    /// carried, so a deserialized result is indistinguishable from one
    /// simulated in-process. The `ff_*` engine-observability counters
    /// are excluded: they are a property of the simulating process, not
    /// of the simulated platform.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("total_cycles", Json::num(self.total_cycles as f64)),
            ("compute_cycles", Json::num(self.compute_cycles as f64)),
            ("stall_input_a", Json::num(self.stall_input_a as f64)),
            ("stall_input_b", Json::num(self.stall_input_b as f64)),
            ("stall_output", Json::num(self.stall_output as f64)),
            ("idle_cycles", Json::num(self.idle_cycles as f64)),
            ("starts", Json::num(self.starts as f64)),
            ("runs_completed", Json::num(self.runs_completed as f64)),
            ("kernel_cycles", Json::num(self.kernel_cycles as f64)),
            ("host_instret", Json::num(self.host_instret as f64)),
            ("host_csr_stall", Json::num(self.host_csr_stall as f64)),
            ("spm", self.spm.to_json()),
        ])
    }

    pub fn from_json(v: &Json) -> Result<SimMetrics, String> {
        Ok(SimMetrics {
            total_cycles: json::get_u64(v, "total_cycles")?,
            compute_cycles: json::get_u64(v, "compute_cycles")?,
            stall_input_a: json::get_u64(v, "stall_input_a")?,
            stall_input_b: json::get_u64(v, "stall_input_b")?,
            stall_output: json::get_u64(v, "stall_output")?,
            idle_cycles: json::get_u64(v, "idle_cycles")?,
            starts: json::get_u64(v, "starts")?,
            runs_completed: json::get_u64(v, "runs_completed")?,
            kernel_cycles: json::get_u64(v, "kernel_cycles")?,
            host_instret: json::get_u64(v, "host_instret")?,
            host_csr_stall: json::get_u64(v, "host_csr_stall")?,
            spm: SpmStats::from_json(json::get(v, "spm")?)?,
            ff_jumps: 0,
            ff_skipped_cycles: 0,
        })
    }
}

/// Final per-job report.
#[derive(Debug, Clone, PartialEq)]
pub struct UtilizationReport {
    pub spatial: f64,
    pub temporal: f64,
    pub overall: f64,
    pub total_cycles: u64,
    pub compute_cycles: u64,
}

impl UtilizationReport {
    pub fn from_metrics(su: f64, m: &SimMetrics) -> UtilizationReport {
        let tu = m.temporal_utilization();
        UtilizationReport {
            spatial: su,
            temporal: tu,
            overall: su * tu,
            total_cycles: m.total_cycles,
            compute_cycles: m.compute_cycles,
        }
    }

    /// Achieved GOPS at a clock frequency, given real ops executed.
    pub fn achieved_gops(&self, real_ops: u64, freq_mhz: u64) -> f64 {
        if self.total_cycles == 0 {
            return 0.0;
        }
        real_ops as f64 / self.total_cycles as f64 * freq_mhz as f64 * 1e6 / 1e9
    }

    /// Wire encoding. The derived `f64` ratios are carried verbatim
    /// (not recomputed on decode) and round-trip bit-identically via
    /// shortest-round-trip formatting.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("spatial", Json::num(self.spatial)),
            ("temporal", Json::num(self.temporal)),
            ("overall", Json::num(self.overall)),
            ("total_cycles", Json::num(self.total_cycles as f64)),
            ("compute_cycles", Json::num(self.compute_cycles as f64)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<UtilizationReport, String> {
        Ok(UtilizationReport {
            spatial: json::get_f64(v, "spatial")?,
            temporal: json::get_f64(v, "temporal")?,
            overall: json::get_f64(v, "overall")?,
            total_cycles: json::get_u64(v, "total_cycles")?,
            compute_cycles: json::get_u64(v, "compute_cycles")?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tu_and_ou() {
        let m = SimMetrics { total_cycles: 1000, compute_cycles: 800, ..Default::default() };
        let r = UtilizationReport::from_metrics(0.9, &m);
        assert!((r.temporal - 0.8).abs() < 1e-12);
        assert!((r.overall - 0.72).abs() < 1e-12);
    }

    #[test]
    fn bulk_increments_match_lockstep_sums() {
        let mut bulk = SimMetrics::default();
        bulk.add_stalls(StallReason::InputA, 3);
        bulk.add_stalls(StallReason::Output, 2);
        bulk.add_idle(4);
        bulk.add_host_csr_stalls(5);
        let mut lock = SimMetrics::default();
        for _ in 0..3 {
            lock.stall_input_a += 1;
        }
        for _ in 0..2 {
            lock.stall_output += 1;
        }
        for _ in 0..4 {
            lock.idle_cycles += 1;
        }
        for _ in 0..5 {
            lock.host_csr_stall += 1;
        }
        assert_eq!(bulk, lock);
        assert_eq!(bulk.stall_cycles(), 5);
    }

    #[test]
    fn ff_counters_excluded_from_eq_and_wire() {
        let mut a = SimMetrics { total_cycles: 10, ..Default::default() };
        let b = a.clone();
        a.ff_jumps = 7;
        a.ff_skipped_cycles = 123;
        assert_eq!(a, b, "ff counters must not affect equality");
        let text = a.to_json().pretty();
        assert!(!text.contains("ff_jumps") && !text.contains("ff_skipped_cycles"));
        let back = SimMetrics::from_json(&crate::util::json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, a);
        assert_eq!(back.ff_jumps, 0, "wire round-trip drops engine counters");
    }

    #[test]
    fn empty_metrics_zero_tu() {
        let m = SimMetrics::default();
        assert_eq!(m.temporal_utilization(), 0.0);
    }

    #[test]
    fn gops_math() {
        let r = UtilizationReport {
            spatial: 1.0,
            temporal: 1.0,
            overall: 1.0,
            total_cycles: 1000,
            compute_cycles: 1000,
        };
        // 1000 cycles at 200 MHz executing 1024*1000 ops:
        // ops/s = 1024 * 200e6 -> 204.8 GOPS
        let gops = r.achieved_gops(1024 * 1000, 200);
        assert!((gops - 204.8).abs() < 1e-9);
    }
}
