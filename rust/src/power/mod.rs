//! Analytical area & power model (Sec. 4.4, Fig. 6, Table 3).
//!
//! We cannot re-run Synopsys DC / PrimeTime on TSMC 16nm, so we rebuild
//! the *model*: per-component area/power terms that scale with the
//! generator parameters, anchored so the paper's case-study instance
//! (8x8x8 core, 270 KiB SPM, 200 MHz, 0.675 V) reproduces the published
//! operating point — 0.531 mm^2 cell area, 43.8 mW total power, and the
//! Fig. 6 breakdown percentages. DSE sweeps then expose the same trends
//! (bigger arrays grow the core share, more banks grow the SPM share).
//!
//! Published anchors (Fig. 6):
//! - area: SPM+interconnect 63.47%, GeMM core 11.86%, streamers 2.26%,
//!   RISC-V host 1.13%, remainder (icache, DMA, CSR, misc) 21.28%
//! - power: SPM 41.90%, icache 17.06%, GeMM core 13.18%, streamers
//!   6.50%, host 2.40%, remainder 18.96%

use crate::config::PlatformConfig;

/// Published case-study anchors.
pub const ANCHOR_AREA_MM2: f64 = 0.531;
pub const ANCHOR_POWER_MW: f64 = 43.8;
/// Cell -> layout scaling used by Table 3 (placement & routing estimate
/// "with 60% cell density according to [27]"): 0.531 -> 0.62 mm^2.
pub const LAYOUT_FACTOR: f64 = 0.62 / 0.531;

/// Fig. 6 area shares of the case-study instance.
const A_SPM: f64 = 0.6347;
const A_CORE: f64 = 0.1186;
const A_STREAMER: f64 = 0.0226;
const A_HOST: f64 = 0.0113;
const A_ICACHE: f64 = 0.08;
const A_DMA: f64 = 0.06;
// remainder: CSR manager + misc glue
const A_OTHER: f64 = 1.0 - A_SPM - A_CORE - A_STREAMER - A_HOST - A_ICACHE - A_DMA;

/// Fig. 6 power shares of the case-study instance.
const P_SPM: f64 = 0.4190;
const P_ICACHE: f64 = 0.1706;
const P_CORE: f64 = 0.1318;
const P_STREAMER: f64 = 0.0650;
const P_HOST: f64 = 0.0240;
const P_OTHER: f64 = 1.0 - P_SPM - P_ICACHE - P_CORE - P_STREAMER - P_HOST;

/// A per-component breakdown (same categories as Fig. 6).
#[derive(Debug, Clone, PartialEq)]
pub struct Breakdown {
    pub spm: f64,
    pub gemm_core: f64,
    pub streamers: f64,
    pub host: f64,
    pub icache: f64,
    pub dma: f64,
    pub other: f64,
}

impl Breakdown {
    pub fn total(&self) -> f64 {
        self.spm + self.gemm_core + self.streamers + self.host + self.icache + self.dma + self.other
    }

    pub fn entries(&self) -> Vec<(&'static str, f64)> {
        vec![
            ("multi-banked SPM", self.spm),
            ("GeMM core", self.gemm_core),
            ("data streamers", self.streamers),
            ("RISC-V host", self.host),
            ("instruction cache", self.icache),
            ("DMA", self.dma),
            ("other (CSR, glue)", self.other),
        ]
    }

    pub fn percentages(&self) -> Vec<(&'static str, f64)> {
        let t = self.total();
        self.entries().into_iter().map(|(n, v)| (n, 100.0 * v / t)).collect()
    }
}

/// The analytical model.
#[derive(Debug, Clone, Copy)]
pub struct PowerModel {
    /// Anchor instance (the paper's Table 1 case study).
    anchor: AnchorScales,
}

#[derive(Debug, Clone, Copy)]
struct AnchorScales {
    /// mm^2 per SPM KiB (incl. interconnect share).
    area_per_spm_kib: f64,
    /// mm^2 per MAC (incl. accumulator share, at 8-bit operands).
    area_per_mac: f64,
    /// mm^2 per streamer buffer byte.
    area_per_buf_byte: f64,
    /// fixed blocks (host, icache, dma, other), mm^2.
    area_host: f64,
    area_icache: f64,
    area_dma: f64,
    area_other: f64,
    /// mW per (SPM KiB) at the anchor's access activity & frequency.
    power_per_spm_kib: f64,
    /// mW per MAC at 100% utilization, anchor frequency.
    power_per_mac: f64,
    power_per_buf_byte: f64,
    power_host: f64,
    power_icache: f64,
    power_dma_other: f64,
    anchor_freq_mhz: f64,
}

impl Default for PowerModel {
    fn default() -> Self {
        let cfg = PlatformConfig::case_study();
        let spm_kib = cfg.mem.capacity_bytes() as f64 / 1024.0;
        let macs = cfg.core.macs_per_cycle() as f64;
        let buf_bytes = (cfg.mem.d_stream
            * (cfg.core.a_tile_bytes() + cfg.core.b_tile_bytes() + cfg.core.c_tile_bytes()))
            as f64;
        // the paper's power workload runs near-full utilization; treat
        // the anchor power as utilization ~1.0 at 200 MHz.
        PowerModel {
            anchor: AnchorScales {
                area_per_spm_kib: ANCHOR_AREA_MM2 * A_SPM / spm_kib,
                area_per_mac: ANCHOR_AREA_MM2 * A_CORE / macs,
                area_per_buf_byte: ANCHOR_AREA_MM2 * A_STREAMER / buf_bytes,
                area_host: ANCHOR_AREA_MM2 * A_HOST,
                area_icache: ANCHOR_AREA_MM2 * A_ICACHE,
                area_dma: ANCHOR_AREA_MM2 * A_DMA,
                area_other: ANCHOR_AREA_MM2 * A_OTHER,
                power_per_spm_kib: ANCHOR_POWER_MW * P_SPM / spm_kib,
                power_per_mac: ANCHOR_POWER_MW * P_CORE / macs,
                power_per_buf_byte: ANCHOR_POWER_MW * P_STREAMER / buf_bytes,
                power_host: ANCHOR_POWER_MW * P_HOST,
                power_icache: ANCHOR_POWER_MW * P_ICACHE,
                power_dma_other: ANCHOR_POWER_MW * P_OTHER,
                anchor_freq_mhz: 200.0,
            },
        }
    }
}

impl PowerModel {
    /// Cell-area breakdown of an instance (mm^2).
    pub fn area(&self, cfg: &PlatformConfig) -> Breakdown {
        let a = &self.anchor;
        let spm_kib = cfg.mem.capacity_bytes() as f64 / 1024.0;
        let macs = cfg.core.macs_per_cycle() as f64;
        let buf_bytes = (cfg.mem.d_stream
            * (cfg.core.a_tile_bytes() + cfg.core.b_tile_bytes() + cfg.core.c_tile_bytes()))
            as f64;
        Breakdown {
            spm: a.area_per_spm_kib * spm_kib,
            gemm_core: a.area_per_mac * macs,
            streamers: a.area_per_buf_byte * buf_bytes,
            host: a.area_host,
            icache: a.area_icache,
            dma: a.area_dma,
            other: a.area_other,
        }
    }

    /// Total cell area (mm^2).
    pub fn total_area(&self, cfg: &PlatformConfig) -> f64 {
        self.area(cfg).total()
    }

    /// Layout (post-P&R) area used for area-normalized metrics.
    pub fn layout_area(&self, cfg: &PlatformConfig) -> f64 {
        self.total_area(cfg) * LAYOUT_FACTOR
    }

    /// Power breakdown (mW) at `utilization` (overall array utilization
    /// of the running workload; dynamic terms scale with it, static and
    /// host/icache terms do not).
    pub fn power(&self, cfg: &PlatformConfig, utilization: f64) -> Breakdown {
        let a = &self.anchor;
        let f_scale = cfg.freq_mhz as f64 / a.anchor_freq_mhz;
        let u = utilization.clamp(0.0, 1.0);
        let spm_kib = cfg.mem.capacity_bytes() as f64 / 1024.0;
        let macs = cfg.core.macs_per_cycle() as f64;
        let buf_bytes = (cfg.mem.d_stream
            * (cfg.core.a_tile_bytes() + cfg.core.b_tile_bytes() + cfg.core.c_tile_bytes()))
            as f64;
        // dynamic components scale with utilization and frequency; the
        // static floor is ~15% of anchor component power (16nm FFC at
        // 0.675 V is leakage-light).
        let dyn_scale = (0.15 + 0.85 * u) * f_scale;
        Breakdown {
            spm: a.power_per_spm_kib * spm_kib * dyn_scale,
            gemm_core: a.power_per_mac * macs * dyn_scale,
            streamers: a.power_per_buf_byte * buf_bytes * dyn_scale,
            host: a.power_host * f_scale,
            icache: a.power_icache * f_scale,
            dma: a.power_dma_other * 0.5 * f_scale,
            other: a.power_dma_other * 0.5 * f_scale,
        }
    }

    /// Total power (mW).
    pub fn total_power(&self, cfg: &PlatformConfig, utilization: f64) -> f64 {
        self.power(cfg, utilization).total()
    }

    /// System efficiency in TOPS/W at peak performance (the paper's
    /// headline: 204.8 GOPS / 43.8 mW = 4.68 TOPS/W).
    pub fn tops_per_watt(&self, cfg: &PlatformConfig, utilization: f64) -> f64 {
        let gops = cfg.peak_gops();
        gops / self.total_power(cfg, utilization)
    }
}

/// One row of the Table 3 SotA comparison.
#[derive(Debug, Clone)]
pub struct SotaRow {
    pub name: &'static str,
    pub tech_nm: u32,
    pub area_mm2: f64,
    pub memory_kib: u32,
    pub freq_mhz: u32,
    pub peak_gops: f64,
    pub peak_tops_w: Option<f64>,
    pub precision: &'static str,
    pub open_source: bool,
    pub generated: bool,
}

impl SotaRow {
    pub fn gops_per_mm2(&self) -> f64 {
        self.peak_gops / self.area_mm2
    }

    pub fn op_area_eff(&self) -> Option<f64> {
        self.peak_tops_w.map(|t| t / self.area_mm2)
    }
}

/// Published rows of Table 3 (8-bit numbers where multi-precision).
pub fn sota_published() -> Vec<SotaRow> {
    vec![
        SotaRow { name: "SIGMA", tech_nm: 28, area_mm2: 65.0, memory_kib: 6000, freq_mhz: 500, peak_gops: 16000.0, peak_tops_w: Some(0.48), precision: "BFP16/FP32", open_source: true, generated: false },
        SotaRow { name: "CONNA", tech_nm: 65, area_mm2: 2.36, memory_kib: 144, freq_mhz: 200, peak_gops: 102.4, peak_tops_w: Some(0.856), precision: "INT4/8/16/32", open_source: false, generated: true },
        SotaRow { name: "Gemmini", tech_nm: 22, area_mm2: 1.03, memory_kib: 256, freq_mhz: 1000, peak_gops: 512.0, peak_tops_w: None, precision: "INT8", open_source: true, generated: true },
        SotaRow { name: "DIANA(Dig.)", tech_nm: 22, area_mm2: 8.91, memory_kib: 512, freq_mhz: 280, peak_gops: 224.0, peak_tops_w: Some(1.7), precision: "INT8", open_source: true, generated: false },
        SotaRow { name: "RBE", tech_nm: 22, area_mm2: 2.42, memory_kib: 128, freq_mhz: 420, peak_gops: 91.0, peak_tops_w: Some(0.74), precision: "INT2/4/8", open_source: true, generated: false },
        SotaRow { name: "RedMule", tech_nm: 22, area_mm2: 0.73, memory_kib: 128, freq_mhz: 470, peak_gops: 89.0, peak_tops_w: Some(1.6), precision: "FP8/16", open_source: true, generated: false },
    ]
}

/// Our modeled OpenGeMM row.
pub fn opengemm_row(model: &PowerModel, cfg: &PlatformConfig) -> SotaRow {
    // Table 3 reports the layout-estimated area and the power measured
    // on the (32,32,32) block workload (near-full utilization).
    SotaRow {
        name: "OpenGeMM",
        tech_nm: 16,
        area_mm2: model.layout_area(cfg),
        memory_kib: (cfg.mem.capacity_bytes() / 1024) as u32,
        freq_mhz: cfg.freq_mhz as u32,
        peak_gops: cfg.peak_gops(),
        peak_tops_w: Some(model.tops_per_watt(cfg, 1.0)),
        precision: "INT2/4/8*",
        open_source: true,
        generated: true,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (PowerModel, PlatformConfig) {
        (PowerModel::default(), PlatformConfig::case_study())
    }

    #[test]
    fn anchor_reproduces_published_area() {
        let (m, cfg) = setup();
        assert!((m.total_area(&cfg) - ANCHOR_AREA_MM2).abs() < 1e-9);
        let pct = m.area(&cfg).percentages();
        let spm = pct.iter().find(|(n, _)| n.contains("SPM")).unwrap().1;
        assert!((spm - 63.47).abs() < 0.1, "SPM area share {spm}");
        let core = pct.iter().find(|(n, _)| n.contains("GeMM")).unwrap().1;
        assert!((core - 11.86).abs() < 0.1);
    }

    #[test]
    fn anchor_reproduces_published_power_and_efficiency() {
        let (m, cfg) = setup();
        let total = m.total_power(&cfg, 1.0);
        assert!((total - ANCHOR_POWER_MW).abs() < 1e-6, "total {total}");
        let eff = m.tops_per_watt(&cfg, 1.0);
        assert!((eff - 4.675).abs() < 0.02, "TOPS/W {eff}");
        let pct = m.power(&cfg, 1.0).percentages();
        let spm = pct.iter().find(|(n, _)| n.contains("SPM")).unwrap().1;
        assert!((spm - 41.90).abs() < 0.1, "SPM power share {spm}");
    }

    #[test]
    fn layout_area_matches_table3() {
        let (m, cfg) = setup();
        assert!((m.layout_area(&cfg) - 0.62).abs() < 0.005);
        let row = opengemm_row(&m, &cfg);
        assert!((row.gops_per_mm2() - 329.0).abs() < 5.0, "{}", row.gops_per_mm2());
        assert!((row.op_area_eff().unwrap() - 7.55).abs() < 0.15);
    }

    #[test]
    fn idle_power_below_full_power() {
        let (m, cfg) = setup();
        assert!(m.total_power(&cfg, 0.0) < m.total_power(&cfg, 1.0) * 0.6);
    }

    #[test]
    fn bigger_array_grows_core_share() {
        let (m, mut cfg) = setup();
        cfg.core.mu = 16;
        cfg.core.nu = 16;
        cfg.mem.r_mem = 32; // keep config valid
        cfg.mem.w_mem = 128;
        let base_share = {
            let c = PlatformConfig::case_study();
            let b = m.area(&c);
            b.gemm_core / b.total()
        };
        let b = m.area(&cfg);
        assert!(b.gemm_core / b.total() > base_share * 2.0);
    }

    #[test]
    fn sota_table_has_opengemm_best_op_area_eff_int8() {
        let (m, cfg) = setup();
        let ours = opengemm_row(&m, &cfg);
        for row in sota_published() {
            if let Some(e) = row.op_area_eff() {
                assert!(
                    ours.op_area_eff().unwrap() > e,
                    "{} beats us: {e} vs {:?}",
                    row.name,
                    ours.op_area_eff()
                );
            }
        }
    }

    #[test]
    fn frequency_scales_power() {
        let (m, mut cfg) = setup();
        let p200 = m.total_power(&cfg, 1.0);
        cfg.freq_mhz = 400;
        let p400 = m.total_power(&cfg, 1.0);
        assert!((p400 / p200 - 2.0).abs() < 1e-9);
    }
}
