//! Fault-injection coverage for the cross-host sweep scheduler: a
//! `FlakyTransport` that drops, delays, duplicates (via straggler
//! speculation) and corrupts shard results must still yield a merged
//! sweep byte-identical to the unsharded `Coordinator::run_batch`, and
//! exhausted retries must fail loudly with the failing shard's full
//! error chain. A spool-directory round trip (driver + executor loop
//! over a shared directory, one injected transient failure) pins the
//! same guarantee for the cross-host transport.

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use opengemm::compiler::GemmShape;
use opengemm::config::{Mechanisms, PlatformConfig};
use opengemm::coordinator::dispatch::{
    dispatch_plan, spool_worker_loop, CancelFlag, DispatchOptions, FaultInjector, InProcess,
    SpoolDir, SpoolWorkerOptions, Transport,
};
use opengemm::coordinator::shard::{Shard, ShardResult, SweepOptions, SweepPlan, SweepResult};
use opengemm::coordinator::{Coordinator, JobRequest};
use opengemm::util::rng::Pcg32;

fn requests(n: usize) -> Vec<JobRequest> {
    let mut rng = Pcg32::seeded(0xD15);
    (0..n)
        .map(|i| {
            let shape = GemmShape::new(8 + 8 * (i % 4), 8 + 8 * (i % 3), 8 + 8 * (i % 2));
            let mech = if i % 2 == 0 { Mechanisms::ALL } else { Mechanisms::CPL_BUF };
            let operands = if i % 3 == 0 {
                let mut a = vec![0i8; shape.m * shape.k];
                let mut b = vec![0i8; shape.k * shape.n];
                rng.fill_i8(&mut a);
                rng.fill_i8(&mut b);
                Some((a, b))
            } else {
                None
            };
            let layout = if mech.strided_layout {
                opengemm::compiler::Layout::TiledInterleaved
            } else {
                opengemm::compiler::Layout::TiledContiguous
            };
            JobRequest { shape, layout, mechanisms: mech, repeats: 1 + (i % 2) as u32, operands }
        })
        .collect()
}

fn plan(shards: usize, jobs: usize) -> SweepPlan {
    let cfg = PlatformConfig::case_study();
    let opts = SweepOptions { shards, workers: 1, ..Default::default() };
    SweepPlan::stride(&cfg, requests(jobs), opts)
}

/// The ground truth every dispatch must reproduce byte-for-byte.
fn unsharded_json(jobs: usize) -> String {
    let cfg = PlatformConfig::case_study();
    let coord = Coordinator::new(cfg).with_workers(1);
    let outcomes = coord.run_batch(requests(jobs));
    SweepResult { outcomes, stats: coord.stats() }.to_json().pretty()
}

/// What the flaky transport does to one (shard, attempt) dispatch.
#[derive(Clone, Copy, PartialEq)]
enum Fault {
    /// Return a transport error without producing a result.
    Drop,
    /// Sleep before answering (straggler bait).
    DelayMs(u64),
    /// Return a structurally corrupt result (wrong shard index).
    CorruptIndex,
    /// Return a result whose index cover does not match the shard.
    CorruptCover,
}

/// Deterministically misbehaving transport: a scripted fault per
/// (shard_index, attempt); unscripted dispatches run in-process.
struct FlakyTransport {
    script: Mutex<Vec<(usize, u32, Fault)>>,
}

impl FlakyTransport {
    fn new(script: Vec<(usize, u32, Fault)>) -> FlakyTransport {
        FlakyTransport { script: Mutex::new(script) }
    }
}

impl Transport for FlakyTransport {
    fn dispatch(
        &self,
        shard: &Shard,
        attempt: u32,
        cancel: &CancelFlag,
    ) -> Result<ShardResult, String> {
        let fault = {
            let script = self.script.lock().unwrap();
            script
                .iter()
                .find(|&&(s, a, _)| s == shard.shard_index && a == attempt)
                .map(|&(_, _, f)| f)
        };
        match fault {
            Some(Fault::Drop) => {
                Err(format!("flaky: dropped shard {} attempt {attempt}", shard.shard_index))
            }
            Some(Fault::DelayMs(ms)) => {
                std::thread::sleep(Duration::from_millis(ms));
                InProcess.dispatch(shard, attempt, cancel)
            }
            Some(Fault::CorruptIndex) => {
                let mut result = InProcess.dispatch(shard, attempt, cancel)?;
                result.shard_index = result.shard_index.wrapping_add(7);
                Ok(result)
            }
            Some(Fault::CorruptCover) => {
                let mut result = InProcess.dispatch(shard, attempt, cancel)?;
                result.indices.reverse();
                Ok(result)
            }
            None => InProcess.dispatch(shard, attempt, cancel),
        }
    }

    fn name(&self) -> &'static str {
        "flaky"
    }
}

#[test]
fn flaky_transport_still_merges_byte_identical() {
    const JOBS: usize = 10;
    let want = unsharded_json(JOBS);
    // shard 0: dropped twice, succeeds on the 3rd try;
    // shard 1: corrupt index once, then clean;
    // shard 2: corrupt cover once, then clean;
    // shard 3: clean from the start.
    let transport = FlakyTransport::new(vec![
        (0, 0, Fault::Drop),
        (0, 1, Fault::Drop),
        (1, 0, Fault::CorruptIndex),
        (2, 0, Fault::CorruptCover),
    ]);
    let opts = DispatchOptions { max_retries: 2, concurrency: 4, ..Default::default() };
    let (got, report) = dispatch_plan(plan(4, JOBS), &transport, &opts).unwrap();
    assert_eq!(got.to_json().pretty(), want, "merged JSON byte-identical under faults");
    assert_eq!(report.retries, 4, "2 drops + 2 corruptions all retried");
    let corrupt_errors = report
        .attempts
        .iter()
        .filter(|a| {
            a.error.as_deref().is_some_and(|e| {
                e.contains("returned shard") || e.contains("mismatched indices")
            })
        })
        .count();
    assert_eq!(corrupt_errors, 2, "both corruptions surfaced as validation failures");
}

#[test]
fn straggler_is_redispatched_and_duplicate_discarded() {
    const JOBS: usize = 8;
    let want = unsharded_json(JOBS);
    // Shard 0's first attempt sleeps for 2s — far beyond any multiple
    // of the other shards' wall times — so the scheduler speculates a
    // second copy; the fast copy wins and the sleeper's (identical)
    // result is discarded by shard_index.
    let transport = FlakyTransport::new(vec![(0, 0, Fault::DelayMs(2000))]);
    let opts = DispatchOptions {
        max_retries: 0,
        straggler_factor: 3.0,
        concurrency: 4,
        poll: Duration::from_millis(5),
    };
    let (got, report) = dispatch_plan(plan(4, JOBS), &transport, &opts).unwrap();
    assert_eq!(got.to_json().pretty(), want, "speculation must not change the bytes");
    assert_eq!(report.speculative_dispatches, 1, "exactly one straggler speculated");
    assert_eq!(report.duplicates_discarded, 1, "the slow twin's result was discarded");
    let spec = report
        .attempts
        .iter()
        .find(|a| a.speculative)
        .expect("a speculative attempt is on record");
    assert_eq!(spec.shard_index, 0);
}

#[test]
fn exhausted_retries_fail_loudly_with_the_error_chain() {
    let transport = FlakyTransport::new(vec![
        (1, 0, Fault::Drop),
        (1, 1, Fault::CorruptIndex),
        (1, 2, Fault::Drop),
    ]);
    let opts = DispatchOptions { max_retries: 2, concurrency: 2, ..Default::default() };
    let err = dispatch_plan(plan(3, 9), &transport, &opts).unwrap_err();
    assert!(err.contains("shard 1 failed after 3 attempt(s)"), "{err}");
    assert!(err.contains("attempt 0: flaky: dropped shard 1 attempt 0"), "{err}");
    assert!(err.contains("attempt 1: transport returned shard 8 for shard 1"), "{err}");
    assert!(err.contains("attempt 2: flaky: dropped shard 1 attempt 2"), "{err}");
}

#[test]
fn spool_roundtrip_with_transient_failure_is_byte_identical() {
    const JOBS: usize = 6;
    let want = unsharded_json(JOBS);
    let dir = std::env::temp_dir().join(format!("opengemm-spool-test-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();

    let stop = AtomicBool::new(false);
    let got_json = std::thread::scope(|scope| {
        // executor side: the same loop `opengemm sweep --spool-serve`
        // runs, here on a thread instead of another host
        let worker = scope.spawn(|| {
            let opts = SpoolWorkerOptions { poll: Duration::from_millis(5), ..Default::default() };
            spool_worker_loop(&dir, &opts, &stop).unwrap()
        });
        // driver side: spool transport with one injected transient
        // failure, healed by a single retry
        let poll = Duration::from_millis(5);
        let spool = SpoolDir::new(&dir, "t_", poll, Duration::from_secs(60)).unwrap();
        let transport = FaultInjector::new(spool, vec![1], 1);
        let opts = DispatchOptions { max_retries: 1, concurrency: 3, ..Default::default() };
        let (got, report) = dispatch_plan(plan(3, JOBS), &transport, &opts).unwrap();
        assert_eq!(report.retries, 1, "the injected fault burned exactly one retry");
        stop.store(true, Ordering::Relaxed);
        let served = worker.join().unwrap();
        assert_eq!(served, 3, "every shard ran through the spool directory");
        got.to_json().pretty()
    });
    let _ = std::fs::remove_dir_all(&dir);
    assert_eq!(got_json, want, "spool-dispatched sweep byte-identical to unsharded run");
}

/// A shard file round-trips through the spool protocol's file names:
/// `X.shard.json` offers, `X.shard.json.claimed` claims,
/// `X.result.json` answers. Pin the executor's name derivation so a
/// rename in one place cannot silently strand the other.
#[test]
fn spool_worker_ignores_foreign_files_and_serves_offers() {
    let dir = std::env::temp_dir().join(format!("opengemm-spool-names-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();

    // foreign files the worker must leave alone
    std::fs::write(dir.join("README.txt"), "not a shard").unwrap();
    std::fs::write(dir.join("x.result.json"), "{}").unwrap();
    // a corrupt offer (sorts before the real one, so it is claimed
    // first) must be quarantined, not kill the executor loop
    std::fs::write(dir.join("aaa_bad.shard.json"), "{ not json").unwrap();

    let cfg = PlatformConfig::case_study();
    let opts = SweepOptions { shards: 1, workers: 1, ..Default::default() };
    let plan = SweepPlan::stride(&cfg, requests(2), opts);
    let shard = &plan.shards[0];
    shard.write_file(&dir.join("job_s0_a0.shard.json")).unwrap();

    let stop = AtomicBool::new(false);
    let opts = SpoolWorkerOptions {
        poll: Duration::from_millis(5),
        max_shards: 1,
        ..Default::default()
    };
    let served = spool_worker_loop(&dir, &opts, &stop).unwrap();
    assert_eq!(served, 1);
    let result_path: PathBuf = dir.join("job_s0_a0.result.json");
    let result = ShardResult::read_file(&result_path).unwrap();
    assert_eq!(result.shard_index, 0);
    assert_eq!(result.outcomes.len(), 2);
    assert!(!dir.join("job_s0_a0.shard.json").exists(), "offer consumed");
    assert!(!dir.join("job_s0_a0.shard.json.claimed").exists(), "claim cleaned up");
    assert!(dir.join("README.txt").exists(), "foreign files untouched");
    assert!(
        dir.join("aaa_bad.shard.json.poison").exists(),
        "corrupt offer quarantined instead of crashing the executor"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// Poison-shard quarantine: malformed AND truncated offers are renamed
/// to `.poison` (with the parse error logged) and the loop keeps
/// serving — a bad producer must not strand claims or kill a long-
/// lived executor another driver depends on.
#[test]
fn spool_worker_quarantines_poison_shards_and_keeps_serving() {
    let dir = std::env::temp_dir().join(format!("opengemm-spool-poison-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();

    let cfg = PlatformConfig::case_study();
    let opts = SweepOptions { shards: 1, workers: 1, ..Default::default() };
    let plan = SweepPlan::stride(&cfg, requests(2), opts);
    let shard = &plan.shards[0];

    // a syntactically-broken offer and a truncated-mid-write one, both
    // sorting before the valid offer so they are claimed first
    std::fs::write(dir.join("aa_malformed.shard.json"), "{ not json at all").unwrap();
    let full = {
        let path = dir.join("tmp_full.json");
        shard.write_file(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_file(&path).unwrap();
        text
    };
    std::fs::write(dir.join("ab_truncated.shard.json"), &full[..full.len() / 2]).unwrap();
    shard.write_file(&dir.join("zz_good.shard.json")).unwrap();

    let stop = AtomicBool::new(false);
    let opts = SpoolWorkerOptions {
        poll: Duration::from_millis(5),
        max_shards: 1,
        ..Default::default()
    };
    let served = spool_worker_loop(&dir, &opts, &stop).unwrap();
    assert_eq!(served, 1, "the valid offer behind two poison ones is still served");
    assert!(dir.join("aa_malformed.shard.json.poison").exists(), "malformed quarantined");
    assert!(dir.join("ab_truncated.shard.json.poison").exists(), "truncated quarantined");
    assert!(!dir.join("aa_malformed.shard.json").exists(), "offer renamed, not copied");
    assert!(!dir.join("aa_malformed.shard.json.claimed").exists(), "no stranded claim");
    assert!(!dir.join("ab_truncated.shard.json.claimed").exists(), "no stranded claim");
    assert!(
        ShardResult::read_file(&dir.join("zz_good.result.json")).is_ok(),
        "the valid shard's result was published"
    );
    let _ = std::fs::remove_dir_all(&dir);
}
