//! Bench: regenerate Table 2 — utilization and cycle counts of the
//! four DNN workloads (plus the host-depthwise MobileNetV2 variant).
//!
//! Run with:  cargo bench --bench table2_dnn
//! Env: TABLE2_BERT_SEQ=512 to override the BERT sequence length.

use std::time::Instant;

use opengemm::config::PlatformConfig;
use opengemm::experiments::{table2_dnn, Table2Options};

fn main() {
    let cfg = PlatformConfig::case_study();
    let bert_seq = std::env::var("TABLE2_BERT_SEQ")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(512);
    let t0 = Instant::now();
    let opts = Table2Options { bert_seq, workers: 0, max_repeats: 10, ..Default::default() };
    let res = table2_dnn(&cfg, opts);
    let wall = t0.elapsed();
    println!("{}", res.render());
    println!("bench table2_dnn: {:.2}s wall", wall.as_secs_f64());
}
