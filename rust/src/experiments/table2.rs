//! Table 2: utilization and cycle counts of real DNN workloads.
//!
//! Each model's GeMM stream is folded to unique shapes; every unique
//! shape is simulated (with CPL amortization over its repeat count) and
//! scaled back. SU is MAC-weighted over the stream; TU weights each
//! shape's cycles by its count — the same aggregate the paper reports.

use crate::compiler::GemmShape;
use crate::config::Mechanisms;
use crate::config::PlatformConfig;
use crate::coordinator::shard::{run_sweep, SweepOptions};
use crate::coordinator::JobRequest;
use crate::util::table::{fmt_f, fmt_sci, Table};
use crate::workloads::{bert_base, mobilenet_v2, mobilenet_v2_host_dw, resnet18, vit_b16, ModelWorkload};

#[derive(Debug, Clone, Copy)]
pub struct Table2Options {
    pub bert_seq: usize,
    pub workers: usize,
    /// Cap on per-shape CPL amortization repeats (10 mirrors Fig. 5).
    pub max_repeats: u32,
    /// Event-driven cycle skipping (cycle-exact; off only for
    /// differential checks).
    pub fast_forward: bool,
}

impl Default for Table2Options {
    fn default() -> Self {
        Table2Options { bert_seq: 512, workers: 0, max_repeats: 10, fast_forward: true }
    }
}

#[derive(Debug, Clone)]
pub struct ModelRow {
    pub name: String,
    pub spatial: f64,
    pub temporal: f64,
    pub overall: f64,
    pub cycles: f64,
    pub macs: u64,
}

#[derive(Debug, Clone)]
pub struct Table2Result {
    pub rows: Vec<ModelRow>,
}

fn run_model(cfg: &PlatformConfig, model: &ModelWorkload, opts: &Table2Options) -> ModelRow {
    let sweep_opts = SweepOptions {
        workers: opts.workers,
        fast_forward: opts.fast_forward,
        ..Default::default()
    };
    let unique = model.unique_shapes();
    let requests: Vec<JobRequest> = unique
        .iter()
        .map(|&(shape, count)| {
            let repeats = (count as u32).clamp(1, opts.max_repeats);
            JobRequest::timing(shape, Mechanisms::ALL, repeats)
        })
        .collect();
    let results = run_sweep(cfg, requests, sweep_opts).outcomes;

    let mut total_cycles = 0f64;
    let mut compute_cycles = 0f64;
    for ((shape, count), outcome) in unique.iter().zip(results) {
        let r = outcome.unwrap_or_else(|e| panic!("{}: shape {shape:?}: {e}", model.name));
        let reps = r.metrics.runs_completed.max(1) as f64
            / cfg_calls(cfg, shape) as f64;
        // per-execution steady-state cycles (config amortized by CPL)
        let per_exec_total = r.metrics.total_cycles as f64 / reps;
        let per_exec_compute = r.metrics.compute_cycles as f64 / reps;
        total_cycles += per_exec_total * *count as f64;
        compute_cycles += per_exec_compute * *count as f64;
    }
    let su = model.spatial_utilization(&cfg.core);
    let tu = compute_cycles / total_cycles;
    ModelRow {
        name: model.name.clone(),
        spatial: su,
        temporal: tu,
        overall: su * tu,
        cycles: total_cycles,
        macs: model.total_macs(),
    }
}

fn cfg_calls(cfg: &PlatformConfig, shape: &GemmShape) -> u64 {
    use crate::compiler::{split_for_capacity, Layout};
    split_for_capacity(cfg, *shape, Layout::TiledInterleaved)
        .map(|b| b.len() as u64)
        .unwrap_or(1)
}

pub fn table2_dnn(cfg: &PlatformConfig, opts: Table2Options) -> Table2Result {
    let models = vec![
        mobilenet_v2(),
        mobilenet_v2_host_dw(),
        resnet18(),
        vit_b16(),
        bert_base(opts.bert_seq),
    ];
    let rows = models.iter().map(|m| run_model(cfg, m, &opts)).collect();
    Table2Result { rows }
}

impl Table2Result {
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str("## Table 2 — utilization and performance on real DNNs\n\n");
        let mut t = Table::new(&["model", "SU %", "TU %", "OU %", "cycles", "GMACs"]);
        for r in &self.rows {
            t.row(vec![
                r.name.clone(),
                fmt_f(100.0 * r.spatial, 2),
                fmt_f(100.0 * r.temporal, 2),
                fmt_f(100.0 * r.overall, 2),
                fmt_sci(r.cycles),
                fmt_f(r.macs as f64 / 1e9, 2),
            ]);
        }
        out.push_str(&t.markdown());
        out.push_str(
            "\npaper: MobileNetV2 81.89 / ResNet18 95.74 / ViT-B-16 98.16 / BERT-Base 99.34 (OU %)\n",
        );
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_ou_band_and_ordering() {
        let cfg = PlatformConfig::case_study();
        // short BERT keeps the test fast; utilization is insensitive to
        // sequence length beyond ~128
        let res = table2_dnn(
            &cfg,
            Table2Options { bert_seq: 128, workers: 0, max_repeats: 10, fast_forward: true },
        );
        let get = |name: &str| {
            res.rows
                .iter()
                .find(|r| r.name.starts_with(name))
                .unwrap_or_else(|| panic!("row {name}"))
        };
        let bert = get("BERT-Base");
        let vit = get("ViT-B-16");
        let r18 = get("ResNet18");
        let mnv2_host = get("MobileNetV2(host-dw)");
        // paper ordering: MobileNetV2 < ResNet18 < ViT < BERT
        assert!(mnv2_host.overall < r18.overall + 0.05);
        assert!(r18.overall < vit.overall);
        assert!(vit.overall <= bert.overall + 0.01);
        // transformers approach peak (paper: 98-99%)
        assert!(bert.overall > 0.9, "BERT OU {}", bert.overall);
        assert!(vit.overall > 0.9, "ViT OU {}", vit.overall);
        // ResNet18 in the paper band (95.74%): allow a margin
        assert!(r18.overall > 0.8, "ResNet18 OU {}", r18.overall);
        // TU is high everywhere with all mechanisms on — except the
        // naive per-channel depthwise MobileNetV2 lowering, where
        // hundreds of trivially small (M, 9, 1) accelerator calls are
        // configuration-bound (the extreme of the paper's "thin
        // channels -> lower temporal utilization" observation; see
        // EXPERIMENTS.md deviations)
        for r in &res.rows {
            let bound = if r.name == "MobileNetV2" { 0.40 } else { 0.65 };
            assert!(r.temporal > bound, "{} TU {}", r.name, r.temporal);
        }
    }
}
