//! Summary statistics for experiment reporting: the paper's Fig. 5 is a
//! box plot over 500 utilization samples, so we need exact quantiles,
//! whiskers and outlier fences; the serving harness adds tail-latency
//! percentiles (p90/p95/p99) over per-request latency samples.
//!
//! Every function here is **total**: empty (or otherwise degenerate)
//! inputs return `None` instead of panicking. A serving window with no
//! completed requests is a legitimate, reachable state — it must
//! produce an empty report, not a crash in a reporting thread.

use crate::util::json::Json;

/// Five-number summary plus mean, matching a Tukey box plot.
#[derive(Debug, Clone, PartialEq)]
pub struct BoxStats {
    pub n: usize,
    pub min: f64,
    pub q1: f64,
    pub median: f64,
    pub q3: f64,
    pub max: f64,
    pub mean: f64,
    /// Whisker ends at the last data point within 1.5*IQR of the box.
    pub whisker_lo: f64,
    pub whisker_hi: f64,
    pub outliers: usize,
}

/// Linear-interpolated quantile (type 7, the numpy default) of a sorted
/// slice. `None` on an empty slice or `q` outside `[0, 1]`.
pub fn quantile_sorted(sorted: &[f64], q: f64) -> Option<f64> {
    if sorted.is_empty() || !(0.0..=1.0).contains(&q) {
        return None;
    }
    if sorted.len() == 1 {
        return Some(sorted[0]);
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    Some(sorted[lo] + (sorted[hi] - sorted[lo]) * frac)
}

/// Sort a sample set for quantile extraction. `None` if any sample is
/// NaN (a NaN would poison every order statistic downstream).
fn sorted_finite(samples: &[f64]) -> Option<Vec<f64>> {
    if samples.iter().any(|v| v.is_nan()) {
        return None;
    }
    let mut sorted: Vec<f64> = samples.to_vec();
    sorted.sort_by(f64::total_cmp);
    Some(sorted)
}

impl BoxStats {
    /// `None` on an empty sample set or any NaN sample.
    pub fn compute(samples: &[f64]) -> Option<BoxStats> {
        let sorted = sorted_finite(samples)?;
        if sorted.is_empty() {
            return None;
        }
        let q1 = quantile_sorted(&sorted, 0.25)?;
        let median = quantile_sorted(&sorted, 0.5)?;
        let q3 = quantile_sorted(&sorted, 0.75)?;
        let iqr = q3 - q1;
        let lo_fence = q1 - 1.5 * iqr;
        let hi_fence = q3 + 1.5 * iqr;
        let whisker_lo = sorted
            .iter()
            .copied()
            .find(|&v| v >= lo_fence)
            .unwrap_or(sorted[0]);
        let whisker_hi = sorted
            .iter()
            .rev()
            .copied()
            .find(|&v| v <= hi_fence)
            .unwrap_or(*sorted.last().unwrap());
        let outliers = sorted
            .iter()
            .filter(|&&v| v < lo_fence || v > hi_fence)
            .count();
        Some(BoxStats {
            n: sorted.len(),
            min: sorted[0],
            q1,
            median,
            q3,
            max: *sorted.last().unwrap(),
            mean: sorted.iter().sum::<f64>() / sorted.len() as f64,
            whisker_lo,
            whisker_hi,
            outliers,
        })
    }
}

/// Tail-latency summary: the percentiles a serving report quotes. The
/// quantile definition matches [`quantile_sorted`] (type 7), so p50
/// here equals the [`BoxStats`] median on the same samples.
#[derive(Debug, Clone, PartialEq)]
pub struct TailSummary {
    pub n: usize,
    pub min: f64,
    pub mean: f64,
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl TailSummary {
    /// `None` on an empty sample set or any NaN sample.
    pub fn compute(samples: &[f64]) -> Option<TailSummary> {
        let sorted = sorted_finite(samples)?;
        if sorted.is_empty() {
            return None;
        }
        Some(TailSummary {
            n: sorted.len(),
            min: sorted[0],
            mean: sorted.iter().sum::<f64>() / sorted.len() as f64,
            p50: quantile_sorted(&sorted, 0.50)?,
            p90: quantile_sorted(&sorted, 0.90)?,
            p95: quantile_sorted(&sorted, 0.95)?,
            p99: quantile_sorted(&sorted, 0.99)?,
            max: *sorted.last().unwrap(),
        })
    }

    /// Wire encoding (serving reports). The `f64` percentiles round-trip
    /// bit-identically through `util::json`'s shortest-Display writer,
    /// which is what makes same-seed serve reports byte-identical.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("n", Json::num(self.n as f64)),
            ("min", Json::num(self.min)),
            ("mean", Json::num(self.mean)),
            ("p50", Json::num(self.p50)),
            ("p90", Json::num(self.p90)),
            ("p95", Json::num(self.p95)),
            ("p99", Json::num(self.p99)),
            ("max", Json::num(self.max)),
        ])
    }
}

/// Mean of a slice. `None` on empty input.
pub fn mean(xs: &[f64]) -> Option<f64> {
    if xs.is_empty() {
        return None;
    }
    Some(xs.iter().sum::<f64>() / xs.len() as f64)
}

/// Population standard deviation. `None` on empty input.
pub fn stddev(xs: &[f64]) -> Option<f64> {
    let m = mean(xs)?;
    Some((xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt())
}

/// Geometric mean (used for speedup aggregation across workloads).
/// `None` on empty input or any non-positive value.
pub fn geomean(xs: &[f64]) -> Option<f64> {
    if xs.is_empty() || xs.iter().any(|&x| x.is_nan() || x <= 0.0) {
        return None;
    }
    Some((xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_of_known_sequence() {
        let xs: Vec<f64> = (1..=5).map(|i| i as f64).collect();
        assert_eq!(quantile_sorted(&xs, 0.0), Some(1.0));
        assert_eq!(quantile_sorted(&xs, 0.5), Some(3.0));
        assert_eq!(quantile_sorted(&xs, 1.0), Some(5.0));
        assert_eq!(quantile_sorted(&xs, 0.25), Some(2.0));
    }

    #[test]
    fn quantile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((quantile_sorted(&xs, 0.3).unwrap() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn quantile_is_total() {
        assert_eq!(quantile_sorted(&[], 0.5), None);
        assert_eq!(quantile_sorted(&[1.0], 1.5), None);
        assert_eq!(quantile_sorted(&[1.0], -0.1), None);
        assert_eq!(quantile_sorted(&[7.0], 0.99), Some(7.0), "single sample");
    }

    #[test]
    fn box_stats_basic() {
        let xs: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let s = BoxStats::compute(&xs).unwrap();
        assert_eq!(s.n, 100);
        assert_eq!(s.min, 0.0);
        assert_eq!(s.max, 99.0);
        assert!((s.median - 49.5).abs() < 1e-12);
        assert_eq!(s.outliers, 0);
    }

    #[test]
    fn box_stats_detects_outliers() {
        let mut xs: Vec<f64> = vec![10.0; 50];
        xs.push(1000.0);
        let s = BoxStats::compute(&xs).unwrap();
        assert_eq!(s.outliers, 1);
        assert_eq!(s.whisker_hi, 10.0);
    }

    #[test]
    fn geomean_of_powers() {
        assert!((geomean(&[1.0, 4.0]).unwrap() - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 8.0]).unwrap() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_is_total() {
        assert_eq!(geomean(&[]), None);
        assert_eq!(geomean(&[1.0, 0.0]), None);
        assert_eq!(geomean(&[1.0, -2.0]), None);
    }

    #[test]
    fn stddev_constant_is_zero() {
        assert_eq!(stddev(&[5.0, 5.0, 5.0]), Some(0.0));
    }

    #[test]
    fn empty_inputs_return_none_not_panic() {
        assert_eq!(BoxStats::compute(&[]), None);
        assert_eq!(TailSummary::compute(&[]), None);
        assert_eq!(mean(&[]), None);
        assert_eq!(stddev(&[]), None);
    }

    #[test]
    fn nan_samples_return_none() {
        assert_eq!(BoxStats::compute(&[1.0, f64::NAN]), None);
        assert_eq!(TailSummary::compute(&[f64::NAN]), None);
    }

    #[test]
    fn single_sample() {
        let s = BoxStats::compute(&[3.5]).unwrap();
        assert_eq!(s.median, 3.5);
        assert_eq!(s.q1, 3.5);
        assert_eq!(s.q3, 3.5);
        let t = TailSummary::compute(&[3.5]).unwrap();
        assert_eq!((t.p50, t.p90, t.p95, t.p99), (3.5, 3.5, 3.5, 3.5));
        assert_eq!((t.min, t.max, t.mean, t.n), (3.5, 3.5, 3.5, 1));
    }

    #[test]
    fn tail_percentiles_of_known_sequence() {
        // 1..=100: type-7 pK = 1 + 0.K * 99
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let t = TailSummary::compute(&xs).unwrap();
        assert!((t.p50 - 50.5).abs() < 1e-12);
        assert!((t.p90 - 90.1).abs() < 1e-9);
        assert!((t.p95 - 95.05).abs() < 1e-9);
        assert!((t.p99 - 99.01).abs() < 1e-9);
        assert_eq!(t.max, 100.0);
        assert_eq!(t.min, 1.0);
        assert!((t.mean - 50.5).abs() < 1e-12);
    }

    #[test]
    fn tail_p50_matches_box_median() {
        let xs: Vec<f64> = (0..37).map(|i| (i * 7 % 23) as f64).collect();
        let t = TailSummary::compute(&xs).unwrap();
        let b = BoxStats::compute(&xs).unwrap();
        assert_eq!(t.p50, b.median);
        assert_eq!((t.min, t.max), (b.min, b.max));
    }

    #[test]
    fn tail_summary_json_is_stable() {
        let t = TailSummary::compute(&[1.0, 2.0, 4.0]).unwrap();
        let text = t.to_json().pretty();
        assert_eq!(crate::util::json::parse(&text).unwrap().pretty(), text);
        assert!(text.contains("\"p99\""));
    }
}
