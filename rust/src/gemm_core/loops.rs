//! Built-in hardware loop controller (Sec. 2.3).
//!
//! The GeMM accelerator sequences the three *temporal* loops `(m1, n1,
//! k1)` in hardware — the host only programs the bounds. The controller
//! is "in charge of the timely input data request, outputting of result
//! data, and accumulator resets": [`LoopController::at_k_first`] drives
//! the accumulator reset, [`LoopController::at_k_last`] the result
//! writeback.
//!
//! Bounds are limited by on-chip buffer capacity; larger matrices are
//! tiled by software (the compiler) into multiple accelerator calls.

use crate::streamer::LoopBounds;

/// Hardware limit on each loop bound (paper: "maximum hardware loop
/// upper bound when the required data amount reaches the on-chip buffer
/// capacity"). 2^10 tiles per dimension mirrors a 10-bit bound register
/// (the CSR packing allots 10 bits per bound).
pub const MAX_LOOP_BOUND: u64 = 1 << 10;

#[derive(Debug, Clone)]
pub struct LoopController {
    bounds: LoopBounds,
    pos: u64,
    total: u64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LoopError(pub LoopBounds);

impl std::fmt::Display for LoopError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "loop bounds exceed hardware limits: {:?}", self.0)
    }
}

impl std::error::Error for LoopError {}

impl LoopController {
    pub fn new(bounds: LoopBounds) -> Result<LoopController, LoopError> {
        let ok = |b: u64| b >= 1 && b <= MAX_LOOP_BOUND;
        if !(ok(bounds.mt) && ok(bounds.nt) && ok(bounds.kt)) {
            return Err(LoopError(bounds));
        }
        Ok(LoopController { bounds, pos: 0, total: bounds.total_tiles() })
    }

    pub fn bounds(&self) -> LoopBounds {
        self.bounds
    }

    /// Current (m1, n1, k1).
    #[inline]
    pub fn current(&self) -> (u64, u64, u64) {
        self.bounds.decompose(self.pos)
    }

    /// True when the upcoming compute cycle starts a new output tile
    /// (k1 == 0) — the controller resets the accumulators.
    #[inline]
    pub fn at_k_first(&self) -> bool {
        self.pos % self.bounds.kt == 0
    }

    /// True when the upcoming compute cycle finishes an output tile
    /// (k1 == kt-1) — the controller emits the C' tile.
    #[inline]
    pub fn at_k_last(&self) -> bool {
        self.pos % self.bounds.kt == self.bounds.kt - 1
    }

    /// Advance one tile-MAC. Returns true while more work remains.
    #[inline]
    pub fn advance(&mut self) -> bool {
        debug_assert!(self.pos < self.total);
        self.pos += 1;
        self.pos < self.total
    }

    pub fn finished(&self) -> bool {
        self.pos >= self.total
    }

    pub fn completed_tiles(&self) -> u64 {
        self.pos
    }

    pub fn total_tiles(&self) -> u64 {
        self.total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bounds(mt: u64, nt: u64, kt: u64) -> LoopBounds {
        LoopBounds { mt, nt, kt }
    }

    #[test]
    fn iterates_k_innermost() {
        let mut lc = LoopController::new(bounds(2, 2, 3)).unwrap();
        let mut seq = Vec::new();
        loop {
            seq.push(lc.current());
            if !lc.advance() {
                break;
            }
        }
        assert_eq!(seq.len(), 12);
        assert_eq!(seq[0], (0, 0, 0));
        assert_eq!(seq[1], (0, 0, 1));
        assert_eq!(seq[2], (0, 0, 2));
        assert_eq!(seq[3], (0, 1, 0));
        assert_eq!(seq[11], (1, 1, 2));
    }

    #[test]
    fn k_first_and_last_flags() {
        let mut lc = LoopController::new(bounds(1, 2, 3)).unwrap();
        let mut firsts = 0;
        let mut lasts = 0;
        loop {
            firsts += lc.at_k_first() as u64;
            lasts += lc.at_k_last() as u64;
            if !lc.advance() {
                break;
            }
        }
        // one reset and one writeback per output tile
        assert_eq!(firsts, 2);
        assert_eq!(lasts, 2);
    }

    #[test]
    fn kt_one_is_first_and_last() {
        let lc = LoopController::new(bounds(1, 1, 1)).unwrap();
        assert!(lc.at_k_first() && lc.at_k_last());
    }

    #[test]
    fn rejects_out_of_range_bounds() {
        assert!(LoopController::new(bounds(0, 1, 1)).is_err());
        assert!(LoopController::new(bounds(1, MAX_LOOP_BOUND + 1, 1)).is_err());
        assert!(LoopController::new(bounds(1, MAX_LOOP_BOUND, 1)).is_ok());
    }

    #[test]
    fn finished_only_after_total() {
        let mut lc = LoopController::new(bounds(2, 1, 2)).unwrap();
        assert!(!lc.finished());
        for _ in 0..3 {
            assert!(lc.advance() || lc.finished());
        }
        lc.advance();
        assert!(lc.finished());
        assert_eq!(lc.completed_tiles(), 4);
    }
}
