//! Stable, dependency-free content digests for cache keys.
//!
//! The result cache (`coordinator::cache`) addresses entries by a
//! digest of the canonical `util::json` encoding of the job, so the
//! hash must be identical across processes, hosts and releases.
//! `std::hash` explicitly reserves the right to change between
//! compiler versions (and `RandomState` is seeded per process), so we
//! pin FNV-1a here instead: the 64-bit variant with the reference
//! offset basis and prime, verbatim from the FNV specification.
//!
//! A single 64-bit digest is plenty for collision *accidents* at sweep
//! scale (thousands of jobs), but a silent collision would return the
//! wrong cached result, so [`fingerprint`] concatenates two
//! independent FNV-1a streams — the reference one and one seeded with
//! a distinct basis — into a 128-bit hex key. Changing this format
//! invalidates every on-disk cache, which is safe (entries become
//! misses) but wasteful; treat the constants as frozen.

/// FNV-1a 64-bit offset basis (reference value).
pub const FNV64_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
/// FNV-1a 64-bit prime (reference value).
pub const FNV64_PRIME: u64 = 0x0000_0100_0000_01b3;
/// Offset basis of the second, independent stream in [`fingerprint`]:
/// the reference basis xored with a fixed pattern so the two streams
/// never agree byte-for-byte.
const FNV64_OFFSET_ALT: u64 = FNV64_OFFSET ^ 0x5555_5555_5555_5555;

/// Streaming FNV-1a 64-bit hasher.
#[derive(Debug, Clone, Copy)]
pub struct Fnv64 {
    state: u64,
}

impl Fnv64 {
    pub fn new() -> Fnv64 {
        Fnv64::with_basis(FNV64_OFFSET)
    }

    pub fn with_basis(basis: u64) -> Fnv64 {
        Fnv64 { state: basis }
    }

    pub fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.state ^= u64::from(b);
            self.state = self.state.wrapping_mul(FNV64_PRIME);
        }
    }

    pub fn finish(&self) -> u64 {
        self.state
    }
}

impl Default for Fnv64 {
    fn default() -> Fnv64 {
        Fnv64::new()
    }
}

/// Reference FNV-1a 64-bit digest of `bytes`.
pub fn fnv1a_64(bytes: &[u8]) -> u64 {
    let mut h = Fnv64::new();
    h.write(bytes);
    h.finish()
}

/// 128-bit content fingerprint rendered as 32 lowercase hex digits:
/// two independent FNV-1a streams over the same bytes. This is the
/// cache-key format; it doubles as a filesystem-safe file stem.
pub fn fingerprint(bytes: &[u8]) -> String {
    let mut lo = Fnv64::new();
    let mut hi = Fnv64::with_basis(FNV64_OFFSET_ALT);
    lo.write(bytes);
    hi.write(bytes);
    format!("{:016x}{:016x}", lo.finish(), hi.finish())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Reference vectors from the FNV specification's test suite; if
    /// these move, every persisted cache key silently changes.
    #[test]
    fn fnv1a_64_matches_reference_vectors() {
        assert_eq!(fnv1a_64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a_64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a_64(b"foobar"), 0x8594_4171_f739_67e8);
    }

    #[test]
    fn streaming_equals_one_shot() {
        let mut h = Fnv64::new();
        h.write(b"foo");
        h.write(b"bar");
        assert_eq!(h.finish(), fnv1a_64(b"foobar"));
    }

    #[test]
    fn fingerprint_is_stable_hex_and_input_sensitive() {
        let fp = fingerprint(b"opengemm");
        assert_eq!(fp.len(), 32);
        assert!(fp.bytes().all(|b| b.is_ascii_hexdigit() && !b.is_ascii_uppercase()));
        assert_eq!(fp, fingerprint(b"opengemm"), "deterministic");
        assert_ne!(fp, fingerprint(b"opengemm "), "input-sensitive");
        // The two 64-bit halves are independent streams, not copies.
        assert_ne!(&fp[..16], &fp[16..]);
    }
}
