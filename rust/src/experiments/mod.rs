//! Experiment drivers: one function per paper table/figure, shared by
//! the CLI (`opengemm <subcommand>`) and the `cargo bench` targets.
//! Each driver returns structured results plus a `render()` to markdown.

pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod table2;
pub mod table3;

pub use fig5::{fig5_ablation, Fig5Options, Fig5Result};
pub use fig6::{fig6_area_power, Fig6Options, Fig6Result};
pub use fig7::{fig7_gemmini, Fig7Options, Fig7Result};
pub use table2::{table2_dnn, Table2Options, Table2Result};
pub use table3::{table3_sota, Table3Result};
