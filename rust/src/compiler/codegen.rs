//! RV32I configuration-program generator.
//!
//! The compiler emits a *real* RISC-V machine-code program that the host
//! ISS executes to program the accelerator: `li` the CSR values (the
//! toolchain constant-folds strides, exactly what `-O2` does to the SNAX
//! C runtime), `csrrw` them into the CSRManager, pulse CTRL, and
//! poll/wait according to the synchronization discipline:
//!
//! - **without CPL**: the host must poll STATUS until the accelerator is
//!   idle before touching the CSRs for the next call — configuration
//!   time is fully exposed (Fig. 4(a)(1));
//! - **with CPL**: the host waits only for a free pre-load slot (the
//!   PENDING bit), then configures the *next* call while the current one
//!   computes (Fig. 4(b)(1)).

use crate::csr::{core_csr_base, CSR_BASE, CSR_CTRL, CSR_STATUS, STATUS_BUSY, STATUS_PENDING};
use crate::host::encode::{self as enc, reg, Asm};

/// One accelerator call = an ordered CSR programming image.
pub type CsrImage = Vec<(u32, u32)>;

/// Generate the host program for `repeats` repetitions of a sequence of
/// accelerator calls (single core).
pub fn gen_config_program(calls: &[CsrImage], repeats: u32, cpl: bool) -> Vec<u32> {
    gen_multicore_program(calls, repeats, cpl, 1)
}

/// Generate the host program for a platform with `cores` GeMM cores:
/// call `ci` is dispatched round-robin to core `ci % cores` by offsetting
/// its poll/config/start accesses into that core's CSR window, and the
/// final drain waits for *every* core to go idle. With `cores == 1` the
/// emitted machine code is byte-identical to the single-core generator
/// (window offsets are zero; labels never reach the binary).
pub fn gen_multicore_program(calls: &[CsrImage], repeats: u32, cpl: bool, cores: usize) -> Vec<u32> {
    assert!(!calls.is_empty() && repeats >= 1 && cores >= 1);
    let mut asm = Asm::new();

    // s0 = remaining repeats
    asm.li(reg::S0, repeats as i32);
    asm.label("repeat");

    for (ci, csrs) in calls.iter().enumerate() {
        // this call's core window offset
        let win = core_csr_base(ci % cores) - CSR_BASE;
        let wait = format!("wait_{ci}");
        asm.label(&wait);
        // csrrs t1, STATUS, x0 ; andi ; bne -> wait
        asm.emit(enc::csrrs(reg::T1, CSR_STATUS + win, reg::ZERO));
        if cpl {
            // wait only for a free pre-load slot
            asm.emit(enc::andi(reg::T1, reg::T1, STATUS_PENDING as i32));
        } else {
            // wait for full idle before reconfiguring
            asm.emit(enc::andi(reg::T1, reg::T1, STATUS_BUSY as i32));
        }
        asm.bne_to(reg::T1, reg::ZERO, &wait);

        // program the 16 run-time CSRs
        for &(addr, value) in csrs {
            asm.li(reg::T0, value as i32);
            asm.emit(enc::csrrw(reg::ZERO, addr + win, reg::T0));
        }
        // start pulse (immediate form: one instruction)
        asm.emit(enc::csrrwi(reg::ZERO, CSR_CTRL + win, 1));
    }

    asm.emit(enc::addi(reg::S0, reg::S0, -1));
    // long-range loop back-edge: conditional branches only reach +-4 KiB
    // and multi-call programs can exceed that, so use beq-over-jal
    // (jal reaches +-1 MiB)
    asm.beq_to(reg::S0, reg::ZERO, "done");
    asm.jal_to(reg::ZERO, "repeat");
    asm.label("done");

    // final drain: wait for every core to go idle
    for core in 0..cores {
        let win = core_csr_base(core) - CSR_BASE;
        let drain = format!("drain_{core}");
        asm.label(&drain);
        asm.emit(enc::csrrs(reg::T1, CSR_STATUS + win, reg::ZERO));
        asm.emit(enc::andi(reg::T1, reg::T1, (STATUS_BUSY | STATUS_PENDING) as i32));
        asm.bne_to(reg::T1, reg::ZERO, &drain);
    }
    asm.emit(enc::ebreak());

    asm.assemble()
}

/// Static cost estimate of one call's configuration stretch in host
/// instructions (used by tests and the analytical model; the simulator
/// measures the true cycle count).
pub fn config_instruction_estimate(csrs: &CsrImage) -> u64 {
    let li_cost: u64 = csrs
        .iter()
        .map(|&(_, v)| {
            let v = v as i32;
            if (-2048..=2047).contains(&v) {
                1
            } else {
                2
            }
        })
        .sum();
    // li's + csrrw's + start pulse
    li_cost + csrs.len() as u64 + 1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::csr::{CsrManager, CSR_A_BASE, CSR_BOUNDS};
    use crate::host::Cpu;

    fn image() -> CsrImage {
        vec![(CSR_BOUNDS, 0x00400803), (CSR_A_BASE, 0x1234)]
    }

    /// Drive the generated program against a real CsrManager, manually
    /// completing accelerator runs when busy.
    fn run_program(program: Vec<u32>, cpl: bool, expect_starts: u32) {
        let mut csr = CsrManager::new(cpl);
        let mut cpu = Cpu::new(program, 4096);
        let mut starts = 0u32;
        let mut busy_cycles_left = 0u32;
        for _ in 0..200_000 {
            if cpu.halted() {
                break;
            }
            match cpu.step(&mut csr) {
                crate::host::StepResult::Ran { .. } => {}
                crate::host::StepResult::Halted => break,
                crate::host::StepResult::Fault(f) => panic!("fault: {f}"),
            }
            // model an accelerator that takes 50 host-steps per run
            if let Some(_cfg) = csr.take_start() {
                starts += 1;
                busy_cycles_left = 50;
            }
            if csr.is_busy() && busy_cycles_left > 0 {
                busy_cycles_left -= 1;
                if busy_cycles_left == 0 {
                    csr.notify_done();
                    if csr.take_start().is_some() {
                        starts += 1;
                        busy_cycles_left = 50;
                    }
                }
            }
        }
        assert!(cpu.halted(), "program did not finish");
        assert_eq!(starts, expect_starts);
        assert!(!csr.is_busy());
    }

    #[test]
    fn non_cpl_program_runs_all_repeats() {
        let program = gen_config_program(&[image()], 10, false);
        run_program(program, false, 10);
    }

    #[test]
    fn cpl_program_runs_all_repeats() {
        let program = gen_config_program(&[image()], 10, true);
        run_program(program, true, 10);
    }

    #[test]
    fn multi_call_sequence() {
        let calls = vec![image(), image(), image()];
        let program = gen_config_program(&calls, 4, true);
        run_program(program, true, 12);
    }

    #[test]
    fn single_core_wrapper_is_byte_identical() {
        let calls = vec![image(), image(), image()];
        for cpl in [false, true] {
            assert_eq!(
                gen_config_program(&calls, 4, cpl),
                gen_multicore_program(&calls, 4, cpl, 1),
                "cpl={cpl}"
            );
        }
    }

    #[test]
    fn multicore_program_targets_core_windows() {
        use crate::csr::{core_csr_base, CSR_BASE, CSR_COUNT};
        let calls = vec![image(), image()];
        let program = gen_multicore_program(&calls, 1, true, 2);
        // every csr instruction's address must fall inside window 0 or 1
        let mut windows_seen = [false; 2];
        for &insn in &program {
            if insn & 0x7f == 0x73 && (insn >> 12) & 0x7 != 0 {
                let addr = insn >> 20;
                let rel = addr - CSR_BASE;
                let w = (rel as usize) / CSR_COUNT;
                assert!(w < 2, "csr {addr:#x} outside both windows");
                assert!(addr >= core_csr_base(w), "window math");
                windows_seen[w] = true;
            }
        }
        assert!(windows_seen[0] && windows_seen[1], "both cores programmed");
    }

    #[test]
    fn estimate_counts_li_widths() {
        let csrs: CsrImage = vec![(CSR_BOUNDS, 3), (CSR_A_BASE, 0x123456)];
        // 1 (small li) + 2 (large li) + 2 csrrw + 1 start
        assert_eq!(config_instruction_estimate(&csrs), 6);
    }
}
