//! The cycle-accurate OpenGeMM platform simulator.
//!
//! One [`Platform`] instance wires together the RV32I host, one or more
//! GeMM *core clusters* (GeMM core + CSRManager + three data streamers,
//! [`PlatformConfig::cores`] of them) and the shared multi-banked SPM,
//! and advances them in lock-step, one clock cycle per
//! [`Platform::cycle`]. This is the evaluation vehicle standing in for
//! the paper's Verilator RTL simulation (Sec. 4.1): every utilization
//! number in the reproduced figures/tables comes out of this loop.
//!
//! ## Memory model
//!
//! SPM accesses are *epochs*: all port requests issued in the same cycle
//! (A-tile fetch, B-tile fetch, C-tile writeback) are arbitrated
//! together; the epoch occupies the interconnect for `max bank load`
//! cycles (single-ported banks). Streamers hold at most one outstanding
//! tile access each — exactly one request pipeline per streamer, as in
//! the RTL. On multi-core platforms every cluster's streamers contend
//! on the same read/write crossbars: same-cycle accesses touching a
//! bank already claimed by an earlier cluster (or by the other input
//! streamer, as before) pay one arbitration cycle.
//!
//! ## DMA / data loading
//!
//! By default operand data appears in the SPM "for free" at run start
//! and results are collected at run completion: the paper excludes
//! DRAM<->SPM movement from all cycle counts (Sec. 4.3 footnote). With
//! [`crate::config::DmaParams`] configured, a modeled DMA engine
//! instead stages each call's operand region from background memory
//! into the SPM in `chunk_words`-word bursts before the core may start:
//! each burst pays the background `latency` plus the SPM write cost of
//! its words, contending for write banks like any streamer. The DMA is
//! an ordinary event source — between bursts the engine fast-forwards
//! over the dead time.
//!
//! ## Event model: heap-scheduled fast-forward
//!
//! Long stretches of simulated time are *provably inert*: the cores are
//! stalled or idle, every streamer is waiting on an SPM access whose
//! completion cycle is already scheduled, the DMA is sleeping off a
//! background-memory burst, and the host is sleeping off a CSR
//! handshake with a known expiry. Stepping such stretches one
//! [`Platform::cycle`] at a time only increments counters.
//!
//! With [`SimOptions::fast_forward`] (default on), [`Platform`] runs an
//! event-driven engine instead, built on the [`sched`] substrate: every
//! event source (per-cluster streamer deliveries and bank-gate expiries,
//! the DMA burst horizon, the host stall horizon) *registers* once with
//! the [`EventHeap`] and *pushes* its next wakeup at the point it
//! becomes known — a delivery is pushed when the fetch commits, the
//! host horizon when the stall is charged. `next_event` then asks the
//! heap for the earliest live wakeup instead of re-scanning sources
//! (the previous engine's memoized scan, whose manual invalidation
//! sites this design deletes), and `advance_to` jumps the clock there
//! in one step, batch-accounting the skipped cycles into the same
//! [`SimMetrics`] / core-stall counters the lockstep loop would have
//! incremented. Whenever anything *can* happen next cycle (a tile-MAC
//! would issue, a latched start is waiting, a run is completing, the
//! host is runnable), the engine degrades to plain single-cycle
//! stepping, so the two modes are **bit-identical** in every counter —
//! a property enforced by the `fast_forward_is_cycle_exact`
//! differential test in `tests/platform_properties.rs` across core
//! counts and DMA configurations.

pub mod metrics;
pub mod sched;

pub use metrics::{SimMetrics, UtilizationReport};
pub use sched::{EventHeap, SourceId};

use std::sync::Arc;

use crate::compiler::{layout, CompiledCall, CompiledJob};
use crate::config::{Mechanisms, PlatformConfig};
use crate::csr::{
    core_csr_base, ConfigRegs, CsrError, CsrManager, CSR_A_BASE, CSR_BASE, CSR_B_BASE, CSR_C_BASE,
    CSR_COUNT,
};
use crate::gemm_core::{CoreEvent, CorePending, GemmCore};
use crate::host::{Cpu, CsrBus, StepResult};
use crate::spm::Spm;
use crate::streamer::{InputStreamer, OutputStreamer, OutTile, TileArena};
use crate::util::json::{self, Json};

/// Simulation options.
#[derive(Debug, Clone, Copy)]
pub struct SimOptions {
    pub mechanisms: Mechanisms,
    /// Carry and verify real data through the datapath.
    pub functional: bool,
    /// Extra host-stall cycles per accelerator CSR access (CSRManager
    /// handshake / clock-domain crossing). 1 access = 1 + this.
    pub csr_latency: u64,
    /// Runaway guard.
    pub max_cycles: u64,
    /// Event-driven cycle skipping (see the module docs). Cycle-exact
    /// vs the lockstep loop; disable only to cross-check timing.
    pub fast_forward: bool,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions {
            mechanisms: Mechanisms::ALL,
            functional: false,
            csr_latency: 8,
            max_cycles: 2_000_000_000,
            fast_forward: true,
        }
    }
}

/// Result of running one compiled job.
#[derive(Debug, Clone, PartialEq)]
pub struct JobResult {
    pub metrics: SimMetrics,
    pub report: UtilizationReport,
    /// Result matrix (row-major M x N), functional mode only.
    pub c: Option<Vec<i32>>,
}

impl JobResult {
    /// Wire encoding (sharded-sweep result files): metrics, report and
    /// the functional result matrix all survive the round-trip, so a
    /// worker process's output merges transparently with in-process
    /// runs.
    pub fn to_json(&self) -> Json {
        let c = match &self.c {
            None => Json::Null,
            Some(c) => Json::Arr(c.iter().map(|&x| Json::num(x as f64)).collect()),
        };
        Json::obj(vec![
            ("metrics", self.metrics.to_json()),
            ("report", self.report.to_json()),
            ("c", c),
        ])
    }

    pub fn from_json(v: &Json) -> Result<JobResult, String> {
        let c = match json::get(v, "c")? {
            Json::Null => None,
            Json::Arr(items) => Some(
                items
                    .iter()
                    .map(|x| {
                        x.as_i64()
                            .and_then(|n| i32::try_from(n).ok())
                            .ok_or_else(|| "bad i32 in result matrix".to_string())
                    })
                    .collect::<Result<Vec<i32>, String>>()?,
            ),
            _ => return Err("field \"c\" is neither null nor an array".into()),
        };
        Ok(JobResult {
            metrics: SimMetrics::from_json(json::get(v, "metrics")?)?,
            report: UtilizationReport::from_json(json::get(v, "report")?)?,
            c,
        })
    }
}

/// Simulation failure.
#[derive(Debug)]
pub enum SimError {
    HostFault(crate::host::Fault),
    Csr(CsrError),
    CycleLimit(u64),
}

impl std::fmt::Display for SimError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SimError::HostFault(e) => write!(f, "host fault: {e}"),
            SimError::Csr(e) => write!(f, "csr error: {e}"),
            SimError::CycleLimit(c) => write!(f, "cycle limit {c} exceeded (deadlock?)"),
        }
    }
}

impl std::error::Error for SimError {}

/// Counting CSR bus: routes each access to the owning cluster's
/// CSRManager by address window and counts accelerator accesses so the
/// platform can charge handshake latency.
struct CountingBus<'a> {
    clusters: &'a mut [CoreCluster],
    accesses: u64,
}

impl CountingBus<'_> {
    fn route(&mut self, addr: u32) -> Result<&mut CsrManager, CsrError> {
        if addr < CSR_BASE {
            return Err(CsrError::BadAddress(addr));
        }
        let k = ((addr - CSR_BASE) as usize) / CSR_COUNT;
        match self.clusters.get_mut(k) {
            Some(cl) => Ok(&mut cl.csr),
            None => Err(CsrError::BadAddress(addr)),
        }
    }
}

impl CsrBus for CountingBus<'_> {
    fn csr_read(&mut self, addr: u32) -> Result<u32, CsrError> {
        self.accesses += 1;
        self.route(addr)?.read(addr)
    }
    fn csr_write(&mut self, addr: u32, value: u32) -> Result<(), CsrError> {
        self.accesses += 1;
        self.route(addr)?.write(addr, value)
    }
}

/// Event-heap registrations of one cluster (see [`sched`]).
struct ClusterSources {
    a_deliver: SourceId,
    b_deliver: SourceId,
    c_deliver: SourceId,
    a_issue: SourceId,
    b_issue: SourceId,
    c_issue: SourceId,
    dma: SourceId,
}

/// An in-flight DMA staging transfer: the call's operand region,
/// snapshotted from background memory, being burst into the SPM.
struct DmaTransfer {
    /// The launch configuration, held back until staging completes.
    regs: ConfigRegs,
    /// Modeled background-memory image of the operand region.
    background: Vec<u64>,
    /// First SPM word of the region.
    start_word: u64,
    /// Words staged so far.
    cursor: usize,
    /// Cycle at which the next burst (or completion) may proceed.
    ready_at: u64,
}

/// One GeMM core with its private CSR window, streamers, and run state.
/// Single-core platforms have exactly one; all clusters share the SPM
/// and the host.
struct CoreCluster {
    idx: usize,
    core: GemmCore,
    csr: CsrManager,
    a_stream: InputStreamer,
    b_stream: InputStreamer,
    c_stream: OutputStreamer,
    addr_a: Vec<u64>,
    addr_b: Vec<u64>,
    addr_c: Vec<u64>,
    addr_dma: Vec<u64>,
    /// Which call the *next* start on this cluster corresponds to
    /// (round-robin: idx, idx + cores, ...).
    next_call: usize,
    /// Which call is currently running.
    running_call: Option<usize>,
    run_active: bool,
    run_start_cycle: u64,
    dma: Option<DmaTransfer>,
    src: ClusterSources,
}

struct JobState {
    /// Shared with the [`CompiledJob`] — cloning the `Arc` per
    /// `run_job` call replaces the per-run deep copy of every call's
    /// placement and CSR image (benches re-run the same job thousands
    /// of times).
    calls: Arc<[CompiledCall]>,
    functional_inputs: Option<FunctionalInputs>,
    /// Assembled output (row-major m x n of the parent shape).
    c_out: Option<Vec<i32>>,
    parent_n: usize,
    parent_m: usize,
}

/// Per-call operand sub-blocks for functional mode, pre-sliced once per
/// job into two flat buffers (instead of two fresh `Vec`s per call).
struct FunctionalInputs {
    a: Vec<i8>,
    b: Vec<i8>,
    /// Per call: (range into `a`, range into `b`).
    spans: Vec<(std::ops::Range<usize>, std::ops::Range<usize>)>,
}

impl FunctionalInputs {
    /// Slice the parent operands into each call's blocks (the DMA's
    /// work list).
    fn slice(job: &CompiledJob, a: &[i8], b: &[i8]) -> FunctionalInputs {
        let (k, n) = (job.shape.k, job.shape.n);
        let a_total: usize = job.calls.iter().map(|c| c.block.shape.m * k).sum();
        let b_total: usize = job.calls.iter().map(|c| k * c.block.shape.n).sum();
        let mut a_buf = Vec::with_capacity(a_total);
        let mut b_buf = Vec::with_capacity(b_total);
        let mut spans = Vec::with_capacity(job.calls.len());
        for call in job.calls.iter() {
            let blk = &call.block;
            let a_start = a_buf.len();
            for i in 0..blk.shape.m {
                let src = (blk.m_off + i) * k;
                a_buf.extend_from_slice(&a[src..src + k]);
            }
            let b_start = b_buf.len();
            for i in 0..k {
                let src = i * n + blk.n_off;
                b_buf.extend_from_slice(&b[src..src + blk.shape.n]);
            }
            spans.push((a_start..a_buf.len(), b_start..b_buf.len()));
        }
        FunctionalInputs { a: a_buf, b: b_buf, spans }
    }

    /// The (A-block, B-block) slices of one call.
    fn call(&self, idx: usize) -> (&[i8], &[i8]) {
        let (ra, rb) = &self.spans[idx];
        (&self.a[ra.clone()], &self.b[rb.clone()])
    }
}

/// Build the core clusters for a config, registering each cluster's
/// event sources with the scheduler.
fn build_clusters(
    cfg: &PlatformConfig,
    opts: &SimOptions,
    sched: &mut EventHeap,
) -> Vec<CoreCluster> {
    let mech = opts.mechanisms;
    let depth = if mech.prefetch { cfg.mem.d_stream.max(2) } else { 1 };
    (0..cfg.cores)
        .map(|k| CoreCluster {
            idx: k,
            core: GemmCore::new(cfg.core, opts.functional),
            csr: CsrManager::with_base(mech.config_preloading, core_csr_base(k)),
            a_stream: InputStreamer::new(depth, mech.prefetch),
            b_stream: InputStreamer::new(depth, mech.prefetch),
            c_stream: OutputStreamer::new(depth),
            addr_a: Vec::with_capacity(64),
            addr_b: Vec::with_capacity(64),
            addr_c: Vec::with_capacity(64),
            addr_dma: Vec::with_capacity(64),
            next_call: k,
            running_call: None,
            run_active: false,
            run_start_cycle: 0,
            dma: None,
            src: ClusterSources {
                a_deliver: sched.register("a_deliver"),
                b_deliver: sched.register("b_deliver"),
                c_deliver: sched.register("c_deliver"),
                a_issue: sched.register("a_issue"),
                b_issue: sched.register("b_issue"),
                c_issue: sched.register("c_issue"),
                dma: sched.register("dma"),
            },
        })
        .collect()
}

/// Refresh every streamer event source of one cluster. Called at each
/// point a streamer's schedule can change (delivery fired, fetch/write
/// committed, tile consumed, launch, run end); [`EventHeap::set`] is a
/// no-op for unchanged values, so over-calling is cheap and safe —
/// there is no memo to invalidate and no staleness to manage.
fn push_sources(sched: &mut EventHeap, cl: &CoreCluster) {
    let a_starved = cl.core.busy() && cl.a_stream.head().is_none();
    let b_starved = cl.core.busy() && cl.b_stream.head().is_none();
    sched.set(cl.src.a_deliver, cl.a_stream.next_delivery());
    sched.set(cl.src.b_deliver, cl.b_stream.next_delivery());
    sched.set(cl.src.c_deliver, cl.c_stream.next_delivery());
    sched.set(cl.src.a_issue, cl.a_stream.next_issue(a_starved));
    sched.set(cl.src.b_issue, cl.b_stream.next_issue(b_starved));
    sched.set(cl.src.c_issue, cl.c_stream.next_issue());
}

/// Program a cluster's streamers and start its core (on a DMA platform
/// this is deferred until staging completes).
fn start_core(cfg: &PlatformConfig, sched: &mut EventHeap, cl: &mut CoreCluster, regs: &ConfigRegs) {
    let word = cfg.mem.word_bytes();
    let bounds = regs.bounds();
    let wb = word as u64;
    let nb = cfg.mem.n_bank;
    cl.a_stream.configure2(regs.a_agu(&cfg.core, word), bounds, wb, nb);
    cl.b_stream.configure2(regs.b_agu(&cfg.core, word), bounds, wb, nb);
    cl.c_stream.configure2(regs.c_agu(&cfg.core, word), wb, nb);
    cl.core.start(bounds).expect("loop bounds validated at compile time");
    push_sources(sched, cl);
}

/// The platform.
pub struct Platform {
    pub cfg: PlatformConfig,
    pub opts: SimOptions,
    spm: Spm,
    clusters: Vec<CoreCluster>,
    host: Option<Cpu>,
    host_stall: u64,
    now: u64,
    /// Operand-staging scratch: recycled tile buffers for the
    /// functional data plane (see [`TileArena`]). Survives
    /// [`Platform::reset_for_job`] so back-to-back jobs allocate
    /// nothing.
    arena: TileArena,
    pub metrics: SimMetrics,
    /// `cycle()` invocations actually executed this run — equals
    /// `metrics.total_cycles` in lockstep mode, (much) smaller with
    /// fast-forward. Host-effort telemetry only; not part of the
    /// simulated-hardware metrics.
    pub steps_executed: u64,
    /// The wakeup heap (see [`sched`]). Sources push absolute cycles;
    /// `next_event` clamps the minimum to `now + 1`.
    sched: EventHeap,
    /// Host stall-horizon source: armed at the absolute expiry of the
    /// current CSR-handshake stall when it is charged, disarmed on
    /// halt. The armed time never changes while the stall drains, so
    /// no per-advance refresh is needed.
    src_host: SourceId,
    // job state
    job: Option<JobState>,
}

impl Platform {
    pub fn new(cfg: PlatformConfig, opts: SimOptions) -> Platform {
        cfg.validate().expect("invalid platform config");
        let mut sched = EventHeap::new();
        let clusters = build_clusters(&cfg, &opts, &mut sched);
        let src_host = sched.register("host");
        Platform {
            spm: Spm::new(cfg.mem),
            clusters,
            host: None,
            host_stall: 0,
            now: 0,
            arena: TileArena::new(),
            metrics: SimMetrics::default(),
            steps_executed: 0,
            sched,
            src_host,
            cfg,
            opts,
            job: None,
        }
    }

    /// Run a compiled job to completion. `a`/`b` are the parent operand
    /// matrices (row-major, true dims) in functional mode.
    pub fn run_job(
        &mut self,
        job: &CompiledJob,
        a: Option<&[i8]>,
        b: Option<&[i8]>,
    ) -> Result<JobResult, SimError> {
        assert_eq!(
            job.cores, self.cfg.cores,
            "job compiled for {} cores, platform has {}",
            job.cores, self.cfg.cores
        );
        let (m, k, n) = (job.shape.m, job.shape.k, job.shape.n);
        let functional = self.opts.functional;
        if functional {
            assert_eq!(a.map(|x| x.len()), Some(m * k), "A operand size");
            assert_eq!(b.map(|x| x.len()), Some(k * n), "B operand size");
        }

        // Pre-slice per-call operand blocks once, into flat buffers.
        let functional_inputs =
            functional.then(|| FunctionalInputs::slice(job, a.unwrap(), b.unwrap()));

        self.reset_run_state();
        self.job = Some(JobState {
            calls: Arc::clone(&job.calls),
            functional_inputs,
            c_out: functional.then(|| vec![0i32; m * n]),
            parent_m: m,
            parent_n: n,
        });
        self.host = Some(Cpu::new(job.program.clone(), 1 << 16));

        let fast_forward = self.opts.fast_forward;
        while !self.finished() {
            if fast_forward {
                if let Some(t) = self.next_event() {
                    self.advance_to(t);
                }
            }
            self.cycle()?;
            if self.metrics.total_cycles > self.opts.max_cycles {
                return Err(SimError::CycleLimit(self.opts.max_cycles));
            }
        }

        let job_state = self.job.take().unwrap();
        let su = job.spatial_utilization(&self.cfg);
        self.metrics.spm = self.spm.stats.clone();
        let report = UtilizationReport::from_metrics(su, &self.metrics);
        Ok(JobResult { metrics: self.metrics.clone(), report, c: job_state.c_out })
    }

    /// Re-arm this platform for a new job with new options — the
    /// Coordinator's per-worker reuse path. Equivalent to constructing
    /// a fresh `Platform::new(cfg, opts)` except that the SPM storage
    /// and the tile arena keep their allocations; `run_job` rebuilds
    /// every piece of per-run state (clusters, scheduler, metrics)
    /// regardless, and the layout packers fully overwrite every SPM
    /// region a functional run reads.
    pub fn reset_for_job(&mut self, opts: SimOptions) {
        self.opts = opts;
        self.host = None;
        self.job = None;
    }

    fn reset_run_state(&mut self) {
        self.sched = EventHeap::new();
        self.clusters = build_clusters(&self.cfg, &self.opts, &mut self.sched);
        self.src_host = self.sched.register("host");
        self.host_stall = 0;
        self.now = 0;
        self.metrics = SimMetrics::default();
        self.steps_executed = 0;
        self.spm.reset_stats();
    }

    fn finished(&self) -> bool {
        let host_done = self.host.as_ref().map(|h| h.halted()).unwrap_or(true);
        host_done
            && self
                .clusters
                .iter()
                .all(|cl| !cl.csr.is_busy() && !cl.run_active && cl.dma.is_none())
    }

    /// Advance the platform one clock cycle.
    pub fn cycle(&mut self) -> Result<(), SimError> {
        self.now += 1;
        self.metrics.total_cycles += 1;
        self.steps_executed += 1;
        let now = self.now;
        let n_clusters = self.clusters.len();

        // ---- 1. deliver completed memory traffic --------------------
        for k in 0..n_clusters {
            let cl = &mut self.clusters[k];
            let fired = cl.a_stream.next_delivery().is_some_and(|t| t <= now)
                || cl.b_stream.next_delivery().is_some_and(|t| t <= now);
            cl.a_stream.deliver_ready(now);
            cl.b_stream.deliver_ready(now);
            let c_tile = cl.c_stream.deliver_ready(now);
            let c_fired = c_tile.is_some();
            if let Some(tile) = c_tile {
                self.commit_output_tile(k, tile);
            }
            if fired || c_fired {
                // a delivery freed a pipeline slot / queued a head:
                // this cluster's schedule changed
                push_sources(&mut self.sched, &self.clusters[k]);
            }
        }

        // ---- 2. issue new memory requests (per-streamer pipelines) --
        // Same-cycle bank claims accumulate across clusters; write-side
        // tracking is only needed when someone else (another cluster or
        // the DMA) can contend for write banks.
        let track_writes = n_clusters > 1 || self.cfg.dma.is_some();
        let mut read_banks = 0u64;
        let mut write_banks = 0u64;
        for k in 0..n_clusters {
            self.issue_memory(k, now, &mut read_banks, &mut write_banks, track_writes);
        }

        // ---- 3. core cycles -----------------------------------------
        for k in 0..n_clusters {
            let Platform { clusters, arena, metrics, sched, .. } = self;
            let cl = &mut clusters[k];
            match cl.core.step(&mut cl.a_stream, &mut cl.b_stream, &mut cl.c_stream, arena) {
                CoreEvent::Idle => metrics.idle_cycles += 1,
                CoreEvent::Stalled(reason) => {
                    use crate::gemm_core::StallReason::*;
                    match reason {
                        InputA => metrics.stall_input_a += 1,
                        InputB => metrics.stall_input_b += 1,
                        Output => metrics.stall_output += 1,
                    }
                }
                CoreEvent::Computed { finished, .. } => {
                    // a tile-MAC consumed input heads and may have
                    // queued an output tile — streamer occupancy changed
                    metrics.compute_cycles += 1;
                    if finished {
                        // run completion is gated on the output drain
                        debug_assert!(cl.run_active);
                    }
                    push_sources(sched, cl);
                }
            }
        }

        // ---- 4. run completion --------------------------------------
        for k in 0..n_clusters {
            let cl = &self.clusters[k];
            if cl.run_active && !cl.core.busy() && cl.c_stream.is_drained() && cl.dma.is_none() {
                self.finish_run(k);
            }
        }

        // ---- 5. accelerator starts ----------------------------------
        for k in 0..n_clusters {
            let cl = &self.clusters[k];
            if !cl.core.busy() && cl.dma.is_none() {
                if let Some(regs) = self.clusters[k].csr.take_start() {
                    self.launch(k, regs);
                }
            }
        }

        // ---- 5b. DMA staging bursts ---------------------------------
        // After launches (a fresh transfer bursts its first chunk this
        // very cycle) and sharing the cycle's write-bank claims: DMA
        // bursts contend with streamer writebacks issued above.
        if self.cfg.dma.is_some() {
            for k in 0..n_clusters {
                self.dma_step(k, now, &mut write_banks);
            }
        }

        // ---- 6. host cycle -------------------------------------------
        if self.host_stall > 0 {
            self.host_stall -= 1;
            self.metrics.host_csr_stall += 1;
        } else if let Some(host) = self.host.as_mut() {
            if !host.halted() {
                let mut bus = CountingBus { clusters: &mut self.clusters, accesses: 0 };
                match host.step(&mut bus) {
                    StepResult::Ran { cycles } => {
                        let extra = bus.accesses * self.opts.csr_latency;
                        self.host_stall = (cycles - 1) + extra;
                        self.metrics.host_instret += 1;
                        // arm the stall horizon at its absolute expiry
                        // (constant while the stall drains)
                        let wake = (self.host_stall > 0).then(|| now + self.host_stall + 1);
                        self.sched.set(self.src_host, wake);
                    }
                    StepResult::Halted => self.sched.set(self.src_host, None),
                    StepResult::Fault(f) => return Err(SimError::HostFault(f)),
                }
            }
        }

        Ok(())
    }

    /// The earliest absolute cycle `> self.now` at which the platform
    /// state can change, or `None` when no event is scheduled (a
    /// deadlocked platform; the caller then falls back to lockstep
    /// stepping and the runaway guard).
    ///
    /// Returning `self.now + 1` means "something can happen next cycle
    /// — simulate it"; any later value proves every cycle before it is
    /// a pure counter increment (see [`Platform::advance_to`]).
    ///
    /// Scheduled wakeups (deliveries, bank-gate expiries, DMA bursts,
    /// the host stall horizon) come from the [`EventHeap`]: each source
    /// pushed its time when it became known, so this is a heap peek,
    /// not a scan. Armed times are raw absolute cycles and may be in
    /// the past (a bank gate that expired while the streamer had
    /// nothing to issue); the clamp to `now + 1` resolves them, since
    /// `min(max(e_i, next)) == max(min(e_i), next)`.
    fn next_event(&mut self) -> Option<u64> {
        let next = self.now + 1;

        // Immediately-actionable states: the coming cycle must be
        // simulated for real.
        for cl in &self.clusters {
            if cl.core.pending(&cl.a_stream, &cl.b_stream, &cl.c_stream) == CorePending::Compute {
                return Some(next);
            }
            if cl.csr.has_fired_start() && !cl.core.busy() && cl.dma.is_none() {
                return Some(next); // a latched start launches next cycle
            }
            if cl.run_active && !cl.core.busy() && cl.c_stream.is_drained() && cl.dma.is_none() {
                return Some(next); // run completing
            }
        }
        if let Some(host) = self.host.as_ref() {
            if !host.halted() && self.host_stall == 0 {
                return Some(next); // host retires an instruction
            }
        }

        self.sched.next_wake().map(|t| t.max(next))
    }

    /// Fast-forward the clock to just before event time `t`,
    /// batch-accounting the skipped cycles exactly as `t - now - 1`
    /// no-op invocations of [`Platform::cycle`] would have: total /
    /// idle / stall counters (platform *and* core statistics, per
    /// cluster) and the host's CSR-stall budget. Must only be called
    /// with the `t` returned by [`Platform::next_event`].
    fn advance_to(&mut self, t: u64) {
        debug_assert!(t > self.now);
        let skip = t - (self.now + 1);
        if skip == 0 {
            return;
        }
        for cl in &mut self.clusters {
            match cl.core.pending(&cl.a_stream, &cl.b_stream, &cl.c_stream) {
                CorePending::Idle => self.metrics.add_idle(skip),
                CorePending::Stalled(reason) => {
                    self.metrics.add_stalls(reason, skip);
                    cl.core.account_stalls(reason, skip);
                }
                CorePending::Compute => unreachable!("fast-forward across a compute cycle"),
            }
        }
        if let Some(host) = self.host.as_ref() {
            if !host.halted() {
                debug_assert!(self.host_stall >= skip, "host wakes inside a fast-forward window");
                self.host_stall -= skip;
                self.metrics.add_host_csr_stalls(skip);
            }
        }
        self.now += skip;
        self.metrics.total_cycles += skip;
        self.metrics.ff_jumps += 1;
        self.metrics.ff_skipped_cycles += skip;
    }

    /// Per-streamer memory issue for one cluster. Each input streamer
    /// pipelines up to its buffer depth of outstanding tile fetches;
    /// its banks are busy for `max own-bank load` cycles per fetch, and
    /// a fetch issued the same cycle as an earlier read claim (the
    /// other input streamer, or any streamer of an earlier cluster)
    /// pays one arbitration cycle per shared bank group (the read
    /// crossbar serializes them). The output writer runs on the
    /// independent write-port network (banks are 1R1W); writebacks
    /// contend only with other write claims (other clusters, the DMA).
    fn issue_memory(
        &mut self,
        k: usize,
        now: u64,
        read_banks: &mut u64,
        write_banks: &mut u64,
        track_writes: bool,
    ) {
        let Platform { cfg, opts, spm, clusters, arena, sched, .. } = self;
        let cl = &mut clusters[k];
        let word = cfg.mem.word_bytes() as u64;
        let word_shift = spm.word_shift();
        let n_bank = cfg.mem.n_bank as u32;
        let rd_lat = cfg.mem.read_latency;
        let wr_lat = cfg.mem.write_latency;
        let a_starved = cl.core.busy() && cl.a_stream.head().is_none();
        let b_starved = cl.core.busy() && cl.b_stream.head().is_none();
        let functional = opts.functional;

        let a_issues = cl.a_stream.wants_fetch(now, a_starved);
        let b_issues = cl.b_stream.wants_fetch(now, b_starved);
        let c_issues = cl.c_stream.wants_write(now);

        // Timing-only fast path: the precomputed bank pattern gives the
        // access cost and bank mask without materializing addresses.
        if a_issues {
            let (mut cost, mask, pos, data) = match (functional, cl.a_stream.pattern) {
                (false, Some(p)) if !p.self_conflict => {
                    let (pos, base) = cl.a_stream.begin_fetch_timing();
                    let base_bank = ((base as u64) >> word_shift) & (n_bank - 1) as u64;
                    let mask = p.mask_at(base_bank as u32);
                    spm.note_fast_access(cl.a_stream.agu.ports() as u64, 1);
                    (1, mask, pos, None)
                }
                _ => {
                    let pos = cl.a_stream.begin_fetch(word, &mut cl.addr_a);
                    let cost = spm.read_cost(&cl.addr_a);
                    let mut mask = 0u64;
                    for &w in &cl.addr_a {
                        mask |= 1u64 << spm.bank_of(w);
                    }
                    let data =
                        functional.then(|| read_tile(spm, arena, word, &cl.addr_a));
                    (cost, mask, pos, data)
                }
            };
            if *read_banks & mask != 0 {
                // same-cycle arbitration against an earlier read claim
                cost += 1;
                spm.stats.conflict_cycles += 1;
            }
            *read_banks |= mask;
            cl.a_stream.commit_fetch(pos, data, now + cost + rd_lat - 1, now + cost);
        }
        if b_issues {
            let (mut cost, mask, pos, data) = match (functional, cl.b_stream.pattern) {
                (false, Some(p)) if !p.self_conflict => {
                    let (pos, base) = cl.b_stream.begin_fetch_timing();
                    let base_bank = ((base as u64) >> word_shift) & (n_bank - 1) as u64;
                    let mask = p.mask_at(base_bank as u32);
                    spm.note_fast_access(cl.b_stream.agu.ports() as u64, 1);
                    (1u64, mask, pos, None)
                }
                _ => {
                    let pos = cl.b_stream.begin_fetch(word, &mut cl.addr_b);
                    let cost = spm.read_cost(&cl.addr_b);
                    let mut mask = 0u64;
                    for &w in &cl.addr_b {
                        mask |= 1u64 << spm.bank_of(w);
                    }
                    let data =
                        functional.then(|| read_tile(spm, arena, word, &cl.addr_b));
                    (cost, mask, pos, data)
                }
            };
            if *read_banks & mask != 0 {
                cost += 1;
                spm.stats.conflict_cycles += 1;
            }
            *read_banks |= mask;
            cl.b_stream.commit_fetch(pos, data, now + cost + rd_lat - 1, now + cost);
        }
        if c_issues {
            match (functional, cl.c_stream.pattern) {
                (false, Some(p)) if !p.self_conflict => {
                    let (tile, base) = cl.c_stream.begin_write_timing();
                    spm.note_fast_access(cl.c_stream.agu.ports() as u64, 1);
                    let mut cost = 1u64;
                    if track_writes {
                        let base_bank = ((base as u64) >> word_shift) & (n_bank - 1) as u64;
                        let mask = p.mask_at(base_bank as u32);
                        if *write_banks & mask != 0 {
                            cost += 1;
                            spm.stats.conflict_cycles += 1;
                        }
                        *write_banks |= mask;
                    }
                    cl.c_stream.commit_write(tile, now + cost + wr_lat - 1, now + cost);
                }
                _ => {
                    let tile = cl.c_stream.begin_write(word, &mut cl.addr_c);
                    let mut cost = spm.write_cost(&cl.addr_c);
                    if track_writes {
                        let mut mask = 0u64;
                        for &w in &cl.addr_c {
                            mask |= 1u64 << spm.bank_of(w);
                        }
                        if *write_banks & mask != 0 {
                            cost += 1;
                            spm.stats.conflict_cycles += 1;
                        }
                        *write_banks |= mask;
                    }
                    cl.c_stream.commit_write(tile, now + cost + wr_lat - 1, now + cost);
                }
            }
        }
        if a_issues || b_issues || c_issues {
            // new fetches/writes scheduled new deliveries and bank gates
            push_sources(sched, cl);
        }
    }

    /// One DMA engine step for a cluster: burst the next chunk of the
    /// staged operand region into the SPM, or — once the region is
    /// fully staged and the last burst has drained — start the core
    /// with the held-back launch configuration.
    fn dma_step(&mut self, k: usize, now: u64, write_banks: &mut u64) {
        let Platform { cfg, spm, clusters, sched, .. } = self;
        let cl = &mut clusters[k];
        let Some(t) = cl.dma.as_mut() else { return };
        if now < t.ready_at {
            return;
        }
        if t.cursor < t.background.len() {
            let dma = cfg.dma.expect("transfer without DMA config");
            let chunk = dma.chunk_words.min(t.background.len() - t.cursor);
            let base = t.start_word + t.cursor as u64;
            cl.addr_dma.clear();
            cl.addr_dma.extend((0..chunk as u64).map(|i| base + i));
            let mut cost = spm.write_cost(&cl.addr_dma);
            let mut mask = 0u64;
            for &w in &cl.addr_dma {
                mask |= 1u64 << spm.bank_of(w);
            }
            if *write_banks & mask != 0 {
                // contends with this cycle's streamer writebacks
                cost += 1;
                spm.stats.conflict_cycles += 1;
            }
            *write_banks |= mask;
            spm.write_words(base, &t.background[t.cursor..t.cursor + chunk]);
            t.cursor += chunk;
            t.ready_at = now + dma.latency + cost;
            sched.set(cl.src.dma, Some(t.ready_at));
        } else {
            let done = cl.dma.take().expect("checked above");
            sched.set(cl.src.dma, None);
            start_core(cfg, sched, cl, &done.regs);
        }
    }

    /// Functional commit of a completed C' tile through the C AGU; the
    /// tile buffer returns to the arena afterwards.
    fn commit_output_tile(&mut self, k: usize, tile: OutTile) {
        let Some(data) = tile.data else { return };
        let word = self.cfg.mem.word_bytes() as u64;
        let agu = self.clusters[k].c_stream.agu;
        let per_word = (word / 4) as usize;
        for port in 0..agu.ports() as u64 {
            let byte = agu.byte_addr(tile.m1, tile.n1, 0, port);
            let idx = port as usize * per_word;
            if idx < data.len() {
                let end = (idx + per_word).min(data.len());
                self.spm.write_i32(byte, &data[idx..end]);
            }
        }
        self.arena.release_i32(data);
    }

    /// A start fired on cluster `k`: account the launch, place operands,
    /// and either start the core directly or hand the call to the DMA.
    fn launch(&mut self, k: usize, regs: ConfigRegs) {
        let Platform { cfg, spm, clusters, metrics, sched, job, now, .. } = self;
        let cl = &mut clusters[k];
        let job = job.as_mut().expect("start without a job");
        let call_idx = cl.next_call;
        debug_assert!(call_idx < job.calls.len(), "start on a coreless call slot");
        // round-robin cursor: this cluster's calls are idx, idx+cores,
        // ...; wrap to idx for the next repeat
        cl.next_call = if cl.next_call + cfg.cores >= job.calls.len() {
            cl.idx
        } else {
            cl.next_call + cfg.cores
        };
        cl.running_call = Some(call_idx);
        cl.run_active = true;
        cl.run_start_cycle = metrics.total_cycles;
        metrics.starts += 1;

        // Place this call's operands (functional mode only; zero
        // simulated cycles — on DMA platforms the *timing* of the load
        // is modeled by the staging bursts below, which rewrite the
        // same words).
        if let Some(inputs) = job.functional_inputs.as_ref() {
            let call = &job.calls[call_idx];
            let (asub, bsub) = inputs.call(call_idx);
            layout::pack_a(spm, cfg, &call.placement, asub, call.block.shape.m, call.block.shape.k);
            layout::pack_b(spm, cfg, &call.placement, bsub, call.block.shape.k, call.block.shape.n);
        }

        if cfg.dma.is_some() {
            // Snapshot the call's operand region (everything below the
            // C base) as the background-memory image and stage it in
            // bursts; the core starts when staging completes.
            let word = cfg.mem.word_bytes() as u64;
            let a_base = regs.regs[(CSR_A_BASE - CSR_BASE) as usize] as u64;
            let b_base = regs.regs[(CSR_B_BASE - CSR_BASE) as usize] as u64;
            let c_base = regs.regs[(CSR_C_BASE - CSR_BASE) as usize] as u64;
            let start_word = a_base.min(b_base) / word;
            let end_word = c_base.div_ceil(word);
            let mut background = vec![0u64; (end_word - start_word) as usize];
            spm.read_words(start_word, &mut background);
            cl.dma = Some(DmaTransfer { regs, background, start_word, cursor: 0, ready_at: *now });
            // first burst issues this very cycle (phase 5b)
            sched.set(cl.src.dma, Some(*now));
        } else {
            start_core(cfg, sched, cl, &regs);
        }
    }

    fn finish_run(&mut self, k: usize) {
        let Platform { cfg, spm, clusters, metrics, sched, job, .. } = self;
        let cl = &mut clusters[k];
        let job = job.as_mut().expect("run completion without a job");
        let call_idx = cl.running_call.take().expect("no running call");
        cl.run_active = false;
        metrics.kernel_cycles += metrics.total_cycles - cl.run_start_cycle;
        metrics.runs_completed += 1;

        // collect functional results into the parent C
        if let Some(c_out) = job.c_out.as_mut() {
            let call = &job.calls[call_idx];
            let c = layout::unpack_c(spm, cfg, &call.placement, call.block.shape.m, call.block.shape.n);
            let n = job.parent_n;
            for i in 0..call.block.shape.m {
                for j in 0..call.block.shape.n {
                    c_out[(call.block.m_off + i) * n + (call.block.n_off + j)] =
                        c[i * call.block.shape.n + j];
                }
            }
            debug_assert!(call.block.m_off + call.block.shape.m <= job.parent_m);
        }

        // CPL: a pre-loaded start may fire instantly
        cl.csr.notify_done();
        // core no longer busy: starvation gates flip
        push_sources(sched, cl);
    }
}

/// Bulk functional tile fetch: one gathered word read per port into
/// an arena-recycled buffer (the seed allocated a fresh `Box` and
/// resolved the word mapping per byte).
fn read_tile(spm: &Spm, arena: &mut TileArena, word: u64, word_addrs: &[u64]) -> Box<[i8]> {
    let mut out = arena.acquire_i8(word_addrs.len() * word as usize);
    spm.read_ports_i8(word_addrs, word as usize, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile_gemm, GemmShape, Layout};
    use crate::config::DmaParams;
    use crate::util::rng::Pcg32;

    fn run(
        shape: GemmShape,
        layout: Layout,
        mech: Mechanisms,
        repeats: u32,
        functional: bool,
    ) -> (JobResult, CompiledJob) {
        run_mode(shape, layout, mech, repeats, functional, true)
    }

    fn run_mode(
        shape: GemmShape,
        layout: Layout,
        mech: Mechanisms,
        repeats: u32,
        functional: bool,
        fast_forward: bool,
    ) -> (JobResult, CompiledJob) {
        run_cfg_mode(PlatformConfig::case_study(), shape, layout, mech, repeats, functional, fast_forward)
    }

    fn run_cfg_mode(
        cfg: PlatformConfig,
        shape: GemmShape,
        layout: Layout,
        mech: Mechanisms,
        repeats: u32,
        functional: bool,
        fast_forward: bool,
    ) -> (JobResult, CompiledJob) {
        let job = compile_gemm(&cfg, shape, layout, repeats, mech.config_preloading).unwrap();
        let opts = SimOptions { mechanisms: mech, functional, fast_forward, ..Default::default() };
        let mut platform = Platform::new(cfg, opts);
        let (a, b) = if functional {
            let mut rng = Pcg32::seeded(42);
            let mut a = vec![0i8; shape.m * shape.k];
            let mut b = vec![0i8; shape.k * shape.n];
            rng.fill_i8(&mut a);
            rng.fill_i8(&mut b);
            (Some(a), Some(b))
        } else {
            (None, None)
        };
        let res = platform.run_job(&job, a.as_deref(), b.as_deref()).unwrap();
        (res, job)
    }

    fn naive_gemm(a: &[i8], b: &[i8], m: usize, k: usize, n: usize) -> Vec<i32> {
        let mut c = vec![0i32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0i32;
                for kk in 0..k {
                    acc = acc
                        .wrapping_add((a[i * k + kk] as i32).wrapping_mul(b[kk * n + j] as i32));
                }
                c[i * n + j] = acc;
            }
        }
        c
    }

    fn seeded_operands(shape: GemmShape) -> (Vec<i8>, Vec<i8>) {
        let mut rng = Pcg32::seeded(42);
        let mut a = vec![0i8; shape.m * shape.k];
        let mut b = vec![0i8; shape.k * shape.n];
        rng.fill_i8(&mut a);
        rng.fill_i8(&mut b);
        (a, b)
    }

    #[test]
    fn functional_gemm_matches_naive() {
        let shape = GemmShape::new(13, 22, 17);
        let (res, _) = run(shape, Layout::TiledInterleaved, Mechanisms::ALL, 1, true);
        let (a, b) = seeded_operands(shape);
        assert_eq!(res.c.unwrap(), naive_gemm(&a, &b, 13, 22, 17));
    }

    #[test]
    fn functional_gemm_row_major_layout() {
        let shape = GemmShape::new(32, 40, 24);
        let (res, _) = run(shape, Layout::RowMajor, Mechanisms::BASELINE, 1, true);
        let (a, b) = seeded_operands(shape);
        assert_eq!(res.c.unwrap(), naive_gemm(&a, &b, 32, 40, 24));
    }

    #[test]
    fn functional_split_job_matches_naive() {
        // 256^3 splits into multiple calls
        let shape = GemmShape::new(256, 64, 256);
        let (res, job) = run(shape, Layout::TiledInterleaved, Mechanisms::ALL, 1, true);
        assert!(job.calls.len() >= 1);
        let (a, b) = seeded_operands(shape);
        assert_eq!(res.c.unwrap(), naive_gemm(&a, &b, 256, 64, 256));
    }

    #[test]
    fn multicore_functional_matches_naive() {
        let mut cfg = PlatformConfig::case_study();
        cfg.cores = 2;
        let shape = GemmShape::new(256, 64, 256);
        let (res, job) =
            run_cfg_mode(cfg, shape, Layout::TiledInterleaved, Mechanisms::ALL, 1, true, true);
        assert!(job.calls.len() >= 2, "shape must split across cores");
        let (a, b) = seeded_operands(shape);
        assert_eq!(res.c.unwrap(), naive_gemm(&a, &b, 256, 64, 256));
    }

    #[test]
    fn dma_staging_preserves_results_and_adds_cycles() {
        let shape = GemmShape::new(64, 64, 64);
        let mut cfg = PlatformConfig::case_study();
        cfg.dma = Some(DmaParams { chunk_words: 8, latency: 4 });
        let (dma, _) =
            run_cfg_mode(cfg, shape, Layout::TiledInterleaved, Mechanisms::ALL, 1, true, true);
        let (plain, _) = run(shape, Layout::TiledInterleaved, Mechanisms::ALL, 1, true);
        let (a, b) = seeded_operands(shape);
        let expect = naive_gemm(&a, &b, 64, 64, 64);
        assert_eq!(plain.c.as_ref().unwrap(), &expect);
        assert_eq!(dma.c.as_ref().unwrap(), &expect, "staging must be functionally transparent");
        assert!(
            dma.metrics.total_cycles > plain.metrics.total_cycles,
            "staging must cost cycles: {} vs {}",
            dma.metrics.total_cycles,
            plain.metrics.total_cycles
        );
        assert_eq!(dma.metrics.compute_cycles, plain.metrics.compute_cycles);
    }

    #[test]
    fn multicore_beats_single_core_on_split_jobs() {
        let shape = GemmShape::new(256, 128, 256);
        let (single, job1) =
            run(shape, Layout::TiledInterleaved, Mechanisms::ALL, 2, false);
        let mut cfg = PlatformConfig::case_study();
        cfg.cores = 2;
        let (multi, job2) =
            run_cfg_mode(cfg, shape, Layout::TiledInterleaved, Mechanisms::ALL, 2, false, true);
        assert!(job1.calls.len() >= 2 && job2.calls.len() >= 2);
        assert!(
            multi.metrics.total_cycles < single.metrics.total_cycles,
            "2 cores must beat 1 on a multi-call job: {} vs {}",
            multi.metrics.total_cycles,
            single.metrics.total_cycles
        );
        // same work either way
        assert_eq!(multi.metrics.compute_cycles, single.metrics.compute_cycles);
    }

    #[test]
    fn engines_bit_identical_across_cores_and_dma() {
        // the exhaustive randomized grid lives in
        // tests/platform_properties.rs; this smokes the heap engine vs
        // lockstep over the new platform dimensions
        for cores in [1usize, 2, 4] {
            for dma in [None, Some(DmaParams { chunk_words: 16, latency: 2 })] {
                let mut cfg = PlatformConfig::case_study();
                cfg.cores = cores;
                cfg.dma = dma;
                let shape = GemmShape::new(96, 64, 96);
                let (ff, _) = run_cfg_mode(
                    cfg.clone(),
                    shape,
                    Layout::TiledInterleaved,
                    Mechanisms::ALL,
                    2,
                    false,
                    true,
                );
                let (ls, _) = run_cfg_mode(
                    cfg,
                    shape,
                    Layout::TiledInterleaved,
                    Mechanisms::ALL,
                    2,
                    false,
                    false,
                );
                assert_eq!(
                    ff.metrics, ls.metrics,
                    "engines diverge at cores={cores} dma={dma:?}"
                );
                assert_eq!(ff.report, ls.report, "reports diverge at cores={cores}");
            }
        }
    }

    #[test]
    fn mechanisms_strictly_improve_utilization() {
        let shape = GemmShape::new(128, 128, 128);
        let (r1, _) = run(shape, Layout::RowMajor, Mechanisms::BASELINE, 10, false);
        let (r2, _) = run(shape, Layout::RowMajor, Mechanisms::CPL, 10, false);
        let (r3, _) = run(shape, Layout::RowMajor, Mechanisms::CPL_BUF, 10, false);
        let (r4, _) = run(shape, Layout::TiledInterleaved, Mechanisms::ALL, 10, false);
        let u = |r: &JobResult| r.report.overall;
        assert!(u(&r2) >= u(&r1), "CPL must not hurt: {} vs {}", u(&r2), u(&r1));
        assert!(u(&r3) > u(&r2), "prefetch must help: {} vs {}", u(&r3), u(&r2));
        assert!(u(&r4) > u(&r3), "SMA must help: {} vs {}", u(&r4), u(&r3));
        assert!(u(&r4) > 0.85, "full mechanisms should approach peak: {}", u(&r4));
    }

    #[test]
    fn compute_cycles_equal_ideal_times_repeats() {
        let shape = GemmShape::new(64, 64, 64);
        let (res, job) = run(shape, Layout::TiledInterleaved, Mechanisms::ALL, 10, false);
        let cfg = PlatformConfig::case_study();
        assert_eq!(res.metrics.compute_cycles, job.ideal_cycles(&cfg) * 10);
        assert_eq!(res.metrics.starts, 10);
        assert_eq!(res.metrics.runs_completed, 10);
    }

    #[test]
    fn aligned_all_mech_utilization_near_one() {
        let shape = GemmShape::new(128, 128, 128);
        let (res, _) = run(shape, Layout::TiledInterleaved, Mechanisms::ALL, 10, false);
        assert!(
            res.report.overall > 0.9,
            "expected near-peak utilization, got {:?}",
            res.report
        );
    }

    #[test]
    fn baseline_utilization_is_much_lower() {
        let shape = GemmShape::new(64, 64, 64);
        let (res, _) = run(shape, Layout::RowMajor, Mechanisms::BASELINE, 10, false);
        assert!(
            res.report.overall < 0.5,
            "baseline should be slow, got {:?}",
            res.report
        );
    }

    #[test]
    fn fast_forward_matches_lockstep_smoke() {
        // the exhaustive randomized grid lives in
        // tests/platform_properties.rs; this pins a few known-tricky
        // corners (deep-K stalls, config-bound tiny shapes, splits)
        let cases = [
            (GemmShape::new(16, 256, 16), Layout::RowMajor, Mechanisms::BASELINE, 3),
            (GemmShape::new(8, 8, 8), Layout::TiledInterleaved, Mechanisms::BASELINE, 10),
            (GemmShape::new(64, 64, 64), Layout::TiledInterleaved, Mechanisms::ALL, 10),
            (GemmShape::new(48, 40, 56), Layout::TiledContiguous, Mechanisms::CPL_BUF, 2),
            (GemmShape::new(256, 64, 256), Layout::TiledInterleaved, Mechanisms::ALL, 1),
        ];
        for (shape, layout, mech, repeats) in cases {
            let (ff, _) = run_mode(shape, layout, mech, repeats, false, true);
            let (ls, _) = run_mode(shape, layout, mech, repeats, false, false);
            assert_eq!(
                ff.metrics, ls.metrics,
                "fast-forward metrics diverge for {shape:?} {layout:?} {}",
                mech.label()
            );
            assert_eq!(ff.report, ls.report, "reports diverge for {shape:?}");
        }
    }

    #[test]
    fn fast_forward_skips_cycles_in_bulk() {
        // on a stall-heavy workload (no prefetch, deep K, conflicting
        // row-major layout) the engine must execute far fewer `cycle()`
        // steps than simulated cycles — that ratio is the speedup lever
        let cfg = PlatformConfig::case_study();
        let job =
            compile_gemm(&cfg, GemmShape::new(16, 256, 16), Layout::RowMajor, 3, false).unwrap();
        let opts = SimOptions {
            mechanisms: Mechanisms::BASELINE,
            fast_forward: true,
            ..Default::default()
        };
        let mut platform = Platform::new(cfg, opts);
        platform.run_job(&job, None, None).unwrap();
        let total = platform.metrics.total_cycles;
        let steps = platform.steps_executed;
        assert!(
            steps * 2 < total,
            "expected >50% of cycles skipped, got {steps} steps for {total} cycles"
        );
        assert!(platform.metrics.ff_jumps > 0, "jumps must be counted");
        assert_eq!(
            platform.metrics.ff_skipped_cycles,
            total - steps,
            "skipped + stepped must cover the run"
        );
    }

    #[test]
    fn tiny_gemm_dominated_by_config() {
        let shape = GemmShape::new(8, 8, 8);
        let (res, _) = run(shape, Layout::TiledInterleaved, Mechanisms::BASELINE, 10, false);
        // 10 tile-MACs of work under hundreds of config cycles
        assert!(res.report.temporal < 0.1, "{:?}", res.report);
    }
}
