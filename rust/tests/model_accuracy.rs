//! The analytical cost model's accuracy contract, pinned as a
//! regression oracle for both tiers:
//!
//! 1. A seeded randomized grid — GeMM shapes x mechanism/layout
//!    regimes x (Mu, Nu, Ku) core instances — where predicted
//!    total-cycle error against the cycle-accurate engine must hold
//!    median |err| <= 5% and p95 |err| <= 15%. A change to the event
//!    engine that silently shifts cycle counts trips this bound just as
//!    surely as a regression in the model itself.
//! 2. The prefilter differential: the variants a
//!    `--prefilter analytical --confirm-top K` sweep confirms must be
//!    byte-identical (wire JSON included) to the same variants of an
//!    unfiltered sweep — pruning may only remove work, never perturb it.

use opengemm::compiler::Layout;
use opengemm::config::{Mechanisms, PlatformConfig};
use opengemm::coordinator::shard::{run_sweep, SweepOptions};
use opengemm::coordinator::{Coordinator, JobRequest};
use opengemm::experiments::fig5::{variant_config, variant_specs};
use opengemm::model::{predict_with, prefilter};
use opengemm::workloads::random_suite;

/// A generator point, scaled like `examples/dse_sweep.rs`: memory
/// ports grow with the array so the instance still elaborates.
fn instance(mu: usize, nu: usize, ku: usize) -> PlatformConfig {
    let mut cfg = PlatformConfig::case_study();
    cfg.core.mu = mu;
    cfg.core.nu = nu;
    cfg.core.ku = ku;
    let need_read = cfg.core.a_tile_bytes() + cfg.core.b_tile_bytes();
    cfg.mem.r_mem = need_read.div_ceil(cfg.mem.word_bytes()).next_power_of_two();
    cfg.mem.w_mem = (cfg.core.c_tile_bytes().div_ceil(cfg.mem.word_bytes()))
        .next_power_of_two()
        .max(4);
    cfg.mem.n_bank = cfg.mem.n_bank.max(cfg.mem.r_mem.next_power_of_two());
    cfg.validate().expect("generator point elaborates");
    cfg
}

/// The mechanism ladder paired with every layout the compiler accepts
/// for it (`JobRequest::timing` picks one canonical layout; the model
/// must hold on the rest too).
fn regimes() -> Vec<(Mechanisms, Layout)> {
    vec![
        (Mechanisms::BASELINE, Layout::RowMajor),
        (Mechanisms::BASELINE, Layout::TiledContiguous),
        (Mechanisms::CPL, Layout::TiledContiguous),
        (Mechanisms::CPL_BUF, Layout::TiledContiguous),
        (Mechanisms::CPL_BUF, Layout::TiledInterleaved),
        (Mechanisms::ALL, Layout::TiledInterleaved),
    ]
}

#[test]
fn predicted_cycles_track_simulated_cycles() {
    let csr_latency = SweepOptions::default().csr_latency;
    let instances = [instance(8, 8, 8), instance(4, 4, 8), instance(8, 8, 16)];
    let shapes = random_suite(99, 6);
    let mut errors: Vec<f64> = Vec::new();
    let mut worst: (f64, String) = (0.0, String::new());
    for cfg in &instances {
        let coordinator = Coordinator::new(cfg.clone()).with_workers(2);
        for &shape in &shapes {
            for &(mechanisms, layout) in &regimes() {
                let req = JobRequest { shape, layout, mechanisms, repeats: 2, operands: None };
                let ctx = format!(
                    "({},{},{}) {shape:?} {} {layout:?}",
                    cfg.core.mu,
                    cfg.core.nu,
                    cfg.core.ku,
                    mechanisms.label(),
                );
                let pred = predict_with(cfg, &req, csr_latency)
                    .unwrap_or_else(|e| panic!("{ctx}: does not compile: {e}"));
                let sim = coordinator
                    .run_one(&req)
                    .unwrap_or_else(|e| panic!("{ctx}: simulation failed: {e}"));
                // Exact sub-accountings first: these are bookkeeping,
                // not modeling, and must never drift.
                assert_eq!(
                    pred.compute_cycles, sim.metrics.compute_cycles,
                    "{ctx}: ideal-compute accounting"
                );
                assert_eq!(
                    pred.spm_traffic_words, sim.metrics.spm.word_requests,
                    "{ctx}: SPM traffic accounting"
                );
                let err = pred.cycle_error(sim.metrics.total_cycles).abs();
                if err > worst.0 {
                    worst = (err, ctx);
                }
                errors.push(err);
            }
        }
    }
    errors.sort_by(f64::total_cmp);
    let median = prefilter::percentile(&errors, 0.5);
    let p95 = prefilter::percentile(&errors, 0.95);
    assert!(
        median <= 0.05,
        "median |cycle error| {median:.4} > 5% over {} points (worst {:.4} at {})",
        errors.len(),
        worst.0,
        worst.1
    );
    assert!(
        p95 <= 0.15,
        "p95 |cycle error| {p95:.4} > 15% over {} points (worst {:.4} at {})",
        errors.len(),
        worst.0,
        worst.1
    );
}

/// Build the pinned small grid the CI `model-smoke` lane also runs:
/// the first four Fig. 5 ladder rungs (each a distinct mechanism, so
/// medians are well-separated) over a seeded workload suite.
fn pinned_grid(repeats: u32) -> Vec<prefilter::GridVariant> {
    let base = PlatformConfig::case_study();
    let shapes = random_suite(13, 10);
    variant_specs()
        .into_iter()
        .take(4)
        .map(|(label, mech, depth)| prefilter::GridVariant {
            label: label.to_string(),
            cfg: variant_config(&base, depth),
            requests: shapes.iter().map(|&s| JobRequest::timing(s, mech, repeats)).collect(),
        })
        .collect()
}

#[test]
fn prefilter_frontier_is_byte_identical_to_the_unfiltered_run() {
    let sweep_opts = SweepOptions { workers: 2, ..Default::default() };
    let grid = pinned_grid(2);
    // Unfiltered: simulate every variant.
    let full: Vec<_> = grid
        .iter()
        .map(|gv| run_sweep(&gv.cfg, gv.requests.clone(), sweep_opts))
        .collect();
    // Prefiltered: rank analytically, confirm only the frontier.
    let ranked = prefilter::rank(&grid, sweep_opts.csr_latency);
    let keep = prefilter::frontier(&ranked, prefilter::confirm_count(grid.len(), Some(1), None));
    assert_eq!(keep.len(), 1);
    // fraction_simulated on the pinned grid: 1 of 4 variants = 25%,
    // the model-smoke ceiling.
    assert!(keep.len() as f64 <= 0.25 * grid.len() as f64);
    for &i in &keep {
        let confirmed = run_sweep(&grid[i].cfg, grid[i].requests.clone(), sweep_opts);
        // The confirmation run is the unfiltered run's slice, down to
        // the serialized wire bytes the sweep documents carry.
        assert_eq!(
            confirmed.to_json().pretty(),
            full[i].to_json().pretty(),
            "variant {i} ({}) diverged under the prefilter",
            grid[i].label
        );
    }
    // With distinct mechanisms per rung the ranking is unambiguous:
    // the predicted winner IS the simulated winner.
    let sim_best = (0..grid.len())
        .max_by(|&a, &b| median_overall(&full[a]).total_cmp(&median_overall(&full[b])))
        .unwrap();
    assert_eq!(
        keep[0], sim_best,
        "prefilter confirmed {} but the unfiltered winner is {}",
        grid[keep[0]].label, grid[sim_best].label
    );
}

fn median_overall(result: &opengemm::coordinator::shard::SweepResult) -> f64 {
    let mut overall: Vec<f64> = result
        .outcomes
        .iter()
        .filter_map(|o| o.as_ref().ok().map(|r| r.report.overall))
        .collect();
    overall.sort_by(f64::total_cmp);
    prefilter::percentile(&overall, 0.5)
}
