//! Bench: wall-clock speedup of the analytical DSE prefilter over full
//! simulation on the pinned model-smoke grid (the first four Fig. 5
//! ladder rungs x a seeded workload suite).
//!
//! Emits BENCH_analytical_prefilter.json at the repo root: grid size,
//! fraction simulated, per-prediction cost, and the measured wall-clock
//! speedup of `--prefilter analytical --confirm-top 1` vs simulating
//! everything.
//!
//! Run with:  cargo bench --bench prefilter_speedup [-- --smoke]

use std::time::Instant;

use opengemm::config::PlatformConfig;
use opengemm::coordinator::shard::{run_sweep, SweepOptions, SweepResult};
use opengemm::coordinator::JobRequest;
use opengemm::experiments::fig5::{variant_config, variant_specs};
use opengemm::model::prefilter;
use opengemm::util::json::Json;
use opengemm::workloads::random_suite;

fn median_overall(result: &SweepResult) -> f64 {
    let mut overall: Vec<f64> = result
        .outcomes
        .iter()
        .filter_map(|o| o.as_ref().ok().map(|r| r.report.overall))
        .collect();
    overall.sort_by(f64::total_cmp);
    prefilter::percentile(&overall, 0.5)
}

fn artifact_path(name: &str) -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("package root has a parent")
        .join(name)
}

fn main() {
    let smoke = std::env::args().any(|arg| arg == "--smoke");
    let workloads = if smoke { 12 } else { 60 };
    let repeats: u32 = if smoke { 2 } else { 5 };
    let sweep_opts = SweepOptions::default();
    let base = PlatformConfig::case_study();
    let shapes = random_suite(13, workloads);
    let grid: Vec<prefilter::GridVariant> = variant_specs()
        .into_iter()
        .take(4)
        .map(|(label, mech, depth)| prefilter::GridVariant {
            label: label.to_string(),
            cfg: variant_config(&base, depth),
            requests: shapes.iter().map(|&s| JobRequest::timing(s, mech, repeats)).collect(),
        })
        .collect();
    let grid_jobs: usize = grid.iter().map(|g| g.requests.len()).sum();
    eprintln!(
        "prefilter bench: {} variants x {} workloads ({} jobs, {} repeats)",
        grid.len(),
        workloads,
        grid_jobs,
        repeats
    );

    // Baseline: simulate every variant of the grid.
    let t0 = Instant::now();
    let full: Vec<SweepResult> = grid
        .iter()
        .map(|gv| run_sweep(&gv.cfg, gv.requests.clone(), sweep_opts))
        .collect();
    let full_s = t0.elapsed().as_secs_f64();

    // Prefiltered: rank the whole grid analytically, simulate only the
    // top-1 variant.
    let t1 = Instant::now();
    let ranked = prefilter::rank(&grid, sweep_opts.csr_latency);
    let rank_s = t1.elapsed().as_secs_f64();
    let keep = prefilter::frontier(&ranked, 1);
    let confirmed: Vec<SweepResult> = keep
        .iter()
        .map(|&i| run_sweep(&grid[i].cfg, grid[i].requests.clone(), sweep_opts))
        .collect();
    let prefilter_s = t1.elapsed().as_secs_f64();

    let simulated_jobs: usize = confirmed.iter().map(|r| r.outcomes.len()).sum();
    let fraction = simulated_jobs as f64 / grid_jobs as f64;
    let speedup = full_s / prefilter_s.max(1e-9);
    let us_per_prediction = rank_s * 1e6 / grid_jobs as f64;
    let sim_best = (0..grid.len())
        .max_by(|&a, &b| median_overall(&full[a]).total_cmp(&median_overall(&full[b])))
        .expect("grid is non-empty");
    let top1_matches = keep[0] == sim_best;
    let frontier_identical = keep
        .iter()
        .zip(&confirmed)
        .all(|(&i, c)| c.to_json().pretty() == full[i].to_json().pretty());

    eprintln!(
        "  full sweep {full_s:.3}s | prefilter {prefilter_s:.3}s \
         (ranking {:.1}us/job) -> {speedup:.2}x, {:.1}% simulated",
        us_per_prediction,
        fraction * 100.0
    );
    eprintln!(
        "  top-1 {} unfiltered winner; frontier bytes {}",
        if top1_matches { "matches" } else { "MISSES" },
        if frontier_identical { "identical" } else { "DIVERGED" }
    );

    let doc = Json::obj(vec![
        ("bench", Json::str("analytical_prefilter")),
        ("unit", Json::str("wall-clock seconds; speedup = full simulation / prefiltered")),
        ("grid_variants", Json::num(grid.len() as f64)),
        ("grid_jobs", Json::num(grid_jobs as f64)),
        ("confirm_top", Json::num(keep.len() as f64)),
        ("simulated_jobs", Json::num(simulated_jobs as f64)),
        ("fraction_simulated", Json::num(fraction)),
        ("full_sweep_seconds", Json::num(full_s)),
        ("prefiltered_seconds", Json::num(prefilter_s)),
        ("ranking_us_per_job", Json::num(us_per_prediction)),
        ("wall_clock_speedup", Json::num(speedup)),
        ("top1_matches_unfiltered", Json::Bool(top1_matches)),
        ("frontier_byte_identical", Json::Bool(frontier_identical)),
    ]);
    let out = artifact_path("BENCH_analytical_prefilter.json");
    match std::fs::write(&out, doc.pretty()) {
        Ok(()) => println!("wrote {}", out.display()),
        Err(e) => eprintln!("could not write {}: {e}", out.display()),
    }
}
