//! Dependency-free utility substrates: PRNG, statistics, JSON, tables,
//! CLI parsing, error plumbing, micro-benchmarking and property testing.
//! These replace `rand`, `serde`, `clap`, `anyhow`, `criterion` and
//! `proptest`, none of which are available in the offline crate registry.

pub mod bench;
pub mod check;
pub mod cli;
pub mod digest;
pub mod error;
pub mod json;
pub mod rng;
pub mod stats;
pub mod table;
