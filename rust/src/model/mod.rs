//! Closed-form analytical cost model — the "price in microseconds" tier.
//!
//! `predict` computes the same quantities the cycle-accurate engine
//! produces by stepping — total cycles, utilization split, SPM traffic,
//! energy — from arithmetic over the compiled job alone: tile counts
//! (`ceil(M/Mu)·ceil(K/Ku)·ceil(N/Nu)`), per-tile SPM bank conflicts
//! derived from the AGU programming the compiler emits, the RV32I CSR
//! handshake budget of the generated config program, and the overlap
//! the config-preloading / prefetch / output-buffering mechanisms buy.
//! No `Platform` is built and no cycle is stepped, so a prediction
//! costs microseconds where a simulation costs milliseconds to seconds.
//!
//! The model mirrors the event engine's semantics exactly where they
//! are closed-form, and approximates only genuinely dynamic effects:
//!
//! - **Kernel, prefetch regime** (`Mechanisms::prefetch`): the core
//!   retires one tile-MAC per cycle once the pipeline fills, so the
//!   kernel body is `max(tiles, read-port demand A, read-port demand B,
//!   write-port demand C)`. A bank conflict between A and B issued the
//!   same cycle costs B one extra arbitration cycle; in the steady
//!   prefetch orbit the delayed B alternates between conflicting and
//!   conflict-free issue slots, so a conflicting tile costs +1/2 cycle
//!   on average (the one deliberate approximation in this regime).
//! - **Kernel, on-demand regime** (no prefetch): depth-1 FIFOs
//!   serialize fetch latency with compute; each tile costs
//!   `max(cost_A, cost_B + arb) + read_latency` cycles, exactly.
//! - **Host timeline**: the generated config program's poll loops,
//!   `li`/`csrrw` stretches, and the CSR-latency stall per access are
//!   replayed arithmetically on the poll grid (a status poll samples
//!   every `csr_latency + 4` cycles), including the config-preloading
//!   pending-latch chaining that back-to-back launches runs.
//!
//! `tests/model_accuracy.rs` pins predicted-vs-simulated total-cycle
//! error on a randomized grid: median |err| <= 5%, p95 <= 15% across
//! shapes x mechanism variants x layouts x core instances. The bound
//! doubles as a regression oracle for the event engine: a change that
//! silently shifts cycle counts trips the analytical tier.

pub mod prefilter;

use crate::compiler::{compile_gemm, CompiledCall, CompiledJob};
use crate::config::{Mechanisms, PlatformConfig};
use crate::coordinator::JobRequest;
use crate::csr::{ConfigRegs, CSR_BASE};
use crate::power::PowerModel;
use crate::sim::SimOptions;
use crate::streamer::AguConfig;
use crate::util::json::{self, Json};

/// Analytical counterpart of a simulated [`crate::sim::JobResult`]:
/// what the platform is predicted to do with a job, without stepping.
#[derive(Debug, Clone, PartialEq)]
pub struct Prediction {
    /// Predicted end-to-end platform cycles (program start to drain).
    pub cycles: u64,
    /// Predicted cycles spent inside accelerator runs.
    pub kernel_cycles: u64,
    /// Ideal compute cycles (tile count x repeats) — exact, the
    /// simulator pins `compute_cycles` to the same number.
    pub compute_cycles: u64,
    /// PE-array occupancy of the mapped tiles (exact).
    pub spatial_utilization: f64,
    /// compute_cycles / cycles.
    pub temporal_utilization: f64,
    /// spatial x temporal — the paper's Fig. 5 metric.
    pub overall_utilization: f64,
    /// Predicted SPM word requests (reads + writes) — exact.
    pub spm_traffic_words: u64,
    /// Predicted energy in millijoules at the power model's anchor.
    pub energy_mj: f64,
}

impl Prediction {
    /// Sentinel for a job that does not compile for its platform
    /// instance (the simulator rejects it identically): zero
    /// utilization ranks it behind every schedulable candidate, and
    /// error accounting skips it because the simulated outcome is an
    /// error too.
    pub fn unschedulable() -> Prediction {
        Prediction {
            cycles: 0,
            kernel_cycles: 0,
            compute_cycles: 0,
            spatial_utilization: 0.0,
            temporal_utilization: 0.0,
            overall_utilization: 0.0,
            spm_traffic_words: 0,
            energy_mj: 0.0,
        }
    }

    /// Signed relative cycle error of this prediction against a
    /// simulated total: `(predicted - simulated) / simulated`.
    pub fn cycle_error(&self, simulated_cycles: u64) -> f64 {
        (self.cycles as f64 - simulated_cycles as f64) / simulated_cycles as f64
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("cycles", Json::num(self.cycles as f64)),
            ("kernel_cycles", Json::num(self.kernel_cycles as f64)),
            ("compute_cycles", Json::num(self.compute_cycles as f64)),
            ("spatial_utilization", Json::num(self.spatial_utilization)),
            ("temporal_utilization", Json::num(self.temporal_utilization)),
            ("overall_utilization", Json::num(self.overall_utilization)),
            ("spm_traffic_words", Json::num(self.spm_traffic_words as f64)),
            ("energy_mj", Json::num(self.energy_mj)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Prediction, String> {
        Ok(Prediction {
            cycles: json::get_u64(v, "cycles")?,
            kernel_cycles: json::get_u64(v, "kernel_cycles")?,
            compute_cycles: json::get_u64(v, "compute_cycles")?,
            spatial_utilization: json::get_f64(v, "spatial_utilization")?,
            temporal_utilization: json::get_f64(v, "temporal_utilization")?,
            overall_utilization: json::get_f64(v, "overall_utilization")?,
            spm_traffic_words: json::get_u64(v, "spm_traffic_words")?,
            energy_mj: json::get_f64(v, "energy_mj")?,
        })
    }
}

/// Predict a job at the default CSR handshake latency. Errs exactly
/// when the simulator would: the job does not compile for `cfg`.
pub fn predict(cfg: &PlatformConfig, request: &JobRequest) -> Result<Prediction, String> {
    predict_with(cfg, request, SimOptions::default().csr_latency)
}

/// Predict a job at an explicit CSR handshake latency (the sweep
/// stack's `SweepOptions::csr_latency`).
pub fn predict_with(
    cfg: &PlatformConfig,
    request: &JobRequest,
    csr_latency: u64,
) -> Result<Prediction, String> {
    let job = compile_gemm(
        cfg,
        request.shape,
        request.layout,
        request.repeats,
        request.mechanisms.config_preloading,
    )
    .map_err(|e| e.to_string())?;
    Ok(predict_job(cfg, &job, request.mechanisms, csr_latency))
}

/// Predict an already-compiled job (public so callers holding a
/// `CompiledJob` skip the recompilation `predict` pays).
pub fn predict_job(
    cfg: &PlatformConfig,
    job: &CompiledJob,
    mech: Mechanisms,
    csr_latency: u64,
) -> Prediction {
    let calls: Vec<CallCost> = job
        .calls
        .iter()
        .map(|c| analyze_call(cfg, mech, c, csr_latency))
        .collect();
    let repeats = job.repeats as u64;
    let cycles = host_timeline(&calls, job.cpl, repeats, csr_latency, job.cores.max(1));
    let kernel_cycles = repeats * calls.iter().map(|c| c.kernel).sum::<u64>();
    let compute_cycles = repeats * job.ideal_cycles(cfg);
    let spatial = job.spatial_utilization(cfg);
    let temporal = compute_cycles as f64 / cycles as f64;
    let overall = spatial * temporal;
    let spm_traffic_words = repeats * calls.iter().map(|c| c.traffic_words).sum::<u64>();
    let power_mw = PowerModel::default().total_power(cfg, overall);
    let seconds = cycles as f64 / (cfg.freq_mhz as f64 * 1e6);
    Prediction {
        cycles,
        kernel_cycles,
        compute_cycles,
        spatial_utilization: spatial,
        temporal_utilization: temporal,
        overall_utilization: overall,
        spm_traffic_words,
        energy_mj: power_mw * seconds,
    }
}

/// Per-call closed-form costs.
struct CallCost {
    /// Launch-to-drain cycles of one accelerator run of this call.
    kernel: u64,
    /// Host cycles of the call's `li`/`csrrw` config stretch (between
    /// the poll-loop exit and the start pulse).
    config_cycles: u64,
    /// SPM word requests of one run (reads for A/B, writes for C).
    traffic_words: u64,
}

/// Host cycles of materializing `value` in a register: the codegen's
/// `li` emits one instruction for 12-bit-signed immediates, two
/// (`lui`+`addi`) otherwise, at one cycle each.
fn li_cycles(value: u32) -> u64 {
    if (-2048..=2047).contains(&(value as i32)) {
        1
    } else {
        2
    }
}

/// Max per-bank load (the SPM's slow-path epoch cost) and touched-bank
/// set of one tile access through `agu`. Bank set folds into 128 bits;
/// every supported instance has `n_bank <= 64`, matching the
/// simulator's own fast-path mask width.
fn access_cost(
    agu: &AguConfig,
    m1: u64,
    n1: u64,
    k1: u64,
    word_bytes: u64,
    n_bank: usize,
    loads: &mut [u16],
) -> (u64, u128) {
    loads.iter_mut().for_each(|l| *l = 0);
    let mut mask: u128 = 0;
    let mut max_load: u16 = 0;
    for port in 0..agu.ports() as u64 {
        let bank = ((agu.byte_addr(m1, n1, k1, port) / word_bytes) as usize) & (n_bank - 1);
        loads[bank] += 1;
        max_load = max_load.max(loads[bank]);
        mask |= 1u128 << (bank & 127);
    }
    (max_load.max(1) as u64, mask)
}

fn analyze_call(
    cfg: &PlatformConfig,
    mech: Mechanisms,
    call: &CompiledCall,
    csr_latency: u64,
) -> CallCost {
    let word_bytes = cfg.mem.word_bytes() as u64;
    let n_bank = cfg.mem.n_bank;
    let rd = cfg.mem.read_latency;
    let wr = cfg.mem.write_latency;

    // Reconstruct the register file the run will be launched with from
    // the CSR writes the compiler emits — the model prices exactly what
    // the hardware is programmed to do.
    let mut regs = ConfigRegs::default();
    for &(addr, value) in &call.placement.csr_writes {
        regs.regs[(addr - CSR_BASE) as usize] = value;
    }
    let bounds = regs.bounds();
    let (mt, nt, kt) = (bounds.mt, bounds.nt, bounds.kt);
    let a_agu = regs.a_agu(&cfg.core, word_bytes as usize);
    let b_agu = regs.b_agu(&cfg.core, word_bytes as usize);
    let c_agu = regs.c_agu(&cfg.core, word_bytes as usize);

    // Per-tile cost/bank-set tables. A varies over (m1, k1), B over
    // (n1, k1), C over (m1, n1); the remaining loop index never enters
    // the respective AGU's address arithmetic.
    let mut loads = vec![0u16; n_bank];
    let mut a_tab = Vec::with_capacity((mt * kt) as usize);
    for m1 in 0..mt {
        for k1 in 0..kt {
            a_tab.push(access_cost(&a_agu, m1, 0, k1, word_bytes, n_bank, &mut loads));
        }
    }
    let mut b_tab = Vec::with_capacity((nt * kt) as usize);
    for n1 in 0..nt {
        for k1 in 0..kt {
            b_tab.push(access_cost(&b_agu, 0, n1, k1, word_bytes, n_bank, &mut loads));
        }
    }
    let mut c_tab = Vec::with_capacity((mt * nt) as usize);
    for m1 in 0..mt {
        for n1 in 0..nt {
            c_tab.push(access_cost(&c_agu, m1, n1, 0, word_bytes, n_bank, &mut loads));
        }
    }

    let tiles = mt * nt * kt;
    // The write network is independent of the read network (1R1W
    // banks); a burst occupies its write ports for `cost + wr - 1`.
    let sum_c: u64 = c_tab.iter().map(|&(c, _)| c + wr - 1).sum();

    let kernel = if mech.prefetch {
        // Steady state: one tile-MAC per cycle unless a streamer's
        // issue bandwidth (one burst per `cost` cycles) falls behind.
        let sum_a: u64 = nt * a_tab.iter().map(|&(c, _)| c).sum::<u64>();
        let mut sum_b_halves: u64 = 0;
        for m1 in 0..mt {
            for n1 in 0..nt {
                for k1 in 0..kt {
                    let (_, a_mask) = a_tab[(m1 * kt + k1) as usize];
                    let (b_cost, b_mask) = b_tab[(n1 * kt + k1) as usize];
                    // A conflicting tile pays the arbitration cycle on
                    // every other issue slot in the steady orbit.
                    let conflict = (a_mask & b_mask != 0) as u64;
                    sum_b_halves += 2 * b_cost + conflict;
                }
            }
        }
        let sum_b = sum_b_halves.div_ceil(2);
        let first = a_tab.first().map_or(1, |&(c, _)| c);
        tiles.max(sum_a).max(sum_b).max(sum_c) + first + rd + wr
    } else {
        // Depth-1 FIFOs: fetch latency serializes with compute. Both
        // streamers issue in the same starved cycle, so a bank overlap
        // always costs B the arbitration cycle.
        let mut sum_p: u64 = 0;
        for m1 in 0..mt {
            for n1 in 0..nt {
                for k1 in 0..kt {
                    let (a_cost, a_mask) = a_tab[(m1 * kt + k1) as usize];
                    let (b_cost, b_mask) = b_tab[(n1 * kt + k1) as usize];
                    let conflict = (a_mask & b_mask != 0) as u64;
                    sum_p += a_cost.max(b_cost + conflict) + rd;
                }
            }
        }
        let last_c = c_tab.last().map_or(1, |&(c, _)| c);
        sum_p.max(sum_c) + last_c + wr
    };

    let csrs = &call.placement.csr_writes;
    let config_cycles = csrs.iter().map(|&(_, v)| li_cycles(v)).sum::<u64>()
        + csrs.len() as u64 * (1 + csr_latency);
    let traffic_words = tiles * (a_agu.ports() + b_agu.ports()) as u64
        + mt * nt * c_agu.ports() as u64;

    CallCost { kernel, config_cycles, traffic_words }
}

/// First point of the arithmetic grid `{t0, t0+period, ...}` at or
/// after `target`.
fn first_on_grid(t0: u64, period: u64, target: u64) -> u64 {
    if target <= t0 {
        t0
    } else {
        t0 + (target - t0).div_ceil(period) * period
    }
}

/// Replay the generated config program's timeline arithmetically.
///
/// The program is `li s0, repeats`, then per repeat x call: a status
/// poll loop (`csrrs`/`andi`/`bne`, sampling every `csr_latency + 4`
/// cycles), the config stretch, and the `csrrwi` start pulse; then the
/// per-core drain loops and `ebreak`. Without config preloading the
/// poll watches BUSY and a run launches the cycle after its pulse; with
/// it the poll watches PENDING and a pulse landing on a busy
/// accelerator latches, launching back-to-back in the very cycle the
/// previous run drains.
///
/// On multi-core platforms call `ci` targets core `ci % cores`: its
/// poll waits on *that core's* status while the other cores compute in
/// the background, which is exactly how the generated program overlaps
/// work across clusters. Cross-cluster SPM bank contention is not
/// priced (the streamers' claims rarely collide across partitions), so
/// multi-core predictions are slightly optimistic.
fn host_timeline(calls: &[CallCost], cpl: bool, repeats: u64, lat: u64, cores: usize) -> u64 {
    let poll = lat + 4;
    // `li s0` executes at cycle 1; the first poll's `csrrs` follows.
    let mut t = 1 + li_cycles(repeats as u32);
    let mut finish = vec![0u64; cores];
    let mut pending_clear = vec![0u64; cores];
    for r in 0..repeats {
        for (ci, call) in calls.iter().enumerate() {
            let k = ci % cores;
            let target = if cpl { pending_clear[k] } else { finish[k] };
            let exit = first_on_grid(t, poll, target);
            // Poll exit (`andi` + untaken `bne`), config stretch, pulse.
            let pulse = exit + lat + 3 + call.config_cycles;
            let launch = if cpl && finish[k] > pulse {
                pending_clear[k] = finish[k];
                finish[k]
            } else {
                if cpl {
                    pending_clear[k] = 0;
                }
                pulse + 1
            };
            finish[k] = launch + call.kernel;
            t = if ci + 1 < calls.len() {
                // Next wait loop's csrrs, right after the pulse stall.
                pulse + 1 + lat
            } else if r + 1 < repeats {
                // `addi`, untaken `beq`, `jal` back to the loop head.
                pulse + lat + 5
            } else {
                // `addi`, taken `beq` into the drain loop.
                pulse + lat + 4
            };
        }
    }
    // Sequential per-core drain loops: each exits once its core's last
    // run (or pending latch) resolves, then falls through to the next
    // core's poll (`andi`, untaken `bne`; the last fall-through is the
    // `ebreak`).
    let mut t_drain = t;
    for k in 0..cores {
        let exit = first_on_grid(t_drain, poll, finish[k].max(pending_clear[k]));
        t_drain = exit + lat + 3;
    }
    t_drain
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::GemmShape;
    use crate::coordinator::Coordinator;

    fn case(shape: GemmShape, mech: Mechanisms) -> (Prediction, u64) {
        let cfg = PlatformConfig::case_study();
        let req = JobRequest::timing(shape, mech, 2);
        let pred = predict(&cfg, &req).expect("job compiles");
        let sim = Coordinator::new(cfg)
            .with_workers(1)
            .run_one(&req)
            .expect("simulation succeeds");
        (pred, sim.metrics.total_cycles)
    }

    fn assert_tight(pred: &Prediction, sim: u64, ctx: &str) {
        let err = pred.cycle_error(sim).abs();
        assert!(
            err <= 0.02,
            "{ctx}: predicted {} vs simulated {} (err {:.3}%)",
            pred.cycles,
            sim,
            err * 100.0
        );
    }

    #[test]
    fn tight_on_the_conflict_free_prefetch_regime() {
        // SMA layout is conflict-free by construction; the prefetch
        // kernel and host timeline are both closed-form.
        for shape in [
            GemmShape::new(8, 8, 8),
            GemmShape::new(64, 64, 64),
            GemmShape::new(72, 40, 88),
        ] {
            let (pred, sim) = case(shape, Mechanisms::ALL);
            assert_tight(&pred, sim, &format!("{shape:?} ALL"));
        }
    }

    #[test]
    fn tight_on_the_on_demand_baseline() {
        for shape in [GemmShape::new(8, 8, 8), GemmShape::new(48, 64, 32)] {
            let (pred, sim) = case(shape, Mechanisms::BASELINE);
            assert_tight(&pred, sim, &format!("{shape:?} BASELINE"));
        }
    }

    #[test]
    fn utilization_and_traffic_fields_are_consistent() {
        let cfg = PlatformConfig::case_study();
        let req = JobRequest::timing(GemmShape::new(64, 64, 64), Mechanisms::ALL, 2);
        let pred = predict(&cfg, &req).expect("job compiles");
        let overall = pred.spatial_utilization * pred.temporal_utilization;
        assert!((pred.overall_utilization - overall).abs() < 1e-12);
        assert!(pred.energy_mj > 0.0);
        let sim = Coordinator::new(cfg).with_workers(1).run_one(&req).unwrap();
        // Traffic and ideal-compute accounting are exact, not modeled.
        assert_eq!(pred.spm_traffic_words, sim.metrics.spm.word_requests);
        assert_eq!(pred.compute_cycles, sim.metrics.compute_cycles);
    }

    #[test]
    fn multicore_prediction_overlaps_calls() {
        // A job that splits into several calls: dispatching them
        // round-robin over two cores must be predicted faster than one
        // core (compute overlaps), with identical work.
        let shape = GemmShape::new(256, 128, 256);
        let cfg1 = PlatformConfig::case_study();
        let req = JobRequest::timing(shape, Mechanisms::ALL, 2);
        let p1 = predict(&cfg1, &req).expect("compiles on one core");
        let mut cfg2 = PlatformConfig::case_study();
        cfg2.cores = 2;
        let p2 = predict(&cfg2, &req).expect("compiles on two cores");
        assert_eq!(p1.compute_cycles, p2.compute_cycles, "same work either way");
        assert!(
            p2.cycles < p1.cycles,
            "2 cores predicted no faster: {} vs {}",
            p2.cycles,
            p1.cycles
        );
    }

    #[test]
    fn prediction_json_round_trips_bit_identical() {
        // Same contract as the sweep wire format: shortest-Display f64
        // encoding parses back to the identical bits.
        let cfg = PlatformConfig::case_study();
        let req = JobRequest::timing(GemmShape::new(56, 120, 72), Mechanisms::CPL_BUF, 3);
        let pred = predict(&cfg, &req).expect("job compiles");
        let text = pred.to_json().pretty();
        let back = Prediction::from_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, pred);
        assert_eq!(
            back.temporal_utilization.to_bits(),
            pred.temporal_utilization.to_bits()
        );
        assert_eq!(back.energy_mj.to_bits(), pred.energy_mj.to_bits());
    }
}
