//! Sharded sweep engine: wire-format property tests and the
//! sharded-vs-unsharded differential.
//!
//! Three layers of the same guarantee:
//! 1. every wire type survives encode -> parse bit-identically
//!    (property tests over randomized values);
//! 2. an in-process 2- and 4-shard sweep merges to exactly the
//!    unsharded `Coordinator::run_batch` output — outcomes, order,
//!    and summed stats;
//! 3. the multi-process `sweep --processes 2` driver emits merged JSON
//!    byte-identical to the single-process run (the same check the CI
//!    `sweep-smoke` lane performs with `diff`).

use std::process::Command;

use opengemm::compiler::{GemmShape, Layout};
use opengemm::config::{Mechanisms, PlatformConfig};
use opengemm::coordinator::shard::{run_sweep, SweepOptions};
use opengemm::coordinator::{
    outcome_from_json, outcome_to_json, Coordinator, CoordinatorStats, JobRequest,
};
use opengemm::sim::{JobResult, SimMetrics, UtilizationReport};
use opengemm::spm::SpmStats;
use opengemm::util::json;
use opengemm::util::rng::Pcg32;

const LAYOUTS: [Layout; 3] =
    [Layout::RowMajor, Layout::TiledContiguous, Layout::TiledInterleaved];
const MECHS: [Mechanisms; 4] =
    [Mechanisms::BASELINE, Mechanisms::CPL, Mechanisms::CPL_BUF, Mechanisms::ALL];

fn random_request(rng: &mut Pcg32) -> JobRequest {
    let shape = GemmShape::new(
        1 + rng.below(64) as usize,
        1 + rng.below(64) as usize,
        1 + rng.below(64) as usize,
    );
    let operands = if rng.below(3) == 0 {
        let mut a = vec![0i8; shape.m * shape.k];
        let mut b = vec![0i8; shape.k * shape.n];
        rng.fill_i8(&mut a);
        rng.fill_i8(&mut b);
        Some((a, b))
    } else {
        None
    };
    JobRequest {
        shape,
        layout: *rng.choose(&LAYOUTS),
        mechanisms: *rng.choose(&MECHS),
        repeats: 1 + rng.below(10),
        operands,
    }
}

/// Counters stay within f64's exact-integer range (2^53); real
/// simulations are far below it, and the wire format documents the
/// bound.
fn random_counter(rng: &mut Pcg32) -> u64 {
    rng.next_u64() & ((1u64 << 48) - 1)
}

fn random_metrics(rng: &mut Pcg32) -> SimMetrics {
    SimMetrics {
        total_cycles: random_counter(rng),
        compute_cycles: random_counter(rng),
        stall_input_a: random_counter(rng),
        stall_input_b: random_counter(rng),
        stall_output: random_counter(rng),
        idle_cycles: random_counter(rng),
        starts: random_counter(rng),
        runs_completed: random_counter(rng),
        kernel_cycles: random_counter(rng),
        host_instret: random_counter(rng),
        host_csr_stall: random_counter(rng),
        spm: SpmStats {
            word_requests: random_counter(rng),
            epochs: random_counter(rng),
            busy_cycles: random_counter(rng),
            conflict_cycles: random_counter(rng),
        },
    }
}

#[test]
fn job_request_json_roundtrip_property() {
    let mut rng = Pcg32::seeded(0xF1E5);
    for i in 0..50 {
        let request = random_request(&mut rng);
        let text = request.to_json().pretty();
        let back = JobRequest::from_json(&json::parse(&text).expect("parse"))
            .unwrap_or_else(|e| panic!("case {i}: {e}"));
        assert_eq!(back, request, "case {i} must round-trip bit-identically");
        // the encoding itself is stable under a second pass
        assert_eq!(back.to_json().pretty(), text, "case {i} re-encode");
    }
}

#[test]
fn job_result_json_roundtrip_property() {
    let mut rng = Pcg32::seeded(0xBEEF);
    for i in 0..50 {
        let metrics = random_metrics(&mut rng);
        let report = UtilizationReport::from_metrics(rng.unit_f64(), &metrics);
        let c = if i % 2 == 0 {
            let mut v = vec![0i8; 32];
            rng.fill_i8(&mut v);
            Some(v.iter().map(|&x| x as i32 * 65_537).collect())
        } else {
            None
        };
        let result = JobResult { metrics, report, c };
        let text = result.to_json().pretty();
        let back = JobResult::from_json(&json::parse(&text).expect("parse"))
            .unwrap_or_else(|e| panic!("case {i}: {e}"));
        assert_eq!(back, result, "case {i} must round-trip bit-identically");
    }
}

#[test]
fn coordinator_stats_json_roundtrip_property() {
    let mut rng = Pcg32::seeded(0x57A75);
    for i in 0..50 {
        let stats = CoordinatorStats {
            jobs_completed: random_counter(&mut rng),
            jobs_failed: random_counter(&mut rng),
            simulated_cycles: random_counter(&mut rng),
        };
        let text = stats.to_json().pretty();
        let back = CoordinatorStats::from_json(&json::parse(&text).expect("parse"))
            .unwrap_or_else(|e| panic!("case {i}: {e}"));
        assert_eq!(back, stats, "case {i}");
    }
}

#[test]
fn failed_outcome_roundtrips_with_escapes() {
    let outcome: Result<JobResult, String> =
        Err("tile split failed:\n\t\"K too deep\" \\ at (8, 300000, 8)".into());
    let text = outcome_to_json(&outcome).pretty();
    let back = outcome_from_json(&json::parse(&text).unwrap()).unwrap();
    assert_eq!(back, outcome);
}

/// Mixed batch: timing and functional jobs, differing mechanisms, and
/// one job that fails in the tiler.
fn differential_requests() -> Vec<JobRequest> {
    let mut rng = Pcg32::seeded(2025);
    let mut reqs: Vec<JobRequest> = (0..9).map(|_| random_request(&mut rng)).collect();
    // oversized K fails the split — failures must merge like successes
    reqs.push(JobRequest::timing(GemmShape::new(8, 300_000, 8), Mechanisms::ALL, 1));
    reqs
}

#[test]
fn sharded_sweep_is_bit_identical_to_unsharded_run_batch() {
    let cfg = PlatformConfig::case_study();
    let reqs = differential_requests();

    let unsharded = Coordinator::new(cfg.clone()).with_workers(2);
    let want = unsharded.run_batch(reqs.clone());
    let want_stats = unsharded.stats();

    for shards in [2usize, 4] {
        let opts = SweepOptions { shards, workers: 2, ..Default::default() };
        let got = run_sweep(&cfg, reqs.clone(), opts);
        assert_eq!(
            got.outcomes.len(),
            want.len(),
            "{shards}-shard sweep must preserve batch size"
        );
        for (i, (g, w)) in got.outcomes.iter().zip(&want).enumerate() {
            assert_eq!(g, w, "{shards}-shard sweep, job {i} (submission order)");
        }
        assert_eq!(got.stats, want_stats, "{shards}-shard summed stats");
    }
}

#[test]
fn multi_process_sweep_driver_matches_single_process() {
    let exe = env!("CARGO_BIN_EXE_opengemm");
    let base = [
        "sweep",
        "--workloads",
        "4",
        "--variants",
        "2",
        "--repeats",
        "2",
        "--seed",
        "11",
        "--workers",
        "1",
    ];

    let single = Command::new(exe).args(base).output().expect("single-process sweep");
    assert!(
        single.status.success(),
        "single-process sweep failed: {}",
        String::from_utf8_lossy(&single.stderr)
    );

    let sharded = Command::new(exe)
        .args(base)
        .args(["--processes", "2"])
        .output()
        .expect("driver sweep");
    assert!(
        sharded.status.success(),
        "driver sweep failed: {}",
        String::from_utf8_lossy(&sharded.stderr)
    );

    assert_eq!(
        String::from_utf8_lossy(&single.stdout),
        String::from_utf8_lossy(&sharded.stdout),
        "merged sweep JSON must be byte-identical across process counts"
    );

    // sanity: the merged document is our sweep format and complete
    let doc = json::parse(std::str::from_utf8(&single.stdout).unwrap()).unwrap();
    assert_eq!(doc.get("sweep").and_then(|s| s.as_str()), Some("fig5"));
    let variants = doc.get("variants").and_then(|v| v.as_arr()).unwrap();
    assert_eq!(variants.len(), 2);
    for v in variants {
        let result = v.get("result").unwrap();
        let outcomes = result.get("outcomes").and_then(|o| o.as_arr()).unwrap();
        assert_eq!(outcomes.len(), 4, "one outcome per workload");
    }
}
