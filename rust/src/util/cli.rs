//! Tiny command-line argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional
//! arguments, with typed accessors and an auto-generated usage string.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct Args {
    flags: BTreeMap<String, String>,
    positional: Vec<String>,
}

#[derive(Debug)]
pub struct ArgError(pub String);

impl std::fmt::Display for ArgError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "argument error: {}", self.0)
    }
}

impl std::error::Error for ArgError {}

impl Args {
    /// Parse raw argv (without the program name).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args, ArgError> {
        let mut flags = BTreeMap::new();
        let mut positional = Vec::new();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(body) = arg.strip_prefix("--") {
                if body.is_empty() {
                    // `--` terminator: rest is positional
                    positional.extend(it.by_ref());
                    break;
                }
                if let Some((k, v)) = body.split_once('=') {
                    flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    flags.insert(body.to_string(), v);
                } else {
                    flags.insert(body.to_string(), String::from("true"));
                }
            } else {
                positional.push(arg);
            }
        }
        Ok(Args { flags, positional })
    }

    pub fn from_env() -> Result<Args, ArgError> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize, ArgError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| ArgError(format!("--{key} expects an integer, got {v:?}"))),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64, ArgError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| ArgError(format!("--{key} expects an integer, got {v:?}"))),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64, ArgError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| ArgError(format!("--{key} expects a number, got {v:?}"))),
        }
    }

    pub fn bool_flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Default-on switch: true unless `--no-<key>` was passed.
    pub fn enabled_unless_no(&self, key: &str) -> bool {
        !self.has(&format!("no-{key}"))
    }

    /// Parse a `MxKxN` triple like `64x128x32`.
    pub fn shape_or(
        &self,
        key: &str,
        default: (usize, usize, usize),
    ) -> Result<(usize, usize, usize), ArgError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => {
                let parts: Vec<_> = v.split('x').collect();
                if parts.len() != 3 {
                    return Err(ArgError(format!("--{key} expects MxKxN, got {v:?}")));
                }
                let parse = |s: &str| {
                    s.parse::<usize>()
                        .map_err(|_| ArgError(format!("--{key}: bad dimension {s:?}")))
                };
                Ok((parse(parts[0])?, parse(parts[1])?, parse(parts[2])?))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn positional_and_flags() {
        let a = parse(&["run", "--size", "32", "--verbose", "--k=v"]);
        assert_eq!(a.positional(), &["run".to_string()]);
        assert_eq!(a.get("size"), Some("32"));
        assert!(a.bool_flag("verbose"));
        assert_eq!(a.get("k"), Some("v"));
    }

    #[test]
    fn typed_accessors() {
        let a = parse(&["--n", "10", "--x", "1.5"]);
        assert_eq!(a.usize_or("n", 0).unwrap(), 10);
        assert_eq!(a.f64_or("x", 0.0).unwrap(), 1.5);
        assert_eq!(a.usize_or("missing", 7).unwrap(), 7);
        assert!(a.usize_or("x", 0).is_err());
    }

    #[test]
    fn shape_triple() {
        let a = parse(&["--shape", "64x128x32"]);
        assert_eq!(a.shape_or("shape", (0, 0, 0)).unwrap(), (64, 128, 32));
        assert!(parse(&["--shape", "8x8"]).shape_or("shape", (0, 0, 0)).is_err());
    }

    #[test]
    fn default_on_switches() {
        let a = parse(&["--no-fast-forward"]);
        assert!(!a.enabled_unless_no("fast-forward"));
        assert!(a.enabled_unless_no("prefetch"));
    }

    #[test]
    fn double_dash_terminator() {
        let a = parse(&["--a", "1", "--", "--not-a-flag"]);
        assert_eq!(a.positional(), &["--not-a-flag".to_string()]);
    }
}
