//! Fixed-capacity ring-buffer FIFO used for the input pre-fetch buffers
//! and the output buffers (paper Sec. 3.3, design-time depth `D_stream`).

/// A bounded FIFO with occupancy statistics.
#[derive(Debug, Clone)]
pub struct Fifo<T> {
    buf: Vec<Option<T>>,
    head: usize,
    len: usize,
    /// High-water mark (peak occupancy) since last reset.
    pub peak: usize,
    /// Total pushes since last reset.
    pub pushes: u64,
}

impl<T> Fifo<T> {
    pub fn new(capacity: usize) -> Fifo<T> {
        assert!(capacity > 0, "FIFO capacity must be >= 1");
        Fifo {
            buf: (0..capacity).map(|_| None).collect(),
            head: 0,
            len: 0,
            peak: 0,
            pushes: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.buf.len()
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn is_full(&self) -> bool {
        self.len == self.buf.len()
    }

    /// Push; panics if full (producers must check `is_full` — backpressure
    /// is explicit in the simulator, a full-FIFO push is a model bug).
    pub fn push(&mut self, item: T) {
        assert!(!self.is_full(), "push into full FIFO");
        let tail = (self.head + self.len) % self.buf.len();
        self.buf[tail] = Some(item);
        self.len += 1;
        self.pushes += 1;
        self.peak = self.peak.max(self.len);
    }

    pub fn pop(&mut self) -> Option<T> {
        if self.len == 0 {
            return None;
        }
        let item = self.buf[self.head].take();
        self.head = (self.head + 1) % self.buf.len();
        self.len -= 1;
        item
    }

    pub fn peek(&self) -> Option<&T> {
        if self.len == 0 {
            None
        } else {
            self.buf[self.head].as_ref()
        }
    }

    pub fn clear(&mut self) {
        while self.pop().is_some() {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::property;
    use std::collections::VecDeque;

    #[test]
    fn fifo_ordering() {
        let mut f = Fifo::new(3);
        f.push(1);
        f.push(2);
        f.push(3);
        assert!(f.is_full());
        assert_eq!(f.pop(), Some(1));
        f.push(4);
        assert_eq!(f.pop(), Some(2));
        assert_eq!(f.pop(), Some(3));
        assert_eq!(f.pop(), Some(4));
        assert_eq!(f.pop(), None);
    }

    #[test]
    fn peak_tracks_high_water() {
        let mut f = Fifo::new(4);
        f.push(1);
        f.push(2);
        f.pop();
        f.push(3);
        assert_eq!(f.peak, 2);
        assert_eq!(f.pushes, 3);
    }

    #[test]
    #[should_panic(expected = "full FIFO")]
    fn push_full_panics() {
        let mut f = Fifo::new(1);
        f.push(1);
        f.push(2);
    }

    #[test]
    #[should_panic(expected = "capacity")]
    fn zero_capacity_rejected() {
        let _ = Fifo::<u8>::new(0);
    }

    #[test]
    fn behaves_like_vecdeque() {
        property("fifo vs VecDeque", 50, |rng| {
            let cap = 1 + rng.below(8) as usize;
            let mut fifo = Fifo::new(cap);
            let mut model: VecDeque<u32> = VecDeque::new();
            for _ in 0..200 {
                if rng.below(2) == 0 && !fifo.is_full() {
                    let v = rng.next_u32();
                    fifo.push(v);
                    model.push_back(v);
                } else {
                    crate::prop_assert_eq!(fifo.pop(), model.pop_front(), "pop mismatch");
                }
                crate::prop_assert_eq!(fifo.len(), model.len(), "len mismatch");
                crate::prop_assert_eq!(
                    fifo.peek().copied(),
                    model.front().copied(),
                    "peek mismatch"
                );
            }
            Ok(())
        });
    }
}
