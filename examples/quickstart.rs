//! Quickstart: compile one GeMM for the OpenGeMM platform, run it on
//! the cycle-accurate simulator, verify the numerics against the
//! AOT-compiled JAX/Pallas golden model (if artifacts are built), and
//! print the utilization report.
//!
//! Run with:  cargo run --release --example quickstart

use opengemm::compiler::{compile_gemm, GemmShape, Layout};
use opengemm::config::PlatformConfig;
use opengemm::runtime::Runtime;
use opengemm::sim::{Platform, SimOptions};
use opengemm::util::rng::Pcg32;

fn main() -> opengemm::util::error::Result<()> {
    // 1. a platform instance: the paper's 8x8x8 case study
    let cfg = PlatformConfig::case_study();
    println!(
        "platform: {}x{}x{} GeMM core, {} KiB SPM, {} MHz, {:.1} GOPS peak",
        cfg.core.mu,
        cfg.core.nu,
        cfg.core.ku,
        cfg.mem.capacity_bytes() / 1024,
        cfg.freq_mhz,
        cfg.peak_gops()
    );

    // 2. compile a 64x64x64 int8 GeMM: tiling, SMA layout, and the
    //    RV32I host program that configures the accelerator
    let shape = GemmShape::new(64, 64, 64);
    let job = compile_gemm(&cfg, shape, Layout::TiledInterleaved, 10, true)?;
    println!(
        "compiled: {} accelerator call(s), {} host instructions",
        job.calls.len(),
        job.program.len()
    );

    // 3. random int8 operands
    let mut rng = Pcg32::seeded(42);
    let mut a = vec![0i8; shape.m * shape.k];
    let mut b = vec![0i8; shape.k * shape.n];
    rng.fill_i8(&mut a);
    rng.fill_i8(&mut b);

    // 4. run on the cycle-accurate platform (functional mode)
    let opts = SimOptions { functional: true, ..Default::default() };
    let mut platform = Platform::new(cfg.clone(), opts);
    let result = platform.run_job(&job, Some(&a), Some(&b))?;
    let c_sim = result.c.clone().expect("functional result");
    println!(
        "simulated: {} cycles total, {} compute, SU {:.3} TU {:.3} OU {:.3}",
        result.metrics.total_cycles,
        result.metrics.compute_cycles,
        result.report.spatial,
        result.report.temporal,
        result.report.overall,
    );
    let gops = result
        .report
        .achieved_gops(shape.ops() * 10, cfg.freq_mhz);
    println!("throughput: {gops:.1} GOPS of {:.1} peak", cfg.peak_gops());

    // 5. verify against the JAX/Pallas AOT artifact through PJRT
    let dir = Runtime::default_dir();
    if dir.join("manifest.json").exists() {
        let mut rt = Runtime::load(dir)?;
        let golden = rt.execute_gemm("gemm_64x64x64", &a, &b)?;
        assert_eq!(c_sim, golden, "simulator != JAX/Pallas golden model");
        println!("verified: bit-exact vs AOT Pallas kernel through PJRT ✓");
    } else {
        println!("note: run `make artifacts` to enable the golden-model check");
    }
    Ok(())
}
