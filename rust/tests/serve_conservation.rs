//! Randomized conservation properties for the serving engines.
//!
//! Rather than pinning one timeline, these tests throw seeded random
//! workloads (arrival schedules, service tables, batching policies,
//! fleet shapes, fault schedules) at both the single-device queue
//! engine and the fleet engine and check the invariants no correct
//! schedule may violate:
//!
//! - every admitted request is served **exactly once** (ids partition
//!   into served + shed, with no duplicates and no gaps);
//! - `arrival <= start <= completion` for every served request;
//! - no device executes two attempts in overlapping windows;
//! - `shed + served == offered`;
//! - a device's reported busy cycles equal the sum of its attempt
//!   windows (no phantom or unaccounted occupancy).

use std::collections::BTreeMap;

use opengemm::serve::{
    simulate_fleet, simulate_queue, ArrivalSource, BatchPolicy, FaultKind, FaultSpec, FleetSpec,
    PlacementPolicy, RequestRecord,
};
use opengemm::util::rng::Pcg32;

/// A seeded random open-arrival schedule: `n` requests over `kinds`
/// request kinds, bursty inter-arrival gaps.
fn random_arrivals(rng: &mut Pcg32, n: usize, kinds: usize) -> Vec<(u64, usize)> {
    let mut t = 0u64;
    (0..n)
        .map(|_| {
            // mix tight bursts with long gaps so batches of every size
            // and idle flushes all occur
            t += match rng.below(4) {
                0 => rng.below(10) as u64,
                1 => rng.below(300) as u64,
                _ => rng.below(2000) as u64,
            };
            (t, rng.below(kinds as u32) as usize)
        })
        .collect()
}

fn random_policy(rng: &mut Pcg32) -> BatchPolicy {
    match rng.below(3) {
        0 => BatchPolicy::Immediate,
        1 => BatchPolicy::Size(1 + rng.below(4) as usize),
        _ => BatchPolicy::Deadline {
            max_batch: 1 + rng.below(4) as usize,
            max_wait_cycles: rng.below(800) as u64,
        },
    }
}

fn check_served_exactly_once(records: &[RequestRecord], shed_ids: &[usize], offered: usize) {
    let mut seen = vec![0usize; offered];
    for r in records {
        assert!(r.id < offered, "record id {} out of range {offered}", r.id);
        seen[r.id] += 1;
    }
    for &id in shed_ids {
        assert!(id < offered, "shed id {id} out of range {offered}");
        seen[id] += 1;
    }
    for (id, &count) in seen.iter().enumerate() {
        assert_eq!(count, 1, "request {id} resolved {count} times (must be exactly once)");
    }
}

fn check_causality(records: &[RequestRecord]) {
    for r in records {
        assert!(
            r.arrival <= r.start && r.start <= r.completion,
            "request {}: arrival {} start {} completion {} out of order",
            r.id,
            r.arrival,
            r.start,
            r.completion
        );
    }
}

#[test]
fn single_device_engine_conserves_requests() {
    for trial in 0..30u64 {
        let mut rng = Pcg32::new(0xC0_5E_41, trial);
        let n = 1 + rng.below(60) as usize;
        let kinds = 1 + rng.below(3) as usize;
        let service: Vec<u64> = (0..kinds).map(|_| 50 + rng.below(1500) as u64).collect();
        let policy = random_policy(&mut rng);
        let overhead = rng.below(40) as u64;
        let arrivals = random_arrivals(&mut rng, n, kinds);

        let out = simulate_queue(&mut ArrivalSource::open(arrivals), &service, policy, overhead);
        assert_eq!(out.records.len(), n, "trial {trial}: open loop serves everything");
        check_served_exactly_once(&out.records, &[], n);
        check_causality(&out.records);
        // the single device never overlaps batch windows
        let mut batches = out.batches.clone();
        batches.sort_by_key(|b| b.start);
        for w in batches.windows(2) {
            assert!(
                w[1].start >= w[0].completion,
                "trial {trial}: batch windows overlap: {w:?}"
            );
        }
    }
}

#[test]
fn fleet_engine_conserves_requests_under_faults() {
    for trial in 0..30u64 {
        let mut rng = Pcg32::new(0xF1_EE_7, trial);
        let n = 1 + rng.below(60) as usize;
        let kinds = 1 + rng.below(3) as usize;
        let service: Vec<u64> = (0..kinds).map(|_| 50 + rng.below(1500) as u64).collect();
        let policy = random_policy(&mut rng);
        let overhead = rng.below(40) as u64;
        let devices = 1 + rng.below(4) as usize;
        let placement = match rng.below(3) {
            0 => PlacementPolicy::RoundRobin,
            1 => PlacementPolicy::LeastWork,
            _ => PlacementPolicy::ShapeAffinity,
        };
        // fault at most devices-1 of them, so a live device always
        // remains; a generous retry budget keeps failover legal even
        // when several doomed devices are tried in sequence
        let mut faults = Vec::new();
        if devices > 1 {
            for d in 0..rng.below(devices as u32) as usize {
                faults.push(match rng.below(2) {
                    0 => FaultSpec {
                        device: d,
                        at_cycle: rng.below(20_000) as u64,
                        kind: FaultKind::FailStop,
                    },
                    _ => FaultSpec {
                        device: d,
                        at_cycle: rng.below(20_000) as u64,
                        kind: FaultKind::Degrade { factor: 1.0 + rng.below(8) as f64 },
                    },
                });
            }
        }
        let spec = FleetSpec {
            devices,
            placement,
            faults,
            slo_cycles: if rng.below(2) == 0 { Some(500 + rng.below(4000) as u64) } else { None },
            hedge: rng.below(2) == 0,
            retries: 16,
        };
        let arrivals = random_arrivals(&mut rng, n, kinds);

        let out =
            simulate_fleet(&mut ArrivalSource::open(arrivals), &service, policy, overhead, &spec)
                .unwrap_or_else(|e| panic!("trial {trial} ({spec:?}): {e}"));

        // conservation: shed + served == offered, each exactly once
        assert_eq!(out.offered, n, "trial {trial}: every arrival is offered");
        assert_eq!(
            out.records.len() + out.shed.len(),
            out.offered,
            "trial {trial}: shed + served == offered"
        );
        assert_eq!(out.counters.sheds, out.shed.len(), "trial {trial}: sheds counted");
        let shed_ids: Vec<usize> = out.shed.iter().map(|s| s.id).collect();
        check_served_exactly_once(&out.records, &shed_ids, n);
        check_causality(&out.records);

        // no device runs two attempts in overlapping windows, and its
        // reported busy cycles are exactly the sum of its windows
        let mut by_device: BTreeMap<usize, Vec<(u64, u64)>> = BTreeMap::new();
        for a in &out.attempts {
            assert!(a.start <= a.end, "trial {trial}: inverted attempt window {a:?}");
            by_device.entry(a.device).or_default().push((a.start, a.end));
        }
        for (device, mut windows) in by_device {
            windows.sort();
            for w in windows.windows(2) {
                assert!(
                    w[1].0 >= w[0].1,
                    "trial {trial}: device {device} attempt windows overlap: {windows:?}"
                );
            }
            let busy: u64 = windows.iter().map(|&(s, e)| e - s).sum();
            assert_eq!(
                out.devices[device].busy_cycles, busy,
                "trial {trial}: device {device} busy cycles != sum of attempt windows"
            );
        }
    }
}

/// The two engines agree on every 1-device no-fault schedule, not just
/// hand-picked ones — the randomized form of the pinned differential.
#[test]
fn engines_agree_on_random_single_device_schedules() {
    for trial in 0..20u64 {
        let mut rng = Pcg32::new(0xD1FF, trial);
        let n = 1 + rng.below(50) as usize;
        let kinds = 1 + rng.below(3) as usize;
        let service: Vec<u64> = (0..kinds).map(|_| 50 + rng.below(1500) as u64).collect();
        let policy = random_policy(&mut rng);
        let overhead = rng.below(40) as u64;
        let arrivals = random_arrivals(&mut rng, n, kinds);

        let q = simulate_queue(
            &mut ArrivalSource::open(arrivals.clone()),
            &service,
            policy,
            overhead,
        );
        let f = simulate_fleet(
            &mut ArrivalSource::open(arrivals),
            &service,
            policy,
            overhead,
            &FleetSpec::default(),
        )
        .unwrap();
        assert_eq!(q.records, f.records, "trial {trial}: timelines diverge under {policy:?}");
    }
}
