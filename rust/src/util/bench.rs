//! Micro-benchmark harness — criterion is unavailable offline, so the
//! `cargo bench` targets (`harness = false`) use this small, dependency-
//! free runner: warm-up, calibrated iteration counts, and robust summary
//! statistics (median + MAD instead of mean + stddev).

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters_per_sample: u64,
    pub samples_ns: Vec<f64>,
    pub median_ns: f64,
    pub mad_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.median_ns * 1e-9)
    }
}

pub struct Bencher {
    warmup: Duration,
    target_sample: Duration,
    samples: usize,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(300),
            target_sample: Duration::from_millis(120),
            samples: 12,
            results: Vec::new(),
        }
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher {
            warmup: Duration::from_millis(50),
            target_sample: Duration::from_millis(30),
            samples: 6,
            results: Vec::new(),
        }
    }

    /// Run a closure repeatedly and record a result line.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // Warm-up and calibration: find iters such that one sample takes
        // roughly `target_sample`.
        let warm_start = Instant::now();
        let mut warm_iters = 0u64;
        while warm_start.elapsed() < self.warmup || warm_iters == 0 {
            f();
            warm_iters += 1;
        }
        let per_iter = warm_start.elapsed().as_secs_f64() / warm_iters as f64;
        let iters = ((self.target_sample.as_secs_f64() / per_iter).ceil() as u64).max(1);

        let mut samples_ns = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            samples_ns.push(t0.elapsed().as_nanos() as f64 / iters as f64);
        }
        let mut sorted = samples_ns.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = sorted[sorted.len() / 2];
        let mut dev: Vec<f64> = sorted.iter().map(|s| (s - median).abs()).collect();
        dev.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mad = dev[dev.len() / 2];
        let result = BenchResult {
            name: name.to_string(),
            iters_per_sample: iters,
            min_ns: sorted[0],
            median_ns: median,
            mad_ns: mad,
            samples_ns,
        };
        println!(
            "bench {:<44} {:>12} /iter  (±{:>9}, {} iters x {} samples)",
            result.name,
            fmt_ns(result.median_ns),
            fmt_ns(result.mad_ns),
            iters,
            self.samples,
        );
        self.results.push(result);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.1} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut b = Bencher::quick();
        let mut acc = 0u64;
        let r = b
            .bench("noop-ish", || {
                acc = black_box(acc.wrapping_add(1));
            })
            .clone();
        assert!(r.median_ns > 0.0);
        assert!(r.iters_per_sample >= 1);
    }

    #[test]
    fn fmt_ns_scales() {
        assert!(fmt_ns(12.0).ends_with("ns"));
        assert!(fmt_ns(12_000.0).ends_with("µs"));
        assert!(fmt_ns(12_000_000.0).ends_with("ms"));
        assert!(fmt_ns(2e9).ends_with('s'));
    }
}
