//! DSE prefilter: rank the full sweep grid analytically, simulate only
//! the frontier.
//!
//! A Fig. 5-scale grid is `variants x workloads` jobs; pricing every
//! job with [`super::predict`] costs microseconds per point, so the
//! driver can rank all candidate accelerator variants before a single
//! shard is built, dispatch only the most promising variants through
//! the unchanged `coordinator::shard`/`dispatch` machinery, and report
//! predicted numbers (plus per-job prediction error) for everything it
//! did simulate. The pruned variants keep their analytical stats in
//! the report, so nothing disappears — it just isn't re-derived by
//! stepping cycles.
//!
//! The frontier is chosen at variant granularity (the DSE question is
//! "which configuration wins", not "which workload"), which also keeps
//! the confirmation runs byte-identical to the same variants of an
//! unfiltered sweep — pinned by `tests/model_accuracy.rs`.

use super::{predict_with, Prediction};
use crate::analysis;
use crate::config::PlatformConfig;
use crate::coordinator::cache::{prediction_key, ResultCache};
use crate::coordinator::shard::SweepResult;
use crate::coordinator::JobRequest;
use crate::util::json::Json;

/// One candidate of a prefilterable DSE grid: a platform instance and
/// mechanism variant with its workload jobs.
#[derive(Debug, Clone)]
pub struct GridVariant {
    pub label: String,
    pub cfg: PlatformConfig,
    pub requests: Vec<JobRequest>,
}

/// Analytical pricing of one grid variant.
#[derive(Debug, Clone)]
pub struct VariantPrediction {
    pub label: String,
    /// Per-job predictions, in request order.
    pub predictions: Vec<Prediction>,
    /// Median predicted overall utilization — the ranking key (the
    /// paper's Fig. 5 reports the same statistic of the simulated runs).
    pub median_overall: f64,
    pub mean_cycles: f64,
    /// Diagnostic code from [`analysis::verify_config`] when the grid
    /// point is statically illegal (never priced, never simulated).
    pub statically_rejected: Option<String>,
}

impl VariantPrediction {
    pub fn stats_json(&self) -> Json {
        let overall: Vec<Json> = self
            .predictions
            .iter()
            .map(|p| Json::num(p.overall_utilization))
            .collect();
        Json::obj(vec![
            ("median_overall_utilization", Json::num(self.median_overall)),
            ("mean_cycles", Json::num(self.mean_cycles)),
            ("overall_utilization", Json::arr(overall)),
            (
                "statically_rejected",
                match &self.statically_rejected {
                    Some(code) => Json::str(code),
                    None => Json::Null,
                },
            ),
        ])
    }
}

/// Price every job of every variant analytically, in grid order. A
/// variant whose platform config fails [`analysis::verify_config`] is
/// pruned statically: it carries unschedulable sentinel predictions and
/// the rejecting diagnostic code instead of analytical prices, so it
/// can never rank into the frontier.
pub fn rank(variants: &[GridVariant], csr_latency: u64) -> Vec<VariantPrediction> {
    rank_cached(variants, csr_latency, None)
}

/// [`rank`] with the content-addressed result cache in front of the
/// pricing: each per-job prediction is keyed by
/// [`prediction_key`]`(cfg, csr_latency, request)` and looked up before
/// `predict_with` runs, so re-ranking an unchanged grid under
/// `--cache DIR` re-prices nothing — the same incrementality the
/// simulation tier already has. Statically rejected variants bypass the
/// cache entirely: their sentinel rows were never priced, so there is
/// nothing worth remembering.
pub fn rank_cached(
    variants: &[GridVariant],
    csr_latency: u64,
    cache: Option<&ResultCache>,
) -> Vec<VariantPrediction> {
    variants
        .iter()
        .map(|v| {
            let rejection = analysis::first_error(&analysis::verify_config(&v.cfg))
                .map(|d| d.code.to_string());
            let predictions: Vec<Prediction> = v
                .requests
                .iter()
                .map(|r| {
                    if rejection.is_some() {
                        return Prediction::unschedulable();
                    }
                    let key = cache.map(|c| prediction_key(&v.cfg, csr_latency, r));
                    if let (Some(c), Some(key)) = (cache, &key) {
                        if let Some(p) = c.lookup_prediction(key) {
                            return p;
                        }
                    }
                    let p = predict_with(&v.cfg, r, csr_latency)
                        .unwrap_or_else(|_| Prediction::unschedulable());
                    if let (Some(c), Some(key)) = (cache, &key) {
                        c.insert_prediction(key, &p);
                    }
                    p
                })
                .collect();
            let statically_rejected = rejection;
            let mut ou: Vec<f64> = predictions.iter().map(|p| p.overall_utilization).collect();
            ou.sort_by(f64::total_cmp);
            let median_overall = percentile(&ou, 0.5);
            let n = predictions.len().max(1) as f64;
            let mean_cycles = predictions.iter().map(|p| p.cycles as f64).sum::<f64>() / n;
            VariantPrediction {
                label: v.label.clone(),
                predictions,
                median_overall,
                mean_cycles,
                statically_rejected,
            }
        })
        .collect()
}

/// Indices of the `confirm_top` best-predicted variants, best first.
/// Ties break toward the earlier grid position, so the frontier is
/// deterministic for identical predictions. Statically rejected
/// variants never enter the frontier (the returned set may then be
/// smaller than `confirm_top`, or empty if the whole grid is illegal).
pub fn frontier(ranked: &[VariantPrediction], confirm_top: usize) -> Vec<usize> {
    let mut order: Vec<usize> =
        (0..ranked.len()).filter(|&i| ranked[i].statically_rejected.is_none()).collect();
    order.sort_by(|&a, &b| {
        ranked[b].median_overall.total_cmp(&ranked[a].median_overall).then(a.cmp(&b))
    });
    order.truncate(confirm_top.clamp(1, ranked.len().max(1)));
    order
}

/// Resolve the `--confirm-top K` / `--confirm-frac F` knobs into a
/// variant count (K wins if both are somehow present; F rounds up so a
/// positive fraction always confirms at least one variant).
pub fn confirm_count(
    n_variants: usize,
    confirm_top: Option<usize>,
    confirm_frac: Option<f64>,
) -> usize {
    let k = match (confirm_top, confirm_frac) {
        (Some(k), _) => k,
        (None, Some(f)) => (f * n_variants as f64).ceil() as usize,
        (None, None) => 1,
    };
    k.clamp(1, n_variants.max(1))
}

/// Signed per-job prediction errors against a simulated result
/// (`None` where the job failed), in request order.
pub fn job_errors(predictions: &[Prediction], result: &SweepResult) -> Vec<Option<f64>> {
    predictions
        .iter()
        .zip(result.outcomes.iter())
        .map(|(p, outcome)| {
            outcome
                .as_ref()
                .ok()
                .map(|r| p.cycle_error(r.metrics.total_cycles))
        })
        .collect()
}

/// |error| summary of a confirmed variant.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ErrorSummary {
    pub median_abs: f64,
    pub p95_abs: f64,
    pub max_abs: f64,
}

impl ErrorSummary {
    pub fn from_errors(errors: &[Option<f64>]) -> Option<ErrorSummary> {
        let mut abs: Vec<f64> = errors.iter().flatten().map(|e| e.abs()).collect();
        if abs.is_empty() {
            return None;
        }
        abs.sort_by(f64::total_cmp);
        Some(ErrorSummary {
            median_abs: percentile(&abs, 0.5),
            p95_abs: percentile(&abs, 0.95),
            max_abs: *abs.last().unwrap(),
        })
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("median_abs", Json::num(self.median_abs)),
            ("p95_abs", Json::num(self.p95_abs)),
            ("max_abs", Json::num(self.max_abs)),
        ])
    }
}

/// Nearest-rank percentile of an ascending-sorted sample (`p` in
/// [0, 1]); the same convention the property test pins the error
/// bounds with.
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = ((sorted.len() as f64 * p).ceil() as usize).clamp(1, sorted.len());
    sorted[rank - 1]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::GemmShape;
    use crate::config::Mechanisms;

    fn grid(labels: &[&str]) -> Vec<GridVariant> {
        labels
            .iter()
            .map(|l| GridVariant {
                label: l.to_string(),
                cfg: PlatformConfig::case_study(),
                requests: vec![JobRequest::timing(
                    GemmShape::new(32, 32, 32),
                    Mechanisms::ALL,
                    1,
                )],
            })
            .collect()
    }

    #[test]
    fn frontier_orders_by_predicted_utilization() {
        let variants = grid(&["a", "b", "c"]);
        let mut ranked = rank(&variants, 8);
        // Force a known ordering.
        ranked[0].median_overall = 0.2;
        ranked[1].median_overall = 0.9;
        ranked[2].median_overall = 0.5;
        assert_eq!(frontier(&ranked, 2), vec![1, 2]);
        assert_eq!(frontier(&ranked, 1), vec![1]);
        // Oversized K clamps to the grid.
        assert_eq!(frontier(&ranked, 10), vec![1, 2, 0]);
    }

    #[test]
    fn frontier_breaks_ties_deterministically() {
        let variants = grid(&["a", "b"]);
        let ranked = rank(&variants, 8);
        assert_eq!(ranked[0].median_overall, ranked[1].median_overall);
        assert_eq!(frontier(&ranked, 1), vec![0]);
    }

    #[test]
    fn statically_illegal_variants_are_pruned_not_priced() {
        let mut variants = grid(&["good", "bad"]);
        variants[1].cfg.mem.n_bank = 3; // not a power of two
        let ranked = rank(&variants, 8);
        assert_eq!(ranked[0].statically_rejected, None);
        assert_eq!(ranked[1].statically_rejected.as_deref(), Some("A010-config-invalid"));
        // sentinel predictions only — never priced, never in the frontier
        assert_eq!(ranked[1].median_overall, 0.0);
        assert_eq!(frontier(&ranked, 2), vec![0]);
        let v = ranked[1].stats_json();
        assert_eq!(
            crate::util::json::get_str(&v, "statically_rejected").unwrap(),
            "A010-config-invalid"
        );
    }

    #[test]
    fn rank_cached_is_incremental_and_identical() {
        let variants = grid(&["a", "b"]);
        let cold = rank(&variants, 8);
        let cache = ResultCache::in_memory();
        let warm1 = rank_cached(&variants, 8, Some(&cache));
        // 2 variants x 1 request, all unseen
        assert_eq!((cache.prediction_hits(), cache.prediction_misses()), (0, 2));
        let warm2 = rank_cached(&variants, 8, Some(&cache));
        assert_eq!((cache.prediction_hits(), cache.prediction_misses()), (2, 2));
        for warm in [&warm1, &warm2] {
            for (u, c) in cold.iter().zip(warm.iter()) {
                assert_eq!(u.predictions, c.predictions, "cache must not change the ranking");
                assert_eq!(u.median_overall, c.median_overall);
            }
        }
    }

    #[test]
    fn rank_cached_skips_the_cache_for_rejected_variants() {
        let mut variants = grid(&["bad"]);
        variants[0].cfg.mem.n_bank = 3;
        let cache = ResultCache::in_memory();
        let ranked = rank_cached(&variants, 8, Some(&cache));
        assert!(ranked[0].statically_rejected.is_some());
        assert_eq!((cache.prediction_hits(), cache.prediction_misses()), (0, 0));
    }

    #[test]
    fn confirm_count_resolution() {
        assert_eq!(confirm_count(6, None, None), 1);
        assert_eq!(confirm_count(6, Some(2), None), 2);
        assert_eq!(confirm_count(6, Some(0), None), 1);
        assert_eq!(confirm_count(6, Some(99), None), 6);
        assert_eq!(confirm_count(6, None, Some(0.25)), 2);
        assert_eq!(confirm_count(6, None, Some(1.0)), 6);
        assert_eq!(confirm_count(6, Some(3), Some(0.9)), 3);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.5), 2.0);
        assert_eq!(percentile(&xs, 0.95), 4.0);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        let one = [7.0];
        assert_eq!(percentile(&one, 0.5), 7.0);
    }

    #[test]
    fn error_summary_skips_failed_jobs() {
        let errors = vec![Some(0.01), None, Some(-0.03), Some(0.02)];
        let s = ErrorSummary::from_errors(&errors).unwrap();
        assert_eq!(s.median_abs, 0.02);
        assert_eq!(s.max_abs, 0.03);
        assert!(ErrorSummary::from_errors(&[None]).is_none());
    }
}
