//! The software stack: tiling, data-layout planning, convolution
//! lowering, and RV32I configuration-code generation.
//!
//! `compile_gemm` is the top-level entry: it splits a GeMM over the SPM
//! capacity, plans per-call placements under the chosen layout, and
//! generates the host program that configures and launches every call
//! (with or without configuration pre-loading).

pub mod codegen;
pub mod im2col;
pub mod layout;
pub mod tiling;

pub use codegen::{config_instruction_estimate, gen_config_program, gen_multicore_program, CsrImage};
pub use im2col::{im2col as im2col_transform, weights_to_b, ConvShape};
pub use layout::{pack_a, pack_b, plan, unpack_c, Layout, Placement};
pub use tiling::{call_footprint, split_for_capacity, GemmBlock, GemmShape, SplitError};

use std::sync::Arc;

use crate::config::PlatformConfig;

/// One compiled accelerator call.
#[derive(Debug, Clone)]
pub struct CompiledCall {
    pub block: GemmBlock,
    pub placement: Placement,
}

/// A fully compiled GeMM job: calls + host configuration program.
#[derive(Debug, Clone)]
pub struct CompiledJob {
    pub shape: GemmShape,
    pub layout: Layout,
    pub repeats: u32,
    pub cpl: bool,
    /// GeMM cores the program dispatches over (call `i` runs on core
    /// `i % cores`; 1 on single-core platforms).
    pub cores: usize,
    /// Shared so the simulator can reference the call list per run
    /// without deep-copying every placement (`Arc` clone instead).
    pub calls: Arc<[CompiledCall]>,
    /// RV32I machine code for the host.
    pub program: Vec<u32>,
}

impl CompiledJob {
    /// Total ideal compute cycles per repeat (sum over calls).
    pub fn ideal_cycles(&self, cfg: &PlatformConfig) -> u64 {
        self.calls
            .iter()
            .map(|c| c.block.shape.ideal_cycles(&cfg.core))
            .sum()
    }

    /// Aggregate spatial utilization over all calls (real MACs over
    /// array-slot MACs).
    pub fn spatial_utilization(&self, cfg: &PlatformConfig) -> f64 {
        let real: u64 = self.calls.iter().map(|c| c.block.shape.macs()).sum();
        let padded: u64 = self
            .calls
            .iter()
            .map(|c| c.block.shape.padded_macs(&cfg.core))
            .sum();
        real as f64 / padded as f64
    }
}

/// Compile a GeMM for the platform.
pub fn compile_gemm(
    cfg: &PlatformConfig,
    shape: GemmShape,
    layout: Layout,
    repeats: u32,
    cpl: bool,
) -> Result<CompiledJob, SplitError> {
    let blocks = split_for_capacity(cfg, shape, layout)?;
    // Round-robin dispatch: call i runs on core i % cores, inside that
    // core's SPM partition (placements relocate; the CSR *addresses*
    // stay canonical — codegen adds the per-core window offset).
    let partition = cfg.spm_partition_bytes() as u64;
    let calls: Arc<[CompiledCall]> = blocks
        .into_iter()
        .enumerate()
        .map(|(i, block)| {
            let mut placement = plan(cfg, &block.shape, layout);
            placement.offset_by((i % cfg.cores) as u64 * partition);
            CompiledCall { placement, block }
        })
        .collect();
    let images: Vec<CsrImage> = calls.iter().map(|c| c.placement.csr_writes.clone()).collect();
    let program = gen_multicore_program(&images, repeats, cpl, cfg.cores);
    Ok(CompiledJob { shape, layout, repeats, cpl, cores: cfg.cores, calls, program })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PlatformConfig;

    #[test]
    fn compile_single_call_job() {
        let cfg = PlatformConfig::case_study();
        let job =
            compile_gemm(&cfg, GemmShape::new(64, 64, 64), Layout::TiledInterleaved, 10, true)
                .unwrap();
        assert_eq!(job.calls.len(), 1);
        assert_eq!(job.ideal_cycles(&cfg), 512);
        assert_eq!(job.spatial_utilization(&cfg), 1.0);
        assert!(!job.program.is_empty());
    }

    #[test]
    fn compile_split_job_has_multiple_calls() {
        let cfg = PlatformConfig::case_study();
        let job = compile_gemm(&cfg, GemmShape::new(256, 256, 256), Layout::RowMajor, 1, false)
            .unwrap();
        assert!(job.calls.len() >= 2);
        // per-repeat ideal cycles equal the unsplit ideal (split changes
        // locality, not work)
        assert_eq!(job.ideal_cycles(&cfg), 32 * 32 * 32);
    }

    #[test]
    fn multicore_job_partitions_calls() {
        let mut cfg = PlatformConfig::case_study();
        cfg.cores = 2;
        let job = compile_gemm(&cfg, GemmShape::new(256, 256, 256), Layout::RowMajor, 1, true)
            .unwrap();
        assert!(job.calls.len() >= 2);
        assert_eq!(job.cores, 2);
        let partition = cfg.spm_partition_bytes() as u64;
        for (i, call) in job.calls.iter().enumerate() {
            let lo = (i % 2) as u64 * partition;
            assert!(
                call.placement.a_base >= lo && call.placement.footprint() <= lo + partition,
                "call {i} escapes its partition: [{}, {})",
                call.placement.a_base,
                call.placement.footprint()
            );
        }
        // same job on one core: identical blocks, placements at base 0
        let mut cfg1 = cfg.clone();
        cfg1.cores = 1;
        let job1 = compile_gemm(&cfg1, GemmShape::new(256, 256, 256), Layout::RowMajor, 1, true)
            .unwrap();
        assert!(job1.calls.len() >= job.calls.len());
    }

    #[test]
    fn irregular_shape_su_below_one() {
        let cfg = PlatformConfig::case_study();
        let job = compile_gemm(&cfg, GemmShape::new(13, 22, 17), Layout::TiledInterleaved, 1, true)
            .unwrap();
        let su = job.spatial_utilization(&cfg);
        assert!(su < 1.0 && su > 0.3, "su = {su}");
    }
}
