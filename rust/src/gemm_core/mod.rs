//! The GeMM accelerator core: a 3D MAC array driven by the hardware loop
//! controller, consuming A'/B' tiles from the input streamers and
//! emitting C' tiles to the output streamer (Sec. 2, Fig. 2-3).
//!
//! One call to [`GemmCore::step`] models one core clock cycle.

pub mod dotprod;
pub mod loops;

pub use dotprod::{tile_mac, tile_mac_reference, Accumulators};
pub use loops::{LoopController, LoopError, MAX_LOOP_BOUND};

use crate::config::GemmCoreParams;
use crate::streamer::{InputStreamer, LoopBounds, OutTile, OutputStreamer, TileArena};

/// Why the array did not compute this cycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StallReason {
    /// A' tile not yet in the A pre-fetch buffer.
    InputA,
    /// B' tile not yet in the B pre-fetch buffer.
    InputB,
    /// Output buffer full (writeback backpressure).
    Output,
}

/// Outcome of one core cycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CoreEvent {
    /// Not started (waiting for configuration / start pulse).
    Idle,
    /// Started but stalled.
    Stalled(StallReason),
    /// One tile-MAC issued; `finished` marks the run's last cycle.
    Computed { emitted_output: bool, finished: bool },
}

/// What [`GemmCore::step`] *would* do this cycle, computed without
/// mutating anything — the stall-reason introspection the event-driven
/// fast-forward engine uses to batch-account skipped cycles.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CorePending {
    /// No run in flight.
    Idle,
    /// Started but unable to issue; the reason is stable until a
    /// streamer delivery or writeback-drain event changes the inputs.
    Stalled(StallReason),
    /// A tile-MAC would issue — this cycle must be simulated.
    Compute,
}

/// Per-run compute statistics (the utilization numerators/denominators).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct CoreStats {
    pub compute_cycles: u64,
    pub stall_input_a: u64,
    pub stall_input_b: u64,
    pub stall_output: u64,
    pub output_tiles: u64,
}

impl CoreStats {
    pub fn stall_cycles(&self) -> u64 {
        self.stall_input_a + self.stall_input_b + self.stall_output
    }
}

#[derive(Debug, Clone)]
pub struct GemmCore {
    params: GemmCoreParams,
    lc: Option<LoopController>,
    acc: Accumulators,
    /// Functional mode: actually compute tile MACs (timing-only runs skip
    /// the arithmetic but keep identical cycle behaviour).
    pub functional: bool,
    pub stats: CoreStats,
}

impl GemmCore {
    pub fn new(params: GemmCoreParams, functional: bool) -> GemmCore {
        GemmCore {
            acc: Accumulators::new(&params),
            params,
            lc: None,
            functional,
            stats: CoreStats::default(),
        }
    }

    pub fn params(&self) -> &GemmCoreParams {
        &self.params
    }

    pub fn busy(&self) -> bool {
        self.lc.is_some()
    }

    /// Start a run with the given temporal bounds (the CSR start pulse).
    pub fn start(&mut self, bounds: LoopBounds) -> Result<(), LoopError> {
        assert!(self.lc.is_none(), "start while busy");
        self.lc = Some(LoopController::new(bounds)?);
        Ok(())
    }

    pub fn reset_stats(&mut self) {
        self.stats = CoreStats::default();
    }

    /// Preview of the upcoming [`GemmCore::step`] outcome. Must mirror
    /// the short-circuit order of `step` exactly (A before B before
    /// output backpressure) so batch-accounted stall counters are
    /// bit-identical to stepping cycle by cycle.
    pub fn pending(
        &self,
        a: &InputStreamer,
        b: &InputStreamer,
        out: &OutputStreamer,
    ) -> CorePending {
        let Some(lc) = self.lc.as_ref() else {
            return CorePending::Idle;
        };
        if a.head().is_none() {
            return CorePending::Stalled(StallReason::InputA);
        }
        if b.head().is_none() {
            return CorePending::Stalled(StallReason::InputB);
        }
        if lc.at_k_last() && !out.can_accept() {
            return CorePending::Stalled(StallReason::Output);
        }
        CorePending::Compute
    }

    /// Bulk-account `cycles` stalled cycles of one reason (the
    /// fast-forward engine's replacement for `cycles` repeated
    /// [`GemmCore::step`] calls while stalled).
    pub fn account_stalls(&mut self, reason: StallReason, cycles: u64) {
        match reason {
            StallReason::InputA => self.stats.stall_input_a += cycles,
            StallReason::InputB => self.stats.stall_input_b += cycles,
            StallReason::Output => self.stats.stall_output += cycles,
        }
    }

    /// One core clock cycle. `arena` is the platform's operand-staging
    /// pool: consumed input-tile buffers are released back to it and
    /// the emitted output tile draws its buffer from it (zero
    /// steady-state allocation in functional mode).
    pub fn step(
        &mut self,
        a: &mut InputStreamer,
        b: &mut InputStreamer,
        out: &mut OutputStreamer,
        arena: &mut TileArena,
    ) -> CoreEvent {
        let Some(lc) = self.lc.as_mut() else {
            return CoreEvent::Idle;
        };

        // Operand availability.
        if a.head().is_none() {
            self.stats.stall_input_a += 1;
            return CoreEvent::Stalled(StallReason::InputA);
        }
        if b.head().is_none() {
            self.stats.stall_input_b += 1;
            return CoreEvent::Stalled(StallReason::InputB);
        }
        // Result backpressure: the cycle that finishes an output tile
        // needs a free output-buffer slot.
        if lc.at_k_last() && !out.can_accept() {
            self.stats.stall_output += 1;
            return CoreEvent::Stalled(StallReason::Output);
        }

        let (m1, n1, k1) = lc.current();
        let at_first = lc.at_k_first();
        let at_last = lc.at_k_last();

        let mut a_tile = a.pop().expect("checked above");
        let mut b_tile = b.pop().expect("checked above");
        debug_assert_eq!(
            (a_tile.m1, a_tile.n1, a_tile.k1),
            (m1, n1, k1),
            "A streamer out of sync with loop controller"
        );
        debug_assert_eq!(
            (b_tile.m1, b_tile.n1, b_tile.k1),
            (m1, n1, k1),
            "B streamer out of sync with loop controller"
        );

        if at_first {
            self.acc.reset();
        }
        if self.functional {
            let a_data = a_tile.data.take().expect("functional mode needs A data");
            let b_data = b_tile.data.take().expect("functional mode needs B data");
            tile_mac(&mut self.acc, &self.params, &a_data, &b_data);
            // operand buffers are consumed this cycle; recycle them
            arena.release_i8(a_data);
            arena.release_i8(b_data);
        }

        let mut emitted = false;
        if at_last {
            let data = self.functional.then(|| {
                let mut buf = arena.acquire_i32(self.acc.acc.len());
                self.acc.copy_into(&mut buf);
                buf
            });
            out.accept(OutTile { m1, n1, data });
            self.stats.output_tiles += 1;
            emitted = true;
        }

        self.stats.compute_cycles += 1;
        let more = lc.advance();
        let finished = !more;
        if finished {
            self.lc = None;
        }
        CoreEvent::Computed { emitted_output: emitted, finished }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::streamer::AguConfig;

    fn make_streamers(bounds: LoopBounds, depth: usize) -> (InputStreamer, InputStreamer, OutputStreamer) {
        let mut a = InputStreamer::new(depth, true);
        let mut b = InputStreamer::new(depth, true);
        a.configure(AguConfig::linear(0, 1, 0), bounds);
        b.configure(AguConfig::linear(0, 1, 0), bounds);
        let o = OutputStreamer::new(depth);
        (a, b, o)
    }

    fn feed(s: &mut InputStreamer) {
        let mut addrs = Vec::new();
        while s.wants_fetch(u64::MAX, true) || s.wants_fetch(u64::MAX, false) {
            let pos = s.begin_fetch(8, &mut addrs);
            s.commit_fetch(pos, None, 0, 0);
        }
        s.deliver_ready(u64::MAX);
    }

    #[test]
    fn idle_until_started() {
        let bounds = LoopBounds { mt: 1, nt: 1, kt: 1 };
        let (mut a, mut b, mut o) = make_streamers(bounds, 2);
        let mut arena = TileArena::new();
        let mut core = GemmCore::new(GemmCoreParams::CASE_STUDY, false);
        assert_eq!(core.step(&mut a, &mut b, &mut o, &mut arena), CoreEvent::Idle);
    }

    #[test]
    fn stalls_without_operands() {
        let bounds = LoopBounds { mt: 1, nt: 1, kt: 2 };
        let (mut a, mut b, mut o) = make_streamers(bounds, 2);
        let mut arena = TileArena::new();
        let mut core = GemmCore::new(GemmCoreParams::CASE_STUDY, false);
        core.start(bounds).unwrap();
        assert_eq!(
            core.step(&mut a, &mut b, &mut o, &mut arena),
            CoreEvent::Stalled(StallReason::InputA)
        );
        feed(&mut a);
        assert_eq!(
            core.step(&mut a, &mut b, &mut o, &mut arena),
            CoreEvent::Stalled(StallReason::InputB)
        );
        feed(&mut b);
        assert!(matches!(
            core.step(&mut a, &mut b, &mut o, &mut arena),
            CoreEvent::Computed { .. }
        ));
        assert_eq!(core.stats.stall_input_a, 1);
        assert_eq!(core.stats.stall_input_b, 1);
    }

    #[test]
    fn full_run_produces_all_output_tiles() {
        let bounds = LoopBounds { mt: 2, nt: 3, kt: 4 };
        let (mut a, mut b, mut o) = make_streamers(bounds, 4);
        let mut arena = TileArena::new();
        let mut core = GemmCore::new(GemmCoreParams::CASE_STUDY, false);
        core.start(bounds).unwrap();
        let mut outputs = 0;
        let mut cycles = 0;
        while core.busy() {
            feed(&mut a);
            feed(&mut b);
            // drain the output buffer continuously
            if o.wants_write(0) {
                let mut addrs = Vec::new();
                let t = o.begin_write(8, &mut addrs);
                o.commit_write(t, 0, 0);
                o.deliver_ready(u64::MAX);
            }
            match core.step(&mut a, &mut b, &mut o, &mut arena) {
                CoreEvent::Computed { emitted_output, .. } => {
                    outputs += emitted_output as u64;
                    cycles += 1;
                }
                e => panic!("unexpected event {e:?}"),
            }
        }
        assert_eq!(outputs, 6);
        assert_eq!(cycles, 24); // one cycle per tile-MAC, zero stalls
        assert_eq!(core.stats.compute_cycles, 24);
        assert_eq!(core.stats.output_tiles, 6);
    }

    #[test]
    fn output_backpressure_stalls_only_k_last() {
        let bounds = LoopBounds { mt: 1, nt: 1, kt: 3 };
        let (mut a, mut b, mut o) = make_streamers(bounds, 4);
        let mut arena = TileArena::new();
        let mut core = GemmCore::new(GemmCoreParams::CASE_STUDY, false);
        core.start(bounds).unwrap();
        feed(&mut a);
        feed(&mut b);
        // fill the output buffer so it cannot accept
        while o.can_accept() {
            o.accept(OutTile { m1: 9, n1: 9, data: None });
        }
        // k=0,1 compute fine
        assert!(matches!(
            core.step(&mut a, &mut b, &mut o, &mut arena),
            CoreEvent::Computed { .. }
        ));
        assert!(matches!(
            core.step(&mut a, &mut b, &mut o, &mut arena),
            CoreEvent::Computed { .. }
        ));
        // k=2 (k_last) stalls on output
        assert_eq!(
            core.step(&mut a, &mut b, &mut o, &mut arena),
            CoreEvent::Stalled(StallReason::Output)
        );
    }

    #[test]
    fn functional_mode_computes_known_product() {
        let params = GemmCoreParams::CASE_STUDY;
        let bounds = LoopBounds { mt: 1, nt: 1, kt: 2 };
        let mut a = InputStreamer::new(4, true);
        let mut b = InputStreamer::new(4, true);
        a.configure(AguConfig::linear(0, 1, 0), bounds);
        b.configure(AguConfig::linear(0, 1, 0), bounds);
        let mut o = OutputStreamer::new(2);
        let mut arena = TileArena::new();
        let mut core = GemmCore::new(params, true);
        core.start(bounds).unwrap();
        let mut addrs = Vec::new();
        for s in [&mut a, &mut b] {
            while s.wants_fetch(u64::MAX, true) {
                let pos = s.begin_fetch(8, &mut addrs);
                s.commit_fetch(pos, Some(vec![1i8; 64].into_boxed_slice()), 0, 0);
            }
            s.deliver_ready(u64::MAX);
        }
        while core.busy() {
            core.step(&mut a, &mut b, &mut o, &mut arena);
        }
        // the only arena allocation is the single C' output buffer; the
        // consumed operand buffers were released back to the pool
        assert_eq!(arena.allocs, 1);
        let mut w = Vec::new();
        let tile = o.begin_write(8, &mut w);
        let data = tile.data.clone().unwrap();
        o.commit_write(tile, 0, 0);
        // ones(8,8) @ ones(8,8) accumulated over kt=2: every entry = 16
        assert!(data.iter().all(|&v| v == 16));
    }

    #[test]
    fn pending_mirrors_step() {
        let bounds = LoopBounds { mt: 1, nt: 1, kt: 2 };
        let (mut a, mut b, mut o) = make_streamers(bounds, 2);
        let mut arena = TileArena::new();
        let mut core = GemmCore::new(GemmCoreParams::CASE_STUDY, false);
        assert_eq!(core.pending(&a, &b, &o), CorePending::Idle);
        core.start(bounds).unwrap();
        assert_eq!(core.pending(&a, &b, &o), CorePending::Stalled(StallReason::InputA));
        feed(&mut a);
        assert_eq!(core.pending(&a, &b, &o), CorePending::Stalled(StallReason::InputB));
        feed(&mut b);
        assert_eq!(core.pending(&a, &b, &o), CorePending::Compute);
        // k_last with a full output buffer -> Output stall preview
        assert!(matches!(
            core.step(&mut a, &mut b, &mut o, &mut arena),
            CoreEvent::Computed { .. }
        ));
        while o.can_accept() {
            o.accept(OutTile { m1: 0, n1: 0, data: None });
        }
        assert_eq!(core.pending(&a, &b, &o), CorePending::Stalled(StallReason::Output));
        assert_eq!(
            core.step(&mut a, &mut b, &mut o, &mut arena),
            CoreEvent::Stalled(StallReason::Output)
        );
    }

    #[test]
    fn account_stalls_bulk_matches_counters() {
        let mut core = GemmCore::new(GemmCoreParams::CASE_STUDY, false);
        core.account_stalls(StallReason::InputA, 5);
        core.account_stalls(StallReason::InputB, 2);
        core.account_stalls(StallReason::Output, 3);
        assert_eq!(core.stats.stall_input_a, 5);
        assert_eq!(core.stats.stall_input_b, 2);
        assert_eq!(core.stats.stall_output, 3);
        assert_eq!(core.stats.stall_cycles(), 10);
    }

    #[test]
    #[should_panic(expected = "start while busy")]
    fn double_start_panics() {
        let bounds = LoopBounds { mt: 1, nt: 1, kt: 1 };
        let mut core = GemmCore::new(GemmCoreParams::CASE_STUDY, false);
        core.start(bounds).unwrap();
        core.start(bounds).unwrap();
    }
}
